#!/usr/bin/env sh
# Design-doc citation checking now lives in the `design-ref` rule of
# `tools/zipcache-lint` (DESIGN.md §13); this wrapper is kept so existing
# invocations (and muscle memory) keep working.  Run from the repo root.
set -eu
exec cargo run -q -p zipcache-lint -- --rule design-ref \
    rust python examples tools Cargo.toml vendor
