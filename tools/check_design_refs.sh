#!/usr/bin/env sh
# Verify that every `DESIGN.md §N` citation in the source tree resolves to
# a real `## §N` section heading in DESIGN.md.  Run from the repo root.
set -eu

design="DESIGN.md"
if [ ! -f "$design" ]; then
    echo "FAIL: $design missing" >&2
    exit 1
fi

fail=0
# Collect cited section numbers, e.g. `DESIGN.md §5` -> 5.
refs=$(grep -rhoE 'DESIGN\.md §[0-9]+' rust python examples tools Cargo.toml vendor 2>/dev/null \
    | sed 's/.*§//' | sort -un)

if [ -z "$refs" ]; then
    echo "FAIL: no DESIGN.md § references found (checker misconfigured?)" >&2
    exit 1
fi

for n in $refs; do
    if grep -qE "^## §$n " "$design"; then
        echo "ok: DESIGN.md §$n"
    else
        echo "FAIL: DESIGN.md §$n is cited but has no '## §$n' section" >&2
        fail=1
    fi
done
exit $fail
