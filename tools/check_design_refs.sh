#!/usr/bin/env sh
# Verify that every `DESIGN.md §N` citation in the source tree resolves to
# a real `## §N` section heading in DESIGN.md, and that every named
# EXPERIMENTS.md section citation resolves to a `## §<section>` heading
# in EXPERIMENTS.md.  Run from the repo root.
set -eu

fail=0

design="DESIGN.md"
if [ ! -f "$design" ]; then
    echo "FAIL: $design missing" >&2
    exit 1
fi

# Collect cited section numbers, e.g. `DESIGN.md §5` -> 5.
refs=$(grep -rhoE 'DESIGN\.md §[0-9]+' rust python examples tools Cargo.toml vendor 2>/dev/null \
    | sed 's/.*§//' | sort -un)

if [ -z "$refs" ]; then
    echo "FAIL: no DESIGN.md § references found (checker misconfigured?)" >&2
    exit 1
fi

for n in $refs; do
    if grep -qE "^## §$n " "$design"; then
        echo "ok: DESIGN.md §$n"
    else
        echo "FAIL: DESIGN.md §$n is cited but has no '## §$n' section" >&2
        fail=1
    fi
done

experiments="EXPERIMENTS.md"
# Named sections, e.g. `EXPERIMENTS.md §Perf` -> Perf.
erefs=$(grep -rhoE 'EXPERIMENTS\.md §[A-Za-z][A-Za-z0-9_-]*' rust python examples tools Cargo.toml vendor 2>/dev/null \
    | sed 's/.*§//' | sort -u)

if [ -n "$erefs" ]; then
    if [ ! -f "$experiments" ]; then
        echo "FAIL: EXPERIMENTS.md is cited but missing" >&2
        exit 1
    fi
    for name in $erefs; do
        if grep -qE "^## §$name( |$)" "$experiments"; then
            echo "ok: EXPERIMENTS.md §$name"
        else
            echo "FAIL: EXPERIMENTS.md §$name is cited but has no '## §$name' section" >&2
            fail=1
        fi
    done
fi

exit $fail
