//! Item indexer: lint directives, function items, impl owners, call
//! sites, gauge registrations, and `#[cfg(test)]` regions (DESIGN.md
//! §13).
//!
//! Runs on the cleaned code/comment channels produced by
//! [`crate::lexer`].  The structural pass is brace-depth tracking over
//! code tokens — deliberately an approximation, not a parser: it
//! recognizes `impl` headers (for method ownership), `fn` items (name,
//! body line range), and `#[cfg(test)]`-gated blocks, which is exactly
//! what the rules need.  Known limits are documented in DESIGN.md §13.

use crate::lexer::{self, Line};

/// One code token: an identifier/number word or a single punctuation
/// character.  Whitespace is dropped.
#[derive(Debug, Clone, PartialEq)]
pub enum Tok {
    Word(String),
    P(char),
}

impl Tok {
    fn word(&self) -> Option<&str> {
        match self {
            Tok::Word(w) => Some(w.as_str()),
            Tok::P(_) => None,
        }
    }
}

/// Tokenize one cleaned code line.
pub fn tokenize(code: &str) -> Vec<Tok> {
    let chars: Vec<char> = code.chars().collect();
    let mut toks = Vec::new();
    let mut i = 0usize;
    while i < chars.len() {
        let c = chars[i];
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        if c.is_alphanumeric() || c == '_' {
            let mut w = String::new();
            while i < chars.len() && (chars[i].is_alphanumeric() || chars[i] == '_') {
                w.push(chars[i]);
                i += 1;
            }
            toks.push(Tok::Word(w));
            continue;
        }
        toks.push(Tok::P(c));
        i += 1;
    }
    toks
}

/// A call site observed inside a function body.
#[derive(Debug, Clone)]
pub struct Call {
    pub name: String,
    /// `Foo::bar(…)` records `Some("Foo")`; `Self` is resolved to the
    /// enclosing impl owner at extraction time.
    pub qualifier: Option<String>,
    /// `.bar(…)` — a method call on some receiver.
    pub method: bool,
    /// `bar::<T>(…)` — turbofish; flagged for allocation matching but
    /// never resolved for call-graph descent (DESIGN.md §13).
    pub turbofish: bool,
    /// `bar!(…)` — macro invocation.
    pub is_macro: bool,
    /// 1-based source line.
    pub line: usize,
}

/// A function item (or bodyless trait signature).
#[derive(Debug, Clone)]
pub struct FnItem {
    pub name: String,
    /// Enclosing `impl` type, when any.
    pub owner: Option<String>,
    /// 1-based line of the `fn` keyword.
    pub line: usize,
    /// 1-based inclusive body line range; `None` for bodyless sigs.
    pub body: Option<(usize, usize)>,
    /// Marked `// lint: hot-path` — a traversal root.
    pub hot: bool,
    /// Marked `// lint: cold-path` — traversal stops here.
    pub cold: bool,
    /// Inside a `#[cfg(test)]` region (or itself `#[cfg(test)]`).
    pub in_test: bool,
    pub calls: Vec<Call>,
}

/// A `// lint: gauge` registration attached to an atomic field/static.
#[derive(Debug, Clone)]
pub struct Gauge {
    pub name: String,
    pub line: usize,
}

/// A parsed `lint-allow(rule): reason` suppression.
#[derive(Debug, Clone)]
pub struct Suppression {
    pub rule: String,
    pub reason: String,
    /// 1-based code line the suppression applies to.
    pub line: usize,
    /// 1-based line the directive itself was written on.
    pub at: usize,
}

/// Everything the rules need to know about one Rust source file.
#[derive(Debug)]
pub struct FileIndex {
    pub lines: Vec<Line>,
    pub fns: Vec<FnItem>,
    pub gauges: Vec<Gauge>,
    pub suppressions: Vec<Suppression>,
    /// Per line (0-based): inside a `#[cfg(test)]` region.
    pub test_lines: Vec<bool>,
}

impl FileIndex {
    /// The suppression covering `line` for `rule`, if any.
    pub fn allow_for(&self, line: usize, rule: &str) -> Option<&Suppression> {
        self.suppressions.iter().find(|s| s.line == line && s.rule == rule)
    }
}

const KEYWORDS: &[&str] = &[
    "if", "while", "for", "match", "return", "loop", "in", "as", "let", "mut", "ref", "pub",
    "use", "mod", "impl", "struct", "enum", "trait", "type", "where", "const", "static", "dyn",
    "break", "continue", "else", "fn", "unsafe", "move", "crate", "self", "super", "true",
    "false", "await", "async",
];

/// Build the full index for one source file.
pub fn index_file(src: &str) -> FileIndex {
    let lines = lexer::lex(src);
    let nlines = lines.len();

    // --- directive pass (comments) -----------------------------------
    let mut suppressions = Vec::new();
    let mut hot_marks = Vec::new();
    let mut cold_marks = Vec::new();
    let mut gauge_marks = Vec::new();
    for (l0, line) in lines.iter().enumerate() {
        let at = l0 + 1;
        let target = directive_target(&lines, l0);
        let mut rest = line.comment.as_str();
        while let Some(p) = rest.find("lint-allow(") {
            rest = &rest[p + "lint-allow(".len()..];
            if let Some(close) = rest.find(')') {
                let rule = rest[..close].trim().to_string();
                rest = &rest[close + 1..];
                let mut reason = rest;
                if let Some(colon) = reason.find(':') {
                    reason = &reason[colon + 1..];
                }
                if let Some(next) = reason.find("lint-allow(") {
                    reason = &reason[..next];
                }
                suppressions.push(Suppression {
                    rule,
                    reason: reason.trim().to_string(),
                    line: target,
                    at,
                });
            } else {
                break;
            }
        }
        // Anchors must *start* the comment, so prose that merely
        // mentions `lint: hot-path` (this crate's own docs) is inert.
        let ct = line.comment.trim_start();
        if ct.starts_with("lint: hot-path") {
            hot_marks.push(target);
        }
        if ct.starts_with("lint: cold-path") {
            cold_marks.push(target);
        }
        if ct.starts_with("lint: gauge") {
            gauge_marks.push(target);
        }
    }

    // --- gauge registrations -----------------------------------------
    let mut gauges = Vec::new();
    for &line in &gauge_marks {
        if let Some(name) = field_name(&lines[line - 1].code) {
            gauges.push(Gauge { name, line });
        }
    }

    // --- structural pass ---------------------------------------------
    let mut fns: Vec<FnItem> = Vec::new();
    let mut test_lines = vec![false; nlines];

    let mut depth: i32 = 0;
    let mut paren: i32 = 0;
    // (owner name, depth inside the impl block)
    let mut impl_stack: Vec<(String, i32)> = Vec::new();
    // Header tokens collected between `impl` and its `{`.
    let mut impl_collect: Option<Vec<Tok>> = None;
    // `fn` seen; waiting for the name, then for `{` or `;`.
    let mut pending_fn: Option<(Option<String>, usize)> = None;
    // `#[cfg(test)]` seen; next block at item level opens a test region.
    let mut pending_test = false;
    let mut pending_test_fn = false;
    // Depths (inside the block) of open test regions.
    let mut test_stack: Vec<i32> = Vec::new();
    // Open fn bodies: (index into fns, depth inside the body).
    let mut open_fns: Vec<(usize, i32)> = Vec::new();

    for l0 in 0..nlines {
        let lineno = l0 + 1;
        if !test_stack.is_empty() {
            test_lines[l0] = true;
        }
        let code = &lines[l0].code;
        if code.contains("#[cfg(test)]") {
            pending_test = true;
        }
        let toks = tokenize(code);
        let mut k = 0usize;
        while k < toks.len() {
            match &toks[k] {
                Tok::Word(w) => {
                    // `fn` directly followed by `(` is a pointer type
                    // (`fn(usize) -> u8`), not an item.
                    let fn_item = w == "fn" && toks.get(k + 1).map_or(true, |t| matches!(t, Tok::Word(_)));
                    if fn_item && pending_fn.is_none() {
                        pending_fn = Some((None, lineno));
                        if pending_test {
                            // `#[cfg(test)] fn …`: the fn itself is the
                            // gated item.
                            pending_test_fn = true;
                        }
                    } else if let Some((name @ None, _)) = &mut pending_fn {
                        if w != "fn" {
                            *name = Some(w.clone());
                        }
                    } else if w == "impl" && pending_fn.is_none() && impl_collect.is_none() {
                        impl_collect = Some(Vec::new());
                    } else if let Some(c) = &mut impl_collect {
                        c.push(toks[k].clone());
                    }
                }
                Tok::P('(') => paren += 1,
                Tok::P(')') => paren -= 1,
                Tok::P('{') => {
                    depth += 1;
                    if impl_collect.is_some() && pending_fn.is_none() {
                        let header = impl_collect.take().unwrap();
                        impl_stack.push((impl_owner_name(&header), depth));
                    } else if let Some((name, fnline)) = pending_fn.take() {
                        let name = name.unwrap_or_default();
                        let in_test = !test_stack.is_empty() || pending_test_fn;
                        fns.push(FnItem {
                            name,
                            owner: impl_stack.last().map(|(n, _)| n.clone()),
                            line: fnline,
                            body: Some((lineno, lineno)),
                            hot: hot_marks.contains(&fnline),
                            cold: cold_marks.contains(&fnline),
                            in_test,
                            calls: Vec::new(),
                        });
                        open_fns.push((fns.len() - 1, depth));
                        if pending_test_fn {
                            test_stack.push(depth);
                            pending_test_fn = false;
                            pending_test = false;
                            test_lines[l0] = true;
                        }
                    }
                    if pending_test {
                        test_stack.push(depth);
                        pending_test = false;
                        test_lines[l0] = true;
                    }
                    if let Some(c) = &mut impl_collect {
                        // `{` inside an impl header can only come from a
                        // const-generic default — treat as opaque.
                        c.push(Tok::P('{'));
                    }
                }
                Tok::P('}') => {
                    depth -= 1;
                    while test_stack.last().is_some_and(|&d| depth < d) {
                        test_stack.pop();
                    }
                    while open_fns.last().is_some_and(|&(_, d)| depth < d) {
                        let (fi, _) = open_fns.pop().unwrap();
                        if let Some((_, end)) = &mut fns[fi].body {
                            *end = lineno;
                        }
                    }
                    while impl_stack.last().is_some_and(|&(_, d)| depth < d) {
                        impl_stack.pop();
                    }
                }
                Tok::P(';') => {
                    if paren == 0 {
                        if let Some((Some(name), fnline)) = pending_fn.take() {
                            // Bodyless trait signature.
                            fns.push(FnItem {
                                name,
                                owner: impl_stack.last().map(|(n, _)| n.clone()),
                                line: fnline,
                                body: None,
                                hot: false,
                                cold: false,
                                in_test: !test_stack.is_empty(),
                                calls: Vec::new(),
                            });
                        }
                        pending_test = false;
                        pending_test_fn = false;
                        if open_fns.is_empty() {
                            // `impl Trait for X;` cannot occur; a `;` at
                            // item level abandons any stale header.
                            impl_collect = None;
                        }
                    }
                }
                Tok::P(p) => {
                    if let Some(c) = &mut impl_collect {
                        c.push(Tok::P(*p));
                    }
                }
            }
            k += 1;
        }
    }
    // Close anything left open at EOF (unbalanced input).
    while let Some((fi, _)) = open_fns.pop() {
        if let Some((_, end)) = &mut fns[fi].body {
            *end = nlines;
        }
    }

    // --- call extraction ---------------------------------------------
    for f in &mut fns {
        if let Some((start, end)) = f.body {
            let owner = f.owner.clone();
            for lineno in start..=end {
                let toks = tokenize(&lines[lineno - 1].code);
                extract_calls(&toks, lineno, owner.as_deref(), &mut f.calls);
            }
        }
    }

    FileIndex { lines, fns, gauges, suppressions, test_lines }
}

/// The 1-based code line a comment directive on (0-based) line `l0`
/// applies to: the line itself when it carries code, else the next line
/// that does — skipping attribute lines (`#[inline]`, `#[derive(…)]`)
/// so an anchor above an attributed fn still lands on the `fn` line.
fn directive_target(lines: &[Line], l0: usize) -> usize {
    let ct = lines[l0].code.trim();
    if !ct.is_empty() && !ct.starts_with("#[") {
        return l0 + 1;
    }
    let mut j = l0 + 1;
    while j < lines.len() {
        let ct = lines[j].code.trim();
        if !ct.is_empty() && !ct.starts_with("#[") {
            return j + 1;
        }
        j += 1;
    }
    l0 + 1
}

/// Parse the field/static name out of a declaration line like
/// `pub(crate) queued: AtomicUsize,` or `static NEXT: AtomicU64 = …;`.
fn field_name(code: &str) -> Option<String> {
    let toks = tokenize(code);
    let mut k = 0usize;
    while k < toks.len() {
        match &toks[k] {
            Tok::Word(w) if w == "pub" => {
                k += 1;
                if toks.get(k) == Some(&Tok::P('(')) {
                    while k < toks.len() && toks[k] != Tok::P(')') {
                        k += 1;
                    }
                    k += 1;
                }
            }
            Tok::Word(w) if w == "static" || w == "let" || w == "mut" || w == "const" => k += 1,
            Tok::Word(w) => return Some(w.clone()),
            _ => return None,
        }
    }
    None
}

/// Extract the owner type name from the tokens of an `impl` header
/// (everything between `impl` and `{`): skips leading generics, honours
/// `Trait for Type`, and keeps the last path segment.
fn impl_owner_name(toks: &[Tok]) -> String {
    let mut i = 0usize;
    if toks.first() == Some(&Tok::P('<')) {
        let mut d = 0i32;
        while i < toks.len() {
            match toks[i] {
                Tok::P('<') => d += 1,
                Tok::P('>') => d -= 1,
                _ => {}
            }
            i += 1;
            if d == 0 {
                break;
            }
        }
    }
    let mut start = i;
    let mut d = 0i32;
    for (j, t) in toks.iter().enumerate().skip(i) {
        match t {
            Tok::P('<') => d += 1,
            Tok::P('>') => d -= 1,
            Tok::Word(w) if w == "for" && d == 0 => start = j + 1,
            _ => {}
        }
    }
    let mut name = String::new();
    let mut k = start;
    while k < toks.len() {
        match &toks[k] {
            Tok::Word(w) if w == "dyn" || w == "mut" => k += 1,
            Tok::Word(w) => {
                name = w.clone();
                k += 1;
            }
            Tok::P(':') | Tok::P('&') => k += 1,
            _ => break,
        }
    }
    name
}

/// Scan one token line for call sites and append them to `out`.
fn extract_calls(toks: &[Tok], line: usize, owner: Option<&str>, out: &mut Vec<Call>) {
    for k in 0..toks.len() {
        let name = match toks[k].word() {
            Some(w) => w,
            None => continue,
        };
        if name.starts_with(|c: char| c.is_ascii_digit()) || KEYWORDS.contains(&name) {
            continue;
        }
        if k > 0 && toks[k - 1].word() == Some("fn") {
            continue;
        }
        let next = toks.get(k + 1);
        // A macro call needs a delimiter after the `!`, so that `a != b`
        // is not read as macro `a`.
        let is_macro = next == Some(&Tok::P('!'))
            && matches!(toks.get(k + 2), Some(Tok::P('(')) | Some(Tok::P('[')) | Some(Tok::P('{')));
        let direct_call = next == Some(&Tok::P('('));
        let turbofish = !direct_call
            && next == Some(&Tok::P(':'))
            && toks.get(k + 2) == Some(&Tok::P(':'))
            && toks.get(k + 3) == Some(&Tok::P('<'));
        if !is_macro && !direct_call && !turbofish {
            continue;
        }
        let method = k > 0 && toks[k - 1] == Tok::P('.');
        let mut qualifier = None;
        if !method && k >= 3 && toks[k - 1] == Tok::P(':') && toks[k - 2] == Tok::P(':') {
            if let Some(q) = toks[k - 3].word() {
                let q = if q == "Self" { owner.unwrap_or(q) } else { q };
                qualifier = Some(q.to_string());
            }
        }
        out.push(Call {
            name: name.to_string(),
            qualifier,
            method,
            turbofish,
            is_macro,
            line,
        });
    }
}
