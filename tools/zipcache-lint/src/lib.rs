//! zipcache-lint — repo-local static analysis for the ZipCache tree
//! (DESIGN.md §13).
//!
//! A dependency-free lexer-level analyzer that machine-checks the
//! invariants the dynamic gates only probe in one configuration:
//!
//! - `hot-path-alloc` — the zero-allocation steady decode contract
//!   (DESIGN.md §9): from `// lint: hot-path` roots, transitively flag
//!   allocation constructors.
//! - `balanced-accounting` — every `// lint: gauge` atomic
//!   (queue depth, byte reservations, slot counts) has both an
//!   increment and a release in its module group (DESIGN.md §10).
//! - `undocumented-unsafe` — every `unsafe` carries a `// SAFETY:`
//!   comment.
//! - `design-ref` — `DESIGN.md §<N>` / `EXPERIMENTS.md §<Name>`
//!   citations and `INVARIANT(§<N>)` tags resolve, bidirectionally for
//!   DESIGN.md.
//!
//! Pipeline: [`lexer`] (comment/string-aware line splitter) →
//! [`index`] (items, calls, directives) → [`rules`] → [`report`].
//! Suppressions are explicit and audited: `// lint-allow(rule): reason`
//! on the offending line, counted in the report.

pub mod index;
pub mod lexer;
pub mod report;
pub mod rules;

use std::fs;
use std::io;
use std::path::{Path, PathBuf};

pub use report::{Finding, Report};

/// Directory names never descended into: VCS state, build output,
/// Python caches, and the lint's own known-bad test fixtures.
const SKIP_DIRS: &[&str] = &[".git", "target", "fixtures", "__pycache__", "node_modules"];

/// One scanned file.  Non-Rust files carry raw text only (scanned by
/// `design-ref`); Rust files additionally carry the full index.
pub struct SourceFile {
    /// Path as reported in findings (scan-root-relative).
    pub display: String,
    /// Accounting module group: scan root plus first directory
    /// component, so `rust/src/server/dispatch.rs` and
    /// `rust/src/server/mod.rs` pair up (DESIGN.md §13).
    pub group: String,
    pub raw: String,
    pub rust: Option<index::FileIndex>,
}

/// One lint invocation.
pub struct Options {
    /// Files or directories to scan (default: `rust/src`).
    pub paths: Vec<PathBuf>,
    /// Where DESIGN.md / EXPERIMENTS.md live (default: `.`).
    pub docs_root: PathBuf,
    /// Rule names to run; empty means all.
    pub rules: Vec<String>,
}

impl Default for Options {
    fn default() -> Self {
        Options {
            paths: vec![PathBuf::from("rust/src")],
            docs_root: PathBuf::from("."),
            rules: Vec::new(),
        }
    }
}

/// Run the configured rules over the scan roots and return the report.
pub fn run(opts: &Options) -> io::Result<Report> {
    let mut files = Vec::new();
    for root in &opts.paths {
        collect(root, root, &mut files)?;
    }
    // Deterministic order regardless of directory iteration order.
    files.sort_by(|a, b| a.display.cmp(&b.display));

    let rules_run: Vec<String> = if opts.rules.is_empty() {
        rules::ALL_RULES.iter().map(|r| r.to_string()).collect()
    } else {
        opts.rules.clone()
    };

    let mut findings = Vec::new();
    for rule in &rules_run {
        match rule.as_str() {
            rules::HOT_PATH_ALLOC => rules::hot_path_alloc(&files, &mut findings),
            rules::BALANCED_ACCOUNTING => rules::balanced_accounting(&files, &mut findings),
            rules::UNDOCUMENTED_UNSAFE => rules::undocumented_unsafe(&files, &mut findings),
            rules::DESIGN_REF => {
                let design = fs::read_to_string(opts.docs_root.join("DESIGN.md")).ok();
                let experiments = fs::read_to_string(opts.docs_root.join("EXPERIMENTS.md")).ok();
                rules::design_ref(
                    &files,
                    design.as_deref(),
                    experiments.as_deref(),
                    &mut findings,
                );
            }
            other => {
                return Err(io::Error::new(
                    io::ErrorKind::InvalidInput,
                    format!("unknown rule `{other}` (see --list-rules)"),
                ));
            }
        }
    }
    findings.sort_by(|a, b| {
        (&a.file, a.line, &a.rule).cmp(&(&b.file, b.line, &b.rule))
    });

    let mut roots = Vec::new();
    let mut gauges = Vec::new();
    for file in &files {
        if let Some(ix) = &file.rust {
            for f in &ix.fns {
                if f.hot && !f.in_test {
                    match &f.owner {
                        Some(o) => roots.push(format!("{o}::{}", f.name)),
                        None => roots.push(f.name.clone()),
                    }
                }
            }
            for g in &ix.gauges {
                gauges.push(g.name.clone());
            }
        }
    }
    roots.sort();
    gauges.sort();

    Ok(Report { findings, roots, gauges, files_scanned: files.len(), rules_run })
}

/// Recursively collect scannable files under `path` (itself a file or a
/// directory), skipping [`SKIP_DIRS`] and hidden directories.
fn collect(root: &Path, path: &Path, out: &mut Vec<SourceFile>) -> io::Result<()> {
    let meta = fs::metadata(path).map_err(|e| {
        io::Error::new(e.kind(), format!("cannot scan {}: {e}", path.display()))
    })?;
    if meta.is_file() {
        let raw = match fs::read_to_string(path) {
            Ok(raw) => raw,
            // Binary or non-UTF-8 content is out of scope.
            Err(_) => return Ok(()),
        };
        let display = path.to_string_lossy().replace('\\', "/");
        let group = group_of(root, path);
        let rust = if path.extension().is_some_and(|e| e == "rs") {
            Some(index::index_file(&raw))
        } else {
            None
        };
        out.push(SourceFile { display, group, raw, rust });
        return Ok(());
    }
    let mut entries: Vec<PathBuf> =
        fs::read_dir(path)?.filter_map(|e| e.ok().map(|e| e.path())).collect();
    entries.sort();
    for entry in entries {
        let name = entry.file_name().and_then(|n| n.to_str()).unwrap_or("");
        if entry.is_dir() && (SKIP_DIRS.contains(&name) || name.starts_with('.')) {
            continue;
        }
        collect(root, &entry, out)?;
    }
    Ok(())
}

/// The accounting module group: scan root plus the first directory
/// component of the path below it.
fn group_of(root: &Path, path: &Path) -> String {
    let base = root.to_string_lossy().replace('\\', "/");
    match path.strip_prefix(root) {
        Ok(rel) => {
            let rel = rel.to_string_lossy().replace('\\', "/");
            match rel.split('/').next() {
                Some(first) if rel.contains('/') => format!("{base}/{first}"),
                _ => base,
            }
        }
        Err(_) => base,
    }
}
