//! The rule engine: four named, suppressible rules over the indexed
//! tree (DESIGN.md §13).
//!
//! Every rule pushes [`Finding`]s; suppression (`lint-allow(rule):
//! reason` on the offending line) is resolved here so the report can
//! count allows explicitly instead of silently dropping them.

use std::collections::{HashMap, HashSet, VecDeque};

use crate::index::{Tok, tokenize};
use crate::report::Finding;
use crate::SourceFile;

pub const HOT_PATH_ALLOC: &str = "hot-path-alloc";
pub const BALANCED_ACCOUNTING: &str = "balanced-accounting";
pub const UNDOCUMENTED_UNSAFE: &str = "undocumented-unsafe";
pub const DESIGN_REF: &str = "design-ref";

pub const ALL_RULES: &[&str] =
    &[HOT_PATH_ALLOC, BALANCED_ACCOUNTING, UNDOCUMENTED_UNSAFE, DESIGN_REF];

/// Attach the suppression state for (`file`, `line`, `rule`) to a
/// finding under construction.
fn finish(file: &SourceFile, rule: &str, line: usize, message: String) -> Finding {
    let allow = file
        .rust
        .as_ref()
        .and_then(|ix| ix.allow_for(line, rule))
        .map(|s| s.reason.clone())
        .or_else(|| raw_allow(file, line, rule));
    Finding {
        rule: rule.to_string(),
        file: file.display.clone(),
        line,
        message,
        suppressed: allow,
    }
}

/// Raw-text suppression lookup for non-Rust files (and markdown/HTML
/// comments): `lint-allow(rule): reason` anywhere on the line.
fn raw_allow(file: &SourceFile, line: usize, rule: &str) -> Option<String> {
    let text = file.raw.lines().nth(line.checked_sub(1)?)?;
    let needle = format!("lint-allow({rule})");
    let p = text.find(&needle)?;
    let mut reason = &text[p + needle.len()..];
    if let Some(colon) = reason.find(':') {
        reason = &reason[colon + 1..];
    }
    Some(reason.trim().trim_end_matches("-->").trim().to_string())
}

// ---------------------------------------------------------------------
// Rule: hot-path-alloc
// ---------------------------------------------------------------------

/// Allocation constructors matched as `Qualifier::name(` calls.
const ALLOC_QUALIFIED: &[(&str, &str)] = &[
    ("Vec", "new"),
    ("Vec", "with_capacity"),
    ("Vec", "from"),
    ("Box", "new"),
    ("String", "new"),
    ("String", "from"),
    ("String", "with_capacity"),
    ("Arc", "new"),
    ("Rc", "new"),
    ("VecDeque", "new"),
    ("VecDeque", "with_capacity"),
    ("HashMap", "new"),
    ("HashSet", "new"),
    ("BTreeMap", "new"),
    ("PathBuf", "from"),
];

/// Allocating calls matched by bare name (method or free position),
/// turbofish included (`collect::<Vec<_>>()`).
const ALLOC_NAMES: &[&str] = &["to_vec", "to_owned", "to_string", "collect", "clone", "cloned"];

/// Allocating macros.  Diverging/error macros (`panic!`, `assert!`,
/// `bail!`, `ensure!`, …) are deliberately absent: they allocate only on
/// the failure path, which is never the steady state.
const ALLOC_MACROS: &[&str] = &["vec", "format"];

/// From every `// lint: hot-path` root, walk the intra-crate call graph
/// (qualified, free, and method calls resolved by name against the
/// index; `// lint: cold-path` stops traversal) and flag allocation
/// constructors with the call chain that reaches them.
pub fn hot_path_alloc(files: &[SourceFile], out: &mut Vec<Finding>) {
    // Global fn name index: name -> [(file idx, fn idx)].
    let mut by_name: HashMap<&str, Vec<(usize, usize)>> = HashMap::new();
    for (fi, file) in files.iter().enumerate() {
        if let Some(ix) = &file.rust {
            for (gi, f) in ix.fns.iter().enumerate() {
                if !f.in_test {
                    by_name.entry(f.name.as_str()).or_default().push((fi, gi));
                }
            }
        }
    }

    // BFS from the annotated roots; `chains` holds the reaching path for
    // the finding message.
    let mut queue: VecDeque<(usize, usize)> = VecDeque::new();
    let mut chains: HashMap<(usize, usize), String> = HashMap::new();
    for (fi, file) in files.iter().enumerate() {
        if let Some(ix) = &file.rust {
            for (gi, f) in ix.fns.iter().enumerate() {
                if f.hot && !f.in_test {
                    queue.push_back((fi, gi));
                    chains.insert((fi, gi), qualified_name(files, fi, gi));
                }
            }
        }
    }

    let mut flagged: HashSet<(usize, usize, String)> = HashSet::new();
    while let Some((fi, gi)) = queue.pop_front() {
        let chain = chains[&(fi, gi)].clone();
        let file = &files[fi];
        let ix = file.rust.as_ref().unwrap();
        let f = &ix.fns[gi];
        for call in &f.calls {
            // -- allocation matching ----------------------------------
            let mut hit: Option<String> = None;
            if call.is_macro {
                if ALLOC_MACROS.contains(&call.name.as_str()) {
                    hit = Some(format!("`{}!`", call.name));
                }
            } else if let Some(q) = &call.qualifier {
                if ALLOC_QUALIFIED.iter().any(|(qq, nn)| qq == q && *nn == call.name) {
                    hit = Some(format!("`{}::{}`", q, call.name));
                }
            }
            if hit.is_none() && !call.is_macro && ALLOC_NAMES.contains(&call.name.as_str()) {
                hit = Some(format!("`{}()`", call.name));
            }
            if let Some(what) = hit {
                if flagged.insert((fi, call.line, what.clone())) {
                    out.push(finish(
                        file,
                        HOT_PATH_ALLOC,
                        call.line,
                        format!("allocation {what} reachable from hot path: {chain}"),
                    ));
                }
                continue;
            }
            // -- call-graph descent -----------------------------------
            if call.is_macro || call.turbofish {
                continue;
            }
            for (tfi, tgi) in resolve(&by_name, call.qualifier.as_deref(), &call.name, files) {
                let tf = &files[tfi].rust.as_ref().unwrap().fns[tgi];
                if tf.cold || chains.contains_key(&(tfi, tgi)) {
                    continue;
                }
                chains.insert((tfi, tgi), format!("{chain} -> {}", call.name));
                queue.push_back((tfi, tgi));
            }
        }
    }
}

/// `Owner::name` (or bare `name`) for root chain labels.
fn qualified_name(files: &[SourceFile], fi: usize, gi: usize) -> String {
    let f = &files[fi].rust.as_ref().unwrap().fns[gi];
    match &f.owner {
        Some(o) => format!("{o}::{}", f.name),
        None => f.name.clone(),
    }
}

/// Resolve a call to candidate fn items.  Qualified calls prefer
/// methods of the named type, falling back to free fns of that name;
/// method calls match any impl's method of that name; bare calls match
/// free fns only.  Unresolvable calls (std, vendor crates) return empty
/// — the traversal simply does not descend (DESIGN.md §13).
fn resolve(
    by_name: &HashMap<&str, Vec<(usize, usize)>>,
    qualifier: Option<&str>,
    name: &str,
    files: &[SourceFile],
) -> Vec<(usize, usize)> {
    let cands = match by_name.get(name) {
        Some(c) => c,
        None => return Vec::new(),
    };
    let owner_of = |&(fi, gi): &(usize, usize)| -> Option<String> {
        files[fi].rust.as_ref().unwrap().fns[gi].owner.clone()
    };
    if let Some(q) = qualifier {
        let owned: Vec<_> =
            cands.iter().filter(|c| owner_of(c).as_deref() == Some(q)).copied().collect();
        if !owned.is_empty() {
            return owned;
        }
        // `module::free_fn(…)` — the qualifier is a module path segment.
        return cands.iter().filter(|c| owner_of(c).is_none()).copied().collect();
    }
    cands.to_vec()
}

// ---------------------------------------------------------------------
// Rule: balanced-accounting
// ---------------------------------------------------------------------

const CAS_OPS: &[&str] = &["compare_exchange", "compare_exchange_weak", "fetch_update"];

/// Every `// lint: gauge` atomic must have both an increment and a
/// release reachable in its module group.  Direct `fetch_add` /
/// `fetch_sub` / CAS sites count, and so do indirect sites where the
/// gauge is passed by reference to an adjuster fn (a fn whose body runs
/// one of those ops on a bare parameter); CAS and indirect sites count
/// on both sides since the direction is not statically visible.
pub fn balanced_accounting(files: &[SourceFile], out: &mut Vec<Finding>) {
    // Adjuster fns: body applies an atomic RMW op to a bare identifier
    // (a parameter), e.g. `fn try_reserve(a: &AtomicUsize, …)`.
    let mut adjusters: HashSet<String> = HashSet::new();
    for file in files {
        let ix = match &file.rust {
            Some(ix) => ix,
            None => continue,
        };
        for f in &ix.fns {
            if f.in_test {
                continue;
            }
            let Some((start, end)) = f.body else { continue };
            for lineno in start..=end {
                let toks = tokenize(&ix.lines[lineno - 1].code);
                for k in 2..toks.len() {
                    let op = match toks[k].word() {
                        Some(w) => w,
                        None => continue,
                    };
                    let rmw = op == "fetch_add" || op == "fetch_sub" || CAS_OPS.contains(&op);
                    if rmw
                        && toks[k - 1] == Tok::P('.')
                        && toks[k - 2].word().is_some()
                        && (k < 3 || toks[k - 3] != Tok::P('.'))
                    {
                        adjusters.insert(f.name.clone());
                    }
                }
            }
        }
    }

    for (fi, file) in files.iter().enumerate() {
        let ix = match &file.rust {
            Some(ix) => ix,
            None => continue,
        };
        for gauge in &ix.gauges {
            let mut incs = 0usize;
            let mut decs = 0usize;
            let mut both = 0usize;
            for peer in files.iter().filter(|p| p.group == files[fi].group) {
                let pix = match &peer.rust {
                    Some(pix) => pix,
                    None => continue,
                };
                for (l0, line) in pix.lines.iter().enumerate() {
                    if pix.test_lines[l0] {
                        continue;
                    }
                    let toks = tokenize(&line.code);
                    for k in 0..toks.len() {
                        let w = match toks[k].word() {
                            Some(w) => w,
                            None => continue,
                        };
                        // Direct site: `<gauge>.fetch_add(…)` etc.
                        if w == gauge.name && toks.get(k + 1) == Some(&Tok::P('.')) {
                            if let Some(op) = toks.get(k + 2).and_then(|t| t.word()) {
                                if op == "fetch_add" {
                                    incs += 1;
                                } else if op == "fetch_sub" {
                                    decs += 1;
                                } else if CAS_OPS.contains(&op) {
                                    both += 1;
                                }
                            }
                        }
                        // Indirect site: gauge passed to an adjuster fn,
                        // `try_reserve(&self.reserved, …)` — scan the
                        // few lines the call's arguments may span.
                        if adjusters.contains(w) && toks.get(k + 1) == Some(&Tok::P('(')) {
                            let hit = (l0..(l0 + 3).min(pix.lines.len())).any(|a0| {
                                let atoks = tokenize(&pix.lines[a0].code);
                                atoks.iter().enumerate().any(|(j, t)| {
                                    t.word() == Some(&gauge.name)
                                        && j > 0
                                        && matches!(atoks[j - 1], Tok::P('.') | Tok::P('&'))
                                })
                            });
                            if hit {
                                both += 1;
                            }
                        }
                    }
                }
            }
            let inc_total = incs + both;
            let dec_total = decs + both;
            let msg = if inc_total == 0 && dec_total == 0 {
                Some(format!(
                    "gauge `{}` is registered but never adjusted in module group `{}`",
                    gauge.name, file.group
                ))
            } else if dec_total == 0 {
                Some(format!(
                    "gauge `{}` is incremented ({inc_total} sites) but never released in module group `{}`",
                    gauge.name, file.group
                ))
            } else if inc_total == 0 {
                Some(format!(
                    "gauge `{}` is released ({dec_total} sites) but never incremented in module group `{}`",
                    gauge.name, file.group
                ))
            } else {
                None
            };
            if let Some(m) = msg {
                out.push(finish(file, BALANCED_ACCOUNTING, gauge.line, m));
            }
        }
    }
}

// ---------------------------------------------------------------------
// Rule: undocumented-unsafe
// ---------------------------------------------------------------------

/// Every `unsafe` keyword in code must have a `SAFETY:` comment on the
/// same line or in the contiguous comment/attribute block above it.
pub fn undocumented_unsafe(files: &[SourceFile], out: &mut Vec<Finding>) {
    for file in files {
        let ix = match &file.rust {
            Some(ix) => ix,
            None => continue,
        };
        let mut seen: HashSet<usize> = HashSet::new();
        for (l0, line) in ix.lines.iter().enumerate() {
            let has_unsafe = tokenize(&line.code).iter().any(|t| t.word() == Some("unsafe"));
            if !has_unsafe || !seen.insert(l0) {
                continue;
            }
            let mut documented = line.comment.contains("SAFETY:");
            let mut j = l0;
            while !documented && j > 0 {
                j -= 1;
                let prev = &ix.lines[j];
                if prev.comment.contains("SAFETY:") {
                    documented = true;
                    break;
                }
                let ct = prev.code.trim();
                if !(ct.is_empty() || ct.starts_with("#[")) {
                    break;
                }
            }
            if !documented {
                out.push(finish(
                    file,
                    UNDOCUMENTED_UNSAFE,
                    l0 + 1,
                    "`unsafe` without a `// SAFETY:` comment".to_string(),
                ));
            }
        }
    }
}

// ---------------------------------------------------------------------
// Rule: design-ref
// ---------------------------------------------------------------------

/// Design-doc citation checking, absorbed from
/// `tools/check_design_refs.sh`: every `DESIGN.md §<N>` /
/// `EXPERIMENTS.md §<Name>` citation and every `INVARIANT(§<N>)` tag in the
/// scanned tree must resolve to a real `## §…` heading, and —
/// bidirectionally — every DESIGN.md section must be cited (or tagged)
/// somewhere in the scanned tree.
pub fn design_ref(
    files: &[SourceFile],
    design: Option<&str>,
    experiments: Option<&str>,
    out: &mut Vec<Finding>,
) {
    let design_secs = design.map(headings_numeric);
    let exp_secs = experiments.map(headings_named);

    let mut cited: HashSet<String> = HashSet::new();
    for file in files {
        for (l0, text) in file.raw.lines().enumerate() {
            let lineno = l0 + 1;
            for num in scan_refs(text, "DESIGN.md §", false)
                .into_iter()
                .chain(scan_refs(text, "INVARIANT(§", false))
            {
                if file.display.ends_with("DESIGN.md") {
                    continue;
                }
                cited.insert(num.clone());
                match &design_secs {
                    Some(secs) if secs.contains_key(&num) => {}
                    Some(_) => out.push(finish(
                        file,
                        DESIGN_REF,
                        lineno,
                        format!("cites DESIGN.md §{num}, but DESIGN.md has no `## §{num}` heading"),
                    )),
                    None => out.push(finish(
                        file,
                        DESIGN_REF,
                        lineno,
                        format!("cites DESIGN.md §{num}, but DESIGN.md was not found"),
                    )),
                }
            }
            for name in scan_refs(text, "EXPERIMENTS.md §", true) {
                if file.display.ends_with("EXPERIMENTS.md") {
                    continue;
                }
                match &exp_secs {
                    Some(secs) if secs.contains(&name) => {}
                    Some(_) => out.push(finish(
                        file,
                        DESIGN_REF,
                        lineno,
                        format!(
                            "cites EXPERIMENTS.md §{name}, but EXPERIMENTS.md has no `## §{name}` heading"
                        ),
                    )),
                    None => out.push(finish(
                        file,
                        DESIGN_REF,
                        lineno,
                        format!("cites EXPERIMENTS.md §{name}, but EXPERIMENTS.md was not found"),
                    )),
                }
            }
        }
    }

    // Reverse direction: every DESIGN.md section is cited somewhere.
    // (EXPERIMENTS.md sections are forward-only: benches cite them, but
    // not every experiment section needs a code anchor.)
    if let (Some(secs), Some(raw)) = (&design_secs, design) {
        let mut nums: Vec<_> = secs.iter().collect();
        nums.sort_by_key(|(n, _)| n.parse::<u64>().unwrap_or(u64::MAX));
        for (num, &heading_line) in nums {
            if cited.contains(num) {
                continue;
            }
            let heading_text = raw.lines().nth(heading_line - 1).unwrap_or("");
            let suppressed = heading_text
                .find(&format!("lint-allow({DESIGN_REF})"))
                .map(|p| {
                    let mut reason = &heading_text[p + format!("lint-allow({DESIGN_REF})").len()..];
                    if let Some(colon) = reason.find(':') {
                        reason = &reason[colon + 1..];
                    }
                    reason.trim().trim_end_matches("-->").trim().to_string()
                });
            out.push(Finding {
                rule: DESIGN_REF.to_string(),
                file: "DESIGN.md".to_string(),
                line: heading_line,
                message: format!(
                    "DESIGN.md §{num} is never cited (no `DESIGN.md §{num}` or `INVARIANT(§{num})` in the scanned tree)"
                ),
                suppressed,
            });
        }
    }
}

/// `## §N · Title` headings of DESIGN.md: number -> 1-based line.
fn headings_numeric(raw: &str) -> HashMap<String, usize> {
    let mut secs = HashMap::new();
    for (l0, line) in raw.lines().enumerate() {
        if let Some(rest) = line.strip_prefix("## §") {
            let num: String = rest.chars().take_while(|c| c.is_ascii_digit()).collect();
            if !num.is_empty() {
                secs.entry(num).or_insert(l0 + 1);
            }
        }
    }
    secs
}

/// `## §Name …` headings of EXPERIMENTS.md.
fn headings_named(raw: &str) -> HashSet<String> {
    let mut secs = HashSet::new();
    for line in raw.lines() {
        if let Some(rest) = line.strip_prefix("## §") {
            let name: String = rest
                .chars()
                .take_while(|c| c.is_ascii_alphanumeric() || *c == '_' || *c == '-')
                .collect();
            if !name.is_empty() {
                secs.insert(name);
            }
        }
    }
    secs
}

/// All `§…` references following `prefix` on one raw line.  `named`
/// selects section-name tokens (`E2E`, `Perf`) over numeric ones.
fn scan_refs(text: &str, prefix: &str, named: bool) -> Vec<String> {
    let mut found = Vec::new();
    let mut rest = text;
    while let Some(p) = rest.find(prefix) {
        rest = &rest[p + prefix.len()..];
        let tok: String = if named {
            rest.chars()
                .take_while(|c| c.is_ascii_alphanumeric() || *c == '_' || *c == '-')
                .collect()
        } else {
            rest.chars().take_while(|c| c.is_ascii_digit()).collect()
        };
        let valid = if named {
            tok.chars().next().is_some_and(|c| c.is_ascii_alphabetic())
        } else {
            !tok.is_empty()
        };
        if valid {
            found.push(tok);
        }
    }
    found
}
