//! zipcache-lint CLI (DESIGN.md §13).
//!
//! ```text
//! cargo run -p zipcache-lint -- [PATH…] [--json FILE] [--rule NAME]…
//!                               [--docs-root DIR] [--list-rules] [-q]
//! ```
//!
//! Exit codes: 0 — no unsuppressed findings; 1 — unsuppressed findings;
//! 2 — usage or I/O error.

use std::path::PathBuf;
use std::process::ExitCode;

use zipcache_lint::{rules, Options};

const USAGE: &str = "\
zipcache-lint — static analysis for the ZipCache tree (DESIGN.md §13)

usage: zipcache-lint [PATH…] [options]

  PATH…             files or directories to scan (default: rust/src)
  --rule NAME       run only this rule (repeatable; default: all)
  --json FILE       also write machine-readable findings to FILE
  --docs-root DIR   where DESIGN.md / EXPERIMENTS.md live (default: .)
  --list-rules      print the rule names and exit
  -q, --quiet       suppress the human table (exit code only)
  -h, --help        this help
";

fn main() -> ExitCode {
    let mut opts = Options { paths: Vec::new(), ..Options::default() };
    let mut json_path: Option<PathBuf> = None;
    let mut quiet = false;

    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--json" | "--rule" | "--docs-root" => {
                let Some(v) = args.next() else {
                    eprintln!("zipcache-lint: {arg} needs a value\n\n{USAGE}");
                    return ExitCode::from(2);
                };
                match arg.as_str() {
                    "--json" => json_path = Some(PathBuf::from(v)),
                    "--rule" => opts.rules.push(v),
                    _ => opts.docs_root = PathBuf::from(v),
                }
            }
            "--list-rules" => {
                for r in rules::ALL_RULES {
                    println!("{r}");
                }
                return ExitCode::SUCCESS;
            }
            "-q" | "--quiet" => quiet = true,
            "-h" | "--help" => {
                print!("{USAGE}");
                return ExitCode::SUCCESS;
            }
            flag if flag.starts_with('-') => {
                eprintln!("zipcache-lint: unknown option {flag}\n\n{USAGE}");
                return ExitCode::from(2);
            }
            path => opts.paths.push(PathBuf::from(path)),
        }
    }
    if opts.paths.is_empty() {
        opts.paths.push(PathBuf::from("rust/src"));
    }

    let report = match zipcache_lint::run(&opts) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("zipcache-lint: {e}");
            return ExitCode::from(2);
        }
    };

    if let Some(path) = &json_path {
        if let Err(e) = std::fs::write(path, report.to_json()) {
            eprintln!("zipcache-lint: cannot write {}: {e}", path.display());
            return ExitCode::from(2);
        }
    }
    if !quiet {
        print!("{}", report.render());
    }
    if report.unsuppressed() == 0 {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}
