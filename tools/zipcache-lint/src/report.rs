//! Finding collection, the human table, and the machine-readable JSON
//! findings file (DESIGN.md §13).  JSON is hand-rolled: the crate is
//! dependency-free by design (offline build, DESIGN.md §6).

/// One rule hit.  `suppressed` carries the `lint-allow` reason when the
/// offending line opted out — suppressions are counted, not dropped.
#[derive(Debug, Clone)]
pub struct Finding {
    pub rule: String,
    pub file: String,
    pub line: usize,
    pub message: String,
    pub suppressed: Option<String>,
}

/// The full result of one lint run.
#[derive(Debug, Default)]
pub struct Report {
    pub findings: Vec<Finding>,
    /// `Owner::fn` labels of the hot-path roots that seeded traversal.
    pub roots: Vec<String>,
    /// Registered gauge names.
    pub gauges: Vec<String>,
    pub files_scanned: usize,
    pub rules_run: Vec<String>,
}

impl Report {
    pub fn unsuppressed(&self) -> usize {
        self.findings.iter().filter(|f| f.suppressed.is_none()).count()
    }

    pub fn suppressed(&self) -> usize {
        self.findings.iter().filter(|f| f.suppressed.is_some()).count()
    }

    /// The human-readable table: one line per finding, suppressions in
    /// a trailing audit section, then the summary line.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for f in self.findings.iter().filter(|f| f.suppressed.is_none()) {
            out.push_str(&format!("{}:{}: [{}] {}\n", f.file, f.line, f.rule, f.message));
        }
        let allowed: Vec<&Finding> =
            self.findings.iter().filter(|f| f.suppressed.is_some()).collect();
        if !allowed.is_empty() {
            out.push_str("\nsuppressed (lint-allow):\n");
            for f in allowed {
                out.push_str(&format!(
                    "  {}:{}: [{}] {} — allowed: {}\n",
                    f.file,
                    f.line,
                    f.rule,
                    f.message,
                    f.suppressed.as_deref().unwrap_or("")
                ));
            }
        }
        out.push_str(&format!(
            "\nzipcache-lint: {} file(s), rules [{}], {} root(s), {} gauge(s): {} finding(s), {} suppressed\n",
            self.files_scanned,
            self.rules_run.join(", "),
            self.roots.len(),
            self.gauges.len(),
            self.unsuppressed(),
            self.suppressed(),
        ));
        out
    }

    /// The machine-readable findings file uploaded as a CI artifact.
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\n  \"findings\": [");
        for (i, f) in self.findings.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str("\n    {");
            out.push_str(&format!("\"rule\": \"{}\", ", esc(&f.rule)));
            out.push_str(&format!("\"file\": \"{}\", ", esc(&f.file)));
            out.push_str(&format!("\"line\": {}, ", f.line));
            out.push_str(&format!("\"message\": \"{}\", ", esc(&f.message)));
            match &f.suppressed {
                Some(r) => out.push_str(&format!("\"suppressed\": \"{}\"", esc(r))),
                None => out.push_str("\"suppressed\": null"),
            }
            out.push('}');
        }
        if !self.findings.is_empty() {
            out.push_str("\n  ");
        }
        out.push_str("],\n");
        out.push_str(&format!(
            "  \"summary\": {{\"files_scanned\": {}, \"rules\": [{}], \"roots\": [{}], \"gauges\": [{}], \"unsuppressed\": {}, \"suppressed\": {}}}\n",
            self.files_scanned,
            join_json(&self.rules_run),
            join_json(&self.roots),
            join_json(&self.gauges),
            self.unsuppressed(),
            self.suppressed(),
        ));
        out.push_str("}\n");
        out
    }
}

fn join_json(items: &[String]) -> String {
    items.iter().map(|s| format!("\"{}\"", esc(s))).collect::<Vec<_>>().join(", ")
}

/// Minimal JSON string escaping (quotes, backslashes, control chars).
fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}
