//! Comment/string-aware line lexer (DESIGN.md §13).
//!
//! Splits Rust source into per-line `code` / `comment` channels so every
//! downstream pass can pattern-match on code without being fooled by
//! tokens inside comments or string literals, and can read lint
//! directives (`lint: hot-path`, `lint-allow(rule): reason`, `SAFETY:`)
//! out of comments without seeing code.
//!
//! The lexer is a character state machine that understands:
//!   - line comments (`//`, `///`, `//!`) — text goes to the comment
//!     channel, a single space is pushed to the code channel so the
//!     comment still separates code tokens;
//!   - nested block comments (`/* /* */ */`), possibly spanning lines;
//!   - string literals with escapes, byte strings, and raw strings
//!     (`r"…"`, `r#"…"#`, `br#"…"#`) — contents are elided from the code
//!     channel (a bare `"` delimiter is kept as a token separator);
//!   - char literals vs. lifetimes (`'a'` / `b'x'` vs. `'a`, `'static`).
//!
//! It does not tokenize beyond that; see [`crate::index`] for the token
//! pass that runs on the cleaned code channel.

/// One source line split into its code and comment parts.
#[derive(Debug, Clone, Default)]
pub struct Line {
    /// The line with comments removed and string/char-literal contents
    /// elided (delimiters kept so literals still separate tokens).
    pub code: String,
    /// The concatenated text of every comment overlapping the line.
    pub comment: String,
}

/// Lexer mode carried across lines: block comments and string literals
/// may span line boundaries.
#[derive(Clone, Copy)]
enum Mode {
    Code,
    /// Inside a block comment; payload is the nesting depth.
    Block(u32),
    /// Inside a normal (escapable) string literal.
    Str,
    /// Inside a raw string literal; payload is the `#` count.
    RawStr(u32),
}

/// Lex `src` into per-line code/comment channels.  Every source line
/// (including blank ones) produces exactly one [`Line`], so indices into
/// the result are `line_number - 1`.
pub fn lex(src: &str) -> Vec<Line> {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut lines = Vec::new();
    let mut code = String::new();
    let mut comment = String::new();
    let mut mode = Mode::Code;
    let mut i = 0usize;
    while i < n {
        let c = chars[i];
        if c == '\n' {
            lines.push(Line { code: std::mem::take(&mut code), comment: std::mem::take(&mut comment) });
            i += 1;
            continue;
        }
        match mode {
            Mode::Code => {
                if c == '/' && i + 1 < n && chars[i + 1] == '/' {
                    // Line comment.  Strip the slashes (and doc-comment
                    // `!`) so directives parse the same under `//` and
                    // `///`; push a space so the comment still separates
                    // code tokens.
                    code.push(' ');
                    i += 2;
                    while i < n && (chars[i] == '/' || chars[i] == '!') {
                        i += 1;
                    }
                    while i < n && chars[i] != '\n' {
                        comment.push(chars[i]);
                        i += 1;
                    }
                    continue;
                }
                if c == '/' && i + 1 < n && chars[i + 1] == '*' {
                    mode = Mode::Block(1);
                    code.push(' ');
                    i += 2;
                    continue;
                }
                if c == '"' {
                    mode = Mode::Str;
                    code.push('"');
                    i += 1;
                    continue;
                }
                if (c == 'r' || c == 'b') && !prev_is_ident(&chars, i) {
                    // Raw / byte string prefixes, only when the letter
                    // is not the tail of a longer identifier.
                    if let Some((hashes, skip)) = raw_str_open(&chars, i) {
                        mode = Mode::RawStr(hashes);
                        code.push('"');
                        i += skip;
                        continue;
                    }
                    if c == 'b' && i + 1 < n && chars[i + 1] == '"' {
                        mode = Mode::Str;
                        code.push('"');
                        i += 2;
                        continue;
                    }
                    if c == 'b' && i + 1 < n && chars[i + 1] == '\'' {
                        let len = char_literal_len(&chars, i + 1);
                        if len > 0 {
                            code.push_str("' '");
                            i += 1 + len;
                            continue;
                        }
                    }
                }
                if c == '\'' {
                    let len = char_literal_len(&chars, i);
                    if len > 0 {
                        // Char literal: elide the content.
                        code.push_str("' '");
                        i += len;
                    } else {
                        // Lifetime tick.
                        code.push('\'');
                        i += 1;
                    }
                    continue;
                }
                code.push(c);
                i += 1;
            }
            Mode::Block(d) => {
                if c == '*' && i + 1 < n && chars[i + 1] == '/' {
                    mode = if d == 1 { Mode::Code } else { Mode::Block(d - 1) };
                    i += 2;
                    continue;
                }
                if c == '/' && i + 1 < n && chars[i + 1] == '*' {
                    mode = Mode::Block(d + 1);
                    i += 2;
                    continue;
                }
                comment.push(c);
                i += 1;
            }
            Mode::Str => {
                if c == '\\' {
                    // Skip the escaped character, but never swallow a
                    // newline (a `\` line continuation must still end
                    // the current Line).
                    if i + 1 < n && chars[i + 1] != '\n' {
                        i += 2;
                    } else {
                        i += 1;
                    }
                    continue;
                }
                if c == '"' {
                    mode = Mode::Code;
                    code.push('"');
                }
                i += 1;
            }
            Mode::RawStr(h) => {
                if c == '"' && has_hashes(&chars, i + 1, h) {
                    mode = Mode::Code;
                    code.push('"');
                    i += 1 + h as usize;
                    continue;
                }
                i += 1;
            }
        }
    }
    if !code.is_empty() || !comment.is_empty() {
        lines.push(Line { code, comment });
    }
    lines
}

/// True when the character before `i` can end an identifier, meaning a
/// following `r`/`b` is part of that identifier rather than a raw/byte
/// string prefix.
fn prev_is_ident(chars: &[char], i: usize) -> bool {
    i > 0 && (chars[i - 1].is_alphanumeric() || chars[i - 1] == '_')
}

/// If a raw string opens at `i` (`r"`, `r#"`, `br##"` …), return the
/// hash count and the number of characters in the opener.
fn raw_str_open(chars: &[char], i: usize) -> Option<(u32, usize)> {
    let n = chars.len();
    let mut j = i;
    if chars[j] == 'b' {
        j += 1;
    }
    if j >= n || chars[j] != 'r' {
        return None;
    }
    j += 1;
    let mut hashes = 0u32;
    while j < n && chars[j] == '#' {
        hashes += 1;
        j += 1;
    }
    if j < n && chars[j] == '"' {
        Some((hashes, j + 1 - i))
    } else {
        None
    }
}

/// True when `h` `#` characters follow position `from`.
fn has_hashes(chars: &[char], from: usize, h: u32) -> bool {
    let h = h as usize;
    from + h <= chars.len() && chars[from..from + h].iter().all(|&c| c == '#')
}

/// With `chars[i] == '\''`: the total character length of the char
/// literal starting at `i`, or 0 when the tick starts a lifetime.
fn char_literal_len(chars: &[char], i: usize) -> usize {
    let n = chars.len();
    if i + 1 >= n {
        return 0;
    }
    if chars[i + 1] == '\\' {
        // Escaped char: scan to the closing quote on the same line
        // (handles `'\n'`, `'\\'`, `'\u{1F600}'`).
        let mut j = i + 3;
        while j < n && chars[j] != '\'' && chars[j] != '\n' {
            j += 1;
        }
        if j < n && chars[j] == '\'' {
            return j - i + 1;
        }
        return 0;
    }
    // Unescaped: exactly one char then the closing quote, e.g. `'x'`.
    // Anything else (`'a`, `'static`, `<'a>`) is a lifetime.
    if i + 2 < n && chars[i + 1] != '\'' && chars[i + 2] == '\'' {
        return 3;
    }
    0
}
