//! Per-rule fixture tests: each rule must fire on its known-bad
//! fixture and stay silent on the known-good one (DESIGN.md §13).
//!
//! The fixtures live under `tests/fixtures/` — a directory name the
//! walker never descends into, so scanning the real tree (or `tools/`)
//! can never trip on the deliberately-bad files.  Here they are passed
//! as explicit root paths, which bypasses the skip list.

use std::path::PathBuf;

use zipcache_lint::{run, Options, Report};

fn fixture(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests").join("fixtures").join(name)
}

fn run_rule(rule: &str, file: &str) -> Report {
    let opts = Options {
        paths: vec![fixture(file)],
        docs_root: fixture("docs"),
        rules: vec![rule.to_string()],
    };
    run(&opts).expect("lint run failed")
}

#[test]
fn hot_path_alloc_fires_on_bad() {
    let r = run_rule("hot-path-alloc", "hot_path_bad.rs");
    assert_eq!(r.unsuppressed(), 2, "{}", r.render());
    let msgs: Vec<&str> = r.findings.iter().map(|f| f.message.as_str()).collect();
    assert!(msgs.iter().any(|m| m.contains("`to_vec()`")), "{msgs:?}");
    assert!(
        msgs.iter().any(|m| m.contains("`vec!`") && m.contains("decode_step -> stage")),
        "transitive chain missing: {msgs:?}"
    );
}

#[test]
fn hot_path_alloc_clean_on_good() {
    let r = run_rule("hot-path-alloc", "hot_path_good.rs");
    assert_eq!(r.unsuppressed(), 0, "{}", r.render());
    assert_eq!(r.suppressed(), 1, "the audited allow must still be counted");
    assert!(r.findings[0].message.contains("Vec::new"), "{}", r.findings[0].message);
    assert_eq!(
        r.findings[0].suppressed.as_deref(),
        Some("capacity-0 Vec::new is heap-free")
    );
}

#[test]
fn balanced_accounting_fires_on_bad() {
    let r = run_rule("balanced-accounting", "accounting_bad.rs");
    assert_eq!(r.unsuppressed(), 2, "{}", r.render());
    let msgs: Vec<&str> = r.findings.iter().map(|f| f.message.as_str()).collect();
    assert!(
        msgs.iter().any(|m| m.contains("`leaked`") && m.contains("never released")),
        "{msgs:?}"
    );
    assert!(
        msgs.iter().any(|m| m.contains("`idle`") && m.contains("never adjusted")),
        "{msgs:?}"
    );
}

#[test]
fn balanced_accounting_clean_on_good() {
    let r = run_rule("balanced-accounting", "accounting_good.rs");
    assert_eq!(r.unsuppressed(), 0, "{}", r.render());
    assert_eq!(r.gauges, vec!["active".to_string(), "reserved".to_string()]);
}

#[test]
fn undocumented_unsafe_fires_on_bad() {
    let r = run_rule("undocumented-unsafe", "unsafe_bad.rs");
    assert_eq!(r.unsuppressed(), 2, "{}", r.render());
}

#[test]
fn undocumented_unsafe_clean_on_good() {
    let r = run_rule("undocumented-unsafe", "unsafe_good.rs");
    assert_eq!(r.unsuppressed(), 0, "{}", r.render());
}

#[test]
fn design_ref_fires_on_bad() {
    let r = run_rule("design-ref", "design_ref_bad.rs");
    assert_eq!(r.unsuppressed(), 4, "{}", r.render());
    let msgs: Vec<&str> = r.findings.iter().map(|f| f.message.as_str()).collect();
    assert!(msgs.iter().any(|m| m.contains("§99")), "{msgs:?}");
    assert!(msgs.iter().any(|m| m.contains("§98")), "{msgs:?}");
    assert!(msgs.iter().any(|m| m.contains("§Nope")), "{msgs:?}");
    assert!(
        msgs.iter().any(|m| m.contains("§2 is never cited")),
        "reverse-direction finding missing: {msgs:?}"
    );
}

#[test]
fn design_ref_clean_on_good() {
    let r = run_rule("design-ref", "design_ref_good.rs");
    assert_eq!(r.unsuppressed(), 0, "{}", r.render());
}

#[test]
fn unknown_rule_is_an_error() {
    let opts = Options {
        paths: vec![fixture("hot_path_good.rs")],
        docs_root: fixture("docs"),
        rules: vec!["bogus".to_string()],
    };
    assert!(run(&opts).is_err());
}

#[test]
fn json_report_shape() {
    let r = run_rule("hot-path-alloc", "hot_path_bad.rs");
    let json = r.to_json();
    assert!(json.contains("\"rule\": \"hot-path-alloc\""), "{json}");
    assert!(json.contains("\"unsuppressed\": 2"), "{json}");
    assert!(json.contains("\"suppressed\": null"), "{json}");
}
