//! The real tree must stay lint-clean: zero unsuppressed findings over
//! `rust/src` against the workspace DESIGN.md / EXPERIMENTS.md — the
//! same invocation the CI `lint` job runs (DESIGN.md §13).

use std::path::PathBuf;

use zipcache_lint::{run, Options};

fn repo_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("..").join("..")
}

#[test]
fn repo_tree_is_lint_clean() {
    let root = repo_root();
    let opts = Options {
        paths: vec![root.join("rust").join("src")],
        docs_root: root,
        rules: Vec::new(),
    };
    let r = run(&opts).expect("lint run failed");
    assert_eq!(
        r.unsuppressed(),
        0,
        "unsuppressed lint findings in the repo tree:\n{}",
        r.render()
    );
    // The anchors themselves are load-bearing: if the §9 hot roots or
    // the §10 gauges disappear, the rules silently check nothing.
    assert!(
        r.roots.iter().any(|x| x == "Engine::decode_step"),
        "hot-path roots lost: {:?}",
        r.roots
    );
    assert!(r.gauges.iter().any(|g| g == "in_use"), "gauges lost: {:?}", r.gauges);
    // The §16 segment-store gauges must stay registered (and therefore
    // balance-checked): payload bytes, interned entries, reader pins.
    for g in ["shared_bytes", "seg_entries", "seg_refs"] {
        assert!(r.gauges.iter().any(|x| x == g),
                "prefix-store gauge '{g}' lost: {:?}", r.gauges);
    }
    assert!(r.suppressed() >= 1, "the audited allows should be counted, not dropped");
}
