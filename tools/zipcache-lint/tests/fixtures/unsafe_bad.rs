//! Known-bad fixture: `unsafe` without `// SAFETY:` documentation.
//! Never compiled — scanned by `tests/rules.rs` only.

pub fn first(xs: &[u8]) -> u8 {
    unsafe { *xs.as_ptr() }
}

pub unsafe fn advance(p: *const u8, n: usize) -> *const u8 {
    p.add(n)
}
