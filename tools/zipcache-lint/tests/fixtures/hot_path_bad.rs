//! Known-bad fixture: allocations reachable from a `lint: hot-path`
//! root, directly (`to_vec`) and transitively (`vec!` two hops down).
//! Never compiled — scanned by `tests/rules.rs` only.

// lint: hot-path
pub fn decode_step(out: &mut Vec<u32>, xs: &[u32]) -> usize {
    let extra = xs.to_vec();
    stage(out, &extra);
    out.len()
}

fn stage(out: &mut Vec<u32>, xs: &[u32]) {
    let tmp = vec![0u32; 4];
    out.extend_from_slice(&tmp);
    out.extend_from_slice(xs);
}
