//! Known-good fixture: every citation resolves and both sections of
//! the fixture design doc are anchored from code.
//! Never compiled — scanned by `tests/rules.rs` only.

/// Covered by DESIGN.md §1 and measured in EXPERIMENTS.md §Perf.
pub fn anchored() {}

/// INVARIANT(§2): the second section's contract.
pub fn tagged() {}
