//! Known-bad fixture: one gauge leaks (incremented, never released),
//! one is registered but never adjusted at all.
//! Never compiled — scanned by `tests/rules.rs` only.

use std::sync::atomic::{AtomicUsize, Ordering};

pub struct Shared {
    // lint: gauge — admitted-but-never-released count
    leaked: AtomicUsize,
    // lint: gauge — registered but never adjusted
    idle: AtomicUsize,
}

impl Shared {
    pub fn admit(&self) {
        self.leaked.fetch_add(1, Ordering::AcqRel);
    }

    pub fn snapshot(&self) -> usize {
        self.idle.load(Ordering::Acquire)
    }
}
