//! Known-good fixture: the hot root is allocation-free, a `lint:
//! cold-path` anchor stops traversal into startup code, and the one
//! intentional constructor carries an audited `lint-allow`.
//! Never compiled — scanned by `tests/rules.rs` only.

// lint: hot-path
pub fn decode_step(out: &mut [u32], xs: &[u32]) -> usize {
    let mut acc = 0usize;
    for (dst, src) in out.iter_mut().zip(xs) {
        *dst = *src;
        acc += *src as usize;
    }
    acc + empty_scratch()
}

fn empty_scratch() -> usize {
    // lint-allow(hot-path-alloc): capacity-0 Vec::new is heap-free
    let v: Vec<u32> = Vec::new();
    let _ = warm_tables();
    v.capacity()
}

// lint: cold-path — startup table build, outside the steady contract
fn warm_tables() -> Vec<u32> {
    vec![0u32; 16]
}
