//! Known-bad fixture: a stale section citation, a stale invariant tag,
//! an unknown experiment section — and by citing only §1, it leaves the
//! fixture doc's §2 uncited (reverse-direction finding).
//! Never compiled — scanned by `tests/rules.rs` only.

/// Cites DESIGN.md §1 (fine) and DESIGN.md §99 (stale).
pub fn stale() {}

/// INVARIANT(§98): no such section.
pub fn tag() {}

/// Results in EXPERIMENTS.md §Nope.
pub fn exp() {}
