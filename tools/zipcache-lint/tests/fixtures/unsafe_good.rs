//! Known-good fixture: every `unsafe` carries a `SAFETY:` comment —
//! directly above, or above through an attribute line.
//! Never compiled — scanned by `tests/rules.rs` only.

pub fn first(xs: &[u8]) -> u8 {
    debug_assert!(!xs.is_empty());
    // SAFETY: asserted non-empty above; as_ptr is in-bounds for index 0.
    unsafe { *xs.as_ptr() }
}

// SAFETY: caller must keep `p + n` within the same allocation.
#[inline]
pub unsafe fn advance(p: *const u8, n: usize) -> *const u8 {
    p.add(n)
}
