//! Known-good fixture: balanced gauges — one via direct
//! `fetch_add`/`fetch_sub`, one via a CAS adjuster fn whose call sites
//! count on both sides.  Never compiled — scanned by `tests/rules.rs`.

use std::sync::atomic::{AtomicUsize, Ordering};

pub struct Shared {
    // lint: gauge — active request count
    active: AtomicUsize,
    // lint: gauge — reserved byte budget
    reserved: AtomicUsize,
}

impl Shared {
    pub fn admit(&self) {
        self.active.fetch_add(1, Ordering::AcqRel);
    }

    pub fn retire(&self) {
        self.active.fetch_sub(1, Ordering::AcqRel);
    }

    pub fn reserve(&self, n: usize, cap: usize) -> bool {
        try_adjust(&self.reserved, n, cap)
    }

    pub fn release(&self, n: usize) {
        self.reserved.fetch_sub(n, Ordering::AcqRel);
    }
}

/// CAS loop on a bare parameter: makes this an adjuster fn, so call
/// sites passing a gauge count as both increment and release.
fn try_adjust(a: &AtomicUsize, n: usize, cap: usize) -> bool {
    let mut cur = a.load(Ordering::Acquire);
    loop {
        if cur + n > cap {
            return false;
        }
        match a.compare_exchange_weak(cur, cur + n, Ordering::AcqRel, Ordering::Acquire) {
            Ok(_) => return true,
            Err(v) => cur = v,
        }
    }
}
