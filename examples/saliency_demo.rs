//! Figure 3 reproduction: accumulated vs normalized attention scores on a
//! GSM-style chain-of-thought prompt.
//!
//! Runs the full-score prefill artifact on a sample whose *question* is at
//! the very end (the paper's Fig. 3(b) layout), then prints where each
//! metric ranks the question tokens and the queried fact.  Accumulated
//! scores (Eq. 7) should rank the earliest tokens highest; normalized
//! scores (Eq. 8) should surface the question span.
//!
//! ```sh
//! cargo run --release --example saliency_demo -- --model micro
//! ```

use zipcache::config::{EngineConfig, PolicyKind};
use zipcache::coordinator::{Engine, GenerationRequest};
use zipcache::saliency::metric::select_salient;
use zipcache::util::cli::Args;
use zipcache::workload::{Task, TaskGen};
use zipcache::Result;

fn main() -> Result<()> {
    let args = Args::new("saliency_demo", "Fig. 3: accumulated vs normalized saliency")
        .flag("artifacts", "artifacts", "artifacts directory")
        .flag("model", "micro", "model config")
        .flag("seed", "11", "sample seed")
        .flag("ratio", "0.4", "saliency ratio for the selection comparison")
        .parse()?;

    let mut cfg = EngineConfig::load_default(args.get("artifacts"), &args.get("model"))?;
    cfg.policy = PolicyKind::Mikv; // forces the full-score prefill path
    let mut engine = Engine::new(cfg)?;
    let info = engine.runtime().model_info().clone();

    let gen = TaskGen::new(Task::Gsm, info.max_seq - 2);
    let sample = gen.sample(args.get_u64("seed")?);
    let n = sample.prompt_len;
    println!("prompt: {n} tokens; queried fact at {:?}; question tokens at [{}, {})",
             sample.salient_span, n - 3, n);

    // Run a session start: the engine stores layer-averaged saliency.
    let sess = engine
        .start_session(GenerationRequest::new(sample.prompt().to_vec(), 2))?;
    let acc = &sess.acc_saliency[..n];
    let nrm = &sess.norm_saliency[..n];

    let ratio = args.get_f64("ratio")?;
    let acc_mask = select_salient(acc, n, ratio);
    let nrm_mask = select_salient(nrm, n, ratio);

    let span = sample.salient_span.0..sample.salient_span.1;
    let question = n - 3..n;

    let covered = |mask: &[bool], r: &std::ops::Range<usize>| {
        r.clone().filter(|&i| mask[i]).count()
    };
    println!("\n{:<28} {:>12} {:>12}", "", "accumulated", "normalized");
    println!("{:<28} {:>9}/{:<2} {:>9}/{:<2}",
             "queried-fact tokens salient",
             covered(&acc_mask, &span), span.len(),
             covered(&nrm_mask, &span), span.len());
    println!("{:<28} {:>9}/{:<2} {:>9}/{:<2}",
             "question tokens salient",
             covered(&acc_mask, &question), question.len(),
             covered(&nrm_mask, &question), question.len());

    // Positional bias: mean saliency rank of the first 10% vs last 10%.
    let decile = (n / 10).max(1);
    let mean = |xs: &[f32]| xs.iter().sum::<f32>() / xs.len() as f32;
    println!("\nmean saliency, first {decile} tokens : acc={:.4}  norm={:.4}",
             mean(&acc[..decile]), mean(&nrm[..decile]));
    println!("mean saliency, last  {decile} tokens : acc={:.4}  norm={:.4}",
             mean(&acc[n - decile..]), mean(&nrm[n - decile..]));
    println!("\n(the paper's Fig. 3: accumulated scores inflate early tokens; \
              normalized scores recover the question span)");
    Ok(())
}
