//! End-to-end driver (the repo's headline validation run): serve batched
//! requests from all three paper workloads through the full stack —
//! threaded server -> continuous batcher -> engine -> PJRT artifacts
//! (FlashAttention + probe kernels) -> mixed-precision compressed cache —
//! and report accuracy, latency, throughput and compression per policy.
//!
//! Results are recorded in EXPERIMENTS.md §E2E.
//!
//! ```sh
//! make artifacts
//! cargo run --release --example serve_e2e -- --model tiny --requests 24
//! ```

use std::time::Instant;

use zipcache::config::{EngineConfig, PolicyKind};
use zipcache::eval::{score_generation, AccuracyReport};
use zipcache::metrics::LatencyStats;
use zipcache::server::Server;
use zipcache::util::bench::Table;
use zipcache::util::cli::Args;
use zipcache::workload::{RequestTrace, Task};
use zipcache::Result;

fn main() -> Result<()> {
    let args = Args::new("serve_e2e", "end-to-end batched serving over all workloads")
        .flag("artifacts", "artifacts", "artifacts directory (or \"sim\")")
        .flag("model", "tiny", "model config")
        .flag("requests", "24", "requests per workload")
        .flag("rate", "20.0", "arrival rate (req/s)")
        .flag("max-new", "3", "decode budget")
        .flag("shards", "1", "engine shards (0 = per-core)")
        .flag("policies", "fp16,zipcache", "comma-separated policy list")
        .flag("seed", "0", "trace seed")
        .parse()?;

    let requests = args.get_usize("requests")?;
    let rate = args.get_f64("rate")?;
    let max_new = args.get_usize("max-new")?;
    let seed = args.get_u64("seed")?;

    let mut table = Table::new(&[
        "policy", "task", "acc%", "p50 ms", "p99 ms", "tok/s", "req/s",
    ]);

    for pol in args.get("policies").split(',') {
        let policy: PolicyKind = pol.trim().parse()?;
        for (task, label) in [
            (Task::Gsm, "gsm"),
            (Task::Lines(8), "lines8"),
            (Task::Code, "code"),
        ] {
            let mut cfg =
                EngineConfig::load_default(args.get("artifacts"), &args.get("model"))?;
            cfg.policy = policy;
            cfg.seed = seed;
            cfg.scheduler.shards = args.get_usize("shards")?;
            // derive the window from the artifacts (or sim registry)
            let window = zipcache::runtime::load_model_info(
                &cfg.artifacts_dir, &cfg.model)?.max_seq;
            anyhow::ensure!(max_new >= 1 && max_new < window,
                            "max-new must be in [1, {window})");
            let server = Server::start(cfg)?;
            let trace = RequestTrace::poisson(task, window - max_new, requests,
                                              rate, max_new, seed);

            let t0 = Instant::now();
            let mut workers = Vec::new();
            for e in trace.entries {
                let h = server.handle.clone();
                workers.push(std::thread::spawn(move || {
                    std::thread::sleep(std::time::Duration::from_millis(
                        e.arrival_ms as u64));
                    let t_sub = Instant::now();
                    let out = h.generate(e.sample.prompt().to_vec(), e.max_new_tokens);
                    (t_sub.elapsed(), e.sample, out)
                }));
            }
            let mut report = AccuracyReport::default();
            let mut lat = LatencyStats::default();
            let mut tokens = 0usize;
            for w in workers {
                let (dur, sample, out) =
                    w.join().map_err(|_| anyhow::anyhow!("worker panicked"))?;
                let out = out?;
                report.add(score_generation(&sample, &out.tokens));
                lat.record(dur);
                tokens += out.tokens.len();
            }
            let wall = t0.elapsed().as_secs_f64();
            table.row(&[
                policy.to_string(),
                label.to_string(),
                format!("{:.1}", report.accuracy_pct),
                format!("{:.0}", lat.p50_ms()),
                format!("{:.0}", lat.p99_ms()),
                format!("{:.1}", tokens as f64 / wall),
                format!("{:.1}", requests as f64 / wall),
            ]);
            server.shutdown()?;
            eprintln!("[{}] {} done", policy, label);
        }
    }

    println!("\n== end-to-end serving ({requests} req/workload, rate {rate}/s) ==");
    table.print();
    Ok(())
}
