//! Quickstart: load the AOT artifacts, serve one prompt with ZipCache
//! compression, and print the generation + compression stats.
//!
//! ```sh
//! make artifacts          # build HLO artifacts (once)
//! cargo run --release --example quickstart -- --model micro
//! ```

use zipcache::config::EngineConfig;
use zipcache::coordinator::Engine;
use zipcache::eval::score_generation;
use zipcache::util::cli::Args;
use zipcache::workload::{Task, TaskGen};
use zipcache::Result;

fn main() -> Result<()> {
    let args = Args::new("quickstart", "one-prompt ZipCache demo")
        .flag("artifacts", "artifacts", "artifacts directory")
        .flag("model", "micro", "model config")
        .flag("seed", "7", "sample seed")
        .parse()?;

    let cfg = EngineConfig::load_default(args.get("artifacts"), &args.get("model"))?;
    println!("loading artifacts from {:?} ...", cfg.artifacts_dir);
    let mut engine = Engine::new(cfg)?;
    let info = engine.runtime().model_info().clone();
    println!(
        "model '{}' ready: {} layers, window {}, {:.2}M params",
        engine.runtime().model_name(), info.n_layers, info.max_seq,
        info.n_params as f64 / 1e6
    );

    // A line-retrieval prompt: the model must fetch the value stored at the
    // queried line index — the workload where salient-token identification
    // matters most (paper §5.2.2).
    let max_new = 2;
    let gen = TaskGen::new(Task::Lines(6), info.max_seq - max_new);
    let sample = gen.sample(args.get_u64("seed")?);
    println!(
        "\nprompt: {} tokens, queried span at {:?}, expected answer token {}",
        sample.prompt_len, sample.salient_span, sample.answer[0]
    );

    let out = engine.generate(sample.prompt(), max_new)?;
    println!("generated tokens : {:?}", out.tokens);
    println!("correct          : {}", score_generation(&sample, &out.tokens));
    println!("prefill latency  : {:.1} ms", out.prefill_ms);
    println!("decode latency   : {:.1} ms", out.decode_ms);
    println!("compression      : {:.2}x ({} bytes cache)",
             out.compression_ratio, out.cache_bytes);
    Ok(())
}
