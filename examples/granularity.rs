//! Table 1 companion: quantization-granularity comparison on *real* KV
//! tensors pulled from the model's prefill, reporting reconstruction error
//! and measured compression ratio per scheme.
//!
//! ```sh
//! cargo run --release --example granularity -- --model micro
//! ```

use zipcache::config::{EngineConfig, PolicyKind};
use zipcache::coordinator::{Engine, GenerationRequest};
use zipcache::kvcache::{CompressedKV, PrecisionClass, QuantSpec};
use zipcache::quant::Granularity;
use zipcache::util::bench::Table;
use zipcache::util::cli::Args;
use zipcache::workload::{Task, TaskGen};
use zipcache::Result;

fn main() -> Result<()> {
    let args = Args::new("granularity", "Table 1: quantization granularities on real KV")
        .flag("artifacts", "artifacts", "artifacts directory")
        .flag("model", "micro", "model config")
        .flag("bits", "4", "quantization bit-width")
        .flag("seed", "3", "sample seed")
        .parse()?;
    let bits: u8 = args.get("bits").parse()?;

    let mut cfg = EngineConfig::load_default(args.get("artifacts"), &args.get("model"))?;
    cfg.policy = PolicyKind::Fp16; // we quantize manually below
    let mut engine = Engine::new(cfg)?;
    let info = engine.runtime().model_info().clone();
    let layout = info.cache_layout();

    // Pull real K/V from a prefill.
    let gen = TaskGen::new(Task::Gsm, info.max_seq - 2);
    let sample = gen.sample(args.get_u64("seed")?);
    let sess = engine
        .start_session(GenerationRequest::new(sample.prompt().to_vec(), 2))?;
    let n = sample.prompt_len;
    let (k, v) = (sess.kbuf(), sess.vbuf());

    let variants: Vec<(&str, QuantSpec)> = vec![
        ("groupwise/groupwise", QuantSpec {
            key_gran: Granularity::Group(8), value_gran: Granularity::Group(8) }),
        ("tokenwise/tokenwise", QuantSpec {
            key_gran: Granularity::Token, value_gran: Granularity::Token }),
        ("channelwise/tokenwise", QuantSpec {
            key_gran: Granularity::Channel, value_gran: Granularity::Token }),
        ("channelwise/CST (paper)", QuantSpec {
            key_gran: Granularity::Channel,
            value_gran: Granularity::ChannelSeparableToken }),
    ];

    let mut table = Table::new(&["K/V granularity", "ratio", "recon MSE"]);
    let classes = vec![PrecisionClass::Bits(bits); n];
    for (name, spec) in variants {
        let store = CompressedKV::compress(k, v, layout, &classes, spec);
        table.row(&[
            name.to_string(),
            format!("{:.2}x", store.compression_ratio()),
            format!("{:.3e}", store.reconstruction_mse(k, v)),
        ]);
    }
    println!("== quantization granularities at {bits}-bit on {n} live tokens ==");
    table.print();
    println!("\n(paper Table 1: channelwise-K + CST-V matches groupwise accuracy \
              at tokenwise-level parameter overhead)");
    Ok(())
}
