//! Memory-residency sweep (EXPERIMENTS.md §Memory, DESIGN.md §10):
//! peak resident bytes, park cycles, and throughput across
//! `memory.slots` x `max_batch` configurations on the sim backend.
//!
//! The contract under test: dense fp32 memory is bounded by the slot
//! pool — peak resident bytes never exceed
//! `slots x slot_bytes + batch x worst_case_compressed` — while per-tag
//! outputs stay bit-identical at every slot count (park/unpark is
//! bit-exact), so shrinking `slots` trades park/re-materialization
//! cycles for bounded memory, never accuracy.  Emits
//! `BENCH_memory.json` (uploaded by the CI `bench-smoke` job).
//!
//! Run: `cargo bench --bench memory_residency` (append `-- --smoke` for
//! the short CI variant).

use std::time::Instant;

use zipcache::config::EngineConfig;
use zipcache::coordinator::batcher::{ContinuousBatcher, QueuedRequest};
use zipcache::coordinator::{Engine, GenerationRequest};
use zipcache::kvcache::worst_case_resident_bytes;
use zipcache::util::bench::Table;
use zipcache::workload::{Task, TaskGen};

const MAX_NEW: usize = 12;
const SEED: u64 = 42;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let batches: &[usize] = if smoke { &[4] } else { &[4, 8] };
    let requests_per_batch = if smoke { 2 } else { 3 };

    let mut table = Table::new(&[
        "batch", "slots", "park cycles", "preempted", "peak slots",
        "peak resident KiB", "dense bound KiB", "tok/s", "wall ms",
    ]);
    let mut rows = Vec::new();

    for &batch in batches {
        let n_requests = batch * requests_per_batch;
        // Per-tag outputs must be identical at every slot count — the
        // determinism contract the sweep rides on.
        let mut reference: Option<Vec<(u64, Vec<u16>)>> = None;

        for slots in [1usize, 2, batch] {
            let mut cfg = EngineConfig::load_default("sim", "micro")
                .expect("sim config");
            cfg.scheduler.max_batch = batch;
            cfg.memory.slots = slots;
            cfg.quant.recompress_every = 4;
            cfg.parallelism = 1;
            cfg.seed = SEED;
            let recompress = cfg.quant.recompress_every;
            let mut engine = Engine::new(cfg).expect("engine");
            let layout = engine.layout();
            let slot_bytes = engine.slot_pool().slot_bytes();

            let gen = TaskGen::new(Task::Code, layout.seq - MAX_NEW);
            let mut batcher = ContinuousBatcher::new(batch, n_requests);
            for tag in 0..n_requests as u64 {
                batcher
                    .submit(QueuedRequest {
                        request: GenerationRequest::new(
                            gen.sample(tag).prompt().to_vec(), MAX_NEW),
                        tag,
                    })
                    .expect("queue sized to the trace");
            }
            let t0 = Instant::now();
            let outcomes = batcher.run_to_completion(&mut engine).expect("run");
            let wall = t0.elapsed();
            assert_eq!(outcomes.len(), n_requests, "requests dropped");

            let outputs: Vec<(u64, Vec<u16>)> = outcomes
                .iter()
                .map(|o| (o.tag, o.tokens.clone()))
                .collect();
            match &reference {
                None => reference = Some(outputs),
                Some(want) => assert_eq!(
                    want, &outputs,
                    "batch={batch} slots={slots} changed per-request outputs"
                ),
            }

            // The residency contract: dense memory bounded by the slot
            // pool, compressed state bounded by the worst case per
            // active session.
            let peak_resident = engine.metrics.peak_resident_bytes;
            let peak_slots = engine.slot_pool().peak_in_use();
            let wc = worst_case_resident_bytes(layout, layout.seq, recompress);
            assert!(peak_slots <= slots,
                    "batch={batch} slots={slots}: {peak_slots} dense slots");
            assert!(
                peak_resident <= slots * slot_bytes + batch * wc,
                "batch={batch} slots={slots}: peak resident {peak_resident} B \
                 exceeds {slots} x {slot_bytes} + {batch} x {wc}"
            );
            // And the dense part is real: at least one slot's worth was
            // resident at the peak.
            assert!(peak_resident >= slot_bytes,
                    "peak resident below a single dense slot");
            let park_cycles = engine.metrics.park_cycles;
            if slots == batch {
                assert_eq!(park_cycles, 0, "full pool must never park");
            } else {
                assert!(park_cycles > 0, "bounded pool never parked");
            }

            let tokens: usize =
                outcomes.iter().map(|o| o.tokens.len()).sum();
            let tok_s = tokens as f64 / wall.as_secs_f64();
            table.row(&[
                batch.to_string(),
                slots.to_string(),
                park_cycles.to_string(),
                batcher.preempted().to_string(),
                peak_slots.to_string(),
                format!("{:.1}", peak_resident as f64 / 1024.0),
                format!("{:.1}", (slots * slot_bytes) as f64 / 1024.0),
                format!("{tok_s:.0}"),
                format!("{:.1}", wall.as_secs_f64() * 1000.0),
            ]);
            rows.push(format!(
                "    {{\"batch\": {batch}, \"slots\": {slots}, \
                 \"park_cycles\": {park_cycles}, \
                 \"preempted\": {}, \
                 \"peak_slots_in_use\": {peak_slots}, \
                 \"peak_resident_bytes\": {peak_resident}, \
                 \"dense_slot_bytes\": {slot_bytes}, \
                 \"worst_case_request_bytes\": {wc}, \
                 \"tok_per_s\": {tok_s:.1}, \
                 \"wall_ms\": {:.1}}}",
                batcher.preempted(),
                wall.as_secs_f64() * 1000.0,
            ));
        }
    }

    let json = format!(
        "{{\n  \"bench\": \"memory_residency\",\n  \"model\": \"micro\",\n  \
         \"smoke\": {smoke},\n  \"max_new\": {MAX_NEW},\n  \"rows\": [\n{}\n  ]\n}}\n",
        rows.join(",\n")
    );
    std::fs::write("BENCH_memory.json", &json).unwrap();

    println!("== memory residency (sim backend, micro) ==");
    table.print();
    print!("{json}");
    println!(
        "\nOK: outputs bit-identical across slot counts; peak resident \
         bounded by slots x dense + batch x worst-case compressed"
    );
}
