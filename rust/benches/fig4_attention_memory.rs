//! Figure 4 reproduction: standard attention (full score materialization)
//! vs FlashAttention (tiled) — measured prefill wall-clock on the PJRT
//! artifacts plus the analytic O(l²) vs O(l) workspace argument at the
//! paper's A100 scale.

mod common;

use zipcache::runtime::{Runtime, Tensor};
use zipcache::simcost::{prefill_cost, prefill_workspace_bytes, AttnKind, AttnShape,
                        Hardware};
use zipcache::util::bench::{Bencher, Table};
use zipcache::workload::{Task, TaskGen};

fn main() -> zipcache::Result<()> {
    let rt = Runtime::load(common::artifacts_dir(), &common::bench_model())?;
    let info = rt.model_info().clone();
    let smax = info.max_seq;
    let pc = info.probe_count;

    // --- measured: the two prefill artifacts on this box -------------------
    let gen = TaskGen::new(Task::Gsm, smax - 2);
    let sample = gen.sample(5);
    let n = sample.prompt_len;
    let mut tokens = vec![0i32; smax];
    for (j, &t) in sample.prompt().iter().enumerate() {
        tokens[j] = t as i32;
    }
    let mut valid = vec![0f32; smax];
    valid[..n].fill(1.0);
    let pidx: Vec<i32> = (0..pc).map(|i| (n - 1 - i.min(n - 1)) as i32).rev().collect();

    let b = Bencher::quick();
    let m_full = b.measure("prefill_full", || {
        rt.execute(&rt.entry("prefill_full"),
                   &[Tensor::i32(tokens.clone(), &[smax]),
                     Tensor::f32(valid.clone(), &[smax])])
            .unwrap();
    });
    let m_flash = b.measure("prefill_flash", || {
        rt.execute(&rt.entry("prefill_flash"),
                   &[Tensor::i32(tokens.clone(), &[smax]),
                     Tensor::f32(valid.clone(), &[smax]),
                     Tensor::i32(pidx.clone(), &[pc])])
            .unwrap();
    });

    println!("\n== Figure 4 (measured, model={} l={n}) ==", common::bench_model());
    let mut mt = Table::new(&["path", "median ms", "mean ms", "stddev"]);
    for m in [&m_full, &m_flash] {
        mt.row(&[m.name.clone(), format!("{:.1}", m.median_ms()),
                 format!("{:.1}", m.mean_ms()), format!("{:.1}", m.stddev_ms())]);
    }
    mt.print();

    // --- analytic: the paper's scale (A100, LLaMA3-8B-ish shape) -----------
    println!("\n== Figure 4 (analytic A100 roofline, b=8 h=32 d=128) ==");
    let hw = Hardware::a100();
    let mut at = Table::new(&["l", "std ms", "flash ms", "zip(10% probe) ms",
                              "std workspace MB", "flash workspace MB"]);
    for l in [512usize, 1024, 2048, 4096, 8192] {
        let s = AttnShape { batch: 8, heads: 32, seq: l, d_head: 128, elem: 2.0 };
        at.row(&[
            l.to_string(),
            format!("{:.2}", prefill_cost(hw, s, AttnKind::Standard) * 1e3 * 32.0),
            format!("{:.2}", prefill_cost(hw, s, AttnKind::Flash) * 1e3 * 32.0),
            format!("{:.2}", prefill_cost(hw, s,
                AttnKind::FlashWithProbes { probe_pct: 10 }) * 1e3 * 32.0),
            format!("{:.0}", prefill_workspace_bytes(s, AttnKind::Standard) / 1e6),
            format!("{:.2}", prefill_workspace_bytes(s, AttnKind::Flash) / 1e6),
        ]);
    }
    at.print();
    println!("(per-model = 32 layers; standard attention workspace grows \
              quadratically, flash stays constant — the paper's O(l²) vs O(l))");
    Ok(())
}
