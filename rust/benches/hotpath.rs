//! Hot-path micro-benchmarks for the L3 perf pass (EXPERIMENTS.md §Perf):
//! bit-packing, quantization, cache compression/materialization, saliency
//! selection.  These are the pieces the engine runs on every request and
//! every 100-token recompression cycle.

mod common;

use zipcache::kvcache::{CacheLayout, CompressedKV, PrecisionClass, QuantSpec};
use zipcache::quant::kernel;
use zipcache::quant::packing::PackedCodes;
use zipcache::quant::{Granularity, QuantizedPlane};
use zipcache::saliency::metric::select_salient;
use zipcache::util::bench::{black_box, Bencher, Table};
use zipcache::util::pool::WorkerPool;

fn main() {
    let b = Bencher { warmup: 3, samples: 20, ..Default::default() };
    let mut t = Table::new(&["op", "input", "median ms", "mean ms"]);

    // ---- bit packing --------------------------------------------------------
    let codes: Vec<u8> = (0..1 << 20).map(|i| (i % 4) as u8).collect();
    let m = b.measure("pack 2-bit", || {
        black_box(PackedCodes::pack(&codes, 2));
    });
    t.row(&["pack".into(), "1M codes @2b".into(),
            format!("{:.3}", m.median_ms()), format!("{:.3}", m.mean_ms())]);
    let packed = PackedCodes::pack(&codes, 2);
    let mut out = vec![0u8; codes.len()];
    let m = b.measure("unpack 2-bit", || {
        packed.unpack_into(black_box(&mut out));
    });
    t.row(&["unpack".into(), "1M codes @2b".into(),
            format!("{:.3}", m.median_ms()), format!("{:.3}", m.mean_ms())]);

    // ---- plane quantization -------------------------------------------------
    let rows = 4096;
    let cols = 128;
    let x: Vec<f32> = (0..rows * cols)
        .map(|i| ((i as f32) * 0.137).sin() * if i % 17 == 0 { 8.0 } else { 1.0 })
        .collect();
    for (name, g) in [("token", Granularity::Token),
                      ("channel", Granularity::Channel),
                      ("group(32)", Granularity::Group(32)),
                      ("CST", Granularity::ChannelSeparableToken)] {
        let m = b.measure(name, || {
            black_box(QuantizedPlane::quantize(&x, rows, cols, 4, g));
        });
        t.row(&[format!("quantize {name}"), format!("{rows}x{cols} @4b"),
                format!("{:.3}", m.median_ms()), format!("{:.3}", m.mean_ms())]);
    }
    let q = QuantizedPlane::quantize(&x, rows, cols, 4,
                                     Granularity::ChannelSeparableToken);
    let mut deq = vec![0f32; rows * cols];
    let m = b.measure("dequantize CST", || {
        q.dequantize_into(black_box(&mut deq));
    });
    t.row(&["dequantize CST".into(), format!("{rows}x{cols} @4b"),
            format!("{:.3}", m.median_ms()), format!("{:.3}", m.mean_ms())]);

    // ---- scalar vs SIMD kernel tiers (DESIGN.md §15) ------------------------
    // Same inputs through every kernel kind the CPU supports; the scalar
    // row is the speedup baseline.  Outputs are bit-identical across
    // rows (the parity property tests pin that), so this is purely a
    // wall-clock comparison.
    let kinds: Vec<kernel::Kind> = kernel::compiled_kinds()
        .iter()
        .copied()
        .filter(|&k| kernel::available(k))
        .collect();
    let mut kt = Table::new(&["op", "kernel", "median ms", "speedup vs scalar"]);
    for op in ["pack 1M @2b", "unpack 1M @2b", "quantize token @4b",
               "dequantize CST @4b"] {
        let mut base = 0.0f64;
        for &k in &kinds {
            let m = b.measure(op, || match op {
                "pack 1M @2b" => {
                    black_box(PackedCodes::pack_with(k, &codes, 2));
                }
                "unpack 1M @2b" => packed.unpack_into_with(k, black_box(&mut out)),
                "quantize token @4b" => {
                    black_box(QuantizedPlane::quantize_with(k, &x, rows, cols, 4,
                                                            Granularity::Token));
                }
                _ => q.dequantize_into_with(k, black_box(&mut deq)),
            });
            if k == kernel::Kind::Scalar {
                base = m.median_ms();
            }
            kt.row(&[op.into(), k.name().into(),
                     format!("{:.3}", m.median_ms()),
                     format!("{:.2}x", base / m.median_ms().max(1e-9))]);
        }
    }

    // ---- full cache compress + materialize (recompression cycle cost) -------
    let lay = CacheLayout { layers: 4, heads: 8, seq: 1024, d_head: 64 };
    let n = lay.cache_len();
    let kc: Vec<f32> = (0..n).map(|i| ((i as f32) * 0.377).sin()).collect();
    let vc: Vec<f32> = (0..n).map(|i| ((i as f32) * 0.733).cos()).collect();
    let classes: Vec<PrecisionClass> = (0..1024)
        .map(|i| PrecisionClass::Bits(if i % 5 == 0 { 4 } else { 2 }))
        .collect();
    let m = b.measure("compress", || {
        black_box(CompressedKV::compress(&kc, &vc, lay, &classes,
                                         QuantSpec::default()));
    });
    t.row(&["cache compress".into(), "L4 H8 S1024 d64".into(),
            format!("{:.2}", m.median_ms()), format!("{:.2}", m.mean_ms())]);
    let store = CompressedKV::compress(&kc, &vc, lay, &classes, QuantSpec::default());
    let mut ko = vec![0f32; n];
    let mut vo = vec![0f32; n];
    let mut va = vec![0f32; 1024];
    let m = b.measure("materialize", || {
        store.materialize_into(black_box(&mut ko), black_box(&mut vo),
                               black_box(&mut va));
    });
    t.row(&["cache materialize".into(), "L4 H8 S1024 d64".into(),
            format!("{:.2}", m.median_ms()), format!("{:.2}", m.mean_ms())]);

    // ---- parallel plane-level compression (DESIGN.md §5) --------------------
    // Same cache, same classes: the pooled path must be bit-identical and
    // strictly a wall-clock knob.  Stage timings expose where the time goes.
    let pools = [("compress seq x1", WorkerPool::sequential()),
                 ("compress par auto", WorkerPool::new(0))];
    let mut stage_table = Table::new(&["path", "threads", "split ms", "quant wall ms",
                                       "quant cpu ms", "concat ms", "quant speedup"]);
    let seq_digest = store.content_digest();
    for (name, pool) in &pools {
        let m = b.measure(name, || {
            black_box(CompressedKV::compress_with_pool(
                &kc, &vc, lay, &classes, QuantSpec::default(), pool));
        });
        t.row(&[(*name).into(), format!("L4 H8 S1024 d64 x{}", pool.threads()),
                format!("{:.2}", m.median_ms()), format!("{:.2}", m.mean_ms())]);
        let (par_store, st) = CompressedKV::compress_instrumented(
            &kc, &vc, lay, &classes, QuantSpec::default(), pool);
        assert_eq!(par_store.content_digest(), seq_digest,
                   "parallel compression diverged from sequential");
        stage_table.row(&[
            (*name).into(),
            format!("{}", st.threads),
            format!("{:.3}", st.split_us as f64 / 1000.0),
            format!("{:.3}", st.quant_wall_us as f64 / 1000.0),
            format!("{:.3}", st.quant_cpu_us as f64 / 1000.0),
            format!("{:.3}", st.concat_us as f64 / 1000.0),
            format!("{:.2}x", st.quant_cpu_us as f64 / st.quant_wall_us.max(1) as f64),
        ]);
    }

    // ---- saliency selection --------------------------------------------------
    let sal: Vec<f32> = (0..16384).map(|i| ((i as f32) * 0.91).sin()).collect();
    let m = b.measure("select_salient", || {
        black_box(select_salient(&sal, sal.len(), 0.4));
    });
    t.row(&["select_salient".into(), "16k tokens".into(),
            format!("{:.3}", m.median_ms()), format!("{:.3}", m.mean_ms())]);

    println!("\n== L3 hot-path micro-benchmarks ==");
    t.print();
    println!("\n== compression stage breakdown (Split -> Quant -> Concat) ==");
    stage_table.print();
    println!("\n== quant kernel tiers (scalar baseline, DESIGN.md §15) ==");
    kt.print();
}
