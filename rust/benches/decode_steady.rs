//! Steady-state decode bench with a counting allocator (EXPERIMENTS.md
//! §Perf, DESIGN.md §9): per-token latency and per-step heap-allocation
//! counts of `Engine::decode_step` on the sim backend, partitioned into
//! non-recompression (steady) steps and recompression-cycle steps.
//!
//! This is the gate for the zero-allocation decode hot path: after a
//! short per-session warm-up (two steps — the first step materializes the
//! session scratch), every step that does not run a recompression cycle
//! must perform **zero** heap allocations.  The bench panics otherwise,
//! and emits `BENCH_decode.json` (consumed as a CI artifact by the
//! `bench-smoke` job) to seed the perf trajectory.
//!
//! Run: `cargo bench --bench decode_steady` (append `-- --smoke` for the
//! short CI variant).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use zipcache::config::EngineConfig;
use zipcache::coordinator::{Engine, GenerationRequest};
use zipcache::quant::kernel;
use zipcache::quant::packing::PackedCodes;
use zipcache::quant::{Granularity, QuantizedPlane};

/// The system allocator wrapped with allocation-event counters.  Frees
/// are not counted: the hot-path contract is about *new* heap traffic.
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);
static ALLOC_BYTES: AtomicU64 = AtomicU64::new(0);

// SAFETY: pure pass-through to `System` with side-effect-free atomic
// counters; every GlobalAlloc contract obligation (layout validity,
// pointer provenance) is delegated unchanged.
unsafe impl GlobalAlloc for CountingAlloc {
    // SAFETY: caller upholds the `GlobalAlloc::alloc` contract
    // (non-zero-sized `layout`); forwarded to `System` verbatim.
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc(layout)
    }

    // SAFETY: same contract as `alloc`, forwarded to `System` verbatim.
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    // SAFETY: caller guarantees `ptr` came from this allocator with
    // `layout`, and `new_size` is non-zero; forwarded to `System`.
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        // Count only the growth: a 1 MB -> 2 MB regrow is 1 MB of new
        // heap traffic, not 2 MB (shrinks count as an event, zero bytes).
        ALLOC_BYTES.fetch_add(new_size.saturating_sub(layout.size()) as u64,
                              Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    // SAFETY: caller guarantees `ptr` came from this allocator with
    // `layout`; forwarded to `System` verbatim (frees are not counted).
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocs() -> (u64, u64) {
    (ALLOCS.load(Ordering::Relaxed), ALLOC_BYTES.load(Ordering::Relaxed))
}

#[derive(Default)]
struct Bucket {
    steps: u64,
    ns: Vec<u64>,
    allocs: u64,
    bytes: u64,
    max_allocs_one_step: u64,
}

impl Bucket {
    fn record(&mut self, ns: u64, a: u64, b: u64) {
        self.steps += 1;
        self.ns.push(ns);
        self.allocs += a;
        self.bytes += b;
        self.max_allocs_one_step = self.max_allocs_one_step.max(a);
    }

    fn p50_us(&mut self) -> f64 {
        if self.ns.is_empty() {
            return 0.0;
        }
        self.ns.sort_unstable();
        self.ns[self.ns.len() / 2] as f64 / 1000.0
    }

    fn mean_us(&self) -> f64 {
        if self.ns.is_empty() {
            return 0.0;
        }
        self.ns.iter().sum::<u64>() as f64 / self.ns.len() as f64 / 1000.0
    }
}

/// Median wall time of `f` over a few samples (3 warm-ups, 9 measured)
/// — enough resolution for the kernel speedup ratio columns.
fn median_ns<F: FnMut()>(mut f: F) -> u64 {
    for _ in 0..3 {
        f();
    }
    let mut v: Vec<u64> = (0..9)
        .map(|_| {
            let t = Instant::now();
            f();
            t.elapsed().as_nanos() as u64
        })
        .collect();
    v.sort_unstable();
    v[v.len() / 2]
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let target_steps: u64 = if smoke { 240 } else { 1200 };

    let mut cfg = EngineConfig::load_default("sim", "tiny").unwrap();
    cfg.parallelism = 2;
    let recompress_every = cfg.quant.recompress_every;
    let mut engine = Engine::new(cfg).unwrap();
    let smax = engine.layout().seq;

    // Histogram pushes inside the engine are amortized-O(1); reserve
    // generously (bounded by the 10k-session cap below) so the measured
    // window never lands on a growth step.
    engine.metrics.decode.reserve(1 << 20);
    engine.metrics.compress.reserve(1 << 14);
    engine.metrics.prefill.reserve(1 << 14);

    let mut steady = Bucket::default();
    let mut cycle = Bucket::default();
    let mut sessions = 0u64;
    let mut violations = 0u64;

    // Run until the step target is met AND at least two recompression
    // cycles were observed (sessions can end early on EOS; the session
    // cap bounds the loop — everything here is deterministic, so this is
    // belt-and-braces, not flake control).
    while (steady.steps + cycle.steps < target_steps || cycle.steps < 2)
        && sessions < 10_000
    {
        // A fresh prompt per session (content-derived seeds make each
        // trajectory distinct); budget sized to the window.
        let prompt: Vec<u16> = (0..16u64)
            .map(|i| 16 + ((sessions * 31 + i * 7) % 200) as u16)
            .collect();
        let max_new = smax - prompt.len() - 1;
        let mut s = engine
            .start_session(GenerationRequest::new(prompt, max_new))
            .unwrap();
        s.stream.reserve_rows(recompress_every, smax);
        sessions += 1;

        // Per-session warm-up: step 1 materializes the session scratch
        // (execution slots, layer-mean buffer); from step 2 on the
        // non-recompression path must be allocation-free.
        for _ in 0..2 {
            if s.is_done() {
                break;
            }
            engine.decode_step(&mut s).unwrap();
        }

        while !s.is_done()
            && (steady.steps + cycle.steps < target_steps || cycle.steps < 2)
        {
            let (a0, b0) = allocs();
            let c0 = engine.metrics.compress.count();
            let t = Instant::now();
            engine.decode_step(&mut s).unwrap();
            let ns = t.elapsed().as_nanos() as u64;
            let (a1, b1) = allocs();
            let recompressed = engine.metrics.compress.count() > c0;
            let (da, db) = (a1 - a0, b1 - b0);
            if recompressed {
                cycle.record(ns, da, db);
            } else {
                steady.record(ns, da, db);
                if da != 0 {
                    violations += 1;
                    eprintln!(
                        "ALLOC VIOLATION: steady step did {da} allocations \
                         ({db} bytes) at pos {}",
                        s.pos
                    );
                }
            }
        }
    }

    // ---- per-kernel unpack/dequant ratio (DESIGN.md §15) -------------------
    // Micro-measure the two decode-side kernels under the scalar tier vs
    // the tier the engine actually ran with, and emit the speedup into
    // the JSON so the perf trajectory tracks the SIMD win per release.
    let active_kind = kernel::active();
    let kcodes: Vec<u8> = (0..1 << 18).map(|i| (i % 4) as u8).collect();
    let kpacked = PackedCodes::pack(&kcodes, 2);
    let mut kunp = vec![0u8; kcodes.len()];
    let kx: Vec<f32> = (0..256 * 128).map(|i| (i as f32 * 0.137).sin()).collect();
    let kq = QuantizedPlane::quantize_with(kernel::Kind::Scalar, &kx, 256, 128, 4,
                                           Granularity::ChannelSeparableToken);
    let mut kdeq = vec![0f32; kx.len()];
    let unpack_scalar = median_ns(|| {
        kpacked.unpack_into_with(kernel::Kind::Scalar, std::hint::black_box(&mut kunp));
    });
    let unpack_active = median_ns(|| {
        kpacked.unpack_into_with(active_kind, std::hint::black_box(&mut kunp));
    });
    let dequant_scalar = median_ns(|| {
        kq.dequantize_into_with(kernel::Kind::Scalar, std::hint::black_box(&mut kdeq));
    });
    let dequant_active = median_ns(|| {
        kq.dequantize_into_with(active_kind, std::hint::black_box(&mut kdeq));
    });
    let quant_kernel = active_kind.name();
    let unpack_speedup = unpack_scalar as f64 / unpack_active.max(1) as f64;
    let dequant_speedup = dequant_scalar as f64 / dequant_active.max(1) as f64;

    let steady_steps = steady.steps;
    let steady_p50 = steady.p50_us();
    let steady_mean = steady.mean_us();
    let steady_allocs_per_step = steady.allocs as f64 / steady.steps.max(1) as f64;
    let steady_bytes_per_step = steady.bytes as f64 / steady.steps.max(1) as f64;
    let steady_max_allocs = steady.max_allocs_one_step;
    let cycle_steps = cycle.steps;
    let cycle_p50 = cycle.p50_us();
    let cycle_mean = cycle.mean_us();
    let cycle_allocs = cycle.allocs as f64 / cycle.steps.max(1) as f64;
    let json = format!(
        "{{\n  \"bench\": \"decode_steady\",\n  \"model\": \"tiny\",\n  \
         \"smoke\": {smoke},\n  \"sessions\": {sessions},\n  \
         \"steady_steps\": {steady_steps},\n  \
         \"steady_per_token_us_p50\": {steady_p50:.3},\n  \
         \"steady_per_token_us_mean\": {steady_mean:.3},\n  \
         \"steady_allocs_per_step\": {steady_allocs_per_step:.4},\n  \
         \"steady_bytes_per_step\": {steady_bytes_per_step:.1},\n  \
         \"steady_max_allocs_one_step\": {steady_max_allocs},\n  \
         \"recompress_steps\": {cycle_steps},\n  \
         \"recompress_us_p50\": {cycle_p50:.3},\n  \
         \"recompress_us_mean\": {cycle_mean:.3},\n  \
         \"recompress_allocs_per_step\": {cycle_allocs:.1},\n  \
         \"quant_kernel\": \"{quant_kernel}\",\n  \
         \"kernel_unpack_speedup_vs_scalar\": {unpack_speedup:.2},\n  \
         \"kernel_dequant_speedup_vs_scalar\": {dequant_speedup:.2}\n}}\n",
    );
    std::fs::write("BENCH_decode.json", &json).unwrap();

    println!("== decode steady-state (sim backend, tiny) ==");
    print!("{json}");

    // The tentpole contract (ISSUE 3): zero heap allocations on the
    // steady-state decode step, every recompression confined to its own
    // cycle steps.
    assert_eq!(
        violations, 0,
        "steady-state decode steps performed heap allocations"
    );
    assert!(
        cycle.steps > 0,
        "bench never exercised a recompression cycle — widen the window"
    );
    println!("OK: {} steady steps, 0 allocations/step (quant kernel: {quant_kernel})",
             steady.steps);
}
