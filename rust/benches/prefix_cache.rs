//! Shared-prefix segment store sweep (DESIGN.md §16 and
//! EXPERIMENTS.md §Prefix): cold vs warm admission-to-first-token over a
//! hit-ratio sweep, priced on the `simcost` roofline virtual clock.
//!
//! Each point replays the same request set against a cold engine (store
//! disabled) and a warm engine (store primed with the shared system
//! prompt): `hit_pct` percent of the requests fork from the interned
//! prefix and prefill only their private tail, the rest carry distinct
//! cold prompts.  TTFT is virtual — tokens actually prefilled times the
//! per-token prefill cost plus one decode step — so the speedup is
//! exactly the skipped-prefill fraction, identical on every host.
//! Outputs and snapshot digests must stay bit-identical between the two
//! engines at every point (the contract pinned by
//! `tests/prefix_parity.rs`).  Emits `BENCH_prefix.json` (uploaded by
//! the CI `prefix-cache` job).
//!
//! Run: `cargo bench --bench prefix_cache` (append `-- --smoke` for the
//! short CI variant).

use std::time::Instant;

use zipcache::config::EngineConfig;
use zipcache::coordinator::{Engine, GenerationRequest};
use zipcache::server::loadgen;
use zipcache::simcost::{decode_cost_per_token, prefill_cost, AttnKind,
                        AttnShape, Hardware};
use zipcache::util::bench::Table;
use zipcache::workload::tasks::FIL0;
use zipcache::workload::{Task, TaskGen};

const MAX_NEW: usize = 4;
const CHUNK: usize = 3;
const N_REQUESTS: usize = 8;
const SEED: u64 = 13;

fn sim_cfg(prefix: bool) -> EngineConfig {
    let mut cfg = EngineConfig::load_default("sim", "micro").expect("sim config");
    cfg.scheduler.prefill_chunk = CHUNK;
    cfg.quant.recompress_every = 4;
    cfg.parallelism = 1;
    cfg.seed = SEED;
    cfg.prefix.enable = prefix;
    cfg
}

/// Run one prompt to completion; returns (tokens, digest, virtual TTFT,
/// prompt tokens actually prefilled).  TTFT = prefilled tokens priced at
/// the per-token prefill cost + one decode step.
fn run_one(engine: &mut Engine, p: &[u16], per_tok: f64, decode: f64)
           -> (Vec<u16>, u64, f64, usize) {
    let skipped0 = engine.metrics.prefill_tokens_skipped;
    let mut s = engine
        .start_session(GenerationRequest::new(p.to_vec(), MAX_NEW))
        .expect("session");
    while !s.is_done() {
        engine.decode_step(&mut s).expect("decode");
    }
    let skipped = (engine.metrics.prefill_tokens_skipped - skipped0) as usize;
    let prefilled = p.len() - skipped;
    let digest = s.compressed.as_ref().expect("snapshot").content_digest();
    (s.generated.clone(), digest, prefilled as f64 * per_tok + decode, prefilled)
}

struct Point {
    hit_pct: usize,
    hits: u64,
    tokens_skipped: u64,
    cold_ttft_vns: f64,
    warm_ttft_vns: f64,
    wall_ms: f64,
}

fn run_point(hit_pct: usize) -> Point {
    let t0 = Instant::now();
    let k = N_REQUESTS * hit_pct / 100;
    // k requests fork from one shared system prompt (distinct tails);
    // the rest are distinct near-window cold prompts.
    let shared = loadgen::shared_prefix_trace(64, k + 1, 0, SEED);
    let prime = shared.entries[0].sample.prompt().to_vec();
    let mut prompts: Vec<Vec<u16>> = shared.entries[1..1 + k]
        .iter()
        .map(|e| e.sample.prompt().to_vec())
        .collect();
    let cold_gen = TaskGen::new(Task::Lines(8), 56);
    for i in 0..N_REQUESTS - k {
        let mut p = cold_gen.sample(SEED ^ (0x51 + i as u64)).prompt().to_vec();
        // A unique filler token right after BOS keeps every cold
        // prompt's first granule distinct (two line-retrieval samples
        // can share a leading digit token, which would register as an
        // accidental store hit and skew the hit accounting).
        p[1] = FIL0 + i as u16;
        prompts.push(p);
    }

    let lay = {
        let e = Engine::new(sim_cfg(false)).expect("engine");
        e.layout()
    };
    let shape = AttnShape {
        batch: 1,
        heads: lay.heads,
        seq: lay.seq,
        d_head: lay.d_head,
        elem: 2.0,
    };
    let hw = Hardware::a100();
    let per_tok =
        prefill_cost(hw, shape, AttnKind::FlashWithProbes { probe_pct: 10 })
            / lay.seq as f64;
    let decode = decode_cost_per_token(hw, shape, 2.8, AttnKind::Flash);

    let mut cold_engine = Engine::new(sim_cfg(false)).expect("cold engine");
    let mut warm_engine = Engine::new(sim_cfg(true)).expect("warm engine");
    // Prime the store: one full cold pass over the system prompt (its
    // prefill epilogue interns the shared segments).  Not measured.
    let _ = run_one(&mut warm_engine, &prime, per_tok, decode);

    let (mut cold_vns, mut warm_vns) = (0.0f64, 0.0f64);
    for (i, p) in prompts.iter().enumerate() {
        let cold = run_one(&mut cold_engine, p, per_tok, decode);
        let warm = run_one(&mut warm_engine, p, per_tok, decode);
        // The headline contract: forking from the store is invisible to
        // generation and to the retained snapshot.
        assert_eq!((&cold.0, cold.1), (&warm.0, warm.1),
                   "hit_pct={hit_pct} request {i}: warm diverged from cold");
        assert_eq!(cold.3, p.len(), "cold engine must prefill everything");
        cold_vns += cold.2;
        warm_vns += warm.2;
    }
    Point {
        hit_pct,
        hits: warm_engine.metrics.prefix_hits,
        tokens_skipped: warm_engine.metrics.prefill_tokens_skipped,
        cold_ttft_vns: cold_vns / prompts.len() as f64 * 1e9,
        warm_ttft_vns: warm_vns / prompts.len() as f64 * 1e9,
        wall_ms: t0.elapsed().as_secs_f64() * 1000.0,
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let pcts: &[usize] = if smoke { &[0, 100] } else { &[0, 25, 50, 75, 100] };

    let mut table = Table::new(&[
        "hit %", "hits", "tokens skipped", "cold TTFT vns", "warm TTFT vns",
        "speedup", "wall ms",
    ]);
    let mut rows = Vec::new();
    let mut prev_warm = f64::INFINITY;
    for &pct in pcts {
        let st = run_point(pct);
        let expect_hits = (N_REQUESTS * pct / 100) as u64;
        assert_eq!(st.hits, expect_hits, "hit_pct={pct}: hit accounting");
        if pct == 0 {
            assert_eq!(st.tokens_skipped, 0);
            assert!((st.warm_ttft_vns - st.cold_ttft_vns).abs() < 1e-9,
                    "an idle store must cost nothing on the virtual clock");
        } else {
            assert!(st.tokens_skipped > 0);
            assert!(st.warm_ttft_vns < st.cold_ttft_vns,
                    "hit_pct={pct}: warm TTFT must beat cold");
        }
        assert!(st.warm_ttft_vns <= prev_warm + 1e-9,
                "warm TTFT must be non-increasing in the hit ratio");
        prev_warm = st.warm_ttft_vns;
        let speedup = st.cold_ttft_vns / st.warm_ttft_vns;
        table.row(&[
            pct.to_string(),
            st.hits.to_string(),
            st.tokens_skipped.to_string(),
            format!("{:.3}", st.cold_ttft_vns),
            format!("{:.3}", st.warm_ttft_vns),
            format!("{speedup:.2}x"),
            format!("{:.2}", st.wall_ms),
        ]);
        rows.push(format!(
            "    {{\"hit_pct\": {pct}, \"requests\": {N_REQUESTS}, \
             \"prefix_hits\": {}, \"prefill_tokens_skipped\": {}, \
             \"cold_ttft_vns_mean\": {:.3}, \"warm_ttft_vns_mean\": {:.3}, \
             \"ttft_speedup\": {speedup:.4}, \"wall_ms\": {:.2}}}",
            st.hits, st.tokens_skipped, st.cold_ttft_vns, st.warm_ttft_vns,
            st.wall_ms,
        ));
    }

    let json = format!(
        "{{\n  \"bench\": \"prefix_cache\",\n  \"model\": \"micro\",\n  \
         \"smoke\": {smoke},\n  \"prefill_chunk\": {CHUNK},\n  \
         \"max_new\": {MAX_NEW},\n  \"rows\": [\n{}\n  ]\n}}\n",
        rows.join(",\n")
    );
    std::fs::write("BENCH_prefix.json", &json).unwrap();

    println!("== shared-prefix cache sweep (sim backend, micro, virtual clock) ==");
    table.print();
    print!("{json}");
    println!(
        "\nOK: warm forks bit-identical to cold starts; TTFT falls \
         monotonically with the hit ratio"
    );
}
