//! Shared helpers for the paper-reproduction benches.
//!
//! Every bench honours:
//!   * `ZIPCACHE_BENCH_MODEL`   — model config (default "micro"; use "tiny"
//!     for the full-scale runs recorded in EXPERIMENTS.md)
//!   * `ZIPCACHE_BENCH_SAMPLES` — per-cell sample count (default small so
//!     `cargo bench` completes quickly on CPU)
//!   * `ZIPCACHE_ARTIFACTS`     — artifacts dir (default "artifacts")

#![allow(dead_code)]

use zipcache::config::{EngineConfig, PolicyKind};
use zipcache::coordinator::Engine;
use zipcache::eval::{score_generation, AccuracyReport};
use zipcache::workload::{Task, TaskGen};
use zipcache::Result;

pub fn bench_model() -> String {
    std::env::var("ZIPCACHE_BENCH_MODEL").unwrap_or_else(|_| "micro".into())
}

pub fn bench_samples(default: usize) -> usize {
    std::env::var("ZIPCACHE_BENCH_SAMPLES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

pub fn artifacts_dir() -> String {
    std::env::var("ZIPCACHE_ARTIFACTS").unwrap_or_else(|_| "artifacts".into())
}

/// Engine with the given policy over the bench model.
pub fn engine(policy: PolicyKind, saliency_ratio: f64) -> Result<Engine> {
    let mut cfg = EngineConfig::load_default(artifacts_dir(), &bench_model())?;
    cfg.policy = policy;
    cfg.quant.saliency_ratio = saliency_ratio;
    Engine::new(cfg)
}

/// Evaluate task accuracy + mean measured compression ratio.
pub fn eval_policy(engine: &mut Engine, task: Task, samples: usize, max_new: usize,
                   seed: u64) -> Result<(AccuracyReport, f64)> {
    let info = engine.runtime().model_info().clone();
    let gen = TaskGen::new(task, info.max_seq - max_new);
    let mut report = AccuracyReport::default();
    let mut ratio = 0.0;
    for i in 0..samples {
        let s = gen.sample(seed.wrapping_add(i as u64 * 7919));
        let out = engine.generate(s.prompt(), max_new)?;
        report.add(score_generation(&s, &out.tokens));
        ratio += out.compression_ratio;
    }
    Ok((report, ratio / samples.max(1) as f64))
}

/// Largest line-retrieval size fitting a window (6 tokens/line + overhead).
pub fn lines_fitting(window: usize) -> usize {
    ((window - 7) / 6).min(100)
}
