//! Table 2 reproduction: probe-token selection strategies.
//!
//! For each strategy (all / random / special / recent / random+recent) the
//! probe indices are fed to the `prefill_flash` artifact, its approximate
//! normalized saliency drives a 4/2-bit mixed-precision compression, and
//! the answer-token accuracy is measured.  Paper shape: all > random+recent
//! > recent > random ≈ special.

mod common;

use zipcache::kvcache::{CompressedKV, PrecisionClass, QuantSpec};
use zipcache::runtime::{Runtime, Tensor};
use zipcache::saliency::{select_probes, select_salient, ProbeStrategy};
use zipcache::util::bench::Table;
use zipcache::workload::tasks::is_special;
use zipcache::workload::{Task, TaskGen};

fn main() -> zipcache::Result<()> {
    let samples = common::bench_samples(20);
    let saliency_ratio = 0.4; // paper Table 2: 40% salient at 4-bit
    let (hi, lo) = (4u8, 2u8);
    let rt = Runtime::load(common::artifacts_dir(), &common::bench_model())?;
    let info = rt.model_info().clone();
    let layout = info.cache_layout();
    let (smax, pc) = (info.max_seq, info.probe_count);

    let strategies = [
        ("All tokens", ProbeStrategy::All),
        ("Random tokens", ProbeStrategy::Random),
        ("Special tokens", ProbeStrategy::Special),
        ("Recent tokens", ProbeStrategy::Recent),
        ("Random+recent", ProbeStrategy::RandomRecent),
    ];

    let gen = TaskGen::new(Task::Gsm, smax - 2);
    let mut table = Table::new(&["Probe strategy", "Acc(%)"]);

    for (name, strat) in strategies {
        let mut correct = 0usize;
        for i in 0..samples {
            let sample = gen.sample(2000 + i as u64 * 104729);
            let n = sample.prompt_len;
            let mut tokens = vec![0i32; smax];
            for (j, &t) in sample.prompt().iter().enumerate() {
                tokens[j] = t as i32;
            }
            let mut valid = vec![0f32; smax];
            valid[..n].fill(1.0);

            // Saliency source: exact (full prefill) for "All", probe
            // approximation through prefill_flash otherwise.
            let saliency: Vec<f32> = if matches!(strat, ProbeStrategy::All) {
                let out = rt.execute(&rt.entry("prefill_full"),
                                     &[Tensor::i32(tokens.clone(), &[smax]),
                                       Tensor::f32(valid.clone(), &[smax])])?;
                layer_mean(out[4].as_f32(), info.n_layers, smax)
            } else {
                let special: Vec<bool> =
                    sample.prompt().iter().map(|&t| is_special(t)).collect();
                let probes = select_probes(strat, n, 0.10, Some(&special),
                                           42 + i as u64);
                let mut pidx: Vec<i32> = probes.iter().map(|&x| x as i32).collect();
                while pidx.len() < pc {
                    pidx.push((n - 1) as i32);
                }
                pidx.truncate(pc);
                pidx.sort_unstable();
                let out = rt.execute(&rt.entry("prefill_flash"),
                                     &[Tensor::i32(tokens.clone(), &[smax]),
                                       Tensor::f32(valid.clone(), &[smax]),
                                       Tensor::i32(pidx, &[pc])])?;
                layer_mean(out[3].as_f32(), info.n_layers, smax)
            };

            // Compress with the derived saliency; we need the caches too.
            let out = rt.execute(&rt.entry("prefill_full"),
                                 &[Tensor::i32(tokens, &[smax]),
                                   Tensor::f32(valid.clone(), &[smax])])?;
            let kc = out[1].as_f32();
            let vc = out[2].as_f32();
            let mask = select_salient(&saliency, n, saliency_ratio);
            let classes: Vec<PrecisionClass> = mask
                .into_iter()
                .map(|m| PrecisionClass::Bits(if m { hi } else { lo }))
                .collect();
            let store = CompressedKV::compress(kc, vc, layout, &classes,
                                               QuantSpec::default());
            let mut ko = vec![0f32; layout.cache_len()];
            let mut vo = vec![0f32; layout.cache_len()];
            let mut va = vec![0f32; smax];
            store.materialize_into(&mut ko, &mut vo, &mut va);
            for v in va.iter_mut().skip(n - 1) {
                *v = 0.0; // last prompt token is re-fed as the decode input
            }
            let dec = rt.execute(&rt.entry("decode"), &[
                Tensor::scalar_i32(sample.prompt()[n - 1] as i32),
                Tensor::scalar_i32(n as i32 - 1),
                Tensor::f32(ko, &[layout.layers, layout.heads, smax, layout.d_head]),
                Tensor::f32(vo, &[layout.layers, layout.heads, smax, layout.d_head]),
                Tensor::f32(va, &[smax]),
            ])?;
            let pred = argmax(dec[0].as_f32()) as u16;
            correct += (pred == sample.answer[0]) as usize;
        }
        table.row(&[name.to_string(),
                    format!("{:.1}", 100.0 * correct as f64 / samples as f64)]);
        eprintln!("[table2] {name} done");
    }

    println!("\n== Table 2: probe strategy comparison (40% salient, 4/2-bit, \
              10% probes) ==");
    println!("model={} samples={samples}", common::bench_model());
    table.print();
    Ok(())
}

fn layer_mean(x: &[f32], layers: usize, s: usize) -> Vec<f32> {
    let mut out = vec![0f32; s];
    for l in 0..layers {
        for i in 0..s {
            out[i] += x[l * s + i];
        }
    }
    for o in out.iter_mut() {
        *o /= layers as f32;
    }
    out
}

fn argmax(xs: &[f32]) -> usize {
    xs.iter().enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
        .map(|(i, _)| i).unwrap_or(0)
}
