//! Table 1 reproduction: quantization-granularity comparison for the KV
//! cache — accuracy, measured ratio, reconstruction error, and the paper's
//! analytic ratios (Appendix A) side by side.
//!
//! Drives the runtime directly (prefill -> compress under each granularity
//! -> materialize -> decode the answer token) so the only variable is the
//! quantization scheme.

mod common;

use zipcache::kvcache::ratio::{self, RatioShape};
use zipcache::kvcache::{CompressedKV, PrecisionClass, QuantSpec};
use zipcache::quant::Granularity;
use zipcache::runtime::{Runtime, Tensor};
use zipcache::util::bench::Table;
use zipcache::workload::{Task, TaskGen};

fn main() -> zipcache::Result<()> {
    let samples = common::bench_samples(20);
    let bits = 4u8;
    let rt = Runtime::load(common::artifacts_dir(), &common::bench_model())?;
    let info = rt.model_info().clone();
    let layout = info.cache_layout();
    let smax = info.max_seq;

    let variants: Vec<(&str, Option<QuantSpec>)> = vec![
        ("FP16 (no quant)", None),
        ("Groupwise/Groupwise", Some(QuantSpec {
            key_gran: Granularity::Group(8), value_gran: Granularity::Group(8) })),
        ("Tokenwise/Tokenwise", Some(QuantSpec {
            key_gran: Granularity::Token, value_gran: Granularity::Token })),
        ("Channelwise/Tokenwise", Some(QuantSpec {
            key_gran: Granularity::Channel, value_gran: Granularity::Token })),
        ("Channelwise/CST (paper)", Some(QuantSpec {
            key_gran: Granularity::Channel,
            value_gran: Granularity::ChannelSeparableToken })),
    ];

    // Paper-accounting analytic ratios at the appendix's shape.
    let paper = RatioShape::paper_example();
    let analytic = [
        1.0,
        ratio::groupwise(paper, bits as u32, 32),
        ratio::tokenwise(paper, bits as u32),
        ratio::channel_token(paper, bits as u32),
        ratio::zipcache_baseline(paper, bits as u32),
    ];

    let gen = TaskGen::new(Task::Gsm, smax - 2);
    let mut table = Table::new(&[
        "K/V granularity", "PaperRatio", "MeasuredRatio", "ReconMSE", "Acc(%)",
    ]);

    for (vi, (name, spec)) in variants.iter().enumerate() {
        let mut correct = 0usize;
        let mut ratio_sum = 0f64;
        let mut mse_sum = 0f64;
        for i in 0..samples {
            let sample = gen.sample(1000 + i as u64 * 7919);
            let n = sample.prompt_len;
            // prefill (full-score path: saliency-free comparison)
            let mut tokens = vec![0i32; smax];
            for (j, &t) in sample.prompt().iter().enumerate() {
                tokens[j] = t as i32;
            }
            let mut valid = vec![0f32; smax];
            valid[..n].fill(1.0);
            let out = rt.execute(&rt.entry("prefill_full"),
                                 &[Tensor::i32(tokens, &[smax]),
                                   Tensor::f32(valid.clone(), &[smax])])?;
            let mut it = out.into_iter();
            let _logits = it.next().unwrap();
            let kc = it.next().unwrap().into_f32();
            let vc = it.next().unwrap().into_f32();

            // compress + materialize under this granularity
            let (kq, vq, valid2) = match spec {
                None => (kc.clone(), vc.clone(), valid.clone()),
                Some(spec) => {
                    let classes = vec![PrecisionClass::Bits(bits); n];
                    let store = CompressedKV::compress(&kc, &vc, layout, &classes, *spec);
                    ratio_sum += store.compression_ratio();
                    mse_sum += store.reconstruction_mse(&kc, &vc);
                    let mut ko = vec![0f32; layout.cache_len()];
                    let mut vo = vec![0f32; layout.cache_len()];
                    let mut va = vec![0f32; smax];
                    store.materialize_into(&mut ko, &mut vo, &mut va);
                    (ko, vo, va)
                }
            };

            // decode the answer token against the quantized cache
            let last_tok = sample.prompt()[n - 1];
            let dec = rt.execute(&rt.entry("decode"), &[
                Tensor::scalar_i32(last_tok as i32),
                Tensor::scalar_i32(n as i32 - 1),
                Tensor::f32(kq, &[layout.layers, layout.heads, smax, layout.d_head]),
                Tensor::f32(vq, &[layout.layers, layout.heads, smax, layout.d_head]),
                Tensor::f32(clip_pos(valid2, n - 1), &[smax]),
            ])?;
            let logits = dec[0].as_f32();
            let pred = argmax(logits) as u16;
            correct += (pred == sample.answer[0]) as usize;
        }
        let acc = 100.0 * correct as f64 / samples as f64;
        let (mratio, mmse) = if spec.is_some() {
            (format!("{:.2}x", ratio_sum / samples as f64),
             format!("{:.2e}", mse_sum / samples as f64))
        } else {
            ("1.00x".into(), "0".into())
        };
        table.row(&[name.to_string(), format!("{:.3}x", analytic[vi]),
                    mratio, mmse, format!("{acc:.1}")]);
        eprintln!("[table1] {name} done");
    }

    println!("\n== Table 1: quantization granularity comparison ({bits}-bit) ==");
    println!("model={} samples={samples}; PaperRatio = Appendix-A formula at \
              b=8, hd=l=4096, n=32", common::bench_model());
    table.print();
    Ok(())
}

/// The decode artifact attends to rows with kpos < pos; the prompt's last
/// token is re-fed as the decode input, so mask it out of the cache view.
fn clip_pos(mut valid: Vec<f32>, pos: usize) -> Vec<f32> {
    for v in valid.iter_mut().skip(pos) {
        *v = 0.0;
    }
    valid
}

fn argmax(xs: &[f32]) -> usize {
    xs.iter().enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
        .map(|(i, _)| i).unwrap_or(0)
}
