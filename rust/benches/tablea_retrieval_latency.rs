//! Table A reproduction: accuracy + prefill latency on the line-retrieval
//! task, per compression method — the accuracy/efficiency joint view.
//!
//! Paper shape: ZipCache matches FP16 accuracy at the highest ratio while
//! its prefill latency stays near the FP16 flash path; the accumulated-
//! score methods (H2O/GEAR/MiKV) pay the standard-attention prefill tax.

mod common;

use zipcache::config::PolicyKind;
use zipcache::util::bench::Table;
use zipcache::workload::Task;

fn main() -> zipcache::Result<()> {
    let samples = common::bench_samples(15);
    let saliency_ratio = 0.8; // paper Table A uses 80%

    let probe = common::engine(PolicyKind::Fp16, saliency_ratio)?;
    let window = probe.runtime().model_info().max_seq;
    drop(probe);
    let n_lines = common::lines_fitting(window - 3);

    let mut table = Table::new(&[
        "Method", "SalRatio", "MeasuredRatio", "Acc(%)", "Prefill p50 (ms)",
    ]);
    for policy in PolicyKind::ALL {
        let mut engine = common::engine(policy, saliency_ratio)?;
        let (report, ratio) = common::eval_policy(
            &mut engine, Task::Lines(n_lines), samples, 3, 400)?;
        table.row(&[
            policy.to_string(),
            format!("{:.0}%", saliency_ratio * 100.0),
            format!("{ratio:.2}x"),
            format!("{:.1}", report.accuracy_pct),
            format!("{:.1}", engine.metrics.prefill.p50_ms()),
        ]);
        eprintln!("[tablea] {policy} done");
    }

    println!("\n== Table A: {n_lines}-line retrieval — accuracy & prefill latency ==");
    println!("model={} samples={samples}", common::bench_model());
    table.print();
    println!("(policies that need full attention scores — H2O/GEAR/MiKV — run \
              the standard-attention prefill artifact; FP16/KIVI/ZipCache run \
              the flash artifact)");
    Ok(())
}
