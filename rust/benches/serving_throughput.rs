//! Serving-throughput sweep over the sharded engine pool (DESIGN.md §8):
//! requests/sec and p50/p99 request latency for shard counts x
//! compression pool widths, driven by an open-loop Poisson trace through
//! the real server stack (dispatcher -> shards -> continuous batchers).
//!
//! Runs on the sim backend, so it needs no artifacts — the numbers
//! measure the *serving machinery* (dispatch, batching, per-shard
//! engines, plane-compression pool), not transformer math.  The engine
//! histogram columns also surface the PR 2 accounting fix: the compress
//! histogram now times only the recompression block, so its p50 stays
//! well below the full decode-step p50 instead of engulfing it.
//!
//! ```sh
//! cargo bench --bench serving_throughput
//! ```

use zipcache::config::EngineConfig;
use zipcache::server::{loadgen, Server};
use zipcache::util::bench::Table;
use zipcache::workload::{RequestTrace, Task};

const REQUESTS: usize = 32;
const RATE_PER_S: f64 = 400.0;
const MAX_NEW: usize = 16;
const SEED: u64 = 42;

fn main() {
    let mut table = Table::new(&[
        "shards", "pool", "req/s", "tok/s", "p50 ms", "p99 ms", "rejected",
        "decode p50 ms", "compress p50 ms", "compress n",
    ]);
    // Per-tag outputs must be identical across every (shards, pool)
    // configuration — the determinism contract the sweep rides on.
    let mut reference: Option<Vec<(usize, Vec<u16>)>> = None;

    for shards in [1usize, 2, 4] {
        for pool in [1usize, 2] {
            let mut cfg = EngineConfig::load_default("sim", "micro")
                .expect("sim config");
            cfg.scheduler.shards = shards;
            cfg.scheduler.max_batch = 4;
            cfg.parallelism = pool;
            cfg.quant.recompress_every = 8; // several cycles per request
            cfg.seed = SEED;
            let info = zipcache::runtime::load_model_info(
                &cfg.artifacts_dir, &cfg.model,
            )
            .expect("sim model info");
            let trace = RequestTrace::poisson(
                Task::Code, info.max_seq - MAX_NEW, REQUESTS, RATE_PER_S,
                MAX_NEW, SEED,
            );

            let server = Server::start(cfg).expect("server start");
            let report = loadgen::replay(&server.handle, &trace).expect("replay");
            let snap = server.handle.metrics();
            server.shutdown().expect("shutdown");

            assert_eq!(report.completed, REQUESTS,
                       "shards={shards} pool={pool}: requests dropped");
            let outputs: Vec<(usize, Vec<u16>)> = report
                .outputs
                .iter()
                .map(|(i, o)| (*i, o.tokens.clone()))
                .collect();
            match &reference {
                None => reference = Some(outputs),
                Some(want) => assert_eq!(
                    want, &outputs,
                    "shards={shards} pool={pool} changed per-request outputs"
                ),
            }

            table.row(&[
                shards.to_string(),
                pool.to_string(),
                format!("{:.1}", report.requests_per_second()),
                format!("{:.1}", report.tokens_per_second()),
                format!("{:.1}", report.latency.p50_ms()),
                format!("{:.1}", report.latency.p99_ms()),
                report.rejected.to_string(),
                format!("{:.3}", snap.total.decode.p50_ms()),
                format!("{:.3}", snap.total.compress.p50_ms()),
                snap.total.compress.count().to_string(),
            ]);
        }
    }

    println!("\n== serving throughput: {REQUESTS} requests, Poisson \
              {RATE_PER_S}/s, max_new {MAX_NEW}, sim micro ==");
    table.print();
    println!(
        "\nper-request outputs verified bit-identical across all \
         shard/pool configurations"
    );
}
