//! Table 3 reproduction: GSM-style CoT accuracy per compression method.
//!
//! Paper shape to match: FP16 >= ZipCache > GEAR/KIVI/H2O-ish > MiKV at the
//! same mixed-precision ratio (MiKV's accumulated scores misidentify the
//! question tokens; H2O's eviction destroys them).

mod common;

use zipcache::config::PolicyKind;
use zipcache::kvcache::ratio::RatioShape;
use zipcache::util::bench::Table;
use zipcache::workload::Task;

fn main() -> zipcache::Result<()> {
    let samples = common::bench_samples(20);
    let saliency_ratio = 0.6;
    let max_new = 3;

    let mut table = Table::new(&[
        "Method", "Bits(H/L)", "SalRatio", "AnalyticRatio", "MeasuredRatio", "Acc(%)",
    ]);

    for policy in PolicyKind::ALL {
        let mut engine = common::engine(policy, saliency_ratio)?;
        let info = engine.runtime().model_info().clone();
        let shape = RatioShape { b: 1, hd: info.n_heads * info.d_head,
                                 l: info.max_seq * 3 / 4 };
        let (report, ratio) =
            common::eval_policy(&mut engine, Task::Gsm, samples, max_new, 100)?;
        let analytic = {
            use zipcache::baselines::standard_policies;
            standard_policies(saliency_ratio)
                .into_iter()
                .find(|p| p.name().eq_ignore_ascii_case(policy.as_str()))
                .map(|p| p.analytic_ratio(shape))
                .unwrap_or(1.0)
        };
        let bits = match policy {
            PolicyKind::Fp16 => "16/16",
            PolicyKind::H2o => "16/0",
            PolicyKind::Gear => "4/4",
            PolicyKind::Kivi => "16/2",
            PolicyKind::Mikv | PolicyKind::Zipcache => "4/2",
        };
        table.row(&[
            policy.to_string(),
            bits.to_string(),
            format!("{:.0}%", saliency_ratio * 100.0),
            format!("{analytic:.2}x"),
            format!("{ratio:.2}x"),
            format!("{:.1}", report.accuracy_pct),
        ]);
        eprintln!("[table3] {} done ({samples} samples)", policy);
    }

    println!("\n== Table 3: GSM-style CoT accuracy vs compression method ==");
    println!("model={} samples={samples}", common::bench_model());
    table.print();
    Ok(())
}
