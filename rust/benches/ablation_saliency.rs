//! Ablation: saliency-ratio sweep for ZipCache (and MiKV as the metric
//! control).  The paper fixes the ratio per task by hand (its stated
//! limitation); this bench maps the accuracy/compression trade-off curve,
//! which is what an auto-tuner would consume.

mod common;

use zipcache::config::PolicyKind;
use zipcache::util::bench::Table;
use zipcache::workload::Task;

fn main() -> zipcache::Result<()> {
    let samples = common::bench_samples(20);
    let mut table = Table::new(&["policy", "saliency ratio", "measured ratio", "acc %"]);
    for policy in [PolicyKind::Zipcache, PolicyKind::Mikv] {
        for ratio in [0.2, 0.4, 0.6, 0.8] {
            let mut engine = common::engine(policy, ratio)?;
            let (report, mratio) =
                common::eval_policy(&mut engine, Task::Gsm, samples, 3, 700)?;
            table.row(&[
                policy.to_string(),
                format!("{ratio:.1}"),
                format!("{mratio:.2}x"),
                format!("{:.1}", report.accuracy_pct),
            ]);
            eprintln!("[ablation] {policy} @ {ratio} done");
        }
    }
    println!("\n== Ablation: saliency ratio sweep (4/2-bit, GSM task) ==");
    println!("model={} samples={samples}", common::bench_model());
    table.print();
    println!("(lower ratio -> more 2-bit tokens -> higher compression, lower \
              accuracy; ZipCache should degrade more gracefully than MiKV \
              because its salient set is better chosen)");
    Ok(())
}
