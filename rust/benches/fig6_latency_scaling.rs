//! Figure 6 reproduction: prefill latency, decoding latency and memory vs
//! input length — MiKV (standard-attention + accumulated scores) vs
//! ZipCache (flash + probes).
//!
//! Measured: engine wall-clock per phase on this box at the model's window.
//! Analytic: A100 roofline at the paper's lengths (512..4096), which is
//! where the 37.3%/56.9%/19.8% headline reductions live.

mod common;

use zipcache::config::PolicyKind;
use zipcache::simcost::{decode_cost_per_token, prefill_cost, AttnKind, AttnShape,
                        Hardware};
use zipcache::util::bench::Table;
use zipcache::workload::{Task, TaskGen};

fn main() -> zipcache::Result<()> {
    let samples = common::bench_samples(8);

    // --- measured on this box ----------------------------------------------
    println!("\n== Figure 6 (measured, model={}) ==", common::bench_model());
    let mut mt = Table::new(&["policy", "prefill p50 ms", "decode/tok p50 ms",
                              "peak cache KB", "mem ratio"]);
    for policy in [PolicyKind::Mikv, PolicyKind::Zipcache] {
        let mut engine = common::engine(policy, 0.6)?;
        let info = engine.runtime().model_info().clone();
        let gen = TaskGen::new(Task::Gsm, info.max_seq - 4);
        for i in 0..samples {
            let s = gen.sample(600 + i as u64 * 31);
            engine.generate(s.prompt(), 4)?;
        }
        mt.row(&[
            policy.to_string(),
            format!("{:.1}", engine.metrics.prefill.p50_ms()),
            format!("{:.2}", engine.metrics.decode.p50_ms()),
            format!("{:.0}", engine.metrics.peak_cache_bytes as f64 / 1024.0),
            format!("{:.2}x", engine.metrics.memory_ratio()),
        ]);
        eprintln!("[fig6] {policy} done");
    }
    mt.print();

    // --- analytic at the paper's scale --------------------------------------
    println!("\n== Figure 6 (analytic A100, 32 layers, b=8 h=32 d=128) ==");
    let hw = Hardware::a100();
    let mut at = Table::new(&["l", "MiKV prefill ms", "Zip prefill ms", "prefill Δ",
                              "MiKV dec ms/tok", "Zip dec ms/tok", "decode Δ"]);
    for l in [512usize, 1024, 2048, 4096] {
        let s = AttnShape { batch: 8, heads: 32, seq: l, d_head: 128, elem: 2.0 };
        let layers = 32.0;
        let mikv_p = prefill_cost(hw, s, AttnKind::Standard) * layers * 1e3;
        let zip_p = prefill_cost(hw, s, AttnKind::FlashWithProbes { probe_pct: 10 })
            * layers * 1e3;
        // decode: MiKV streams fp16-ish mixed cache through the standard
        // path; ZipCache streams the 4/2 mixed cache through flash-decoding.
        let mikv_d = decode_cost_per_token(hw, s, 3.2, AttnKind::Standard) * layers * 1e3;
        let zip_d = decode_cost_per_token(hw, s, 3.2, AttnKind::Flash) * layers * 1e3;
        at.row(&[
            l.to_string(),
            format!("{mikv_p:.2}"),
            format!("{zip_p:.2}"),
            format!("-{:.1}%", 100.0 * (1.0 - zip_p / mikv_p)),
            format!("{mikv_d:.3}"),
            format!("{zip_d:.3}"),
            format!("-{:.1}%", 100.0 * (1.0 - zip_d / mikv_d)),
        ]);
    }
    at.print();
    println!("(paper at l=4096: -37.3% prefill, -56.9% decode, -19.8% memory)");
    Ok(())
}
