//! Figure 6 reproduction: prefill latency, decoding latency and memory vs
//! input length — MiKV (standard-attention + accumulated scores) vs
//! ZipCache (flash + probes).
//!
//! Measured: engine wall-clock per phase on this box at the model's window.
//! Analytic: A100 roofline at the paper's lengths (512..4096), which is
//! where the 37.3%/56.9%/19.8% headline reductions live.

mod common;

use zipcache::config::PolicyKind;
use zipcache::kvcache::{CacheLayout, CompressedKV, PrecisionClass, QuantSpec};
use zipcache::simcost::{decode_cost_per_token, prefill_cost, AttnKind, AttnShape,
                        Hardware};
use zipcache::util::bench::{black_box, Bencher, Table};
use zipcache::util::pool::WorkerPool;
use zipcache::workload::{Task, TaskGen};

fn main() -> zipcache::Result<()> {
    let samples = common::bench_samples(8);

    // --- measured on this box ----------------------------------------------
    let artifacts_ok = std::path::Path::new(&common::artifacts_dir())
        .join("manifest.json")
        .exists();
    if !artifacts_ok {
        println!("\n== Figure 6 (measured) SKIPPED: artifacts not built ==");
    } else {
        println!("\n== Figure 6 (measured, model={}) ==", common::bench_model());
        let mut mt = Table::new(&["policy", "prefill p50 ms", "decode/tok p50 ms",
                                  "peak cache KB", "mem ratio"]);
        for policy in [PolicyKind::Mikv, PolicyKind::Zipcache] {
            let mut engine = common::engine(policy, 0.6)?;
            let info = engine.runtime().model_info().clone();
            let gen = TaskGen::new(Task::Gsm, info.max_seq - 4);
            for i in 0..samples {
                let s = gen.sample(600 + i as u64 * 31);
                engine.generate(s.prompt(), 4)?;
            }
            mt.row(&[
                policy.to_string(),
                format!("{:.1}", engine.metrics.prefill.p50_ms()),
                format!("{:.2}", engine.metrics.decode.p50_ms()),
                format!("{:.0}", engine.metrics.peak_cache_bytes as f64 / 1024.0),
                format!("{:.2}x", engine.metrics.memory_ratio()),
            ]);
            eprintln!("[fig6] {policy} done");
        }
        mt.print();
    }

    // --- compression scaling with the pool width (DESIGN.md §5) ------------
    // The recompression cycle (Alg. 3) on a paper-scale cache, swept over
    // the `parallelism` knob; output is bit-identical at every width.
    println!("\n== recompression wall-clock vs parallelism (L8 H8 S1024 d64) ==");
    let lay = CacheLayout { layers: 8, heads: 8, seq: 1024, d_head: 64 };
    let n = lay.cache_len();
    let kc: Vec<f32> = (0..n).map(|i| ((i as f32) * 0.377).sin()).collect();
    let vc: Vec<f32> = (0..n).map(|i| ((i as f32) * 0.733).cos()).collect();
    let classes: Vec<PrecisionClass> = (0..lay.seq)
        .map(|i| PrecisionClass::Bits(if i % 5 == 0 { 4 } else { 2 }))
        .collect();
    let b = Bencher { warmup: 1, samples: common::bench_samples(8).max(3),
                      ..Default::default() };
    let baseline = CompressedKV::compress(&kc, &vc, lay, &classes,
                                          QuantSpec::default());
    let mut pt = Table::new(&["threads", "median ms", "mean ms", "speedup"]);
    let mut seq_median = 0.0f64;
    for threads in [1usize, 2, 4, 8] {
        let pool = WorkerPool::new(threads);
        let m = b.measure("compress", || {
            black_box(CompressedKV::compress_with_pool(
                &kc, &vc, lay, &classes, QuantSpec::default(), &pool));
        });
        let check = CompressedKV::compress_with_pool(
            &kc, &vc, lay, &classes, QuantSpec::default(), &pool);
        assert_eq!(check.content_digest(), baseline.content_digest(),
                   "threads={threads} diverged");
        if threads == 1 {
            seq_median = m.median_ms();
        }
        pt.row(&[
            threads.to_string(),
            format!("{:.2}", m.median_ms()),
            format!("{:.2}", m.mean_ms()),
            format!("{:.2}x", seq_median / m.median_ms().max(1e-9)),
        ]);
    }
    pt.print();

    // --- analytic at the paper's scale --------------------------------------
    println!("\n== Figure 6 (analytic A100, 32 layers, b=8 h=32 d=128) ==");
    let hw = Hardware::a100();
    let mut at = Table::new(&["l", "MiKV prefill ms", "Zip prefill ms", "prefill Δ",
                              "MiKV dec ms/tok", "Zip dec ms/tok", "decode Δ"]);
    for l in [512usize, 1024, 2048, 4096] {
        let s = AttnShape { batch: 8, heads: 32, seq: l, d_head: 128, elem: 2.0 };
        let layers = 32.0;
        let mikv_p = prefill_cost(hw, s, AttnKind::Standard) * layers * 1e3;
        let zip_p = prefill_cost(hw, s, AttnKind::FlashWithProbes { probe_pct: 10 })
            * layers * 1e3;
        // decode: MiKV streams fp16-ish mixed cache through the standard
        // path; ZipCache streams the 4/2 mixed cache through flash-decoding.
        let mikv_d = decode_cost_per_token(hw, s, 3.2, AttnKind::Standard) * layers * 1e3;
        let zip_d = decode_cost_per_token(hw, s, 3.2, AttnKind::Flash) * layers * 1e3;
        at.row(&[
            l.to_string(),
            format!("{mikv_p:.2}"),
            format!("{zip_p:.2}"),
            format!("-{:.1}%", 100.0 * (1.0 - zip_p / mikv_p)),
            format!("{mikv_d:.3}"),
            format!("{zip_d:.3}"),
            format!("-{:.1}%", 100.0 * (1.0 - zip_d / mikv_d)),
        ]);
    }
    at.print();
    println!("(paper at l=4096: -37.3% prefill, -56.9% decode, -19.8% memory)");
    Ok(())
}
