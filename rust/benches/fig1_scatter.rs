//! Figure 1 reproduction: the latency/accuracy/compression scatter — every
//! method as one point (accuracy vs prefill latency, sized by ratio).
//!
//! Prints the scatter as a table plus a coarse ASCII plot; the paper's
//! shape is ZipCache in the top-left (fast + accurate) at the largest
//! marker (highest ratio).

mod common;

use zipcache::config::PolicyKind;
use zipcache::util::bench::Table;
use zipcache::workload::Task;

fn main() -> zipcache::Result<()> {
    let samples = common::bench_samples(12);
    let saliency_ratio = 0.8;

    let probe = common::engine(PolicyKind::Fp16, saliency_ratio)?;
    let window = probe.runtime().model_info().max_seq;
    drop(probe);
    let n_lines = common::lines_fitting(window - 3);

    let mut points = Vec::new();
    for policy in PolicyKind::ALL {
        let mut engine = common::engine(policy, saliency_ratio)?;
        let (report, ratio) = common::eval_policy(
            &mut engine, Task::Lines(n_lines), samples, 3, 500)?;
        points.push((policy.to_string(), engine.metrics.prefill.p50_ms(),
                     report.accuracy_pct, ratio));
        eprintln!("[fig1] {policy} done");
    }

    println!("\n== Figure 1: accuracy vs prefill latency vs ratio ==");
    let mut t = Table::new(&["method", "prefill ms", "acc %", "ratio"]);
    for (name, lat, acc, ratio) in &points {
        t.row(&[name.clone(), format!("{lat:.1}"), format!("{acc:.1}"),
                format!("{ratio:.2}x")]);
    }
    t.print();

    // coarse ASCII scatter: x = latency (normalized), y = accuracy
    let lmax = points.iter().map(|p| p.1).fold(1e-9, f64::max);
    println!("\n  acc%  (x: prefill latency 0..{lmax:.0} ms)");
    for row in (0..=10).rev() {
        let lo = row as f64 * 10.0;
        let mut line = format!("{:>4} |", lo);
        let mut cells = vec![' '; 44];
        for (name, lat, acc, _) in &points {
            if *acc >= lo && *acc < lo + 10.0 {
                let x = ((lat / lmax) * 40.0) as usize;
                let c = name.chars().next().unwrap().to_ascii_uppercase();
                cells[x.min(43)] = c;
            }
        }
        line.extend(cells);
        println!("{line}");
    }
    println!("      +{}", "-".repeat(44));
    println!("       F=FP16 H=H2O G=GEAR K=KIVI M=MiKV Z=ZipCache");
    Ok(())
}
