//! Figure 5 reproduction: line-retrieval accuracy vs number of lines, per
//! compression method.
//!
//! Paper shape: quantization methods (GEAR/KIVI/MiKV/ZipCache) beat the
//! eviction method (H2O) everywhere; ZipCache tracks FP16 closest because
//! the queried line can sit anywhere in the context.

mod common;

use zipcache::config::PolicyKind;
use zipcache::util::bench::Table;
use zipcache::workload::Task;

fn main() -> zipcache::Result<()> {
    let samples = common::bench_samples(15);
    let saliency_ratio = 0.6;

    // Line counts scaled to the model window (paper sweeps 20..200 lines).
    let probe = common::engine(PolicyKind::Fp16, saliency_ratio)?;
    let window = probe.runtime().model_info().max_seq;
    drop(probe);
    let max_lines = common::lines_fitting(window - 3);
    let mut line_counts = vec![max_lines / 4, max_lines / 2, (3 * max_lines) / 4,
                               max_lines];
    line_counts.dedup();
    line_counts.retain(|&n| n >= 2);

    let mut headers: Vec<String> = vec!["Method".into()];
    headers.extend(line_counts.iter().map(|n| format!("{n} lines")));
    let hrefs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(&hrefs);

    for policy in PolicyKind::ALL {
        let mut engine = common::engine(policy, saliency_ratio)?;
        let mut row = vec![policy.to_string()];
        for &n in &line_counts {
            let (report, _) = common::eval_policy(
                &mut engine, Task::Lines(n), samples, 3, 300 + n as u64)?;
            row.push(format!("{:.1}", report.accuracy_pct));
        }
        table.row(&row);
        eprintln!("[fig5] {policy} done");
    }

    println!("\n== Figure 5: line-retrieval accuracy (%) vs number of lines ==");
    println!("model={} samples/cell={samples}", common::bench_model());
    table.print();
    Ok(())
}
