//! Table B reproduction: short-prompt ("HumanEval-like") accuracy per
//! compression method.
//!
//! Paper shape: with prompts of ~tens of tokens, KIVI's fixed fp16 recent
//! window covers a large fraction of the cache (its ratio collapses), while
//! ZipCache keeps its ratio and accuracy.

mod common;

use zipcache::config::PolicyKind;
use zipcache::util::bench::Table;
use zipcache::workload::Task;

fn main() -> zipcache::Result<()> {
    let samples = common::bench_samples(20);
    let saliency_ratio = 0.6;

    let mut table = Table::new(&["Method", "MeasuredRatio", "Acc(%)"]);
    for policy in PolicyKind::ALL {
        let mut engine = common::engine(policy, saliency_ratio)?;
        let (report, ratio) =
            common::eval_policy(&mut engine, Task::Code, samples, 3, 200)?;
        table.row(&[
            policy.to_string(),
            format!("{ratio:.2}x"),
            format!("{:.1}", report.accuracy_pct),
        ]);
        eprintln!("[tableb] {policy} done");
    }

    println!("\n== Table B: short-prompt (code) accuracy vs method ==");
    println!("model={} samples={samples} (short prompts: KIVI's fp16 window \
              dominates its ratio)", common::bench_model());
    table.print();
    Ok(())
}
