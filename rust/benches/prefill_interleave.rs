//! Chunked-prefill interleave sweep (DESIGN.md §12 and
//! EXPERIMENTS.md §Prefill): the long-prompt-burst scenario — three
//! Interactive decode streams plus one Background near-window prefill
//! — driven through
//! `ContinuousBatcher::step` at each `scheduler.prefill_chunk` setting,
//! priced on the `simcost` roofline virtual clock.
//!
//! The sweep exposes the latency trade the knob buys: tighter chunks
//! shrink the interactive token-gap p99 (the long prefill yields to
//! decode every chunk) while stretching the background request's TTFT
//! (its prompt crosses more scheduler iterations); `chunk = 0` is the
//! monolithic extreme — best TTFT, worst gap.  Per-tag outputs must stay
//! bit-identical at every point (the parity contract pinned by
//! `tests/prefill_parity.rs`).  Emits `BENCH_prefill.json` (uploaded by
//! the CI `prefill-interleave` job).
//!
//! Run: `cargo bench --bench prefill_interleave` (append `-- --smoke`
//! for the short CI variant).  Times are virtual nanoseconds (vns) from
//! the deterministic cost model, not wall time — identical on every
//! host.

use std::time::Instant;

use zipcache::config::EngineConfig;
use zipcache::coordinator::batcher::{ContinuousBatcher, QueuedRequest};
use zipcache::coordinator::{Engine, GenerationRequest, Priority};
use zipcache::simcost::{decode_cost_per_token, prefill_cost, AttnKind,
                        AttnShape, Hardware};
use zipcache::util::bench::Table;
use zipcache::workload::{Task, TaskGen};

const N_INTERACTIVE: usize = 3;
const INTERACTIVE_MAX_NEW: usize = 24;
const BG_MAX_NEW: usize = 2;
const BG_TAG: u64 = 100;
const SEED: u64 = 7;

struct RunStats {
    steps: usize,
    chunks_run: u64,
    long_len: usize,
    gap_p99_vns: f64,
    ttft_vns: f64,
    vt_total_vns: f64,
    wall_ms: f64,
    outputs: Vec<(u64, Vec<u16>)>,
}

fn p99(mut xs: Vec<f64>) -> f64 {
    assert!(!xs.is_empty());
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    xs[((xs.len() - 1) as f64 * 0.99).round() as usize]
}

/// One sweep point: warm three Interactive streams up, submit the
/// Background near-window prompt, and run to idle on the virtual clock.
fn run_cfg(chunk: usize) -> RunStats {
    let mut cfg = EngineConfig::load_default("sim", "micro").expect("sim config");
    cfg.scheduler.max_batch = 8;
    cfg.scheduler.prefill_chunk = chunk;
    cfg.parallelism = 1;
    cfg.seed = SEED;
    let mut engine = Engine::new(cfg).expect("engine");
    let lay = engine.layout();
    let shape = AttnShape {
        batch: 1,
        heads: lay.heads,
        seq: lay.seq,
        d_head: lay.d_head,
        elem: 2.0,
    };
    let hw = Hardware::a100();
    let per_tok_prefill =
        prefill_cost(hw, shape, AttnKind::FlashWithProbes { probe_pct: 10 })
            / lay.seq as f64;
    let decode = decode_cost_per_token(hw, shape, 2.8, AttnKind::Flash);

    let mut b = ContinuousBatcher::new(8, 16);
    let short = TaskGen::new(Task::Lines(3), lay.seq - INTERACTIVE_MAX_NEW);
    for tag in 0..N_INTERACTIVE as u64 {
        b.submit(QueuedRequest {
            request: GenerationRequest::new(
                short.sample(SEED + tag).prompt().to_vec(),
                INTERACTIVE_MAX_NEW,
            )
            .priority(Priority::Interactive),
            tag,
        })
        .expect("queue sized to the scenario");
    }

    // Virtual clock (same pricing as tests/serving_pool.rs): every
    // iteration costs its decode-artifact executions plus the prompt
    // tokens its prefill chunks covered; tokens emitted in an iteration
    // are stamped with the end-of-step time.
    let t0 = Instant::now();
    let mut vt = 0.0f64;
    let mut steps = 0usize;
    let mut stamps: Vec<Vec<f64>> = vec![Vec::new(); N_INTERACTIVE];
    let mut ttft: Option<f64> = None;
    let mut step = |b: &mut ContinuousBatcher, engine: &mut Engine,
                    vt: &mut f64, stamps: &mut Vec<Vec<f64>>,
                    ttft: &mut Option<f64>, vt_submit: f64| {
        let r = b.step(engine).expect("step");
        *vt += r.decoded as f64 * decode
            + r.prefill_tokens as f64 * per_tok_prefill;
        for (tag, _tok) in b.drain_emitted() {
            if (tag as usize) < N_INTERACTIVE {
                stamps[tag as usize].push(*vt);
            } else if tag == BG_TAG && ttft.is_none() {
                *ttft = Some(*vt - vt_submit);
            }
        }
    };

    // Warm up until every Interactive session is streaming tokens.
    let mut guard = 0;
    while stamps.iter().any(|s| s.is_empty()) {
        step(&mut b, &mut engine, &mut vt, &mut stamps, &mut ttft, 0.0);
        steps += 1;
        guard += 1;
        assert!(guard < 256, "interactive sessions never started decoding");
    }

    // The burst: one Background near-window prompt, sized like
    // `loadgen::long_prompt_burst_trace` (the sim-window analogue of an
    // 8k-token production prefill).
    let long_lines = (lay.seq.saturating_sub(BG_MAX_NEW + 5) / 6).clamp(1, 100);
    let long: Vec<u16> = TaskGen::new(Task::Lines(long_lines), lay.seq - BG_MAX_NEW)
        .sample(SEED ^ 0xB00)
        .prompt()
        .to_vec();
    let long_len = long.len();
    let vt_submit = vt;
    b.submit(QueuedRequest {
        request: GenerationRequest::new(long, BG_MAX_NEW)
            .priority(Priority::Background),
        tag: BG_TAG,
    })
    .expect("background submit");
    while !b.idle() {
        step(&mut b, &mut engine, &mut vt, &mut stamps, &mut ttft, vt_submit);
        steps += 1;
    }
    let wall = t0.elapsed();
    let outs = b.take_outcomes();
    assert_eq!(outs.len(), N_INTERACTIVE + 1, "requests dropped");
    assert!(outs.iter().all(|o| o.finish.is_natural()));
    let mut outputs: Vec<(u64, Vec<u16>)> =
        outs.into_iter().map(|o| (o.tag, o.tokens)).collect();
    outputs.sort_by_key(|(tag, _)| *tag);

    let gaps: Vec<f64> = stamps
        .iter()
        .flat_map(|s| s.windows(2).map(|w| w[1] - w[0]))
        .collect();
    RunStats {
        steps,
        chunks_run: engine.metrics.prefill_chunks,
        long_len,
        gap_p99_vns: p99(gaps) * 1e9,
        ttft_vns: ttft.expect("background request emitted no token") * 1e9,
        vt_total_vns: vt * 1e9,
        wall_ms: wall.as_secs_f64() * 1000.0,
        outputs,
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let chunks: &[usize] = if smoke { &[0, 4] } else { &[0, 1, 2, 4, 8, 16] };

    let mut table = Table::new(&[
        "chunk", "steps", "chunks run", "gap p99 vns", "bg TTFT vns",
        "vt total vns", "wall ms",
    ]);
    let mut rows = Vec::new();
    let mut mono: Option<RunStats> = None;

    for &chunk in chunks {
        let st = run_cfg(chunk);
        match &mono {
            None => {
                assert_eq!(chunk, 0, "sweep must lead with the monolithic point");
                assert_eq!(st.chunks_run, 0, "chunk=0 ran chunked entries");
            }
            Some(base) => {
                // The parity contract rides along: chunking is invisible
                // to generation.
                assert_eq!(
                    base.outputs, st.outputs,
                    "chunk={chunk} changed per-tag outputs vs monolithic"
                );
                // And the trade is directional on the deterministic
                // clock: chunking tightens the interactive gap and pays
                // for it in background TTFT.
                assert!(
                    st.gap_p99_vns < base.gap_p99_vns,
                    "chunk={chunk}: gap p99 {:.3} vns not below monolithic {:.3}",
                    st.gap_p99_vns, base.gap_p99_vns
                );
                assert!(
                    st.ttft_vns >= base.ttft_vns,
                    "chunk={chunk}: TTFT {:.3} vns below monolithic {:.3}",
                    st.ttft_vns, base.ttft_vns
                );
            }
        }
        table.row(&[
            chunk.to_string(),
            st.steps.to_string(),
            st.chunks_run.to_string(),
            format!("{:.3}", st.gap_p99_vns),
            format!("{:.3}", st.ttft_vns),
            format!("{:.3}", st.vt_total_vns),
            format!("{:.2}", st.wall_ms),
        ]);
        rows.push(format!(
            "    {{\"prefill_chunk\": {chunk}, \"steps\": {}, \
             \"prefill_chunks_run\": {}, \"long_prompt_tokens\": {}, \
             \"interactive_gap_p99_vns\": {:.3}, \"bg_ttft_vns\": {:.3}, \
             \"vt_total_vns\": {:.3}, \"wall_ms\": {:.2}}}",
            st.steps, st.chunks_run, st.long_len, st.gap_p99_vns,
            st.ttft_vns, st.vt_total_vns, st.wall_ms,
        ));
        if mono.is_none() {
            mono = Some(st);
        }
    }

    let json = format!(
        "{{\n  \"bench\": \"prefill_interleave\",\n  \"model\": \"micro\",\n  \
         \"smoke\": {smoke},\n  \"n_interactive\": {N_INTERACTIVE},\n  \
         \"interactive_max_new\": {INTERACTIVE_MAX_NEW},\n  \
         \"rows\": [\n{}\n  ]\n}}\n",
        rows.join(",\n")
    );
    std::fs::write("BENCH_prefill.json", &json).unwrap();

    println!("== chunked prefill interleave (sim backend, micro, virtual clock) ==");
    table.print();
    print!("{json}");
    println!(
        "\nOK: outputs bit-identical across chunk sizes; tighter chunks \
         shrink interactive gap p99 and stretch background TTFT"
    );
}
