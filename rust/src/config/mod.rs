//! Typed configuration system: engine/model/quantization/scheduler knobs,
//! loadable from flat `key = value` config files (see [`crate::util::kvconf`])
//! and overridable from the CLI.

use std::path::{Path, PathBuf};

use anyhow::ensure;

use crate::quant::KernelChoice;
use crate::util::kvconf::KvConf;
use crate::Result;

/// Which compression policy the engine runs.  `Hash` because the kind
/// is a coordinate of the prefix store's `SegmentKey` (DESIGN.md §16).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PolicyKind {
    Fp16,
    H2o,
    Gear,
    Kivi,
    Mikv,
    Zipcache,
}

impl PolicyKind {
    pub const ALL: [PolicyKind; 6] = [
        PolicyKind::Fp16, PolicyKind::H2o, PolicyKind::Gear,
        PolicyKind::Kivi, PolicyKind::Mikv, PolicyKind::Zipcache,
    ];

    pub fn as_str(&self) -> &'static str {
        match self {
            PolicyKind::Fp16 => "fp16",
            PolicyKind::H2o => "h2o",
            PolicyKind::Gear => "gear",
            PolicyKind::Kivi => "kivi",
            PolicyKind::Mikv => "mikv",
            PolicyKind::Zipcache => "zipcache",
        }
    }
}

impl std::fmt::Display for PolicyKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

impl std::str::FromStr for PolicyKind {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "fp16" => PolicyKind::Fp16,
            "h2o" => PolicyKind::H2o,
            "gear" => PolicyKind::Gear,
            "kivi" => PolicyKind::Kivi,
            "mikv" => PolicyKind::Mikv,
            "zipcache" | "zip" => PolicyKind::Zipcache,
            other => anyhow::bail!("unknown policy '{other}'"),
        })
    }
}

/// Quantization policy knobs (paper §5.1 defaults).
#[derive(Debug, Clone)]
pub struct QuantConfig {
    /// Fraction of tokens treated as salient ("Saliency Ratio").
    pub saliency_ratio: f64,
    /// Bits for salient tokens (H).
    pub bits_high: u8,
    /// Bits for regular tokens (L).
    pub bits_low: u8,
    /// Total probe fraction for the fast saliency path.
    pub probe_ratio: f64,
    /// Recompress the cache every N generated tokens (Alg. 3).
    pub recompress_every: usize,
    /// Quant/dequant kernel selection (DESIGN.md §15): `auto` picks the
    /// widest SIMD implementation the CPU supports, `scalar` pins the
    /// portable path, `simd` requires a SIMD kernel (startup error
    /// otherwise).  `ZIPCACHE_FORCE_SCALAR=1` overrides all of them.
    pub kernel: KernelChoice,
}

impl Default for QuantConfig {
    fn default() -> Self {
        QuantConfig {
            saliency_ratio: 0.6,
            bits_high: 4,
            bits_low: 2,
            probe_ratio: 0.10,
            recompress_every: 100,
            kernel: KernelChoice::Auto,
        }
    }
}

/// Scheduler/batcher knobs.
#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    /// Max sequences decoded concurrently *per shard* (continuous
    /// batching width).
    pub max_batch: usize,
    /// Max requests waiting for a decode slot across the whole server
    /// (the single admission boundary — DESIGN.md §8); in-flight capacity
    /// on top of this is `shards * max_batch`.
    pub queue_depth: usize,
    /// Engine shards: serving threads that each own an engine, a
    /// compression worker pool, and a continuous batcher (DESIGN.md §8).
    /// `0` = one shard per available core.
    pub shards: usize,
    /// Prefill chunk size in prompt tokens (DESIGN.md §12): the batcher
    /// interleaves chunks of this size with decode iterations instead of
    /// running the whole prompt at admission.  `0` = monolithic prefill
    /// (today's behaviour bit-for-bit; also the forced mode on backends
    /// without the chunked entries).
    pub prefill_chunk: usize,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig { max_batch: 8, queue_depth: 256, shards: 1,
                          prefill_chunk: 0 }
    }
}

/// Memory-residency knobs (DESIGN.md §10).  The all-zero default means
/// "one slot per decode slot, no byte budget" — today's unbounded
/// behaviour.
#[derive(Debug, Clone, Default)]
pub struct MemoryConfig {
    /// Dense materialization slots per shard: sessions beyond this stay
    /// compressed-resident and are parked/unparked by the batcher's park
    /// policy.  `0` = one slot per decode slot (`max_batch`, the
    /// bit-identical unbounded behaviour); otherwise must be
    /// `<= max_batch`.
    pub slots: usize,
    /// Per-shard byte budget for worst-case compressed session
    /// footprints: admission rejects a request when no shard can reserve
    /// its worst-case bytes (exact CAS boundary, like `queue_depth`).
    /// `0` = unlimited.
    pub budget_bytes: usize,
}

/// Shared-prefix segment store knobs (DESIGN.md §16).  Off by default:
/// the cold path is bit-for-bit the pre-store behaviour, and the warm
/// path is pinned bit-identical to it anyway (`prefix_parity.rs`).
#[derive(Debug, Clone, Default)]
pub struct PrefixConfig {
    /// Enable the per-shard content-addressed prefix store: prompts
    /// sharing an interned prefix skip prefill for the covered span.
    /// Only effective on backends with the chunked-prefill/saliency
    /// catch-up entries (the sim backend); ignored elsewhere.
    pub enable: bool,
    /// Byte cap on live interned segment payload per shard (LRU
    /// eviction above it).  `0` = unlimited; must be non-zero and below
    /// `memory.budget_bytes` when both the store and the byte budget
    /// are on, because the store is budgeted *inside* the shard budget.
    pub max_bytes: usize,
}

/// Fault-injection and shard-supervision knobs (DESIGN.md §14).  The
/// default (empty plan) is the fault-free runtime bit-for-bit; the
/// supervisor knobs always govern the sharded server's restart policy.
#[derive(Debug, Clone)]
pub struct FaultConfig {
    /// Fault plan (grammar in DESIGN.md §14 / [`crate::runtime::fault`]):
    /// `;`-separated `shard<K>:<site>:<trigger>:<kind>` clauses, e.g.
    /// `shard0:decode:3:panic;shard1:execute:p0.01:error`.  Empty = off.
    pub plan: String,
    /// Seed for probabilistic triggers (chaos runs are replayable).
    pub seed: u64,
    /// Supervisor poll cadence in ms: how often heartbeats are scanned
    /// for stalled shards between failure events.
    pub poll_ms: u64,
    /// Consecutive unchanged-heartbeat polls (while the shard holds
    /// work) before it is declared stalled and severed.  The default
    /// (100 polls x 10 ms = ~1 s) stays far above a legitimately slow
    /// engine step; chaos tests shrink it.
    pub stall_ticks: u64,
    /// Restart backoff: base delay in ms, doubled per consecutive
    /// restart of the same shard, capped at `backoff_cap_ms`.
    pub backoff_base_ms: u64,
    pub backoff_cap_ms: u64,
    /// Stop restarting a shard after this many attempts (0 = never stop).
    pub max_restarts: u64,
}

impl Default for FaultConfig {
    fn default() -> Self {
        FaultConfig {
            plan: String::new(),
            seed: 0,
            poll_ms: 10,
            stall_ticks: 100,
            backoff_base_ms: 10,
            backoff_cap_ms: 1000,
            max_restarts: 0,
        }
    }
}

/// Full engine configuration.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Directory containing `manifest.json` + `*.hlo.txt`.
    pub artifacts_dir: PathBuf,
    /// Model config name ("micro", "tiny", ...) — must exist in the manifest.
    pub model: String,
    pub policy: PolicyKind,
    pub quant: QuantConfig,
    pub scheduler: SchedulerConfig,
    pub memory: MemoryConfig,
    /// Worker threads for plane-level compression (DESIGN.md §5):
    /// `0` = one per available core, `1` = sequential.  Output is
    /// bit-identical at any width, so this is a pure latency knob.
    pub parallelism: usize,
    /// Request seed base (determinism).
    pub seed: u64,
    /// Fault injection + shard supervision (DESIGN.md §14).
    pub faults: FaultConfig,
    /// Shared-prefix segment store (DESIGN.md §16).
    pub prefix: PrefixConfig,
}

impl EngineConfig {
    /// A ready-to-run ZipCache config over the given artifacts/model.
    pub fn load_default(artifacts_dir: impl Into<PathBuf>, model: &str) -> Result<Self> {
        let cfg = EngineConfig {
            artifacts_dir: artifacts_dir.into(),
            model: model.to_string(),
            policy: PolicyKind::Zipcache,
            quant: QuantConfig::default(),
            scheduler: SchedulerConfig::default(),
            memory: MemoryConfig::default(),
            parallelism: 0,
            seed: 0,
            faults: FaultConfig::default(),
            prefix: PrefixConfig::default(),
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Parse from a `key = value` config file (example in `configs/`).
    pub fn from_file(path: impl AsRef<Path>) -> Result<Self> {
        let c = KvConf::load(path)?;
        let cfg = EngineConfig {
            artifacts_dir: PathBuf::from(c.get_or("artifacts_dir", "artifacts")),
            model: c.get_or("model", "tiny"),
            policy: c.get_or("policy", "zipcache").parse()?,
            quant: QuantConfig {
                saliency_ratio: c.get_f64("quant.saliency_ratio", 0.6)?,
                bits_high: c.get_u8("quant.bits_high", 4)?,
                bits_low: c.get_u8("quant.bits_low", 2)?,
                probe_ratio: c.get_f64("quant.probe_ratio", 0.10)?,
                recompress_every: c.get_usize("quant.recompress_every", 100)?,
                kernel: c.get_or("quant.kernel", "auto").parse()?,
            },
            scheduler: SchedulerConfig {
                max_batch: c.get_usize("scheduler.max_batch", 8)?,
                queue_depth: c.get_usize("scheduler.queue_depth", 256)?,
                shards: c.get_usize("scheduler.shards", 1)?,
                prefill_chunk: c.get_usize("scheduler.prefill_chunk", 0)?,
            },
            memory: MemoryConfig {
                slots: c.get_usize("memory.slots", 0)?,
                budget_bytes: c.get_usize("memory.budget_bytes", 0)?,
            },
            parallelism: c.get_usize("parallelism", 0)?,
            seed: c.get_u64("seed", 0)?,
            faults: FaultConfig {
                plan: c.get_or("faults.plan", ""),
                seed: c.get_u64("faults.seed", 0)?,
                poll_ms: c.get_u64("faults.poll_ms", 10)?,
                stall_ticks: c.get_u64("faults.stall_ticks", 100)?,
                backoff_base_ms: c.get_u64("faults.backoff_base_ms", 10)?,
                backoff_cap_ms: c.get_u64("faults.backoff_cap_ms", 1000)?,
                max_restarts: c.get_u64("faults.max_restarts", 0)?,
            },
            prefix: PrefixConfig {
                enable: c.get_bool("prefix.enable", false)?,
                max_bytes: c.get_usize("prefix.max_bytes", 0)?,
            },
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Sanity-check ranges.
    pub fn validate(&self) -> Result<()> {
        let q = &self.quant;
        ensure!((0.0..=1.0).contains(&q.saliency_ratio),
                "saliency_ratio must be in [0,1]");
        ensure!((0.0..=1.0).contains(&q.probe_ratio),
                "probe_ratio must be in [0,1]");
        ensure!(matches!(q.bits_high, 1 | 2 | 4 | 8), "bits_high in {{1,2,4,8}}");
        ensure!(matches!(q.bits_low, 1 | 2 | 4 | 8), "bits_low in {{1,2,4,8}}");
        ensure!(q.bits_high >= q.bits_low, "bits_high >= bits_low");
        ensure!(q.recompress_every > 0, "recompress_every > 0");
        ensure!(self.scheduler.max_batch > 0, "max_batch > 0");
        ensure!(
            self.memory.slots <= self.scheduler.max_batch,
            "memory.slots ({}) must be <= scheduler.max_batch ({}) — extra \
             slots beyond the decode width can never be used",
            self.memory.slots,
            self.scheduler.max_batch
        );
        ensure!(!self.model.is_empty(), "model name required");
        let f = &self.faults;
        if !f.plan.is_empty() {
            // Malformed plans die here, not mid-run inside a shard.
            crate::runtime::fault::FaultPlan::parse(&f.plan)?;
        }
        ensure!(f.poll_ms >= 1, "faults.poll_ms >= 1");
        ensure!(f.stall_ticks >= 1, "faults.stall_ticks >= 1");
        ensure!(
            f.backoff_base_ms <= f.backoff_cap_ms,
            "faults.backoff_base_ms must be <= faults.backoff_cap_ms"
        );
        if self.prefix.enable && self.memory.budget_bytes > 0 {
            // The store lives inside the shard budget: the dispatcher
            // subtracts live `shared_bytes` from the admittable budget,
            // so an uncapped (or budget-sized) store could starve
            // admission entirely (DESIGN.md §16).
            ensure!(
                self.prefix.max_bytes > 0
                    && self.prefix.max_bytes < self.memory.budget_bytes,
                "prefix.max_bytes must be in (0, memory.budget_bytes) when \
                 both the prefix store and the byte budget are enabled \
                 (the store is budgeted inside memory.budget_bytes)"
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_valid() {
        let c = EngineConfig::load_default("artifacts", "micro").unwrap();
        assert_eq!(c.policy, PolicyKind::Zipcache);
        assert_eq!(c.quant.bits_high, 4);
    }

    #[test]
    fn invalid_ratio_rejected() {
        let mut c = EngineConfig::load_default("artifacts", "micro").unwrap();
        c.quant.saliency_ratio = 1.5;
        assert!(c.validate().is_err());
    }

    #[test]
    fn bits_ordering_enforced() {
        let mut c = EngineConfig::load_default("artifacts", "micro").unwrap();
        c.quant.bits_high = 2;
        c.quant.bits_low = 4;
        assert!(c.validate().is_err());
    }

    #[test]
    fn file_parsing() {
        let text = r#"
model = "tiny"
policy = "mikv"
seed = 9
[quant]
saliency_ratio = 0.7
[scheduler]
max_batch = 4
"#;
        let path = std::env::temp_dir().join("zipcache_cfg_test.conf");
        std::fs::write(&path, text).unwrap();
        let c = EngineConfig::from_file(&path).unwrap();
        assert_eq!(c.model, "tiny");
        assert_eq!(c.policy, PolicyKind::Mikv);
        assert_eq!(c.quant.saliency_ratio, 0.7);
        assert_eq!(c.quant.bits_low, 2); // default preserved
        assert_eq!(c.scheduler.max_batch, 4);
        assert_eq!(c.seed, 9);
        assert_eq!(c.parallelism, 0); // default preserved
    }

    #[test]
    fn parallelism_from_file() {
        let text = "model = \"tiny\"\nparallelism = 4\n";
        let path = std::env::temp_dir().join("zipcache_cfg_par_test.conf");
        std::fs::write(&path, text).unwrap();
        let c = EngineConfig::from_file(&path).unwrap();
        assert_eq!(c.parallelism, 4);
    }

    #[test]
    fn shards_from_file_and_default() {
        let text = "model = \"tiny\"\n[scheduler]\nshards = 4\n";
        let path = std::env::temp_dir().join("zipcache_cfg_shards_test.conf");
        std::fs::write(&path, text).unwrap();
        let c = EngineConfig::from_file(&path).unwrap();
        assert_eq!(c.scheduler.shards, 4);
        let d = EngineConfig::load_default("sim", "micro").unwrap();
        assert_eq!(d.scheduler.shards, 1);
    }

    #[test]
    fn prefill_chunk_from_file_and_default() {
        let text = "model = \"tiny\"\n[scheduler]\nprefill_chunk = 16\n";
        let path = std::env::temp_dir().join("zipcache_cfg_chunk_test.conf");
        std::fs::write(&path, text).unwrap();
        let c = EngineConfig::from_file(&path).unwrap();
        assert_eq!(c.scheduler.prefill_chunk, 16);
        let d = EngineConfig::load_default("sim", "micro").unwrap();
        assert_eq!(d.scheduler.prefill_chunk, 0); // 0 = monolithic
    }

    #[test]
    fn memory_from_file_and_default() {
        let text = "model = \"tiny\"\n[scheduler]\nmax_batch = 4\n\
                    [memory]\nslots = 2\nbudget_bytes = 65536\n";
        let path = std::env::temp_dir().join("zipcache_cfg_mem_test.conf");
        std::fs::write(&path, text).unwrap();
        let c = EngineConfig::from_file(&path).unwrap();
        assert_eq!(c.memory.slots, 2);
        assert_eq!(c.memory.budget_bytes, 65536);
        let d = EngineConfig::load_default("sim", "micro").unwrap();
        assert_eq!(d.memory.slots, 0); // 0 = one slot per decode slot
        assert_eq!(d.memory.budget_bytes, 0); // 0 = unlimited
    }

    #[test]
    fn slots_beyond_max_batch_rejected() {
        let mut c = EngineConfig::load_default("sim", "micro").unwrap();
        c.scheduler.max_batch = 4;
        c.memory.slots = 4;
        assert!(c.validate().is_ok());
        c.memory.slots = 5;
        assert!(c.validate().is_err());
    }

    #[test]
    fn faults_from_file_and_default() {
        let text = "model = \"tiny\"\n[faults]\nplan = \"shard0:decode:2:panic\"\n\
                    seed = 11\npoll_ms = 2\nstall_ticks = 3\n\
                    backoff_base_ms = 0\nbackoff_cap_ms = 50\nmax_restarts = 4\n";
        let path = std::env::temp_dir().join("zipcache_cfg_faults_test.conf");
        std::fs::write(&path, text).unwrap();
        let c = EngineConfig::from_file(&path).unwrap();
        assert_eq!(c.faults.plan, "shard0:decode:2:panic");
        assert_eq!(c.faults.seed, 11);
        assert_eq!(c.faults.poll_ms, 2);
        assert_eq!(c.faults.stall_ticks, 3);
        assert_eq!(c.faults.backoff_base_ms, 0);
        assert_eq!(c.faults.backoff_cap_ms, 50);
        assert_eq!(c.faults.max_restarts, 4);
        let d = EngineConfig::load_default("sim", "micro").unwrap();
        assert!(d.faults.plan.is_empty()); // default: fault-free
        assert_eq!(d.faults.stall_ticks, 100);
    }

    #[test]
    fn malformed_fault_plan_rejected_at_validate() {
        let mut c = EngineConfig::load_default("sim", "micro").unwrap();
        c.faults.plan = "shard0:decode:2:panic".to_string();
        assert!(c.validate().is_ok());
        c.faults.plan = "shard0:warp:2:panic".to_string();
        assert!(c.validate().is_err());
        c.faults = FaultConfig::default();
        c.faults.backoff_base_ms = 100;
        c.faults.backoff_cap_ms = 50;
        assert!(c.validate().is_err());
    }

    #[test]
    fn prefix_from_file_and_default() {
        let text = "model = \"tiny\"\n[prefix]\nenable = true\n\
                    max_bytes = 4096\n";
        let path = std::env::temp_dir().join("zipcache_cfg_prefix_test.conf");
        std::fs::write(&path, text).unwrap();
        let c = EngineConfig::from_file(&path).unwrap();
        assert!(c.prefix.enable);
        assert_eq!(c.prefix.max_bytes, 4096);
        let d = EngineConfig::load_default("sim", "micro").unwrap();
        assert!(!d.prefix.enable); // default: off, pre-store behaviour
        assert_eq!(d.prefix.max_bytes, 0);
    }

    #[test]
    fn prefix_store_must_fit_inside_byte_budget() {
        let mut c = EngineConfig::load_default("sim", "micro").unwrap();
        c.prefix.enable = true;
        assert!(c.validate().is_ok(), "no byte budget: any store cap is fine");
        c.memory.budget_bytes = 100_000;
        assert!(c.validate().is_err(), "uncapped store inside a budget");
        c.prefix.max_bytes = 100_000;
        assert!(c.validate().is_err(), "store as large as the budget");
        c.prefix.max_bytes = 50_000;
        assert!(c.validate().is_ok());
        c.prefix.enable = false;
        c.prefix.max_bytes = 0;
        assert!(c.validate().is_ok(), "disabled store is never checked");
    }

    #[test]
    fn quant_kernel_from_file_and_default() {
        let text = "model = \"tiny\"\n[quant]\nkernel = \"scalar\"\n";
        let path = std::env::temp_dir().join("zipcache_cfg_kernel_test.conf");
        std::fs::write(&path, text).unwrap();
        let c = EngineConfig::from_file(&path).unwrap();
        assert_eq!(c.quant.kernel, KernelChoice::Scalar);
        let d = EngineConfig::load_default("sim", "micro").unwrap();
        assert_eq!(d.quant.kernel, KernelChoice::Auto);
        let bad = "model = \"tiny\"\n[quant]\nkernel = \"avx512\"\n";
        std::fs::write(&path, bad).unwrap();
        assert!(EngineConfig::from_file(&path).is_err());
    }

    #[test]
    fn policy_parsing() {
        assert_eq!("zipcache".parse::<PolicyKind>().unwrap(), PolicyKind::Zipcache);
        assert_eq!("H2O".parse::<PolicyKind>().unwrap(), PolicyKind::H2o);
        assert!("bogus".parse::<PolicyKind>().is_err());
        assert_eq!(PolicyKind::Gear.to_string(), "gear");
    }
}
