//! Task generators — the Rust mirror of `python/compile/data.py`.
//!
//! Token map (must stay in lockstep with the Python side; vocab = 256):
//! `0 PAD | 1 BOS | 2 SEP | 3 QUERY | 4 EOS | 5 NL | 6 LINE`,
//! keys 16..79, values 80..143, filler 144..207, digits 208..217.

use super::rng::SplitMix64;

pub const PAD: u16 = 0;
pub const BOS: u16 = 1;
pub const SEP: u16 = 2;
pub const QUERY: u16 = 3;
pub const EOS: u16 = 4;
pub const NL: u16 = 5;
pub const LINE: u16 = 6;
pub const KEY0: u16 = 16;
pub const NKEY: u16 = 64;
pub const VAL0: u16 = 80;
pub const NVAL: u16 = 64;
pub const FIL0: u16 = 144;
pub const NFIL: u16 = 64;
pub const DIG0: u16 = 208;

/// Vocabulary size shared with the model configs.
pub fn vocab() -> usize {
    256
}

/// Is this token "special" (used by the `Special` probe strategy)?
pub fn is_special(tok: u16) -> bool {
    tok < 16 || (DIG0..DIG0 + 10).contains(&tok)
}

/// One generated sample: prompt + expected answer + the queried span.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Sample {
    /// Full sequence including the answer (training layout).
    pub tokens: Vec<u16>,
    /// `tokens[..prompt_len]` is the serving-time prompt.
    pub prompt_len: usize,
    /// Expected continuation: `[value_token, EOS]`.
    pub answer: Vec<u16>,
    /// `[start, end)` of the queried key/value pair inside the prompt —
    /// the ground-truth salient span.
    pub salient_span: (usize, usize),
}

impl Sample {
    pub fn prompt(&self) -> &[u16] {
        &self.tokens[..self.prompt_len]
    }
}

/// The paper's three workloads (DESIGN.md §2 mapping).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Task {
    /// GSM8k-like: long CoT body, question at the very end (Fig. 3(b)).
    Gsm,
    /// LongEval line retrieval with `n` lines (Fig. 5 / Table A).
    Lines(usize),
    /// HumanEval-like short-prompt regime (Table B).
    Code,
}

/// Deterministic generator for a (task, max_seq) pair.
#[derive(Debug, Clone, Copy)]
pub struct TaskGen {
    pub task: Task,
    pub max_seq: usize,
}

impl TaskGen {
    pub fn new(task: Task, max_seq: usize) -> Self {
        TaskGen { task, max_seq }
    }

    /// Generate the sample for `seed` — identical to the Python
    /// `gen_task` / `gen_line_retrieval` for the same inputs.
    pub fn sample(&self, seed: u64) -> Sample {
        match self.task {
            Task::Gsm => {
                let cap_pairs = ((self.max_seq.saturating_sub(8)) / 8).clamp(3, 16);
                let mut r1 = SplitMix64::new(seed ^ 0xA5);
                let n_pairs = 3 + r1.below((cap_pairs - 2) as u64) as usize;
                let budget = (self.max_seq as i64 - 6 - 4 * n_pairs as i64) / 2;
                let budget = budget.max(0) as usize;
                let mut r2 = SplitMix64::new(seed ^ 0x5A);
                let want = 1 + r2.below(budget.max(1) as u64) as usize;
                let n_filler = want.min(budget);
                gen_recall(seed, n_pairs, n_filler)
            }
            Task::Code => {
                let mut r = SplitMix64::new(seed ^ 0xC0);
                let n_pairs = 4 + r.below(5) as usize;
                gen_recall(seed, n_pairs, 2)
            }
            Task::Lines(n) => gen_line_retrieval(seed, n),
        }
    }

    /// Generate `n` samples with consecutive derived seeds.
    pub fn batch(&self, seed0: u64, n: usize) -> Vec<Sample> {
        (0..n).map(|i| self.sample(seed0.wrapping_add(i as u64 * 0x9E37))).collect()
    }
}

/// Core associative recall (Python `gen_recall`).
pub fn gen_recall(seed: u64, n_pairs: usize, n_filler: usize) -> Sample {
    let mut rng = SplitMix64::new(seed);
    let mut keys: Vec<u16> = (0..NKEY).collect();
    rng.shuffle(&mut keys);
    keys.truncate(n_pairs);
    let vals: Vec<u16> = (0..n_pairs).map(|_| rng.below(NVAL as u64) as u16).collect();
    let qi = rng.below(n_pairs as u64) as usize;

    let mut body: Vec<Vec<u16>> = keys
        .iter()
        .zip(&vals)
        .map(|(&k, &v)| vec![KEY0 + k, SEP, VAL0 + v, NL])
        .collect();
    for _ in 0..n_filler {
        body.push(vec![FIL0 + rng.below(NFIL as u64) as u16, NL]);
    }
    rng.shuffle(&mut body);

    let mut toks: Vec<u16> = vec![BOS];
    let mut sal = (0usize, 0usize);
    let qkey = KEY0 + keys[qi];
    for chunk in &body {
        if chunk[0] == qkey {
            sal = (toks.len(), toks.len() + chunk.len());
        }
        toks.extend_from_slice(chunk);
    }
    toks.extend_from_slice(&[QUERY, qkey, SEP]);
    let prompt_len = toks.len();
    let answer = vec![VAL0 + vals[qi], EOS];
    toks.extend_from_slice(&answer);
    Sample { tokens: toks, prompt_len, answer, salient_span: sal }
}

/// LongEval-style line retrieval (Python `gen_line_retrieval`).
pub fn gen_line_retrieval(seed: u64, n_lines: usize) -> Sample {
    assert!(n_lines <= 100, "2-digit line indices");
    let mut rng = SplitMix64::new(seed);
    let mut idxs: Vec<u16> = (0..100).collect();
    rng.shuffle(&mut idxs);
    idxs.truncate(n_lines);
    let vals: Vec<u16> = (0..n_lines).map(|_| rng.below(NVAL as u64) as u16).collect();
    let qi = rng.below(n_lines as u64) as usize;

    let mut toks: Vec<u16> = vec![BOS];
    let mut sal = (0usize, 0usize);
    for (i, (&ix, &v)) in idxs.iter().zip(&vals).enumerate() {
        let start = toks.len();
        toks.extend_from_slice(&[LINE, DIG0 + ix / 10, DIG0 + ix % 10, SEP,
                                 VAL0 + v, NL]);
        if i == qi {
            sal = (start, toks.len());
        }
    }
    toks.extend_from_slice(&[QUERY, DIG0 + idxs[qi] / 10, DIG0 + idxs[qi] % 10, SEP]);
    let prompt_len = toks.len();
    let answer = vec![VAL0 + vals[qi], EOS];
    toks.extend_from_slice(&answer);
    Sample { tokens: toks, prompt_len, answer, salient_span: sal }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gsm_fits_and_query_at_end() {
        for seed in 0..50 {
            let s = TaskGen::new(Task::Gsm, 256).sample(seed);
            assert!(s.tokens.len() <= 256, "seed {seed}: {}", s.tokens.len());
            assert_eq!(s.tokens[s.prompt_len - 1], SEP);
            assert_eq!(s.tokens[s.prompt_len - 3], QUERY);
            assert_eq!(*s.tokens.last().unwrap(), EOS);
        }
    }

    #[test]
    fn answer_matches_salient_span() {
        for seed in 0..50 {
            let s = TaskGen::new(Task::Gsm, 256).sample(seed);
            let (a, b) = s.salient_span;
            assert!(b > a, "seed {seed}");
            // span layout: KEY SEP VAL NL -> answer value at span start + 2
            assert_eq!(s.tokens[a + 2], s.answer[0], "seed {seed}");
            // and the queried key matches the span's key
            assert_eq!(s.tokens[a], s.tokens[s.prompt_len - 2]);
        }
    }

    #[test]
    fn line_retrieval_layout() {
        for seed in 0..30 {
            let s = TaskGen::new(Task::Lines(20), 256).sample(seed);
            assert!(s.tokens.len() <= 256);
            assert_eq!(s.tokens[0], BOS);
            let (a, b) = s.salient_span;
            assert_eq!(b - a, 6);
            assert_eq!(s.tokens[a], LINE);
            assert_eq!(s.tokens[a + 4], s.answer[0]);
        }
    }

    #[test]
    fn code_is_short_prompt() {
        for seed in 0..30 {
            let s = TaskGen::new(Task::Code, 256).sample(seed);
            assert!(s.prompt_len < 64, "{}", s.prompt_len);
        }
    }

    #[test]
    fn deterministic() {
        let g = TaskGen::new(Task::Lines(10), 256);
        assert_eq!(g.sample(7), g.sample(7));
        assert_ne!(g.sample(7), g.sample(8));
    }

    #[test]
    fn unique_keys_per_sample() {
        let s = gen_recall(3, 10, 5);
        let mut keys: Vec<u16> = s
            .tokens
            .windows(2)
            .filter(|w| (KEY0..KEY0 + NKEY).contains(&w[0]) && w[1] == SEP)
            .map(|w| w[0])
            .collect();
        keys.pop(); // drop the query repeat
        let n = keys.len();
        keys.sort_unstable();
        keys.dedup();
        assert_eq!(keys.len(), n);
    }
}
