//! Request-arrival traces for the serving benchmarks.
//!
//! The paper's efficiency section (Fig. 6, Table A) serves batches of
//! fixed-length prompts; the e2e example additionally replays an open-loop
//! trace with exponential inter-arrival times to exercise the continuous
//! batcher under load.

use std::time::Duration;

use crate::coordinator::request::{GenerationRequest, Priority};

use super::rng::SplitMix64;
use super::tasks::{Sample, Task, TaskGen};

/// One request in a trace, carrying the per-request options of the typed
/// serving API (DESIGN.md §11).  The plain constructors
/// ([`RequestTrace::batch`], [`RequestTrace::poisson`]) leave every
/// option at its default, reproducing the legacy positional path.
#[derive(Debug, Clone)]
pub struct TraceEntry {
    /// Arrival offset from trace start, in milliseconds.
    pub arrival_ms: f64,
    /// The prompt/task sample.
    pub sample: Sample,
    /// Decode budget (max new tokens).
    pub max_new_tokens: usize,
    /// Urgency class for the submitted request.
    pub priority: Priority,
    /// Deadline relative to submission; `Some(0.0)` is already expired
    /// at pop time, so the request is deterministically shed.
    pub deadline_ms: Option<f64>,
    /// Submit the request with its cancellation token already fired —
    /// the deterministic way to exercise the cancellation path in a
    /// replay (the request retires with `FinishReason::Cancelled` at pop,
    /// never holding a slot).
    pub cancelled: bool,
    /// Expected prefix-cache outcome on a prefix-enabled server
    /// (DESIGN.md §16): `Some(false)` marks a cold prefix (first sight,
    /// or right after a roll), `Some(true)` an entry whose shared prefix
    /// an earlier entry interned, `None` (the default) no expectation.
    /// Traces that set this ([`loadgen::shared_prefix_trace`]) space
    /// arrivals so the earlier prefill finishes first; the expectation
    /// describes that in-order replay, not arbitrary interleavings.
    /// [`loadgen::replay`] aggregates these into the [`LoadReport`] for
    /// callers to compare against the server's `prefix_hits` /
    /// `prefix_misses` metrics.
    ///
    /// [`loadgen::shared_prefix_trace`]: crate::server::loadgen::shared_prefix_trace
    /// [`loadgen::replay`]: crate::server::loadgen::replay
    /// [`LoadReport`]: crate::server::loadgen::LoadReport
    pub expect_prefix_hit: Option<bool>,
}

impl TraceEntry {
    fn defaults(arrival_ms: f64, sample: Sample, max_new_tokens: usize) -> Self {
        TraceEntry {
            arrival_ms,
            sample,
            max_new_tokens,
            priority: Priority::default(),
            deadline_ms: None,
            cancelled: false,
            expect_prefix_hit: None,
        }
    }

    /// Build the typed request this entry describes (prompt cloned; the
    /// trace stays replayable).  The deadline clock starts at call time,
    /// so build immediately before submitting.
    pub fn request(&self) -> GenerationRequest {
        let mut req =
            GenerationRequest::new(self.sample.prompt().to_vec(), self.max_new_tokens)
                .priority(self.priority);
        if let Some(ms) = self.deadline_ms {
            req = req.deadline_in(Duration::from_micros((ms * 1000.0) as u64));
        }
        if self.cancelled {
            req.cancel.cancel();
        }
        req
    }
}

/// A replayable request trace.
#[derive(Debug, Clone)]
pub struct RequestTrace {
    pub entries: Vec<TraceEntry>,
}

impl RequestTrace {
    /// Closed-loop batch: `n` requests all arriving at t=0 (the paper's
    /// batched-serving setup).
    pub fn batch(task: Task, max_seq: usize, n: usize, max_new_tokens: usize,
                 seed: u64) -> Self {
        let gen = TaskGen::new(task, max_seq);
        let entries = gen
            .batch(seed, n)
            .into_iter()
            .map(|sample| TraceEntry::defaults(0.0, sample, max_new_tokens))
            .collect();
        RequestTrace { entries }
    }

    /// Open-loop Poisson arrivals at `rate_per_s` over `n` requests.
    pub fn poisson(task: Task, max_seq: usize, n: usize, rate_per_s: f64,
                   max_new_tokens: usize, seed: u64) -> Self {
        let gen = TaskGen::new(task, max_seq);
        let mut rng = SplitMix64::new(seed ^ 0x7E15);
        let mut t = 0.0f64;
        let mut entries = Vec::with_capacity(n);
        for (i, sample) in gen.batch(seed, n).into_iter().enumerate() {
            if i > 0 {
                // exponential inter-arrival: -ln(U)/rate
                let u = rng.unit_f64().max(1e-12);
                t += -u.ln() / rate_per_s * 1000.0;
            }
            entries.push(TraceEntry::defaults(t, sample, max_new_tokens));
        }
        RequestTrace { entries }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_all_arrive_at_zero() {
        let t = RequestTrace::batch(Task::Code, 128, 8, 4, 1);
        assert_eq!(t.len(), 8);
        assert!(t.entries.iter().all(|e| e.arrival_ms == 0.0));
    }

    #[test]
    fn poisson_monotone_arrivals() {
        let t = RequestTrace::poisson(Task::Gsm, 256, 32, 10.0, 4, 2);
        for w in t.entries.windows(2) {
            assert!(w[1].arrival_ms >= w[0].arrival_ms);
        }
        // mean inter-arrival should be within 3x of 100ms for 32 samples
        let total = t.entries.last().unwrap().arrival_ms;
        assert!(total > 0.0 && total < 32.0 * 400.0);
    }

    #[test]
    fn plain_traces_carry_default_options() {
        let t = RequestTrace::batch(Task::Code, 128, 2, 4, 1);
        for e in &t.entries {
            assert_eq!(e.priority, Priority::Interactive);
            assert!(e.deadline_ms.is_none() && !e.cancelled);
            assert!(e.expect_prefix_hit.is_none());
            let r = e.request();
            assert!(r.deadline.is_none() && !r.cancel.is_cancelled());
            assert_eq!(r.prompt, e.sample.prompt());
            assert_eq!(r.max_new, 4);
        }
    }

    #[test]
    fn entry_options_reach_the_request() {
        let mut t = RequestTrace::batch(Task::Code, 128, 1, 4, 1);
        let e = &mut t.entries[0];
        e.priority = Priority::Background;
        e.deadline_ms = Some(0.0);
        e.cancelled = true;
        let r = e.request();
        assert_eq!(r.priority, Priority::Background);
        assert!(r.expired(std::time::Instant::now()));
        assert!(r.cancel.is_cancelled());
    }

    #[test]
    fn trace_deterministic() {
        let a = RequestTrace::poisson(Task::Code, 128, 5, 5.0, 2, 9);
        let b = RequestTrace::poisson(Task::Code, 128, 5, 5.0, 2, 9);
        for (x, y) in a.entries.iter().zip(&b.entries) {
            assert_eq!(x.arrival_ms, y.arrival_ms);
            assert_eq!(x.sample, y.sample);
        }
    }
}
