//! Workload generation: the paper's three evaluation tasks rebuilt as
//! synthetic generators (DESIGN.md §2), bit-identical to the Python
//! training corpus (`python/compile/data.py`).
//!
//! * [`rng::SplitMix64`] — the shared deterministic PRNG.
//! * [`tasks`] — GSM-style CoT recall, LongEval-style line retrieval, and
//!   short-prompt code tasks over the shared token map.
//! * [`trace`] — request-arrival traces for the serving benchmarks
//!   (open-loop Poisson-ish arrivals, batched replays).

pub mod rng;
pub mod tasks;
pub mod trace;

pub use tasks::{Sample, Task, TaskGen, vocab};
pub use trace::{RequestTrace, TraceEntry};
