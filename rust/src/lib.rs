//! # ZipCache — accurate and efficient KV cache quantization
//!
//! Rust/JAX/Pallas reproduction of *"ZipCache: Accurate and Efficient KV
//! Cache Quantization with Salient Token Identification"* (NeurIPS 2024).
//!
//! Three-layer architecture (DESIGN.md §1):
//!
//! * **L1** — Pallas kernels (`python/compile/kernels/`, DESIGN.md §3):
//!   CSTQuant, FlashAttention, probe-token saliency.  Build-time only.
//! * **L2** — JAX model (`python/compile/model.py`): a GPT-style decoder
//!   AOT-lowered to HLO text artifacts.
//! * **L3** — this crate: the serving coordinator.  Loads the artifacts via
//!   PJRT ([`runtime`]), owns the KV cache in its *physical* mixed-precision
//!   bit-packed form ([`kvcache`]), identifies salient tokens
//!   ([`saliency`]), schedules prefill/decode with streaming recompression
//!   ([`coordinator`]), fans plane-level compression out across a worker
//!   pool ([`util::pool`], DESIGN.md §5), and implements the paper's
//!   comparison baselines ([`baselines`]).  Python never runs on the
//!   request path.
//!
//! Quick tour:
//!
//! ```no_run
//! use zipcache::config::EngineConfig;
//! use zipcache::coordinator::Engine;
//! use zipcache::workload::{Task, TaskGen};
//!
//! let mut cfg = EngineConfig::load_default("artifacts", "micro").unwrap();
//! cfg.parallelism = 0; // compression workers: 0 = one per core
//! let mut engine = Engine::new(cfg).unwrap();
//! let sample = TaskGen::new(Task::Gsm, 60).sample(42);
//! let out = engine.generate(sample.prompt(), 4).unwrap();
//! println!("generated: {:?} at {:.2}x compression",
//!          out.tokens, out.compression_ratio);
//! ```

pub mod baselines;
pub mod config;
pub mod coordinator;
pub mod eval;
pub mod kvcache;
pub mod metrics;
pub mod quant;
pub mod runtime;
pub mod saliency;
pub mod server;
pub mod simcost;
pub mod util;
pub mod workload;

/// Crate-wide result type (anyhow-based, like the rest of the binary).
pub type Result<T> = anyhow::Result<T>;
