//! The compressed KV store: Alg. 2's `Split -> Quant -> Concat` made
//! physical, with per-token precision classes and byte-level accounting.
//!
//! Every `(layer, head)` K/V plane is compressed independently, so the
//! whole `Split -> Quant -> Concat` pipeline fans out across a
//! [`WorkerPool`] (DESIGN.md §5): [`CompressedKV::compress_with_pool`]
//! produces output **bit-identical** to the sequential
//! [`CompressedKV::compress`] at any pool width, verified by
//! `rust/tests/parallel_parity.rs` via [`CompressedKV::content_digest`].

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::kvcache::fp16::round_f16;
use crate::quant::kernel;
use crate::quant::{Granularity, QuantizedPlane};
use crate::util::pool::WorkerPool;

/// Per-worker gather/staging buffers for one plane compression (the
/// `kg`/`vg` row gathers of `compress_plane`).
#[derive(Debug, Default)]
struct PlaneScratch {
    kg: Vec<f32>,
    vg: Vec<f32>,
}

/// Checkout pool of [`PlaneScratch`] shared across the worker fan-out.
/// One uncontended lock per plane is noise next to the plane's
/// quantization work (hundreds of µs), and the buffers persist across
/// recompression cycles (DESIGN.md §9).
#[derive(Debug, Default)]
struct PlanePool {
    free: Mutex<Vec<PlaneScratch>>,
}

impl PlanePool {
    fn checkout(&self) -> PlaneScratch {
        self.free.lock().unwrap().pop().unwrap_or_default()
    }

    fn restore(&self, s: PlaneScratch) {
        self.free.lock().unwrap().push(s);
    }
}

/// Reusable scratch for the whole compression cycle (DESIGN.md §9):
/// the Split stage's class-group vectors, the per-worker gather buffers
/// of the Quant stage, and the subset-dequant staging buffer of
/// [`CompressedKV::materialize_into_scratch`].  Owned by the engine and
/// reused across recompression cycles; a fresh default is equivalent
/// (outputs are bit-identical either way — scratch holds no state
/// between calls, only warm capacity).
#[derive(Debug, Default)]
pub struct CompressScratch {
    /// Split output: `(class, member token rows)` in first-seen order.
    groups: Vec<(PrecisionClass, Vec<u32>)>,
    /// Retired group row vectors, kept for their capacity.
    spare_rows: Vec<Vec<u32>>,
    /// Per-worker plane gather buffers.
    planes: PlanePool,
    /// Subset-plane dequant staging for materialization.
    setbuf: Vec<f32>,
}

/// Static shape of one sequence's cache.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheLayout {
    pub layers: usize,
    pub heads: usize,
    pub seq: usize,
    pub d_head: usize,
}

impl CacheLayout {
    pub fn plane_len(&self) -> usize {
        self.seq * self.d_head
    }
    pub fn cache_len(&self) -> usize {
        self.layers * self.heads * self.plane_len()
    }
    /// FP16 baseline bytes for `n_tokens` cached tokens (K and V).
    pub fn fp16_baseline_bytes(&self, n_tokens: usize) -> usize {
        2 * self.layers * self.heads * n_tokens * self.d_head * 2
    }
}

/// Precision assigned to one token's K/V rows.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PrecisionClass {
    /// Uncompressed half precision (FP16 baseline, KIVI recent window).
    Fp16,
    /// Quantized to `bits` (e.g. Hi=4 for salient, Lo=2 for regular).
    Bits(u8),
    /// Dropped entirely (H2O); contributes no storage and is masked out.
    Evicted,
}

impl PrecisionClass {
    pub fn is_evicted(&self) -> bool {
        matches!(self, PrecisionClass::Evicted)
    }
}

/// Key/value granularity configuration (paper §5.1 defaults; Table 1
/// variants are produced by changing these).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QuantSpec {
    pub key_gran: Granularity,
    pub value_gran: Granularity,
}

impl Default for QuantSpec {
    fn default() -> Self {
        QuantSpec {
            key_gran: Granularity::Channel,
            value_gran: Granularity::ChannelSeparableToken,
        }
    }
}

/// One quantized subset of rows within a plane (one precision class).
#[derive(Debug, Clone)]
struct SubsetPlane {
    rows: Vec<u32>,
    plane: QuantizedPlane,
}

/// Per-stage wall/CPU timing of one compression pass (Alg. 2's
/// `Split -> Quant -> Concat`), reported by
/// [`CompressedKV::compress_instrumented`] and aggregated into
/// `EngineMetrics::compress_stages`.
#[derive(Debug, Clone, Copy, Default)]
pub struct CompressStats {
    /// Wall time grouping tokens by precision class (the Split stage).
    pub split_us: u64,
    /// Wall time of the plane fan-out + join (the Quant stage — gather,
    /// quantize, bit-pack).  This is the number that shrinks with pool
    /// width.
    pub quant_wall_us: u64,
    /// CPU time summed across workers inside the Quant stage; roughly
    /// constant in pool width, so `quant_cpu_us / quant_wall_us` is the
    /// achieved parallel speedup.
    pub quant_cpu_us: u64,
    /// Wall time assembling the final store (the Concat stage).
    pub concat_us: u64,
    /// End-to-end wall time of the compression pass.
    pub wall_us: u64,
    /// Number of `(layer, head)` planes compressed.
    pub planes: usize,
    /// Pool width used.
    pub threads: usize,
}

/// One (layer, head) pair of compressed K/V planes.
#[derive(Debug, Clone, Default)]
struct HeadStore {
    k_sets: Vec<SubsetPlane>,
    v_sets: Vec<SubsetPlane>,
    /// Fp16-class rows, stored rounded-through-f16 (accounted at 2 B/value).
    fp_rows: Vec<(u32, Vec<f32>, Vec<f32>)>, // (token, k_row, v_row)
}

/// A fully compressed KV cache for one sequence.
///
/// Construction consumes fp32 caches in `[L, H, S, dh]` layout (exactly the
/// prefill artifact's output) plus a per-token class assignment; the store
/// keeps only packed codes + params, and can materialize the fp32 cache the
/// decode artifact consumes (`materialize_into`) or report true byte usage
/// (`storage_bytes`).
#[derive(Debug, Clone)]
pub struct CompressedKV {
    pub layout: CacheLayout,
    pub classes: Vec<PrecisionClass>,
    pub n_tokens: usize,
    pub spec: QuantSpec,
    heads: Vec<HeadStore>,
}

impl CompressedKV {
    /// Compress `kcache`/`vcache` (`[L, H, S, dh]` fp32, row-major) under
    /// the per-token `classes` (length = n_tokens <= S), sequentially.
    pub fn compress(
        kcache: &[f32],
        vcache: &[f32],
        layout: CacheLayout,
        classes: &[PrecisionClass],
        spec: QuantSpec,
    ) -> Self {
        Self::compress_with_pool(kcache, vcache, layout, classes, spec,
                                 &WorkerPool::sequential())
    }

    /// Like [`CompressedKV::compress`], fanning the independent
    /// `(layer, head)` planes out across `pool` (DESIGN.md §5).
    ///
    /// The result is bit-identical to the sequential path at any pool
    /// width: each plane is compressed by the same code on the same
    /// inputs, and the join restores index order.
    pub fn compress_with_pool(
        kcache: &[f32],
        vcache: &[f32],
        layout: CacheLayout,
        classes: &[PrecisionClass],
        spec: QuantSpec,
        pool: &WorkerPool,
    ) -> Self {
        Self::compress_instrumented(kcache, vcache, layout, classes, spec, pool).0
    }

    /// [`CompressedKV::compress_with_pool`] plus per-stage timing
    /// ([`CompressStats`]) for the engine metrics and the hot-path bench.
    /// Allocates its scratch per call; the recompression cycle passes a
    /// persistent [`CompressScratch`] via
    /// [`CompressedKV::compress_instrumented_scratch`].
    pub fn compress_instrumented(
        kcache: &[f32],
        vcache: &[f32],
        layout: CacheLayout,
        classes: &[PrecisionClass],
        spec: QuantSpec,
        pool: &WorkerPool,
    ) -> (Self, CompressStats) {
        let mut scratch = CompressScratch::default();
        Self::compress_instrumented_scratch(kcache, vcache, layout, classes, spec,
                                            pool, &mut scratch)
    }

    /// [`CompressedKV::compress_instrumented`] with caller-owned scratch:
    /// the Split-stage class groups, the workers' plane gather buffers,
    /// and (for materialization) the subset staging buffer all reuse
    /// `scratch`'s warm capacity instead of reallocating every cycle
    /// (DESIGN.md §9).  Output is bit-identical to the scratch-free path.
    pub fn compress_instrumented_scratch(
        kcache: &[f32],
        vcache: &[f32],
        layout: CacheLayout,
        classes: &[PrecisionClass],
        spec: QuantSpec,
        pool: &WorkerPool,
        scratch: &mut CompressScratch,
    ) -> (Self, CompressStats) {
        Self::compress_kind_scratch(kcache, vcache, layout, classes, spec, pool,
                                    scratch, kernel::active())
    }

    /// [`CompressedKV::compress`] pinned to an explicit quant kernel kind
    /// (DESIGN.md §15): the cross-kind parity tests and benches compare
    /// kernels without touching the process-wide selection.  Sequential;
    /// the store (and its [`CompressedKV::content_digest`]) is
    /// bit-identical across kinds.
    pub fn compress_with_kind(
        kcache: &[f32],
        vcache: &[f32],
        layout: CacheLayout,
        classes: &[PrecisionClass],
        spec: QuantSpec,
        kind: kernel::Kind,
    ) -> Self {
        let mut scratch = CompressScratch::default();
        Self::compress_kind_scratch(kcache, vcache, layout, classes, spec,
                                    &WorkerPool::sequential(), &mut scratch, kind)
            .0
    }

    fn compress_kind_scratch(
        kcache: &[f32],
        vcache: &[f32],
        layout: CacheLayout,
        classes: &[PrecisionClass],
        spec: QuantSpec,
        pool: &WorkerPool,
        scratch: &mut CompressScratch,
        kind: kernel::Kind,
    ) -> (Self, CompressStats) {
        assert_eq!(kcache.len(), layout.cache_len());
        assert_eq!(vcache.len(), layout.cache_len());
        let n_tokens = classes.len();
        assert!(n_tokens <= layout.seq);
        let CompressScratch { groups, spare_rows, planes, setbuf: _ } = scratch;
        let t_all = Instant::now();

        // Split: group token indices by class (stable order within class).
        // Retired row vectors from the previous cycle are recycled for
        // their capacity.
        spare_rows.extend(groups.drain(..).map(|(_, mut v)| {
            v.clear();
            v
        }));
        for (t, &c) in classes.iter().enumerate() {
            if c.is_evicted() {
                continue;
            }
            match groups.iter_mut().find(|(gc, _)| *gc == c) {
                Some((_, v)) => v.push(t as u32),
                None => {
                    let mut v = spare_rows.pop().unwrap_or_default();
                    v.push(t as u32);
                    groups.push((c, v));
                }
            }
        }
        let split_us = t_all.elapsed().as_micros() as u64;

        // Quant: every (layer, head) plane is independent — fan out.
        let (s, dh) = (layout.seq, layout.d_head);
        let n_planes = layout.layers * layout.heads;
        let quant_cpu = AtomicU64::new(0);
        let groups = &*groups;
        let t_quant = Instant::now();
        let heads = pool.run(n_planes, |hi| {
            let t_plane = Instant::now();
            let base = hi * s * dh;
            let mut ps = planes.checkout();
            let hs = compress_plane(&kcache[base..base + s * dh],
                                    &vcache[base..base + s * dh],
                                    dh, groups, spec, kind, &mut ps);
            planes.restore(ps);
            quant_cpu.fetch_add(t_plane.elapsed().as_micros() as u64,
                                Ordering::Relaxed);
            hs
        });
        let quant_wall_us = t_quant.elapsed().as_micros() as u64;

        // Concat: assemble the store (the planes are already in order).
        let t_concat = Instant::now();
        let store = CompressedKV { layout, classes: classes.to_vec(), n_tokens,
                                   spec, heads };
        let stats = CompressStats {
            split_us,
            quant_wall_us,
            quant_cpu_us: quant_cpu.load(Ordering::Relaxed),
            concat_us: t_concat.elapsed().as_micros() as u64,
            wall_us: t_all.elapsed().as_micros() as u64,
            planes: n_planes,
            threads: pool.threads(),
        };
        (store, stats)
    }

    /// FNV-1a digest over the store's physical content:
    /// packed code bytes, quantization parameters, row indices, channel
    /// scales, and fp16 rows, walked in `(layer, head)` order.
    ///
    /// Two stores digest equal iff they hold byte-identical compressed
    /// planes — the parallel/sequential parity contract of DESIGN.md §5.
    pub fn content_digest(&self) -> u64 {
        const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
        const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
        fn put(h: u64, bytes: &[u8]) -> u64 {
            let mut h = h;
            for &b in bytes {
                h ^= b as u64;
                h = h.wrapping_mul(FNV_PRIME);
            }
            h
        }
        fn put_plane(mut h: u64, set: &SubsetPlane) -> u64 {
            for &r in &set.rows {
                h = put(h, &r.to_le_bytes());
            }
            let p = &set.plane;
            h = put(h, &[p.bits]);
            h = put(h, &(p.rows as u64).to_le_bytes());
            h = put(h, &(p.cols as u64).to_le_bytes());
            h = put(h, p.codes.as_bytes());
            for q in &p.params {
                h = put(h, &q.scale.to_bits().to_le_bytes());
                h = put(h, &q.zero.to_bits().to_le_bytes());
            }
            for &c in &p.chan_scale {
                h = put(h, &c.to_bits().to_le_bytes());
            }
            h
        }
        let mut h = FNV_OFFSET;
        for hs in &self.heads {
            for set in hs.k_sets.iter().chain(hs.v_sets.iter()) {
                h = put_plane(h, set);
            }
            for (r, kr, vr) in &hs.fp_rows {
                h = put(h, &r.to_le_bytes());
                for &x in kr.iter().chain(vr.iter()) {
                    h = put(h, &x.to_bits().to_le_bytes());
                }
            }
        }
        h
    }

    /// Scatter the dequantized cache into fp32 buffers shaped `[L,H,S,dh]`
    /// and fill `valid` (length S): 1.0 for live tokens, 0.0 for evicted /
    /// beyond `n_tokens`.
    ///
    /// Clears the whole output first, so the buffers may hold anything on
    /// entry.  The recompression cycle uses
    /// [`CompressedKV::materialize_into_scratch`], which skips the full
    /// clear under the session's buffer invariant (DESIGN.md §9).
    pub fn materialize_into(&self, kout: &mut [f32], vout: &mut [f32], valid: &mut [f32]) {
        kout.fill(0.0);
        vout.fill(0.0);
        let mut setbuf: Vec<f32> = Vec::new();
        self.scatter_live(kout, vout, valid, &mut setbuf, false);
    }

    /// [`CompressedKV::materialize_into`] for the steady-state
    /// recompression cycle: reuses `scratch`'s staging buffer and zeroes
    /// only the *dead* rows inside the live prefix (`Evicted` classes)
    /// instead of `fill(0.0)` over the whole `[L,H,S,dh]` cache.
    ///
    /// Precondition (DESIGN.md §9): rows at positions `>= n_tokens` must
    /// already be neutral in `kout`/`vout` — exactly the session buffer
    /// invariant (the engine zeroes every row beyond the live prefix once
    /// after the prefill compression, and decode only writes at `pos`,
    /// which later cycles cover; consumers mask by `valid` regardless).
    /// Under
    /// that invariant the resulting buffers are bit-identical to the
    /// full-clear path.
    pub fn materialize_into_scratch(
        &self,
        kout: &mut [f32],
        vout: &mut [f32],
        valid: &mut [f32],
        scratch: &mut CompressScratch,
    ) {
        self.scatter_live(kout, vout, valid, &mut scratch.setbuf, true);
    }

    /// Shared scatter core: rebuild `valid`, overwrite every live row
    /// from the compressed planes, and (when `zero_dead_rows`) clear the
    /// evicted rows of the live prefix.
    fn scatter_live(
        &self,
        kout: &mut [f32],
        vout: &mut [f32],
        valid: &mut [f32],
        setbuf: &mut Vec<f32>,
        zero_dead_rows: bool,
    ) {
        let lay = self.layout;
        assert_eq!(kout.len(), lay.cache_len());
        assert_eq!(vout.len(), lay.cache_len());
        assert_eq!(valid.len(), lay.seq);
        valid.fill(0.0);
        for (t, c) in self.classes.iter().enumerate() {
            if !c.is_evicted() {
                valid[t] = 1.0;
            }
        }
        let (s, dh) = (lay.seq, lay.d_head);
        // Evicted positions are plane-independent: collect them once, not
        // once per (layer, head) plane.  The common zero-evictions case
        // collects nothing (and allocates nothing).
        let evicted: Vec<usize> = if zero_dead_rows {
            self.classes
                .iter()
                .enumerate()
                .filter(|(_, c)| c.is_evicted())
                .map(|(t, _)| t)
                .collect()
        } else {
            Vec::new()
        };
        // Perf (EXPERIMENTS.md §Perf): bulk-dequantize each subset plane
        // once (fused unpack–dequant) and scatter rows, instead of
        // per-row random-access decode — ~2x on the recompression cycle.
        for (hi, hs) in self.heads.iter().enumerate() {
            let base = hi * s * dh;
            for &t in &evicted {
                let o = base + t * dh;
                kout[o..o + dh].fill(0.0);
                vout[o..o + dh].fill(0.0);
            }
            for (sets, out) in [(&hs.k_sets, &mut *kout), (&hs.v_sets, &mut *vout)] {
                for set in sets {
                    setbuf.resize(set.rows.len() * dh, 0.0);
                    set.plane.dequantize_into(setbuf);
                    for (i, &r) in set.rows.iter().enumerate() {
                        let o = base + r as usize * dh;
                        out[o..o + dh].copy_from_slice(&setbuf[i * dh..(i + 1) * dh]);
                    }
                }
            }
            for (r, kr, vr) in &hs.fp_rows {
                let o = base + *r as usize * dh;
                kout[o..o + dh].copy_from_slice(kr);
                vout[o..o + dh].copy_from_slice(vr);
            }
        }
    }

    /// Physical storage in bytes of the quantized payload: packed codes,
    /// quantization parameters (`param_bytes` selects their accounting —
    /// paper Appendix A uses 16-bit => 2), CST channel scales, and fp16
    /// rows.  The per-token class/validity sidecar is accounted
    /// separately by [`CompressedKV::metadata_bytes`]; use
    /// [`CompressedKV::resident_bytes`] for the full footprint.
    pub fn storage_bytes(&self, param_bytes: usize) -> usize {
        let dh = self.layout.d_head;
        let mut total = 0;
        for hs in &self.heads {
            for set in hs.k_sets.iter().chain(hs.v_sets.iter()) {
                total += set.plane.storage_bytes(param_bytes);
            }
            total += hs.fp_rows.len() * 2 * dh * 2; // k+v rows at 2 B/value
        }
        total
    }

    /// Bytes of the class/validity sidecar: one byte per live-window
    /// token encoding its [`PrecisionClass`].  The per-plane row-index
    /// lists and the validity mask are both derivable from it (classes
    /// are shared across every `(layer, head)` plane, and `Evicted` *is*
    /// the invalidity marker), so this one sidecar is the entire
    /// metadata footprint.
    pub fn metadata_bytes(&self) -> usize {
        self.n_tokens
    }

    /// Full resident footprint of the compressed cache: quantized
    /// payload (params at the paper's 16-bit accounting) plus the
    /// class/validity metadata sidecar.  This is the number the engine
    /// reports as `cache_bytes` and the byte-budget admission reserves
    /// against (DESIGN.md §10).
    pub fn resident_bytes(&self) -> usize {
        self.storage_bytes(2) + self.metadata_bytes()
    }

    /// Achieved compression ratio vs. the FP16 dense cache for the live
    /// prefix (the number the paper's tables report).  Uses the full
    /// resident footprint — quantization parameters *and* the
    /// class/validity metadata — so the ratio never overstates what the
    /// quantizer saves.
    pub fn compression_ratio(&self) -> f64 {
        let base = self.layout.fp16_baseline_bytes(self.n_tokens) as f64;
        let used = self.resident_bytes() as f64;
        if used == 0.0 {
            f64::INFINITY
        } else {
            base / used
        }
    }

    /// Mean squared reconstruction error against the original caches
    /// (fidelity metric used by Table-1-style evaluations).
    pub fn reconstruction_mse(&self, kcache: &[f32], vcache: &[f32]) -> f64 {
        let lay = self.layout;
        let mut k = vec![0f32; lay.cache_len()];
        let mut v = vec![0f32; lay.cache_len()];
        let mut valid = vec![0f32; lay.seq];
        self.materialize_into(&mut k, &mut v, &mut valid);
        let (s, dh) = (lay.seq, lay.d_head);
        let mut se = 0f64;
        let mut n = 0usize;
        for hi in 0..lay.layers * lay.heads {
            let base = hi * s * dh;
            for (t, c) in self.classes.iter().enumerate() {
                if c.is_evicted() {
                    continue;
                }
                let o = base + t * dh;
                for j in 0..dh {
                    let dk = (k[o + j] - kcache[o + j]) as f64;
                    let dv = (v[o + j] - vcache[o + j]) as f64;
                    se += dk * dk + dv * dv;
                    n += 2;
                }
            }
        }
        if n == 0 {
            0.0
        } else {
            se / n as f64
        }
    }
}

/// Compress one `(layer, head)` pair of K/V planes under the pre-split
/// class `groups` — the per-plane unit of work the pool fans out
/// (Alg. 2's Quant stage).  `ps` holds the worker's reusable gather
/// buffers (checked out of the [`CompressScratch`] plane pool).
fn compress_plane(
    kplane: &[f32],
    vplane: &[f32],
    dh: usize,
    groups: &[(PrecisionClass, Vec<u32>)],
    spec: QuantSpec,
    kind: kernel::Kind,
    ps: &mut PlaneScratch,
) -> HeadStore {
    let mut hs = HeadStore::default();
    for (class, rows) in groups {
        match class {
            PrecisionClass::Fp16 => {
                for &r in rows {
                    let r0 = r as usize * dh;
                    let kr: Vec<f32> =
                        kplane[r0..r0 + dh].iter().map(|&x| round_f16(x)).collect();
                    let vr: Vec<f32> =
                        vplane[r0..r0 + dh].iter().map(|&x| round_f16(x)).collect();
                    hs.fp_rows.push((r, kr, vr));
                }
            }
            PrecisionClass::Bits(bits) => {
                // Gather rows into the reused scratch, quantize the
                // subset on its own statistics (Alg. 2's Split
                // semantics).
                let (kg, vg) = (&mut ps.kg, &mut ps.vg);
                kg.clear();
                vg.clear();
                for &r in rows {
                    let r0 = r as usize * dh;
                    kg.extend_from_slice(&kplane[r0..r0 + dh]);
                    vg.extend_from_slice(&vplane[r0..r0 + dh]);
                }
                hs.k_sets.push(SubsetPlane {
                    rows: rows.clone(),
                    plane: QuantizedPlane::quantize_with(
                        kind, kg, rows.len(), dh, *bits, spec.key_gran),
                });
                hs.v_sets.push(SubsetPlane {
                    rows: rows.clone(),
                    plane: QuantizedPlane::quantize_with(
                        kind, vg, rows.len(), dh, *bits, spec.value_gran),
                });
            }
            PrecisionClass::Evicted => unreachable!(),
        }
    }
    hs
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layout() -> CacheLayout {
        CacheLayout { layers: 2, heads: 2, seq: 16, d_head: 8 }
    }

    fn caches(lay: CacheLayout) -> (Vec<f32>, Vec<f32>) {
        let n = lay.cache_len();
        let k: Vec<f32> = (0..n).map(|i| ((i as f32 * 0.317).sin()) * 2.0).collect();
        let v: Vec<f32> = (0..n).map(|i| ((i as f32 * 0.711).cos()) * 3.0).collect();
        (k, v)
    }

    #[test]
    fn mixed_precision_roundtrip_and_masking() {
        let lay = layout();
        let (k, v) = caches(lay);
        let mut classes = vec![PrecisionClass::Bits(2); 12];
        classes[3] = PrecisionClass::Bits(4);
        classes[4] = PrecisionClass::Fp16;
        classes[5] = PrecisionClass::Evicted;
        let c = CompressedKV::compress(&k, &v, lay, &classes, QuantSpec::default());
        let mut ko = vec![0f32; lay.cache_len()];
        let mut vo = vec![0f32; lay.cache_len()];
        let mut valid = vec![0f32; lay.seq];
        c.materialize_into(&mut ko, &mut vo, &mut valid);
        assert_eq!(valid[5], 0.0);
        assert_eq!(valid[3], 1.0);
        assert_eq!(&valid[12..], &[0.0; 4]); // beyond n_tokens
        // fp16 row nearly exact
        let dh = lay.d_head;
        for j in 0..dh {
            assert!((ko[4 * dh + j] - k[4 * dh + j]).abs() < 2e-3);
        }
        // evicted row zeroed
        assert!(ko[5 * dh..6 * dh].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn hi_bits_rows_more_accurate_than_lo() {
        let lay = layout();
        let (k, v) = caches(lay);
        let mut classes = vec![PrecisionClass::Bits(2); 16];
        for t in 0..8 {
            classes[t] = PrecisionClass::Bits(4);
        }
        let c = CompressedKV::compress(&k, &v, lay, &classes, QuantSpec::default());
        let mut ko = vec![0f32; lay.cache_len()];
        let mut vo = vec![0f32; lay.cache_len()];
        let mut valid = vec![0f32; lay.seq];
        c.materialize_into(&mut ko, &mut vo, &mut valid);
        let dh = lay.d_head;
        let err = |rows: std::ops::Range<usize>| -> f32 {
            let mut e = 0.0;
            for hi in 0..lay.layers * lay.heads {
                let base = hi * lay.seq * dh;
                for t in rows.clone() {
                    for j in 0..dh {
                        e += (vo[base + t * dh + j] - v[base + t * dh + j]).powi(2);
                    }
                }
            }
            e
        };
        assert!(err(0..8) < err(8..16));
    }

    #[test]
    fn compression_ratio_sane() {
        let lay = layout();
        let (k, v) = caches(lay);
        let classes = vec![PrecisionClass::Bits(4); 16];
        let c = CompressedKV::compress(&k, &v, lay, &classes, QuantSpec::default());
        let r = c.compression_ratio();
        // 4-bit of 16-bit baseline minus param overhead: between 2x and 4x
        assert!(r > 2.0 && r <= 4.0, "{r}");
        let classes2 = vec![PrecisionClass::Bits(2); 16];
        let c2 = CompressedKV::compress(&k, &v, lay, &classes2, QuantSpec::default());
        assert!(c2.compression_ratio() > r);
    }

    #[test]
    fn byte_accounting_pinned_on_hand_computed_layout() {
        // 1 layer x 1 head, 4-token window, d_head = 2, two live tokens,
        // both Bits(4), tokenwise K and V — small enough to account by
        // hand:
        //   codes     : 2 rows x 2 cols x 4 bit = 2 B  (per plane, K and V)
        //   params    : Token => one (s, z) pair per row = 2 pairs
        //               -> 4 values x 2 B = 8 B          (per plane, K and V)
        //   payload   : (2 + 8) x 2 planes              = 20 B
        //   metadata  : 1 B/token class sidecar x 2     =  2 B
        //   resident  : 20 + 2                          = 22 B
        let lay = CacheLayout { layers: 1, heads: 1, seq: 4, d_head: 2 };
        let spec = QuantSpec {
            key_gran: Granularity::Token,
            value_gran: Granularity::Token,
        };
        let k: Vec<f32> = (0..lay.cache_len()).map(|i| i as f32 * 0.5).collect();
        let v: Vec<f32> = (0..lay.cache_len()).map(|i| 1.0 - i as f32).collect();
        let classes = vec![PrecisionClass::Bits(4); 2];
        let c = CompressedKV::compress(&k, &v, lay, &classes, spec);
        assert_eq!(c.storage_bytes(2), 20);
        assert_eq!(c.metadata_bytes(), 2);
        assert_eq!(c.resident_bytes(), 22);
        // fp16 baseline for 2 tokens: 2 (K,V) x 2 tokens x 2 cols x 2 B = 16 B
        assert_eq!(lay.fp16_baseline_bytes(2), 16);
        assert!((c.compression_ratio() - 16.0 / 22.0).abs() < 1e-12);
        // Honest-f32 params accounting doubles only the param bytes.
        assert_eq!(c.storage_bytes(4), 2 * 2 + 8 * 2 * 2);
    }

    #[test]
    fn widest_override_byte_accounting_pinned_by_hand() {
        // The widest per-request quant override, Bits(8) everywhere
        // (DESIGN.md §11), on the same hand-accountable layout as the
        // 22 B pin above:
        //   codes     : 2 rows x 2 cols x 8 bit = 4 B  (per plane, K and V)
        //   params    : Token => one (s, z) pair per row = 2 pairs
        //               -> 4 values x 2 B = 8 B          (per plane, K and V)
        //   payload   : (4 + 8) x 2 planes              = 24 B
        //   metadata  : 1 B/token class sidecar x 2     =  2 B
        //   resident  : 24 + 2                          = 26 B
        let lay = CacheLayout { layers: 1, heads: 1, seq: 4, d_head: 2 };
        let spec = QuantSpec {
            key_gran: Granularity::Token,
            value_gran: Granularity::Token,
        };
        let k: Vec<f32> = (0..lay.cache_len()).map(|i| i as f32 * 0.5).collect();
        let v: Vec<f32> = (0..lay.cache_len()).map(|i| 1.0 - i as f32).collect();
        let classes = vec![PrecisionClass::Bits(8); 2];
        let c = CompressedKV::compress(&k, &v, lay, &classes, spec);
        assert_eq!(c.storage_bytes(2), 24);
        assert_eq!(c.metadata_bytes(), 2);
        assert_eq!(c.resident_bytes(), 26);
        // ...and the dispatcher's override-independent admission bound
        // dominates it (fp16 payload + densest-mix params slack).
        assert!(c.resident_bytes()
                <= crate::kvcache::worst_case_resident_bytes(lay, 2, 100));
    }

    #[test]
    fn override_bits_stay_under_worst_case_bound() {
        // Byte-budget soundness for per-request quant overrides
        // (DESIGN.md §11): every admissible override width — uniform or
        // mixed — stays under the override-independent worst-case bound
        // the dispatcher reserves at admission.
        let lay = layout();
        let (k, v) = caches(lay);
        let n = lay.seq;
        let wc = crate::kvcache::worst_case_resident_bytes(lay, n, 100);
        for bits in [1u8, 2, 4, 8] {
            let classes = vec![PrecisionClass::Bits(bits); n];
            let c = CompressedKV::compress(&k, &v, lay, &classes,
                                           QuantSpec::default());
            assert!(c.resident_bytes() <= wc,
                    "bits={bits}: {} B exceeds the worst-case bound {wc} B",
                    c.resident_bytes());
        }
        // A salient/regular split like an override produces (8-bit heads,
        // 1-bit tail) is bounded too.
        let mut classes = vec![PrecisionClass::Bits(1); n];
        for c in classes.iter_mut().take(n / 2) {
            *c = PrecisionClass::Bits(8);
        }
        let c = CompressedKV::compress(&k, &v, lay, &classes, QuantSpec::default());
        assert!(c.resident_bytes() <= wc);
    }

    #[test]
    fn eviction_reduces_storage_to_zero() {
        let lay = layout();
        let (k, v) = caches(lay);
        let classes = vec![PrecisionClass::Evicted; 16];
        let c = CompressedKV::compress(&k, &v, lay, &classes, QuantSpec::default());
        assert_eq!(c.storage_bytes(2), 0);
    }

    #[test]
    fn parallel_compress_is_bit_identical() {
        let lay = CacheLayout { layers: 3, heads: 4, seq: 32, d_head: 8 };
        let (k, v) = caches(lay);
        let classes: Vec<PrecisionClass> = (0..28)
            .map(|t| match t % 5 {
                0 => PrecisionClass::Bits(4),
                1 => PrecisionClass::Fp16,
                2 => PrecisionClass::Evicted,
                _ => PrecisionClass::Bits(2),
            })
            .collect();
        let seq = CompressedKV::compress(&k, &v, lay, &classes, QuantSpec::default());
        for threads in [2usize, 3, 8] {
            let par = CompressedKV::compress_with_pool(
                &k, &v, lay, &classes, QuantSpec::default(),
                &WorkerPool::new(threads));
            assert_eq!(par.content_digest(), seq.content_digest(), "t={threads}");
            assert_eq!(par.storage_bytes(2), seq.storage_bytes(2));
            assert_eq!(par.compression_ratio(), seq.compression_ratio());
        }
    }

    #[test]
    fn digest_detects_content_changes() {
        let lay = layout();
        let (k, v) = caches(lay);
        let classes = vec![PrecisionClass::Bits(2); 16];
        let a = CompressedKV::compress(&k, &v, lay, &classes, QuantSpec::default());
        let b = CompressedKV::compress(&k, &v, lay, &classes, QuantSpec::default());
        assert_eq!(a.content_digest(), b.content_digest());
        let mut k2 = k.clone();
        k2[0] += 1.0;
        let c = CompressedKV::compress(&k2, &v, lay, &classes, QuantSpec::default());
        assert_ne!(a.content_digest(), c.content_digest());
    }

    #[test]
    fn compress_stats_accounted() {
        let lay = layout();
        let (k, v) = caches(lay);
        let classes = vec![PrecisionClass::Bits(4); 16];
        let (_, st) = CompressedKV::compress_instrumented(
            &k, &v, lay, &classes, QuantSpec::default(), &WorkerPool::new(2));
        assert_eq!(st.planes, lay.layers * lay.heads);
        assert_eq!(st.threads, 2);
        assert!(st.wall_us >= st.quant_wall_us);
    }

    #[test]
    fn scratch_reuse_is_bit_identical() {
        // One CompressScratch carried across cycles (different class
        // assignments, growing live prefix) must give exactly the outputs
        // of a fresh scratch every time.
        let lay = CacheLayout { layers: 2, heads: 3, seq: 24, d_head: 8 };
        let (k, v) = {
            let n = lay.cache_len();
            let k: Vec<f32> = (0..n).map(|i| ((i as f32 * 0.531).sin()) * 2.0).collect();
            let v: Vec<f32> = (0..n).map(|i| ((i as f32 * 0.277).cos()) * 3.0).collect();
            (k, v)
        };
        let pool = WorkerPool::new(3);
        let mut scratch = CompressScratch::default();
        for n_tokens in [7usize, 13, 24] {
            let classes: Vec<PrecisionClass> = (0..n_tokens)
                .map(|t| match t % 4 {
                    0 => PrecisionClass::Bits(4),
                    1 => PrecisionClass::Fp16,
                    2 => PrecisionClass::Evicted,
                    _ => PrecisionClass::Bits(2),
                })
                .collect();
            let (warm, _) = CompressedKV::compress_instrumented_scratch(
                &k, &v, lay, &classes, QuantSpec::default(), &pool, &mut scratch);
            let fresh = CompressedKV::compress(&k, &v, lay, &classes,
                                               QuantSpec::default());
            assert_eq!(warm.content_digest(), fresh.content_digest(),
                       "n_tokens={n_tokens}");
        }
    }

    #[test]
    fn scratch_materialize_matches_full_clear() {
        // Under the session invariant (rows >= n_tokens neutral), the
        // zero-dead-rows materialization must produce buffers bit-equal
        // to the full-clear path — including when a row that was live in
        // the previous cycle becomes evicted in the next one.
        let lay = layout();
        let (k, v) = caches(lay);
        let n = lay.cache_len();
        let mut classes = vec![PrecisionClass::Bits(4); 10];
        classes[2] = PrecisionClass::Fp16;
        let first = CompressedKV::compress(&k, &v, lay, &classes, QuantSpec::default());

        let mut scratch = CompressScratch::default();
        let (mut ks, mut vs, mut vas) = (vec![0f32; n], vec![0f32; n], vec![0f32; lay.seq]);
        first.materialize_into_scratch(&mut ks, &mut vs, &mut vas, &mut scratch);
        let (mut kf, mut vf, mut vaf) = (vec![0f32; n], vec![0f32; n], vec![0f32; lay.seq]);
        first.materialize_into(&mut kf, &mut vf, &mut vaf);
        assert_eq!(ks, kf);
        assert_eq!(vs, vf);
        assert_eq!(vas, vaf);

        // Next cycle: longer prefix, token 2 now evicted — its stale
        // fp16 content must be cleared by the dead-row pass.
        let mut classes2 = vec![PrecisionClass::Bits(2); 12];
        classes2[2] = PrecisionClass::Evicted;
        let second = CompressedKV::compress(&k, &v, lay, &classes2, QuantSpec::default());
        second.materialize_into_scratch(&mut ks, &mut vs, &mut vas, &mut scratch);
        second.materialize_into(&mut kf, &mut vf, &mut vaf);
        assert_eq!(ks, kf);
        assert_eq!(vs, vf);
        assert_eq!(vas, vaf);
        let dh = lay.d_head;
        assert!(ks[2 * dh..3 * dh].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn kernel_kinds_compress_digest_identical() {
        // content_digest pin for DESIGN.md §15: every available kernel
        // kind compresses a mixed-class store to byte-identical planes
        // (packed codes, params, channel scales, fp16 rows).  d_head is
        // deliberately not a multiple of the 8-wide f32 blocks so the
        // SIMD rows exercise their scalar tails.
        let lay = CacheLayout { layers: 2, heads: 2, seq: 33, d_head: 10 };
        let (k, v) = caches(lay);
        let classes: Vec<PrecisionClass> = (0..29)
            .map(|t| match t % 5 {
                0 => PrecisionClass::Bits(4),
                1 => PrecisionClass::Fp16,
                2 => PrecisionClass::Evicted,
                3 => PrecisionClass::Bits(1),
                _ => PrecisionClass::Bits(2),
            })
            .collect();
        let base = CompressedKV::compress_with_kind(
            &k, &v, lay, &classes, QuantSpec::default(), kernel::Kind::Scalar);
        for &kind in kernel::compiled_kinds() {
            if !kernel::available(kind) {
                continue;
            }
            let c = CompressedKV::compress_with_kind(
                &k, &v, lay, &classes, QuantSpec::default(), kind);
            assert_eq!(c.content_digest(), base.content_digest(), "{kind:?}");
        }
    }

    #[test]
    fn subset_quantization_uses_subset_stats() {
        // A salient token with a huge outlier must not degrade regular
        // tokens' quantization (the Split in Alg. 2).
        let lay = CacheLayout { layers: 1, heads: 1, seq: 8, d_head: 4 };
        let mut k = vec![0.1f32; lay.cache_len()];
        let v = k.clone();
        // token 0 is an outlier and salient
        for j in 0..4 {
            k[j] = 100.0;
        }
        let mut classes = vec![PrecisionClass::Bits(2); 8];
        classes[0] = PrecisionClass::Bits(4);
        let c = CompressedKV::compress(&k, &v, lay, &classes, QuantSpec::default());
        let mut ko = vec![0f32; lay.cache_len()];
        let mut vo = vec![0f32; lay.cache_len()];
        let mut valid = vec![0f32; 8];
        c.materialize_into(&mut ko, &mut vo, &mut valid);
        // regular tokens (constant 0.1) quantized on their own stats -> exact
        for t in 1..8 {
            for j in 0..4 {
                assert!((ko[t * 4 + j] - 0.1).abs() < 1e-6, "t={t} j={j}");
            }
        }
    }
}
