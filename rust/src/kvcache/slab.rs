//! Bounded pool of dense materialization slots (DESIGN.md §10).
//!
//! ZipCache's residency story is that the *compressed* cache is what
//! lives in memory; the dense fp32 `[L, H, S, dh]` buffers the decode
//! artifact consumes are a transient working set.  This module makes
//! that physical: a shard owns one [`SlotPool`] of at most
//! `memory.slots` reusable [`DenseSlot`]s (default `max_batch`), a
//! session *checks a slot out* while it is scheduled for decode and
//! returns it when parked, and shard dense memory is therefore bounded
//! by `slots x slot_bytes` regardless of how many sessions are live.
//!
//! Ownership rules (DESIGN.md §10): a slot is either in the pool's free
//! list or moved by value into exactly one `Session`'s
//! `Residency::Dense`; there is no aliasing and no index indirection.
//! A [`DenseSlot`] carries a handle back to its home pool and returns
//! its buffers on `Drop`, so a dropped session — an error path, a bench
//! that never calls `Engine::finish`, a torn-down shard — can never
//! leak pool capacity.  Buffers are zeroed on the way back in, so a
//! freshly acquired slot always satisfies the session buffer invariant
//! (rows beyond the live prefix are neutral — DESIGN.md §9) that
//! `CompressedKV::materialize_into_scratch` relies on.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::kvcache::CacheLayout;

/// The buffer payload that cycles through a pool's free list.
#[derive(Debug, Default)]
struct SlotBufs {
    kbuf: Vec<f32>,
    vbuf: Vec<f32>,
    valid: Vec<f32>,
}

impl SlotBufs {
    fn new(layout: CacheLayout) -> Self {
        let n = layout.cache_len();
        SlotBufs {
            kbuf: vec![0f32; n],
            vbuf: vec![0f32; n],
            valid: vec![0f32; layout.seq],
        }
    }
}

/// Pool state shared with every checked-out slot (so `Drop` can find
/// the way home).  The mutex is uncontended — one engine thread checks
/// slots in and out; slot traffic is the cold park/admission path.
#[derive(Debug)]
struct PoolShared {
    free: Mutex<Vec<SlotBufs>>,
    // lint: gauge — checked-out slot count; inc at `acquire`, dec in
    // `DenseSlot::drop`.
    in_use: AtomicUsize,
    peak_in_use: AtomicUsize,
}

/// One dense materialization target: the fp32 K/V caches plus the
/// validity mask, exactly the borrowed inputs of the decode artifact.
/// Returns itself to its home pool on drop (zeroed).
#[derive(Debug)]
pub struct DenseSlot {
    /// Materialized fp32 caches, `[L, H, S, dh]`.
    pub kbuf: Vec<f32>,
    pub vbuf: Vec<f32>,
    /// Validity mask (1.0 = live row; 0 = evicted or empty).
    pub valid: Vec<f32>,
    home: Arc<PoolShared>,
}

impl DenseSlot {
    /// Physical bytes of this slot (two fp32 caches + the mask).
    pub fn bytes(&self) -> usize {
        (self.kbuf.len() + self.vbuf.len() + self.valid.len()) * 4
    }
}

impl Drop for DenseSlot {
    fn drop(&mut self) {
        let mut bufs = SlotBufs {
            kbuf: std::mem::take(&mut self.kbuf),
            vbuf: std::mem::take(&mut self.vbuf),
            valid: std::mem::take(&mut self.valid),
        };
        // Zero on the way in (the cold path) so acquire hands out
        // buffers already satisfying the neutral-rows invariant.
        bufs.kbuf.fill(0.0);
        bufs.vbuf.fill(0.0);
        bufs.valid.fill(0.0);
        self.home.in_use.fetch_sub(1, Ordering::Relaxed);
        self.home.free.lock().expect("slot pool poisoned").push(bufs);
    }
}

/// Bounded free-list of [`DenseSlot`]s for one shard/engine.
///
/// Slots are allocated lazily (first `capacity` acquires), so a
/// single-session caller over a large pool never pays for slots it does
/// not touch; `peak_in_use` records the high-water mark the
/// memory-residency bench asserts against.
#[derive(Debug)]
pub struct SlotPool {
    layout: CacheLayout,
    capacity: usize,
    shared: Arc<PoolShared>,
}

impl SlotPool {
    pub fn new(capacity: usize, layout: CacheLayout) -> Self {
        assert!(capacity >= 1, "slot pool needs at least one slot");
        SlotPool {
            layout,
            capacity,
            shared: Arc::new(PoolShared {
                free: Mutex::new(Vec::new()),
                in_use: AtomicUsize::new(0),
                peak_in_use: AtomicUsize::new(0),
            }),
        }
    }

    /// Check a zeroed slot out of the pool; `None` when every slot is in
    /// use (the caller must park a session first).
    pub fn acquire(&mut self) -> Option<DenseSlot> {
        let bufs = {
            let mut free = self.shared.free.lock().expect("slot pool poisoned");
            match free.pop() {
                Some(b) => b,
                None if self.in_use() < self.capacity => SlotBufs::new(self.layout),
                None => return None,
            }
        };
        let now = self.shared.in_use.fetch_add(1, Ordering::Relaxed) + 1;
        self.shared.peak_in_use.fetch_max(now, Ordering::Relaxed);
        Some(DenseSlot {
            kbuf: bufs.kbuf,
            vbuf: bufs.vbuf,
            valid: bufs.valid,
            home: self.shared.clone(),
        })
    }

    /// Return a slot to the pool explicitly.  Equivalent to dropping it
    /// (the `Drop` impl does the actual return), kept as the engine's
    /// named release point with a layout sanity check.
    pub fn release(&mut self, slot: DenseSlot) {
        debug_assert_eq!(slot.kbuf.len(), self.layout.cache_len(),
                         "released slot has a foreign layout");
        drop(slot);
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn in_use(&self) -> usize {
        self.shared.in_use.load(Ordering::Relaxed)
    }

    /// Slots acquirable right now.
    pub fn available(&self) -> usize {
        self.capacity - self.in_use()
    }

    /// High-water mark of concurrently checked-out slots.
    pub fn peak_in_use(&self) -> usize {
        self.shared.peak_in_use.load(Ordering::Relaxed)
    }

    /// Bytes of one dense slot under this pool's layout.
    pub fn slot_bytes(&self) -> usize {
        (2 * self.layout.cache_len() + self.layout.seq) * 4
    }
}

/// Worst-case resident bytes of one session with an `n_tokens` live
/// window — the admission bound the dispatcher's byte budget reserves
/// against (DESIGN.md §10).
///
/// The bound covers the compressed-resident state of a *parked* session
/// (dense slots are bounded separately by the pool and are not part of
/// the per-request budget):
///
/// * every token at the largest precision class, `Fp16` (2 B/value for
///   K and V — quantized classes store strictly less *payload* per row
///   at the paper's granularities): `fp16_baseline_bytes(n_tokens)`;
/// * quantization-parameter slack per plane and side, covering the
///   densest parameterization any engine class mix can produce.
///   Row-wise pairs: Token/CST granularity costs one `(s, z)` pair per
///   row; `Group(g)` costs `ceil(d_head / g)` pairs per row, and the
///   smallest group any engine policy uses is 32 (GEAR/KIVI), so rows
///   are charged `ceil(d_head / 32)` pairs each.  Subset-fixed params:
///   each precision class quantizes as its own subset plane with its
///   own parameters, and `PrecisionClass::Bits` admits 4 distinct
///   widths ({1, 2, 4, 8}), so up to 4 subsets of channelwise pairs
///   (`2 * d_head` values) plus CST channel scales (`d_head` values)
///   each — `12 * d_head` total.  The per-subset term is what keeps the
///   bound an upper bound at *small* `n`, where fixed per-subset
///   channel params dominate the payload;
/// * the per-token class/validity metadata sidecar (1 B/token,
///   `CompressedKV::metadata_bytes`);
/// * the fp32 uncompressed tail of rows appended since the last
///   recompression cycle, at most `recompress_every` rows.
///
/// Per-request quantization overrides (`QuantOverride`, DESIGN.md §11)
/// never break this bound: an override only re-mixes
/// `PrecisionClass::Bits` widths within {1, 2, 4, 8} and the saliency
/// split, and the bound already charges the engine maximum on both axes
/// — fp16 payload (2 B/value, strictly above the widest 8-bit override
/// payload at every granularity) and the densest 4-subset class mix in
/// the params term.  The dispatcher therefore reserves the same
/// conservative figure for every request regardless of override
/// (pinned by `override_bits_stay_under_worst_case_bound` in
/// `kvcache::store` and the hand-computed 8-bit layout test beside
/// PR-4's 22 B pin).
pub fn worst_case_resident_bytes(
    layout: CacheLayout,
    n_tokens: usize,
    recompress_every: usize,
) -> usize {
    let planes = layout.layers * layout.heads;
    let payload = layout.fp16_baseline_bytes(n_tokens);
    let row_pair_values = 2 * n_tokens * layout.d_head.div_ceil(32).max(1);
    let params = 2 * planes * (row_pair_values + 12 * layout.d_head) * 2;
    let metadata = n_tokens;
    let tail = 2 * planes * recompress_every.min(n_tokens) * layout.d_head * 4;
    payload + params + metadata + tail
}

/// Per-token shrink of the admission reservation on a prefix hit
/// (DESIGN.md §16): `worst_case_resident_bytes` charges every token 2
/// B/value fp16 K/V payload, but under an all-quantized policy (GEAR /
/// MiKV / ZipCache assign only `PrecisionClass::Bits(<= 8)`) no token's
/// *payload* ever exceeds 1 B/value — half the fp16 charge — so the
/// dispatcher can safely hand back half the payload charge for each
/// covered token.  The bound is a policy-wide property, not a
/// hit-outcome property: it stays sound even if the probed hit
/// evaporates before the session starts (eviction race, redelivery to a
/// cold shard), because the session's actual payload obeys the same
/// per-token ceiling either way.  Policies that can assign `Fp16`
/// classes (fp16 / H2O / KIVI windows) get no shrink — the caller
/// passes 0.  Params/metadata/tail slack in the worst-case bound is
/// never shrunk.
pub fn prefix_reservation_shrink(layout: CacheLayout) -> usize {
    layout.fp16_baseline_bytes(1) / 2
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layout() -> CacheLayout {
        CacheLayout { layers: 2, heads: 2, seq: 8, d_head: 4 }
    }

    #[test]
    fn pool_bounds_and_recycles() {
        let mut p = SlotPool::new(2, layout());
        assert_eq!(p.available(), 2);
        let a = p.acquire().unwrap();
        let b = p.acquire().unwrap();
        assert!(p.acquire().is_none(), "pool exceeded its bound");
        assert_eq!((p.in_use(), p.available()), (2, 0));
        p.release(a);
        let c = p.acquire().unwrap();
        assert_eq!(p.peak_in_use(), 2);
        p.release(b);
        p.release(c);
        assert_eq!(p.in_use(), 0);
    }

    #[test]
    fn dropped_slot_returns_to_pool() {
        // A Session dropped without Engine::finish/park must not leak
        // pool capacity: the slot's Drop impl returns the buffers.
        let mut p = SlotPool::new(1, layout());
        let s = p.acquire().unwrap();
        assert_eq!(p.available(), 0);
        drop(s);
        assert_eq!((p.in_use(), p.available()), (0, 1));
        let s = p.acquire().unwrap();
        assert!(s.kbuf.iter().all(|&x| x == 0.0), "recycled slot not zeroed");
        drop(s);
        assert_eq!(p.peak_in_use(), 1);
    }

    #[test]
    fn released_slots_come_back_zeroed() {
        let mut p = SlotPool::new(1, layout());
        let mut s = p.acquire().unwrap();
        s.kbuf[3] = 7.0;
        s.vbuf[0] = -1.0;
        s.valid[2] = 1.0;
        p.release(s);
        let s = p.acquire().unwrap();
        assert!(s.kbuf.iter().all(|&x| x == 0.0));
        assert!(s.vbuf.iter().all(|&x| x == 0.0));
        assert!(s.valid.iter().all(|&x| x == 0.0));
        p.release(s);
    }

    #[test]
    fn slot_bytes_match_layout() {
        let lay = layout();
        let mut p = SlotPool::new(1, lay);
        let s = p.acquire().unwrap();
        assert_eq!(s.bytes(), p.slot_bytes());
        assert_eq!(s.bytes(), (2 * lay.cache_len() + lay.seq) * 4);
        p.release(s);
    }

    #[test]
    fn worst_case_dominates_fp16_payload_and_grows() {
        let lay = layout();
        let w4 = worst_case_resident_bytes(lay, 4, 100);
        let w8 = worst_case_resident_bytes(lay, 8, 100);
        assert!(w4 > lay.fp16_baseline_bytes(4));
        assert!(w8 > w4, "bound must grow with the window");
    }

    #[test]
    fn prefix_shrink_stays_under_the_bound_growth() {
        // Shrinking `covered` tokens off a reservation must never push
        // it below the worst case of the remaining window under an
        // all-Bits policy: the shrink is exactly half the per-token
        // fp16 payload charge, and 8-bit payload is exactly half of
        // fp16 at every granularity, so bound(n) - covered * shrink
        // still dominates payload(n at 8 bit) + full slack.
        let lay = layout();
        let shrink = prefix_reservation_shrink(lay);
        assert_eq!(shrink, lay.fp16_baseline_bytes(1) / 2);
        let n = 8usize;
        for covered in 0..n {
            let reserved = worst_case_resident_bytes(lay, n, 4) - covered * shrink;
            // 8-bit payload for all n tokens (1 B/value K and V).
            let widest_payload = lay.fp16_baseline_bytes(n) / 2;
            let slack = worst_case_resident_bytes(lay, n, 4)
                - lay.fp16_baseline_bytes(n);
            assert!(reserved >= widest_payload + slack,
                    "covered={covered}: shrunk reservation {reserved} below \
                     all-8-bit worst case {}", widest_payload + slack);
        }
    }

    #[test]
    fn worst_case_dominates_actual_storage_at_small_n() {
        // The short-window regime is where fixed per-subset channel
        // params dominate the payload: a two-class mix on a 2-token
        // window must still come in under the bound (the original
        // formula counted channel params once, not per subset, and was
        // NOT an upper bound here).
        use crate::kvcache::{CompressedKV, PrecisionClass, QuantSpec};
        let lay = CacheLayout { layers: 2, heads: 4, seq: 64, d_head: 16 };
        let k: Vec<f32> = (0..lay.cache_len()).map(|i| (i as f32 * 0.13).sin()).collect();
        let v: Vec<f32> = (0..lay.cache_len()).map(|i| (i as f32 * 0.29).cos()).collect();
        for n in 1..=6usize {
            // Worst realistic mix: alternate the two widest classes so
            // every plane carries two fully-parameterized subsets.
            let classes: Vec<PrecisionClass> = (0..n)
                .map(|t| if t % 2 == 0 { PrecisionClass::Bits(8) } else { PrecisionClass::Bits(4) })
                .collect();
            let c = CompressedKV::compress(&k, &v, lay, &classes, QuantSpec::default());
            let bound = worst_case_resident_bytes(lay, n, 100);
            assert!(
                c.resident_bytes() <= bound,
                "n={n}: resident {} exceeds bound {bound}",
                c.resident_bytes()
            );
        }
    }
}
