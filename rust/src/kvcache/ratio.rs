//! Closed-form compression-ratio formulas from the paper's Appendix A, plus
//! the mixed-precision generalization used by Tables 3/A/B.
//!
//! These are *analytic* ratios over the paper's accounting conventions
//! (FP16 baseline, quantization parameters stored at 16 bits each); the
//! physical store ([`super::store::CompressedKV`]) reports its own measured
//! ratios, and the two agree on matched configurations (see tests).

/// Shape parameters of the appendix calculations.
#[derive(Debug, Clone, Copy)]
pub struct RatioShape {
    /// batch size `b`
    pub b: usize,
    /// `h * d` (heads x head-dim, the hidden width of K or V)
    pub hd: usize,
    /// sequence length `l`
    pub l: usize,
}

impl RatioShape {
    /// The appendix's worked example: b=8, hd=l=4096.
    pub fn paper_example() -> Self {
        RatioShape { b: 8, hd: 4096, l: 4096 }
    }

    /// Total FP16 bits of the dense K+V cache: `2 * b*hd*l * 16`.
    fn baseline_bits(&self) -> f64 {
        2.0 * (self.b * self.hd * self.l) as f64 * 16.0
    }
}

/// Eq. (A): groupwise quantization at `bits` with group size `n`.
/// `R = 2*bhld*16 / (2*bhld*k + (4*bhld/n)*16)`.
pub fn groupwise(shape: RatioShape, bits: u32, n: usize) -> f64 {
    let bhld = (shape.b * shape.hd * shape.l) as f64;
    let data = 2.0 * bhld * bits as f64;
    let params = (4.0 * bhld / n as f64) * 16.0;
    shape.baseline_bits() / (data + params)
}

/// Eq. (B): tokenwise quantization at `bits`.
/// `R = 2*bhld*16 / (2*bhld*k + 4*bl*16)`.
pub fn tokenwise(shape: RatioShape, bits: u32) -> f64 {
    let bhld = (shape.b * shape.hd * shape.l) as f64;
    let data = 2.0 * bhld * bits as f64;
    let params = 4.0 * (shape.b * shape.l) as f64 * 16.0;
    shape.baseline_bits() / (data + params)
}

/// Eq. (C): the paper's baseline — channelwise keys + channel-separable
/// tokenwise values. `R = 2*bhld*16 / (2*bhld*k + 3*hd*16 + 2*bl*16)`.
pub fn zipcache_baseline(shape: RatioShape, bits: u32) -> f64 {
    let bhld = (shape.b * shape.hd * shape.l) as f64;
    let data = 2.0 * bhld * bits as f64;
    let params = 3.0 * shape.hd as f64 * 16.0 + 2.0 * (shape.b * shape.l) as f64 * 16.0;
    shape.baseline_bits() / (data + params)
}

/// Channelwise K + plain tokenwise V (Table 1's third row):
/// params = 2*hd + 2*bl pairs.
pub fn channel_token(shape: RatioShape, bits: u32) -> f64 {
    let bhld = (shape.b * shape.hd * shape.l) as f64;
    let data = 2.0 * bhld * bits as f64;
    let params = 2.0 * shape.hd as f64 * 16.0 + 2.0 * (shape.b * shape.l) as f64 * 16.0;
    shape.baseline_bits() / (data + params)
}

/// Mixed-precision ratio for the adaptive methods (Tables 3/A/B):
/// a `saliency_ratio` fraction of tokens at `hi` bits, the rest at `lo`
/// bits (lo = 0 encodes eviction), with the ZipCache parameter overhead.
pub fn mixed_precision(shape: RatioShape, hi: u32, lo: u32, saliency_ratio: f64) -> f64 {
    let bhld = (shape.b * shape.hd * shape.l) as f64;
    let eff_bits = saliency_ratio * hi as f64 + (1.0 - saliency_ratio) * lo as f64;
    let data = 2.0 * bhld * eff_bits;
    // params for the two partitions (each quantized separately):
    // channelwise K (hd pairs) + CST V (bl pairs + hd scales) per partition.
    let live = if lo == 0 { saliency_ratio } else { 1.0 };
    let params = 2.0 * (3.0 * shape.hd as f64 * 16.0)
        + 2.0 * (shape.b as f64 * shape.l as f64 * live) * 16.0;
    shape.baseline_bits() / (data + params)
}

/// H2O-style eviction keeping `keep_ratio` tokens at fp16: `R = 1/keep`.
pub fn eviction(keep_ratio: f64) -> f64 {
    1.0 / keep_ratio
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn appendix_a_exact_values() {
        let s = RatioShape::paper_example();
        // The paper prints 3.200, 3.992, 3.995 for 4-bit / n=32.
        assert!((groupwise(s, 4, 32) - 3.200).abs() < 5e-4, "{}", groupwise(s, 4, 32));
        assert!((tokenwise(s, 4) - 3.992).abs() < 5e-4, "{}", tokenwise(s, 4));
        assert!((zipcache_baseline(s, 4) - 3.995).abs() < 5e-4,
                "{}", zipcache_baseline(s, 4));
    }

    #[test]
    fn table1_ratio_column() {
        // Table 1 prints 3.2x / 3.99x / 4.00x / 4.00x (rounded).
        let s = RatioShape::paper_example();
        assert_eq!(format!("{:.1}", groupwise(s, 4, 32)), "3.2");
        assert_eq!(format!("{:.2}", tokenwise(s, 4)), "3.99");
        assert_eq!(format!("{:.2}", channel_token(s, 4)), "4.00");
        assert_eq!(format!("{:.2}", zipcache_baseline(s, 4)), "4.00");
    }

    #[test]
    fn mixed_precision_matches_headline_numbers() {
        // Table 3: l=840, 4/2 bits, 60% salient -> ~4.98x (paper prints 4.98).
        let s = RatioShape { b: 1, hd: 4096, l: 840 };
        let r = mixed_precision(s, 4, 2, 0.60);
        assert!((r - 4.98).abs() < 0.08, "{r}");
        // 70% salient -> 4.69x
        let r = mixed_precision(s, 4, 2, 0.70);
        assert!((r - 4.69).abs() < 0.08, "{r}");
    }

    #[test]
    fn eviction_ratio() {
        assert!((eviction(0.4) - 2.5).abs() < 1e-9);
    }

    #[test]
    fn monotonicity() {
        let s = RatioShape::paper_example();
        assert!(zipcache_baseline(s, 2) > zipcache_baseline(s, 4));
        assert!(mixed_precision(s, 4, 2, 0.2) > mixed_precision(s, 4, 2, 0.8));
        assert!(groupwise(s, 4, 64) > groupwise(s, 4, 32));
    }
}
