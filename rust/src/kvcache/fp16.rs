//! Minimal IEEE-754 binary16 conversion (no external crate).
//!
//! Used for the `Fp16` precision class (KIVI's full-precision recent window
//! and the FP16 baseline): values round-trip through real half precision so
//! fidelity measurements are honest, and storage is accounted at 2 bytes.

/// f32 -> f16 bit pattern (round-to-nearest-even, IEEE semantics).
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let mant = bits & 0x007F_FFFF;

    if exp == 0xFF {
        // Inf / NaN
        let m = if mant != 0 { 0x0200 } else { 0 };
        return sign | 0x7C00 | m;
    }
    // Re-bias exponent: f32 bias 127 -> f16 bias 15.
    let e = exp - 127 + 15;
    if e >= 0x1F {
        return sign | 0x7C00; // overflow -> inf
    }
    if e <= 0 {
        // subnormal or zero
        if e < -10 {
            return sign;
        }
        let m = mant | 0x0080_0000; // implicit bit
        let shift = (14 - e) as u32;
        let half = 1u32 << (shift - 1);
        let mut v = m >> shift;
        // round to nearest even
        if (m & (half | (half - 1))) > half || ((m & half) != 0 && (v & 1) != 0) {
            v += 1;
        }
        return sign | v as u16;
    }
    let mut v = ((e as u32) << 10) | (mant >> 13);
    // round mantissa
    let rem = mant & 0x1FFF;
    if rem > 0x1000 || (rem == 0x1000 && (v & 1) != 0) {
        v += 1; // may carry into exponent; that is correct behaviour
    }
    sign | v as u16
}

/// f16 bit pattern -> f32.
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let mant = (h & 0x03FF) as u32;
    let bits = if exp == 0 {
        if mant == 0 {
            sign
        } else {
            // subnormal: value = mant * 2^-24; normalize to 1.f * 2^(-14-shifts)
            let mut shifts = 0i32;
            let mut m = mant;
            while m & 0x0400 == 0 {
                m <<= 1;
                shifts += 1;
            }
            let m = (m & 0x03FF) << 13;
            let e = (127 - 14 - shifts) as u32;
            sign | (e << 23) | m
        }
    } else if exp == 0x1F {
        sign | 0x7F80_0000 | (mant << 13)
    } else {
        sign | ((exp + 127 - 15) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

/// Round an f32 through half precision (the `Fp16` class fidelity model).
#[inline]
pub fn round_f16(x: f32) -> f32 {
    f16_bits_to_f32(f32_to_f16_bits(x))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_values_roundtrip() {
        for &v in &[0.0f32, 1.0, -1.0, 0.5, 2.0, 65504.0, -65504.0, 0.099976] {
            let r = round_f16(v);
            assert!((r - v).abs() <= v.abs() * 0.001 + 1e-7, "{v} -> {r}");
        }
    }

    #[test]
    fn relative_error_within_half_ulp() {
        for i in 0..1000 {
            let v = (i as f32 * 0.713).sin() * 100.0;
            let r = round_f16(v);
            assert!((r - v).abs() <= v.abs() * (1.0 / 1024.0) + 1e-6, "{v} {r}");
        }
    }

    #[test]
    fn overflow_to_inf() {
        assert!(round_f16(1e6).is_infinite());
        assert!(round_f16(-1e6).is_infinite());
    }

    #[test]
    fn subnormals() {
        let v = 3.0e-6f32;
        let r = round_f16(v);
        assert!(r > 0.0 && (r - v).abs() < 1e-6);
    }

    #[test]
    fn nan_stays_nan() {
        assert!(round_f16(f32::NAN).is_nan());
    }
}
