//! Mixed-precision KV cache management (the paper's storage contribution).
//!
//! The cache for one sequence is held *physically compressed*
//! (DESIGN.md §4): per
//! (layer, head) plane, token rows are partitioned by [`PrecisionClass`]
//! (salient → high bits, regular → low bits, plus `Fp16` for KIVI-style
//! windows and `Evicted` for H2O-style dropping), each partition quantized
//! separately exactly as Alg. 2's `Split -> ChannelQuant/CSTQuant ->
//! Concat`.  Keys default to channelwise quantization and values to
//! channel-separable tokenwise quantization (§5.1).
//!
//! [`store::CompressedKV`] owns the packed bytes and the accounting;
//! [`ratio`] reproduces the paper's Appendix-A compression-ratio formulas
//! exactly (unit-tested against the printed 3.200 / 3.992 / 3.995);
//! [`slab`] bounds the dense fp32 working set with a pool of reusable
//! materialization slots so the compressed form is what stays resident
//! (DESIGN.md §10); [`segment`] + [`prefix_store`] intern immutable
//! shared-prefix granules so sessions forked from a common prompt skip
//! the covered prefill span entirely (DESIGN.md §16).

pub mod fp16;
pub mod prefix_store;
pub mod ratio;
pub mod segment;
pub mod slab;
pub mod store;

pub use prefix_store::PrefixStore;
pub use segment::{CompressedSegment, PrefixHit, SegmentKey, SegmentRef};
pub use slab::{prefix_reservation_shrink, worst_case_resident_bytes, DenseSlot,
               SlotPool};
pub use store::{CacheLayout, CompressScratch, CompressStats, CompressedKV,
                PrecisionClass, QuantSpec};
