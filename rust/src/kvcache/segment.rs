//! Immutable shared-prefix segments (DESIGN.md §16).
//!
//! A [`CompressedSegment`] is one interned granule of a shared prompt
//! prefix: the *exact* dense fp32 K/V prefill rows for token positions
//! `[start, end)`, keyed by the rolling content hash of every token up
//! to `end` plus the model and quantization policy.  Segments are
//! created once by the first (cold) session to prefill the prefix and
//! are **never mutated afterwards** — a warm session copies the rows
//! into its own pinned `DenseSlot` and every write it ever performs
//! (quantization, recompression, decode appends) lands in
//! session-private state.  That is the copy-on-write contract: forks
//! diverge by appending, shared history is frozen.
//!
//! Why exact fp32 rows and not packed quantized planes?  ZipCache's
//! quantization parameters are per-(layer, head, class) subset
//! statistics over the *request's* saliency partition, and saliency is
//! a function of the full prompt (and, on the flash path, of the
//! probe positions derived from the request seed).  Two requests that
//! share a prefix but differ in their tails therefore assign different
//! classes and different quant params to the same prefix tokens —
//! packed planes can never be shared bit-identically.  The dense
//! prefill rows, by contrast, are a pure function of `(token,
//! position)` per position, so the shared span *is* bitwise stable
//! across requests.  Sharing them trades memory dedup for prefill
//! compute dedup: the warm win is the skipped prefill work (the
//! paper's dominant serving cost), while each session still compresses
//! its full span privately and pays its own compressed footprint.
//!
//! Reclamation is deferred via `Arc`: the store's eviction only drops
//! its own map entry; live [`SegmentRef`]s keep the payload alive until
//! the last reader drops, at which point [`CompressedSegment::drop`]
//! releases the `shared_bytes` gauge.  Readers never block eviction and
//! eviction never invalidates a reader.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use crate::config::PolicyKind;
use crate::kvcache::store::CacheLayout;

/// Identity of one interned segment (DESIGN.md §16): the rolling FNV-1a
/// hash of the token prefix through this segment's end boundary, plus
/// the model and quantization-policy coordinates.  The hash chain
/// commits to the *entire* prefix (each boundary hash extends the
/// previous one), so equal keys imply equal token history, not merely
/// equal granule content.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct SegmentKey {
    /// Rolling FNV-1a over `tokens[0 .. end]` (little-endian u16 bytes).
    pub content_hash: u64,
    /// Model name — row values are model-keyed.
    pub model: String,
    /// Policy kind the segment was interned under.  The fp32 payload is
    /// policy-independent, but keying on the policy keeps the store
    /// partitioned the way compressed cold-tier segments will need.
    pub policy: PolicyKind,
}

/// Gauges shared by the store and every outstanding segment / ref, so
/// deferred reclamation can release byte accounting at the true end of
/// life (last `Arc` drop), not at map removal (DESIGN.md §16).
#[derive(Debug, Default)]
pub struct SegmentGauges {
    // lint: gauge — payload bytes of live interned segments on this
    // shard; inc at `PrefixStore::intern`, dec in
    // `CompressedSegment::drop` (deferred reclamation).
    pub(crate) shared_bytes: AtomicUsize,
    // lint: gauge — interned map entries; inc at `PrefixStore::intern`,
    // dec at eviction / `evict_all` map removal.
    pub(crate) seg_entries: AtomicUsize,
    // lint: gauge — outstanding `SegmentRef` handles across all
    // sessions; inc at `SegmentRef::new` / `clone`, dec in
    // `SegmentRef::drop`.
    pub(crate) seg_refs: AtomicUsize,
}

impl SegmentGauges {
    pub fn shared_bytes(&self) -> usize {
        self.shared_bytes.load(Ordering::SeqCst)
    }
    pub fn entries(&self) -> usize {
        self.seg_entries.load(Ordering::SeqCst)
    }
    pub fn refs(&self) -> usize {
        self.seg_refs.load(Ordering::SeqCst)
    }
}

/// One immutable interned prefix granule: dense `[layers, heads, span,
/// d_head]` K/V rows for token positions `[start, end)` (see the module
/// docs for why the shared form is the exact fp32 rows).  The name
/// keeps the subsystem's unit-of-sharing term even though the payload
/// is the pre-compression form: it is the segment the *compressed*
/// session view is assembled from, and the cold-tier ROADMAP item
/// entropy-codes exactly these immutable payloads.
pub struct CompressedSegment {
    pub key: SegmentKey,
    /// First token position covered (inclusive).
    pub start: usize,
    /// One past the last token position covered.
    pub end: usize,
    /// Dense K rows, `[layers, heads, end - start, d_head]`.
    k_rows: Vec<f32>,
    /// Dense V rows, same shape.
    v_rows: Vec<f32>,
    /// Payload bytes charged to `shared_bytes` (k + v).
    bytes: usize,
    gauges: Arc<SegmentGauges>,
}

impl CompressedSegment {
    /// Intern-side constructor: copies the `[start, end)` rows out of a
    /// dense `[layers, heads, seq, d_head]` slot buffer pair and charges
    /// `shared_bytes`.  Only `PrefixStore::intern` calls this.
    pub(crate) fn from_slot(key: SegmentKey, start: usize, end: usize,
                            kbuf: &[f32], vbuf: &[f32], layout: &CacheLayout,
                            gauges: Arc<SegmentGauges>) -> Self {
        debug_assert!(start < end && end <= layout.seq);
        let (planes, dh, smax) =
            (layout.layers * layout.heads, layout.d_head, layout.seq);
        let span = end - start;
        let mut k_rows = vec![0f32; planes * span * dh];
        let mut v_rows = vec![0f32; planes * span * dh];
        for p in 0..planes {
            let src = p * smax * dh + start * dh;
            let dst = p * span * dh;
            k_rows[dst..dst + span * dh]
                .copy_from_slice(&kbuf[src..src + span * dh]);
            v_rows[dst..dst + span * dh]
                .copy_from_slice(&vbuf[src..src + span * dh]);
        }
        let bytes = (k_rows.len() + v_rows.len()) * std::mem::size_of::<f32>();
        gauges.shared_bytes.fetch_add(bytes, Ordering::SeqCst);
        CompressedSegment { key, start, end, k_rows, v_rows, bytes, gauges }
    }

    /// Number of token positions covered.
    pub fn span(&self) -> usize {
        self.end - self.start
    }

    /// Payload bytes charged to the `shared_bytes` gauge.
    pub fn bytes(&self) -> usize {
        self.bytes
    }

    /// Copy the rows back into a dense `[layers, heads, seq, d_head]`
    /// slot buffer pair at their home positions — the warm-path inverse
    /// of [`Self::from_slot`], bitwise (fp32 moves, no arithmetic).
    pub fn materialize_into(&self, kbuf: &mut [f32], vbuf: &mut [f32],
                            layout: &CacheLayout) {
        let (planes, dh, smax) =
            (layout.layers * layout.heads, layout.d_head, layout.seq);
        let span = self.span();
        debug_assert!(self.end <= smax);
        for p in 0..planes {
            let src = p * span * dh;
            let dst = p * smax * dh + self.start * dh;
            kbuf[dst..dst + span * dh]
                .copy_from_slice(&self.k_rows[src..src + span * dh]);
            vbuf[dst..dst + span * dh]
                .copy_from_slice(&self.v_rows[src..src + span * dh]);
        }
    }
}

impl Drop for CompressedSegment {
    /// Deferred reclamation endpoint: the payload's byte charge is
    /// released only when the last `Arc` (store entry or live reader)
    /// drops, so eviction under concurrent readers leaks nothing and
    /// frees nothing early.
    fn drop(&mut self) {
        self.gauges.shared_bytes.fetch_sub(self.bytes, Ordering::SeqCst);
    }
}

impl std::fmt::Debug for CompressedSegment {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("CompressedSegment")
            .field("hash", &format_args!("{:016x}", self.key.content_hash))
            .field("range", &(self.start..self.end))
            .field("bytes", &self.bytes)
            .finish()
    }
}

/// A counted read handle on an interned segment.  Cloning and dropping
/// adjust the store's `seg_refs` gauge, so the churn tests can assert
/// that eviction plus session teardown drains every handle; the payload
/// itself lives as long as any handle does (deferred reclamation).
pub struct SegmentRef {
    seg: Arc<CompressedSegment>,
}

impl SegmentRef {
    pub(crate) fn new(seg: Arc<CompressedSegment>) -> Self {
        seg.gauges.seg_refs.fetch_add(1, Ordering::SeqCst);
        SegmentRef { seg }
    }

    pub fn segment(&self) -> &CompressedSegment {
        &self.seg
    }
}

impl Clone for SegmentRef {
    fn clone(&self) -> Self {
        SegmentRef::new(Arc::clone(&self.seg))
    }
}

impl Drop for SegmentRef {
    fn drop(&mut self) {
        self.seg.gauges.seg_refs.fetch_sub(1, Ordering::SeqCst);
    }
}

impl std::fmt::Debug for SegmentRef {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "SegmentRef({:016x}, {}..{})",
               self.seg.key.content_hash, self.seg.start, self.seg.end)
    }
}

/// A resolved prefix hit travelling with a request: the pinned segment
/// chain plus the covered token count (`covered` = sum of spans, always
/// `<= prompt_len - 1` so the last prompt token is prefilled privately).
/// Dropping the hit (request shed, cancel, redelivery) releases the
/// refs; cloning pins them again — both through [`SegmentRef`]'s
/// counted handles.
#[derive(Debug, Clone, Default)]
pub struct PrefixHit {
    pub segs: Vec<SegmentRef>,
    pub covered: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layout() -> CacheLayout {
        CacheLayout { layers: 2, heads: 3, seq: 16, d_head: 4 }
    }

    fn key(h: u64) -> SegmentKey {
        SegmentKey { content_hash: h, model: "micro".into(),
                     policy: PolicyKind::Zipcache }
    }

    #[test]
    fn from_slot_roundtrips_bitwise() {
        let lay = layout();
        let g = Arc::new(SegmentGauges::default());
        let n = lay.cache_len();
        let kbuf: Vec<f32> = (0..n).map(|i| i as f32 * 0.5 - 3.0).collect();
        let vbuf: Vec<f32> = (0..n).map(|i| (i as f32).sin()).collect();
        let seg = CompressedSegment::from_slot(key(7), 2, 9, &kbuf, &vbuf,
                                               &lay, Arc::clone(&g));
        assert_eq!(seg.span(), 7);
        assert_eq!(g.shared_bytes(), seg.bytes());
        let mut k2 = vec![0f32; n];
        let mut v2 = vec![0f32; n];
        seg.materialize_into(&mut k2, &mut v2, &lay);
        let (dh, smax) = (lay.d_head, lay.seq);
        for p in 0..lay.layers * lay.heads {
            for pos in 0..smax {
                let off = p * smax * dh + pos * dh;
                if (2..9).contains(&pos) {
                    assert_eq!(&k2[off..off + dh], &kbuf[off..off + dh]);
                    assert_eq!(&v2[off..off + dh], &vbuf[off..off + dh]);
                } else {
                    assert!(k2[off..off + dh].iter().all(|&x| x == 0.0));
                }
            }
        }
        drop(seg);
        assert_eq!(g.shared_bytes(), 0, "drop must release the byte charge");
    }

    #[test]
    fn refs_gauge_balances_across_clones() {
        let lay = layout();
        let g = Arc::new(SegmentGauges::default());
        let buf = vec![1f32; lay.cache_len()];
        let seg = Arc::new(CompressedSegment::from_slot(
            key(1), 0, 4, &buf, &buf, &lay, Arc::clone(&g)));
        let r1 = SegmentRef::new(Arc::clone(&seg));
        assert_eq!(g.refs(), 1);
        let r2 = r1.clone();
        let r3 = r2.clone();
        assert_eq!(g.refs(), 3);
        drop(r1);
        drop(seg);
        assert_eq!(g.refs(), 2);
        assert!(g.shared_bytes() > 0,
                "live refs keep the payload (deferred reclamation)");
        drop((r2, r3));
        assert_eq!(g.refs(), 0);
        assert_eq!(g.shared_bytes(), 0);
    }

    #[test]
    fn keys_commit_to_policy_and_model() {
        let a = key(5);
        let mut b = key(5);
        assert_eq!(a, b);
        b.policy = PolicyKind::Gear;
        assert_ne!(a, b);
        let mut c = key(5);
        c.model = "tiny".into();
        assert_ne!(a, c);
    }
}
