//! Shard-level content-addressed prefix store (DESIGN.md §16).
//!
//! Interns the immutable [`CompressedSegment`] granules of shared
//! prompt prefixes, keyed by a **rolling FNV-1a hash chain** over the
//! token stream: the boundary hash at token `end` extends the boundary
//! hash at the previous granule, so one key commits to the *entire*
//! prefix, and a lookup is a walk along the chain that stops at the
//! first missing link.  Boundaries are aligned to the prefill granule
//! (`scheduler.prefill_chunk`, or a fixed default when prefill is
//! monolithic) and always stop at or before `prompt_len - 1`: the last
//! prompt token is never covered, so every session — warm or cold —
//! runs at least one private prefill step and the monolithic epilogue
//! (probe selection over the full prompt, final compression) is
//! replicated exactly.
//!
//! Concurrency: one mutex around the intern map (poison-recovered —
//! the map holds plain data, any consistent view is safe), `Arc`
//! payloads for deferred reclamation, and atomic gauges shared with the
//! segments themselves.  Eviction (LRU, enforced against
//! `prefix.max_bytes`) removes map entries only; live readers keep
//! their pinned payloads until drop, so readers never block eviction
//! and eviction never invalidates a reader (DESIGN.md §16).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

use crate::config::PolicyKind;
use crate::kvcache::segment::{CompressedSegment, PrefixHit, SegmentGauges,
                              SegmentKey, SegmentRef};
use crate::kvcache::store::CacheLayout;

/// Granule when prefill is monolithic (`scheduler.prefill_chunk == 0`):
/// boundaries still need an alignment rule so hits survive a chunk-size
/// reconfiguration to 0 and bare-engine runs.
pub const DEFAULT_GRANULE: usize = 8;

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Extend a rolling FNV-1a hash with the little-endian bytes of a token
/// run — the chain step of the boundary-hash rule.
fn fnv_extend(mut h: u64, tokens: &[u16]) -> u64 {
    for &t in tokens {
        for b in t.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(FNV_PRIME);
        }
    }
    h
}

struct Entry {
    seg: Arc<CompressedSegment>,
    /// LRU clock value at the last lookup/intern touch.
    last_used: u64,
}

/// The per-shard store.  Created once per engine (or shared by the
/// dispatcher across a shard's restarts — the store outlives shard
/// incarnations, which is what makes warm restarts warm).
pub struct PrefixStore {
    model: String,
    policy: PolicyKind,
    granule: usize,
    /// Byte cap on live segment payload (0 = unlimited), enforced
    /// against `shared_bytes` — which includes evicted-but-still-pinned
    /// payloads, because those still occupy memory.
    max_bytes: usize,
    gauges: Arc<SegmentGauges>,
    /// Monotonic LRU clock.
    tick: AtomicU64,
    /// Cumulative map-entry evictions (budget pressure + `evict_all`).
    evictions: AtomicU64,
    map: Mutex<HashMap<SegmentKey, Entry>>,
}

impl PrefixStore {
    /// `granule` must be the shard's prefill chunk size (or
    /// [`DEFAULT_GRANULE`] when prefill is monolithic); `max_bytes == 0`
    /// disables the byte cap.
    pub fn new(model: &str, policy: PolicyKind, granule: usize,
               max_bytes: usize) -> Arc<Self> {
        Arc::new(PrefixStore {
            model: model.to_string(),
            policy,
            granule: granule.max(1),
            max_bytes,
            gauges: Arc::new(SegmentGauges::default()),
            tick: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            map: Mutex::new(HashMap::new()),
        })
    }

    /// Poison-recovered lock: the map holds plain owned data, so a
    /// panicking holder cannot leave it logically torn.
    fn lock(&self) -> MutexGuard<'_, HashMap<SegmentKey, Entry>> {
        self.map.lock().unwrap_or_else(|e| e.into_inner())
    }

    pub fn granule(&self) -> usize {
        self.granule
    }

    pub fn max_bytes(&self) -> usize {
        self.max_bytes
    }

    /// Live payload bytes (interned + evicted-but-pinned), counted once
    /// per shard regardless of how many sessions reference a segment.
    pub fn shared_bytes(&self) -> usize {
        self.gauges.shared_bytes()
    }

    /// Interned map entries.
    pub fn entries(&self) -> usize {
        self.gauges.entries()
    }

    /// Outstanding `SegmentRef` handles.
    pub fn refs(&self) -> usize {
        self.gauges.refs()
    }

    /// Cumulative evictions.
    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::SeqCst)
    }

    /// Walk the boundary-hash chain for `tokens`, yielding
    /// `(key, start, end)` per granule until the closure declines or the
    /// cap (`end <= len - 1`) is reached.
    fn walk(&self, tokens: &[u16],
            mut f: impl FnMut(&SegmentKey, usize, usize) -> bool) {
        let n = tokens.len();
        let mut h = FNV_OFFSET;
        let mut start = 0usize;
        loop {
            let end = start + self.granule;
            if n < 2 || end > n - 1 {
                return; // the last prompt token always stays private
            }
            h = fnv_extend(h, &tokens[start..end]);
            let key = SegmentKey {
                content_hash: h,
                model: self.model.clone(),
                policy: self.policy,
            };
            if !f(&key, start, end) {
                return;
            }
            start = end;
        }
    }

    /// Covered-token count if `tokens` were looked up now — no refs
    /// taken, no LRU touch, no counters.  The dispatcher calls this per
    /// candidate shard for affinity routing and the reservation shrink;
    /// only the chosen shard pays for a real [`Self::lookup`].
    pub fn probe(&self, tokens: &[u16]) -> usize {
        let map = self.lock();
        let mut covered = 0usize;
        self.walk(tokens, |key, _, end| {
            if map.contains_key(key) {
                covered = end;
                true
            } else {
                false
            }
        });
        covered
    }

    /// Resolve the longest interned prefix of `tokens`: pins every
    /// matched segment with a counted [`SegmentRef`] and bumps its LRU
    /// clock.  Returns `None` on a cold prefix (nothing matched).
    pub fn lookup(&self, tokens: &[u16]) -> Option<PrefixHit> {
        let mut map = self.lock();
        let now = self.tick.fetch_add(1, Ordering::SeqCst);
        let mut segs = Vec::new();
        let mut covered = 0usize;
        self.walk(tokens, |key, _, end| match map.get_mut(key) {
            Some(e) => {
                e.last_used = now;
                segs.push(SegmentRef::new(Arc::clone(&e.seg)));
                covered = end;
                true
            }
            None => false,
        });
        if covered == 0 {
            None
        } else {
            Some(PrefixHit { segs, covered })
        }
    }

    /// Intern every missing granule of `tokens` out of a freshly
    /// prefilled dense slot (`kbuf`/`vbuf`, `[layers, heads, seq,
    /// d_head]`): the cold session that just paid for prefill publishes
    /// the exact fp32 rows it computed, then the byte cap is enforced by
    /// LRU eviction.  Existing links are touched, never rewritten —
    /// interned payloads are immutable (the CoW contract).  Returns the
    /// number of segments newly interned.
    pub fn intern(&self, tokens: &[u16], kbuf: &[f32], vbuf: &[f32],
                  layout: &CacheLayout) -> usize {
        let mut map = self.lock();
        let now = self.tick.fetch_add(1, Ordering::SeqCst);
        let mut added = 0usize;
        self.walk(tokens, |key, start, end| {
            match map.get_mut(key) {
                Some(e) => e.last_used = now,
                None => {
                    let seg = Arc::new(CompressedSegment::from_slot(
                        key.clone(), start, end, kbuf, vbuf, layout,
                        Arc::clone(&self.gauges)));
                    self.gauges.seg_entries.fetch_add(1, Ordering::SeqCst);
                    map.insert(key.clone(), Entry { seg, last_used: now });
                    added += 1;
                }
            }
            true
        });
        if self.max_bytes > 0 {
            self.enforce_budget(&mut map);
        }
        added
    }

    /// Evict LRU entries until the live payload fits `max_bytes` (or the
    /// map is empty — pinned evicted payloads may keep `shared_bytes`
    /// high until their readers drop; that memory is genuinely still in
    /// use, so the cap keeps pressing on what the store can control).
    fn enforce_budget(&self, map: &mut HashMap<SegmentKey, Entry>) {
        while self.gauges.shared_bytes() > self.max_bytes && !map.is_empty() {
            let oldest = map
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k.clone())
                .expect("non-empty map has a minimum");
            self.remove_entry(map, &oldest);
        }
    }

    fn remove_entry(&self, map: &mut HashMap<SegmentKey, Entry>,
                    key: &SegmentKey) {
        if map.remove(key).is_some() {
            self.gauges.seg_entries.fetch_sub(1, Ordering::SeqCst);
            self.evictions.fetch_add(1, Ordering::SeqCst);
        }
    }

    /// Drop every interned entry (churn tests, shutdown): payloads with
    /// live readers survive until those readers drop.
    pub fn evict_all(&self) {
        let mut map = self.lock();
        let keys: Vec<SegmentKey> = map.keys().cloned().collect();
        for k in &keys {
            self.remove_entry(&mut map, k);
        }
    }
}

impl std::fmt::Debug for PrefixStore {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PrefixStore")
            .field("model", &self.model)
            .field("policy", &self.policy)
            .field("granule", &self.granule)
            .field("max_bytes", &self.max_bytes)
            .field("entries", &self.entries())
            .field("shared_bytes", &self.shared_bytes())
            .field("refs", &self.refs())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layout() -> CacheLayout {
        CacheLayout { layers: 2, heads: 2, seq: 32, d_head: 4 }
    }

    fn slot_for(tokens: &[u16], lay: &CacheLayout) -> (Vec<f32>, Vec<f32>) {
        // Position-pure pseudo rows, like the sim backend's kv_elem.
        let n = lay.cache_len();
        let mut k = vec![0f32; n];
        let mut v = vec![0f32; n];
        let (dh, smax) = (lay.d_head, lay.seq);
        for p in 0..lay.layers * lay.heads {
            for (pos, &t) in tokens.iter().enumerate() {
                let off = p * smax * dh + pos * dh;
                for c in 0..dh {
                    k[off + c] = (p * 131 + pos * 17 + c + t as usize) as f32;
                    v[off + c] = -(k[off + c]) * 0.5;
                }
            }
        }
        (k, v)
    }

    fn store(granule: usize, max_bytes: usize) -> Arc<PrefixStore> {
        PrefixStore::new("micro", PolicyKind::Zipcache, granule, max_bytes)
    }

    #[test]
    fn boundary_rule_caps_below_last_token() {
        let s = store(4, 0);
        let tokens: Vec<u16> = (0..13).collect();
        let lay = layout();
        let (k, v) = slot_for(&tokens, &lay);
        // Boundaries at 4, 8, 12; 12 <= 13 - 1 so all three intern.
        assert_eq!(s.intern(&tokens, &k, &v, &lay), 3);
        assert_eq!(s.probe(&tokens), 12);
        // A 12-token prompt can only use boundaries <= 11: covered = 8.
        assert_eq!(s.probe(&tokens[..12]), 8);
        // Too short for even one granule + private tail.
        assert_eq!(s.probe(&tokens[..4]), 0);
        assert_eq!(s.probe(&tokens[..1]), 0);
    }

    #[test]
    fn lookup_is_prefix_exact_not_granule_exact() {
        let s = store(4, 0);
        let lay = layout();
        let a: Vec<u16> = (0..13).collect();
        let (k, v) = slot_for(&a, &lay);
        s.intern(&a, &k, &v, &lay);
        // Same first granule, divergent second: only granule 0 hits —
        // the chain hash at boundary 8 commits to tokens[0..8].
        let mut b = a.clone();
        b[6] = 200;
        assert_eq!(s.probe(&b), 4);
        // Divergence inside granule 0: full miss.
        let mut c = a.clone();
        c[0] = 99;
        assert_eq!(s.probe(&c), 0);
        let hit = s.lookup(&a).unwrap();
        assert_eq!(hit.covered, 12);
        assert_eq!(hit.segs.len(), 3);
        assert_eq!(s.refs(), 3);
        drop(hit);
        assert_eq!(s.refs(), 0);
    }

    #[test]
    fn materialized_rows_match_the_interning_slot() {
        let s = store(4, 0);
        let lay = layout();
        let tokens: Vec<u16> = (5..18).collect();
        let (k, v) = slot_for(&tokens, &lay);
        s.intern(&tokens, &k, &v, &lay);
        let hit = s.lookup(&tokens).unwrap();
        let mut k2 = vec![0f32; lay.cache_len()];
        let mut v2 = vec![0f32; lay.cache_len()];
        for r in &hit.segs {
            r.segment().materialize_into(&mut k2, &mut v2, &lay);
        }
        let (dh, smax) = (lay.d_head, lay.seq);
        for p in 0..lay.layers * lay.heads {
            let off = p * smax * dh;
            let cov = hit.covered * dh;
            assert_eq!(&k2[off..off + cov], &k[off..off + cov]);
            assert_eq!(&v2[off..off + cov], &v[off..off + cov]);
        }
    }

    #[test]
    fn lru_eviction_under_byte_cap() {
        let lay = layout();
        // One granule = 2 planes * 2 * 4 tokens * 4 dh * 4 B * 2 (k+v)
        let seg_bytes = 2 * lay.layers * lay.heads * 4 * lay.d_head * 4;
        let s = store(4, 2 * seg_bytes);
        let a: Vec<u16> = (0..9).collect();
        let (ka, va) = slot_for(&a, &lay);
        s.intern(&a, &ka, &va, &lay); // granules 0..4, 4..8
        assert_eq!(s.entries(), 2);
        // Touch prefix a so its first granule is recent.
        s.lookup(&a);
        let b: Vec<u16> = (100..109).collect();
        let (kb, vb) = slot_for(&b, &lay);
        s.intern(&b, &kb, &vb, &lay);
        assert!(s.shared_bytes() <= 2 * seg_bytes,
                "cap must hold: {} > {}", s.shared_bytes(), 2 * seg_bytes);
        assert!(s.evictions() >= 2);
        assert_eq!(s.entries(), 2);
    }

    #[test]
    fn deferred_reclamation_survives_evict_all() {
        let s = store(4, 0);
        let lay = layout();
        let tokens: Vec<u16> = (0..9).collect();
        let (k, v) = slot_for(&tokens, &lay);
        s.intern(&tokens, &k, &v, &lay);
        let hit = s.lookup(&tokens).unwrap();
        let pinned = s.shared_bytes();
        assert!(pinned > 0);
        s.evict_all();
        assert_eq!(s.entries(), 0);
        assert_eq!(s.probe(&tokens), 0, "evicted links must not match");
        // The reader still holds the payload...
        assert_eq!(s.shared_bytes(), pinned);
        let mut k2 = vec![0f32; lay.cache_len()];
        let mut v2 = vec![0f32; lay.cache_len()];
        for r in &hit.segs {
            r.segment().materialize_into(&mut k2, &mut v2, &lay);
        }
        // ...and only its drop releases the bytes: nothing leaks.
        drop(hit);
        assert_eq!(s.shared_bytes(), 0);
        assert_eq!(s.refs(), 0);
    }

    #[test]
    fn reintern_after_eviction_is_bitwise_stable() {
        let s = store(4, 0);
        let lay = layout();
        let tokens: Vec<u16> = (3..16).collect();
        let (k, v) = slot_for(&tokens, &lay);
        s.intern(&tokens, &k, &v, &lay);
        let first = s.lookup(&tokens).unwrap();
        s.evict_all();
        s.intern(&tokens, &k, &v, &lay);
        let second = s.lookup(&tokens).unwrap();
        let mat = |hit: &PrefixHit| {
            let mut k2 = vec![0f32; lay.cache_len()];
            let mut v2 = vec![0f32; lay.cache_len()];
            for r in &hit.segs {
                r.segment().materialize_into(&mut k2, &mut v2, &lay);
            }
            (k2, v2)
        };
        assert_eq!(mat(&first), mat(&second));
    }
}
