//! Lightweight property testing (offline replacement for proptest).
//!
//! [`check`] runs a property over deterministic SplitMix64-generated cases;
//! on failure it reports the failing seed (re-runnable) and attempts a
//! simple size-shrink by re-generating with halved size hints.

use crate::workload::rng::SplitMix64;

/// Deterministic case generator handed to properties.
pub struct Gen {
    pub rng: SplitMix64,
    /// Size hint (shrinks on failure).
    pub size: usize,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi >= lo);
        lo + self.rng.below((hi - lo + 1) as u64) as usize
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (self.rng.unit_f64() as f32) * (hi - lo)
    }

    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.f32_in(lo, hi)).collect()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.below(2) == 1
    }

    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len() as u64) as usize]
    }
}

/// Run `prop` over `cases` generated cases.  Panics with the failing seed
/// and the smallest reproduced size on violation.
pub fn check<F>(name: &str, cases: usize, prop: F)
where
    F: Fn(&mut Gen) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = 0xC0FFEE ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut g = Gen { rng: SplitMix64::new(seed), size: 64 };
        if let Err(msg) = prop(&mut g) {
            // try shrinking the size hint
            let mut min_fail = (64usize, msg.clone());
            let mut size = 32usize;
            while size >= 2 {
                let mut g2 = Gen { rng: SplitMix64::new(seed), size };
                match prop(&mut g2) {
                    Err(m) => {
                        min_fail = (size, m);
                        size /= 2;
                    }
                    Ok(()) => break,
                }
            }
            panic!(
                "property '{name}' failed (case {case}, seed {seed:#x}, \
                 size {}): {}",
                min_fail.0, min_fail.1
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check("add-commutes", 50, |g| {
            let a = g.usize_in(0, 1000);
            let b = g.usize_in(0, 1000);
            if a + b == b + a {
                Ok(())
            } else {
                Err("math broke".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics() {
        check("always-fails", 3, |_| Err("nope".into()));
    }

    #[test]
    fn gen_ranges() {
        let mut g = Gen { rng: SplitMix64::new(1), size: 8 };
        for _ in 0..100 {
            let x = g.usize_in(5, 10);
            assert!((5..=10).contains(&x));
            let f = g.f32_in(-1.0, 1.0);
            assert!((-1.0..=1.0).contains(&f));
        }
        let v = g.vec_f32(16, 0.0, 1.0);
        assert_eq!(v.len(), 16);
    }
}
