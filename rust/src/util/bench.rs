//! Measurement harness (offline replacement for criterion): warmup,
//! fixed-iteration or fixed-duration sampling, robust statistics, and a
//! table printer shared by every paper-reproduction bench.

use std::time::{Duration, Instant};

/// Summary statistics over one measured function.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub samples_ns: Vec<u64>,
}

impl Measurement {
    pub fn mean_ms(&self) -> f64 {
        if self.samples_ns.is_empty() {
            return 0.0;
        }
        self.samples_ns.iter().sum::<u64>() as f64 / self.samples_ns.len() as f64 / 1e6
    }

    pub fn median_ms(&self) -> f64 {
        self.percentile_ms(50.0)
    }

    pub fn percentile_ms(&self, p: f64) -> f64 {
        if self.samples_ns.is_empty() {
            return 0.0;
        }
        let mut v = self.samples_ns.clone();
        v.sort_unstable();
        let idx = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
        v[idx.min(v.len() - 1)] as f64 / 1e6
    }

    pub fn stddev_ms(&self) -> f64 {
        if self.samples_ns.len() < 2 {
            return 0.0;
        }
        let m = self.mean_ms();
        let var = self
            .samples_ns
            .iter()
            .map(|&x| (x as f64 / 1e6 - m).powi(2))
            .sum::<f64>()
            / (self.samples_ns.len() - 1) as f64;
        var.sqrt()
    }
}

/// Benchmark runner with warmup + sample-count control.
pub struct Bencher {
    pub warmup: usize,
    pub samples: usize,
    /// Hard cap on total time per measurement.
    pub max_total: Duration,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { warmup: 2, samples: 10, max_total: Duration::from_secs(60) }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher { warmup: 1, samples: 5, max_total: Duration::from_secs(30) }
    }

    /// Measure `f` (each call is one sample).
    pub fn measure<F: FnMut()>(&self, name: &str, mut f: F) -> Measurement {
        for _ in 0..self.warmup {
            f();
        }
        let mut samples = Vec::with_capacity(self.samples);
        let t_total = Instant::now();
        for _ in 0..self.samples {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_nanos() as u64);
            if t_total.elapsed() > self.max_total {
                break;
            }
        }
        Measurement { name: name.to_string(), samples_ns: samples }
    }
}

/// Prevent the optimizer from discarding a value (criterion's black_box).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Fixed-width table printer for bench reports (paper-table style).
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn to_string(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("| ");
            for (c, w) in cells.iter().zip(widths) {
                line.push_str(&format!("{c:<w$} | ", w = w));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push_str("|");
        for w in &widths {
            out.push_str(&format!("{}-|", "-".repeat(w + 2)));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.to_string());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_counts_samples() {
        let b = Bencher { warmup: 1, samples: 4, max_total: Duration::from_secs(5) };
        let m = b.measure("noop", || {
            black_box(1 + 1);
        });
        assert_eq!(m.samples_ns.len(), 4);
        assert!(m.mean_ms() >= 0.0);
    }

    #[test]
    fn stats_reasonable() {
        let m = Measurement { name: "x".into(),
                              samples_ns: vec![1_000_000, 2_000_000, 3_000_000] };
        assert!((m.mean_ms() - 2.0).abs() < 1e-9);
        assert!((m.median_ms() - 2.0).abs() < 1e-9);
        assert!(m.stddev_ms() > 0.9 && m.stddev_ms() < 1.1);
    }

    #[test]
    fn table_renders() {
        let mut t = Table::new(&["method", "acc"]);
        t.row(&["ZipCache".to_string(), "99.0".to_string()]);
        let s = t.to_string();
        assert!(s.contains("ZipCache"));
        assert!(s.lines().count() == 3);
    }
}
