//! Scoped worker pool for plane-level parallelism (DESIGN.md §5).
//!
//! The offline environment ships no rayon/tokio, so this is a small
//! `std::thread::scope`-based fan-out primitive: [`WorkerPool::run`] maps
//! an index-addressed job list across up to `threads` workers and joins
//! the results **in index order**, so callers see exactly the sequential
//! output regardless of scheduling.  Work is claimed dynamically from an
//! atomic counter (cheap work-stealing without queues), which keeps
//! ragged per-item costs balanced.
//!
//! Threads are spawned per call rather than kept hot: the compression
//! jobs this pool exists for (one `(layer, head)` K/V plane each,
//! Alg. 2/3) run for hundreds of microseconds to milliseconds, so spawn
//! overhead is noise — and a scoped pool needs no `'static` bounds,
//! channels, or shutdown protocol.

use std::sync::atomic::{AtomicUsize, Ordering};

/// A fixed-width scoped worker pool.
///
/// `threads == 1` is the sequential identity: `run` degenerates to a
/// plain in-order map on the calling thread, which is what makes the
/// parallel/sequential parity tests in `rust/tests/parallel_parity.rs`
/// meaningful.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WorkerPool {
    threads: usize,
}

impl WorkerPool {
    /// Build a pool with the given width.  `0` means "one worker per
    /// available core" (the `parallelism = 0` config default).
    pub fn new(parallelism: usize) -> Self {
        let threads = if parallelism == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            parallelism
        };
        WorkerPool { threads: threads.max(1) }
    }

    /// The sequential pool (width 1) — the bit-identical reference path.
    pub fn sequential() -> Self {
        WorkerPool { threads: 1 }
    }

    /// Worker count this pool fans out to.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Evaluate `f(0), f(1), .., f(n-1)` across the pool and return the
    /// results in index order.
    ///
    /// Each index is evaluated exactly once by exactly one worker, and
    /// `f` never observes partial results of other indices — so for any
    /// pure `f` the output is identical to `(0..n).map(f).collect()`,
    /// independent of the pool width.  Panics in `f` propagate.
    pub fn run<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        if self.threads == 1 || n <= 1 {
            return (0..n).map(f).collect();
        }
        let workers = self.threads.min(n);
        let next = AtomicUsize::new(0);
        let parts: Vec<Vec<(usize, T)>> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..workers)
                .map(|_| {
                    scope.spawn(|| {
                        let mut out = Vec::new();
                        loop {
                            let i = next.fetch_add(1, Ordering::Relaxed);
                            if i >= n {
                                break;
                            }
                            out.push((i, f(i)));
                        }
                        out
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("pool worker panicked"))
                .collect()
        });
        let mut items: Vec<(usize, T)> = Vec::with_capacity(n);
        for part in parts {
            items.extend(part);
        }
        items.sort_unstable_by_key(|&(i, _)| i);
        items.into_iter().map(|(_, t)| t).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn matches_sequential_map() {
        let f = |i: usize| (i * i) as u64;
        let want: Vec<u64> = (0..257).map(f).collect();
        for threads in [1, 2, 3, 8, 64] {
            let pool = WorkerPool::new(threads);
            assert_eq!(pool.run(257, f), want, "threads={threads}");
        }
    }

    #[test]
    fn each_index_runs_exactly_once() {
        let calls = AtomicU64::new(0);
        let pool = WorkerPool::new(4);
        let out = pool.run(1000, |i| {
            calls.fetch_add(1, Ordering::Relaxed);
            i
        });
        assert_eq!(calls.load(Ordering::Relaxed), 1000);
        assert_eq!(out, (0..1000).collect::<Vec<_>>());
    }

    #[test]
    fn ragged_workloads_stay_ordered() {
        // Wildly uneven per-item cost must not reorder results.
        let pool = WorkerPool::new(8);
        let out = pool.run(64, |i| {
            if i % 7 == 0 {
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
            i * 3
        });
        assert_eq!(out, (0..64).map(|i| i * 3).collect::<Vec<_>>());
    }

    #[test]
    fn empty_and_single() {
        let pool = WorkerPool::new(4);
        assert_eq!(pool.run(0, |i| i), Vec::<usize>::new());
        assert_eq!(pool.run(1, |i| i + 9), vec![9]);
    }

    #[test]
    fn auto_width_is_positive() {
        assert!(WorkerPool::new(0).threads() >= 1);
        assert_eq!(WorkerPool::sequential().threads(), 1);
        assert_eq!(WorkerPool::new(5).threads(), 5);
    }
}
