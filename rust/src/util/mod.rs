//! From-scratch utility substrates.
//!
//! This build runs fully offline with only `xla` + `anyhow` as external
//! crates, so the usual ecosystem pieces are implemented here (DESIGN.md §6
//! records each substitution):
//!
//! * [`json`]  — minimal JSON parser/serializer (replaces serde_json) for
//!   the artifact manifest and bench reports.
//! * [`kvconf`] — flat `key = value` config-file parser (replaces toml).
//! * [`cli`]   — tiny declarative flag parser (replaces clap).
//! * [`bench`] — measurement harness with warmup/iteration control and
//!   robust statistics (replaces criterion).
//! * [`prop`]  — property-testing loop over SplitMix64-generated inputs
//!   (replaces proptest; shrinks by halving failing sizes).
//! * [`pool`]  — scoped worker pool for plane-level compression
//!   parallelism (replaces rayon; DESIGN.md §5).

pub mod bench;
pub mod cli;
pub mod json;
pub mod kvconf;
pub mod pool;
pub mod prop;
