//! Minimal JSON: a recursive-descent parser + serializer covering the full
//! JSON grammar (objects, arrays, strings with escapes, numbers, booleans,
//! null).  Replaces serde_json in this offline build; used for the artifact
//! manifest and machine-readable bench reports.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{bail, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // -- accessors ---------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that errors with the key name (manifest parsing ergonomics).
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow::anyhow!("missing key '{key}'"))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    // -- serializer ---------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    // -- builders -----------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Json> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        bail!("trailing garbage at byte {}", p.pos);
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Result<u8> {
        let b = self.peek().ok_or_else(|| anyhow::anyhow!("unexpected EOF"))?;
        self.pos += 1;
        Ok(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        let got = self.bump()?;
        if got != b {
            bail!("expected '{}' got '{}' at byte {}", b as char, got as char,
                  self.pos - 1);
        }
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos);
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at byte {}", other.map(|c| c as char),
                           self.pos),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b'}' => return Ok(Json::Obj(m)),
                c => bail!("expected ',' or '}}' got '{}'", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b']' => return Ok(Json::Arr(v)),
                c => bail!("expected ',' or ']' got '{}'", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump()? {
                b'"' => return Ok(s),
                b'\\' => match self.bump()? {
                    b'"' => s.push('"'),
                    b'\\' => s.push('\\'),
                    b'/' => s.push('/'),
                    b'b' => s.push('\u{8}'),
                    b'f' => s.push('\u{c}'),
                    b'n' => s.push('\n'),
                    b'r' => s.push('\r'),
                    b't' => s.push('\t'),
                    b'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump()? as char;
                            code = code * 16
                                + c.to_digit(16)
                                    .ok_or_else(|| anyhow::anyhow!("bad \\u escape"))?;
                        }
                        // Surrogate pairs: join if a high surrogate.
                        if (0xD800..0xDC00).contains(&code) {
                            self.expect(b'\\')?;
                            self.expect(b'u')?;
                            let mut lo = 0u32;
                            for _ in 0..4 {
                                let c = self.bump()? as char;
                                lo = lo * 16
                                    + c.to_digit(16)
                                        .ok_or_else(|| anyhow::anyhow!("bad \\u escape"))?;
                            }
                            code = 0x10000 + ((code - 0xD800) << 10) + (lo - 0xDC00);
                        }
                        s.push(char::from_u32(code)
                            .ok_or_else(|| anyhow::anyhow!("bad codepoint"))?);
                    }
                    c => bail!("bad escape '\\{}'", c as char),
                },
                c if c < 0x20 => bail!("raw control char in string"),
                c => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = if c >= 0xF0 { 4 } else if c >= 0xE0 { 3 } else { 2 };
                        if start + len > self.bytes.len() {
                            bail!("truncated UTF-8");
                        }
                        let chunk = std::str::from_utf8(&self.bytes[start..start + len])?;
                        s.push_str(chunk);
                        self.pos = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse(r#""hi\nthere""#).unwrap(), Json::Str("hi\nthere".into()));
    }

    #[test]
    fn parse_nested() {
        let j = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(j.get("c").unwrap().as_str(), Some("x"));
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].as_f64(), Some(2.0));
        assert!(arr[2].get("b").unwrap().is_null());
    }

    #[test]
    fn roundtrip() {
        let text = r#"{"entries":{"x":{"file":"x.hlo.txt","shape":[1,2]}},"n":42}"#;
        let j = parse(text).unwrap();
        let j2 = parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(parse(r#""é""#).unwrap(), Json::Str("é".into()));
        assert_eq!(parse(r#""😀""#).unwrap(), Json::Str("😀".into()));
        // raw multibyte UTF-8 passes through
        assert_eq!(parse("\"héllo\"").unwrap(), Json::Str("héllo".into()));
    }

    #[test]
    fn errors() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn escaping_in_output() {
        let j = Json::str("a\"b\\c\nd");
        assert_eq!(parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn integer_formatting() {
        assert_eq!(Json::num(42.0).to_string(), "42");
        assert_eq!(Json::num(1.5).to_string(), "1.5");
    }
}
