//! Flat `key = value` config-file parser (offline replacement for toml).
//!
//! Supports comments (`#`), blank lines, quoted strings, and `[section]`
//! headers that prefix keys as `section.key`.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Result};

/// Parsed config: flattened `section.key -> value` map.
#[derive(Debug, Clone, Default)]
pub struct KvConf {
    map: BTreeMap<String, String>,
}

impl KvConf {
    pub fn parse(text: &str) -> Result<Self> {
        let mut map = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
                section = name.trim().to_string();
                continue;
            }
            let Some((k, v)) = line.split_once('=') else {
                bail!("line {}: expected 'key = value', got {raw:?}", lineno + 1);
            };
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            let mut val = v.trim().to_string();
            if val.len() >= 2 && val.starts_with('"') && val.ends_with('"') {
                val = val[1..val.len() - 1].to_string();
            }
            map.insert(key, val);
        }
        Ok(KvConf { map })
    }

    // lint: cold-path — config parsing; name-collides with atomic
    // `load` calls under the lint's name-level resolution (DESIGN.md
    // §13).
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        Self::parse(&std::fs::read_to_string(path)?)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.map.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    pub fn get_u8(&self, key: &str, default: u8) -> Result<u8> {
        match self.get(key) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    pub fn get_bool(&self, key: &str, default: bool) -> Result<bool> {
        match self.get(key) {
            Some("true") | Some("1") | Some("yes") | Some("on") => Ok(true),
            Some("false") | Some("0") | Some("no") | Some("off") => Ok(false),
            Some(v) => bail!("key '{key}': expected a boolean, got {v:?}"),
            None => Ok(default),
        }
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.map.keys().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# engine config
model = "tiny"
seed = 7

[quant]
saliency_ratio = 0.6
bits_high = 4

[scheduler]
max_batch = 8
"#;

    #[test]
    fn parse_sections_and_types() {
        let c = KvConf::parse(SAMPLE).unwrap();
        assert_eq!(c.get("model"), Some("tiny"));
        assert_eq!(c.get_u64("seed", 0).unwrap(), 7);
        assert_eq!(c.get_f64("quant.saliency_ratio", 0.0).unwrap(), 0.6);
        assert_eq!(c.get_u8("quant.bits_high", 0).unwrap(), 4);
        assert_eq!(c.get_usize("scheduler.max_batch", 0).unwrap(), 8);
    }

    #[test]
    fn defaults_for_missing() {
        let c = KvConf::parse("").unwrap();
        assert_eq!(c.get_or("nope", "d"), "d");
        assert_eq!(c.get_f64("nope", 1.5).unwrap(), 1.5);
    }

    #[test]
    fn bad_line_errors() {
        assert!(KvConf::parse("just a line").is_err());
    }

    #[test]
    fn bools_parse_and_default() {
        let c = KvConf::parse("a = true\nb = 0\nc = nonsense\n").unwrap();
        assert!(c.get_bool("a", false).unwrap());
        assert!(!c.get_bool("b", true).unwrap());
        assert!(c.get_bool("c", false).is_err());
        assert!(c.get_bool("missing", true).unwrap());
    }
}
