//! Tiny declarative CLI flag parser (offline replacement for clap).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, positional
//! subcommands, defaults, and auto-generated `--help`.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// One declared flag.
#[derive(Debug, Clone)]
struct FlagSpec {
    name: String,
    help: String,
    default: Option<String>,
    is_bool: bool,
}

/// Declarative argument parser.
#[derive(Debug, Clone)]
pub struct Args {
    program: String,
    about: String,
    flags: Vec<FlagSpec>,
    values: BTreeMap<String, String>,
    positionals: Vec<String>,
}

impl Args {
    pub fn new(program: &str, about: &str) -> Self {
        Args {
            program: program.to_string(),
            about: about.to_string(),
            flags: Vec::new(),
            values: BTreeMap::new(),
            positionals: Vec::new(),
        }
    }

    /// Declare `--name <value>` with a default.
    pub fn flag(mut self, name: &str, default: &str, help: &str) -> Self {
        self.flags.push(FlagSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: Some(default.to_string()),
            is_bool: false,
        });
        self
    }

    /// Declare a boolean `--name` switch (default false).
    pub fn switch(mut self, name: &str, help: &str) -> Self {
        self.flags.push(FlagSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            is_bool: true,
        });
        self
    }

    /// Parse an explicit argv (no program name).  `Err` includes usage.
    pub fn parse_from(mut self, argv: &[String]) -> Result<Self> {
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if a == "--help" || a == "-h" {
                bail!("{}", self.usage());
            }
            if let Some(stripped) = a.strip_prefix("--") {
                let (name, inline) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .flags
                    .iter()
                    .find(|f| f.name == name)
                    .ok_or_else(|| {
                        anyhow::anyhow!("unknown flag --{name}\n{}", self.usage())
                    })?
                    .clone();
                let value = if spec.is_bool {
                    inline.unwrap_or_else(|| "true".to_string())
                } else if let Some(v) = inline {
                    v
                } else {
                    i += 1;
                    argv.get(i)
                        .ok_or_else(|| anyhow::anyhow!("--{name} needs a value"))?
                        .clone()
                };
                self.values.insert(name, value);
            } else {
                self.positionals.push(a.clone());
            }
            i += 1;
        }
        Ok(self)
    }

    /// Parse `std::env::args()` (skipping the program name).
    pub fn parse(self) -> Result<Self> {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        self.parse_from(&argv)
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nflags:\n", self.program, self.about);
        for f in &self.flags {
            let d = match (&f.default, f.is_bool) {
                (_, true) => " (switch)".to_string(),
                (Some(d), _) => format!(" (default: {d})"),
                _ => String::new(),
            };
            s.push_str(&format!("  --{:<18} {}{}\n", f.name, f.help, d));
        }
        s
    }

    // -- typed getters -------------------------------------------------------

    // lint: cold-path — CLI parsing; name-collides with slice/map `get`
    // calls under the lint's name-level resolution (DESIGN.md §13).
    pub fn get(&self, name: &str) -> String {
        if let Some(v) = self.values.get(name) {
            return v.clone();
        }
        self.flags
            .iter()
            .find(|f| f.name == name)
            .and_then(|f| f.default.clone())
            .unwrap_or_default()
    }

    pub fn get_bool(&self, name: &str) -> bool {
        matches!(self.values.get(name).map(|s| s.as_str()), Some("true") | Some("1"))
    }

    pub fn get_usize(&self, name: &str) -> Result<usize> {
        Ok(self.get(name).parse()?)
    }

    pub fn get_f64(&self, name: &str) -> Result<f64> {
        Ok(self.get(name).parse()?)
    }

    pub fn get_u64(&self, name: &str) -> Result<u64> {
        Ok(self.get(name).parse()?)
    }

    pub fn positionals(&self) -> &[String] {
        &self.positionals
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn flags_and_defaults() {
        let a = Args::new("t", "test")
            .flag("model", "tiny", "model name")
            .flag("n", "5", "count")
            .parse_from(&argv(&["--model", "micro"]))
            .unwrap();
        assert_eq!(a.get("model"), "micro");
        assert_eq!(a.get_usize("n").unwrap(), 5);
    }

    #[test]
    fn equals_syntax_and_switch() {
        let a = Args::new("t", "test")
            .flag("x", "0", "")
            .switch("verbose", "")
            .parse_from(&argv(&["--x=9", "--verbose", "sub"]))
            .unwrap();
        assert_eq!(a.get("x"), "9");
        assert!(a.get_bool("verbose"));
        assert_eq!(a.positionals(), &["sub".to_string()]);
    }

    #[test]
    fn unknown_flag_errors() {
        let r = Args::new("t", "test").parse_from(&argv(&["--bogus"]));
        assert!(r.is_err());
    }

    #[test]
    fn missing_value_errors() {
        let r = Args::new("t", "t").flag("x", "0", "").parse_from(&argv(&["--x"]));
        assert!(r.is_err());
    }
}
