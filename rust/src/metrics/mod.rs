//! Serving metrics: latency histograms, throughput counters, memory
//! accounting — what the Fig. 6 / Table A benches read out — plus the
//! per-stage compression timers (`Split -> Quant -> Concat`, DESIGN.md §5)
//! that quantify what plane-level parallelism buys on the hot path.

use std::time::Duration;

use crate::kvcache::store::CompressStats;

/// A simple sorted-sample latency recorder (exact percentiles; sample
//  counts here are small enough that O(n log n) is irrelevant).
#[derive(Debug, Clone, Default)]
pub struct LatencyStats {
    samples_us: Vec<u64>,
}

impl LatencyStats {
    pub fn record(&mut self, d: Duration) {
        self.samples_us.push(d.as_micros() as u64);
    }

    pub fn record_us(&mut self, us: u64) {
        self.samples_us.push(us);
    }

    /// Pre-reserve room for `additional` samples.  Recording is an
    /// amortized-O(1) push; callers that must not allocate mid-window
    /// (the steady-state decode bench, DESIGN.md §9) reserve up front.
    pub fn reserve(&mut self, additional: usize) {
        self.samples_us.reserve(additional);
    }

    pub fn count(&self) -> usize {
        self.samples_us.len()
    }

    pub fn mean_ms(&self) -> f64 {
        if self.samples_us.is_empty() {
            return 0.0;
        }
        self.samples_us.iter().sum::<u64>() as f64 / self.samples_us.len() as f64 / 1000.0
    }

    pub fn percentile_ms(&self, p: f64) -> f64 {
        if self.samples_us.is_empty() {
            return 0.0;
        }
        let mut v = self.samples_us.clone();
        v.sort_unstable();
        let idx = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
        v[idx.min(v.len() - 1)] as f64 / 1000.0
    }

    pub fn p50_ms(&self) -> f64 {
        self.percentile_ms(50.0)
    }

    pub fn p99_ms(&self) -> f64 {
        self.percentile_ms(99.0)
    }

    /// Fold another recorder's samples into this one (shard aggregation:
    /// percentiles over the union are exact, not averaged).
    pub fn merge(&mut self, other: &LatencyStats) {
        self.samples_us.extend_from_slice(&other.samples_us);
    }
}

/// Per-stage compression timing across a run: one [`LatencyStats`] per
/// `Split -> Quant -> Concat` stage (Alg. 2/3), recorded at every prefill
/// compression and streaming recompression cycle.
#[derive(Debug, Clone, Default)]
pub struct CompressStageStats {
    /// Split: grouping tokens by precision class.
    pub split: LatencyStats,
    /// Quant: wall-clock of the plane fan-out + join (shrinks with the
    /// `parallelism` knob).
    pub quant_wall: LatencyStats,
    /// Quant: CPU time summed across pool workers (roughly constant in
    /// pool width — `quant_cpu / quant_wall` is the achieved speedup).
    pub quant_cpu: LatencyStats,
    /// Concat: assembling the compressed store.
    pub concat: LatencyStats,
    /// Pool width of the last recorded pass.
    pub threads: usize,
}

impl CompressStageStats {
    pub fn record(&mut self, st: &CompressStats) {
        self.split.record_us(st.split_us);
        self.quant_wall.record_us(st.quant_wall_us);
        self.quant_cpu.record_us(st.quant_cpu_us);
        self.concat.record_us(st.concat_us);
        self.threads = st.threads;
    }

    /// Mean achieved parallel speedup inside the Quant stage
    /// (worker CPU time / fan-out wall time); 1.0 when nothing recorded.
    pub fn mean_quant_speedup(&self) -> f64 {
        let wall = self.quant_wall.mean_ms();
        if wall == 0.0 {
            return 1.0;
        }
        self.quant_cpu.mean_ms() / wall
    }

    /// Fold another shard's stage timings into this one.
    pub fn merge(&mut self, other: &CompressStageStats) {
        self.split.merge(&other.split);
        self.quant_wall.merge(&other.quant_wall);
        self.quant_cpu.merge(&other.quant_cpu);
        self.concat.merge(&other.concat);
        self.threads = self.threads.max(other.threads);
    }
}

/// Aggregated engine metrics for one run.
#[derive(Debug, Clone, Default)]
pub struct EngineMetrics {
    /// Session-level prefill total: one sample per session, covering the
    /// whole prompt pass.  Under chunked prefill (DESIGN.md §12) this is
    /// the *sum of active chunk spans* — inter-chunk queueing time while
    /// the batcher runs decode is excluded, mirroring how `decode`
    /// excludes recompression spans.
    pub prefill: LatencyStats,
    /// Per-chunk prefill latency: one sample per `prefill_chunk` call
    /// (monolithic prefill records nothing here).
    pub prefill_chunk: LatencyStats,
    /// Prefill chunks executed (0 when running monolithic).
    pub prefill_chunks: u64,
    pub decode: LatencyStats,
    pub compress: LatencyStats,
    /// Stage-level breakdown of every compression pass (DESIGN.md §5).
    pub compress_stages: CompressStageStats,
    /// Naturally completed requests (`Eos` / `MaxTokens`) — always equals
    /// the `completed_by_priority` sum; cancelled and deadline-shed
    /// requests are counted only in `cancelled` / `shed_by_priority`.
    pub requests_completed: u64,
    pub tokens_generated: u64,
    /// Sessions started, indexed by `Priority::rank()`
    /// (interactive / batch / background — DESIGN.md §11).
    pub admitted_by_priority: [u64; 3],
    /// Natural completions (`Eos` / `MaxTokens`), by `Priority::rank()`.
    pub completed_by_priority: [u64; 3],
    /// Requests shed with `DeadlineExpired` (at pop time, before ever
    /// holding a slot), by `Priority::rank()`.
    pub shed_by_priority: [u64; 3],
    /// Requests finishing with `Cancelled` (waiting or mid-decode).
    pub cancelled: u64,
    /// Peak compressed-cache bytes across live sequences.
    pub peak_cache_bytes: usize,
    /// FP16-equivalent bytes of the same prefixes (for the ratio).
    pub peak_cache_baseline_bytes: usize,
    /// Bytes currently resident across live sessions (compressed
    /// snapshots + parked tails + checked-out dense slots), as last
    /// recorded by the scheduler (DESIGN.md §10).
    pub resident_bytes: usize,
    /// High-water mark of `resident_bytes` over the run.
    pub peak_resident_bytes: usize,
    /// Sessions parked out of their materialization slot
    /// (`Engine::park` calls; unparks mirror them 1:1 while a session
    /// is live).
    pub park_cycles: u64,
    /// Times this shard was restarted by the supervisor after a panic,
    /// engine error, or severed stall (DESIGN.md §14).
    pub shard_restarts: u64,
    /// Requests that were waiting on a failed shard and were resubmitted
    /// to a live shard (their outputs stay bit-identical — §14).
    pub redelivered: u64,
    /// Live sessions lost to a shard failure: their callers saw
    /// `FinishReason::ShardFailed` with the tokens streamed so far.
    pub failed_sessions: u64,
    /// Sessions admitted warm off the shared-prefix store
    /// (DESIGN.md §16).  Engine-lifetime counter; sums across shards.
    pub prefix_hits: u64,
    /// Sessions admitted with the prefix machinery active but no usable
    /// hit (cold prefill over the whole prompt).
    pub prefix_misses: u64,
    /// Prompt tokens whose prefill compute was skipped by warm hits
    /// (the sum of covered spans — the work the store actually saved).
    pub prefill_tokens_skipped: u64,
    /// Segments LRU-evicted from the shared store to stay inside
    /// `prefix.max_bytes`.  Store-derived snapshot: the supervisor zeros
    /// it in a respawned shard's baseline because the store — unlike the
    /// engine — survives the restart (DESIGN.md §14/§16).
    pub prefix_evictions: u64,
    /// Bytes interned in the shared store right now, counted once per
    /// shard no matter how many sessions pin the segments — the
    /// complement of `resident_bytes`, which deliberately excludes
    /// shared segments (single-count invariant, DESIGN.md §16).
    /// Store-derived snapshot, zeroed like `prefix_evictions` at respawn.
    pub shared_segment_bytes: u64,
}

impl EngineMetrics {
    pub fn record_cache(&mut self, used: usize, baseline: usize) {
        if used > self.peak_cache_bytes {
            self.peak_cache_bytes = used;
            self.peak_cache_baseline_bytes = baseline;
        }
    }

    /// Record the current resident-bytes gauge (and its peak).
    pub fn note_resident(&mut self, bytes: usize) {
        self.resident_bytes = bytes;
        self.peak_resident_bytes = self.peak_resident_bytes.max(bytes);
    }

    /// Record one compression pass's stage timing.
    pub fn record_compress_stages(&mut self, st: &CompressStats) {
        self.compress_stages.record(st);
    }

    pub fn memory_ratio(&self) -> f64 {
        if self.peak_cache_bytes == 0 {
            return 1.0;
        }
        self.peak_cache_baseline_bytes as f64 / self.peak_cache_bytes as f64
    }

    pub fn tokens_per_second(&self, wall: Duration) -> f64 {
        if wall.as_secs_f64() == 0.0 {
            return 0.0;
        }
        self.tokens_generated as f64 / wall.as_secs_f64()
    }

    /// Fold another engine's metrics into this one: histograms take the
    /// sample union, counters sum, and the peak-cache pair follows the
    /// shard with the larger peak (it is a single-sequence high-water
    /// mark, not an additive quantity).
    pub fn merge(&mut self, other: &EngineMetrics) {
        self.prefill.merge(&other.prefill);
        self.prefill_chunk.merge(&other.prefill_chunk);
        self.prefill_chunks += other.prefill_chunks;
        self.decode.merge(&other.decode);
        self.compress.merge(&other.compress);
        self.compress_stages.merge(&other.compress_stages);
        self.requests_completed += other.requests_completed;
        self.tokens_generated += other.tokens_generated;
        for i in 0..3 {
            self.admitted_by_priority[i] += other.admitted_by_priority[i];
            self.completed_by_priority[i] += other.completed_by_priority[i];
            self.shed_by_priority[i] += other.shed_by_priority[i];
        }
        self.cancelled += other.cancelled;
        if other.peak_cache_bytes > self.peak_cache_bytes {
            self.peak_cache_bytes = other.peak_cache_bytes;
            self.peak_cache_baseline_bytes = other.peak_cache_baseline_bytes;
        }
        // Resident gauges are per-shard sums: currents add exactly;
        // the peak sum is an upper bound on the fleet-wide peak (shards
        // need not peak simultaneously).
        self.resident_bytes += other.resident_bytes;
        self.peak_resident_bytes += other.peak_resident_bytes;
        self.park_cycles += other.park_cycles;
        self.shard_restarts += other.shard_restarts;
        self.redelivered += other.redelivered;
        self.failed_sessions += other.failed_sessions;
        self.prefix_hits += other.prefix_hits;
        self.prefix_misses += other.prefix_misses;
        self.prefill_tokens_skipped += other.prefill_tokens_skipped;
        self.prefix_evictions += other.prefix_evictions;
        // Per-shard stores are disjoint, so current shared bytes add
        // exactly — same argument as `resident_bytes`.
        self.shared_segment_bytes += other.shared_segment_bytes;
    }
}

/// A coherent read of a sharded server's metrics (DESIGN.md §8): the
/// per-shard [`EngineMetrics`] as captured, plus their aggregate.  Built
/// by [`MetricsSnapshot::aggregate`]; obtained from a running server via
/// `ServerHandle::metrics`.
#[derive(Debug, Clone, Default)]
pub struct MetricsSnapshot {
    /// Union/sum across shards (histogram percentiles are exact over the
    /// pooled samples).
    pub total: EngineMetrics,
    /// One entry per shard, in shard-index order.
    pub per_shard: Vec<EngineMetrics>,
}

impl MetricsSnapshot {
    pub fn aggregate(per_shard: Vec<EngineMetrics>) -> Self {
        let mut total = EngineMetrics::default();
        for m in &per_shard {
            total.merge(m);
        }
        MetricsSnapshot { total, per_shard }
    }

    pub fn shards(&self) -> usize {
        self.per_shard.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles() {
        let mut s = LatencyStats::default();
        for i in 1..=100u64 {
            s.record_us(i * 1000);
        }
        assert!((s.p50_ms() - 50.0).abs() <= 1.0);
        assert!((s.p99_ms() - 99.0).abs() <= 1.0);
        assert!((s.mean_ms() - 50.5).abs() < 0.01);
        assert_eq!(s.count(), 100);
    }

    #[test]
    fn empty_stats_are_zero() {
        let s = LatencyStats::default();
        assert_eq!(s.mean_ms(), 0.0);
        assert_eq!(s.p99_ms(), 0.0);
    }

    #[test]
    fn cache_peak_tracking() {
        let mut m = EngineMetrics::default();
        m.record_cache(100, 500);
        m.record_cache(50, 400);
        assert_eq!(m.peak_cache_bytes, 100);
        assert_eq!(m.memory_ratio(), 5.0);
    }

    #[test]
    fn stage_stats_record_and_speedup() {
        let mut m = EngineMetrics::default();
        m.record_compress_stages(&CompressStats {
            split_us: 10,
            quant_wall_us: 100,
            quant_cpu_us: 300,
            concat_us: 5,
            wall_us: 120,
            planes: 8,
            threads: 4,
        });
        assert_eq!(m.compress_stages.threads, 4);
        assert_eq!(m.compress_stages.quant_wall.count(), 1);
        assert!((m.compress_stages.mean_quant_speedup() - 3.0).abs() < 1e-9);
    }

    #[test]
    fn resident_gauge_tracks_current_and_peak() {
        let mut m = EngineMetrics::default();
        m.note_resident(500);
        m.note_resident(200);
        assert_eq!(m.resident_bytes, 200);
        assert_eq!(m.peak_resident_bytes, 500);
        let mut other = EngineMetrics::default();
        other.note_resident(300);
        other.park_cycles = 4;
        m.park_cycles = 1;
        m.merge(&other);
        assert_eq!(m.resident_bytes, 500); // current sums across shards
        assert_eq!(m.peak_resident_bytes, 800); // per-shard peak sum
        assert_eq!(m.park_cycles, 5);
    }

    #[test]
    fn priority_and_cancellation_counters_sum_across_shards() {
        let mut a = EngineMetrics::default();
        a.admitted_by_priority = [3, 1, 0];
        a.completed_by_priority = [2, 1, 0];
        a.shed_by_priority = [0, 0, 2];
        a.cancelled = 1;
        let mut b = EngineMetrics::default();
        b.admitted_by_priority = [1, 0, 4];
        b.completed_by_priority = [1, 0, 3];
        b.shed_by_priority = [1, 0, 0];
        b.cancelled = 2;
        a.merge(&b);
        assert_eq!(a.admitted_by_priority, [4, 1, 4]);
        assert_eq!(a.completed_by_priority, [3, 1, 3]);
        assert_eq!(a.shed_by_priority, [1, 0, 2]);
        assert_eq!(a.cancelled, 3);
    }

    #[test]
    fn failure_counters_sum_across_shards() {
        let mut a = EngineMetrics::default();
        a.shard_restarts = 1;
        a.redelivered = 3;
        a.failed_sessions = 2;
        let mut b = EngineMetrics::default();
        b.shard_restarts = 2;
        b.redelivered = 1;
        let snap = MetricsSnapshot::aggregate(vec![a, b]);
        assert_eq!(snap.total.shard_restarts, 3);
        assert_eq!(snap.total.redelivered, 4);
        assert_eq!(snap.total.failed_sessions, 2);
        assert_eq!(snap.per_shard[0].redelivered, 3);
    }

    #[test]
    fn prefill_chunk_stats_merge_across_shards() {
        let mut a = EngineMetrics::default();
        a.prefill.record_us(9_000);
        a.prefill_chunk.record_us(4_000);
        a.prefill_chunk.record_us(5_000);
        a.prefill_chunks = 2;
        let mut b = EngineMetrics::default();
        b.prefill_chunk.record_us(6_000);
        b.prefill_chunks = 1;
        a.merge(&b);
        // Session total stays one-sample-per-session; chunks pool.
        assert_eq!(a.prefill.count(), 1);
        assert_eq!(a.prefill_chunk.count(), 3);
        assert_eq!(a.prefill_chunks, 3);
        assert!((a.prefill_chunk.p50_ms() - 5.0).abs() < 1e-9);
    }

    #[test]
    fn prefix_counters_sum_across_shards() {
        let mut a = EngineMetrics::default();
        a.prefix_hits = 2;
        a.prefix_misses = 1;
        a.prefill_tokens_skipped = 48;
        a.prefix_evictions = 1;
        a.shared_segment_bytes = 1024;
        let mut b = EngineMetrics::default();
        b.prefix_hits = 1;
        b.prefix_misses = 3;
        b.shared_segment_bytes = 512;
        let snap = MetricsSnapshot::aggregate(vec![a, b]);
        assert_eq!(snap.total.prefix_hits, 3);
        assert_eq!(snap.total.prefix_misses, 4);
        assert_eq!(snap.total.prefill_tokens_skipped, 48);
        assert_eq!(snap.total.prefix_evictions, 1);
        // Disjoint per-shard stores: shared bytes sum exactly.
        assert_eq!(snap.total.shared_segment_bytes, 1536);
        assert_eq!(snap.per_shard[1].prefix_misses, 3);
    }

    #[test]
    fn throughput() {
        let mut m = EngineMetrics::default();
        m.tokens_generated = 200;
        assert!((m.tokens_per_second(Duration::from_secs(4)) - 50.0).abs() < 1e-9);
    }

    #[test]
    fn snapshot_aggregates_across_shards() {
        let mut a = EngineMetrics::default();
        a.requests_completed = 3;
        a.tokens_generated = 30;
        a.decode.record_us(1000);
        a.decode.record_us(3000);
        a.record_cache(100, 500);
        let mut b = EngineMetrics::default();
        b.requests_completed = 2;
        b.tokens_generated = 20;
        b.decode.record_us(2000);
        b.record_cache(200, 800);
        let snap = MetricsSnapshot::aggregate(vec![a, b]);
        assert_eq!(snap.shards(), 2);
        assert_eq!(snap.total.requests_completed, 5);
        assert_eq!(snap.total.tokens_generated, 50);
        // pooled samples: exact percentiles over the union
        assert_eq!(snap.total.decode.count(), 3);
        assert!((snap.total.decode.p50_ms() - 2.0).abs() < 1e-9);
        // peak follows the larger shard's pair
        assert_eq!(snap.total.peak_cache_bytes, 200);
        assert_eq!(snap.total.peak_cache_baseline_bytes, 800);
        // per-shard breakdown preserved
        assert_eq!(snap.per_shard[0].requests_completed, 3);
        assert_eq!(snap.per_shard[1].requests_completed, 2);
    }
}
