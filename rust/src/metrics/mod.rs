//! Serving metrics: latency histograms, throughput counters, memory
//! accounting — what the Fig. 6 / Table A benches read out.

use std::time::Duration;

/// A simple sorted-sample latency recorder (exact percentiles; sample
//  counts here are small enough that O(n log n) is irrelevant).
#[derive(Debug, Clone, Default)]
pub struct LatencyStats {
    samples_us: Vec<u64>,
}

impl LatencyStats {
    pub fn record(&mut self, d: Duration) {
        self.samples_us.push(d.as_micros() as u64);
    }

    pub fn record_us(&mut self, us: u64) {
        self.samples_us.push(us);
    }

    pub fn count(&self) -> usize {
        self.samples_us.len()
    }

    pub fn mean_ms(&self) -> f64 {
        if self.samples_us.is_empty() {
            return 0.0;
        }
        self.samples_us.iter().sum::<u64>() as f64 / self.samples_us.len() as f64 / 1000.0
    }

    pub fn percentile_ms(&self, p: f64) -> f64 {
        if self.samples_us.is_empty() {
            return 0.0;
        }
        let mut v = self.samples_us.clone();
        v.sort_unstable();
        let idx = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
        v[idx.min(v.len() - 1)] as f64 / 1000.0
    }

    pub fn p50_ms(&self) -> f64 {
        self.percentile_ms(50.0)
    }

    pub fn p99_ms(&self) -> f64 {
        self.percentile_ms(99.0)
    }
}

/// Aggregated engine metrics for one run.
#[derive(Debug, Clone, Default)]
pub struct EngineMetrics {
    pub prefill: LatencyStats,
    pub decode: LatencyStats,
    pub compress: LatencyStats,
    pub requests_completed: u64,
    pub tokens_generated: u64,
    /// Peak compressed-cache bytes across live sequences.
    pub peak_cache_bytes: usize,
    /// FP16-equivalent bytes of the same prefixes (for the ratio).
    pub peak_cache_baseline_bytes: usize,
}

impl EngineMetrics {
    pub fn record_cache(&mut self, used: usize, baseline: usize) {
        if used > self.peak_cache_bytes {
            self.peak_cache_bytes = used;
            self.peak_cache_baseline_bytes = baseline;
        }
    }

    pub fn memory_ratio(&self) -> f64 {
        if self.peak_cache_bytes == 0 {
            return 1.0;
        }
        self.peak_cache_baseline_bytes as f64 / self.peak_cache_bytes as f64
    }

    pub fn tokens_per_second(&self, wall: Duration) -> f64 {
        if wall.as_secs_f64() == 0.0 {
            return 0.0;
        }
        self.tokens_generated as f64 / wall.as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles() {
        let mut s = LatencyStats::default();
        for i in 1..=100u64 {
            s.record_us(i * 1000);
        }
        assert!((s.p50_ms() - 50.0).abs() <= 1.0);
        assert!((s.p99_ms() - 99.0).abs() <= 1.0);
        assert!((s.mean_ms() - 50.5).abs() < 0.01);
        assert_eq!(s.count(), 100);
    }

    #[test]
    fn empty_stats_are_zero() {
        let s = LatencyStats::default();
        assert_eq!(s.mean_ms(), 0.0);
        assert_eq!(s.p99_ms(), 0.0);
    }

    #[test]
    fn cache_peak_tracking() {
        let mut m = EngineMetrics::default();
        m.record_cache(100, 500);
        m.record_cache(50, 400);
        assert_eq!(m.peak_cache_bytes, 100);
        assert_eq!(m.memory_ratio(), 5.0);
    }

    #[test]
    fn throughput() {
        let mut m = EngineMetrics::default();
        m.tokens_generated = 200;
        assert!((m.tokens_per_second(Duration::from_secs(4)) - 50.0).abs() < 1e-9);
    }
}
