//! Bit-packing of quantization codes (2/4/8-bit) into dense byte buffers.
//!
//! This is where the compression ratio physically comes from: a 2-bit code
//! stream packs 4 codes per byte.  The pack/unpack loops are on the
//! recompression hot path (every 100 generated tokens, Alg. 3), so the
//! byte-aligned fast paths matter; see `benches/hotpath.rs`.

/// Densely packed integer codes with a fixed bit-width.
#[derive(Debug, Clone, PartialEq)]
pub struct PackedCodes {
    pub bits: u8,
    pub len: usize,
    data: Vec<u8>,
}

impl PackedCodes {
    /// Number of code values that fit in one byte.
    #[inline]
    pub fn per_byte(bits: u8) -> usize {
        debug_assert!(matches!(bits, 1 | 2 | 4 | 8), "unsupported bits {bits}");
        8 / bits as usize
    }

    /// Pack `codes` (each `< 2^bits`) into a dense buffer.
    pub fn pack(codes: &[u8], bits: u8) -> Self {
        let pb = Self::per_byte(bits);
        let mut data = vec![0u8; codes.len().div_ceil(pb)];
        match bits {
            8 => data.copy_from_slice(codes),
            4 => {
                // 2 codes/byte: low nibble first.
                for (i, chunk) in codes.chunks(2).enumerate() {
                    let hi = chunk.get(1).copied().unwrap_or(0);
                    data[i] = (chunk[0] & 0x0F) | (hi << 4);
                }
            }
            2 => {
                // 4 codes/byte, little-endian 2-bit lanes.
                for (i, chunk) in codes.chunks(4).enumerate() {
                    let mut b = 0u8;
                    for (j, &c) in chunk.iter().enumerate() {
                        b |= (c & 0x3) << (2 * j);
                    }
                    data[i] = b;
                }
            }
            1 => {
                for (i, chunk) in codes.chunks(8).enumerate() {
                    let mut b = 0u8;
                    for (j, &c) in chunk.iter().enumerate() {
                        b |= (c & 0x1) << j;
                    }
                    data[i] = b;
                }
            }
            _ => unreachable!(),
        }
        PackedCodes { bits, len: codes.len(), data }
    }

    /// Unpack into a fresh vector.
    pub fn unpack(&self) -> Vec<u8> {
        let mut out = vec![0u8; self.len];
        self.unpack_into(&mut out);
        out
    }

    /// Unpack into a caller-provided buffer (len must equal `self.len`).
    ///
    /// Perf note (EXPERIMENTS.md §Perf): the whole-byte fast paths below
    /// replace a per-element `div/mod` indexing scheme; on the 1M-code
    /// recompression workload this is ~3x faster, which matters because
    /// unpack feeds every cache materialization (one per decode
    /// recompression cycle, Alg. 3).
    pub fn unpack_into(&self, out: &mut [u8]) {
        assert_eq!(out.len(), self.len);
        match self.bits {
            8 => out.copy_from_slice(&self.data[..self.len]),
            4 => {
                let full = self.len / 2;
                for (i, &b) in self.data[..full].iter().enumerate() {
                    out[2 * i] = b & 0x0F;
                    out[2 * i + 1] = b >> 4;
                }
                if self.len % 2 == 1 {
                    out[self.len - 1] = self.data[full] & 0x0F;
                }
            }
            2 => {
                let full = self.len / 4;
                for (i, &b) in self.data[..full].iter().enumerate() {
                    let o = &mut out[4 * i..4 * i + 4];
                    o[0] = b & 0x3;
                    o[1] = (b >> 2) & 0x3;
                    o[2] = (b >> 4) & 0x3;
                    o[3] = b >> 6;
                }
                for i in full * 4..self.len {
                    out[i] = (self.data[i / 4] >> (2 * (i % 4))) & 0x3;
                }
            }
            1 => {
                let full = self.len / 8;
                for (i, &b) in self.data[..full].iter().enumerate() {
                    for j in 0..8 {
                        out[8 * i + j] = (b >> j) & 1;
                    }
                }
                for i in full * 8..self.len {
                    out[i] = (self.data[i / 8] >> (i % 8)) & 0x1;
                }
            }
            _ => unreachable!(),
        }
    }

    /// Random access to one code (used by sparse dequant paths).
    #[inline]
    pub fn get(&self, i: usize) -> u8 {
        debug_assert!(i < self.len);
        match self.bits {
            8 => self.data[i],
            4 => {
                let b = self.data[i / 2];
                if i % 2 == 0 { b & 0x0F } else { b >> 4 }
            }
            2 => (self.data[i / 4] >> (2 * (i % 4))) & 0x3,
            1 => (self.data[i / 8] >> (i % 8)) & 0x1,
            _ => unreachable!(),
        }
    }

    /// Bytes of packed payload (the real storage cost of the codes).
    #[inline]
    pub fn storage_bytes(&self) -> usize {
        self.data.len()
    }

    /// The packed payload itself — read-only byte view, used by the
    /// parallel/sequential parity digest (`CompressedKV::content_digest`).
    #[inline]
    pub fn as_bytes(&self) -> &[u8] {
        &self.data
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(bits: u8, n: usize) {
        let max = 1u32 << bits; // up to 256: reduce in u32, then narrow
        let codes: Vec<u8> = (0..n).map(|i| ((i * 7 + 3) as u32 % max) as u8).collect();
        let packed = PackedCodes::pack(&codes, bits);
        assert_eq!(packed.unpack(), codes);
        for (i, &c) in codes.iter().enumerate() {
            assert_eq!(packed.get(i), c, "bits={bits} i={i}");
        }
        assert_eq!(packed.storage_bytes(), n.div_ceil(8 / bits as usize));
    }

    #[test]
    fn roundtrip_all_widths() {
        for bits in [1u8, 2, 4, 8] {
            for n in [0usize, 1, 3, 4, 5, 7, 8, 9, 64, 1000] {
                roundtrip(bits, n);
            }
        }
    }

    #[test]
    fn two_bit_is_quarter_size() {
        let codes = vec![3u8; 4096];
        let p = PackedCodes::pack(&codes, 2);
        assert_eq!(p.storage_bytes(), 1024);
    }
}
