//! Bit-packing of quantization codes (2/4/8-bit) into dense byte buffers.
//!
//! This is where the compression ratio physically comes from: a 2-bit code
//! stream packs 4 codes per byte.  The pack/unpack loops are on the
//! recompression hot path (every 100 generated tokens, Alg. 3), so the
//! byte-aligned fast paths matter; see `benches/hotpath.rs`.
//!
//! Pack/unpack dispatch through the runtime-selected kernel
//! (DESIGN.md §15): the scalar lane loops below are the reference
//! semantics, and the SIMD kinds in `quant/kernel.rs` are pinned
//! bit-identical to them by the parity tests here and in
//! `quant/plane.rs`.

use super::kernel;

/// Densely packed integer codes with a fixed bit-width.
#[derive(Debug, Clone, PartialEq)]
pub struct PackedCodes {
    pub bits: u8,
    pub len: usize,
    data: Vec<u8>,
}

impl PackedCodes {
    /// Number of code values that fit in one byte.
    #[inline]
    pub fn per_byte(bits: u8) -> usize {
        debug_assert!(matches!(bits, 1 | 2 | 4 | 8), "unsupported bits {bits}");
        8 / bits as usize
    }

    /// Pack `codes` (each `< 2^bits`) into a dense buffer with the
    /// process-wide kernel.
    pub fn pack(codes: &[u8], bits: u8) -> Self {
        Self::pack_with(kernel::active(), codes, bits)
    }

    /// Pack with an explicit kernel kind — the parity tests and benches
    /// compare kinds without touching the global selection.
    pub fn pack_with(kind: kernel::Kind, codes: &[u8], bits: u8) -> Self {
        let pb = Self::per_byte(bits);
        let mut data = vec![0u8; codes.len().div_ceil(pb)];
        if kind != kernel::Kind::Scalar {
            kernel::pack_lanes(kind, bits, codes, &mut data);
            return PackedCodes { bits, len: codes.len(), data };
        }
        match bits {
            8 => data.copy_from_slice(codes),
            4 => {
                // 2 codes/byte: low nibble first.
                for (i, chunk) in codes.chunks(2).enumerate() {
                    let hi = chunk.get(1).copied().unwrap_or(0);
                    data[i] = (chunk[0] & 0x0F) | ((hi & 0x0F) << 4);
                }
            }
            2 => {
                // 4 codes/byte, little-endian 2-bit lanes.
                for (i, chunk) in codes.chunks(4).enumerate() {
                    let mut b = 0u8;
                    for (j, &c) in chunk.iter().enumerate() {
                        b |= (c & 0x3) << (2 * j);
                    }
                    data[i] = b;
                }
            }
            1 => {
                for (i, chunk) in codes.chunks(8).enumerate() {
                    let mut b = 0u8;
                    for (j, &c) in chunk.iter().enumerate() {
                        b |= (c & 0x1) << j;
                    }
                    data[i] = b;
                }
            }
            _ => unreachable!(),
        }
        PackedCodes { bits, len: codes.len(), data }
    }

    /// Unpack into a fresh vector.
    pub fn unpack(&self) -> Vec<u8> {
        let mut out = vec![0u8; self.len];
        self.unpack_into(&mut out);
        out
    }

    /// Unpack into a caller-provided buffer (len must equal `self.len`).
    ///
    /// Perf note (EXPERIMENTS.md §Perf): the whole-byte fast paths in
    /// [`PackedCodes::for_each`] replace a per-element `div/mod` indexing
    /// scheme; on the 1M-code recompression workload this is ~3x faster,
    /// which matters because unpack feeds every cache materialization
    /// (one per decode recompression cycle, Alg. 3).
    // lint: hot-path — fused-unpack entry (DESIGN.md §13).
    pub fn unpack_into(&self, out: &mut [u8]) {
        self.unpack_into_with(kernel::active(), out);
    }

    /// [`PackedCodes::unpack_into`] with an explicit kernel kind (the
    /// parity tests and benches compare kinds directly).
    // lint: hot-path — fused-unpack entry, kind-dispatched (DESIGN.md §13).
    pub fn unpack_into_with(&self, kind: kernel::Kind, out: &mut [u8]) {
        assert_eq!(out.len(), self.len);
        if self.bits == 8 {
            out.copy_from_slice(&self.data[..self.len]);
            return;
        }
        if kind == kernel::Kind::Scalar {
            self.for_each(|i, c| out[i] = c);
        } else {
            kernel::unpack_lanes(kind, self.bits, &self.data, out);
        }
    }

    /// Visit every code in index order without materializing the unpacked
    /// buffer — the fused unpack half of the unpack–dequant kernels
    /// (EXPERIMENTS.md §Perf).  Whole bytes are decoded in unrolled lane
    /// order; the ragged tail falls back to shifted extraction.
    // lint: hot-path — fused unpack–dequant inner loop (DESIGN.md §13).
    #[inline]
    pub fn for_each<F: FnMut(usize, u8)>(&self, mut f: F) {
        match self.bits {
            8 => {
                for (i, &b) in self.data[..self.len].iter().enumerate() {
                    f(i, b);
                }
            }
            4 => {
                let full = self.len / 2;
                for (i, &b) in self.data[..full].iter().enumerate() {
                    f(2 * i, b & 0x0F);
                    f(2 * i + 1, b >> 4);
                }
                if self.len % 2 == 1 {
                    f(self.len - 1, self.data[full] & 0x0F);
                }
            }
            2 => {
                let full = self.len / 4;
                for (i, &b) in self.data[..full].iter().enumerate() {
                    f(4 * i, b & 0x3);
                    f(4 * i + 1, (b >> 2) & 0x3);
                    f(4 * i + 2, (b >> 4) & 0x3);
                    f(4 * i + 3, b >> 6);
                }
                for i in full * 4..self.len {
                    f(i, (self.data[i / 4] >> (2 * (i % 4))) & 0x3);
                }
            }
            1 => {
                let full = self.len / 8;
                for (i, &b) in self.data[..full].iter().enumerate() {
                    for j in 0..8 {
                        f(8 * i + j, (b >> j) & 1);
                    }
                }
                for i in full * 8..self.len {
                    f(i, (self.data[i / 8] >> (i % 8)) & 0x1);
                }
            }
            _ => unreachable!(),
        }
    }

    /// Random access to one code (used by sparse dequant paths).
    // lint: hot-path — sparse-path code access (DESIGN.md §13).
    #[inline]
    pub fn get(&self, i: usize) -> u8 {
        debug_assert!(i < self.len);
        match self.bits {
            8 => self.data[i],
            4 => {
                let b = self.data[i / 2];
                if i % 2 == 0 { b & 0x0F } else { b >> 4 }
            }
            2 => (self.data[i / 4] >> (2 * (i % 4))) & 0x3,
            1 => (self.data[i / 8] >> (i % 8)) & 0x1,
            _ => unreachable!(),
        }
    }

    /// Bytes of packed payload (the real storage cost of the codes).
    #[inline]
    pub fn storage_bytes(&self) -> usize {
        self.data.len()
    }

    /// The packed payload itself — read-only byte view, used by the
    /// parallel/sequential parity digest (`CompressedKV::content_digest`).
    #[inline]
    pub fn as_bytes(&self) -> &[u8] {
        &self.data
    }
}

/// Incremental packer: accepts one code at a time and produces the same
/// dense byte stream as [`PackedCodes::pack`] — the fused pack half of the
/// quantize-and-pack encode path (EXPERIMENTS.md §Perf).  Eliminates the
/// unpacked `codes` staging vector the two-pass encoder needed.
#[derive(Debug)]
pub struct PackWriter {
    bits: u8,
    len: usize,
    cur: u8,
    shift: u8,
    data: Vec<u8>,
}

impl PackWriter {
    /// A writer for `n` expected codes at `bits` (capacity hint only —
    /// pushing more than `n` codes still works).
    pub fn with_capacity(bits: u8, n: usize) -> Self {
        let pb = PackedCodes::per_byte(bits);
        PackWriter {
            bits,
            len: 0,
            cur: 0,
            shift: 0,
            data: Vec::with_capacity(n.div_ceil(pb)),
        }
    }

    /// Append one code (`< 2^bits`), low lanes first — the exact lane
    /// order of [`PackedCodes::pack`].
    // lint: hot-path — quantize-as-pack writer (DESIGN.md §13); the
    // amortized `Vec::push` growth is the dynamic bench's concern, not
    // this rule's (see the known-limits list there).
    #[inline]
    pub fn push(&mut self, code: u8) {
        if self.bits == 8 {
            self.data.push(code);
        } else {
            let mask = ((1u16 << self.bits) - 1) as u8;
            self.cur |= (code & mask) << self.shift;
            self.shift += self.bits;
            if self.shift == 8 {
                self.data.push(self.cur);
                self.cur = 0;
                self.shift = 0;
            }
        }
        self.len += 1;
    }

    /// Append a run of codes, producing the exact byte stream of
    /// repeated [`PackWriter::push`].  SIMD kinds pack the byte-aligned
    /// bulk through the kernel layer; the unaligned head (a partially
    /// filled tail byte from earlier pushes) and the ragged tail go
    /// through `push` itself.
    // lint: hot-path — bulk quantize-as-pack writer (DESIGN.md §13);
    // the amortized growth note on `push` applies to `resize` here too.
    #[inline]
    pub fn push_slice(&mut self, kind: kernel::Kind, codes: &[u8]) {
        if self.bits == 8 {
            self.data.extend_from_slice(codes);
            self.len += codes.len();
            return;
        }
        let mut i = 0;
        if kind != kernel::Kind::Scalar {
            while self.shift != 0 && i < codes.len() {
                self.push(codes[i]);
                i += 1;
            }
            let pb = PackedCodes::per_byte(self.bits);
            let bulk = (codes.len() - i) / pb * pb;
            if bulk > 0 {
                let old = self.data.len();
                self.data.resize(old + bulk / pb, 0);
                kernel::pack_lanes(kind, self.bits, &codes[i..i + bulk], &mut self.data[old..]);
                self.len += bulk;
                i += bulk;
            }
        }
        for &c in &codes[i..] {
            self.push(c);
        }
    }

    /// Codes pushed so far.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Flush the partial tail byte and seal the packed stream.
    // lint: hot-path — seals the recompression write (DESIGN.md §13).
    pub fn finish(mut self) -> PackedCodes {
        if self.shift > 0 {
            self.data.push(self.cur);
        }
        PackedCodes { bits: self.bits, len: self.len, data: self.data }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(bits: u8, n: usize) {
        let max = 1u32 << bits; // up to 256: reduce in u32, then narrow
        let codes: Vec<u8> = (0..n).map(|i| ((i * 7 + 3) as u32 % max) as u8).collect();
        let packed = PackedCodes::pack(&codes, bits);
        assert_eq!(packed.unpack(), codes);
        for (i, &c) in codes.iter().enumerate() {
            assert_eq!(packed.get(i), c, "bits={bits} i={i}");
        }
        assert_eq!(packed.storage_bytes(), n.div_ceil(8 / bits as usize));
    }

    #[test]
    fn roundtrip_all_widths() {
        for bits in [1u8, 2, 4, 8] {
            for n in [0usize, 1, 3, 4, 5, 7, 8, 9, 64, 1000] {
                roundtrip(bits, n);
            }
        }
    }

    #[test]
    fn two_bit_is_quarter_size() {
        let codes = vec![3u8; 4096];
        let p = PackedCodes::pack(&codes, 2);
        assert_eq!(p.storage_bytes(), 1024);
    }

    #[test]
    fn writer_matches_pack_bit_for_bit() {
        for bits in [1u8, 2, 4, 8] {
            let max = 1u32 << bits;
            for n in [0usize, 1, 3, 5, 8, 9, 63, 64, 65, 1000] {
                let codes: Vec<u8> =
                    (0..n).map(|i| ((i * 11 + 5) as u32 % max) as u8).collect();
                let two_pass = PackedCodes::pack(&codes, bits);
                let mut w = PackWriter::with_capacity(bits, n);
                for &c in &codes {
                    w.push(c);
                }
                assert_eq!(w.len(), n);
                let streamed = w.finish();
                assert_eq!(streamed, two_pass, "bits={bits} n={n}");
                assert_eq!(streamed.as_bytes(), two_pass.as_bytes());
            }
        }
    }

    #[test]
    fn for_each_visits_every_code_in_order() {
        for bits in [1u8, 2, 4, 8] {
            let max = 1u32 << bits;
            for n in [0usize, 1, 7, 8, 9, 257] {
                let codes: Vec<u8> =
                    (0..n).map(|i| ((i * 13 + 1) as u32 % max) as u8).collect();
                let packed = PackedCodes::pack(&codes, bits);
                let mut seen = Vec::with_capacity(n);
                packed.for_each(|i, c| {
                    assert_eq!(i, seen.len(), "bits={bits} out-of-order index");
                    seen.push(c);
                });
                assert_eq!(seen, codes, "bits={bits} n={n}");
            }
        }
    }

    /// Kinds this machine can execute (always includes Scalar).
    fn kinds() -> Vec<kernel::Kind> {
        kernel::compiled_kinds()
            .iter()
            .copied()
            .filter(|&k| kernel::available(k))
            .collect()
    }

    // Regression: the 4-bit scalar path used to OR the high lane
    // unmasked (`hi << 4`).  For u8 the shift discards the same bits
    // the mask would, so the bug was latent — but the packed stream
    // must stay pinned to the masked semantics of `PackWriter::push`
    // (and of every SIMD kind) even for out-of-range codes, which is
    // exactly the input an upstream bug would produce with
    // debug_assertions off.
    #[test]
    fn out_of_range_codes_pack_like_masked_codes() {
        for bits in [1u8, 2, 4] {
            let mask = (1u8 << bits) - 1;
            for n in [1usize, 2, 3, 16, 31, 257] {
                let wild: Vec<u8> = (0..n).map(|i| (i * 37 + 171) as u8).collect();
                let masked: Vec<u8> = wild.iter().map(|c| c & mask).collect();
                let want = PackedCodes::pack_with(kernel::Kind::Scalar, &masked, bits);
                let mut w = PackWriter::with_capacity(bits, n);
                for &c in &wild {
                    w.push(c);
                }
                assert_eq!(w.finish().as_bytes(), want.as_bytes(), "writer bits={bits} n={n}");
                for k in kinds() {
                    let got = PackedCodes::pack_with(k, &wild, bits);
                    assert_eq!(got.as_bytes(), want.as_bytes(), "bits={bits} n={n} kind={k:?}");
                }
            }
        }
    }

    #[test]
    fn pack_unpack_parity_across_kinds() {
        for bits in [1u8, 2, 4, 8] {
            let max = 1u32 << bits;
            for n in [0usize, 1, 5, 15, 16, 17, 33, 64, 100, 257, 1000] {
                let codes: Vec<u8> = (0..n).map(|i| ((i * 7 + 3) as u32 % max) as u8).collect();
                let base = PackedCodes::pack_with(kernel::Kind::Scalar, &codes, bits);
                for k in kinds() {
                    let p = PackedCodes::pack_with(k, &codes, bits);
                    assert_eq!(p.as_bytes(), base.as_bytes(), "pack bits={bits} n={n} kind={k:?}");
                    let mut out = vec![0u8; n];
                    p.unpack_into_with(k, &mut out);
                    assert_eq!(out, codes, "unpack bits={bits} n={n} kind={k:?}");
                }
            }
        }
    }

    #[test]
    fn push_slice_matches_push_across_kinds() {
        for bits in [1u8, 2, 4, 8] {
            let max = 1u32 << bits;
            for n in [0usize, 1, 7, 16, 33, 100, 257] {
                // Start from an unaligned writer state: 3 pushed codes
                // leave a partial byte for every sub-byte width.
                let head: Vec<u8> = (0..3).map(|i| (i as u32 % max) as u8).collect();
                let body: Vec<u8> = (0..n).map(|i| ((i * 11 + 5) as u32 % max) as u8).collect();
                let mut want = PackWriter::with_capacity(bits, n + 3);
                for &c in head.iter().chain(body.iter()) {
                    want.push(c);
                }
                let want = want.finish();
                for k in kinds() {
                    let mut w = PackWriter::with_capacity(bits, n + 3);
                    for &c in &head {
                        w.push(c);
                    }
                    w.push_slice(k, &body);
                    assert_eq!(w.len(), n + 3);
                    let got = w.finish();
                    assert_eq!(got, want, "bits={bits} n={n} kind={k:?}");
                }
            }
        }
    }
}
