//! Runtime-dispatched SIMD kernels for the quant / saliency hot paths
//! (DESIGN.md §15).
//!
//! A single process-wide kernel [`Kind`] is resolved once at startup —
//! CPU feature detection via `is_x86_feature_detected!`, overridable by
//! the `quant.kernel` config knob / `--quant-kernel` CLI flag /
//! `ZIPCACHE_FORCE_SCALAR` environment variable — and then read with a
//! relaxed atomic load at every hot-path entry: no per-call feature
//! probing and no allocation, preserving the zero-allocation decode
//! contract (DESIGN.md §9).
//!
//! Every vectorized path is pinned **bit-identical** to the scalar
//! fallback: integer lane extraction follows the same little-endian
//! low-lane-first order as `PackWriter::push`, and the f32 kernels
//! apply the exact scalar expression per element in the same operation
//! order (`_mm_round_ps` with the round-to-nearest-even control word
//! matches `f32::round_ties_even`).  Range reductions — the min/max
//! scans and the CST column max-abs — deliberately stay scalar in every
//! kind: vector reassociation could flip the sign of a ±0.0 bound or
//! reorder NaN propagation, which would leak into `QuantParams::zero`
//! and the snapshot content digest.  The parity gates are the
//! per-primitive tests below, the cross-kind property test in
//! `quant/plane.rs`, and the `content_digest` pin in
//! `kvcache/store.rs`.

use std::sync::atomic::{AtomicU8, Ordering};

/// Tile width (in codes / elements) for the stack staging buffers used
/// by the tiled kernels (`codes_to_f32`, the fused encode loops in
/// `quant/plane.rs`).  A multiple of every lane group size (8 codes per
/// byte at 1 bit, 16-code SIMD blocks), so whole tiles never split a
/// packed byte.
pub const TILE: usize = 256;

/// A concrete kernel implementation tier.
///
/// Discriminants start at 1 so the zero-initialised [`ACTIVE`] atomic
/// can use 0 as "not resolved yet".
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum Kind {
    /// Portable scalar code — the reference semantics, always compiled.
    Scalar = 1,
    /// 128-bit x86 kernels (SSE2 integer/f32 lanes, SSE4.1 rounding).
    Sse41 = 2,
    /// 256-bit x86 f32 kernels; integer codecs stay 128-bit.
    Avx2 = 3,
}

impl Kind {
    /// Stable lowercase name for banners, benches, and JSON columns.
    pub fn name(self) -> &'static str {
        match self {
            Kind::Scalar => "scalar",
            Kind::Sse41 => "sse4.1",
            Kind::Avx2 => "avx2",
        }
    }
}

/// The `quant.kernel` knob: how to pick the process-wide [`Kind`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KernelChoice {
    /// Detect the widest available implementation (the default).
    #[default]
    Auto,
    /// Pin the portable scalar path.
    Scalar,
    /// Require a SIMD tier; fails validation on CPUs without one.
    Simd,
}

impl KernelChoice {
    /// Canonical config-file spelling.
    pub fn as_str(self) -> &'static str {
        match self {
            KernelChoice::Auto => "auto",
            KernelChoice::Scalar => "scalar",
            KernelChoice::Simd => "simd",
        }
    }
}

impl std::fmt::Display for KernelChoice {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

impl std::str::FromStr for KernelChoice {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        Ok(match s {
            "auto" => KernelChoice::Auto,
            "scalar" => KernelChoice::Scalar,
            "simd" => KernelChoice::Simd,
            other => anyhow::bail!("unknown quant kernel '{other}' (auto|scalar|simd)"),
        })
    }
}

/// The resolved process-wide kernel; 0 = not resolved yet.
static ACTIVE: AtomicU8 = AtomicU8::new(0);

/// The process-wide kernel: one relaxed load on the hot path.  Falls
/// back to a cold first-use resolution (env override, then feature
/// detection) when `apply_choice` has not run — tests and standalone
/// tools hit that path; the engine resolves explicitly at startup.
// lint: hot-path
#[inline]
pub fn active() -> Kind {
    match ACTIVE.load(Ordering::Relaxed) {
        1 => Kind::Scalar,
        2 => Kind::Sse41,
        3 => Kind::Avx2,
        _ => init_active(),
    }
}

/// First-use resolution, kept out of line so `active()` stays a bare
/// load-and-branch in steady state.
// lint: cold-path
#[cold]
fn init_active() -> Kind {
    let kind = if force_scalar_env() {
        Kind::Scalar
    } else {
        detect_widest()
    };
    ACTIVE.store(kind as u8, Ordering::Relaxed);
    kind
}

/// `ZIPCACHE_FORCE_SCALAR` pins the portable path regardless of the
/// config knob ("" and "0" mean unset, anything else forces scalar).
fn force_scalar_env() -> bool {
    std::env::var_os("ZIPCACHE_FORCE_SCALAR").is_some_and(|v| !v.is_empty() && v != "0")
}

/// Resolve and install the process-wide kernel from the config knob.
/// Called once from `Engine::new` (idempotent across shards — every
/// call installs the same answer for the same inputs).  The env
/// override wins over the knob so deployments can pin the portable
/// path without touching config.
pub fn apply_choice(choice: KernelChoice) -> crate::Result<Kind> {
    let kind = if force_scalar_env() {
        Kind::Scalar
    } else {
        match choice {
            KernelChoice::Auto => detect_widest(),
            KernelChoice::Scalar => Kind::Scalar,
            KernelChoice::Simd => {
                let k = detect_widest();
                anyhow::ensure!(
                    k != Kind::Scalar,
                    "quant.kernel = simd requested but no SIMD kernel is \
                     available on this CPU/arch"
                );
                k
            }
        }
    };
    ACTIVE.store(kind as u8, Ordering::Relaxed);
    Ok(kind)
}

/// Widest implementation the running CPU supports.
fn detect_widest() -> Kind {
    #[cfg(target_arch = "x86_64")]
    {
        if available(Kind::Avx2) {
            return Kind::Avx2;
        }
        if available(Kind::Sse41) {
            return Kind::Sse41;
        }
    }
    Kind::Scalar
}

/// Every kernel tier compiled into this binary (parity tests iterate
/// this, filtered by [`available`]).
pub fn compiled_kinds() -> &'static [Kind] {
    #[cfg(target_arch = "x86_64")]
    {
        &[Kind::Scalar, Kind::Sse41, Kind::Avx2]
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        &[Kind::Scalar]
    }
}

/// Whether `kind` can run on this CPU.  The Avx2 tier also requires
/// SSE4.1 because its encode kernels share the 128-bit narrowing tail.
pub fn available(kind: Kind) -> bool {
    match kind {
        Kind::Scalar => true,
        #[cfg(target_arch = "x86_64")]
        Kind::Sse41 => is_x86_feature_detected!("sse4.1"),
        #[cfg(target_arch = "x86_64")]
        Kind::Avx2 => is_x86_feature_detected!("avx2") && is_x86_feature_detected!("sse4.1"),
        #[cfg(not(target_arch = "x86_64"))]
        _ => false,
    }
}

// ---- const-eval lane-expansion tables (DESIGN.md §15) ---------------------
//
// One table per sub-byte width, indexed by the packed control byte and
// yielding all its codes as a little-endian word — the `vbe_simd`
// idiom.  Used for whole-byte remainders below a 16-byte SIMD block
// (and as the entire 1-bit unpack fallback); built at compile time so
// the hot path is a single indexed load per byte.

const fn build_u4_lut() -> [u16; 256] {
    let mut t = [0u16; 256];
    let mut b = 0usize;
    while b < 256 {
        t[b] = ((b & 0x0F) | ((b >> 4) << 8)) as u16;
        b += 1;
    }
    t
}

const fn build_u2_lut() -> [u32; 256] {
    let mut t = [0u32; 256];
    let mut b = 0usize;
    while b < 256 {
        let mut w = 0u32;
        let mut k = 0;
        while k < 4 {
            w |= (((b >> (2 * k)) & 0x3) as u32) << (8 * k);
            k += 1;
        }
        t[b] = w;
        b += 1;
    }
    t
}

const fn build_u1_lut() -> [u64; 256] {
    let mut t = [0u64; 256];
    let mut b = 0usize;
    while b < 256 {
        let mut w = 0u64;
        let mut i = 0;
        while i < 8 {
            w |= (((b >> i) & 1) as u64) << (8 * i);
            i += 1;
        }
        t[b] = w;
        b += 1;
    }
    t
}

static U4_LUT: [u16; 256] = build_u4_lut();
static U2_LUT: [u32; 256] = build_u2_lut();
static U1_LUT: [u64; 256] = build_u1_lut();

// ---- public dispatchers ---------------------------------------------------
//
// Each dispatcher runs the widest compiled twin for `kind` over a
// SIMD-width prefix (the twin returns how many elements it consumed),
// then finishes with the scalar expression — which is also the entire
// body when `kind == Kind::Scalar` (prefix 0).  The scalar tails below
// ARE the reference semantics: byte-for-byte the same expressions as
// the pre-dispatch code in `quant/packing.rs` / `quant/plane.rs`.

/// Pack one-code-per-byte `codes` into `out` (lane k of each byte holds
/// code k at shift `k * bits`, low lane first — `PackWriter::push`
/// order).  Codes are masked to `bits`, so out-of-range inputs pack the
/// same bytes on every kind.
// lint: hot-path
#[inline]
pub fn pack_lanes(kind: Kind, bits: u8, codes: &[u8], out: &mut [u8]) {
    if bits == 8 {
        out.copy_from_slice(codes);
        return;
    }
    let pb = (8 / bits) as usize;
    debug_assert_eq!(out.len(), codes.len().div_ceil(pb));
    let ci = simd_pack(kind, bits, codes, out);
    let mask = (1u8 << bits) - 1;
    for (k, chunk) in codes[ci..].chunks(pb).enumerate() {
        let mut b = 0u8;
        for (j, &c) in chunk.iter().enumerate() {
            b |= (c & mask) << (j as u8 * bits);
        }
        out[ci / pb + k] = b;
    }
}

/// Unpack `out.len()` codes from the packed bytes in `data` (inverse of
/// [`pack_lanes`], same lane order).  Whole-byte remainders below a
/// 16-byte SIMD block go through the const lane-expansion tables; the
/// final partial byte uses the shifted-extraction scalar loop.
// lint: hot-path
#[inline]
pub fn unpack_lanes(kind: Kind, bits: u8, data: &[u8], out: &mut [u8]) {
    if bits == 8 {
        out.copy_from_slice(&data[..out.len()]);
        return;
    }
    let pb = (8 / bits) as usize;
    let nb = out.len() / pb;
    let bi = simd_unpack(kind, bits, &data[..nb], out);
    match bits {
        4 => {
            for i in bi..nb {
                let w = U4_LUT[data[i] as usize].to_le_bytes();
                out[i * 2..i * 2 + 2].copy_from_slice(&w);
            }
        }
        2 => {
            for i in bi..nb {
                let w = U2_LUT[data[i] as usize].to_le_bytes();
                out[i * 4..i * 4 + 4].copy_from_slice(&w);
            }
        }
        _ => {
            for i in bi..nb {
                let w = U1_LUT[data[i] as usize].to_le_bytes();
                out[i * 8..i * 8 + 8].copy_from_slice(&w);
            }
        }
    }
    let done = nb * pb;
    if done < out.len() {
        let b = data[nb];
        let mask = (1u8 << bits) - 1;
        for k in 0..(out.len() - done) {
            out[done + k] = (b >> (k as u8 * bits)) & mask;
        }
    }
}

/// Unpack + widen packed codes straight to f32 (`c as f32` is exact for
/// u8), tiled through a fixed stack buffer — no allocation.
// lint: hot-path
#[inline]
pub fn codes_to_f32(kind: Kind, bits: u8, data: &[u8], out: &mut [f32]) {
    if bits == 8 {
        u8_to_f32(kind, &data[..out.len()], out);
        return;
    }
    let pb = (8 / bits) as usize;
    let mut buf = [0u8; TILE];
    let mut done = 0usize;
    while done < out.len() {
        // `done` stays a multiple of TILE (itself a multiple of every
        // per-byte lane count), so `done / pb` is exact.
        let n = TILE.min(out.len() - done);
        unpack_lanes(kind, bits, &data[done / pb..], &mut buf[..n]);
        u8_to_f32(kind, &buf[..n], &mut out[done..done + n]);
        done += n;
    }
}

/// Widen u8 codes to f32.
// lint: hot-path
#[inline]
pub fn u8_to_f32(kind: Kind, src: &[u8], out: &mut [f32]) {
    debug_assert_eq!(src.len(), out.len());
    let n = simd_u8_to_f32(kind, src, out);
    for j in n..src.len() {
        out[j] = src[j] as f32;
    }
}

/// In-place affine: `x = (x - zero) * scale` — `QuantParams::decode`
/// applied to pre-widened codes.
// lint: hot-path
#[inline]
pub fn affine_inplace(kind: Kind, xs: &mut [f32], zero: f32, scale: f32) {
    let n = simd_affine(kind, xs, zero, scale);
    for x in &mut xs[n..] {
        *x = (*x - zero) * scale;
    }
}

/// In-place affine with a per-column factor:
/// `x[j] = (x[j] - zero) * scale * chan[j]` — the CST decode row.
// lint: hot-path
#[inline]
pub fn affine_mul_inplace(kind: Kind, xs: &mut [f32], zero: f32, scale: f32, chan: &[f32]) {
    debug_assert_eq!(xs.len(), chan.len());
    let n = simd_affine_mul(kind, xs, zero, scale, chan);
    for j in n..xs.len() {
        xs[j] = (xs[j] - zero) * scale * chan[j];
    }
}

/// In-place affine with per-column params:
/// `x[j] = (x[j] - zeros[j]) * scales[j]` — the Channel decode row.
// lint: hot-path
#[inline]
pub fn affine_cols_inplace(kind: Kind, xs: &mut [f32], scales: &[f32], zeros: &[f32]) {
    debug_assert_eq!(xs.len(), scales.len());
    debug_assert_eq!(xs.len(), zeros.len());
    let n = simd_affine_cols(kind, xs, scales, zeros);
    for j in n..xs.len() {
        xs[j] = (xs[j] - zeros[j]) * scales[j];
    }
}

/// Fused encode with a hoisted reciprocal scale (the Token / CST row
/// loop): `out[j] = ((src[j] * inv_s).round_ties_even() + zero)
/// .clamp(0.0, qmax) as u8`.
// lint: hot-path
#[inline]
pub fn encode_mul(kind: Kind, src: &[f32], inv_s: f32, zero: f32, qmax: f32, out: &mut [u8]) {
    debug_assert_eq!(src.len(), out.len());
    let n = simd_encode_mul(kind, src, inv_s, zero, qmax, out);
    for j in n..src.len() {
        out[j] = ((src[j] * inv_s).round_ties_even() + zero).clamp(0.0, qmax) as u8;
    }
}

/// Fused encode dividing by the scale (`QuantParams::encode` order, the
/// Group segment loop): `out[j] = ((src[j] / scale).round_ties_even()
/// + zero).clamp(0.0, qmax) as u8`.
// lint: hot-path
#[inline]
pub fn encode_div(kind: Kind, src: &[f32], scale: f32, zero: f32, qmax: f32, out: &mut [u8]) {
    debug_assert_eq!(src.len(), out.len());
    let n = simd_encode_div(kind, src, scale, zero, qmax, out);
    for j in n..src.len() {
        out[j] = ((src[j] / scale).round_ties_even() + zero).clamp(0.0, qmax) as u8;
    }
}

/// Fused encode with per-column params (the Channel row loop):
/// `out[j] = ((src[j] / scales[j]).round_ties_even() + zeros[j])
/// .clamp(0.0, qmax) as u8`.
// lint: hot-path
#[inline]
pub fn encode_cols(
    kind: Kind,
    src: &[f32],
    scales: &[f32],
    zeros: &[f32],
    qmax: f32,
    out: &mut [u8],
) {
    debug_assert_eq!(src.len(), out.len());
    debug_assert_eq!(src.len(), scales.len());
    debug_assert_eq!(src.len(), zeros.len());
    let n = simd_encode_cols(kind, src, scales, zeros, qmax, out);
    for j in n..src.len() {
        out[j] = ((src[j] / scales[j]).round_ties_even() + zeros[j]).clamp(0.0, qmax) as u8;
    }
}

/// Elementwise divide: `out[j] = num[j] / den[j]` — CST row
/// normalization by the column scales.
// lint: hot-path
#[inline]
pub fn div_slice(kind: Kind, num: &[f32], den: &[f32], out: &mut [f32]) {
    debug_assert_eq!(num.len(), den.len());
    debug_assert_eq!(num.len(), out.len());
    let n = simd_div(kind, num, den, out);
    for j in n..num.len() {
        out[j] = num[j] / den[j];
    }
}

/// Elementwise accumulate: `acc[j] += row[j]` — the saliency probe row
/// reduction.
// lint: hot-path
#[inline]
pub fn add_assign(kind: Kind, acc: &mut [f32], row: &[f32]) {
    debug_assert_eq!(acc.len(), row.len());
    let n = simd_add(kind, acc, row);
    for j in n..acc.len() {
        acc[j] += row[j];
    }
}

// ---- per-kind twins -------------------------------------------------------
//
// Each `simd_*` twin returns how many leading elements it handled (0
// for the Scalar kind and on non-x86 targets, where the stub block at
// the bottom compiles instead).  Integer codecs and the per-column f32
// kernels run the 128-bit implementation under both SIMD kinds; the
// uniform-affine / accumulate / encode_mul kernels step up to 256-bit
// under Avx2.

#[cfg(target_arch = "x86_64")]
#[inline]
fn simd_pack(kind: Kind, bits: u8, codes: &[u8], out: &mut [u8]) -> usize {
    debug_assert!(available(kind));
    if kind == Kind::Scalar {
        return 0;
    }
    match bits {
        4 => x86::pack4_sse2(codes, out),
        2 => x86::pack2_sse2(codes, out),
        1 => x86::pack1_sse2(codes, out),
        _ => 0,
    }
}

#[cfg(target_arch = "x86_64")]
#[inline]
fn simd_unpack(kind: Kind, bits: u8, data: &[u8], out: &mut [u8]) -> usize {
    debug_assert!(available(kind));
    if kind == Kind::Scalar {
        return 0;
    }
    match bits {
        4 => x86::unpack4_sse2(data, out),
        2 => x86::unpack2_sse2(data, out),
        // 1-bit expansion is fastest through the U1 table directly.
        _ => 0,
    }
}

#[cfg(target_arch = "x86_64")]
#[inline]
fn simd_u8_to_f32(kind: Kind, src: &[u8], out: &mut [f32]) -> usize {
    debug_assert!(available(kind));
    if kind == Kind::Scalar {
        return 0;
    }
    x86::u8_to_f32_sse2(src, out)
}

#[cfg(target_arch = "x86_64")]
#[inline]
fn simd_affine(kind: Kind, xs: &mut [f32], zero: f32, scale: f32) -> usize {
    debug_assert!(available(kind));
    match kind {
        Kind::Scalar => 0,
        Kind::Sse41 => x86::affine_sse2(xs, zero, scale),
        Kind::Avx2 => {
            // SAFETY: Kind::Avx2 is only ever selected after `available`
            // confirmed the avx2 CPU feature (detect_widest /
            // apply_choice / the kind-filtered test harnesses).
            unsafe { x86::affine_avx2(xs, zero, scale) }
        }
    }
}

#[cfg(target_arch = "x86_64")]
#[inline]
fn simd_affine_mul(kind: Kind, xs: &mut [f32], zero: f32, scale: f32, chan: &[f32]) -> usize {
    debug_assert!(available(kind));
    match kind {
        Kind::Scalar => 0,
        Kind::Sse41 => x86::affine_mul_sse2(xs, zero, scale, chan),
        Kind::Avx2 => {
            // SAFETY: Kind::Avx2 is only ever selected after `available`
            // confirmed the avx2 CPU feature.
            unsafe { x86::affine_mul_avx2(xs, zero, scale, chan) }
        }
    }
}

#[cfg(target_arch = "x86_64")]
#[inline]
fn simd_affine_cols(kind: Kind, xs: &mut [f32], scales: &[f32], zeros: &[f32]) -> usize {
    debug_assert!(available(kind));
    if kind == Kind::Scalar {
        return 0;
    }
    x86::affine_cols_sse2(xs, scales, zeros)
}

#[cfg(target_arch = "x86_64")]
#[inline]
fn simd_encode_mul(
    kind: Kind,
    src: &[f32],
    inv_s: f32,
    zero: f32,
    qmax: f32,
    out: &mut [u8],
) -> usize {
    debug_assert!(available(kind));
    match kind {
        Kind::Scalar => 0,
        Kind::Sse41 => {
            // SAFETY: Kind::Sse41 is only ever selected after `available`
            // confirmed the sse4.1 CPU feature.
            unsafe { x86::encode_mul_sse41(src, inv_s, zero, qmax, out) }
        }
        Kind::Avx2 => {
            // SAFETY: Kind::Avx2 is only ever selected after `available`
            // confirmed the avx2 CPU feature.
            unsafe { x86::encode_mul_avx2(src, inv_s, zero, qmax, out) }
        }
    }
}

#[cfg(target_arch = "x86_64")]
#[inline]
fn simd_encode_div(
    kind: Kind,
    src: &[f32],
    scale: f32,
    zero: f32,
    qmax: f32,
    out: &mut [u8],
) -> usize {
    debug_assert!(available(kind));
    match kind {
        Kind::Scalar => 0,
        Kind::Sse41 | Kind::Avx2 => {
            // SAFETY: both SIMD kinds are only ever selected after
            // `available` confirmed the sse4.1 CPU feature (the Avx2
            // tier requires it too, see `available`).
            unsafe { x86::encode_div_sse41(src, scale, zero, qmax, out) }
        }
    }
}

#[cfg(target_arch = "x86_64")]
#[inline]
fn simd_encode_cols(
    kind: Kind,
    src: &[f32],
    scales: &[f32],
    zeros: &[f32],
    qmax: f32,
    out: &mut [u8],
) -> usize {
    debug_assert!(available(kind));
    match kind {
        Kind::Scalar => 0,
        Kind::Sse41 | Kind::Avx2 => {
            // SAFETY: both SIMD kinds are only ever selected after
            // `available` confirmed the sse4.1 CPU feature (the Avx2
            // tier requires it too, see `available`).
            unsafe { x86::encode_cols_sse41(src, scales, zeros, qmax, out) }
        }
    }
}

#[cfg(target_arch = "x86_64")]
#[inline]
fn simd_div(kind: Kind, num: &[f32], den: &[f32], out: &mut [f32]) -> usize {
    debug_assert!(available(kind));
    if kind == Kind::Scalar {
        return 0;
    }
    x86::div_sse2(num, den, out)
}

#[cfg(target_arch = "x86_64")]
#[inline]
fn simd_add(kind: Kind, acc: &mut [f32], row: &[f32]) -> usize {
    debug_assert!(available(kind));
    match kind {
        Kind::Scalar => 0,
        Kind::Sse41 => x86::add_sse2(acc, row),
        Kind::Avx2 => {
            // SAFETY: Kind::Avx2 is only ever selected after `available`
            // confirmed the avx2 CPU feature.
            unsafe { x86::add_avx2(acc, row) }
        }
    }
}

#[cfg(not(target_arch = "x86_64"))]
mod stubs {
    //! Non-x86 targets compile only the Scalar kind; every twin handles
    //! a zero-length prefix so the dispatcher tails do all the work.
    use super::Kind;

    #[inline]
    pub(super) fn simd_pack(_k: Kind, _b: u8, _c: &[u8], _o: &mut [u8]) -> usize {
        0
    }
    #[inline]
    pub(super) fn simd_unpack(_k: Kind, _b: u8, _d: &[u8], _o: &mut [u8]) -> usize {
        0
    }
    #[inline]
    pub(super) fn simd_u8_to_f32(_k: Kind, _s: &[u8], _o: &mut [f32]) -> usize {
        0
    }
    #[inline]
    pub(super) fn simd_affine(_k: Kind, _x: &mut [f32], _z: f32, _s: f32) -> usize {
        0
    }
    #[inline]
    pub(super) fn simd_affine_mul(_k: Kind, _x: &mut [f32], _z: f32, _s: f32, _c: &[f32]) -> usize {
        0
    }
    #[inline]
    pub(super) fn simd_affine_cols(_k: Kind, _x: &mut [f32], _s: &[f32], _z: &[f32]) -> usize {
        0
    }
    #[inline]
    pub(super) fn simd_encode_mul(
        _k: Kind,
        _s: &[f32],
        _i: f32,
        _z: f32,
        _q: f32,
        _o: &mut [u8],
    ) -> usize {
        0
    }
    #[inline]
    pub(super) fn simd_encode_div(
        _k: Kind,
        _s: &[f32],
        _sc: f32,
        _z: f32,
        _q: f32,
        _o: &mut [u8],
    ) -> usize {
        0
    }
    #[inline]
    pub(super) fn simd_encode_cols(
        _k: Kind,
        _s: &[f32],
        _sc: &[f32],
        _z: &[f32],
        _q: f32,
        _o: &mut [u8],
    ) -> usize {
        0
    }
    #[inline]
    pub(super) fn simd_div(_k: Kind, _n: &[f32], _d: &[f32], _o: &mut [f32]) -> usize {
        0
    }
    #[inline]
    pub(super) fn simd_add(_k: Kind, _a: &mut [f32], _r: &[f32]) -> usize {
        0
    }
}
#[cfg(not(target_arch = "x86_64"))]
use stubs::*;

// ---- x86 implementations --------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod x86 {
    //! 128/256-bit lane kernels.  The SSE2 subset needs no feature
    //! gate — SSE2 is part of the x86_64 ABI baseline, so those
    //! intrinsics are always valid; their only hazard is the raw
    //! pointer loads/stores, covered by the in-bounds arguments on each
    //! block.  SSE4.1 (`roundps`) and AVX2 kernels carry
    //! `#[target_feature]` and a caller contract instead.

    #[allow(clippy::wildcard_imports)]
    use std::arch::x86_64::*;

    /// `roundps` control: round-to-nearest-even, no exception signals —
    /// the `f32::round_ties_even` semantics.
    const RN: i32 = _MM_FROUND_TO_NEAREST_INT | _MM_FROUND_NO_EXC;

    /// Unpack 16 packed bytes -> 32 4-bit codes per block (low nibble
    /// first).  Returns the input bytes consumed.
    pub(super) fn unpack4_sse2(data: &[u8], out: &mut [u8]) -> usize {
        let blocks = data.len() / 16;
        debug_assert!(out.len() >= blocks * 32);
        // SAFETY: SSE2 intrinsics are always available on x86_64; block
        // b reads data[b*16 .. b*16+16] (b < data.len()/16) and writes
        // out[b*32 .. b*32+32] (bounds asserted above).
        unsafe {
            let mask = _mm_set1_epi8(0x0F);
            for b in 0..blocks {
                let v = _mm_loadu_si128(data.as_ptr().add(b * 16) as *const __m128i);
                let lo = _mm_and_si128(v, mask);
                let hi = _mm_and_si128(_mm_srli_epi16::<4>(v), mask);
                let dst = out.as_mut_ptr().add(b * 32) as *mut __m128i;
                _mm_storeu_si128(dst, _mm_unpacklo_epi8(lo, hi));
                _mm_storeu_si128(dst.add(1), _mm_unpackhi_epi8(lo, hi));
            }
        }
        blocks * 16
    }

    /// Unpack 16 packed bytes -> 64 2-bit codes per block (lane 0
    /// first).  Returns the input bytes consumed.
    pub(super) fn unpack2_sse2(data: &[u8], out: &mut [u8]) -> usize {
        let blocks = data.len() / 16;
        debug_assert!(out.len() >= blocks * 64);
        // SAFETY: SSE2 intrinsics are always available on x86_64; block
        // b reads data[b*16 .. b*16+16] (b < data.len()/16) and writes
        // out[b*64 .. b*64+64] (bounds asserted above).
        unsafe {
            let mask = _mm_set1_epi8(0x03);
            for b in 0..blocks {
                let v = _mm_loadu_si128(data.as_ptr().add(b * 16) as *const __m128i);
                let c0 = _mm_and_si128(v, mask);
                let c1 = _mm_and_si128(_mm_srli_epi16::<2>(v), mask);
                let c2 = _mm_and_si128(_mm_srli_epi16::<4>(v), mask);
                let c3 = _mm_and_si128(_mm_srli_epi16::<6>(v), mask);
                let p01l = _mm_unpacklo_epi8(c0, c1);
                let p01h = _mm_unpackhi_epi8(c0, c1);
                let p23l = _mm_unpacklo_epi8(c2, c3);
                let p23h = _mm_unpackhi_epi8(c2, c3);
                let dst = out.as_mut_ptr().add(b * 64) as *mut __m128i;
                _mm_storeu_si128(dst, _mm_unpacklo_epi16(p01l, p23l));
                _mm_storeu_si128(dst.add(1), _mm_unpackhi_epi16(p01l, p23l));
                _mm_storeu_si128(dst.add(2), _mm_unpacklo_epi16(p01h, p23h));
                _mm_storeu_si128(dst.add(3), _mm_unpackhi_epi16(p01h, p23h));
            }
        }
        blocks * 16
    }

    /// Pack 16 4-bit codes -> 8 bytes per block, masking each code like
    /// the scalar path.  Returns the codes consumed.
    pub(super) fn pack4_sse2(codes: &[u8], out: &mut [u8]) -> usize {
        let blocks = codes.len() / 16;
        debug_assert!(out.len() >= blocks * 8);
        // SAFETY: SSE2 intrinsics are always available on x86_64; block
        // b reads codes[b*16 .. b*16+16] (b < codes.len()/16) and
        // stores 8 bytes at out[b*8] (bounds asserted above).
        unsafe {
            let lo_m = _mm_set1_epi16(0x000F);
            let hi_m = _mm_set1_epi16(0x00F0);
            for b in 0..blocks {
                // Each u16 lane holds (lo | hi << 8); fold to
                // (lo & 0x0F) | ((hi & 0x0F) << 4) in the low byte.
                let v = _mm_loadu_si128(codes.as_ptr().add(b * 16) as *const __m128i);
                let lo = _mm_and_si128(v, lo_m);
                let hi = _mm_and_si128(_mm_srli_epi16::<4>(v), hi_m);
                let bytes16 = _mm_or_si128(lo, hi);
                let packed = _mm_packus_epi16(bytes16, bytes16);
                _mm_storel_epi64(out.as_mut_ptr().add(b * 8) as *mut __m128i, packed);
            }
        }
        blocks * 16
    }

    /// Pack 16 2-bit codes -> 4 bytes per block, masking each code.
    /// Returns the codes consumed.
    pub(super) fn pack2_sse2(codes: &[u8], out: &mut [u8]) -> usize {
        let blocks = codes.len() / 16;
        debug_assert!(out.len() >= blocks * 4);
        // SAFETY: SSE2 intrinsics are always available on x86_64; block
        // b reads codes[b*16 .. b*16+16] (b < codes.len()/16); the
        // 4-byte store goes through a safe copy_from_slice.
        unsafe {
            for b in 0..blocks {
                // Each u32 lane holds (c0|c1<<8|c2<<16|c3<<24); fold
                // lane k of the byte to bits 2k..2k+1.
                let v = _mm_loadu_si128(codes.as_ptr().add(b * 16) as *const __m128i);
                let b0 = _mm_and_si128(v, _mm_set1_epi32(0x03));
                let b1 = _mm_and_si128(_mm_srli_epi32::<6>(v), _mm_set1_epi32(0x0C));
                let b2 = _mm_and_si128(_mm_srli_epi32::<12>(v), _mm_set1_epi32(0x30));
                let b3 = _mm_and_si128(_mm_srli_epi32::<18>(v), _mm_set1_epi32(0xC0));
                let m = _mm_or_si128(_mm_or_si128(b0, b1), _mm_or_si128(b2, b3));
                let w = _mm_packs_epi32(m, m);
                let p = _mm_packus_epi16(w, w);
                let four = (_mm_cvtsi128_si32(p) as u32).to_le_bytes();
                out[b * 4..b * 4 + 4].copy_from_slice(&four);
            }
        }
        blocks * 16
    }

    /// Pack 16 1-bit codes -> 2 bytes per block (bit k of each byte is
    /// code k's low bit).  Returns the codes consumed.
    pub(super) fn pack1_sse2(codes: &[u8], out: &mut [u8]) -> usize {
        let blocks = codes.len() / 16;
        debug_assert!(out.len() >= blocks * 2);
        // SAFETY: SSE2 intrinsics are always available on x86_64; block
        // b reads codes[b*16 .. b*16+16] (b < codes.len()/16); the
        // 2-byte store goes through a safe copy_from_slice.
        unsafe {
            for b in 0..blocks {
                // Shift bit 0 of every byte up to bit 7 and gather the
                // sign bits: movemask bit k == code k & 1.
                let v = _mm_loadu_si128(codes.as_ptr().add(b * 16) as *const __m128i);
                let m = _mm_movemask_epi8(_mm_slli_epi16::<7>(v)) as u16;
                out[b * 2..b * 2 + 2].copy_from_slice(&m.to_le_bytes());
            }
        }
        blocks * 16
    }

    /// Widen 16 u8 codes -> 16 f32 per block (exact conversion).
    /// Returns the elements consumed.
    pub(super) fn u8_to_f32_sse2(src: &[u8], out: &mut [f32]) -> usize {
        let blocks = src.len() / 16;
        debug_assert!(out.len() >= blocks * 16);
        // SAFETY: SSE2 intrinsics are always available on x86_64; block
        // b reads src[b*16 .. b*16+16] (b < src.len()/16) and writes
        // out[b*16 .. b*16+16] (bounds asserted above).
        unsafe {
            let z = _mm_setzero_si128();
            for b in 0..blocks {
                let v = _mm_loadu_si128(src.as_ptr().add(b * 16) as *const __m128i);
                let w0 = _mm_unpacklo_epi8(v, z);
                let w1 = _mm_unpackhi_epi8(v, z);
                let dst = out.as_mut_ptr().add(b * 16);
                _mm_storeu_ps(dst, _mm_cvtepi32_ps(_mm_unpacklo_epi16(w0, z)));
                _mm_storeu_ps(dst.add(4), _mm_cvtepi32_ps(_mm_unpackhi_epi16(w0, z)));
                _mm_storeu_ps(dst.add(8), _mm_cvtepi32_ps(_mm_unpacklo_epi16(w1, z)));
                _mm_storeu_ps(dst.add(12), _mm_cvtepi32_ps(_mm_unpackhi_epi16(w1, z)));
            }
        }
        blocks * 16
    }

    /// 4-wide `(x - zero) * scale` in place.  Returns the elements
    /// consumed.
    pub(super) fn affine_sse2(xs: &mut [f32], zero: f32, scale: f32) -> usize {
        let n = xs.len() / 4 * 4;
        // SAFETY: SSE2 intrinsics are always available on x86_64; every
        // load/store touches xs[i .. i+4] with i + 4 <= n <= xs.len().
        unsafe {
            let z = _mm_set1_ps(zero);
            let s = _mm_set1_ps(scale);
            let mut i = 0;
            while i < n {
                let p = xs.as_mut_ptr().add(i);
                let v = _mm_loadu_ps(p);
                _mm_storeu_ps(p, _mm_mul_ps(_mm_sub_ps(v, z), s));
                i += 4;
            }
        }
        n
    }

    /// 4-wide `(x - zero) * scale * chan[j]` in place.  Returns the
    /// elements consumed.
    pub(super) fn affine_mul_sse2(xs: &mut [f32], zero: f32, scale: f32, chan: &[f32]) -> usize {
        let n = xs.len() / 4 * 4;
        debug_assert!(chan.len() >= n);
        // SAFETY: SSE2 intrinsics are always available on x86_64; every
        // load/store touches xs[i .. i+4] / chan[i .. i+4] with
        // i + 4 <= n <= min(xs.len(), chan.len()).
        unsafe {
            let z = _mm_set1_ps(zero);
            let s = _mm_set1_ps(scale);
            let mut i = 0;
            while i < n {
                let p = xs.as_mut_ptr().add(i);
                let v = _mm_loadu_ps(p);
                let c = _mm_loadu_ps(chan.as_ptr().add(i));
                _mm_storeu_ps(p, _mm_mul_ps(_mm_mul_ps(_mm_sub_ps(v, z), s), c));
                i += 4;
            }
        }
        n
    }

    /// 4-wide `(x - zeros[j]) * scales[j]` in place.  Returns the
    /// elements consumed.
    pub(super) fn affine_cols_sse2(xs: &mut [f32], scales: &[f32], zeros: &[f32]) -> usize {
        let n = xs.len() / 4 * 4;
        debug_assert!(scales.len() >= n && zeros.len() >= n);
        // SAFETY: SSE2 intrinsics are always available on x86_64; every
        // load/store touches index range [i, i+4) of xs/scales/zeros
        // with i + 4 <= n <= the length of each slice.
        unsafe {
            let mut i = 0;
            while i < n {
                let p = xs.as_mut_ptr().add(i);
                let v = _mm_loadu_ps(p);
                let s = _mm_loadu_ps(scales.as_ptr().add(i));
                let z = _mm_loadu_ps(zeros.as_ptr().add(i));
                _mm_storeu_ps(p, _mm_mul_ps(_mm_sub_ps(v, z), s));
                i += 4;
            }
        }
        n
    }

    /// 4-wide `num[j] / den[j]`.  Returns the elements consumed.
    pub(super) fn div_sse2(num: &[f32], den: &[f32], out: &mut [f32]) -> usize {
        let n = num.len() / 4 * 4;
        debug_assert!(den.len() >= n && out.len() >= n);
        // SAFETY: SSE2 intrinsics are always available on x86_64; every
        // load/store touches index range [i, i+4) of num/den/out with
        // i + 4 <= n <= the length of each slice.
        unsafe {
            let mut i = 0;
            while i < n {
                let a = _mm_loadu_ps(num.as_ptr().add(i));
                let b = _mm_loadu_ps(den.as_ptr().add(i));
                _mm_storeu_ps(out.as_mut_ptr().add(i), _mm_div_ps(a, b));
                i += 4;
            }
        }
        n
    }

    /// 4-wide `acc[j] += row[j]`.  Returns the elements consumed.
    pub(super) fn add_sse2(acc: &mut [f32], row: &[f32]) -> usize {
        let n = acc.len() / 4 * 4;
        debug_assert!(row.len() >= n);
        // SAFETY: SSE2 intrinsics are always available on x86_64; every
        // load/store touches acc[i .. i+4] / row[i .. i+4] with
        // i + 4 <= n <= min(acc.len(), row.len()).
        unsafe {
            let mut i = 0;
            while i < n {
                let p = acc.as_mut_ptr().add(i);
                let a = _mm_loadu_ps(p);
                let r = _mm_loadu_ps(row.as_ptr().add(i));
                _mm_storeu_ps(p, _mm_add_ps(a, r));
                i += 4;
            }
        }
        n
    }
    /// 8-wide fused encode with a hoisted reciprocal scale:
    /// `((x * inv_s).round_ties_even() + zero).clamp(0.0, qmax) as u8`.
    /// NaN lanes clamp to 0 exactly like the scalar saturating cast
    /// (maxps/minps return the second operand on NaN).  Returns the
    /// elements consumed.
    ///
    /// SAFETY: callers must guarantee the sse4.1 CPU feature (for
    /// `roundps`) — upheld by dispatching only on kinds vetted by
    /// `available`.
    #[target_feature(enable = "sse4.1")]
    pub(super) unsafe fn encode_mul_sse41(
        src: &[f32],
        inv_s: f32,
        zero: f32,
        qmax: f32,
        out: &mut [u8],
    ) -> usize {
        let n = src.len() / 8 * 8;
        debug_assert!(out.len() >= n);
        let invs = _mm_set1_ps(inv_s);
        let z = _mm_set1_ps(zero);
        let lo = _mm_setzero_ps();
        let hi = _mm_set1_ps(qmax);
        let mut i = 0;
        while i < n {
            let v0 = _mm_loadu_ps(src.as_ptr().add(i));
            let v1 = _mm_loadu_ps(src.as_ptr().add(i + 4));
            let r0 = _mm_add_ps(_mm_round_ps::<RN>(_mm_mul_ps(v0, invs)), z);
            let r1 = _mm_add_ps(_mm_round_ps::<RN>(_mm_mul_ps(v1, invs)), z);
            let q0 = _mm_min_ps(_mm_max_ps(r0, lo), hi);
            let q1 = _mm_min_ps(_mm_max_ps(r1, lo), hi);
            let w = _mm_packs_epi32(_mm_cvtps_epi32(q0), _mm_cvtps_epi32(q1));
            let p = _mm_packus_epi16(w, w);
            _mm_storel_epi64(out.as_mut_ptr().add(i) as *mut __m128i, p);
            i += 8;
        }
        n
    }

    /// 8-wide `QuantParams::encode`:
    /// `((x / scale).round_ties_even() + zero).clamp(0.0, qmax) as u8`.
    /// Returns the elements consumed.
    ///
    /// SAFETY: callers must guarantee the sse4.1 CPU feature — upheld
    /// by dispatching only on kinds vetted by `available`.
    #[target_feature(enable = "sse4.1")]
    pub(super) unsafe fn encode_div_sse41(
        src: &[f32],
        scale: f32,
        zero: f32,
        qmax: f32,
        out: &mut [u8],
    ) -> usize {
        let n = src.len() / 8 * 8;
        debug_assert!(out.len() >= n);
        let s = _mm_set1_ps(scale);
        let z = _mm_set1_ps(zero);
        let lo = _mm_setzero_ps();
        let hi = _mm_set1_ps(qmax);
        let mut i = 0;
        while i < n {
            let v0 = _mm_loadu_ps(src.as_ptr().add(i));
            let v1 = _mm_loadu_ps(src.as_ptr().add(i + 4));
            let r0 = _mm_add_ps(_mm_round_ps::<RN>(_mm_div_ps(v0, s)), z);
            let r1 = _mm_add_ps(_mm_round_ps::<RN>(_mm_div_ps(v1, s)), z);
            let q0 = _mm_min_ps(_mm_max_ps(r0, lo), hi);
            let q1 = _mm_min_ps(_mm_max_ps(r1, lo), hi);
            let w = _mm_packs_epi32(_mm_cvtps_epi32(q0), _mm_cvtps_epi32(q1));
            let p = _mm_packus_epi16(w, w);
            _mm_storel_epi64(out.as_mut_ptr().add(i) as *mut __m128i, p);
            i += 8;
        }
        n
    }

    /// 8-wide per-column encode:
    /// `((x / scales[j]).round_ties_even() + zeros[j]).clamp(..) as u8`.
    /// Returns the elements consumed.
    ///
    /// SAFETY: callers must guarantee the sse4.1 CPU feature — upheld
    /// by dispatching only on kinds vetted by `available`.
    #[target_feature(enable = "sse4.1")]
    pub(super) unsafe fn encode_cols_sse41(
        src: &[f32],
        scales: &[f32],
        zeros: &[f32],
        qmax: f32,
        out: &mut [u8],
    ) -> usize {
        let n = src.len() / 8 * 8;
        debug_assert!(scales.len() >= n && zeros.len() >= n && out.len() >= n);
        let lo = _mm_setzero_ps();
        let hi = _mm_set1_ps(qmax);
        let mut i = 0;
        while i < n {
            let v0 = _mm_loadu_ps(src.as_ptr().add(i));
            let v1 = _mm_loadu_ps(src.as_ptr().add(i + 4));
            let s0 = _mm_loadu_ps(scales.as_ptr().add(i));
            let s1 = _mm_loadu_ps(scales.as_ptr().add(i + 4));
            let z0 = _mm_loadu_ps(zeros.as_ptr().add(i));
            let z1 = _mm_loadu_ps(zeros.as_ptr().add(i + 4));
            let r0 = _mm_add_ps(_mm_round_ps::<RN>(_mm_div_ps(v0, s0)), z0);
            let r1 = _mm_add_ps(_mm_round_ps::<RN>(_mm_div_ps(v1, s1)), z1);
            let q0 = _mm_min_ps(_mm_max_ps(r0, lo), hi);
            let q1 = _mm_min_ps(_mm_max_ps(r1, lo), hi);
            let w = _mm_packs_epi32(_mm_cvtps_epi32(q0), _mm_cvtps_epi32(q1));
            let p = _mm_packus_epi16(w, w);
            _mm_storel_epi64(out.as_mut_ptr().add(i) as *mut __m128i, p);
            i += 8;
        }
        n
    }

    /// 8-wide AVX `(x - zero) * scale` in place.  Returns the elements
    /// consumed.
    ///
    /// SAFETY: callers must guarantee the avx2 CPU feature — upheld by
    /// dispatching only on kinds vetted by `available`.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn affine_avx2(xs: &mut [f32], zero: f32, scale: f32) -> usize {
        let n = xs.len() / 8 * 8;
        let z = _mm256_set1_ps(zero);
        let s = _mm256_set1_ps(scale);
        let mut i = 0;
        while i < n {
            let p = xs.as_mut_ptr().add(i);
            let v = _mm256_loadu_ps(p);
            _mm256_storeu_ps(p, _mm256_mul_ps(_mm256_sub_ps(v, z), s));
            i += 8;
        }
        n
    }

    /// 8-wide AVX `(x - zero) * scale * chan[j]` in place.  Returns the
    /// elements consumed.
    ///
    /// SAFETY: callers must guarantee the avx2 CPU feature — upheld by
    /// dispatching only on kinds vetted by `available`.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn affine_mul_avx2(
        xs: &mut [f32],
        zero: f32,
        scale: f32,
        chan: &[f32],
    ) -> usize {
        let n = xs.len() / 8 * 8;
        debug_assert!(chan.len() >= n);
        let z = _mm256_set1_ps(zero);
        let s = _mm256_set1_ps(scale);
        let mut i = 0;
        while i < n {
            let p = xs.as_mut_ptr().add(i);
            let v = _mm256_loadu_ps(p);
            let c = _mm256_loadu_ps(chan.as_ptr().add(i));
            _mm256_storeu_ps(p, _mm256_mul_ps(_mm256_mul_ps(_mm256_sub_ps(v, z), s), c));
            i += 8;
        }
        n
    }

    /// 8-wide AVX `acc[j] += row[j]`.  Returns the elements consumed.
    ///
    /// SAFETY: callers must guarantee the avx2 CPU feature — upheld by
    /// dispatching only on kinds vetted by `available`.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn add_avx2(acc: &mut [f32], row: &[f32]) -> usize {
        let n = acc.len() / 8 * 8;
        debug_assert!(row.len() >= n);
        let mut i = 0;
        while i < n {
            let p = acc.as_mut_ptr().add(i);
            let a = _mm256_loadu_ps(p);
            let r = _mm256_loadu_ps(row.as_ptr().add(i));
            _mm256_storeu_ps(p, _mm256_add_ps(a, r));
            i += 8;
        }
        n
    }

    /// 8-wide AVX2 fused encode (same expression as
    /// [`encode_mul_sse41`], 256-bit arithmetic, 128-bit narrowing).
    /// Returns the elements consumed.
    ///
    /// SAFETY: callers must guarantee the avx2 CPU feature — upheld by
    /// dispatching only on kinds vetted by `available`.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn encode_mul_avx2(
        src: &[f32],
        inv_s: f32,
        zero: f32,
        qmax: f32,
        out: &mut [u8],
    ) -> usize {
        let n = src.len() / 8 * 8;
        debug_assert!(out.len() >= n);
        let invs = _mm256_set1_ps(inv_s);
        let z = _mm256_set1_ps(zero);
        let lo = _mm256_setzero_ps();
        let hi = _mm256_set1_ps(qmax);
        let mut i = 0;
        while i < n {
            let v = _mm256_loadu_ps(src.as_ptr().add(i));
            let r = _mm256_add_ps(_mm256_round_ps::<RN>(_mm256_mul_ps(v, invs)), z);
            let q = _mm256_min_ps(_mm256_max_ps(r, lo), hi);
            let d = _mm256_cvtps_epi32(q);
            let w = _mm_packs_epi32(_mm256_castsi256_si128(d), _mm256_extracti128_si256::<1>(d));
            let p = _mm_packus_epi16(w, w);
            _mm_storel_epi64(out.as_mut_ptr().add(i) as *mut __m128i, p);
            i += 8;
        }
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Every compiled kind this machine can actually run.
    fn kinds() -> Vec<Kind> {
        compiled_kinds()
            .iter()
            .copied()
            .filter(|&k| available(k))
            .collect()
    }

    fn lcg_f32s(n: usize, seed: u64) -> Vec<f32> {
        let mut s = seed;
        let mut v = Vec::with_capacity(n);
        for i in 0..n {
            s = s
                .wrapping_mul(6364136223846793005)
                .wrapping_add(1442695040888963407);
            let u = ((s >> 33) as u32) as f32 / (1u64 << 31) as f32;
            let mut x = (u - 0.5) * 12.0;
            if i % 17 == 0 {
                x = 0.0;
            }
            if i % 23 == 0 {
                x = -0.0;
            }
            v.push(x);
        }
        v
    }

    fn assert_bits_eq(a: &[f32], b: &[f32], what: &str, k: Kind) {
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
            assert!(
                x.to_bits() == y.to_bits(),
                "{what} kind={k:?} diverges at {i}: {x} vs {y}"
            );
        }
    }

    #[test]
    fn choice_parsing_roundtrips() {
        let table = [
            ("auto", KernelChoice::Auto),
            ("scalar", KernelChoice::Scalar),
            ("simd", KernelChoice::Simd),
        ];
        for (s, c) in table {
            assert_eq!(s.parse::<KernelChoice>().unwrap(), c);
            assert_eq!(c.to_string(), s);
        }
        assert!("avx512".parse::<KernelChoice>().is_err());
        assert_eq!(KernelChoice::default(), KernelChoice::Auto);
    }

    #[test]
    fn detection_is_sane() {
        let k = active();
        assert!(available(k), "active kernel {k:?} must be available");
        assert!(compiled_kinds().contains(&Kind::Scalar));
        assert!(available(Kind::Scalar));
    }

    #[test]
    fn pack_unpack_parity_all_kinds() {
        let sizes = [0usize, 1, 3, 5, 7, 8, 15, 16, 17, 31, 33, 64, 100, 257];
        for bits in [1u8, 2, 4, 8] {
            let pb = (8 / bits) as usize;
            for n in sizes {
                let mut codes = vec![0u8; n];
                for (i, c) in codes.iter_mut().enumerate() {
                    *c = ((i * 7 + 3) % (1usize << bits)) as u8;
                }
                let nbytes = n.div_ceil(pb);
                let mut base = vec![0u8; nbytes];
                pack_lanes(Kind::Scalar, bits, &codes, &mut base);
                for k in kinds() {
                    let mut got = vec![0u8; nbytes];
                    pack_lanes(k, bits, &codes, &mut got);
                    assert_eq!(got, base, "pack bits={bits} n={n} kind={k:?}");
                    let mut back = vec![0u8; n];
                    unpack_lanes(k, bits, &got, &mut back);
                    assert_eq!(back, codes, "unpack bits={bits} n={n} kind={k:?}");
                }
            }
        }
    }

    #[test]
    fn out_of_range_codes_pack_masked_on_every_kind() {
        // Codes above the lane range must be masked identically on all
        // kinds (the scalar 4-bit path once ORed the high lane
        // unmasked; this pins the fixed semantics).
        for bits in [1u8, 2, 4] {
            let mask = (1u8 << bits) - 1;
            let n = 37usize;
            let wild: Vec<u8> = (0..n).map(|i| (i * 29 + 201) as u8).collect();
            let masked: Vec<u8> = wild.iter().map(|c| c & mask).collect();
            let nbytes = n.div_ceil((8 / bits) as usize);
            let mut want = vec![0u8; nbytes];
            pack_lanes(Kind::Scalar, bits, &masked, &mut want);
            for k in kinds() {
                let mut got = vec![0u8; nbytes];
                pack_lanes(k, bits, &wild, &mut got);
                assert_eq!(got, want, "bits={bits} kind={k:?}");
            }
        }
    }

    #[test]
    fn codes_to_f32_matches_scalar_widening() {
        for bits in [1u8, 2, 4, 8] {
            let pb = (8 / bits) as usize;
            for n in [0usize, 1, 9, 255, 256, 300, 517] {
                let mut codes = vec![0u8; n];
                for (i, c) in codes.iter_mut().enumerate() {
                    *c = ((i * 5 + 1) % (1usize << bits)) as u8;
                }
                let mut data = vec![0u8; n.div_ceil(pb)];
                pack_lanes(Kind::Scalar, bits, &codes, &mut data);
                let want: Vec<f32> = codes.iter().map(|&c| c as f32).collect();
                for k in kinds() {
                    let mut out = vec![0f32; n];
                    codes_to_f32(k, bits, &data, &mut out);
                    assert_bits_eq(&want, &out, "codes_to_f32", k);
                }
            }
        }
    }

    #[test]
    fn f32_primitives_match_scalar() {
        for n in [1usize, 4, 7, 8, 13, 64, 100, 257] {
            let src = lcg_f32s(n, 42);
            let addend = lcg_f32s(n, 5);
            let chan: Vec<f32> = lcg_f32s(n, 7).iter().map(|x| x.abs() + 0.5).collect();
            let zeros = lcg_f32s(n, 9);
            let scales: Vec<f32> = lcg_f32s(n, 11).iter().map(|x| x.abs() + 0.25).collect();
            for k in kinds() {
                let mut a = src.clone();
                let mut b = src.clone();
                affine_inplace(Kind::Scalar, &mut a, 3.5, 0.127);
                affine_inplace(k, &mut b, 3.5, 0.127);
                assert_bits_eq(&a, &b, "affine", k);

                let mut a = src.clone();
                let mut b = src.clone();
                affine_mul_inplace(Kind::Scalar, &mut a, -1.25, 0.31, &chan);
                affine_mul_inplace(k, &mut b, -1.25, 0.31, &chan);
                assert_bits_eq(&a, &b, "affine_mul", k);

                let mut a = src.clone();
                let mut b = src.clone();
                affine_cols_inplace(Kind::Scalar, &mut a, &scales, &zeros);
                affine_cols_inplace(k, &mut b, &scales, &zeros);
                assert_bits_eq(&a, &b, "affine_cols", k);

                let mut a = vec![0f32; n];
                let mut b = vec![0f32; n];
                div_slice(Kind::Scalar, &src, &chan, &mut a);
                div_slice(k, &src, &chan, &mut b);
                assert_bits_eq(&a, &b, "div_slice", k);

                let mut a = src.clone();
                let mut b = src.clone();
                add_assign(Kind::Scalar, &mut a, &addend);
                add_assign(k, &mut b, &addend);
                assert_bits_eq(&a, &b, "add_assign", k);

                let mut ca = vec![0u8; n];
                let mut cb = vec![0u8; n];
                encode_mul(Kind::Scalar, &src, 2.5, 7.0, 15.0, &mut ca);
                encode_mul(k, &src, 2.5, 7.0, 15.0, &mut cb);
                assert_eq!(ca, cb, "encode_mul kind={k:?}");

                encode_div(Kind::Scalar, &src, 0.4, 3.0, 255.0, &mut ca);
                encode_div(k, &src, 0.4, 3.0, 255.0, &mut cb);
                assert_eq!(ca, cb, "encode_div kind={k:?}");

                let zoff: Vec<f32> = zeros.iter().map(|z| z.abs()).collect();
                encode_cols(Kind::Scalar, &src, &scales, &zoff, 15.0, &mut ca);
                encode_cols(k, &src, &scales, &zoff, 15.0, &mut cb);
                assert_eq!(ca, cb, "encode_cols kind={k:?}");
            }
        }
    }

    #[test]
    fn encode_corner_values_match_scalar() {
        let src = [
            f32::NAN,
            f32::INFINITY,
            f32::NEG_INFINITY,
            -0.0,
            0.0,
            1e30,
            -1e30,
            1e-30,
            0.5,
            -0.5,
            1.5,
            2.5,
            254.5,
            255.5,
            1000.0,
            -7.25,
            3.499_999_9,
        ];
        for k in kinds() {
            let mut a = vec![0u8; src.len()];
            let mut b = vec![0u8; src.len()];
            encode_mul(Kind::Scalar, &src, 1.0, 0.0, 255.0, &mut a);
            encode_mul(k, &src, 1.0, 0.0, 255.0, &mut b);
            assert_eq!(a, b, "encode_mul corners kind={k:?}");

            encode_div(Kind::Scalar, &src, 2.0, 1.0, 15.0, &mut a);
            encode_div(k, &src, 2.0, 1.0, 15.0, &mut b);
            assert_eq!(a, b, "encode_div corners kind={k:?}");
        }
    }

    #[test]
    fn lane_tables_match_shifted_extraction() {
        for b in 0..256usize {
            for k in 0..2 {
                assert_eq!(U4_LUT[b].to_le_bytes()[k], ((b >> (4 * k)) & 0x0F) as u8);
            }
            for k in 0..4 {
                assert_eq!(U2_LUT[b].to_le_bytes()[k], ((b >> (2 * k)) & 0x03) as u8);
            }
            for k in 0..8 {
                assert_eq!(U1_LUT[b].to_le_bytes()[k], ((b >> k) & 1) as u8);
            }
        }
    }
}
