//! Quantized 2-D planes: one `[rows, cols]` slab of a KV tensor (the
//! `[S, d_head]` plane of one layer/head), quantized at one of the paper's
//! granularities (Table 1) and stored bit-packed.
//!
//! Semantics mirror `python/compile/kernels/ref.py` exactly:
//!   * `Token`   — one (s, z) per row (Eq. 5 over each token)
//!   * `Channel` — one (s, z) per column
//!   * `Group(n)`— one (s, z) per `n` contiguous columns within each row
//!   * `ChannelSeparableToken` — Alg. 1: per-channel `c = sqrt(max|col|)`
//!     normalization, then `Token`, then rescale.

use super::kernel;
use super::packing::{PackWriter, PackedCodes};
use super::{min_max, QuantParams};

/// The quantization granularities compared in the paper's Table 1.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Granularity {
    Token,
    Channel,
    Group(usize),
    ChannelSeparableToken,
}

impl Granularity {
    /// Number of (scale, zero) pairs for a `[rows, cols]` plane — the
    /// quantization-parameter overhead the paper's §4.1 analyzes.
    pub fn param_pairs(&self, rows: usize, cols: usize) -> usize {
        match self {
            Granularity::Token => rows,
            Granularity::Channel => cols,
            Granularity::Group(n) => rows * cols.div_ceil(*n),
            Granularity::ChannelSeparableToken => rows, // + cols channel scales
        }
    }

    /// Extra per-channel scale values (CST's `c` vector).
    pub fn channel_scales(&self, cols: usize) -> usize {
        match self {
            Granularity::ChannelSeparableToken => cols,
            _ => 0,
        }
    }
}

/// A quantized `[rows, cols]` plane: packed codes + parameters.
#[derive(Debug, Clone)]
pub struct QuantizedPlane {
    pub bits: u8,
    pub granularity: Granularity,
    pub rows: usize,
    pub cols: usize,
    pub codes: PackedCodes,
    /// (s, z) pairs, laid out per granularity (row-major for Group).
    pub params: Vec<QuantParams>,
    /// CST channel scales `c_i = sqrt(max|X_i|)`; empty otherwise.
    pub chan_scale: Vec<f32>,
}

impl QuantizedPlane {
    /// Quantize `x` (`rows*cols`, row-major) with the process-wide
    /// kernel.
    pub fn quantize(x: &[f32], rows: usize, cols: usize, bits: u8,
                    granularity: Granularity) -> Self {
        Self::quantize_with(kernel::active(), x, rows, cols, bits, granularity)
    }

    /// [`QuantizedPlane::quantize`] with an explicit kernel kind — the
    /// cross-kind parity tests and benches compare kinds without
    /// touching the process-wide selection.  Range reductions (the
    /// min/max scans and the CST column max-abs below) stay scalar in
    /// every kind; see `quant/kernel.rs` on why reassociating them
    /// would break bit-identity.
    pub fn quantize_with(kind: kernel::Kind, x: &[f32], rows: usize, cols: usize,
                         bits: u8, granularity: Granularity) -> Self {
        assert_eq!(x.len(), rows * cols);
        match granularity {
            Granularity::Token => Self::quant_token(kind, x, rows, cols, bits, &[]),
            Granularity::Channel => Self::quant_channel(kind, x, rows, cols, bits),
            Granularity::Group(n) => Self::quant_group(kind, x, rows, cols, bits, n),
            Granularity::ChannelSeparableToken => {
                // Eq. 6: c_i = sqrt(max|X_i|) per column, degenerate -> 1.
                let mut c = vec![0f32; cols];
                for r in 0..rows {
                    for (j, cj) in c.iter_mut().enumerate() {
                        *cj = cj.max(x[r * cols + j].abs());
                    }
                }
                for cj in c.iter_mut() {
                    *cj = if *cj <= 0.0 { 1.0 } else { cj.sqrt() };
                }
                Self::quant_token(kind, x, rows, cols, bits, &c)
            }
        }
    }

    fn quant_token(kind: kernel::Kind, x: &[f32], rows: usize, cols: usize,
                   bits: u8, chan_scale: &[f32]) -> Self {
        let cst = !chan_scale.is_empty();
        let mut w = PackWriter::with_capacity(bits, rows * cols);
        let mut params = Vec::with_capacity(rows);
        let mut normed = vec![0f32; cols];
        // Perf (EXPERIMENTS.md §Perf): the encode loop hoists 1/s out of
        // the per-element path (mul instead of div) — ~25% off the
        // compress cycle — and packs through a `PackWriter` as it
        // quantizes, so no unpacked staging buffer is materialized.  The
        // reciprocal can differ from `x / s` by one ulp on exact rounding
        // ties; the cross-layer contract is an error-bound (not bit)
        // match, verified in rust/tests.
        let qmax = ((1u32 << bits) - 1) as f32;
        let mut cbuf = [0u8; kernel::TILE];
        for r in 0..rows {
            let row = &x[r * cols..(r + 1) * cols];
            let src: &[f32] = if cst {
                // Elementwise IEEE division — identical lanes per kind.
                kernel::div_slice(kind, row, chan_scale, &mut normed);
                &normed
            } else {
                row
            };
            let (mn, mx) = min_max(src);
            let p = QuantParams::from_min_max(mn, mx, bits);
            let inv_s = 1.0 / p.scale;
            if kind == kernel::Kind::Scalar {
                for &v in src {
                    w.push(((v * inv_s).round_ties_even() + p.zero).clamp(0.0, qmax) as u8);
                }
            } else {
                for chunk in src.chunks(kernel::TILE) {
                    let m = chunk.len();
                    kernel::encode_mul(kind, chunk, inv_s, p.zero, qmax, &mut cbuf[..m]);
                    w.push_slice(kind, &cbuf[..m]);
                }
            }
            params.push(p);
        }
        QuantizedPlane {
            bits,
            granularity: if cst { Granularity::ChannelSeparableToken } else { Granularity::Token },
            rows,
            cols,
            codes: w.finish(),
            params,
            chan_scale: chan_scale.to_vec(),
        }
    }

    fn quant_channel(kind: kernel::Kind, x: &[f32], rows: usize, cols: usize,
                     bits: u8) -> Self {
        let mut mn = vec![f32::INFINITY; cols];
        let mut mx = vec![f32::NEG_INFINITY; cols];
        for r in 0..rows {
            for j in 0..cols {
                let v = x[r * cols + j];
                mn[j] = mn[j].min(v);
                mx[j] = mx[j].max(v);
            }
        }
        let params: Vec<QuantParams> = (0..cols)
            .map(|j| QuantParams::from_min_max(mn[j], mx[j], bits))
            .collect();
        let mut w = PackWriter::with_capacity(bits, rows * cols);
        if kind != kernel::Kind::Scalar && cols <= kernel::TILE {
            // Stage (s, z) column vectors once, then encode whole rows.
            let qmax = ((1u32 << bits) - 1) as f32;
            let mut sbuf = [0f32; kernel::TILE];
            let mut zbuf = [0f32; kernel::TILE];
            let mut cbuf = [0u8; kernel::TILE];
            for (j, p) in params.iter().enumerate() {
                sbuf[j] = p.scale;
                zbuf[j] = p.zero;
            }
            for r in 0..rows {
                let row = &x[r * cols..(r + 1) * cols];
                kernel::encode_cols(kind, row, &sbuf[..cols], &zbuf[..cols],
                                    qmax, &mut cbuf[..cols]);
                w.push_slice(kind, &cbuf[..cols]);
            }
        } else {
            for r in 0..rows {
                for (j, p) in params.iter().enumerate() {
                    w.push(p.encode(x[r * cols + j], bits));
                }
            }
        }
        QuantizedPlane {
            bits,
            granularity: Granularity::Channel,
            rows,
            cols,
            codes: w.finish(),
            params,
            chan_scale: vec![],
        }
    }

    fn quant_group(kind: kernel::Kind, x: &[f32], rows: usize, cols: usize,
                   bits: u8, n: usize) -> Self {
        assert!(n > 0);
        let groups = cols.div_ceil(n);
        let mut params = Vec::with_capacity(rows * groups);
        let mut w = PackWriter::with_capacity(bits, rows * cols);
        let qmax = ((1u32 << bits) - 1) as f32;
        let mut cbuf = [0u8; kernel::TILE];
        for r in 0..rows {
            for g in 0..groups {
                let j0 = g * n;
                let j1 = (j0 + n).min(cols);
                let seg = &x[r * cols + j0..r * cols + j1];
                let (mn, mx) = min_max(seg);
                let p = QuantParams::from_min_max(mn, mx, bits);
                if kind == kernel::Kind::Scalar {
                    for &v in seg {
                        w.push(p.encode(v, bits));
                    }
                } else {
                    for chunk in seg.chunks(kernel::TILE) {
                        let m = chunk.len();
                        kernel::encode_div(kind, chunk, p.scale, p.zero, qmax, &mut cbuf[..m]);
                        w.push_slice(kind, &cbuf[..m]);
                    }
                }
                params.push(p);
            }
        }
        QuantizedPlane {
            bits,
            granularity: Granularity::Group(n),
            rows,
            cols,
            codes: w.finish(),
            params,
            chan_scale: vec![],
        }
    }

    /// Dequantize the whole plane into `out` (`rows*cols`, row-major)
    /// with the process-wide kernel.
    // lint: hot-path — steady materialization kernel (DESIGN.md §13).
    #[inline]
    pub fn dequantize_into(&self, out: &mut [f32]) {
        self.dequantize_into_with(kernel::active(), out);
    }

    /// [`QuantizedPlane::dequantize_into`] with an explicit kernel kind
    /// (DESIGN.md §15).
    ///
    /// The scalar kind runs the fused unpack–decode loop
    /// ([`Self::dequantize_scalar`] below); the SIMD kinds widen the
    /// packed codes to f32 in fixed stack tiles and then apply the
    /// per-granularity affine pass segment by segment.  Both orders run
    /// the exact `QuantParams::decode` arithmetic over the same code
    /// sequence, so the planes are bit-identical — pinned by the
    /// `kernels_bit_identical_across_kinds` property test.
    // lint: hot-path — steady materialization kernel (DESIGN.md §13, §15).
    pub fn dequantize_into_with(&self, kind: kernel::Kind, out: &mut [f32]) {
        assert_eq!(out.len(), self.rows * self.cols);
        let cols = self.cols;
        // Channel planes wider than one tile would overflow the staged
        // (s, z) column buffers; `cols` is `d_head` (<= TILE) everywhere
        // in practice, so that corner just takes the fused fallback.
        let wide_channel =
            self.granularity == Granularity::Channel && cols > kernel::TILE;
        if kind == kernel::Kind::Scalar || wide_channel {
            self.dequantize_scalar(out);
            return;
        }
        kernel::codes_to_f32(kind, self.bits, self.codes.as_bytes(), out);
        match self.granularity {
            Granularity::Token => {
                for (r, p) in self.params.iter().enumerate() {
                    let row = &mut out[r * cols..(r + 1) * cols];
                    kernel::affine_inplace(kind, row, p.zero, p.scale);
                }
            }
            Granularity::ChannelSeparableToken => {
                for (r, p) in self.params.iter().enumerate() {
                    let row = &mut out[r * cols..(r + 1) * cols];
                    kernel::affine_mul_inplace(kind, row, p.zero, p.scale,
                                               &self.chan_scale);
                }
            }
            Granularity::Channel => {
                let mut sbuf = [0f32; kernel::TILE];
                let mut zbuf = [0f32; kernel::TILE];
                for (j, p) in self.params.iter().enumerate() {
                    sbuf[j] = p.scale;
                    zbuf[j] = p.zero;
                }
                for r in 0..self.rows {
                    let row = &mut out[r * cols..(r + 1) * cols];
                    kernel::affine_cols_inplace(kind, row, &sbuf[..cols], &zbuf[..cols]);
                }
            }
            Granularity::Group(n) => {
                let groups = cols.div_ceil(n);
                for r in 0..self.rows {
                    for g in 0..groups {
                        let j0 = g * n;
                        let j1 = (j0 + n).min(cols);
                        let p = self.params[r * groups + g];
                        let seg = &mut out[r * cols + j0..r * cols + j1];
                        kernel::affine_inplace(kind, seg, p.zero, p.scale);
                    }
                }
            }
        }
    }

    /// Fused unpack–dequant, the portable scalar kernel (EXPERIMENTS.md
    /// §Perf): 1/2/4/8-bit lanes are decoded straight from the packed
    /// bytes via [`PackedCodes::for_each`], eliminating the `rows*cols`
    /// intermediate byte buffer the old two-pass kernel allocated on
    /// every materialization.  Bit-identical to the two-pass reference
    /// (same `QuantParams::decode` on the same codes in the same order;
    /// pinned by the `fused_dequant_matches_reference` property test).
    // lint: hot-path — steady materialization kernel (DESIGN.md §13).
    fn dequantize_scalar(&self, out: &mut [f32]) {
        let cols = self.cols;
        match self.granularity {
            Granularity::Token => {
                let params = &self.params;
                let (mut r, mut j) = (0usize, 0usize);
                self.codes.for_each(|i, c| {
                    out[i] = params[r].decode(c);
                    j += 1;
                    if j == cols {
                        j = 0;
                        r += 1;
                    }
                });
            }
            Granularity::ChannelSeparableToken => {
                let params = &self.params;
                let scale = &self.chan_scale;
                let (mut r, mut j) = (0usize, 0usize);
                self.codes.for_each(|i, c| {
                    out[i] = params[r].decode(c) * scale[j];
                    j += 1;
                    if j == cols {
                        j = 0;
                        r += 1;
                    }
                });
            }
            Granularity::Channel => {
                let params = &self.params;
                let mut j = 0usize;
                self.codes.for_each(|i, c| {
                    out[i] = params[j].decode(c);
                    j += 1;
                    if j == cols {
                        j = 0;
                    }
                });
            }
            Granularity::Group(n) => {
                let groups = cols.div_ceil(n);
                let params = &self.params;
                // Running (row, group, column-within-group) counters avoid
                // the per-element division of the two-pass kernel.
                let (mut base, mut g, mut jg, mut j) = (0usize, 0usize, 0usize, 0usize);
                self.codes.for_each(|i, c| {
                    out[i] = params[base + g].decode(c);
                    jg += 1;
                    j += 1;
                    if j == cols {
                        j = 0;
                        jg = 0;
                        g = 0;
                        base += groups;
                    } else if jg == n {
                        jg = 0;
                        g += 1;
                    }
                });
            }
        }
    }

    /// Two-pass unpack-then-decode reference implementation of
    /// [`QuantizedPlane::dequantize_into`] — kept as the oracle for the
    /// fused-kernel property tests.
    #[cfg(test)]
    pub(crate) fn dequantize_into_reference(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.rows * self.cols);
        let mut raw = vec![0u8; self.rows * self.cols];
        self.codes.unpack_into(&mut raw);
        match self.granularity {
            Granularity::Token => {
                for r in 0..self.rows {
                    let p = self.params[r];
                    for j in 0..self.cols {
                        out[r * self.cols + j] = p.decode(raw[r * self.cols + j]);
                    }
                }
            }
            Granularity::ChannelSeparableToken => {
                for r in 0..self.rows {
                    let p = self.params[r];
                    for j in 0..self.cols {
                        out[r * self.cols + j] =
                            p.decode(raw[r * self.cols + j]) * self.chan_scale[j];
                    }
                }
            }
            Granularity::Channel => {
                for r in 0..self.rows {
                    for j in 0..self.cols {
                        out[r * self.cols + j] = self.params[j].decode(raw[r * self.cols + j]);
                    }
                }
            }
            Granularity::Group(n) => {
                let groups = self.cols.div_ceil(n);
                for r in 0..self.rows {
                    for j in 0..self.cols {
                        let p = self.params[r * groups + j / n];
                        out[r * self.cols + j] = p.decode(raw[r * self.cols + j]);
                    }
                }
            }
        }
    }

    /// Dequantize a single row into `out` (`cols` long).
    // lint: hot-path — sparse row materialization (DESIGN.md §13).
    pub fn dequantize_row(&self, r: usize, out: &mut [f32]) {
        assert!(r < self.rows && out.len() == self.cols);
        match self.granularity {
            Granularity::Token | Granularity::ChannelSeparableToken => {
                let p = self.params[r];
                for (j, o) in out.iter_mut().enumerate() {
                    *o = p.decode(self.codes.get(r * self.cols + j));
                }
                if self.granularity == Granularity::ChannelSeparableToken {
                    for (j, o) in out.iter_mut().enumerate() {
                        *o *= self.chan_scale[j];
                    }
                }
            }
            Granularity::Channel => {
                for (j, o) in out.iter_mut().enumerate() {
                    *o = self.params[j].decode(self.codes.get(r * self.cols + j));
                }
            }
            Granularity::Group(n) => {
                let groups = self.cols.div_ceil(n);
                for (j, o) in out.iter_mut().enumerate() {
                    *o = self.params[r * groups + j / n]
                        .decode(self.codes.get(r * self.cols + j));
                }
            }
        }
    }

    /// Physical storage: packed codes + parameters.
    ///
    /// `param_bytes_per_value` lets callers use the paper's 16-bit parameter
    /// accounting (Appendix A) or honest f32 (4 bytes).
    pub fn storage_bytes(&self, param_bytes_per_value: usize) -> usize {
        self.codes.storage_bytes()
            + (2 * self.params.len() + self.chan_scale.len()) * param_bytes_per_value
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plane(rows: usize, cols: usize, seed: u64) -> Vec<f32> {
        // channel-outlier structure like the paper's Fig. 2
        (0..rows * cols)
            .map(|i| {
                let r = i / cols;
                let c = i % cols;
                let base = ((seed as f32 + r as f32 * 0.7 + c as f32 * 1.3).sin()) * 2.0;
                let outlier = if c % 7 == 0 { 8.0 } else { 1.0 };
                base * outlier
            })
            .collect()
    }

    fn mse(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| (x - y).powi(2)).sum::<f32>() / a.len() as f32
    }

    #[test]
    fn roundtrip_error_bounded_all_granularities() {
        let x = plane(32, 16, 3);
        for g in [Granularity::Token, Granularity::Channel, Granularity::Group(8),
                  Granularity::ChannelSeparableToken] {
            let q = QuantizedPlane::quantize(&x, 32, 16, 8, g);
            let mut out = vec![0f32; x.len()];
            q.dequantize_into(&mut out);
            assert!(mse(&x, &out) < 1e-3, "{g:?}: {}", mse(&x, &out));
        }
    }

    #[test]
    fn cst_beats_token_under_outliers() {
        let x = plane(64, 32, 5);
        let qt = QuantizedPlane::quantize(&x, 64, 32, 4, Granularity::Token);
        let qc = QuantizedPlane::quantize(&x, 64, 32, 4,
                                          Granularity::ChannelSeparableToken);
        let mut ot = vec![0f32; x.len()];
        let mut oc = vec![0f32; x.len()];
        qt.dequantize_into(&mut ot);
        qc.dequantize_into(&mut oc);
        assert!(mse(&x, &oc) < mse(&x, &ot));
    }

    #[test]
    fn row_dequant_matches_full() {
        let x = plane(16, 8, 9);
        for g in [Granularity::Token, Granularity::Channel, Granularity::Group(4),
                  Granularity::ChannelSeparableToken] {
            let q = QuantizedPlane::quantize(&x, 16, 8, 4, g);
            let mut full = vec![0f32; x.len()];
            q.dequantize_into(&mut full);
            let mut row = vec![0f32; 8];
            for r in 0..16 {
                q.dequantize_row(r, &mut row);
                assert_eq!(&row[..], &full[r * 8..(r + 1) * 8], "{g:?} row {r}");
            }
        }
    }

    #[test]
    fn param_counts_match_paper_formulas() {
        // paper §4.1: tokenwise 2bl pairs -> rows; groupwise 2bhld/n -> rows*cols/n
        assert_eq!(Granularity::Token.param_pairs(100, 64), 100);
        assert_eq!(Granularity::Channel.param_pairs(100, 64), 64);
        assert_eq!(Granularity::Group(32).param_pairs(100, 64), 200);
        assert_eq!(Granularity::ChannelSeparableToken.param_pairs(100, 64), 100);
        assert_eq!(Granularity::ChannelSeparableToken.channel_scales(64), 64);
    }

    #[test]
    fn storage_accounting() {
        let x = plane(64, 32, 1);
        let q = QuantizedPlane::quantize(&x, 64, 32, 2, Granularity::Token);
        // codes: 64*32 at 2 bits = 512 bytes; params: 2*64 at 2 bytes
        assert_eq!(q.storage_bytes(2), 512 + 256);
    }

    #[test]
    fn fused_dequant_matches_reference() {
        // Property: the fused unpack–dequant kernel is bit-identical to
        // the two-pass unpack-then-decode reference across every bit
        // width × granularity × ragged plane shape (rows/cols chosen so
        // packed rows straddle byte boundaries).
        use crate::util::prop::check;
        check("fused-dequant == two-pass reference", 120, |g| {
            let rows = g.usize_in(1, 33);
            let cols = g.usize_in(1, 40);
            let bits = *g.choice(&[1u8, 2, 4, 8]);
            let group_n = g.usize_in(1, cols + 3);
            let gran = *g.choice(&[
                Granularity::Token,
                Granularity::Channel,
                Granularity::Group(group_n),
                Granularity::ChannelSeparableToken,
            ]);
            let x = g.vec_f32(rows * cols, -6.0, 6.0);
            let q = QuantizedPlane::quantize(&x, rows, cols, bits, gran);
            let mut fused = vec![0f32; rows * cols];
            let mut reference = vec![0f32; rows * cols];
            q.dequantize_into(&mut fused);
            q.dequantize_into_reference(&mut reference);
            for (i, (a, b)) in fused.iter().zip(&reference).enumerate() {
                if a.to_bits() != b.to_bits() {
                    return Err(format!(
                        "{gran:?} {rows}x{cols}@{bits}b: element {i} \
                         fused {a} != reference {b}"
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn kernels_bit_identical_across_kinds() {
        // Scalar-vs-SIMD parity gate (DESIGN.md §15): every compiled-in
        // kernel kind available on this CPU must produce byte-identical
        // packed codes, bit-identical (s, z) params / channel scales and
        // bit-identical dequantized planes, across every bit width ×
        // granularity × ragged shape.
        use crate::quant::kernel::Kind;
        use crate::util::prop::check;
        let kinds: Vec<Kind> = kernel::compiled_kinds()
            .iter()
            .copied()
            .filter(|&k| kernel::available(k))
            .collect();
        check("scalar == simd quant/dequant", 120, |g| {
            let rows = g.usize_in(1, 33);
            let cols = g.usize_in(1, 40);
            let bits = *g.choice(&[1u8, 2, 4, 8]);
            let group_n = g.usize_in(1, cols + 3);
            let gran = *g.choice(&[
                Granularity::Token,
                Granularity::Channel,
                Granularity::Group(group_n),
                Granularity::ChannelSeparableToken,
            ]);
            let x = g.vec_f32(rows * cols, -6.0, 6.0);
            let base = QuantizedPlane::quantize_with(Kind::Scalar, &x, rows, cols, bits, gran);
            let mut want = vec![0f32; rows * cols];
            base.dequantize_into_with(Kind::Scalar, &mut want);
            for &k in &kinds {
                let q = QuantizedPlane::quantize_with(k, &x, rows, cols, bits, gran);
                if q.codes.as_bytes() != base.codes.as_bytes() {
                    return Err(format!(
                        "{gran:?} {rows}x{cols}@{bits}b: {k:?} packed bytes differ"
                    ));
                }
                for (p, bp) in q.params.iter().zip(&base.params) {
                    if p.scale.to_bits() != bp.scale.to_bits()
                        || p.zero.to_bits() != bp.zero.to_bits()
                    {
                        return Err(format!("{gran:?}: {k:?} params differ"));
                    }
                }
                for (a, b) in q.chan_scale.iter().zip(&base.chan_scale) {
                    if a.to_bits() != b.to_bits() {
                        return Err(format!("{gran:?}: {k:?} chan_scale differs"));
                    }
                }
                // Cross-materialization: the SIMD dequant must also
                // bit-match on the scalar-packed plane (and vice versa
                // the codes were pinned byte-identical above).
                let mut got = vec![0f32; rows * cols];
                q.dequantize_into_with(k, &mut got);
                let mut cross = vec![0f32; rows * cols];
                base.dequantize_into_with(k, &mut cross);
                for (i, (a, b)) in got.iter().zip(&want).enumerate() {
                    if a.to_bits() != b.to_bits() {
                        return Err(format!(
                            "{gran:?} {rows}x{cols}@{bits}b: element {i} \
                             {k:?} {a} != scalar {b}"
                        ));
                    }
                }
                for (i, (a, b)) in cross.iter().zip(&want).enumerate() {
                    if a.to_bits() != b.to_bits() {
                        return Err(format!(
                            "{gran:?} {rows}x{cols}@{bits}b: element {i} \
                             {k:?}-on-scalar-codes {a} != scalar {b}"
                        ));
                    }
                }
            }
            Ok(())
        });
    }
}
