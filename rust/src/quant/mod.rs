//! Uniform quantization primitives (paper §3.2, §4.1, Alg. 1).
//!
//! This module is the *physical* twin of the Pallas fake-quant kernels in
//! `python/compile/kernels/cstquant.py`: the same math (Eq. 5/6), but
//! producing bit-packed codes + quantization parameters, which is what the
//! KV cache manager actually stores.  `quantize -> dequantize` here must
//! agree with the Python oracle bit-for-bit (both use round-half-even);
//! cross-layer tests in `rust/tests/` verify this against the AOT
//! `quant_kv_*` HLO module.

pub mod kernel;
pub mod packing;
pub mod plane;

pub use kernel::{KernelChoice, Kind};
pub use packing::PackedCodes;
pub use plane::{Granularity, QuantizedPlane};

/// Quantization parameters of one group (Eq. 5): `x̂ = (clip(round(x/s)+z) - z) * s`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QuantParams {
    pub scale: f32,
    pub zero: f32,
}

impl QuantParams {
    /// Derive (s, z) from a min/max range at `bits` (Eq. 5).
    ///
    /// Degenerate ranges (constant data `c`) get `s = |c|` (or 1 for 0) and
    /// `z = 1` for negative `c`, so the constant round-trips exactly —
    /// matching `ref.uniform_quant` and the Pallas `_qparams` helper.
    #[inline]
    pub fn from_min_max(min: f32, max: f32, bits: u8) -> Self {
        let qmax = ((1u32 << bits) - 1) as f32;
        let s = (max - min) / qmax;
        if s <= 0.0 {
            let scale = if min.abs() > 0.0 { min.abs() } else { 1.0 };
            let zero = if min < 0.0 { 1.0 } else { 0.0 };
            return QuantParams { scale, zero };
        }
        let zero = -(min / s).round_ties_even();
        QuantParams { scale: s, zero }
    }

    /// Encode one value to its integer code.
    #[inline]
    pub fn encode(&self, x: f32, bits: u8) -> u8 {
        let qmax = ((1u32 << bits) - 1) as f32;
        let q = (x / self.scale).round_ties_even() + self.zero;
        q.clamp(0.0, qmax) as u8
    }

    /// Decode one integer code back to f32.
    #[inline]
    pub fn decode(&self, code: u8) -> f32 {
        (code as f32 - self.zero) * self.scale
    }
}

/// Min/max of a slice in one pass (NaN-free input assumed).
#[inline]
pub fn min_max(xs: &[f32]) -> (f32, f32) {
    let mut mn = f32::INFINITY;
    let mut mx = f32::NEG_INFINITY;
    for &x in xs {
        mn = mn.min(x);
        mx = mx.max(x);
    }
    (mn, mx)
}

/// Fake-quantize a slice in place with shared params (testing helper).
pub fn fake_quant_slice(xs: &mut [f32], bits: u8) {
    let (mn, mx) = min_max(xs);
    let p = QuantParams::from_min_max(mn, mx, bits);
    for x in xs.iter_mut() {
        *x = p.decode(p.encode(*x, bits));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn params_roundtrip_extremes() {
        let p = QuantParams::from_min_max(-2.0, 6.0, 4);
        // endpoints of the range must round-trip within one step
        for &v in &[-2.0f32, 6.0] {
            let d = p.decode(p.encode(v, 4));
            assert!((d - v).abs() <= p.scale * 0.5 + 1e-6, "{v} -> {d}");
        }
    }

    #[test]
    fn constant_slice_is_exact() {
        let mut xs = vec![3.5f32; 16];
        fake_quant_slice(&mut xs, 2);
        assert!(xs.iter().all(|&x| (x - 3.5).abs() < 1e-6));
    }

    #[test]
    fn more_bits_less_error() {
        let base: Vec<f32> = (0..64).map(|i| (i as f32 * 0.37).sin() * 3.0).collect();
        let mut errs = vec![];
        for bits in [2u8, 4, 8] {
            let mut xs = base.clone();
            fake_quant_slice(&mut xs, bits);
            let e: f32 = xs.iter().zip(&base).map(|(a, b)| (a - b).powi(2)).sum();
            errs.push(e);
        }
        assert!(errs[0] > errs[1] && errs[1] > errs[2], "{errs:?}");
    }

    #[test]
    fn encode_clips_out_of_range() {
        let p = QuantParams::from_min_max(0.0, 1.0, 2);
        assert_eq!(p.encode(-10.0, 2), 0);
        assert_eq!(p.encode(10.0, 2), 3);
    }
}
