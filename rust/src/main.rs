//! `zipcache` CLI: serve / eval / inspect over the AOT artifacts.
//!
//! Usage:
//!   zipcache <serve|eval|inspect> [--artifacts DIR] [--model NAME]
//!            [--policy fp16|h2o|gear|kivi|mikv|zipcache] [flags...]

use zipcache::config::{EngineConfig, PolicyKind};
use zipcache::coordinator::Engine;
use zipcache::quant::KernelChoice;
use zipcache::eval::{score_generation, AccuracyReport};
use zipcache::kvcache::ratio::RatioShape;
use zipcache::server::{loadgen, Server};
use zipcache::util::cli::Args;
use zipcache::workload::{RequestTrace, Task, TaskGen};
use zipcache::Result;

fn parse_task(s: &str) -> Result<Task> {
    Ok(match s {
        "gsm" => Task::Gsm,
        "code" => Task::Code,
        _ if s.starts_with("lines") => Task::Lines(s[5..].parse()?),
        other => anyhow::bail!("unknown task '{other}' (gsm|code|linesN)"),
    })
}

fn main() -> Result<()> {
    let args = Args::new(
        "zipcache",
        "ZipCache KV-cache quantization serving engine (NeurIPS 2024 reproduction)\n\
         subcommands: serve | eval | inspect",
    )
    .flag("artifacts", "artifacts", "artifacts directory")
    .flag("model", "tiny", "model config from the manifest")
    .flag("policy", "zipcache", "fp16|h2o|gear|kivi|mikv|zipcache")
    .flag("saliency-ratio", "0.6", "fraction of tokens at high precision")
    .flag("quant-kernel", "auto",
          "quant/dequant kernel: auto | scalar | simd \
           (ZIPCACHE_FORCE_SCALAR=1 overrides)")
    .flag("parallelism", "0", "compression worker threads (0 = per-core)")
    .flag("shards", "1", "serve: engine shards (0 = per-core)")
    .flag("memory-slots", "0",
          "dense materialization slots per shard (0 = max_batch)")
    .flag("memory-budget", "0",
          "per-shard worst-case byte budget for admission (0 = unlimited)")
    .flag("prefill-chunk", "0",
          "prefill chunk size in tokens (0 = monolithic single pass)")
    .switch("prefix-cache",
            "enable the shared-prefix segment store (DESIGN.md §16)")
    .flag("prefix-max-bytes", "0",
          "per-shard byte cap on interned prefix segments (0 = unlimited; \
           required non-zero and below --memory-budget when both are set)")
    .flag("config", "", "optional key=value config file (overrides flags)")
    .flag("task", "gsm", "gsm | code | linesN (e.g. lines20)")
    .flag("samples", "50", "eval: number of samples")
    .flag("max-new", "4", "decode budget per request")
    .flag("requests", "16", "serve: number of requests")
    .flag("rate", "8.0", "serve: arrival rate (req/s)")
    .flag("trace", "poisson",
          "serve: poisson | memory-pressure | priority-mix | long-prompt-burst \
           | chaos | shared-prefix")
    .flag("fault-plan", "",
          "serve: fault-injection plan, e.g. 'shard0:decode:2:panic' \
           (DESIGN.md §14; empty = fault-free)")
    .flag("seed", "0", "base seed")
    .parse()?;

    let cfg = build_config(&args)?;
    let cmd = args
        .positionals()
        .first()
        .map(|s| s.as_str())
        .unwrap_or("inspect");
    match cmd {
        "inspect" => inspect(cfg),
        "eval" => eval(
            cfg,
            parse_task(&args.get("task"))?,
            args.get_usize("samples")?,
            args.get_usize("max-new")?,
            args.get_u64("seed")?,
        ),
        "serve" => serve(
            cfg,
            parse_task(&args.get("task"))?,
            args.get_usize("requests")?,
            args.get_f64("rate")?,
            args.get_usize("max-new")?,
            &args.get("trace"),
        ),
        other => anyhow::bail!("unknown subcommand '{other}'\n{}", args.usage()),
    }
}

fn build_config(args: &Args) -> Result<EngineConfig> {
    let path = args.get("config");
    if !path.is_empty() {
        return EngineConfig::from_file(&path);
    }
    let mut cfg = EngineConfig::load_default(args.get("artifacts"), &args.get("model"))?;
    cfg.policy = args.get("policy").parse::<PolicyKind>()?;
    cfg.quant.saliency_ratio = args.get_f64("saliency-ratio")?;
    cfg.quant.kernel = args.get("quant-kernel").parse::<KernelChoice>()?;
    cfg.parallelism = args.get_usize("parallelism")?;
    cfg.scheduler.shards = args.get_usize("shards")?;
    cfg.memory.slots = args.get_usize("memory-slots")?;
    cfg.memory.budget_bytes = args.get_usize("memory-budget")?;
    cfg.scheduler.prefill_chunk = args.get_usize("prefill-chunk")?;
    cfg.prefix.enable = args.get_bool("prefix-cache");
    cfg.prefix.max_bytes = args.get_usize("prefix-max-bytes")?;
    cfg.faults.plan = args.get("fault-plan");
    cfg.seed = args.get_u64("seed")?;
    cfg.faults.seed = cfg.seed;
    cfg.validate()?;
    Ok(cfg)
}

fn inspect(cfg: EngineConfig) -> Result<()> {
    let engine = Engine::new(cfg.clone())?;
    let info = engine.runtime().model_info();
    println!(
        "model     : {} ({:.2}M params, trained={})",
        cfg.model,
        info.n_params as f64 / 1e6,
        info.trained.is_some()
    );
    println!(
        "layout    : L={} H={} S={} dh={} vocab={}",
        info.n_layers, info.n_heads, info.max_seq, info.d_head, info.vocab
    );
    let mut entries = engine.runtime().entries();
    entries.sort_unstable();
    println!("entries   : {entries:?}");
    println!("policy    : {}", engine.policy_name());
    let shape = RatioShape {
        b: 1,
        hd: info.n_heads * info.d_head,
        l: info.max_seq,
    };
    println!("analytic compression ratios at l={} (paper accounting):", info.max_seq);
    use zipcache::baselines::standard_policies;
    for p in standard_policies(cfg.quant.saliency_ratio) {
        println!("  {:9}: {:.2}x", p.name(), p.analytic_ratio(shape));
    }
    Ok(())
}

fn eval(cfg: EngineConfig, task: Task, samples: usize, max_new: usize, seed: u64)
        -> Result<()> {
    let mut engine = Engine::new(cfg.clone())?;
    let info = engine.runtime().model_info().clone();
    let gen = TaskGen::new(task, info.max_seq - max_new);
    let mut report = AccuracyReport::default();
    let mut ratio_sum = 0.0;
    let t0 = std::time::Instant::now();
    for i in 0..samples {
        let s = gen.sample(seed.wrapping_add(i as u64 * 7919));
        let out = engine.generate(s.prompt(), max_new)?;
        report.add(score_generation(&s, &out.tokens));
        ratio_sum += out.compression_ratio;
    }
    println!(
        "policy={} task={task:?} samples={samples} acc={:.2}% ratio={:.2}x wall={:.1}s",
        engine.policy_name(),
        report.accuracy_pct,
        ratio_sum / samples as f64,
        t0.elapsed().as_secs_f64()
    );
    println!(
        "prefill p50={:.1}ms decode/tok p50={:.2}ms",
        engine.metrics.prefill.p50_ms(),
        engine.metrics.decode.p50_ms()
    );
    let st = &engine.metrics.compress_stages;
    if st.quant_wall.count() > 0 {
        println!(
            "compress stages (threads={}): split p50={:.3}ms quant p50={:.3}ms \
             (speedup {:.1}x) concat p50={:.3}ms",
            st.threads,
            st.split.p50_ms(),
            st.quant_wall.p50_ms(),
            st.mean_quant_speedup(),
            st.concat.p50_ms()
        );
    }
    Ok(())
}

fn serve(cfg: EngineConfig, task: Task, requests: usize, rate: f64, max_new: usize,
         trace_kind: &str) -> Result<()> {
    // Window sizing: leave decode headroom inside the model's window.
    let info = zipcache::runtime::load_model_info(&cfg.artifacts_dir, &cfg.model)?;
    anyhow::ensure!(max_new >= 1 && max_new < info.max_seq,
                    "max-new must be in [1, {}) for model '{}'",
                    info.max_seq, cfg.model);
    let server = Server::start(cfg.clone())?;
    // Logged once: the kind the engines resolved (after config/env
    // overrides), vs. what the config requested (DESIGN.md §15).
    println!("quant kernel : {} (requested {})",
             zipcache::quant::kernel::active().name(), cfg.quant.kernel);
    let trace = match trace_kind {
        "poisson" => RequestTrace::poisson(task, info.max_seq - max_new, requests,
                                           rate, max_new, cfg.seed),
        "memory-pressure" => loadgen::memory_pressure_trace(info.max_seq, requests,
                                                            cfg.seed),
        "priority-mix" => loadgen::priority_mix_trace(info.max_seq, requests,
                                                      max_new, cfg.seed),
        "long-prompt-burst" => loadgen::long_prompt_burst_trace(
            info.max_seq, requests, max_new, cfg.seed),
        "chaos" => loadgen::chaos_trace(info.max_seq, requests, cfg.seed),
        // One roll: a warm phase on the shared system prompt, then the
        // prompt rotates and the store churns (DESIGN.md §16).
        "shared-prefix" => loadgen::shared_prefix_trace(info.max_seq, requests, 1,
                                                        cfg.seed),
        other => anyhow::bail!(
            "unknown trace '{other}' \
             (poisson|memory-pressure|priority-mix|long-prompt-burst|chaos\
             |shared-prefix)"
        ),
    };
    let report = loadgen::replay(&server.handle, &trace)?;

    let mut acc = AccuracyReport::default();
    for (i, out) in &report.outputs {
        // Cancelled / deadline-shed requests carry no (full) answer;
        // accuracy covers natural completions only.
        if out.finish.is_natural() {
            acc.add(score_generation(&trace.entries[*i].sample, &out.tokens));
        }
    }
    println!(
        "served {}/{requests} requests in {:.2}s across {} shard(s) — \
         {:.1} req/s, {:.1} tok/s, acc {:.1}% (rejected {}, failed {}, \
         cancelled {}, shed {}, shard-failed {})",
        report.completed,
        report.wall.as_secs_f64(),
        server.handle.shards(),
        report.requests_per_second(),
        report.tokens_per_second(),
        acc.accuracy_pct,
        report.rejected,
        report.failed,
        report.cancelled,
        report.shed,
        report.shard_failed,
    );
    println!("request latency p50={:.0}ms p99={:.0}ms",
             report.latency.p50_ms(), report.latency.p99_ms());
    // Let supervision settle before the snapshot (DESIGN.md §14): the
    // replay can drain on the surviving shards while a killed shard is
    // still inside its restart backoff, and the supervision counters
    // below should reflect the completed recovery.  Bounded wait — a
    // shard past `faults.max_restarts` stays dead forever.
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(2);
    while server.handle.shard_alive().iter().any(|a| !*a)
        && std::time::Instant::now() < deadline
    {
        std::thread::yield_now();
    }
    let snap = server.handle.metrics();
    println!(
        "engine histograms: prefill p50={:.2}ms decode/step p50={:.3}ms \
         compress p50={:.3}ms (n={})",
        snap.total.prefill.p50_ms(),
        snap.total.decode.p50_ms(),
        snap.total.compress.p50_ms(),
        snap.total.compress.count(),
    );
    if snap.total.prefill_chunks > 0 {
        println!(
            "chunked prefill: {} chunk(s), per-chunk p50={:.3}ms p99={:.3}ms",
            snap.total.prefill_chunks,
            snap.total.prefill_chunk.p50_ms(),
            snap.total.prefill_chunk.p99_ms(),
        );
    }
    println!(
        "memory: peak resident {:.1} KiB across shards, {} park cycle(s)",
        snap.total.peak_resident_bytes as f64 / 1024.0,
        snap.total.park_cycles,
    );
    println!(
        "priority (admitted/completed/shed by class, DESIGN.md §11): \
         interactive {}/{}/{}, batch {}/{}/{}, background {}/{}/{}; \
         cancelled {}",
        snap.total.admitted_by_priority[0],
        snap.total.completed_by_priority[0],
        snap.total.shed_by_priority[0],
        snap.total.admitted_by_priority[1],
        snap.total.completed_by_priority[1],
        snap.total.shed_by_priority[1],
        snap.total.admitted_by_priority[2],
        snap.total.completed_by_priority[2],
        snap.total.shed_by_priority[2],
        snap.total.cancelled,
    );
    println!(
        "supervision (DESIGN.md §14): restarts {}, redelivered {}, \
         failed sessions {}",
        snap.total.shard_restarts,
        snap.total.redelivered,
        snap.total.failed_sessions,
    );
    if cfg.prefix.enable {
        println!(
            "prefix cache (DESIGN.md §16): prefix_hits {} (trace expected {}), \
             prefix_misses {} (expected {}), prefill tokens skipped {}, \
             prefix_evictions {}, shared_segment_bytes {}",
            snap.total.prefix_hits,
            report.expected_prefix_hits,
            snap.total.prefix_misses,
            report.expected_prefix_misses,
            snap.total.prefill_tokens_skipped,
            snap.total.prefix_evictions,
            snap.total.shared_segment_bytes,
        );
    }
    for (i, m) in snap.per_shard.iter().enumerate() {
        println!("  shard {i}: {} req, {} tok", m.requests_completed,
                 m.tokens_generated);
    }
    server.shutdown()
}
