//! Token-saliency metrics: Eq. (7) vs Eq. (8).
//!
//! The coordinator normally receives per-layer saliency vectors straight
//! from the prefill artifacts (the L1 probe kernel computes Eq. 8 on
//! device); the score-matrix functions here serve the baselines (MiKV/H2O
//! run on accumulated scores from the full-attention artifact), the
//! streaming decode path, and the Fig. 3 demo.

use crate::quant::kernel;

/// Which metric a compression policy ranks tokens by.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SaliencyMetric {
    /// Eq. (7): column sums of the attention matrix (H2O, MiKV).
    Accumulated,
    /// Eq. (8): column sums / column nnz (ZipCache).
    Normalized,
}

/// Eq. (7) over a lower-triangular score matrix `a` (`rows x cols`,
/// row-major): `p_i = sum_k A[k, i]`.
pub fn accumulated_saliency(a: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    assert_eq!(a.len(), rows * cols);
    // Row-major accumulation order is fixed, so the vectorized add is
    // elementwise per column — bit-identical to the scalar loop
    // (DESIGN.md §15).  Dispatch resolves once, outside the row loop.
    let kind = kernel::active();
    let mut p = vec![0f32; cols];
    for r in 0..rows {
        kernel::add_assign(kind, &mut p, &a[r * cols..(r + 1) * cols]);
    }
    p
}

/// Eq. (8) over a causal score matrix: `p̃_i = sum_k A[k,i] / nnz(A[:,i])`,
/// with nnz derived from the causal structure (`nnz_i = rows - i` when
/// rows == cols), never from exact zero counting.
pub fn normalized_saliency(a: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    let mut p = accumulated_saliency(a, rows, cols);
    let offs = cols as isize - rows as isize;
    for (i, pi) in p.iter_mut().enumerate() {
        // column i is visible to query rows k with k + offs >= i
        let first_row = (i as isize - offs).max(0) as usize;
        let nnz = rows.saturating_sub(first_row).max(1);
        *pi /= nnz as f32;
    }
    p
}

/// Probe-row approximation of Eq. (8) (paper §4.3): `a_probe` holds only
/// the rows at `probe_idx` (ascending query positions); coverage of column
/// i is the number of probes at position >= i.
pub fn probe_normalized_saliency(
    a_probe: &[f32],
    probe_idx: &[usize],
    cols: usize,
) -> Vec<f32> {
    let p = probe_idx.len();
    assert_eq!(a_probe.len(), p * cols);
    let kind = kernel::active();
    let mut sums = vec![0f32; cols];
    for r in 0..p {
        kernel::add_assign(kind, &mut sums, &a_probe[r * cols..(r + 1) * cols]);
    }
    divide_by_coverage(&mut sums, probe_idx);
    sums
}

/// [`probe_normalized_saliency`] over the streaming accumulator's
/// per-probe row buffers directly — same Eq. 8 approximation, same
/// accumulation order, without first flattening the rows into a staging
/// buffer (DESIGN.md §15 removed that copy from the recompression
/// boundary).
pub fn probe_normalized_saliency_rows(
    rows: &[Vec<f32>],
    probe_idx: &[usize],
    cols: usize,
) -> Vec<f32> {
    assert_eq!(rows.len(), probe_idx.len());
    let kind = kernel::active();
    let mut sums = vec![0f32; cols];
    for row in rows {
        assert_eq!(row.len(), cols, "probe row width mismatch");
        kernel::add_assign(kind, &mut sums, row);
    }
    divide_by_coverage(&mut sums, probe_idx);
    sums
}

/// Divide column sums by probe coverage: probes are sorted ascending,
/// so coverage of column i is the count of probe positions >= i.
fn divide_by_coverage(sums: &mut [f32], probe_idx: &[usize]) {
    for (i, s) in sums.iter_mut().enumerate() {
        let cover = probe_idx.len() - probe_idx.partition_point(|&x| x < i);
        *s /= cover.max(1) as f32;
    }
}

/// Rank tokens by `saliency` and mark the top `ratio` fraction (of the
/// first `n_tokens`) as salient.  Ties break toward earlier tokens for
/// determinism.  Returns a bool mask of length `n_tokens`.
pub fn select_salient(saliency: &[f32], n_tokens: usize, ratio: f64) -> Vec<bool> {
    let n = n_tokens.min(saliency.len());
    let k = ((n as f64) * ratio).round() as usize;
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| {
        saliency[b].partial_cmp(&saliency[a]).unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    let mut mask = vec![false; n];
    for &i in idx.iter().take(k) {
        mask[i] = true;
    }
    mask
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Uniform causal attention: row k spreads 1/(k+1) over columns 0..=k.
    fn uniform_causal(l: usize) -> Vec<f32> {
        let mut a = vec![0f32; l * l];
        for k in 0..l {
            for i in 0..=k {
                a[k * l + i] = 1.0 / (k + 1) as f32;
            }
        }
        a
    }

    #[test]
    fn accumulated_biased_to_token_zero() {
        // The paper's Fig. 3(a) argument: under uniform attention the first
        // token accumulates the harmonic series while the last gets 1/l.
        let l = 16;
        let a = uniform_causal(l);
        let acc = accumulated_saliency(&a, l, l);
        assert!(acc[0] > 3.0 * acc[l - 1]);
        // and acc[0] = H_l > 1 while every row sums to exactly 1
        assert!(acc[0] > 1.0);
    }

    #[test]
    fn normalized_removes_positional_bias() {
        let l = 16;
        let a = uniform_causal(l);
        let nrm = normalized_saliency(&a, l, l);
        // ratio between max and min should be far smaller than accumulated's
        let acc = accumulated_saliency(&a, l, l);
        let spread = |v: &[f32]| {
            let mx = v.iter().cloned().fold(f32::MIN, f32::max);
            let mn = v.iter().cloned().fold(f32::MAX, f32::min);
            mx / mn
        };
        assert!(spread(&nrm) < spread(&acc) / 2.0);
    }

    #[test]
    fn normalized_finds_late_hot_token() {
        // Plant a hot column late in the sequence: rows after `hot` put
        // half their mass on it, everything else is uniform.  Accumulated
        // scores still rank token 0 on top (it collects the harmonic series
        // over 32 rows); normalized scores rank the hot token on top — the
        // exact bias the paper's Fig. 3 criticizes.
        let l = 32;
        let hot = 28;
        let mut a = vec![0f32; l * l];
        for k in 0..l {
            let cols = (k + 1) as f32;
            let w = if k > hot { 0.5 } else { 0.0 };
            for i in 0..=k {
                a[k * l + i] = (1.0 - w) / cols;
            }
            if k > hot {
                a[k * l + hot] += w;
            }
        }
        let acc = accumulated_saliency(&a, l, l);
        let nrm = normalized_saliency(&a, l, l);
        let argmax = |v: &[f32]| {
            v.iter().enumerate().max_by(|x, y| x.1.partial_cmp(y.1).unwrap()).unwrap().0
        };
        assert_eq!(argmax(&nrm), hot);
        assert_eq!(argmax(&acc), 0); // the bias the paper criticizes
    }

    #[test]
    fn probe_approx_equals_exact_when_all_rows_probed() {
        let l = 24;
        let a = uniform_causal(l);
        let idx: Vec<usize> = (0..l).collect();
        let approx = probe_normalized_saliency(&a, &idx, l);
        let exact = normalized_saliency(&a, l, l);
        for (x, y) in approx.iter().zip(&exact) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn probe_subset_correlates() {
        let l = 64;
        let a = uniform_causal(l);
        let idx: Vec<usize> = (0..l).step_by(4).collect();
        let mut ap = Vec::new();
        for &r in &idx {
            ap.extend_from_slice(&a[r * l..(r + 1) * l]);
        }
        let approx = probe_normalized_saliency(&ap, &idx, l);
        let exact = normalized_saliency(&a, l, l);
        // uniform case: both should be nearly flat over covered columns
        for i in 0..l - 4 {
            assert!((approx[i] - exact[i]).abs() < 0.05, "{i}");
        }
    }

    #[test]
    fn rows_variant_matches_flat_probe_saliency() {
        // The no-flatten rows entry point must be bit-identical to the
        // flat-buffer one: same rows, same order, same coverage divide.
        let l = 24;
        let a = uniform_causal(l);
        let idx: Vec<usize> = (0..l).step_by(3).collect();
        let mut flat = Vec::new();
        let mut rows: Vec<Vec<f32>> = Vec::new();
        for &r in &idx {
            flat.extend_from_slice(&a[r * l..(r + 1) * l]);
            rows.push(a[r * l..(r + 1) * l].to_vec());
        }
        let from_flat = probe_normalized_saliency(&flat, &idx, l);
        let from_rows = probe_normalized_saliency_rows(&rows, &idx, l);
        for (i, (x, y)) in from_rows.iter().zip(&from_flat).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "col {i}: {x} vs {y}");
        }
    }

    #[test]
    fn select_salient_topk() {
        let sal = vec![0.1, 0.9, 0.3, 0.9, 0.05];
        let mask = select_salient(&sal, 5, 0.4);
        assert_eq!(mask, vec![false, true, false, true, false]);
        // ratio 0 -> none; ratio 1 -> all
        assert!(select_salient(&sal, 5, 0.0).iter().all(|&m| !m));
        assert!(select_salient(&sal, 5, 1.0).iter().all(|&m| m));
    }

    #[test]
    fn select_salient_deterministic_ties() {
        let sal = vec![0.5; 8];
        let mask = select_salient(&sal, 8, 0.5);
        assert_eq!(mask, vec![true, true, true, true, false, false, false, false]);
    }
}
