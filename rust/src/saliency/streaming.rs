//! Streaming probe accumulator for the decode phase (paper Alg. 3).
//!
//! During decoding, ZipCache keeps collecting probe attention rows: a row
//! is recorded if the step index is in the trailing 5% of the window
//! (`i > 95` in the paper's 100-token cycle) or with 5% probability
//! (deterministic SplitMix64 draw).  Every `recompress_every` (=100)
//! generated tokens, the accumulated rows approximate Eq. 8 for the whole
//! prefix and the cache is recompressed; the accumulator then resets.

use crate::saliency::metric::probe_normalized_saliency_rows;
use crate::workload::rng::SplitMix64;

/// Decision + storage for streaming decode-time probes.
#[derive(Debug, Clone)]
pub struct StreamingProbe {
    /// Recompression period (100 in the paper).
    pub recompress_every: usize,
    /// Fraction of recent steps always probed (0.05).
    pub recent_ratio: f64,
    /// Probability of probing a non-recent step (0.05).
    pub random_ratio: f64,
    rng: SplitMix64,
    step_in_cycle: usize,
    rows: Vec<Vec<f32>>,      // probe attention rows (length = window cols)
    row_positions: Vec<usize>, // absolute query position of each row
    /// Retired row buffers recycled across cycles (DESIGN.md §9): after
    /// the first cycle, recording a probe row costs a copy, not an
    /// allocation.
    free: Vec<Vec<f32>>,
}

impl StreamingProbe {
    pub fn new(recompress_every: usize, recent_ratio: f64, random_ratio: f64,
               seed: u64) -> Self {
        StreamingProbe {
            recompress_every,
            recent_ratio,
            random_ratio,
            rng: SplitMix64::new(seed ^ 0xA076_1D64_78BD_642F),
            step_in_cycle: 0,
            rows: Vec::new(),
            row_positions: Vec::new(),
            free: Vec::new(),
        }
    }

    /// Pre-warm the row pool with `n` buffers of `cols` capacity, so the
    /// first cycle's recordings allocate nothing either (the steady-state
    /// bench reserves `recompress_every` rows — the per-cycle maximum).
    pub fn reserve_rows(&mut self, n: usize, cols: usize) {
        self.rows.reserve(n);
        self.row_positions.reserve(n);
        while self.free.len() < n {
            self.free.push(Vec::with_capacity(cols));
        }
    }

    /// Should the caller record this step's attention row?  (Alg. 3's
    /// `i > 95 or randint(0,100) < 5` condition, generalized.)
    // lint: hot-path — per-step probe decision (DESIGN.md §13).
    pub fn should_probe(&mut self) -> bool {
        let recent_from =
            self.recompress_every - (self.recompress_every as f64 * self.recent_ratio) as usize;
        if self.step_in_cycle >= recent_from {
            return true;
        }
        (self.rng.below(1000) as f64) < self.random_ratio * 1000.0
    }

    /// Record one probe attention row (`a_row` over the cache columns) for
    /// the query at absolute position `pos`.  Reuses a retired buffer
    /// when one is available.
    // lint: hot-path — steady probe recording (DESIGN.md §13).
    pub fn record(&mut self, a_row: &[f32], pos: usize) {
        let mut buf = self.free.pop().unwrap_or_default();
        buf.clear();
        buf.extend_from_slice(a_row);
        self.rows.push(buf);
        self.row_positions.push(pos);
    }

    /// Advance one decode step; returns `true` when a recompression is due.
    // lint: hot-path — per-step cycle advance (DESIGN.md §13).
    pub fn step(&mut self) -> bool {
        self.step_in_cycle += 1;
        self.step_in_cycle >= self.recompress_every
    }

    /// Number of rows currently accumulated.
    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Approximate normalized saliency over `cols` cache positions from the
    /// accumulated rows, then reset the cycle (Alg. 3's `A_probe = None`).
    // lint: cold-path — runs once per recompression cycle, outside the
    // §9 steady-step contract (DESIGN.md §13).
    pub fn take_saliency(&mut self, cols: usize) -> Option<Vec<f32>> {
        if self.rows.is_empty() {
            self.reset();
            return None;
        }
        // Reduces the recorded rows in place — no flattening copy; the
        // width assert lives inside the rows entry point.
        let sal = probe_normalized_saliency_rows(&self.rows, &self.row_positions, cols);
        self.reset();
        Some(sal)
    }

    fn reset(&mut self) {
        self.step_in_cycle = 0;
        self.free.append(&mut self.rows); // recycle row buffers
        self.row_positions.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recent_steps_always_probe() {
        let mut sp = StreamingProbe::new(100, 0.05, 0.0, 1);
        let mut probed = vec![];
        for i in 0..100 {
            if sp.should_probe() {
                probed.push(i);
            }
            sp.step();
        }
        // last 5 steps of the cycle must all be probed
        for i in 95..100 {
            assert!(probed.contains(&i));
        }
        // and no random probes since random_ratio = 0
        assert_eq!(probed.len(), 5);
    }

    #[test]
    fn random_probe_rate_close_to_ratio() {
        let mut sp = StreamingProbe::new(1_000_000, 0.0, 0.05, 2);
        let mut hits = 0;
        for _ in 0..20_000 {
            if sp.should_probe() {
                hits += 1;
            }
            sp.step();
        }
        let rate = hits as f64 / 20_000.0;
        assert!((rate - 0.05).abs() < 0.01, "{rate}");
    }

    #[test]
    fn cycle_triggers_recompression() {
        let mut sp = StreamingProbe::new(10, 0.1, 0.0, 3);
        let mut due = 0;
        for _ in 0..10 {
            if sp.step() {
                due += 1;
                sp.take_saliency(4);
            }
        }
        assert_eq!(due, 1);
    }

    #[test]
    fn saliency_from_recorded_rows() {
        let mut sp = StreamingProbe::new(10, 0.5, 0.0, 4);
        sp.record(&[0.5, 0.25, 0.25, 0.0], 2);
        sp.record(&[0.1, 0.1, 0.4, 0.4], 3);
        let sal = sp.take_saliency(4).unwrap();
        // col 0: (0.5+0.1)/2; col 3 covered only by the pos-3 probe: 0.4/1
        assert!((sal[0] - 0.3).abs() < 1e-6);
        assert!((sal[3] - 0.4).abs() < 1e-6);
        assert_eq!(sp.n_rows(), 0); // reset happened
    }

    #[test]
    fn empty_cycle_yields_none() {
        let mut sp = StreamingProbe::new(10, 0.0, 0.0, 5);
        assert!(sp.take_saliency(4).is_none());
    }

    #[test]
    fn row_buffers_recycle_across_cycles() {
        let mut sp = StreamingProbe::new(4, 1.0, 0.0, 9);
        sp.reserve_rows(4, 4);
        // Two cycles with identical recordings: the pooled path must not
        // change the computed saliency.
        let mut sals = vec![];
        for _ in 0..2 {
            sp.record(&[0.5, 0.25, 0.25, 0.0], 2);
            sp.record(&[0.1, 0.1, 0.4, 0.4], 3);
            sals.push(sp.take_saliency(4).unwrap());
            assert_eq!(sp.n_rows(), 0);
        }
        assert_eq!(sals[0], sals[1]);
    }

    /// Uniform causal attention over `n` query rows: row k spreads
    /// 1/(k+1) over columns 0..=k.
    fn uniform_causal(n: usize) -> Vec<f32> {
        let mut a = vec![0f32; n * n];
        for k in 0..n {
            for i in 0..=k {
                a[k * n + i] = 1.0 / (k + 1) as f32;
            }
        }
        a
    }

    #[test]
    fn full_cycle_matches_normalized_saliency_ground_truth() {
        // A cycle that probes *every* step must reproduce Eq. 8 exactly:
        // take_saliency == metric::normalized_saliency over the same
        // score matrix (full probe coverage is the paper's exact case).
        use crate::saliency::metric::normalized_saliency;
        let n = 8;
        let mut sp = StreamingProbe::new(n, 1.0, 0.0, 7);
        let a = uniform_causal(n);
        for k in 0..n {
            assert!(sp.should_probe(), "recent_ratio=1.0 probes every step");
            sp.record(&a[k * n..(k + 1) * n], k);
            assert_eq!(sp.step(), k == n - 1,
                       "recompression due exactly at the cycle boundary");
        }
        let sal = sp.take_saliency(n).unwrap();
        let want = normalized_saliency(&a, n, n);
        for (i, (x, y)) in sal.iter().zip(&want).enumerate() {
            assert!((x - y).abs() < 1e-6, "col {i}: {x} vs {y}");
        }
    }

    #[test]
    fn cycles_do_not_leak_into_each_other() {
        // Drive two full probe-everything cycles with *different* score
        // matrices: each take_saliency must equal the ground truth of its
        // own cycle's rows only (the reset really clears the accumulator).
        use crate::saliency::metric::normalized_saliency;
        let n = 6;
        let mut sp = StreamingProbe::new(n, 1.0, 0.0, 3);
        let uniform = uniform_causal(n);
        // second cycle: all mass on column 2 (a planted hot token)
        let mut hot = vec![0f32; n * n];
        for k in 2..n {
            hot[k * n + 2] = 1.0;
        }
        for (matrix, label) in [(&uniform, "uniform"), (&hot, "hot")] {
            let mut due = 0;
            for k in 0..n {
                sp.record(&matrix[k * n..(k + 1) * n], k);
                if sp.step() {
                    due += 1;
                }
            }
            assert_eq!(due, 1, "{label}: one recompression per cycle");
            let sal = sp.take_saliency(n).unwrap();
            let want = normalized_saliency(matrix, n, n);
            for (i, (x, y)) in sal.iter().zip(&want).enumerate() {
                assert!((x - y).abs() < 1e-6, "{label} col {i}: {x} vs {y}");
            }
            assert_eq!(sp.n_rows(), 0, "{label}: accumulator reset");
        }
    }

    #[test]
    fn cycle_period_stays_aligned_across_cycles() {
        // step() must fire every `recompress_every` steps regardless of
        // how many rows were recorded, across many cycles.
        let mut sp = StreamingProbe::new(5, 0.0, 0.0, 11);
        let mut due_steps = Vec::new();
        for i in 1..=23 {
            if sp.step() {
                due_steps.push(i);
                sp.take_saliency(4); // engine always drains at the boundary
            }
        }
        assert_eq!(due_steps, vec![5, 10, 15, 20]);
    }
}
