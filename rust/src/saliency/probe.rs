//! Probe-token selection strategies (paper §4.3, Table 2).
//!
//! The paper compares four strategies and adopts the hybrid
//! `Random+Recent` (5% recent + 5% random).  Selection is deterministic in
//! the request seed via the same SplitMix64 the workload generators use, so
//! runs reproduce exactly.

use crate::workload::rng::SplitMix64;

/// Probe sampling strategies (Table 2 rows).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ProbeStrategy {
    /// Every token is a probe (exact Eq. 8; the "All tokens" row).
    All,
    /// Uniform random positions.
    Random,
    /// Positions of special/punctuation tokens (caller supplies the mask).
    Special,
    /// The trailing window.
    Recent,
    /// The paper's default: half recent, half random from the remainder.
    RandomRecent,
}

/// Select probe indices among `n_tokens` prompt positions.
///
/// `ratio` is the total probe fraction (0.10 in the paper); for
/// `RandomRecent` it is split evenly.  `special_mask` marks tokens eligible
/// for the `Special` strategy (ignored otherwise).  Returns sorted, unique,
/// non-empty indices (at least one probe: the last token).
pub fn select_probes(
    strategy: ProbeStrategy,
    n_tokens: usize,
    ratio: f64,
    special_mask: Option<&[bool]>,
    seed: u64,
) -> Vec<usize> {
    assert!(n_tokens > 0);
    let want = ((n_tokens as f64 * ratio).round() as usize).clamp(1, n_tokens);
    let mut rng = SplitMix64::new(seed ^ 0x9E37_79B9_7F4A_7C15);
    let mut picks: Vec<usize> = match strategy {
        ProbeStrategy::All => (0..n_tokens).collect(),
        ProbeStrategy::Random => sample_without_replacement(&mut rng, 0..n_tokens, want),
        ProbeStrategy::Special => {
            let mask = special_mask.expect("Special strategy needs a token mask");
            let mut v: Vec<usize> =
                (0..n_tokens).filter(|&i| *mask.get(i).unwrap_or(&false)).collect();
            v.truncate(want);
            if v.is_empty() {
                v.push(n_tokens - 1);
            }
            v
        }
        ProbeStrategy::Recent => (n_tokens.saturating_sub(want)..n_tokens).collect(),
        ProbeStrategy::RandomRecent => {
            let n_recent = (want / 2).max(1).min(n_tokens);
            let recent_start = n_tokens - n_recent;
            let n_random = (want - n_recent).min(recent_start);
            let mut v = sample_without_replacement(&mut rng, 0..recent_start, n_random);
            v.extend(recent_start..n_tokens);
            v
        }
    };
    picks.sort_unstable();
    picks.dedup();
    picks
}

/// Floyd's algorithm-ish sampling via partial Fisher-Yates over the range.
fn sample_without_replacement(
    rng: &mut SplitMix64,
    range: std::ops::Range<usize>,
    k: usize,
) -> Vec<usize> {
    let mut pool: Vec<usize> = range.collect();
    let k = k.min(pool.len());
    let n = pool.len();
    for i in 0..k {
        let j = i + rng.below((n - i) as u64) as usize;
        pool.swap(i, j);
    }
    pool.truncate(k);
    pool
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_strategies_sorted_unique_bounded() {
        let special: Vec<bool> = (0..100).map(|i| i % 7 == 0).collect();
        for s in [ProbeStrategy::All, ProbeStrategy::Random, ProbeStrategy::Special,
                  ProbeStrategy::Recent, ProbeStrategy::RandomRecent] {
            let p = select_probes(s, 100, 0.1, Some(&special), 42);
            assert!(!p.is_empty(), "{s:?}");
            assert!(p.windows(2).all(|w| w[0] < w[1]), "{s:?}");
            assert!(p.iter().all(|&i| i < 100), "{s:?}");
        }
    }

    #[test]
    fn recent_is_trailing_window() {
        let p = select_probes(ProbeStrategy::Recent, 100, 0.1, None, 1);
        assert_eq!(p, (90..100).collect::<Vec<_>>());
    }

    #[test]
    fn random_recent_split() {
        let p = select_probes(ProbeStrategy::RandomRecent, 100, 0.1, None, 7);
        let n_recent = p.iter().filter(|&&i| i >= 95).count();
        let n_random = p.iter().filter(|&&i| i < 95).count();
        assert_eq!(n_recent, 5);
        assert_eq!(n_random, 5);
    }

    #[test]
    fn deterministic_in_seed() {
        let a = select_probes(ProbeStrategy::Random, 200, 0.1, None, 9);
        let b = select_probes(ProbeStrategy::Random, 200, 0.1, None, 9);
        let c = select_probes(ProbeStrategy::Random, 200, 0.1, None, 10);
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn tiny_sequences() {
        for n in 1..5 {
            for s in [ProbeStrategy::Random, ProbeStrategy::Recent,
                      ProbeStrategy::RandomRecent] {
                let p = select_probes(s, n, 0.1, None, 3);
                assert!(!p.is_empty());
                assert!(p.iter().all(|&i| i < n));
            }
        }
    }

    #[test]
    fn all_returns_everything() {
        assert_eq!(select_probes(ProbeStrategy::All, 5, 0.1, None, 0),
                   vec![0, 1, 2, 3, 4]);
    }
}
