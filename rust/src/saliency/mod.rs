//! Salient token identification (paper §4.2–4.3).
//!
//! * [`metric`] — accumulated (Eq. 7, the H2O/MiKV metric) and normalized
//!   (Eq. 8, the paper's contribution) attention-score saliency, computed
//!   either from full score matrices or from probe rows.
//! * [`probe`] — the four probe-token selection strategies of Table 2
//!   (random / special / recent / random+recent).
//! * [`streaming`] — the decode-phase probe accumulator of Alg. 3
//!   (5% recent + 5% random rows, recompression every 100 tokens).

pub mod metric;
pub mod probe;
pub mod streaming;

pub use metric::{accumulated_saliency, normalized_saliency, probe_normalized_saliency,
                 select_salient, SaliencyMetric};
pub use probe::{ProbeStrategy, select_probes};
pub use streaming::StreamingProbe;
