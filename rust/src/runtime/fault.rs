//! Deterministic fault injection over the model runtime (DESIGN.md §14).
//!
//! The sim backend never fails at steady state, so the failure paths of
//! the sharded server — panic isolation, supervision, redelivery — were
//! untestable until this module.  A [`FaultInjector`] decorates a
//! [`Runtime`](super::Runtime): every `execute_into` call (and the
//! engine's compression passes, via
//! [`Runtime::fault_point`](super::Runtime::fault_point)) first consults
//! the armed [`FaultPlan`], which can inject an error, a panic, or a
//! stall at a plan-specified call site.
//!
//! Plans are *deterministic*: count-triggered clauses fire on the Nth
//! hit of a site on a given shard (hit counters are per-injector, and a
//! shard's call sequence is a pure function of the requests it serves),
//! and probability-triggered clauses draw from a SplitMix64 stream
//! seeded from `(faults.seed, clause index, shard)` — replaying the same
//! plan over the same traffic reproduces the same faults bit-for-bit.
//!
//! Grammar (`faults.plan` config key / `--fault-plan` CLI flag):
//!
//! ```text
//! plan    := clause (';' clause)*
//! clause  := 'shard' INT ':' site ':' trigger ':' kind
//! site    := 'execute' | 'prefill' | 'prefill_chunk' | 'decode' | 'compress'
//! trigger := INT          fire on the Nth hit of the site (1-based)
//!          | 'p' FLOAT    fire per hit with this probability (seeded)
//! kind    := 'error' | 'panic' | 'stall'
//! ```
//!
//! e.g. `shard0:decode:3:panic` — panic during shard 0's third decode
//! call; `shard1:execute:p0.01:error` — each runtime call on shard 1
//! errors with probability 1%.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

use crate::Result;

/// Call sites a fault clause can target.  `Execute` counts *every*
/// runtime call; the entry-specific sites count only their entry kind;
/// `Compress` is hit by the engine around each compression pass (which
/// never crosses the runtime boundary).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultSite {
    /// Any `Runtime::execute_into` call, regardless of entry.
    Execute,
    /// Monolithic prefill entries (`prefill_full` / `prefill_flash`).
    Prefill,
    /// Chunked prefill entries (`prefill_chunk` / `prefill_fin`).
    PrefillChunk,
    /// The decode entry (the steady-state hot path).
    Decode,
    /// An engine compression pass (prefill compression or a streaming
    /// recompression cycle).
    Compress,
}

impl FaultSite {
    pub const COUNT: usize = 5;

    fn slot(self) -> usize {
        match self {
            FaultSite::Execute => 0,
            FaultSite::Prefill => 1,
            FaultSite::PrefillChunk => 2,
            FaultSite::Decode => 3,
            FaultSite::Compress => 4,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            FaultSite::Execute => "execute",
            FaultSite::Prefill => "prefill",
            FaultSite::PrefillChunk => "prefill_chunk",
            FaultSite::Decode => "decode",
            FaultSite::Compress => "compress",
        }
    }

    /// The entry-specific site of a runtime entry name
    /// (`"decode_micro"` → `Decode`).  Allocation-free: the decode hot
    /// path classifies its entry through here every step.
    pub fn fault_site_of_entry(name: &str) -> FaultSite {
        if name.starts_with("decode") {
            FaultSite::Decode
        } else if name.starts_with("prefill_chunk") || name.starts_with("prefill_fin") {
            FaultSite::PrefillChunk
        } else if name.starts_with("prefill") {
            FaultSite::Prefill
        } else {
            FaultSite::Execute
        }
    }
}

impl std::str::FromStr for FaultSite {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "execute" => FaultSite::Execute,
            "prefill" => FaultSite::Prefill,
            "prefill_chunk" => FaultSite::PrefillChunk,
            "decode" => FaultSite::Decode,
            "compress" => FaultSite::Compress,
            other => anyhow::bail!(
                "unknown fault site '{other}' \
                 (execute|prefill|prefill_chunk|decode|compress)"
            ),
        })
    }
}

/// What an armed clause does when it fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// The call returns an engine error (the shard's fatal path runs).
    Error,
    /// The call panics (caught by the shard loop's `catch_unwind`).
    Panic,
    /// The call completes, then the shard wedges before its next
    /// heartbeat: it stops processing until the supervisor severs its
    /// channel (DESIGN.md §14).
    Stall,
}

/// When a clause fires.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultTrigger {
    /// On exactly the Nth hit of the site (1-based) — fires once.
    Nth(u64),
    /// Independently per hit with this probability, from the seeded
    /// per-clause stream — replayable chaos.
    Prob(f64),
}

/// One parsed plan clause.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultSpec {
    pub shard: usize,
    pub site: FaultSite,
    pub trigger: FaultTrigger,
    pub kind: FaultKind,
}

/// A parsed fault plan: the clauses of a `faults.plan` string.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    pub specs: Vec<FaultSpec>,
}

impl FaultPlan {
    /// Parse the plan grammar (module docs); `Err` on any malformed
    /// clause so bad plans die at config validation, not mid-run.
    // lint: cold-path — config parsing; `parse` name-collides with hot
    // code under the lint's name-level resolution (DESIGN.md §13).
    pub fn parse(text: &str) -> Result<Self> {
        let mut specs = Vec::new();
        for clause in text.split(';').map(str::trim).filter(|c| !c.is_empty()) {
            let parts: Vec<&str> = clause.split(':').map(str::trim).collect();
            anyhow::ensure!(
                parts.len() == 4,
                "fault clause '{clause}' must be shard<K>:<site>:<trigger>:<kind>"
            );
            let shard: usize = parts[0]
                .strip_prefix("shard")
                .ok_or_else(|| anyhow::anyhow!("fault clause '{clause}': expected shard<K>"))?
                .parse()
                .map_err(|e| anyhow::anyhow!("fault clause '{clause}': bad shard index ({e})"))?;
            let site: FaultSite = parts[1].parse()?;
            let trigger = if let Some(p) = parts[2].strip_prefix('p') {
                let p: f64 = p
                    .parse()
                    .map_err(|e| anyhow::anyhow!("fault clause '{clause}': bad probability ({e})"))?;
                anyhow::ensure!(
                    (0.0..=1.0).contains(&p),
                    "fault clause '{clause}': probability must be in [0,1]"
                );
                FaultTrigger::Prob(p)
            } else {
                let n: u64 = parts[2]
                    .parse()
                    .map_err(|e| anyhow::anyhow!("fault clause '{clause}': bad trigger ({e})"))?;
                anyhow::ensure!(n >= 1, "fault clause '{clause}': Nth trigger is 1-based");
                FaultTrigger::Nth(n)
            };
            let kind = match parts[3] {
                "error" => FaultKind::Error,
                "panic" => FaultKind::Panic,
                "stall" => FaultKind::Stall,
                other => anyhow::bail!(
                    "unknown fault kind '{other}' (error|panic|stall)"
                ),
            };
            specs.push(FaultSpec { shard, site, trigger, kind });
        }
        Ok(FaultPlan { specs })
    }
}

const SPLITMIX_GAMMA: u64 = 0x9E37_79B9_7F4A_7C15;

fn splitmix(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Armed fault state for one shard's runtime: the plan's clauses plus
/// per-site hit counters and per-clause RNG streams.  Interior-mutable
/// (`Runtime::execute_into` takes `&self`), allocation-free on the hit
/// path (DESIGN.md §9/§14) — only a *firing* clause constructs anything.
#[derive(Debug)]
pub struct FaultInjector {
    shard: usize,
    specs: Vec<FaultSpec>,
    hits: [AtomicU64; FaultSite::COUNT],
    /// SplitMix64 counters for `Prob` clauses (index-aligned to `specs`).
    streams: Vec<AtomicU64>,
    stall: AtomicBool,
}

impl FaultInjector {
    // lint: cold-path — armed once per shard start; `new` name-collides
    // with hot constructors under name-level resolution (DESIGN.md §13).
    pub fn new(plan: &FaultPlan, shard: usize, seed: u64) -> Self {
        let streams = (0..plan.specs.len())
            .map(|i| {
                AtomicU64::new(splitmix(
                    seed ^ (i as u64).wrapping_mul(SPLITMIX_GAMMA) ^ ((shard as u64) << 32),
                ))
            })
            .collect();
        FaultInjector {
            shard,
            specs: plan.specs.clone(),
            hits: Default::default(),
            streams,
            stall: AtomicBool::new(false),
        }
    }

    pub fn shard(&self) -> usize {
        self.shard
    }

    /// Count one hit at `site` and fire any matching clause: `Err` for
    /// an injected error, unwind for an injected panic; an injected
    /// stall sets the wedge flag (read by the shard loop via
    /// [`FaultInjector::stall_pending`]) and lets the call proceed.
    pub fn fault_hit(&self, site: FaultSite) -> Result<()> {
        let n = self.hits[site.slot()].fetch_add(1, Ordering::Relaxed) + 1;
        for (i, spec) in self.specs.iter().enumerate() {
            if spec.shard != self.shard || spec.site != site {
                continue;
            }
            let fire = match spec.trigger {
                FaultTrigger::Nth(k) => n == k,
                FaultTrigger::Prob(p) => self.fault_draw(i) < p,
            };
            if !fire {
                continue;
            }
            match spec.kind {
                FaultKind::Error => anyhow::bail!(
                    "injected fault: {} hit #{n} on shard {} (DESIGN.md §14)",
                    site.as_str(),
                    self.shard
                ),
                FaultKind::Panic => panic!(
                    "injected panic: {} hit #{n} on shard {} (DESIGN.md §14)",
                    site.as_str(),
                    self.shard
                ),
                FaultKind::Stall => self.stall.store(true, Ordering::SeqCst),
            }
        }
        Ok(())
    }

    /// Uniform draw in [0,1) from clause `i`'s seeded stream.
    fn fault_draw(&self, i: usize) -> f64 {
        let s = self.streams[i]
            .fetch_add(SPLITMIX_GAMMA, Ordering::Relaxed)
            .wrapping_add(SPLITMIX_GAMMA);
        (splitmix(s) >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Has a stall clause fired?  Sticky: the shard stays wedged until
    /// the supervisor severs and restarts it (DESIGN.md §14).
    pub fn stall_pending(&self) -> bool {
        self.stall.load(Ordering::SeqCst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_grammar_round_trips() {
        let p = FaultPlan::parse(
            "shard0:decode:3:panic; shard1:prefill_chunk:1:error;\
             shard0:execute:p0.25:stall",
        )
        .unwrap();
        assert_eq!(p.specs.len(), 3);
        assert_eq!(
            p.specs[0],
            FaultSpec {
                shard: 0,
                site: FaultSite::Decode,
                trigger: FaultTrigger::Nth(3),
                kind: FaultKind::Panic,
            }
        );
        assert_eq!(p.specs[1].site, FaultSite::PrefillChunk);
        assert_eq!(p.specs[1].kind, FaultKind::Error);
        assert_eq!(p.specs[2].trigger, FaultTrigger::Prob(0.25));
        assert!(FaultPlan::parse("").unwrap().specs.is_empty());
    }

    #[test]
    fn plan_rejects_malformed_clauses() {
        for bad in [
            "decode:3:panic",                 // missing shard
            "shard0:decode:3",                // missing kind
            "shardx:decode:3:panic",          // bad shard index
            "shard0:warp:3:panic",            // unknown site
            "shard0:decode:0:panic",          // Nth is 1-based
            "shard0:decode:p1.5:error",       // probability out of range
            "shard0:decode:3:explode",        // unknown kind
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "accepted: {bad}");
        }
    }

    #[test]
    fn nth_trigger_fires_exactly_once_on_the_right_shard() {
        let plan = FaultPlan::parse("shard1:decode:3:error").unwrap();
        let inj = FaultInjector::new(&plan, 1, 0);
        assert!(inj.fault_hit(FaultSite::Decode).is_ok());
        assert!(inj.fault_hit(FaultSite::Prefill).is_ok()); // other site
        assert!(inj.fault_hit(FaultSite::Decode).is_ok());
        assert!(inj.fault_hit(FaultSite::Decode).is_err()); // 3rd decode
        assert!(inj.fault_hit(FaultSite::Decode).is_ok()); // once only
        // Same plan armed on another shard never fires.
        let other = FaultInjector::new(&plan, 0, 0);
        for _ in 0..8 {
            assert!(other.fault_hit(FaultSite::Decode).is_ok());
        }
    }

    #[test]
    fn stall_is_sticky_and_call_proceeds() {
        let plan = FaultPlan::parse("shard0:decode:2:stall").unwrap();
        let inj = FaultInjector::new(&plan, 0, 0);
        assert!(inj.fault_hit(FaultSite::Decode).is_ok());
        assert!(!inj.stall_pending());
        assert!(inj.fault_hit(FaultSite::Decode).is_ok()); // stall ≠ error
        assert!(inj.stall_pending());
        assert!(inj.fault_hit(FaultSite::Decode).is_ok());
        assert!(inj.stall_pending(), "wedge flag must be sticky");
    }

    #[test]
    #[should_panic(expected = "injected panic")]
    fn panic_kind_panics() {
        let plan = FaultPlan::parse("shard0:compress:1:panic").unwrap();
        let inj = FaultInjector::new(&plan, 0, 0);
        let _ = inj.fault_hit(FaultSite::Compress);
    }

    #[test]
    fn probability_stream_is_seed_replayable() {
        let plan = FaultPlan::parse("shard0:decode:p0.5:error").unwrap();
        let pattern = |seed: u64| -> Vec<bool> {
            let inj = FaultInjector::new(&plan, 0, seed);
            (0..64).map(|_| inj.fault_hit(FaultSite::Decode).is_err()).collect()
        };
        let a = pattern(7);
        assert_eq!(a, pattern(7), "same seed must replay the same faults");
        assert_ne!(a, pattern(8), "different seed must draw differently");
        let fired = a.iter().filter(|&&f| f).count();
        assert!((8..=56).contains(&fired), "p=0.5 over 64 draws: {fired}");
    }

    #[test]
    fn entry_names_classify_to_sites() {
        assert_eq!(
            FaultSite::fault_site_of_entry("decode_micro"),
            FaultSite::Decode
        );
        assert_eq!(
            FaultSite::fault_site_of_entry("prefill_chunk_micro"),
            FaultSite::PrefillChunk
        );
        assert_eq!(
            FaultSite::fault_site_of_entry("prefill_fin_micro"),
            FaultSite::PrefillChunk
        );
        assert_eq!(
            FaultSite::fault_site_of_entry("prefill_flash_tiny"),
            FaultSite::Prefill
        );
        assert_eq!(
            FaultSite::fault_site_of_entry("prefill_full_tiny"),
            FaultSite::Prefill
        );
    }
}
