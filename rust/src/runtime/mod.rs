//! Model runtime behind the engine: either the PJRT backend executing the
//! AOT HLO-text artifacts on the CPU PJRT client, or the deterministic
//! simulated backend ([`sim`], selected with `artifacts_dir = "sim"`) that
//! needs no artifacts at all.  This is the only place the `xla` crate is
//! touched; everything above works with plain `Tensor`s.
//!
//! Interchange is HLO **text** (see `python/compile/aot.py` and
//! /opt/xla-example/README.md): jax >= 0.5 emits 64-bit instruction ids in
//! serialized protos that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids and round-trips cleanly.

pub mod fault;
pub mod manifest;
pub mod sim;
pub mod tensor;

pub use fault::{FaultInjector, FaultKind, FaultPlan, FaultSite, FaultSpec, FaultTrigger};
pub use manifest::{EntryInfo, Manifest, ModelInfo};
pub use sim::{sim_model_info, SimModel, SIM_ARTIFACTS_DIR};
pub use tensor::{ExecScratch, Tensor, TensorView};

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::Result;

/// Model hyper-parameters for `model` under `dir` *without* compiling
/// anything: the sim registry for the `"sim"` sentinel, otherwise a plain
/// manifest read.  Lets callers size windows/traces before (or without)
/// paying runtime construction.
pub fn load_model_info(dir: impl AsRef<Path>, model: &str) -> Result<ModelInfo> {
    let dir = dir.as_ref();
    if dir.as_os_str() == SIM_ARTIFACTS_DIR {
        return sim_model_info(model)
            .ok_or_else(|| anyhow::anyhow!("sim backend has no model '{model}'"));
    }
    let manifest = Manifest::load(dir.join("manifest.json"))?;
    manifest
        .configs
        .get(model)
        .cloned()
        .ok_or_else(|| anyhow::anyhow!("model '{model}' not in manifest"))
}

/// Execution backend: compiled PJRT executables or the sim model.
enum Backend {
    Pjrt {
        #[allow(dead_code)] // owns the executables' device context
        client: xla::PjRtClient,
        exes: HashMap<String, xla::PjRtLoadedExecutable>,
        info: ModelInfo,
    },
    Sim(SimModel),
}

/// A loaded model runtime: every entry point of one model config, ready
/// to execute (no JIT on the request path).
pub struct Runtime {
    backend: Backend,
    model: String,
    /// Armed fault plan (DESIGN.md §14): `None` (the default) is the
    /// fault-free runtime, bit-for-bit.
    faults: Option<FaultInjector>,
}

impl Runtime {
    /// Load a runtime for `model` from `dir`.  The sentinel directory
    /// `"sim"` selects the artifact-free simulated backend; anything else
    /// loads `manifest.json` and compiles all the model's entries.
    // lint: cold-path — startup; name-collides with atomic `load` calls
    // under the lint's name-level resolution (DESIGN.md §13).
    pub fn load(dir: impl AsRef<Path>, model: &str) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        if dir.as_os_str() == SIM_ARTIFACTS_DIR {
            return Ok(Runtime {
                backend: Backend::Sim(SimModel::new(model)?),
                model: model.to_string(),
                faults: None,
            });
        }
        Self::load_pjrt(dir, model)
    }

    fn load_pjrt(dir: PathBuf, model: &str) -> Result<Self> {
        let manifest = Manifest::load(dir.join("manifest.json"))?;
        anyhow::ensure!(
            manifest.configs.contains_key(model),
            "model '{model}' not in manifest (have: {:?}); run `make artifacts`",
            manifest.configs.keys().collect::<Vec<_>>()
        );
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("{e:?}"))?;
        let mut exes = HashMap::new();
        // Compile every entry belonging to this model eagerly: serving must
        // never JIT on the request path.
        for (name, entry) in manifest.entries.iter().filter(|(_, e)| e.config == model) {
            let path = dir.join(&entry.file);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow::anyhow!("parse {path:?}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compile {name}: {e:?}"))?;
            exes.insert(name.clone(), exe);
        }
        let info = manifest.configs[model].clone();
        Ok(Runtime {
            backend: Backend::Pjrt { client, exes, info },
            model: model.to_string(),
            faults: None,
        })
    }

    /// Arm deterministic fault injection over this runtime
    /// (DESIGN.md §14).  Every subsequent [`Runtime::execute_into`] call
    /// consults the injector; the engine additionally hits the
    /// `Compress` site around compression passes via
    /// [`Runtime::fault_point`].
    pub fn arm_faults(&mut self, inj: FaultInjector) {
        self.faults = Some(inj);
    }

    /// Count one hit at `site` against the armed plan (no-op without
    /// one): `Err` for an injected error, unwind for an injected panic,
    /// wedge flag for an injected stall.
    pub fn fault_point(&self, site: FaultSite) -> Result<()> {
        match &self.faults {
            Some(inj) => inj.fault_hit(site),
            None => Ok(()),
        }
    }

    /// Has an injected stall wedged this runtime's shard?  Read by the
    /// shard loop between iterations; sticky until the shard is severed
    /// and restarted (DESIGN.md §14).
    pub fn fault_stalled(&self) -> bool {
        self.faults.as_ref().is_some_and(FaultInjector::stall_pending)
    }

    /// Model hyper-parameters (from the manifest, or the sim registry).
    pub fn model_info(&self) -> &ModelInfo {
        match &self.backend {
            Backend::Pjrt { info, .. } => info,
            Backend::Sim(m) => m.info(),
        }
    }

    pub fn model_name(&self) -> &str {
        &self.model
    }

    /// True when running on the simulated backend.
    pub fn is_sim(&self) -> bool {
        matches!(self.backend, Backend::Sim(_))
    }

    /// True when the backend provides the chunked prefill entries
    /// (`prefill_chunk_*` / `prefill_fin_*`) — currently the sim backend
    /// only.  The AOT manifests predate chunking, so PJRT runtimes fall
    /// back to the monolithic pass regardless of
    /// `scheduler.prefill_chunk` (DESIGN.md §12).
    pub fn supports_chunked_prefill(&self) -> bool {
        self.is_sim()
    }

    /// Names of the executable entries.
    pub fn entries(&self) -> Vec<String> {
        match &self.backend {
            Backend::Pjrt { exes, .. } => exes.keys().cloned().collect(),
            Backend::Sim(m) => m.entries(),
        }
    }

    /// Entry-point name helper: e.g. `entry("decode") == "decode_tiny"`.
    pub fn entry(&self, kind: &str) -> String {
        format!("{kind}_{}", self.model)
    }

    /// Execute an entry point; inputs/outputs are f32/i32 [`Tensor`]s.
    ///
    /// The AOT side lowers with `return_tuple=True`, so the single output
    /// literal is a tuple; it is decomposed into one `Tensor` per manifest
    /// output name, in order.  The sim backend produces the same output
    /// order and shapes directly.
    ///
    /// Allocates fresh output tensors per call; the decode hot path uses
    /// [`Runtime::execute_into`] instead (DESIGN.md §9).
    pub fn execute(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let views: Vec<TensorView<'_>> = inputs.iter().map(Tensor::as_view).collect();
        let mut scr = ExecScratch::default();
        self.execute_into(name, &views, &mut scr)?;
        Ok(scr.outs)
    }

    /// Execute an entry point with borrowed inputs and reusable outputs:
    /// inputs are [`TensorView`]s over caller-owned storage (no input
    /// clone), outputs land in `scr.outs` slots reshaped in place
    /// (no output allocation at steady state on the sim backend).  Output
    /// order and shapes are identical to [`Runtime::execute`] — this is
    /// the same computation through a copy-minimal boundary
    /// (DESIGN.md §9).
    pub fn execute_into(
        &self,
        name: &str,
        inputs: &[TensorView<'_>],
        scr: &mut ExecScratch,
    ) -> Result<()> {
        if let Some(inj) = &self.faults {
            // Fault decoration (DESIGN.md §14): the generic site counts
            // every call, then the entry-specific site.  Allocation-free
            // unless a clause fires (§9 holds on the steady path).
            inj.fault_hit(FaultSite::Execute)?;
            inj.fault_hit(FaultSite::fault_site_of_entry(name))?;
        }
        let exes = match &self.backend {
            Backend::Sim(m) => return m.execute_into(name, inputs, scr),
            Backend::Pjrt { exes, .. } => exes,
        };
        let exe = exes
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("entry '{name}' not compiled"))?;
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(tensor::view_to_literal)
            // lint-allow(hot-path-alloc): PJRT device path; the §9
            // zero-alloc contract covers the sim/steady path, and the
            // device transfer dominates here
            .collect::<Result<_>>()?;
        let result = exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| anyhow::anyhow!("execute {name}: {e:?}"))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch {name}: {e:?}"))?;
        let parts = out.to_tuple().map_err(|e| anyhow::anyhow!("untuple {name}: {e:?}"))?;
        // The PJRT device fetch materializes owned literals anyway; move
        // them into the slots (device transfer dominates on this path).
        scr.outs.clear();
        for p in parts {
            scr.outs.push(tensor::from_literal(p)?);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    // Runtime integration tests live in rust/tests/runtime_roundtrip.rs
    // (they need built artifacts).
}
