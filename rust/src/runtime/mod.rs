//! PJRT runtime: loads the AOT HLO-text artifacts and executes them on the
//! CPU PJRT client.  This is the only place the `xla` crate is touched;
//! everything above works with plain `Tensor`s.
//!
//! Interchange is HLO **text** (see `python/compile/aot.py` and
//! /opt/xla-example/README.md): jax >= 0.5 emits 64-bit instruction ids in
//! serialized protos that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids and round-trips cleanly.

pub mod manifest;
pub mod tensor;

pub use manifest::{EntryInfo, Manifest, ModelInfo};
pub use tensor::Tensor;

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::Result;

/// A loaded model runtime: compiled executables for every entry point of
/// one model config.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    model: String,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
    dir: PathBuf,
}

impl Runtime {
    /// Load `manifest.json` from `dir` and compile all entries of `model`.
    pub fn load(dir: impl AsRef<Path>, model: &str) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(dir.join("manifest.json"))?;
        anyhow::ensure!(
            manifest.configs.contains_key(model),
            "model '{model}' not in manifest (have: {:?}); run `make artifacts`",
            manifest.configs.keys().collect::<Vec<_>>()
        );
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("{e:?}"))?;
        let mut rt = Runtime {
            client,
            manifest,
            model: model.to_string(),
            exes: HashMap::new(),
            dir,
        };
        // Compile every entry belonging to this model eagerly: serving must
        // never JIT on the request path.
        let names: Vec<String> = rt
            .manifest
            .entries
            .iter()
            .filter(|(_, e)| e.config == model)
            .map(|(n, _)| n.clone())
            .collect();
        for name in names {
            rt.compile_entry(&name)?;
        }
        Ok(rt)
    }

    fn compile_entry(&mut self, name: &str) -> Result<()> {
        let entry = self
            .manifest
            .entries
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("unknown entry '{name}'"))?;
        let path = self.dir.join(&entry.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow::anyhow!("parse {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {name}: {e:?}"))?;
        self.exes.insert(name.to_string(), exe);
        Ok(())
    }

    /// Model hyper-parameters from the manifest.
    pub fn model_info(&self) -> &ModelInfo {
        &self.manifest.configs[&self.model]
    }

    pub fn model_name(&self) -> &str {
        &self.model
    }

    /// Names of the compiled entries.
    pub fn entries(&self) -> Vec<&str> {
        self.exes.keys().map(|s| s.as_str()).collect()
    }

    /// Entry-point name helper: e.g. `entry("decode") == "decode_tiny"`.
    pub fn entry(&self, kind: &str) -> String {
        format!("{kind}_{}", self.model)
    }

    /// Execute an entry point; inputs/outputs are f32/i32 [`Tensor`]s.
    ///
    /// The AOT side lowers with `return_tuple=True`, so the single output
    /// literal is a tuple; it is decomposed into one `Tensor` per manifest
    /// output name, in order.
    pub fn execute(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let exe = self
            .exes
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("entry '{name}' not compiled"))?;
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(tensor::to_literal)
            .collect::<Result<_>>()?;
        let result = exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| anyhow::anyhow!("execute {name}: {e:?}"))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch {name}: {e:?}"))?;
        let parts = out.to_tuple().map_err(|e| anyhow::anyhow!("untuple {name}: {e:?}"))?;
        parts.into_iter().map(tensor::from_literal).collect()
    }
}

#[cfg(test)]
mod tests {
    // Runtime integration tests live in rust/tests/runtime_roundtrip.rs
    // (they need built artifacts).
}
