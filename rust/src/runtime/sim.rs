//! Deterministic simulated runtime backend (`artifacts_dir = "sim"`).
//!
//! The offline build environment cannot compile or execute the AOT HLO
//! artifacts (the vendored `xla` crate is a stub — DESIGN.md §6), which
//! used to leave the whole serving stack untestable without a GPU-class
//! toolchain.  This backend stands in for the PJRT executables with a
//! *pure deterministic function* of the same entry-point signatures
//! (`prefill_full`, `prefill_flash`, `decode` — same input order, same
//! output order and shapes as `python/compile/aot.py` lowers), so the
//! engine, batcher, sharded server, benches and CI smoke tests run
//! end-to-end with no artifacts present (DESIGN.md §8).
//!
//! It is **not** a transformer: token/position-keyed hash projections
//! stand in for the weights.  What it preserves is exactly what the
//! serving-layer tests need:
//!
//! * **Determinism** — every output is a pure function of the inputs, so
//!   per-request outputs are bit-identical regardless of scheduling,
//!   pool width or shard count.
//! * **Cache sensitivity** — decode logits read the session's
//!   materialized value cache, so quantization policy genuinely changes
//!   trajectories (compression is not a no-op here).
//! * **Attention structure** — attention rows are positive, normalized
//!   over valid columns, and carry persistent column-salient positions,
//!   so the saliency/streaming-probe machinery sees realistic input.

use crate::runtime::{ExecScratch, ModelInfo, Tensor, TensorView};
use crate::workload::rng::splitmix_mix;
use crate::Result;

/// The `artifacts_dir` sentinel that selects this backend.
pub const SIM_ARTIFACTS_DIR: &str = "sim";

/// Built-in model configs mirroring `python/compile/model.py::CONFIGS`
/// (vocab/layer/head/window dims identical, probe_count = 10% of window).
pub fn sim_model_info(model: &str) -> Option<ModelInfo> {
    let (vocab, d_model, n_layers, n_heads, d_ff, max_seq) = match model {
        "micro" => (256, 64, 2, 4, 192, 64),
        "tiny" => (256, 128, 2, 4, 384, 256),
        "base" => (256, 256, 4, 8, 768, 512),
        _ => return None,
    };
    let d_head = d_model / n_heads;
    let per_layer = 4 * d_model * d_model + 3 * d_model * d_ff + 2 * d_model;
    Some(ModelInfo {
        vocab,
        d_model,
        n_layers,
        n_heads,
        d_head,
        d_ff,
        max_seq,
        probe_count: (max_seq as f64 * 0.10).round() as usize,
        n_params: vocab * d_model + n_layers * per_layer + d_model,
        trained: None,
    })
}

/// Combine up to four coordinates into one hash (shared SplitMix64
/// output step — see `workload::rng::splitmix_mix`).
#[inline]
fn key(tag: u64, a: u64, b: u64, c: u64) -> u64 {
    splitmix_mix(tag ^ splitmix_mix(a ^ splitmix_mix(b ^ splitmix_mix(c))))
}

/// Map a hash to f32 in [-1, 1): the top 24 bits over 2^23, recentered.
#[inline]
fn unit(h: u64) -> f32 {
    ((h >> 40) as f32 / (1u64 << 23) as f32) - 1.0
}

// Domain-separation tags for the hash families.
const TAG_KV: u64 = 0x6B76;
const TAG_COL: u64 = 0x636F;
const TAG_PAIR: u64 = 0x7072;
const TAG_LOGIT: u64 = 0x6C67;
const TAG_PROJ: u64 = 0x706A;

/// A simulated model: the three entry points over one built-in config.
#[derive(Debug, Clone)]
pub struct SimModel {
    info: ModelInfo,
    model: String,
}

impl SimModel {
    pub fn new(model: &str) -> Result<Self> {
        let info = sim_model_info(model).ok_or_else(|| {
            anyhow::anyhow!("sim backend has no model '{model}' (micro|tiny|base)")
        })?;
        Ok(SimModel { info, model: model.to_string() })
    }

    pub fn info(&self) -> &ModelInfo {
        &self.info
    }

    /// Entry names, matching the manifest convention (`decode_micro`, ...).
    pub fn entries(&self) -> Vec<String> {
        [
            "prefill_full",
            "prefill_flash",
            "prefill_chunk_full",
            "prefill_chunk_flash",
            "prefill_sal_full",
            "prefill_sal_flash",
            "prefill_fin_full",
            "prefill_fin_flash",
            "decode",
        ]
        .iter()
        .map(|k| format!("{k}_{}", self.model))
        .collect()
    }

    /// One pseudo K/V cache element for (k-or-v, layer, head, pos, chan)
    /// holding token `tok` — the same function at prefill and decode, so a
    /// decode-written row equals the row prefill would have produced.
    #[inline]
    fn kv_elem(&self, which: u64, l: usize, h: usize, pos: usize, ch: usize,
               tok: u16) -> f32 {
        let a = ((l as u64) << 32) | (h as u64);
        let b = ((pos as u64) << 32) | (ch as u64);
        unit(key(TAG_KV ^ which, a, b, tok as u64))
    }

    /// One attention row for the query `(tok, qpos)` at layer `l`,
    /// written into `row` (length `max_seq`): positive weights over valid
    /// columns `<= qpos`, normalized to sum 1.  A column-intrinsic factor
    /// makes some positions persistently hot (the "salient tokens" the
    /// saliency machinery must find); a pair term adds per-query
    /// variation.
    fn attn_row_into(&self, l: usize, tok: u16, qpos: usize, valid: &[f32],
                     row: &mut [f32]) {
        let smax = self.info.max_seq;
        debug_assert_eq!(row.len(), smax);
        row.fill(0.0);
        let mut sum = 0f32;
        for (j, w) in row.iter_mut().enumerate().take(smax) {
            if j > qpos || valid[j] <= 0.0 {
                continue;
            }
            let col = 1.6 + unit(key(TAG_COL, l as u64, j as u64, 0));
            let pair = 1.0
                + 0.25
                    * unit(key(TAG_PAIR, l as u64,
                               ((qpos as u64) << 32) | (j as u64),
                               tok as u64));
            let v = col * col * pair;
            *w = v;
            sum += v;
        }
        if sum > 0.0 {
            let inv = 1.0 / sum;
            for w in row.iter_mut() {
                *w *= inv;
            }
        }
    }

    /// Allocating convenience wrapper over [`Self::attn_row_into`].
    fn attn_row(&self, l: usize, tok: u16, qpos: usize, valid: &[f32]) -> Vec<f32> {
        let mut row = vec![0f32; self.info.max_seq];
        self.attn_row_into(l, tok, qpos, valid, &mut row);
        row
    }

    /// Next-token logits for `(tok, pos)` reading the (possibly
    /// quantized) value cache through the layer-0 attention row — this is
    /// what makes compression policy observable in sim trajectories.
    /// `row`/`sig` are caller-owned scratch; `out` (length `vocab`)
    /// receives the logits.
    fn logits_into(&self, tok: u16, pos: usize, vbuf: &[f32], valid: &[f32],
                   row: &mut Vec<f32>, sig: &mut Vec<f32>, out: &mut [f32]) {
        let dh = self.info.d_head;
        row.resize(self.info.max_seq, 0.0);
        self.attn_row_into(0, tok, pos, valid, row);
        // Aggregate the (l=0, h=0) value plane — the first plane of the
        // [L, H, S, dh] buffer — under the row weights.
        sig.clear();
        sig.resize(dh, 0.0);
        for (j, &w) in row.iter().enumerate() {
            if w == 0.0 {
                continue;
            }
            let off = j * dh;
            for (c, s) in sig.iter_mut().enumerate() {
                *s += w * vbuf[off + c];
            }
        }
        for (v, lg) in out.iter_mut().enumerate() {
            let mut x = 1.2 * unit(key(TAG_LOGIT, v as u64, tok as u64, 0));
            for (c, &s) in sig.iter().enumerate() {
                x += 0.35 * s * unit(key(TAG_PROJ, v as u64, c as u64, 0));
            }
            *lg = x;
        }
    }

    /// Dispatch one entry point into fresh output tensors.  `name` must
    /// be one of [`Self::entries`].
    pub fn execute(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let views: Vec<TensorView<'_>> = inputs.iter().map(Tensor::as_view).collect();
        let mut scr = ExecScratch::default();
        self.execute_into(name, &views, &mut scr)?;
        Ok(scr.outs)
    }

    /// Dispatch one entry point with borrowed inputs and reusable output
    /// slots — the allocation-free twin of [`Self::execute`]
    /// (DESIGN.md §9).  The decode entry performs no heap allocation at
    /// steady state (same shapes every call).
    pub fn execute_into(&self, name: &str, inputs: &[TensorView<'_>],
                        scr: &mut ExecScratch) -> Result<()> {
        let kind = name
            .strip_suffix(&self.model)
            .and_then(|k| k.strip_suffix('_'))
            .ok_or_else(|| anyhow::anyhow!("sim: entry '{name}' not for model '{}'",
                                           self.model))?;
        match kind {
            "prefill_full" => self.prefill(inputs, true, scr),
            "prefill_flash" => self.prefill(inputs, false, scr),
            "prefill_chunk_full" => self.prefill_chunk(inputs, true, scr),
            "prefill_chunk_flash" => self.prefill_chunk(inputs, false, scr),
            "prefill_sal_full" => self.prefill_sal(inputs, true, scr),
            "prefill_sal_flash" => self.prefill_sal(inputs, false, scr),
            "prefill_fin_full" => self.prefill_fin(inputs, true, scr),
            "prefill_fin_flash" => self.prefill_fin(inputs, false, scr),
            "decode" => self.decode(inputs, scr),
            other => anyhow::bail!("sim: unknown entry kind '{other}'"),
        }
    }

    /// Shared prefill: fills the KV cache for the prompt rows and computes
    /// saliency.  `full` emits (logits, k, v, acc_sal, norm_sal); the
    /// flash path emits (logits, k, v, norm_sal) with saliency estimated
    /// from the probe rows only (Alg. 2).  Cold path (once per session):
    /// internal buffers are allocated per call and moved into the output
    /// slots.
    // lint: cold-path — prefill runs once per session, outside the §9
    // steady-decode contract (DESIGN.md §13).
    fn prefill(&self, inputs: &[TensorView<'_>], full: bool,
               scr: &mut ExecScratch) -> Result<()> {
        let info = &self.info;
        let (smax, layers, heads, dh) =
            (info.max_seq, info.n_layers, info.n_heads, info.d_head);
        anyhow::ensure!(inputs.len() >= 2, "sim prefill: need tokens + valid");
        let tokens: Vec<u16> = match &inputs[0] {
            TensorView::I32 { data, .. } => data.iter().map(|&t| t as u16).collect(),
            _ => anyhow::bail!("sim prefill: tokens must be i32"),
        };
        let valid = match &inputs[1] {
            TensorView::F32 { data, .. } => *data,
            _ => anyhow::bail!("sim prefill: valid must be f32"),
        };
        anyhow::ensure!(tokens.len() == smax && valid.len() == smax,
                        "sim prefill: window mismatch");
        let n = valid.iter().filter(|&&v| v > 0.0).count();

        // KV cache rows for the prompt.
        let mut k = vec![0f32; layers * heads * smax * dh];
        let mut v = vec![0f32; layers * heads * smax * dh];
        for l in 0..layers {
            for h in 0..heads {
                for pos in 0..n {
                    let off = ((l * heads + h) * smax + pos) * dh;
                    for c in 0..dh {
                        k[off + c] = self.kv_elem(0, l, h, pos, c, tokens[pos]);
                        v[off + c] = self.kv_elem(1, l, h, pos, c, tokens[pos]);
                    }
                }
            }
        }

        // Saliency: accumulate attention rows per layer.  The full path
        // walks every query row (Eq. 7 + Eq. 8); the flash path reads only
        // the probe rows passed as input 3 (Eq. 8 approximation).
        let mut acc = vec![0f32; layers * smax];
        let mut nrm = vec![0f32; layers * smax];
        if full {
            for l in 0..layers {
                for q in 0..n {
                    let row = self.attn_row(l, tokens[q], q, valid);
                    for i in 0..smax {
                        acc[l * smax + i] += row[i];
                    }
                }
                for i in 0..n {
                    // column i is visible to queries q >= i
                    nrm[l * smax + i] = acc[l * smax + i] / (n - i).max(1) as f32;
                }
            }
        } else {
            anyhow::ensure!(inputs.len() >= 3, "sim prefill_flash: need probe idx");
            let pidx: Vec<usize> = match &inputs[2] {
                TensorView::I32 { data, .. } => {
                    data.iter().map(|&i| (i.max(0) as usize).min(smax - 1)).collect()
                }
                _ => anyhow::bail!("sim prefill_flash: probe idx must be i32"),
            };
            for l in 0..layers {
                let base = l * smax;
                for &p in &pidx {
                    let row = self.attn_row(l, tokens[p], p, valid);
                    for i in 0..smax {
                        nrm[base + i] += row[i];
                    }
                }
                for i in 0..smax {
                    // coverage: probes at position >= i see column i
                    let cover = pidx.iter().filter(|&&p| p >= i).count();
                    nrm[base + i] /= cover.max(1) as f32;
                }
            }
        }

        // Prefill logits are produced but unused by the engine (the first
        // generated token is decoded through the compressed cache).
        let logits = vec![0f32; smax * info.vocab];
        let cache_dims = [layers, heads, smax, dh];
        scr.outs.clear();
        scr.outs.push(Tensor::f32(logits, &[smax, info.vocab]));
        scr.outs.push(Tensor::f32(k, &cache_dims));
        scr.outs.push(Tensor::f32(v, &cache_dims));
        if full {
            scr.outs.push(Tensor::f32(acc, &[layers, smax]));
        }
        scr.outs.push(Tensor::f32(nrm, &[layers, smax]));
        Ok(())
    }

    /// One prefill chunk (DESIGN.md §12): KV rows plus the saliency
    /// contributions of prompt positions `[start, end)`, threading a
    /// running saliency accumulator through so the element-wise f32
    /// addition sequence — and therefore every rounding step — is the one
    /// the monolithic pass executes for the same queries.  Inputs:
    /// tokens `[smax]`, valid `[smax]` (prefix switched on through `end`),
    /// start, end (scalars), probe idx `[pc]` on the flash path, sal_in
    /// `[layers, smax]`.  Outputs: k/v chunk rows
    /// `[layers, heads, end-start, dh]` and the updated accumulator
    /// `[layers, smax]`.
    // lint: cold-path — chunked prefill entry, outside the §9
    // steady-decode contract (DESIGN.md §12, §13).
    fn prefill_chunk(&self, inputs: &[TensorView<'_>], full: bool,
                     scr: &mut ExecScratch) -> Result<()> {
        let info = &self.info;
        let (smax, layers, heads, dh) =
            (info.max_seq, info.n_layers, info.n_heads, info.d_head);
        let n_in = if full { 5 } else { 6 };
        anyhow::ensure!(inputs.len() == n_in,
                        "sim prefill_chunk: need tokens,valid,start,end{}sal_in",
                        if full { "," } else { ",pidx," });
        let tokens: Vec<u16> = match &inputs[0] {
            TensorView::I32 { data, .. } => data.iter().map(|&t| t as u16).collect(),
            _ => anyhow::bail!("sim prefill_chunk: tokens must be i32"),
        };
        let valid = inputs[1].as_f32();
        let start = match &inputs[2] {
            TensorView::I32 { data, .. } => data[0] as usize,
            _ => anyhow::bail!("sim prefill_chunk: start must be i32"),
        };
        let end = match &inputs[3] {
            TensorView::I32 { data, .. } => data[0] as usize,
            _ => anyhow::bail!("sim prefill_chunk: end must be i32"),
        };
        let sal_in = inputs[n_in - 1].as_f32();
        anyhow::ensure!(tokens.len() == smax && valid.len() == smax,
                        "sim prefill_chunk: window mismatch");
        anyhow::ensure!(start < end && end <= smax,
                        "sim prefill_chunk: bad range [{start}, {end})");
        anyhow::ensure!(sal_in.len() == layers * smax,
                        "sim prefill_chunk: accumulator mismatch");
        let clen = end - start;

        scr.ensure_outs(3);
        let ExecScratch { outs, row, .. } = scr;

        // KV rows for the chunk — `kv_elem` is per-position pure, so these
        // are bit-identical to the rows the monolithic pass writes at
        // [start, end).
        let k = outs[0].reset_f32(&[layers, heads, clen, dh]);
        let v = outs[1].reset_f32(&[layers, heads, clen, dh]);
        for l in 0..layers {
            for h in 0..heads {
                for (i, pos) in (start..end).enumerate() {
                    let off = ((l * heads + h) * clen + i) * dh;
                    for c in 0..dh {
                        k[off + c] = self.kv_elem(0, l, h, pos, c, tokens[pos]);
                        v[off + c] = self.kv_elem(1, l, h, pos, c, tokens[pos]);
                    }
                }
            }
        }

        // Saliency: copy the accumulator, then add this chunk's rows in
        // ascending position order — the same `acc += row` sequence,
        // element by element, that the monolithic query sweep executes.
        // An attention row for query q reads valid columns <= q < end
        // only, so the prefix-switched `valid` yields identical rows.
        let sal = outs[2].reset_f32(&[layers, smax]);
        sal.copy_from_slice(sal_in);
        row.resize(smax, 0.0);
        if full {
            for l in 0..layers {
                for q in start..end {
                    self.attn_row_into(l, tokens[q], q, valid, row);
                    for i in 0..smax {
                        sal[l * smax + i] += row[i];
                    }
                }
            }
        } else {
            let pidx: Vec<usize> = match &inputs[4] {
                TensorView::I32 { data, .. } => {
                    data.iter().map(|&i| (i.max(0) as usize).min(smax - 1)).collect()
                }
                _ => anyhow::bail!("sim prefill_chunk: probe idx must be i32"),
            };
            // The engine passes the full sorted probe list every chunk;
            // the probes owned by this chunk are the contiguous run in
            // [start, end), visited in the monolithic order.
            for l in 0..layers {
                let base = l * smax;
                for &p in pidx.iter().filter(|&&p| p >= start && p < end) {
                    self.attn_row_into(l, tokens[p], p, valid, row);
                    for i in 0..smax {
                        sal[base + i] += row[i];
                    }
                }
            }
        }
        Ok(())
    }

    /// Saliency-only catch-up for a shared-prefix hit (DESIGN.md §16):
    /// exactly the saliency half of [`Self::prefill_chunk`] — the same
    /// `acc += row` addition sequence for queries (full) or probe rows
    /// (flash) in `[start, end)` — with the KV loop elided, because the
    /// warm path seeds those rows from interned segments instead of
    /// recomputing them.  Inputs: tokens `[smax]`, valid `[smax]`, start,
    /// end (scalars), probe idx `[pc]` on the flash path, sal_in
    /// `[layers, smax]`.  Output: updated accumulator `[layers, smax]`.
    // lint: cold-path — once per warm prefix admission, outside the §9
    // steady-decode contract (DESIGN.md §13, §16).
    fn prefill_sal(&self, inputs: &[TensorView<'_>], full: bool,
                   scr: &mut ExecScratch) -> Result<()> {
        let info = &self.info;
        let (smax, layers) = (info.max_seq, info.n_layers);
        let n_in = if full { 5 } else { 6 };
        anyhow::ensure!(inputs.len() == n_in,
                        "sim prefill_sal: need tokens,valid,start,end{}sal_in",
                        if full { "," } else { ",pidx," });
        let tokens: Vec<u16> = match &inputs[0] {
            TensorView::I32 { data, .. } => data.iter().map(|&t| t as u16).collect(),
            _ => anyhow::bail!("sim prefill_sal: tokens must be i32"),
        };
        let valid = inputs[1].as_f32();
        let start = match &inputs[2] {
            TensorView::I32 { data, .. } => data[0] as usize,
            _ => anyhow::bail!("sim prefill_sal: start must be i32"),
        };
        let end = match &inputs[3] {
            TensorView::I32 { data, .. } => data[0] as usize,
            _ => anyhow::bail!("sim prefill_sal: end must be i32"),
        };
        let sal_in = inputs[n_in - 1].as_f32();
        anyhow::ensure!(tokens.len() == smax && valid.len() == smax,
                        "sim prefill_sal: window mismatch");
        anyhow::ensure!(start < end && end <= smax,
                        "sim prefill_sal: bad range [{start}, {end})");
        anyhow::ensure!(sal_in.len() == layers * smax,
                        "sim prefill_sal: accumulator mismatch");

        scr.ensure_outs(1);
        let ExecScratch { outs, row, .. } = scr;
        let sal = outs[0].reset_f32(&[layers, smax]);
        sal.copy_from_slice(sal_in);
        row.resize(smax, 0.0);
        if full {
            for l in 0..layers {
                for q in start..end {
                    self.attn_row_into(l, tokens[q], q, valid, row);
                    for i in 0..smax {
                        sal[l * smax + i] += row[i];
                    }
                }
            }
        } else {
            let pidx: Vec<usize> = match &inputs[4] {
                TensorView::I32 { data, .. } => {
                    data.iter().map(|&i| (i.max(0) as usize).min(smax - 1)).collect()
                }
                _ => anyhow::bail!("sim prefill_sal: probe idx must be i32"),
            };
            for l in 0..layers {
                let base = l * smax;
                for &p in pidx.iter().filter(|&&p| p >= start && p < end) {
                    self.attn_row_into(l, tokens[p], p, valid, row);
                    for i in 0..smax {
                        sal[base + i] += row[i];
                    }
                }
            }
        }
        Ok(())
    }

    /// Finalize chunked prefill saliency (DESIGN.md §12): divide the
    /// completed accumulator by per-column coverage — the exact division
    /// loop the monolithic entries run after their query sweep, so the
    /// normalized output is bit-identical.  Full path inputs: acc
    /// `[layers, smax]`, n (scalar i32); flash path inputs: acc, probe idx
    /// `[pc]`.  Output: nrm `[layers, smax]`.
    // lint: cold-path — once per chunked prefill, outside the §9
    // steady-decode contract (DESIGN.md §13).
    fn prefill_fin(&self, inputs: &[TensorView<'_>], full: bool,
                   scr: &mut ExecScratch) -> Result<()> {
        let info = &self.info;
        let (smax, layers) = (info.max_seq, info.n_layers);
        anyhow::ensure!(inputs.len() == 2, "sim prefill_fin: need acc + n/pidx");
        let acc = inputs[0].as_f32();
        anyhow::ensure!(acc.len() == layers * smax, "sim prefill_fin: acc mismatch");
        scr.ensure_outs(1);
        let nrm = scr.outs[0].reset_f32(&[layers, smax]);
        if full {
            let n = match &inputs[1] {
                TensorView::I32 { data, .. } => data[0] as usize,
                _ => anyhow::bail!("sim prefill_fin: n must be i32"),
            };
            anyhow::ensure!(n <= smax, "sim prefill_fin: n outside window");
            for l in 0..layers {
                for i in 0..n {
                    // column i is visible to queries q >= i
                    nrm[l * smax + i] = acc[l * smax + i] / (n - i).max(1) as f32;
                }
            }
        } else {
            let pidx: Vec<usize> = match &inputs[1] {
                TensorView::I32 { data, .. } => {
                    data.iter().map(|&i| (i.max(0) as usize).min(smax - 1)).collect()
                }
                _ => anyhow::bail!("sim prefill_fin: probe idx must be i32"),
            };
            for l in 0..layers {
                let base = l * smax;
                for i in 0..smax {
                    // coverage: probes at position >= i see column i
                    let cover = pidx.iter().filter(|&&p| p >= i).count();
                    nrm[base + i] = acc[base + i] / cover.max(1) as f32;
                }
            }
        }
        Ok(())
    }

    /// Decode one token: logits over the cache, the new KV row, and the
    /// per-layer attention row for the streaming probes.  Hot path: every
    /// temporary lives in `scr`, every output lands in a reshaped slot —
    /// zero heap allocation at steady state (DESIGN.md §9).
    fn decode(&self, inputs: &[TensorView<'_>], scr: &mut ExecScratch) -> Result<()> {
        let info = &self.info;
        let (smax, layers, heads, dh) =
            (info.max_seq, info.n_layers, info.n_heads, info.d_head);
        anyhow::ensure!(inputs.len() == 5, "sim decode: need tok,pos,k,v,valid");
        let tok = match &inputs[0] {
            TensorView::I32 { data, .. } => data[0] as u16,
            _ => anyhow::bail!("sim decode: tok must be i32"),
        };
        let pos = match &inputs[1] {
            TensorView::I32 { data, .. } => data[0] as usize,
            _ => anyhow::bail!("sim decode: pos must be i32"),
        };
        let vbuf = inputs[3].as_f32();
        let valid = inputs[4].as_f32();
        anyhow::ensure!(pos < smax, "sim decode: pos {pos} outside window {smax}");

        scr.ensure_outs(4);
        let ExecScratch { outs, mask, row, sig } = scr;

        let logits = outs[0].reset_f32(&[info.vocab]);
        self.logits_into(tok, pos, vbuf, valid, row, sig, logits);

        let k_new = outs[1].reset_f32(&[layers, heads, dh]);
        for l in 0..layers {
            for h in 0..heads {
                let off = (l * heads + h) * dh;
                for c in 0..dh {
                    k_new[off + c] = self.kv_elem(0, l, h, pos, c, tok);
                }
            }
        }
        let v_new = outs[2].reset_f32(&[layers, heads, dh]);
        for l in 0..layers {
            for h in 0..heads {
                let off = (l * heads + h) * dh;
                for c in 0..dh {
                    v_new[off + c] = self.kv_elem(1, l, h, pos, c, tok);
                }
            }
        }

        // Attention row per layer for the query position itself (the row
        // the engine may record into the streaming probe accumulator),
        // written straight into the output slot.
        mask.clear();
        mask.extend_from_slice(valid);
        mask[pos] = 1.0; // the new row attends to itself
        let a_row = outs[3].reset_f32(&[layers, smax]);
        for l in 0..layers {
            self.attn_row_into(l, tok, pos, mask, &mut a_row[l * smax..(l + 1) * smax]);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> SimModel {
        SimModel::new("micro").unwrap()
    }

    #[test]
    fn configs_mirror_python_registry() {
        let m = sim_model_info("micro").unwrap();
        assert_eq!((m.vocab, m.d_model, m.n_layers, m.n_heads), (256, 64, 2, 4));
        assert_eq!(m.max_seq, 64);
        assert_eq!(m.probe_count, 6);
        assert!(sim_model_info("tiny").is_some());
        assert!(sim_model_info("nope").is_none());
    }

    #[test]
    fn unit_stays_in_range_and_is_roughly_centered() {
        let mut lo = f32::MAX;
        let mut hi = f32::MIN;
        let mut sum = 0f64;
        for i in 0..10_000u64 {
            let u = unit(splitmix_mix(i));
            assert!((-1.0..1.0).contains(&u), "unit out of range: {u}");
            lo = lo.min(u);
            hi = hi.max(u);
            sum += u as f64;
        }
        assert!(lo < -0.9 && hi > 0.9, "range barely covered: [{lo}, {hi}]");
        assert!((sum / 10_000.0).abs() < 0.05, "mean drifted: {}", sum / 10_000.0);
    }

    #[test]
    fn attn_rows_normalized_and_causal() {
        let m = model();
        let mut valid = vec![0f32; 64];
        for v in valid.iter_mut().take(10) {
            *v = 1.0;
        }
        let row = m.attn_row(0, 7, 9, &valid);
        let sum: f32 = row.iter().sum();
        assert!((sum - 1.0).abs() < 1e-5);
        assert!(row.iter().take(10).all(|&w| w > 0.0));
        assert!(row.iter().skip(10).all(|&w| w == 0.0));
    }

    #[test]
    fn execute_is_deterministic() {
        let m = model();
        let smax = m.info().max_seq;
        let mut tokens = vec![0i32; smax];
        let mut valid = vec![0f32; smax];
        for i in 0..8 {
            tokens[i] = (i as i32) + 5;
            valid[i] = 1.0;
        }
        let ins = [Tensor::i32(tokens, &[smax]), Tensor::f32(valid, &[smax])];
        let a = m.execute("prefill_full_micro", &ins).unwrap();
        let b = m.execute("prefill_full_micro", &ins).unwrap();
        assert_eq!(a, b);
        assert_eq!(a.len(), 5);
    }

    #[test]
    fn decode_reads_value_cache() {
        // Perturbing the value cache must change the logits — this is the
        // property that makes quantization observable in sim runs.
        let m = model();
        let info = m.info().clone();
        let n = info.n_layers * info.n_heads * info.max_seq * info.d_head;
        let mut valid = vec![0f32; info.max_seq];
        for v in valid.iter_mut().take(4) {
            *v = 1.0;
        }
        let k = vec![0.1f32; n];
        let v1 = vec![0.2f32; n];
        let mut v2 = v1.clone();
        v2[3] += 1.0; // inside the (l=0,h=0) plane, a valid row
        let run = |vb: Vec<f32>| {
            let ins = [
                Tensor::scalar_i32(9),
                Tensor::scalar_i32(4),
                Tensor::f32(k.clone(),
                            &[info.n_layers, info.n_heads, info.max_seq, info.d_head]),
                Tensor::f32(vb, &[info.n_layers, info.n_heads, info.max_seq,
                                  info.d_head]),
                Tensor::f32(valid.clone(), &[info.max_seq]),
            ];
            m.execute("decode_micro", &ins).unwrap().remove(0).into_f32()
        };
        assert_ne!(run(v1), run(v2));
    }

    #[test]
    fn entry_names_follow_manifest_convention() {
        let m = model();
        assert!(m.entries().contains(&"decode_micro".to_string()));
        assert!(m.entries().contains(&"prefill_chunk_full_micro".to_string()));
        assert!(m.entries().contains(&"prefill_sal_full_micro".to_string()));
        assert!(m.entries().contains(&"prefill_sal_flash_micro".to_string()));
        assert!(m.entries().contains(&"prefill_fin_flash_micro".to_string()));
        assert!(m.execute("decode_tiny", &[]).is_err());
    }

    /// The saliency-only catch-up entry must be bitwise the saliency half
    /// of `prefill_chunk` over the same range (DESIGN.md §16): a warm
    /// session replaying `prefill_sal` over the covered prefix and then
    /// normal chunks over the suffix lands on the monolithic accumulator.
    #[test]
    fn sal_catchup_matches_chunk_saliency_bitwise() {
        let m = model();
        let info = m.info().clone();
        let (smax, layers) = (info.max_seq, info.n_layers);
        let n = 11usize;
        let mut tokens = vec![0i32; smax];
        let mut valid = vec![0f32; smax];
        for i in 0..n {
            tokens[i] = (i as i32 * 7 + 3) % 256;
            valid[i] = 1.0;
        }
        let pidx = vec![0i32, 2, 5, 10, 10, 10];

        for &full in &[true, false] {
            for &covered in &[1usize, 4, 8] {
                // Reference: chunk entries over [0, covered) with the
                // covered span's prefix-switched valid masks.
                let mut want = vec![0f32; layers * smax];
                let mut start = 0usize;
                while start < covered {
                    let end = (start + 3).min(covered);
                    let mut cvalid = vec![0f32; smax];
                    for x in cvalid.iter_mut().take(end) {
                        *x = 1.0;
                    }
                    let mut ins = vec![
                        Tensor::i32(tokens.clone(), &[smax]),
                        Tensor::f32(cvalid, &[smax]),
                        Tensor::scalar_i32(start as i32),
                        Tensor::scalar_i32(end as i32),
                    ];
                    if !full {
                        ins.push(Tensor::i32(pidx.clone(), &[pidx.len()]));
                    }
                    ins.push(Tensor::f32(want.clone(), &[layers, smax]));
                    let entry = if full {
                        "prefill_chunk_full_micro"
                    } else {
                        "prefill_chunk_flash_micro"
                    };
                    let out = m.execute(entry, &ins).unwrap();
                    want.copy_from_slice(out[2].as_f32());
                    start = end;
                }

                // One catch-up call over the whole covered span.
                let mut cvalid = vec![0f32; smax];
                for x in cvalid.iter_mut().take(covered) {
                    *x = 1.0;
                }
                let mut ins = vec![
                    Tensor::i32(tokens.clone(), &[smax]),
                    Tensor::f32(cvalid, &[smax]),
                    Tensor::scalar_i32(0),
                    Tensor::scalar_i32(covered as i32),
                ];
                if !full {
                    ins.push(Tensor::i32(pidx.clone(), &[pidx.len()]));
                }
                ins.push(Tensor::f32(vec![0f32; layers * smax],
                                     &[layers, smax]));
                let entry = if full {
                    "prefill_sal_full_micro"
                } else {
                    "prefill_sal_flash_micro"
                };
                let got = m.execute(entry, &ins).unwrap();
                assert_eq!(got.len(), 1, "sal entry emits the accumulator only");
                assert_eq!(got[0].as_f32(), &want[..],
                           "sal catch-up mismatch (full={full}, covered={covered})");
            }
        }
    }

    /// Chunked prefill replayed at the runtime boundary must reproduce the
    /// monolithic entries bit-for-bit: KV rows, the saliency accumulator,
    /// and the finalized normalization (DESIGN.md §12).
    #[test]
    fn chunked_prefill_matches_monolithic_bitwise() {
        let m = model();
        let info = m.info().clone();
        let (smax, layers, heads, dh) =
            (info.max_seq, info.n_layers, info.n_heads, info.d_head);
        let n = 11usize;
        let mut tokens = vec![0i32; smax];
        let mut valid = vec![0f32; smax];
        for i in 0..n {
            tokens[i] = (i as i32 * 7 + 3) % 256;
            valid[i] = 1.0;
        }
        // Sorted probe list with a duplicate tail, as the engine pads it.
        let pidx = vec![0i32, 2, 5, 10, 10, 10];

        for &full in &[true, false] {
            let mono_entry =
                if full { "prefill_full_micro" } else { "prefill_flash_micro" };
            let mut ins = vec![
                Tensor::i32(tokens.clone(), &[smax]),
                Tensor::f32(valid.clone(), &[smax]),
            ];
            if !full {
                ins.push(Tensor::i32(pidx.clone(), &[pidx.len()]));
            }
            let mono = m.execute(mono_entry, &ins).unwrap();
            let (mono_k, mono_v) = (mono[1].as_f32(), mono[2].as_f32());
            let mono_acc = if full { Some(mono[3].as_f32()) } else { None };
            let mono_nrm = mono.last().unwrap().as_f32();

            for &chunk in &[1usize, 3, 4, n] {
                let mut k = vec![0f32; layers * heads * smax * dh];
                let mut v = vec![0f32; layers * heads * smax * dh];
                let mut sal = vec![0f32; layers * smax];
                let mut start = 0usize;
                while start < n {
                    let end = (start + chunk).min(n);
                    // Chunked callers switch `valid` on prefix-by-prefix.
                    let mut cvalid = vec![0f32; smax];
                    for x in cvalid.iter_mut().take(end) {
                        *x = 1.0;
                    }
                    let mut ins = vec![
                        Tensor::i32(tokens.clone(), &[smax]),
                        Tensor::f32(cvalid, &[smax]),
                        Tensor::scalar_i32(start as i32),
                        Tensor::scalar_i32(end as i32),
                    ];
                    if !full {
                        ins.push(Tensor::i32(pidx.clone(), &[pidx.len()]));
                    }
                    ins.push(Tensor::f32(sal.clone(), &[layers, smax]));
                    let entry = if full {
                        "prefill_chunk_full_micro"
                    } else {
                        "prefill_chunk_flash_micro"
                    };
                    let out = m.execute(entry, &ins).unwrap();
                    let (ck, cv) = (out[0].as_f32(), out[1].as_f32());
                    let clen = end - start;
                    for l in 0..layers {
                        for h in 0..heads {
                            for i in 0..clen {
                                let src = ((l * heads + h) * clen + i) * dh;
                                let dst = ((l * heads + h) * smax + start + i) * dh;
                                k[dst..dst + dh].copy_from_slice(&ck[src..src + dh]);
                                v[dst..dst + dh].copy_from_slice(&cv[src..src + dh]);
                            }
                        }
                    }
                    sal.copy_from_slice(out[2].as_f32());
                    start = end;
                }
                assert_eq!(&k[..], mono_k, "k mismatch (full={full}, chunk={chunk})");
                assert_eq!(&v[..], mono_v, "v mismatch (full={full}, chunk={chunk})");
                if let Some(acc) = mono_acc {
                    assert_eq!(&sal[..], acc,
                               "acc mismatch (chunk={chunk})");
                }
                let fin_ins = if full {
                    vec![Tensor::f32(sal.clone(), &[layers, smax]),
                         Tensor::scalar_i32(n as i32)]
                } else {
                    vec![Tensor::f32(sal.clone(), &[layers, smax]),
                         Tensor::i32(pidx.clone(), &[pidx.len()])]
                };
                let fin_entry = if full {
                    "prefill_fin_full_micro"
                } else {
                    "prefill_fin_flash_micro"
                };
                let fin = m.execute(fin_entry, &fin_ins).unwrap();
                assert_eq!(fin[0].as_f32(), mono_nrm,
                           "nrm mismatch (full={full}, chunk={chunk})");
            }
        }
    }
}
