//! `artifacts/manifest.json` schema (written by `python/compile/aot.py`),
//! parsed with the in-tree JSON parser ([`crate::util::json`]).

use std::collections::BTreeMap;
use std::path::Path;

use crate::util::json::{self, Json};
use crate::Result;

/// Input tensor spec of one entry point.
#[derive(Debug, Clone)]
pub struct InputSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

/// One AOT entry point (one .hlo.txt file).
#[derive(Debug, Clone)]
pub struct EntryInfo {
    pub config: String,
    pub file: String,
    pub inputs: Vec<InputSpec>,
    pub outputs: Vec<String>,
    pub sha256: String,
}

/// Model hyper-parameters recorded at lowering time.
#[derive(Debug, Clone)]
pub struct ModelInfo {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_head: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub probe_count: usize,
    pub n_params: usize,
    /// npz filename if the weights were trained; None = random init baked.
    pub trained: Option<String>,
}

impl ModelInfo {
    /// Cache layout for this model (one sequence).
    pub fn cache_layout(&self) -> crate::kvcache::CacheLayout {
        crate::kvcache::CacheLayout {
            layers: self.n_layers,
            heads: self.n_heads,
            seq: self.max_seq,
            d_head: self.d_head,
        }
    }
}

/// The whole manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub entries: BTreeMap<String, EntryInfo>,
    pub configs: BTreeMap<String, ModelInfo>,
}

impl Manifest {
    // lint: cold-path — startup; name-collides with atomic `load` calls
    // under the lint's name-level resolution (DESIGN.md §13).
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("read {path:?}: {e} — run `make artifacts`"))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Self> {
        let root = json::parse(text)?;
        let mut entries = BTreeMap::new();
        for (name, e) in root.req("entries")?.as_obj().into_iter().flatten() {
            entries.insert(name.clone(), parse_entry(e)?);
        }
        let mut configs = BTreeMap::new();
        for (name, c) in root.req("configs")?.as_obj().into_iter().flatten() {
            configs.insert(name.clone(), parse_model(c)?);
        }
        Ok(Manifest { entries, configs })
    }
}

fn parse_entry(e: &Json) -> Result<EntryInfo> {
    let u = |k: &str| -> Result<String> {
        Ok(e.req(k)?.as_str().ok_or_else(|| anyhow::anyhow!("{k} not a string"))?
            .to_string())
    };
    let mut inputs = Vec::new();
    for i in e.req("inputs")?.as_arr().into_iter().flatten() {
        let shape = i
            .req("shape")?
            .as_arr()
            .map(|a| a.iter().filter_map(Json::as_usize).collect())
            .unwrap_or_default();
        let dtype = i.req("dtype")?.as_str().unwrap_or("").to_string();
        inputs.push(InputSpec { shape, dtype });
    }
    let outputs = e
        .req("outputs")?
        .as_arr()
        .map(|a| a.iter().filter_map(|x| x.as_str().map(String::from)).collect())
        .unwrap_or_default();
    Ok(EntryInfo {
        config: u("config")?,
        file: u("file")?,
        inputs,
        outputs,
        sha256: u("sha256")?,
    })
}

fn parse_model(c: &Json) -> Result<ModelInfo> {
    let n = |k: &str| -> Result<usize> {
        c.req(k)?.as_usize().ok_or_else(|| anyhow::anyhow!("{k} not a number"))
    };
    let trained = match c.get("trained") {
        Some(Json::Str(s)) => Some(s.clone()),
        _ => None,
    };
    Ok(ModelInfo {
        vocab: n("vocab")?,
        d_model: n("d_model")?,
        n_layers: n("n_layers")?,
        n_heads: n("n_heads")?,
        d_head: n("d_head")?,
        d_ff: n("d_ff")?,
        max_seq: n("max_seq")?,
        probe_count: n("probe_count")?,
        n_params: n("n_params")?,
        trained,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_example_manifest() {
        let json = r#"{
          "entries": {
            "decode_micro": {
              "config": "micro",
              "file": "decode_micro.hlo.txt",
              "inputs": [{"shape": [], "dtype": "int32"},
                          {"shape": [2, 4, 64, 16], "dtype": "float32"}],
              "outputs": ["logits", "k_new"],
              "sha256": "abc"
            }
          },
          "configs": {
            "micro": {"vocab": 256, "d_model": 64, "n_layers": 2,
                       "n_heads": 4, "d_head": 16, "d_ff": 192,
                       "max_seq": 64, "probe_count": 6,
                       "n_params": 100000, "trained": null}
          }
        }"#;
        let m = Manifest::parse(json).unwrap();
        let e = &m.entries["decode_micro"];
        assert_eq!(e.outputs, vec!["logits", "k_new"]);
        assert_eq!(e.inputs[1].shape, vec![2, 4, 64, 16]);
        let info = &m.configs["micro"];
        assert!(info.trained.is_none());
        let lay = info.cache_layout();
        assert_eq!(lay.heads, 4);
        assert_eq!(lay.seq, 64);
    }

    #[test]
    fn trained_field_string() {
        let json = r#"{"entries": {}, "configs": {"t": {"vocab":1,"d_model":1,
          "n_layers":1,"n_heads":1,"d_head":1,"d_ff":1,"max_seq":1,
          "probe_count":1,"n_params":1,"trained":"params_t.npz"}}}"#;
        let m = Manifest::parse(json).unwrap();
        assert_eq!(m.configs["t"].trained.as_deref(), Some("params_t.npz"));
    }

    #[test]
    fn missing_key_errors() {
        assert!(Manifest::parse(r#"{"entries": {}}"#).is_err());
    }
}
