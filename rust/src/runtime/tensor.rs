//! Plain host tensors + literal marshalling.
//!
//! The coordinator never touches `xla::Literal` directly; it trades in
//! [`Tensor`] (f32 or i32 data + dims), and this module converts at the
//! runtime boundary.

use crate::Result;

/// A host tensor: row-major data + dims.
#[derive(Debug, Clone, PartialEq)]
pub enum Tensor {
    F32 { data: Vec<f32>, dims: Vec<usize> },
    I32 { data: Vec<i32>, dims: Vec<usize> },
}

impl Tensor {
    pub fn f32(data: Vec<f32>, dims: &[usize]) -> Self {
        debug_assert_eq!(data.len(), dims.iter().product::<usize>());
        Tensor::F32 { data, dims: dims.to_vec() }
    }

    pub fn i32(data: Vec<i32>, dims: &[usize]) -> Self {
        debug_assert_eq!(data.len(), dims.iter().product::<usize>());
        Tensor::I32 { data, dims: dims.to_vec() }
    }

    pub fn scalar_i32(v: i32) -> Self {
        Tensor::I32 { data: vec![v], dims: vec![] }
    }

    pub fn dims(&self) -> &[usize] {
        match self {
            Tensor::F32 { dims, .. } | Tensor::I32 { dims, .. } => dims,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Tensor::F32 { data, .. } => data.len(),
            Tensor::I32 { data, .. } => data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Borrow as f32 slice (panics if i32 — programming error).
    pub fn as_f32(&self) -> &[f32] {
        match self {
            Tensor::F32 { data, .. } => data,
            Tensor::I32 { .. } => panic!("expected f32 tensor"),
        }
    }

    /// Consume into an f32 vector.
    pub fn into_f32(self) -> Vec<f32> {
        match self {
            Tensor::F32 { data, .. } => data,
            Tensor::I32 { data, .. } => data.into_iter().map(|v| v as f32).collect(),
        }
    }
}

/// Tensor -> xla literal (reshaped to the tensor's dims).
pub fn to_literal(t: &Tensor) -> Result<xla::Literal> {
    let lit = match t {
        Tensor::F32 { data, dims } => {
            let l = xla::Literal::vec1(data.as_slice());
            if dims.is_empty() {
                // () scalar: vec1 gives [1]; reshape to scalar shape
                l.reshape(&[]).map_err(|e| anyhow::anyhow!("{e:?}"))?
            } else {
                let d: Vec<i64> = dims.iter().map(|&x| x as i64).collect();
                l.reshape(&d).map_err(|e| anyhow::anyhow!("{e:?}"))?
            }
        }
        Tensor::I32 { data, dims } => {
            let l = xla::Literal::vec1(data.as_slice());
            if dims.is_empty() {
                l.reshape(&[]).map_err(|e| anyhow::anyhow!("{e:?}"))?
            } else {
                let d: Vec<i64> = dims.iter().map(|&x| x as i64).collect();
                l.reshape(&d).map_err(|e| anyhow::anyhow!("{e:?}"))?
            }
        }
    };
    Ok(lit)
}

/// xla literal -> Tensor (f32 or i32 by element type).
pub fn from_literal(lit: xla::Literal) -> Result<Tensor> {
    let shape = lit.array_shape().map_err(|e| anyhow::anyhow!("{e:?}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    match shape.ty() {
        xla::ElementType::F32 => {
            let data = lit.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?;
            Ok(Tensor::F32 { data, dims })
        }
        xla::ElementType::S32 => {
            let data = lit.to_vec::<i32>().map_err(|e| anyhow::anyhow!("{e:?}"))?;
            Ok(Tensor::I32 { data, dims })
        }
        other => anyhow::bail!("unsupported output element type {other:?}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_basics() {
        let t = Tensor::f32(vec![1.0, 2.0, 3.0, 4.0], &[2, 2]);
        assert_eq!(t.dims(), &[2, 2]);
        assert_eq!(t.len(), 4);
        assert_eq!(t.as_f32()[3], 4.0);
    }

    #[test]
    fn scalar() {
        let t = Tensor::scalar_i32(7);
        assert!(t.dims().is_empty());
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn literal_roundtrip_f32() {
        let t = Tensor::f32(vec![1.0, -2.5, 3.25, 0.0, 9.0, 1.5], &[2, 3]);
        let lit = to_literal(&t).unwrap();
        let back = from_literal(lit).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn literal_roundtrip_i32() {
        let t = Tensor::i32(vec![5, 6, 7], &[3]);
        let lit = to_literal(&t).unwrap();
        let back = from_literal(lit).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn literal_roundtrip_scalar() {
        let t = Tensor::scalar_i32(42);
        let lit = to_literal(&t).unwrap();
        let back = from_literal(lit).unwrap();
        assert_eq!(back, t);
    }
}
