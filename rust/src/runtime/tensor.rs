//! Plain host tensors + literal marshalling.
//!
//! The coordinator never touches `xla::Literal` directly; it trades in
//! [`Tensor`] (f32 or i32 data + dims), and this module converts at the
//! runtime boundary.
//!
//! The decode hot path trades in [`TensorView`] instead: a borrowed
//! tensor over caller-owned storage (the session's `kbuf`/`vbuf`), so
//! per-step inputs cross the runtime boundary without cloning the cache
//! (DESIGN.md §9).  Outputs land in reusable [`Tensor`] slots reshaped in
//! place by [`Tensor::reset_f32`].

use crate::Result;

/// A host tensor: row-major data + dims.
#[derive(Debug, Clone, PartialEq)]
pub enum Tensor {
    F32 { data: Vec<f32>, dims: Vec<usize> },
    I32 { data: Vec<i32>, dims: Vec<usize> },
}

/// A borrowed tensor at the runtime boundary: row-major data + dims, both
/// referencing caller-owned storage.  This is what lets `decode_step`
/// hand the session's `[L,H,S,dh]` cache buffers to the runtime without
/// the two full-cache clones the owned [`Tensor`] input path required
/// (DESIGN.md §9).
#[derive(Debug, Clone, Copy)]
pub enum TensorView<'a> {
    F32 { data: &'a [f32], dims: &'a [usize] },
    I32 { data: &'a [i32], dims: &'a [usize] },
}

impl<'a> TensorView<'a> {
    pub fn f32(data: &'a [f32], dims: &'a [usize]) -> Self {
        debug_assert_eq!(data.len(), dims.iter().product::<usize>());
        TensorView::F32 { data, dims }
    }

    pub fn i32(data: &'a [i32], dims: &'a [usize]) -> Self {
        debug_assert_eq!(data.len(), dims.iter().product::<usize>());
        TensorView::I32 { data, dims }
    }

    /// Scalar view over a caller-owned one-element buffer (the borrowed
    /// twin of [`Tensor::scalar_i32`]).
    pub fn scalar_i32(v: &'a [i32; 1]) -> Self {
        TensorView::I32 { data: v, dims: &[] }
    }

    pub fn dims(&self) -> &[usize] {
        match self {
            TensorView::F32 { dims, .. } | TensorView::I32 { dims, .. } => dims,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            TensorView::F32 { data, .. } => data.len(),
            TensorView::I32 { data, .. } => data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Borrow as f32 slice (panics if i32 — programming error).  The
    /// view is `Copy`, so the returned borrow carries the underlying
    /// `'a`, not the view's own lifetime.
    pub fn as_f32(&self) -> &'a [f32] {
        match *self {
            TensorView::F32 { data, .. } => data,
            TensorView::I32 { .. } => panic!("expected f32 tensor view"),
        }
    }

    /// Borrow as i32 slice (panics if f32 — programming error).
    pub fn as_i32(&self) -> &'a [i32] {
        match *self {
            TensorView::I32 { data, .. } => data,
            TensorView::F32 { .. } => panic!("expected i32 tensor view"),
        }
    }
}

impl Tensor {
    pub fn f32(data: Vec<f32>, dims: &[usize]) -> Self {
        debug_assert_eq!(data.len(), dims.iter().product::<usize>());
        Tensor::F32 { data, dims: dims.to_vec() }
    }

    pub fn i32(data: Vec<i32>, dims: &[usize]) -> Self {
        debug_assert_eq!(data.len(), dims.iter().product::<usize>());
        Tensor::I32 { data, dims: dims.to_vec() }
    }

    pub fn scalar_i32(v: i32) -> Self {
        Tensor::I32 { data: vec![v], dims: vec![] }
    }

    pub fn dims(&self) -> &[usize] {
        match self {
            Tensor::F32 { dims, .. } | Tensor::I32 { dims, .. } => dims,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Tensor::F32 { data, .. } => data.len(),
            Tensor::I32 { data, .. } => data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Borrow as f32 slice (panics if i32 — programming error).
    pub fn as_f32(&self) -> &[f32] {
        match self {
            Tensor::F32 { data, .. } => data,
            Tensor::I32 { .. } => panic!("expected f32 tensor"),
        }
    }

    /// Consume into an f32 vector.
    pub fn into_f32(self) -> Vec<f32> {
        match self {
            Tensor::F32 { data, .. } => data,
            Tensor::I32 { data, .. } => data.into_iter().map(|v| v as f32).collect(),
        }
    }

    /// Borrow this tensor as a [`TensorView`].
    pub fn as_view(&self) -> TensorView<'_> {
        match self {
            Tensor::F32 { data, dims } => TensorView::F32 { data, dims },
            Tensor::I32 { data, dims } => TensorView::I32 { data, dims },
        }
    }

    /// An empty f32 tensor — the initial state of a reusable output slot.
    pub fn empty() -> Self {
        // lint-allow(hot-path-alloc): capacity-0 Vec::new is heap-free
        Tensor::F32 { data: Vec::new(), dims: Vec::new() }
    }

    /// Reshape this slot in place to an f32 tensor of `dims`, reusing the
    /// existing allocations, and return the writable (zero-filled) data.
    /// At steady state (same shape every call) this performs no heap
    /// allocation — the core of the `execute_into` output contract
    /// (DESIGN.md §9).
    pub fn reset_f32(&mut self, dims: &[usize]) -> &mut [f32] {
        let n = dims.iter().product::<usize>();
        if !matches!(self, Tensor::F32 { .. }) {
            *self = Tensor::empty();
        }
        match self {
            Tensor::F32 { data, dims: d } => {
                data.clear();
                data.resize(n, 0.0);
                d.clear();
                d.extend_from_slice(dims);
                data
            }
            Tensor::I32 { .. } => unreachable!(),
        }
    }
}

/// Reusable execution scratch for [`crate::runtime::Runtime::execute_into`]:
/// output slots reshaped in place per call, plus backend-internal
/// temporaries (the sim backend's attention row / mask / head-signal
/// buffers).  Owned by the caller (one per [`crate::coordinator::Session`])
/// so the steady-state decode loop performs no heap allocation
/// (DESIGN.md §9).
#[derive(Debug, Clone, Default)]
pub struct ExecScratch {
    /// Output slots, one [`Tensor`] per entry-point output, reshaped in
    /// place by the backend on every call.
    pub outs: Vec<Tensor>,
    /// Sim backend: the query-step validity mask (`valid` with the query
    /// position switched live).
    pub(crate) mask: Vec<f32>,
    /// Sim backend: one attention row.
    pub(crate) row: Vec<f32>,
    /// Sim backend: the aggregated head signal feeding the logits.
    pub(crate) sig: Vec<f32>,
}

impl ExecScratch {
    /// Ensure `n` output slots exist (empty f32 tensors are appended).
    pub fn ensure_outs(&mut self, n: usize) {
        while self.outs.len() < n {
            self.outs.push(Tensor::empty());
        }
        self.outs.truncate(n);
    }

    /// Borrow output `i` as f32 (panics when absent — programming error).
    pub fn out_f32(&self, i: usize) -> &[f32] {
        self.outs[i].as_f32()
    }
}

/// Tensor -> xla literal (reshaped to the tensor's dims).
pub fn to_literal(t: &Tensor) -> Result<xla::Literal> {
    view_to_literal(&t.as_view())
}

/// TensorView -> xla literal: one host copy into the literal, then a
/// zero-copy in-place reshape (`Literal::into_reshape`) — the owned-path
/// `vec1` + `reshape` pair cloned the payload twice (DESIGN.md §9).
pub fn view_to_literal(t: &TensorView<'_>) -> Result<xla::Literal> {
    let (l, dims) = match t {
        TensorView::F32 { data, dims } => (xla::Literal::vec1(*data), *dims),
        TensorView::I32 { data, dims } => (xla::Literal::vec1(*data), *dims),
    };
    // `&[]` reshapes the one-element vec1 to a () scalar.
    let d: Vec<i64> = dims.iter().map(|&x| x as i64).collect();
    l.into_reshape(&d).map_err(|e| anyhow::anyhow!("{e:?}"))
}

/// xla literal -> Tensor (f32 or i32 by element type).
// lint: cold-path — PJRT device fetch; the zero-alloc contract covers
// the sim/steady path, and the device transfer dominates here anyway
// (DESIGN.md §9, §13).
pub fn from_literal(lit: xla::Literal) -> Result<Tensor> {
    let shape = lit.array_shape().map_err(|e| anyhow::anyhow!("{e:?}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    match shape.ty() {
        xla::ElementType::F32 => {
            let data = lit.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?;
            Ok(Tensor::F32 { data, dims })
        }
        xla::ElementType::S32 => {
            let data = lit.to_vec::<i32>().map_err(|e| anyhow::anyhow!("{e:?}"))?;
            Ok(Tensor::I32 { data, dims })
        }
        other => anyhow::bail!("unsupported output element type {other:?}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_basics() {
        let t = Tensor::f32(vec![1.0, 2.0, 3.0, 4.0], &[2, 2]);
        assert_eq!(t.dims(), &[2, 2]);
        assert_eq!(t.len(), 4);
        assert_eq!(t.as_f32()[3], 4.0);
    }

    #[test]
    fn scalar() {
        let t = Tensor::scalar_i32(7);
        assert!(t.dims().is_empty());
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn literal_roundtrip_f32() {
        let t = Tensor::f32(vec![1.0, -2.5, 3.25, 0.0, 9.0, 1.5], &[2, 3]);
        let lit = to_literal(&t).unwrap();
        let back = from_literal(lit).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn literal_roundtrip_i32() {
        let t = Tensor::i32(vec![5, 6, 7], &[3]);
        let lit = to_literal(&t).unwrap();
        let back = from_literal(lit).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn literal_roundtrip_scalar() {
        let t = Tensor::scalar_i32(42);
        let lit = to_literal(&t).unwrap();
        let back = from_literal(lit).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn view_roundtrip_matches_owned() {
        let t = Tensor::f32(vec![1.0, -2.5, 3.25, 0.0, 9.0, 1.5], &[2, 3]);
        let lit = view_to_literal(&t.as_view()).unwrap();
        assert_eq!(from_literal(lit).unwrap(), t);
        let buf = [7i32];
        let v = TensorView::scalar_i32(&buf);
        assert!(v.dims().is_empty());
        assert_eq!(v.as_i32(), &[7]);
        let lit = view_to_literal(&v).unwrap();
        assert_eq!(from_literal(lit).unwrap(), Tensor::scalar_i32(7));
    }

    #[test]
    fn view_borrows_without_copying() {
        let data = vec![1.0f32, 2.0, 3.0, 4.0];
        let dims = [2usize, 2];
        let v = TensorView::f32(&data, &dims);
        assert_eq!(v.len(), 4);
        assert!(!v.is_empty());
        assert_eq!(v.as_f32().as_ptr(), data.as_ptr()); // borrowed, not cloned
    }

    #[test]
    fn reset_f32_reuses_allocation_at_steady_state() {
        let mut slot = Tensor::empty();
        let first_ptr = {
            let buf = slot.reset_f32(&[4, 2]);
            buf[7] = 9.0;
            buf.as_ptr()
        };
        assert_eq!(slot.dims(), &[4, 2]);
        // Same shape again: same allocation, contents re-zeroed.
        let buf = slot.reset_f32(&[4, 2]);
        assert_eq!(buf.as_ptr(), first_ptr);
        assert!(buf.iter().all(|&x| x == 0.0));
        // Slot type flips transparently.
        let mut islot = Tensor::scalar_i32(3);
        let buf = islot.reset_f32(&[3]);
        assert_eq!(buf.len(), 3);
        assert_eq!(islot.dims(), &[3]);
    }

    #[test]
    fn exec_scratch_slots() {
        let mut s = ExecScratch::default();
        s.ensure_outs(3);
        assert_eq!(s.outs.len(), 3);
        s.outs[1].reset_f32(&[2])[0] = 5.0;
        assert_eq!(s.out_f32(1), &[5.0, 0.0]);
        s.ensure_outs(2); // shrink drops the tail slot
        assert_eq!(s.outs.len(), 2);
    }
}
