//! Analytic cost model for attention variants at the paper's hardware
//! scale (A100) and on TPU, used to report Fig. 4/6-shaped numbers next to
//! our CPU wall-clock (DESIGN.md §2: the testbed substitution).
//!
//! The model is a simple roofline: time = max(flops / peak_flops,
//! bytes / mem_bw), summed over the phase's kernels.  It captures exactly
//! the asymmetry the paper measures — standard attention materializes the
//! l×l score matrix (O(l²) HBM traffic), FlashAttention streams tiles
//! (O(l·d) traffic), and ZipCache adds only a p×l probe stripe (p = 10%·l).

/// Hardware profile for the roofline.
#[derive(Debug, Clone, Copy)]
pub struct Hardware {
    pub name: &'static str,
    /// Peak dense f16 throughput, FLOP/s.
    pub peak_flops: f64,
    /// HBM bandwidth, bytes/s.
    pub mem_bw: f64,
}

impl Hardware {
    /// NVIDIA A100-80GB (the paper's testbed): 312 TFLOPS bf16, 2.0 TB/s.
    pub fn a100() -> Self {
        Hardware { name: "A100", peak_flops: 312e12, mem_bw: 2.0e12 }
    }

    /// One TPU v4 core (the port target): ~137.5 TFLOPS bf16 (275/chip),
    /// 1.2 TB/s HBM.
    pub fn tpu_v4() -> Self {
        Hardware { name: "TPUv4", peak_flops: 137.5e12, mem_bw: 1.2e12 }
    }

    /// Roofline time for (flops, bytes).
    pub fn time_s(&self, flops: f64, bytes: f64) -> f64 {
        (flops / self.peak_flops).max(bytes / self.mem_bw)
    }
}

/// Model/workload shape for the cost queries.
#[derive(Debug, Clone, Copy)]
pub struct AttnShape {
    pub batch: usize,
    pub heads: usize,
    pub seq: usize,
    pub d_head: usize,
    /// bytes per element of activations (2 for fp16).
    pub elem: f64,
}

impl AttnShape {
    fn bh(&self) -> f64 {
        (self.batch * self.heads) as f64
    }
}

/// Attention implementation variants the paper compares.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AttnKind {
    /// Materializes the full l×l score matrix (MiKV/H2O/GEAR prefill).
    Standard,
    /// Tiled online-softmax, no score materialization.
    Flash,
    /// Flash for all tokens + standard rows for `probe_ratio` of queries
    /// (the ZipCache prefill, Alg. 2).
    FlashWithProbes { probe_pct: u32 },
}

/// Prefill-phase cost of one attention layer.
pub fn prefill_cost(hw: Hardware, s: AttnShape, kind: AttnKind) -> f64 {
    let (l, d) = (s.seq as f64, s.d_head as f64);
    let bh = s.bh();
    // QK^T + AV flops are common to every variant.
    let flops = bh * (2.0 * l * l * d) * 2.0;
    let io_qkv = bh * 3.0 * l * d * s.elem; // read Q,K,V
    let io_out = bh * l * d * s.elem; // write O
    match kind {
        AttnKind::Standard => {
            // write + read the l×l score matrix (softmax pass), fp16
            let io_scores = bh * 2.0 * l * l * s.elem;
            hw.time_s(flops, io_qkv + io_out + io_scores)
        }
        AttnKind::Flash => hw.time_s(flops, io_qkv + io_out),
        AttnKind::FlashWithProbes { probe_pct } => {
            let p = l * probe_pct as f64 / 100.0;
            let probe_flops = bh * 2.0 * p * l * d;
            let io_probe = bh * 2.0 * p * l * s.elem; // write+read p×l stripe
            hw.time_s(flops + probe_flops, io_qkv + io_out + io_probe)
        }
    }
}

/// Decode-phase cost per generated token for one layer: dominated by
/// streaming the KV cache; `bits_per_value` reflects the compression
/// (16 = fp16, mixed ~ 2.8 for ZipCache 4/2@40%).
pub fn decode_cost_per_token(hw: Hardware, s: AttnShape, bits_per_value: f64,
                             kind: AttnKind) -> f64 {
    let (l, d) = (s.seq as f64, s.d_head as f64);
    let bh = s.bh();
    let flops = bh * 4.0 * l * d;
    let io_cache = bh * 2.0 * l * d * (bits_per_value / 8.0);
    let extra = match kind {
        AttnKind::Standard => bh * 2.0 * l * s.elem, // score row kept + reread
        _ => 0.0,
    };
    hw.time_s(flops, io_cache + extra)
}

/// Peak attention working-set bytes for the prefill (the Fig. 4 memory
/// argument: O(l²) vs O(l)).
pub fn prefill_workspace_bytes(s: AttnShape, kind: AttnKind) -> f64 {
    let (l, d) = (s.seq as f64, s.d_head as f64);
    let bh = s.bh();
    match kind {
        AttnKind::Standard => bh * l * l * s.elem,
        AttnKind::Flash => bh * 2.0 * 128.0 * d * s.elem, // a tile pair
        AttnKind::FlashWithProbes { probe_pct } => {
            bh * (l * probe_pct as f64 / 100.0) * l * s.elem
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shape(l: usize) -> AttnShape {
        AttnShape { batch: 8, heads: 32, seq: l, d_head: 128, elem: 2.0 }
    }

    #[test]
    fn flash_faster_than_standard_at_long_seq() {
        let hw = Hardware::a100();
        let s = shape(4096);
        assert!(prefill_cost(hw, s, AttnKind::Flash)
            < prefill_cost(hw, s, AttnKind::Standard));
    }

    #[test]
    fn probe_overhead_small() {
        // ZipCache's claim: 10% probes cost far less than full scores.
        let hw = Hardware::a100();
        let s = shape(4096);
        let flash = prefill_cost(hw, s, AttnKind::Flash);
        let zip = prefill_cost(hw, s, AttnKind::FlashWithProbes { probe_pct: 10 });
        let std = prefill_cost(hw, s, AttnKind::Standard);
        assert!(zip < std);
        assert!(zip < flash * 1.5);
    }

    #[test]
    fn paper_fig6_shape_prefill_reduction() {
        // Paper: 37.3% prefill latency reduction at l=4096 vs the
        // standard-attention (MiKV) path.  The pure-attention roofline puts
        // l=4096 near the compute/IO boundary, so the modelled reduction is
        // milder than the measured end-to-end figure (which also includes
        // the quantization machinery) — require the right *sign and regime*.
        let hw = Hardware::a100();
        let s = shape(4096);
        let std = prefill_cost(hw, s, AttnKind::Standard);
        let zip = prefill_cost(hw, s, AttnKind::FlashWithProbes { probe_pct: 10 });
        let reduction = 1.0 - zip / std;
        assert!(reduction > 0.1 && reduction < 0.7, "{reduction}");
    }

    #[test]
    fn decode_cost_scales_with_bits() {
        let hw = Hardware::a100();
        let s = shape(4096);
        let fp16 = decode_cost_per_token(hw, s, 16.0, AttnKind::Flash);
        let zip = decode_cost_per_token(hw, s, 2.8, AttnKind::Flash);
        assert!(zip < fp16);
        // paper: 56.9% decode reduction vs the standard-score path
        let mikv = decode_cost_per_token(hw, s, 2.8, AttnKind::Standard);
        assert!(zip < mikv);
    }

    #[test]
    fn workspace_quadratic_vs_linear() {
        let s1 = shape(1024);
        let s2 = shape(4096);
        let std_ratio = prefill_workspace_bytes(s2, AttnKind::Standard)
            / prefill_workspace_bytes(s1, AttnKind::Standard);
        assert!((std_ratio - 16.0).abs() < 1e-9); // quadratic
        let flash_ratio = prefill_workspace_bytes(s2, AttnKind::Flash)
            / prefill_workspace_bytes(s1, AttnKind::Flash);
        assert!((flash_ratio - 1.0).abs() < 1e-9); // constant tile
    }
}
