//! Fidelity metrics: how much does cache compression perturb the model's
//! outputs, independent of any task?  Used by the Table-1 reproduction and
//! the ablation benches to get a continuous signal alongside accuracy.

/// Mean squared error between two logit vectors.
pub fn logit_mse(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    a.iter()
        .zip(b)
        .map(|(x, y)| ((x - y) as f64).powi(2))
        .sum::<f64>()
        / a.len() as f64
}

/// Cosine similarity between two vectors (attention outputs, logits).
pub fn cosine_similarity(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let (mut dot, mut na, mut nb) = (0f64, 0f64, 0f64);
    for (&x, &y) in a.iter().zip(b) {
        dot += x as f64 * y as f64;
        na += (x as f64).powi(2);
        nb += (y as f64).powi(2);
    }
    if na == 0.0 || nb == 0.0 {
        return if na == nb { 1.0 } else { 0.0 };
    }
    dot / (na.sqrt() * nb.sqrt())
}

/// Do two logit vectors agree on the argmax token?
pub fn top1_agreement(a: &[f32], b: &[f32]) -> bool {
    argmax(a) == argmax(b)
}

fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|x, y| x.1.partial_cmp(y.1).unwrap_or(std::cmp::Ordering::Equal))
        .map(|(i, _)| i)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mse_zero_for_identical() {
        let v = vec![1.0f32, -2.0, 3.0];
        assert_eq!(logit_mse(&v, &v), 0.0);
    }

    #[test]
    fn cosine_bounds() {
        let a = vec![1.0f32, 0.0];
        let b = vec![0.0f32, 1.0];
        assert!((cosine_similarity(&a, &a) - 1.0).abs() < 1e-12);
        assert!(cosine_similarity(&a, &b).abs() < 1e-12);
        let c = vec![-1.0f32, 0.0];
        assert!((cosine_similarity(&a, &c) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn zero_vectors() {
        let z = vec![0.0f32; 4];
        let a = vec![1.0f32; 4];
        assert_eq!(cosine_similarity(&z, &z), 1.0);
        assert_eq!(cosine_similarity(&z, &a), 0.0);
    }

    #[test]
    fn top1() {
        assert!(top1_agreement(&[0.1, 0.9], &[0.2, 0.3]));
        assert!(!top1_agreement(&[0.9, 0.1], &[0.2, 0.3]));
    }
}
