//! Evaluation harness: task scorers + cache-fidelity metrics.
//!
//! The paper reports end-task accuracy (GSM8k / Line Retrieval / HumanEval)
//! per compression method; [`scorer`] reproduces that protocol on the
//! synthetic workloads.  [`fidelity`] adds direct cache/logit fidelity
//! metrics (reconstruction MSE, logit divergence, attention-output cosine)
//! that isolate quantization error from task noise.

pub mod fidelity;
pub mod scorer;

pub use fidelity::{cosine_similarity, logit_mse, top1_agreement};
pub use scorer::{score_generation, AccuracyReport};
