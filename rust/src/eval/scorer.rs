//! Task scoring: exact-match answer accuracy, the paper's Table 3/B and
//! Fig. 5 metric.

use crate::workload::Sample;

/// Accuracy over a set of scored generations.
#[derive(Debug, Clone, Default)]
pub struct AccuracyReport {
    pub n: usize,
    pub correct: usize,
    /// Exact-match accuracy in percent (the tables' "Acc.(%)").
    pub accuracy_pct: f64,
}

impl AccuracyReport {
    pub fn add(&mut self, correct: bool) {
        self.n += 1;
        if correct {
            self.correct += 1;
        }
        self.accuracy_pct = 100.0 * self.correct as f64 / self.n as f64;
    }

    pub fn merge(&mut self, other: &AccuracyReport) {
        self.n += other.n;
        self.correct += other.correct;
        self.accuracy_pct = if self.n == 0 {
            0.0
        } else {
            100.0 * self.correct as f64 / self.n as f64
        };
    }
}

/// Score one generation against the sample's expected answer.
///
/// The answer is `[value, EOS]`; generation is correct iff the first
/// generated token equals the value token (EOS afterwards is not required —
/// matching the answer-extraction convention of the eval harnesses the
/// paper uses, which parse the final answer span only).
pub fn score_generation(sample: &Sample, generated: &[u16]) -> bool {
    match generated.first() {
        Some(&tok) => tok == sample.answer[0],
        None => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{Task, TaskGen};

    #[test]
    fn exact_match() {
        let s = TaskGen::new(Task::Code, 128).sample(1);
        assert!(score_generation(&s, &s.answer));
        assert!(score_generation(&s, &[s.answer[0], 99]));
        assert!(!score_generation(&s, &[s.answer[0] + 1]));
        assert!(!score_generation(&s, &[]));
    }

    #[test]
    fn report_accumulates() {
        let mut r = AccuracyReport::default();
        r.add(true);
        r.add(false);
        r.add(true);
        assert_eq!(r.n, 3);
        assert!((r.accuracy_pct - 66.666).abs() < 0.01);
        let mut r2 = AccuracyReport::default();
        r2.add(true);
        r.merge(&r2);
        assert_eq!(r.n, 4);
        assert_eq!(r.correct, 3);
    }
}
