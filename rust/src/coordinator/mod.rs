//! The serving coordinator (Layer 3): ZipCache's Alg. 2 (prefill) and
//! Alg. 3 (decode + streaming recompression) orchestrated over the PJRT
//! runtime, with continuous batching across sessions.
//!
//! * [`engine`] — [`Engine`]: owns the runtime + policy, runs prefill,
//!   compression, and single-token decode steps.
//! * [`session`] — per-request decode state (cache buffers, streaming
//!   probe accumulator, generated tokens).
//! * [`batcher`] — round-robin continuous batcher over active sessions
//!   with admission control.

pub mod batcher;
pub mod engine;
pub mod session;

pub use batcher::{BatchOutcome, ContinuousBatcher};
pub use engine::{merge_streaming_saliency, request_seed, Engine, GenerationOutput};
pub use session::{Session, SessionScratch};
