//! The serving coordinator (Layer 3): ZipCache's Alg. 2 (prefill) and
//! Alg. 3 (decode + streaming recompression) orchestrated over the PJRT
//! runtime, with continuous batching across sessions.
//!
//! * [`engine`] — [`Engine`]: owns the runtime + policy + the bounded
//!   materialization-slot pool, runs prefill, compression, single-token
//!   decode steps, and park/unpark transitions (DESIGN.md §10).
//! * [`session`] — per-request decode state (compressed-resident cache,
//!   dense-slot residency, streaming probe accumulator, generated
//!   tokens).
//! * [`batcher`] — priority-ordered continuous batcher over active
//!   sessions with deadline shedding, cancellation, token streaming, and
//!   park-policy slot scheduling.
//! * [`request`] — the typed request/response surface (DESIGN.md §11):
//!   [`GenerationRequest`] builder, [`Priority`], [`QuantOverride`],
//!   [`CancelToken`], [`FinishReason`], [`GenerationResponse`].

pub mod batcher;
pub mod engine;
pub mod request;
pub mod session;

pub use batcher::{ContinuousBatcher, LruByLastStep, ParkPolicy, PriorityPark,
                  QueuedRequest, RoundRobinPark, SessionMeta, StepReport};
pub use engine::{merge_streaming_saliency, request_seed, Engine};
pub use request::{CancelToken, FinishReason, GenerationOutput, GenerationRequest,
                  GenerationResponse, Priority, QuantOverride};
pub use session::{PrefillProgress, Residency, Session, SessionScratch};
