//! The serving coordinator (Layer 3): ZipCache's Alg. 2 (prefill) and
//! Alg. 3 (decode + streaming recompression) orchestrated over the PJRT
//! runtime, with continuous batching across sessions.
//!
//! * [`engine`] — [`Engine`]: owns the runtime + policy + the bounded
//!   materialization-slot pool, runs prefill, compression, single-token
//!   decode steps, and park/unpark transitions (DESIGN.md §10).
//! * [`session`] — per-request decode state (compressed-resident cache,
//!   dense-slot residency, streaming probe accumulator, generated
//!   tokens).
//! * [`batcher`] — round-robin continuous batcher over active sessions
//!   with admission control and park-policy slot scheduling.

pub mod batcher;
pub mod engine;
pub mod session;

pub use batcher::{BatchOutcome, ContinuousBatcher, LruByLastStep, ParkPolicy,
                  RoundRobinPark, SessionMeta};
pub use engine::{merge_streaming_saliency, request_seed, Engine, GenerationOutput};
pub use session::{Residency, Session, SessionScratch};
