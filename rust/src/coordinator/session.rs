//! Per-request decode state.
//!
//! A session's durable state is its **compressed** cache
//! (`CompressedKV`, retained from the last compression point) plus the
//! probe/saliency accumulators — exactly the paper's residency story.
//! The dense fp32 buffers the decode artifact consumes are *not* owned
//! by the session: they live in a shard-bounded [`SlotPool`]
//! (DESIGN.md §10), and a session holds one [`DenseSlot`] only while it
//! is scheduled for decode ([`Residency::Dense`]).  A parked session
//! ([`Residency::Parked`]) keeps just the fp32 rows appended since the
//! last recompression cycle (the streaming scheme's recent-token tail,
//! at most `recompress_every` rows), so park -> unpark reconstructs the
//! dense buffers bit-exactly.

use crate::baselines::CompressionPolicy;
use crate::kvcache::{CacheLayout, CompressedKV, DenseSlot, PrecisionClass,
                     SegmentRef};
use crate::runtime::ExecScratch;
use crate::saliency::StreamingProbe;

use super::request::{CancelToken, FinishReason, GenerationRequest, Priority,
                     QuantOverride};

/// The compiled form of a request's [`QuantOverride`]: the policy object
/// the engine builds once at session start and reuses at every
/// compression cycle (rebuilding per cycle would put a box allocation on
/// each recompression — DESIGN.md §9's discipline).  A newtype so
/// `Session` can keep deriving `Debug` over a non-`Debug` trait object.
pub struct PolicyOverride(pub Box<dyn CompressionPolicy>);

impl std::fmt::Debug for PolicyOverride {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "PolicyOverride({})", self.0.name())
    }
}

/// Reusable per-session scratch for the decode hot path (DESIGN.md §9):
/// the runtime execution slots plus the layer-mean attention-row buffer.
/// Warm after the first decode step; no steady-state heap allocation.
#[derive(Debug, Clone, Default)]
pub struct SessionScratch {
    /// Runtime boundary: borrowed-input execution + reusable output slots.
    pub exec: ExecScratch,
    /// Layer-mean of the decode attention row (`[S]`), fed to the
    /// streaming probe accumulator.
    pub a_mean: Vec<f32>,
    /// Retired park-tail buffers, kept for their capacity: `Engine::park`
    /// fills these instead of allocating per cycle, and `Engine::unpark`
    /// puts them back (DESIGN.md §10).
    pub tail_spare: (Vec<f32>, Vec<f32>),
}

/// In-flight chunked-prefill state (DESIGN.md §12): everything
/// `Engine::prefill_chunk` needs to run the next chunk through the
/// runtime's `prefill_chunk_*` entries.  Exists only between
/// `Engine::begin_session` and the final chunk; a `Session` holding one
/// is in the *Prefilling* phase — it pins its dense slot (the chunk rows
/// scatter straight into it) but cannot decode, park, or compress until
/// the phase ends.  Boxed in the session so the steady-state decode
/// struct stays small.
#[derive(Debug)]
pub struct PrefillProgress {
    /// Prompt tokens already in the cache: the start of the next chunk.
    /// Cold sessions begin at 0; a warm prefix hit begins at the covered
    /// span (the shared segments seeded those rows — DESIGN.md §16).
    pub done: usize,
    /// Chunk size in prompt tokens (>= 1).
    pub chunk: usize,
    /// Prompt tokens padded to the window, as the runtime consumes them.
    pub tokens: Vec<i32>,
    /// Validity mask, switched on prefix-by-prefix as chunks complete.
    pub valid: Vec<f32>,
    /// Sorted, padded probe indices (flash path; empty on the full path).
    pub probes: Vec<i32>,
    /// True when the saliency source is the full query sweep
    /// (`policy.requires_full_scores()`), false for the probe
    /// approximation.
    pub full_scores: bool,
    /// Running saliency accumulator `[layers, smax]`, threaded through
    /// the chunk entries so the f32 addition order matches the monolithic
    /// pass (DESIGN.md §12).
    pub sal: Vec<f32>,
    /// Active prefill time accumulated across completed chunks (µs) —
    /// the session-level `prefill` total excludes inter-chunk queueing.
    pub us: u64,
    /// Chunk-entry execution scratch (reused across this session's
    /// chunks; dropped with the phase).
    pub exec: ExecScratch,
}

/// Where a session's dense working set currently lives (DESIGN.md §10).
#[derive(Debug)]
pub enum Residency {
    /// Scheduled for decode: holds one checked-out materialization slot.
    Dense(DenseSlot),
    /// Parked: the compressed snapshot is the resident form; only the
    /// fp32 rows appended since that snapshot are saved (per plane
    /// contiguous, rows `[tail_from, pos)`).
    Parked {
        tail_k: Vec<f32>,
        tail_v: Vec<f32>,
        /// First row of the saved tail (= the snapshot's `n_tokens`).
        tail_from: usize,
    },
}

/// State of one in-flight generation request.
#[derive(Debug)]
pub struct Session {
    pub id: u64,
    /// Global submission-order tag, set by the batcher at activation
    /// (0 for bare-engine sessions); carried onto the
    /// [`GenerationResponse`](super::GenerationResponse).
    pub tag: u64,
    /// Request urgency class (queue pop order + park order).
    pub priority: Priority,
    /// Extra stop tokens from the request (besides the built-in `EOS`).
    pub stop_tokens: Vec<u16>,
    /// Per-request quantization override (None = engine config).
    pub quant: Option<QuantOverride>,
    /// Compiled form of `quant`: built once by `Engine::start_session`,
    /// used by every compression cycle (None = engine policy).
    pub policy_override: Option<PolicyOverride>,
    /// Cancellation flag shared with the request's `ResponseHandle`; the
    /// batcher retires the session at the next iteration once set.
    pub cancel: CancelToken,
    /// Why the generation finished (meaningful once `done`).
    pub finish: FinishReason,
    /// The prompt (token ids), length <= layout.seq.
    pub prompt: Vec<u16>,
    /// Number of live cache rows (prompt + generated so far).
    pub pos: usize,
    /// Generated tokens (excluding the prompt).
    pub generated: Vec<u16>,
    /// Decode budget.
    pub max_new: usize,
    /// Cache shape (sizes the slot this session materializes into).
    pub layout: CacheLayout,
    /// Dense slot or parked tail (DESIGN.md §10).
    pub residency: Residency,
    /// Chunked-prefill phase state: `Some` from `Engine::begin_session`
    /// until the final chunk completes (DESIGN.md §12).  Monolithic
    /// prefill (`scheduler.prefill_chunk = 0`) never sets it, except for
    /// a warm prefix hit, which runs its uncovered suffix as one chunk
    /// (DESIGN.md §16).
    pub prefill: Option<Box<PrefillProgress>>,
    /// Pinned shared-prefix segments this session was forked from
    /// (DESIGN.md §16).  Held for the session's lifetime so eviction
    /// can never unmap rows the session's view was seeded with; the
    /// refs drop (and the store's `seg_refs` gauge drains) at finish.
    /// Copy-on-write: the session never writes through these — all
    /// compression and decode writes land in session-private state.
    pub shared: Vec<SegmentRef>,
    /// Prompt tokens covered by `shared` (0 = cold start).
    pub covered: usize,
    /// Latest compressed snapshot — the session's resident cache form,
    /// retained from the last compression point (prefill or streaming
    /// recompression) instead of being rebuilt and discarded.
    pub compressed: Option<CompressedKV>,
    /// Current per-token precision classes (from the last compression).
    pub classes: Vec<PrecisionClass>,
    /// Prefill-time saliency (normalized / accumulated), layer-averaged.
    pub norm_saliency: Vec<f32>,
    pub acc_saliency: Vec<f32>,
    /// Streaming probe accumulator (Alg. 3).
    pub stream: StreamingProbe,
    /// Next token to feed the decode artifact.
    pub next_token: u16,
    /// True until the prompt's final token has been decoded against the
    /// *compressed* cache (it is withheld from the prefill cache so the
    /// first generated token genuinely reads quantized state — see
    /// Engine::start_session).
    pub prompt_tail_pending: bool,
    pub done: bool,
    /// Bytes of the last compressed snapshot (resident accounting:
    /// payload + params + class metadata) + its ratio.
    pub cache_bytes: usize,
    pub compression_ratio: f64,
    /// Wall-clock accounting (filled by the engine).
    pub prefill_us: u64,
    pub decode_us: u64,
    /// Decode hot-path scratch (execution slots + layer-mean buffer).
    pub scratch: SessionScratch,
}

impl Session {
    /// Build the per-request state from a validated [`GenerationRequest`]
    /// (the engine validates before calling).  The batcher fills `tag`
    /// at activation.
    pub fn new(id: u64, req: GenerationRequest, layout: CacheLayout,
               recompress_every: usize, seed: u64, slot: DenseSlot) -> Self {
        let GenerationRequest { prompt, max_new, priority, quant, stop_tokens,
                                cancel, .. } = req;
        Session {
            id,
            tag: 0,
            priority,
            stop_tokens,
            quant,
            policy_override: None,
            cancel,
            finish: FinishReason::default(),
            pos: prompt.len(),
            prompt,
            // Reserved up front: `generated` grows by one push per decode
            // step and must never reallocate mid-generation.
            generated: Vec::with_capacity(max_new),
            max_new,
            layout,
            residency: Residency::Dense(slot),
            prefill: None,
            shared: Vec::new(),
            covered: 0,
            compressed: None,
            classes: Vec::new(),
            norm_saliency: Vec::new(),
            acc_saliency: Vec::new(),
            stream: StreamingProbe::new(recompress_every, 0.05, 0.05, seed),
            next_token: 0,
            prompt_tail_pending: false,
            done: false,
            cache_bytes: 0,
            compression_ratio: 1.0,
            prefill_us: 0,
            decode_us: 0,
            scratch: SessionScratch::default(),
        }
    }

    /// Room left in the fixed window.
    pub fn remaining_window(&self, seq: usize) -> usize {
        seq.saturating_sub(self.pos)
    }

    /// Generation finished (budget, EOS, or window exhausted)?
    pub fn is_done(&self) -> bool {
        self.done
    }

    /// Parked out of its materialization slot?
    pub fn is_parked(&self) -> bool {
        matches!(self.residency, Residency::Parked { .. })
    }

    /// Still in the chunked-prefill phase (DESIGN.md §12)?  Prefilling
    /// sessions pin their dense slot and are excluded from decode
    /// scheduling and park-victim selection until the last chunk lands.
    pub fn is_prefilling(&self) -> bool {
        self.prefill.is_some()
    }

    /// The checked-out dense slot; panics when the session is parked
    /// (callers schedule-in through `Engine::unpark` first).
    pub fn slot(&self) -> &DenseSlot {
        match &self.residency {
            Residency::Dense(slot) => slot,
            Residency::Parked { .. } => panic!("session {} is parked", self.id),
        }
    }

    pub fn slot_mut(&mut self) -> &mut DenseSlot {
        match &mut self.residency {
            Residency::Dense(slot) => slot,
            Residency::Parked { .. } => panic!("session {} is parked", self.id),
        }
    }

    /// Materialized fp32 K cache, `[L, H, S, dh]` (dense sessions only).
    pub fn kbuf(&self) -> &[f32] {
        &self.slot().kbuf
    }

    /// Materialized fp32 V cache, `[L, H, S, dh]` (dense sessions only).
    pub fn vbuf(&self) -> &[f32] {
        &self.slot().vbuf
    }

    /// Bytes this session keeps resident right now: the retained
    /// compressed snapshot (payload + params + metadata), plus either
    /// the checked-out dense slot or the parked fp32 tail
    /// (DESIGN.md §10).  Probe/saliency accumulators are O(S) floats and
    /// excluded, like every other per-request bookkeeping struct.
    ///
    /// Shared-prefix segments (`self.shared`) are deliberately **not**
    /// counted here: their payload is charged exactly once per shard to
    /// the store's `shared_bytes` gauge, however many sessions pin the
    /// same segment (DESIGN.md §16) — pinned by
    /// `resident_bytes_never_count_shared_segments` in
    /// `rust/tests/prefix_parity.rs`.
    pub fn resident_bytes(&self) -> usize {
        let residency = match &self.residency {
            Residency::Dense(slot) => slot.bytes(),
            Residency::Parked { tail_k, tail_v, .. } => {
                (tail_k.len() + tail_v.len()) * 4
            }
        };
        self.cache_bytes + residency
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::SlotPool;

    #[test]
    fn session_init() {
        let lay = CacheLayout { layers: 2, heads: 2, seq: 16, d_head: 4 };
        let mut pool = SlotPool::new(1, lay);
        let s = Session::new(1, GenerationRequest::new(vec![1, 2, 3], 5), lay,
                             100, 0, pool.acquire().unwrap());
        assert_eq!(s.pos, 3);
        assert_eq!((s.tag, s.priority), (0, Priority::Interactive));
        assert!(!s.cancel.is_cancelled());
        assert!(!s.is_parked());
        assert_eq!(s.kbuf().len(), lay.cache_len());
        assert_eq!(s.remaining_window(16), 13);
        assert!(!s.is_done());
        // Dense resident bytes = slot bytes (no snapshot yet).
        assert_eq!(s.resident_bytes(), pool.slot_bytes());
    }

    #[test]
    fn parked_resident_bytes_count_tail_only() {
        let lay = CacheLayout { layers: 1, heads: 1, seq: 8, d_head: 2 };
        let mut pool = SlotPool::new(1, lay);
        let mut s = Session::new(2, GenerationRequest::new(vec![1, 2], 2), lay,
                                 100, 0, pool.acquire().unwrap());
        s.cache_bytes = 100;
        let Residency::Dense(slot) = std::mem::replace(
            &mut s.residency,
            Residency::Parked { tail_k: vec![0.0; 4], tail_v: vec![0.0; 4],
                                tail_from: 2 },
        ) else {
            unreachable!()
        };
        pool.release(slot);
        assert!(s.is_parked());
        assert_eq!(s.resident_bytes(), 100 + 8 * 4);
    }
}
