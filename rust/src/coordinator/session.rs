//! Per-request decode state.
//!
//! A session owns the *materialized* fp32 cache buffers the decode
//! artifact consumes (scattered from the compressed store), the validity
//! mask, and the streaming-probe accumulator of Alg. 3.  The compressed
//! (`CompressedKV`) form is re-created at every recompression point; the
//! fp32 buffers in between hold recent uncompressed rows exactly like the
//! paper's streaming scheme.

use crate::kvcache::{CacheLayout, PrecisionClass};
use crate::runtime::ExecScratch;
use crate::saliency::StreamingProbe;

/// Reusable per-session scratch for the decode hot path (DESIGN.md §9):
/// the runtime execution slots plus the layer-mean attention-row buffer.
/// Warm after the first decode step; no steady-state heap allocation.
#[derive(Debug, Clone, Default)]
pub struct SessionScratch {
    /// Runtime boundary: borrowed-input execution + reusable output slots.
    pub exec: ExecScratch,
    /// Layer-mean of the decode attention row (`[S]`), fed to the
    /// streaming probe accumulator.
    pub a_mean: Vec<f32>,
}

/// State of one in-flight generation request.
#[derive(Debug)]
pub struct Session {
    pub id: u64,
    /// The prompt (token ids), length <= layout.seq.
    pub prompt: Vec<u16>,
    /// Number of live cache rows (prompt + generated so far).
    pub pos: usize,
    /// Generated tokens (excluding the prompt).
    pub generated: Vec<u16>,
    /// Decode budget.
    pub max_new: usize,
    /// Materialized fp32 caches, `[L, H, S, dh]`.
    pub kbuf: Vec<f32>,
    pub vbuf: Vec<f32>,
    /// Validity mask (1.0 = live row; 0 = evicted or empty).
    pub valid: Vec<f32>,
    /// Current per-token precision classes (from the last compression).
    pub classes: Vec<PrecisionClass>,
    /// Prefill-time saliency (normalized / accumulated), layer-averaged.
    pub norm_saliency: Vec<f32>,
    pub acc_saliency: Vec<f32>,
    /// Streaming probe accumulator (Alg. 3).
    pub stream: StreamingProbe,
    /// Next token to feed the decode artifact.
    pub next_token: u16,
    /// True until the prompt's final token has been decoded against the
    /// *compressed* cache (it is withheld from the prefill cache so the
    /// first generated token genuinely reads quantized state — see
    /// Engine::start_session).
    pub prompt_tail_pending: bool,
    pub done: bool,
    /// Bytes of the last compressed snapshot + its ratio.
    pub cache_bytes: usize,
    pub compression_ratio: f64,
    /// Wall-clock accounting (filled by the engine).
    pub prefill_us: u64,
    pub decode_us: u64,
    /// Decode hot-path scratch (execution slots + layer-mean buffer).
    pub scratch: SessionScratch,
}

impl Session {
    pub fn new(id: u64, prompt: Vec<u16>, max_new: usize, layout: CacheLayout,
               recompress_every: usize, seed: u64) -> Self {
        let n = layout.cache_len();
        Session {
            id,
            pos: prompt.len(),
            prompt,
            // Reserved up front: `generated` grows by one push per decode
            // step and must never reallocate mid-generation.
            generated: Vec::with_capacity(max_new),
            max_new,
            kbuf: vec![0f32; n],
            vbuf: vec![0f32; n],
            valid: vec![0f32; layout.seq],
            classes: Vec::new(),
            norm_saliency: Vec::new(),
            acc_saliency: Vec::new(),
            stream: StreamingProbe::new(recompress_every, 0.05, 0.05, seed),
            next_token: 0,
            prompt_tail_pending: false,
            done: false,
            cache_bytes: 0,
            compression_ratio: 1.0,
            prefill_us: 0,
            decode_us: 0,
            scratch: SessionScratch::default(),
        }
    }

    /// Room left in the fixed window.
    pub fn remaining_window(&self, seq: usize) -> usize {
        seq.saturating_sub(self.pos)
    }

    /// Generation finished (budget, EOS, or window exhausted)?
    pub fn is_done(&self) -> bool {
        self.done
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn session_init() {
        let lay = CacheLayout { layers: 2, heads: 2, seq: 16, d_head: 4 };
        let s = Session::new(1, vec![1, 2, 3], 5, lay, 100, 0);
        assert_eq!(s.pos, 3);
        assert_eq!(s.kbuf.len(), lay.cache_len());
        assert_eq!(s.remaining_window(16), 13);
        assert!(!s.is_done());
    }
}
