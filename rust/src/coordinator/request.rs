//! Typed request/response objects for the serving surface (DESIGN.md §11).
//!
//! One [`GenerationRequest`] travels the whole path — `ServerHandle` →
//! dispatcher → shard channel → [`super::batcher::ContinuousBatcher`] →
//! [`Engine::start_session`](super::Engine::start_session) — replacing
//! the positional `(prompt, max_new)` tuple the seed API hard-wired at
//! every layer.  The request carries everything admission and decode need
//! to know about *this* request: priority class, optional deadline,
//! optional per-request quantization override, optional seed override,
//! extra stop tokens, and the cancellation token its
//! [`ResponseHandle`](crate::server::ResponseHandle) shares.
//!
//! The admission contract lives in exactly one place,
//! [`GenerationRequest::validate`]: `ServerHandle::submit_request`
//! (submit-time rejection, so a bad request can never poison a shard) and
//! `Engine::start_session` (the engine's own invariant) both call it, so
//! the two checks cannot drift (they were hand-mirrored `ensure!` blocks
//! before).
//!
//! Determinism: a request built with all defaults is *bit-identical* to
//! the legacy `submit(prompt, max_new)` path — same content-derived seed
//! (`request_seed(cfg.seed, ..)`), same policy, same stop condition —
//! pinned by `rust/tests/serving_pool.rs`.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::kvcache::PrefixHit;
use crate::workload::tasks::EOS;
use crate::Result;

/// Request urgency class (DESIGN.md §11).  Order matters: admission pops
/// the queue in `rank()` order and the priority-aware park policy parks
/// `Background` sessions first under slot pressure.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Priority {
    /// Latency-sensitive: scheduled first, parked last.
    #[default]
    Interactive,
    /// Throughput work: behind Interactive, ahead of Background.
    Batch,
    /// Best-effort: parked first under slot pressure, scheduled last.
    Background,
}

impl Priority {
    pub const ALL: [Priority; 3] =
        [Priority::Interactive, Priority::Batch, Priority::Background];

    /// Scheduling rank: lower pops first (`Interactive` = 0).  Also the
    /// index into the per-priority metrics counters
    /// (`EngineMetrics::admitted_by_priority` and friends).
    pub fn rank(self) -> usize {
        match self {
            Priority::Interactive => 0,
            Priority::Batch => 1,
            Priority::Background => 2,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            Priority::Interactive => "interactive",
            Priority::Batch => "batch",
            Priority::Background => "background",
        }
    }
}

impl std::fmt::Display for Priority {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

impl std::str::FromStr for Priority {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "interactive" => Priority::Interactive,
            "batch" => Priority::Batch,
            "background" => Priority::Background,
            other => anyhow::bail!(
                "unknown priority '{other}' (interactive|batch|background)"
            ),
        })
    }
}

/// Per-request quantization override: a tenant-level precision/footprint
/// trade-off on top of the engine's configured policy kind (the paper's
/// per-workload knobs, but per request — DESIGN.md §11).  Only the class
/// mix and widths are overridable; the policy *kind* (and therefore the
/// prefill path it requires) stays the engine's.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QuantOverride {
    /// Bits for salient tokens (must be in {1, 2, 4, 8}).
    pub bits_high: u8,
    /// Bits for regular tokens (must be in {1, 2, 4, 8}, `<= bits_high`).
    pub bits_low: u8,
    /// Fraction of tokens treated as salient, in [0, 1].
    pub saliency_ratio: f64,
}

/// Shared cancellation flag: cloned between a request (read by the
/// batcher at pop time and between decode steps) and its
/// `ResponseHandle` (whose `cancel()` sets it).  Cancellation is
/// observed at the next scheduler iteration: the session's dense slot
/// returns to the pool and its byte-budget reservation is released
/// immediately, not at natural completion (DESIGN.md §11).
#[derive(Debug, Clone, Default)]
pub struct CancelToken(Arc<AtomicBool>);

impl CancelToken {
    pub fn new() -> Self {
        Self::default()
    }

    /// Request cancellation (idempotent, thread-safe).
    pub fn cancel(&self) {
        self.0.store(true, Ordering::SeqCst);
    }

    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::SeqCst)
    }
}

/// One generation request, built with the builder-style setters:
///
/// ```ignore
/// let req = GenerationRequest::new(prompt, 32)
///     .priority(Priority::Background)
///     .deadline_in(Duration::from_millis(500))
///     .quant(QuantOverride { bits_high: 8, bits_low: 4, saliency_ratio: 0.8 })
///     .stop_token(SEP);
/// ```
///
/// All-defaults requests reproduce the legacy positional path bit-exactly.
#[derive(Debug, Clone, Default)]
pub struct GenerationRequest {
    /// The prompt (token ids); non-empty, `len + max_new <= window`.
    pub prompt: Vec<u16>,
    /// Decode budget (>= 1).
    pub max_new: usize,
    /// Urgency class: queue pop order + park order (default Interactive).
    pub priority: Priority,
    /// Shed the request (with `FinishReason::DeadlineExpired`) if it is
    /// still waiting for a decode slot past this instant; checked at pop
    /// time, so an expired request never occupies a slot.
    pub deadline: Option<Instant>,
    /// Per-request quantization override (None = engine config).
    pub quant: Option<QuantOverride>,
    /// Per-request base-seed override (None = engine `cfg.seed`).  The
    /// effective seed is still content-derived
    /// (`request_seed(base, prompt, max_new)`), so determinism contracts
    /// hold per (override, content) pair.
    pub seed: Option<u64>,
    /// Extra stop tokens: generation finishes with `FinishReason::Eos`
    /// when the decoded token is `EOS` *or* any of these.
    pub stop_tokens: Vec<u16>,
    /// Cancellation flag shared with the request's `ResponseHandle`.
    pub cancel: CancelToken,
    /// Shared-prefix hit pinned at admission (DESIGN.md §16): the
    /// dispatcher resolves the prompt against the chosen shard's prefix
    /// store and attaches the pinned segment chain here; bare-engine
    /// callers leave it `None` and `Engine::begin_session` resolves
    /// against its own store.  Redelivery after a shard failure clears
    /// it (the replacement shard re-resolves on its own store), and
    /// dropping an unserved request releases the pins — both are what
    /// keeps the `seg_refs` gauge drainable.  Cloning a request clones
    /// the pins (counted).
    pub prefix: Option<PrefixHit>,
}

impl GenerationRequest {
    pub fn new(prompt: Vec<u16>, max_new: usize) -> Self {
        GenerationRequest { prompt, max_new, ..GenerationRequest::default() }
    }

    pub fn priority(mut self, p: Priority) -> Self {
        self.priority = p;
        self
    }

    /// Absolute deadline.
    pub fn deadline(mut self, at: Instant) -> Self {
        self.deadline = Some(at);
        self
    }

    /// Deadline relative to now (submission-side convenience).
    pub fn deadline_in(self, d: Duration) -> Self {
        self.deadline(Instant::now() + d)
    }

    pub fn quant(mut self, q: QuantOverride) -> Self {
        self.quant = Some(q);
        self
    }

    pub fn seed(mut self, s: u64) -> Self {
        self.seed = Some(s);
        self
    }

    /// Add one stop token (besides the built-in `EOS`).
    pub fn stop_token(mut self, t: u16) -> Self {
        self.stop_tokens.push(t);
        self
    }

    /// Share an externally created cancellation token (e.g. to cancel a
    /// request deterministically before it is ever popped).  `submit`
    /// paths clone the same token into the `ResponseHandle`.
    pub fn cancel_token(mut self, c: CancelToken) -> Self {
        self.cancel = c;
        self
    }

    /// Deadline expired as of `now`?
    pub fn expired(&self, now: Instant) -> bool {
        self.deadline.is_some_and(|d| now >= d)
    }

    /// Does decoding `tok` finish the generation with `FinishReason::Eos`?
    pub fn is_stop(stop_tokens: &[u16], tok: u16) -> bool {
        tok == EOS || stop_tokens.contains(&tok)
    }

    /// The single admission contract (DESIGN.md §11), shared by
    /// `ServerHandle::submit_request` (submit-time rejection) and
    /// `Engine::start_session` (engine invariant) so the two can never
    /// drift.  `window` is the model's max sequence length.
    pub fn validate(&self, window: usize) -> Result<()> {
        anyhow::ensure!(!self.prompt.is_empty(), "empty prompt");
        anyhow::ensure!(self.max_new >= 1,
                        "max_new must be >= 1 (a zero decode budget would \
                         still emit the prompt-tail token)");
        anyhow::ensure!(
            self.prompt.len() + self.max_new <= window,
            "prompt {} + budget {} exceeds window {window}",
            self.prompt.len(),
            self.max_new
        );
        if let Some(q) = &self.quant {
            anyhow::ensure!(matches!(q.bits_high, 1 | 2 | 4 | 8),
                            "override bits_high in {{1,2,4,8}}");
            anyhow::ensure!(matches!(q.bits_low, 1 | 2 | 4 | 8),
                            "override bits_low in {{1,2,4,8}}");
            anyhow::ensure!(q.bits_high >= q.bits_low,
                            "override bits_high >= bits_low");
            anyhow::ensure!((0.0..=1.0).contains(&q.saliency_ratio),
                            "override saliency_ratio must be in [0,1]");
        }
        Ok(())
    }
}

/// Why a generation finished (DESIGN.md §11).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FinishReason {
    /// Decoded `EOS` or a request stop token.
    Eos,
    /// Exhausted the decode budget or the model window.
    #[default]
    MaxTokens,
    /// Cancelled via `ResponseHandle::cancel` / the request's
    /// [`CancelToken`]; tokens generated before the cancel are kept.
    Cancelled,
    /// Shed at pop time: the deadline passed while the request waited
    /// for a decode slot (it never held one).
    DeadlineExpired,
    /// The shard serving this request died (panic, engine error, or a
    /// supervisor-severed stall — DESIGN.md §14) after the session was
    /// already live.  Tokens streamed before the failure are kept and
    /// are a prefix of the fault-free stream; the stream is never
    /// resumed or replayed, so callers observe at-most-once delivery.
    /// Requests still *waiting* on the dead shard are redelivered
    /// instead and never see this reason.
    ShardFailed,
}

impl FinishReason {
    /// Did the generation run to a natural end (`Eos` / `MaxTokens`)?
    /// The single definition of "natural completion" — metrics counting,
    /// load reports, and accuracy scoring all key off this, so a future
    /// finish reason classifies in one place.
    pub fn is_natural(self) -> bool {
        matches!(self, FinishReason::Eos | FinishReason::MaxTokens)
    }

    pub fn as_str(self) -> &'static str {
        match self {
            FinishReason::Eos => "eos",
            FinishReason::MaxTokens => "max_tokens",
            FinishReason::Cancelled => "cancelled",
            FinishReason::DeadlineExpired => "deadline_expired",
            FinishReason::ShardFailed => "shard_failed",
        }
    }
}

impl std::fmt::Display for FinishReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Result of one completed request: the legacy `GenerationOutput` fields
/// plus the request tag and the finish reason, so outcomes are
/// self-describing wherever they surface (batcher outcomes, server
/// replies, load reports).
#[derive(Debug, Clone)]
pub struct GenerationResponse {
    /// Global submission-order tag (0 for bare-engine runs).
    pub tag: u64,
    pub finish: FinishReason,
    /// Generated tokens (excluding the prompt).  For `Cancelled`, the
    /// tokens generated before the cancel; for `DeadlineExpired`, empty.
    pub tokens: Vec<u16>,
    pub prefill_ms: f64,
    pub decode_ms: f64,
    /// Ratio achieved by the last compression snapshot.
    pub compression_ratio: f64,
    pub cache_bytes: usize,
}

impl GenerationResponse {
    /// A response for a request that never held a session (deadline shed
    /// or cancelled while waiting).
    pub fn without_session(tag: u64, finish: FinishReason) -> Self {
        GenerationResponse {
            tag,
            finish,
            tokens: Vec::new(),
            prefill_ms: 0.0,
            decode_ms: 0.0,
            compression_ratio: 1.0,
            cache_bytes: 0,
        }
    }
}

/// Legacy alias: the pre-§11 name for a completed generation.  Field
/// accesses (`tokens`, `cache_bytes`, ...) are source-compatible.
pub type GenerationOutput = GenerationResponse;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_defaults_are_the_legacy_contract() {
        let r = GenerationRequest::new(vec![1, 2, 3], 4);
        assert_eq!(r.priority, Priority::Interactive);
        assert!(r.deadline.is_none() && r.quant.is_none() && r.seed.is_none());
        assert!(r.stop_tokens.is_empty());
        assert!(!r.cancel.is_cancelled());
        assert!(r.validate(16).is_ok());
    }

    #[test]
    fn validate_rejects_malformed_requests() {
        assert!(GenerationRequest::new(vec![], 4).validate(16).is_err());
        assert!(GenerationRequest::new(vec![1], 0).validate(16).is_err());
        assert!(GenerationRequest::new(vec![1; 13], 4).validate(16).is_err());
        assert!(GenerationRequest::new(vec![1; 12], 4).validate(16).is_ok());
    }

    #[test]
    fn validate_checks_quant_override() {
        let ok = QuantOverride { bits_high: 8, bits_low: 2, saliency_ratio: 0.5 };
        assert!(GenerationRequest::new(vec![1], 2).quant(ok).validate(16).is_ok());
        let bad_bits = QuantOverride { bits_high: 3, ..ok };
        assert!(GenerationRequest::new(vec![1], 2).quant(bad_bits)
            .validate(16).is_err());
        let inverted = QuantOverride { bits_high: 2, bits_low: 4,
                                       saliency_ratio: 0.5 };
        assert!(GenerationRequest::new(vec![1], 2).quant(inverted)
            .validate(16).is_err());
        let bad_ratio = QuantOverride { saliency_ratio: 1.5, ..ok };
        assert!(GenerationRequest::new(vec![1], 2).quant(bad_ratio)
            .validate(16).is_err());
    }

    #[test]
    fn priority_rank_orders_interactive_first() {
        assert!(Priority::Interactive.rank() < Priority::Batch.rank());
        assert!(Priority::Batch.rank() < Priority::Background.rank());
        assert_eq!("background".parse::<Priority>().unwrap(),
                   Priority::Background);
        assert!("urgent".parse::<Priority>().is_err());
    }

    #[test]
    fn cancel_token_is_shared() {
        let c = CancelToken::new();
        let r = GenerationRequest::new(vec![1], 2).cancel_token(c.clone());
        assert!(!r.cancel.is_cancelled());
        c.cancel();
        assert!(r.cancel.is_cancelled(), "token must be shared, not copied");
    }

    #[test]
    fn deadline_expiry() {
        let now = Instant::now();
        let r = GenerationRequest::new(vec![1], 2).deadline(now);
        assert!(r.expired(now));
        let r = GenerationRequest::new(vec![1], 2)
            .deadline_in(Duration::from_secs(3600));
        assert!(!r.expired(Instant::now()));
        assert!(!GenerationRequest::new(vec![1], 2).expired(now));
    }

    #[test]
    fn stop_tokens_extend_eos() {
        let stops = [7u16, 9];
        assert!(GenerationRequest::is_stop(&stops, EOS));
        assert!(GenerationRequest::is_stop(&stops, 7));
        assert!(GenerationRequest::is_stop(&stops, 9));
        assert!(!GenerationRequest::is_stop(&stops, 8));
        assert!(GenerationRequest::is_stop(&[], EOS));
        assert!(!GenerationRequest::is_stop(&[], 5));
    }

    #[test]
    fn is_natural_classifies_reasons() {
        assert!(FinishReason::Eos.is_natural());
        assert!(FinishReason::MaxTokens.is_natural());
        assert!(!FinishReason::Cancelled.is_natural());
        assert!(!FinishReason::DeadlineExpired.is_natural());
        assert!(!FinishReason::ShardFailed.is_natural());
        assert_eq!(FinishReason::ShardFailed.as_str(), "shard_failed");
    }

    #[test]
    fn without_session_response_shape() {
        let r = GenerationResponse::without_session(7, FinishReason::DeadlineExpired);
        assert_eq!(r.tag, 7);
        assert_eq!(r.finish, FinishReason::DeadlineExpired);
        assert!(r.tokens.is_empty());
        assert_eq!(r.cache_bytes, 0);
    }
}
