//! Continuous batcher: round-robin token-level interleaving of active
//! sessions (Orca-style iteration-level scheduling) with admission control.
//!
//! The decode artifact is single-sequence, so "batching" here is
//! interleaved scheduling rather than a batched matmul — the scheduling
//! behaviour (admission, fairness, completion-triggered refill from the
//! queue) is the part of the serving stack the paper's efficiency claims
//! interact with.  DESIGN.md records this substitution.
//!
//! `queue_depth` only applies when the batcher is driven directly (bench
//! harnesses, run_to_completion).  Under the sharded server the
//! dispatcher is the single admission point and feeds the batcher
//! strictly within its free decode slots, so this depth never stacks on
//! the server's boundary (DESIGN.md §8).

use std::collections::VecDeque;

use crate::Result;

use super::engine::{Engine, GenerationOutput};
use super::session::Session;

/// A queued request.
#[derive(Debug, Clone)]
pub struct QueuedRequest {
    pub prompt: Vec<u16>,
    pub max_new: usize,
    /// Opaque tag returned with the outcome (e.g. trace index).
    pub tag: u64,
}

/// Completed request + its output.
#[derive(Debug)]
pub struct BatchOutcome {
    pub tag: u64,
    pub output: GenerationOutput,
}

/// Iteration-level continuous batcher over one engine.
pub struct ContinuousBatcher {
    max_batch: usize,
    queue_depth: usize,
    queue: VecDeque<QueuedRequest>,
    active: Vec<(u64, Session)>,
    outcomes: Vec<BatchOutcome>,
}

impl ContinuousBatcher {
    pub fn new(max_batch: usize, queue_depth: usize) -> Self {
        ContinuousBatcher {
            max_batch,
            queue_depth,
            queue: VecDeque::new(),
            active: Vec::new(),
            outcomes: Vec::new(),
        }
    }

    /// Admit a request; `Err` = backpressure (queue full).
    pub fn submit(&mut self, req: QueuedRequest) -> std::result::Result<(), QueuedRequest> {
        if self.queue.len() >= self.queue_depth {
            return Err(req);
        }
        self.queue.push_back(req);
        Ok(())
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    pub fn active(&self) -> usize {
        self.active.len()
    }

    pub fn idle(&self) -> bool {
        self.queue.is_empty() && self.active.is_empty()
    }

    /// Run one scheduler iteration: refill the batch from the queue
    /// (prefill), then advance every active session by one token.
    pub fn step(&mut self, engine: &mut Engine) -> Result<()> {
        // Admission: fill free slots (prefill happens here).
        while self.active.len() < self.max_batch {
            let Some(req) = self.queue.pop_front() else { break };
            let sess = engine.start_session(req.prompt, req.max_new)?;
            self.active.push((req.tag, sess));
        }
        // Iteration-level decode across the batch.
        for (_, sess) in self.active.iter_mut() {
            engine.decode_step(sess)?;
        }
        // Retire finished sessions.
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].1.is_done() {
                let (tag, sess) = self.active.swap_remove(i);
                self.outcomes.push(BatchOutcome { tag, output: engine.finish(sess) });
            } else {
                i += 1;
            }
        }
        Ok(())
    }

    /// Drive until every queued/active request completes; returns outcomes
    /// sorted by tag.
    pub fn run_to_completion(&mut self, engine: &mut Engine) -> Result<Vec<BatchOutcome>> {
        while !self.idle() {
            self.step(engine)?;
        }
        let mut out = std::mem::take(&mut self.outcomes);
        out.sort_by_key(|o| o.tag);
        Ok(out)
    }

    /// Take completed outcomes accumulated so far.
    pub fn take_outcomes(&mut self) -> Vec<BatchOutcome> {
        std::mem::take(&mut self.outcomes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backpressure_rejects_when_full() {
        let mut b = ContinuousBatcher::new(2, 2);
        let req = QueuedRequest { prompt: vec![1], max_new: 1, tag: 0 };
        assert!(b.submit(req.clone()).is_ok());
        assert!(b.submit(req.clone()).is_ok());
        assert!(b.submit(req).is_err());
        assert_eq!(b.pending(), 2);
    }

    #[test]
    fn idle_initially() {
        let b = ContinuousBatcher::new(4, 8);
        assert!(b.idle());
        assert_eq!(b.active(), 0);
    }
}
