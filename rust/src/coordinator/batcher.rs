//! Continuous batcher: round-robin token-level interleaving of active
//! sessions (Orca-style iteration-level scheduling) with admission control
//! and bounded dense residency (DESIGN.md §10).
//!
//! The decode artifact is single-sequence, so "batching" here is
//! interleaved scheduling rather than a batched matmul — the scheduling
//! behaviour (admission, fairness, completion-triggered refill from the
//! queue) is the part of the serving stack the paper's efficiency claims
//! interact with.  DESIGN.md records this substitution.
//!
//! Dense residency: the engine's slot pool holds at most `memory.slots`
//! materialization slots, so when more sessions are active than slots
//! exist, each iteration *schedules in* only `slots` of them (per the
//! pluggable [`ParkPolicy`]) and parks the rest — their compressed
//! snapshot stays resident, the dense buffers do not.  With
//! `slots == max_batch` every active session is scheduled every
//! iteration and nothing is ever parked, reproducing the unbounded
//! behaviour bit-identically.
//!
//! `queue_depth` only applies when the batcher is driven directly (bench
//! harnesses, run_to_completion).  Under the sharded server the
//! dispatcher is the single admission point and feeds the batcher
//! strictly within its free decode slots, so this depth never stacks on
//! the server's boundary (DESIGN.md §8).

use std::collections::VecDeque;

use crate::Result;

use super::engine::{Engine, GenerationOutput};
use super::session::Session;

/// A queued request.
#[derive(Debug, Clone)]
pub struct QueuedRequest {
    pub prompt: Vec<u16>,
    pub max_new: usize,
    /// Opaque tag returned with the outcome (e.g. trace index).
    pub tag: u64,
}

/// Completed request + its output.
#[derive(Debug)]
pub struct BatchOutcome {
    pub tag: u64,
    pub output: GenerationOutput,
}

/// Scheduling view of one active session, handed to the [`ParkPolicy`].
#[derive(Debug, Clone, Copy)]
pub struct SessionMeta {
    /// Engine-assigned session id (monotone in admission order on one
    /// engine — the round-robin cursor walks it).
    pub session_id: u64,
    /// Batcher iteration at which this session last decoded a token
    /// (admission iteration until then).
    pub last_step: u64,
    /// Currently holding a dense materialization slot?
    pub resident: bool,
}

/// Which active sessions hold dense slots this iteration — the park
/// decision inverted (everyone *not* selected is parked as needed).
/// Implementations must be deterministic: the residency refactor keeps
/// outputs independent of the policy (park/unpark is bit-exact), but
/// park counts and latency profiles are part of the bench surface.
pub trait ParkPolicy: Send {
    fn name(&self) -> &'static str;
    /// Append up to `n_run` indices into `metas` onto `out` (which
    /// arrives empty): the sessions to schedule in.
    fn schedule(&mut self, metas: &[SessionMeta], n_run: usize, out: &mut Vec<usize>);
}

/// Rotate a window of `n_run` sessions through the active list in
/// session-id order: every session is scheduled once per
/// `ceil(active / slots)` iterations.
#[derive(Debug, Default)]
pub struct RoundRobinPark {
    cursor: u64,
}

impl ParkPolicy for RoundRobinPark {
    fn name(&self) -> &'static str {
        "round-robin"
    }

    fn schedule(&mut self, metas: &[SessionMeta], n_run: usize, out: &mut Vec<usize>) {
        if metas.is_empty() || n_run == 0 {
            return;
        }
        // Indices in cyclic session-id order starting at the cursor.
        let mut order: Vec<usize> = (0..metas.len()).collect();
        order.sort_by_key(|&i| metas[i].session_id);
        let start = order
            .iter()
            .position(|&i| metas[i].session_id >= self.cursor)
            .unwrap_or(0);
        for k in 0..n_run.min(order.len()) {
            out.push(order[(start + k) % order.len()]);
        }
        let last = out[out.len() - 1];
        self.cursor = metas[last].session_id + 1;
    }
}

/// Schedule the sessions that decoded least recently (oldest
/// `last_step` first; session id breaks ties).  Equivalent to
/// round-robin under a static batch, fairer when sessions join and
/// leave mid-flight.
#[derive(Debug, Default)]
pub struct LruByLastStep;

impl ParkPolicy for LruByLastStep {
    fn name(&self) -> &'static str {
        "lru-by-last-step"
    }

    fn schedule(&mut self, metas: &[SessionMeta], n_run: usize, out: &mut Vec<usize>) {
        let mut order: Vec<usize> = (0..metas.len()).collect();
        order.sort_by_key(|&i| (metas[i].last_step, metas[i].session_id));
        out.extend(order.into_iter().take(n_run));
    }
}

struct Active {
    tag: u64,
    sess: Session,
    last_step: u64,
}

/// Iteration-level continuous batcher over one engine.
pub struct ContinuousBatcher {
    max_batch: usize,
    queue_depth: usize,
    queue: VecDeque<QueuedRequest>,
    active: Vec<Active>,
    outcomes: Vec<BatchOutcome>,
    policy: Box<dyn ParkPolicy>,
    /// Iteration counter feeding `SessionMeta::last_step`.
    step_counter: u64,
    /// Sessions parked to free a slot (admission or schedule-in).
    preempted: u64,
    // Reusable scheduling scratch.
    sched: Vec<usize>,
    metas: Vec<SessionMeta>,
}

impl ContinuousBatcher {
    pub fn new(max_batch: usize, queue_depth: usize) -> Self {
        Self::with_policy(max_batch, queue_depth,
                          Box::new(RoundRobinPark::default()))
    }

    /// Like [`ContinuousBatcher::new`] with an explicit park policy.
    pub fn with_policy(max_batch: usize, queue_depth: usize,
                       policy: Box<dyn ParkPolicy>) -> Self {
        ContinuousBatcher {
            max_batch,
            queue_depth,
            queue: VecDeque::new(),
            active: Vec::new(),
            outcomes: Vec::new(),
            policy,
            step_counter: 0,
            preempted: 0,
            sched: Vec::new(),
            metas: Vec::new(),
        }
    }

    /// Admit a request; `Err` = backpressure (queue full).
    pub fn submit(&mut self, req: QueuedRequest) -> std::result::Result<(), QueuedRequest> {
        if self.queue.len() >= self.queue_depth {
            return Err(req);
        }
        self.queue.push_back(req);
        Ok(())
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    pub fn active(&self) -> usize {
        self.active.len()
    }

    pub fn idle(&self) -> bool {
        self.queue.is_empty() && self.active.is_empty()
    }

    /// Sessions parked to free a materialization slot so far.
    pub fn preempted(&self) -> u64 {
        self.preempted
    }

    /// Bytes currently resident across active sessions: compressed
    /// snapshots + parked tails + checked-out dense slots
    /// (DESIGN.md §10).  The dispatcher weights routing by this, and the
    /// scheduler publishes it into the engine's resident gauge.
    pub fn active_bytes(&self) -> usize {
        self.active.iter().map(|a| a.sess.resident_bytes()).sum()
    }

    /// Run one scheduler iteration: refill the batch from the queue
    /// (prefill — parking a victim when the slot pool is exhausted),
    /// schedule up to `slots` sessions dense, advance each of them by
    /// one token, and retire the finished ones.
    pub fn step(&mut self, engine: &mut Engine) -> Result<()> {
        self.step_counter += 1;
        // Admission: fill free decode slots (prefill happens here, so
        // each admission needs a dense materialization slot).
        while self.active.len() < self.max_batch && !self.queue.is_empty() {
            if engine.free_slots() == 0 && !self.park_one(engine) {
                break;
            }
            let req = self.queue.pop_front().expect("checked non-empty");
            let sess = engine.start_session(req.prompt, req.max_new)?;
            self.active.push(Active {
                tag: req.tag,
                sess,
                last_step: self.step_counter,
            });
        }

        // Schedule-in: pick which sessions hold dense slots this
        // iteration.  When every active session fits (slots >=
        // active — always true at `slots == max_batch`), skip the
        // policy entirely: nothing is parked and the decode order is
        // exactly the unbounded batcher's.
        let n_run = engine.slot_capacity().min(self.active.len());
        self.sched.clear();
        if n_run == self.active.len() {
            self.sched.extend(0..self.active.len());
            // Everyone fits — but a session parked under earlier pressure
            // (batch has since drained) still needs its slot back.
            // No-op for dense sessions, so the `slots == max_batch` path
            // stays exactly the unbounded batcher.
            for &i in &self.sched {
                engine.unpark(&mut self.active[i].sess)?;
            }
        } else {
            self.metas.clear();
            self.metas.extend(self.active.iter().map(|a| SessionMeta {
                session_id: a.sess.id,
                last_step: a.last_step,
                resident: !a.sess.is_parked(),
            }));
            self.policy.schedule(&self.metas, n_run, &mut self.sched);
            // Decode in active order regardless of policy order (outputs
            // are interleaving-independent; this keeps traces readable).
            self.sched.sort_unstable();
            // Park every resident session not scheduled in — exactly the
            // slots the scheduled parked sessions are about to take.
            for i in 0..self.active.len() {
                if self.sched.binary_search(&i).is_err()
                    && !self.active[i].sess.is_parked()
                {
                    engine.park(&mut self.active[i].sess);
                    self.preempted += 1;
                }
            }
            for &i in &self.sched {
                engine.unpark(&mut self.active[i].sess)?;
            }
        }

        // Iteration-level decode across the scheduled set.
        for &i in &self.sched {
            let a = &mut self.active[i];
            engine.decode_step(&mut a.sess)?;
            a.last_step = self.step_counter;
        }

        // Retire finished sessions.
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].sess.is_done() {
                let a = self.active.swap_remove(i);
                self.outcomes.push(BatchOutcome {
                    tag: a.tag,
                    output: engine.finish(a.sess),
                });
            } else {
                i += 1;
            }
        }
        engine.metrics.note_resident(self.active_bytes());
        Ok(())
    }

    /// Park one resident session (the policy's last pick survives
    /// longest: we keep the `residents - 1` sessions it would schedule
    /// and park the leftover).  Returns false when nothing is parkable.
    fn park_one(&mut self, engine: &mut Engine) -> bool {
        let residents: Vec<usize> = (0..self.active.len())
            .filter(|&i| !self.active[i].sess.is_parked())
            .collect();
        if residents.is_empty() {
            return false;
        }
        self.metas.clear();
        self.metas.extend(residents.iter().map(|&i| SessionMeta {
            session_id: self.active[i].sess.id,
            last_step: self.active[i].last_step,
            resident: true,
        }));
        self.sched.clear();
        self.policy
            .schedule(&self.metas, self.metas.len() - 1, &mut self.sched);
        let victim = (0..self.metas.len())
            .find(|m| !self.sched.contains(m))
            .expect("n-1 of n scheduled leaves one victim");
        engine.park(&mut self.active[residents[victim]].sess);
        self.preempted += 1;
        true
    }

    /// Drive until every queued/active request completes; returns outcomes
    /// sorted by tag.
    pub fn run_to_completion(&mut self, engine: &mut Engine) -> Result<Vec<BatchOutcome>> {
        while !self.idle() {
            self.step(engine)?;
        }
        let mut out = std::mem::take(&mut self.outcomes);
        out.sort_by_key(|o| o.tag);
        Ok(out)
    }

    /// Take completed outcomes accumulated so far.
    pub fn take_outcomes(&mut self) -> Vec<BatchOutcome> {
        std::mem::take(&mut self.outcomes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backpressure_rejects_when_full() {
        let mut b = ContinuousBatcher::new(2, 2);
        let req = QueuedRequest { prompt: vec![1], max_new: 1, tag: 0 };
        assert!(b.submit(req.clone()).is_ok());
        assert!(b.submit(req.clone()).is_ok());
        assert!(b.submit(req).is_err());
        assert_eq!(b.pending(), 2);
    }

    #[test]
    fn idle_initially() {
        let b = ContinuousBatcher::new(4, 8);
        assert!(b.idle());
        assert_eq!(b.active(), 0);
        assert_eq!(b.preempted(), 0);
        assert_eq!(b.active_bytes(), 0);
    }

    fn metas(ids: &[u64], steps: &[u64]) -> Vec<SessionMeta> {
        ids.iter()
            .zip(steps)
            .map(|(&session_id, &last_step)| SessionMeta {
                session_id,
                last_step,
                resident: true,
            })
            .collect()
    }

    #[test]
    fn round_robin_rotates_across_calls() {
        let mut p = RoundRobinPark::default();
        let m = metas(&[0, 1, 2], &[0, 0, 0]);
        let mut out = Vec::new();
        p.schedule(&m, 1, &mut out);
        assert_eq!(out, vec![0]);
        out.clear();
        p.schedule(&m, 1, &mut out);
        assert_eq!(out, vec![1]);
        out.clear();
        p.schedule(&m, 1, &mut out);
        assert_eq!(out, vec![2]);
        out.clear();
        p.schedule(&m, 1, &mut out); // wraps
        assert_eq!(out, vec![0]);
        out.clear();
        p.schedule(&m, 2, &mut out); // window > 1 advances past its end
        assert_eq!(out, vec![1, 2]);
    }

    #[test]
    fn round_robin_survives_retirement() {
        let mut p = RoundRobinPark::default();
        let mut out = Vec::new();
        p.schedule(&metas(&[0, 1, 2], &[0, 0, 0]), 1, &mut out);
        assert_eq!(out, vec![0]);
        // Session 1 retired; cursor (=1) falls through to id 2.
        out.clear();
        p.schedule(&metas(&[0, 2], &[0, 0]), 1, &mut out);
        assert_eq!(out, vec![1]); // index of id 2
    }

    #[test]
    fn lru_prefers_oldest_last_step() {
        let mut p = LruByLastStep;
        let m = metas(&[0, 1, 2], &[5, 2, 9]);
        let mut out = Vec::new();
        p.schedule(&m, 2, &mut out);
        assert_eq!(out, vec![1, 0]); // steps 2, then 5
    }

    #[test]
    fn lru_ties_break_by_session_id() {
        let mut p = LruByLastStep;
        let m = metas(&[7, 3, 5], &[4, 4, 4]);
        let mut out = Vec::new();
        p.schedule(&m, 1, &mut out);
        assert_eq!(out, vec![1]); // id 3 is the lowest
    }
}
