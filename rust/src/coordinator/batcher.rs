//! Continuous batcher: token-level interleaving of active sessions
//! (Orca-style iteration-level scheduling) with priority-ordered
//! admission, deadline shedding, cancellation, token streaming, and
//! bounded dense residency (DESIGN.md §10, §11).
//!
//! The decode artifact is single-sequence, so "batching" here is
//! interleaved scheduling rather than a batched matmul — the scheduling
//! behaviour (admission, fairness, completion-triggered refill from the
//! queue) is the part of the serving stack the paper's efficiency claims
//! interact with.  DESIGN.md records this substitution.
//!
//! Admission (DESIGN.md §11): the staging queue is *priority-ordered* —
//! pops take the waiting request with the lowest
//! `(Priority::rank, tag)`, so `Interactive` requests jump `Background`
//! ones, and equal priorities preserve submission order (which keeps the
//! all-defaults path identical to the old FIFO).  Both the pop order and
//! the park policy apply a [`STARVATION_AGE`] boost, so priority delays
//! low-class work but can never starve it — every admitted request
//! eventually activates and every active session keeps progressing,
//! like the seed's FIFO.  At pop time, cancelled
//! requests and requests whose deadline already passed retire immediately
//! with `Cancelled` / `DeadlineExpired` outcomes — they never consume a
//! materialization slot.  Active sessions whose [`CancelToken`] fires are
//! retired at the next iteration, before admission, so their dense slot
//! is back in the pool for the same iteration's refill.
//!
//! Dense residency: the engine's slot pool holds at most `memory.slots`
//! materialization slots, so when more sessions are active than slots
//! exist, each iteration *schedules in* only `slots` of them (per the
//! pluggable [`ParkPolicy`]) and parks the rest — their compressed
//! snapshot stays resident, the dense buffers do not.  With
//! `slots == max_batch` every active session is scheduled every
//! iteration and nothing is ever parked, reproducing the unbounded
//! behaviour bit-identically.
//!
//! `queue_depth` only applies when the batcher is driven directly (bench
//! harnesses, run_to_completion).  Under the sharded server the
//! dispatcher is the single admission point: its global waiting count is
//! decremented only when a request leaves the staging queue (activation
//! or shed — [`StepReport::activated`]), so staging requests here never
//! stacks a second depth on the server's boundary (DESIGN.md §8).

use std::time::Instant;

use crate::Result;

use super::engine::Engine;
use super::request::{FinishReason, GenerationRequest, GenerationResponse,
                     Priority};
use super::session::Session;

/// A queued request: the typed request plus its submission-order tag.
#[derive(Debug, Clone)]
pub struct QueuedRequest {
    pub request: GenerationRequest,
    /// Opaque tag carried onto the outcome (e.g. trace index or the
    /// dispatcher's global submission index).
    pub tag: u64,
}

/// What one scheduler iteration did, beyond decoding: how many waiting
/// requests left the staging queue (activated into a session, or retired
/// at pop as cancelled/deadline-shed).  The sharded server decrements its
/// global `queued` gauge by this, keeping `queue_depth` an exact boundary
/// even though requests stage here (DESIGN.md §8, §11).
#[derive(Debug, Clone, Copy, Default)]
pub struct StepReport {
    pub activated: usize,
    /// Decode-artifact executions this iteration (one per scheduled
    /// decode-phase session, whether or not a token was emitted).
    pub decoded: usize,
    /// Prefill chunks run this iteration (a monolithic prefill at
    /// admission counts as one).  Together with `prefill_tokens` and
    /// `decoded` this lets a deterministic virtual clock price the
    /// iteration from `simcost` instead of wall time (DESIGN.md §12).
    pub prefill_chunks: usize,
    /// Prompt tokens covered by those chunks.
    pub prefill_tokens: usize,
}

/// Scheduling view of one active session, handed to the [`ParkPolicy`].
#[derive(Debug, Clone, Copy)]
pub struct SessionMeta {
    /// Engine-assigned session id (monotone in admission order on one
    /// engine — the round-robin cursor walks it).
    pub session_id: u64,
    /// Batcher iteration at which this session last decoded a token
    /// (admission iteration until then).
    pub last_step: u64,
    /// Currently holding a dense materialization slot?
    pub resident: bool,
    /// Request urgency class (the priority-aware policy parks
    /// `Background` first).
    pub priority: Priority,
}

/// Which active sessions hold dense slots this iteration — the park
/// decision inverted (everyone *not* selected is parked as needed).
/// Implementations must be deterministic: the residency refactor keeps
/// outputs independent of the policy (park/unpark is bit-exact), but
/// park counts and latency profiles are part of the bench surface.
pub trait ParkPolicy: Send {
    fn name(&self) -> &'static str;
    /// Append up to `n_run` indices into `metas` onto `out` (which
    /// arrives empty): the sessions to schedule in.
    fn schedule(&mut self, metas: &[SessionMeta], n_run: usize, out: &mut Vec<usize>);
}

/// Rotate a window of `n_run` sessions through the active list in
/// session-id order: every session is scheduled once per
/// `ceil(active / slots)` iterations.
#[derive(Debug, Default)]
pub struct RoundRobinPark {
    cursor: u64,
}

impl ParkPolicy for RoundRobinPark {
    fn name(&self) -> &'static str {
        "round-robin"
    }

    fn schedule(&mut self, metas: &[SessionMeta], n_run: usize, out: &mut Vec<usize>) {
        if metas.is_empty() || n_run == 0 {
            return;
        }
        // Indices in cyclic session-id order starting at the cursor.
        let mut order: Vec<usize> = (0..metas.len()).collect();
        order.sort_by_key(|&i| metas[i].session_id);
        let start = order
            .iter()
            .position(|&i| metas[i].session_id >= self.cursor)
            .unwrap_or(0);
        for k in 0..n_run.min(order.len()) {
            out.push(order[(start + k) % order.len()]);
        }
        let last = out[out.len() - 1];
        self.cursor = metas[last].session_id + 1;
    }
}

/// Schedule the sessions that decoded least recently (oldest
/// `last_step` first; session id breaks ties).  Equivalent to
/// round-robin under a static batch, fairer when sessions join and
/// leave mid-flight.
#[derive(Debug, Default)]
pub struct LruByLastStep;

impl ParkPolicy for LruByLastStep {
    fn name(&self) -> &'static str {
        "lru-by-last-step"
    }

    fn schedule(&mut self, metas: &[SessionMeta], n_run: usize, out: &mut Vec<usize>) {
        let mut order: Vec<usize> = (0..metas.len()).collect();
        order.sort_by_key(|&i| (metas[i].last_step, metas[i].session_id));
        out.extend(order.into_iter().take(n_run));
    }
}

/// Iterations a request may go unserved before its class stops
/// outranking it, applied on both priority surfaces: the staging-queue
/// pop ([`ContinuousBatcher`]'s `best_waiting`) and the park policy
/// ([`PriorityPark`]).  Starvation is therefore bounded end to end —
/// an admitted `Background` request activates within `STARVATION_AGE`
/// iterations of queue pressure, and once active decodes at least one
/// token every `STARVATION_AGE` iterations even under sustained
/// `Interactive` pressure — so it always progresses toward completion
/// (and toward releasing its queue_depth slot and byte-budget
/// reservation) instead of blocking its client forever.
const STARVATION_AGE: u64 = 8;

/// Priority-aware parking (DESIGN.md §11): schedule `Interactive`
/// sessions first and `Background` last, LRU (then session id) inside a
/// class — so under slot pressure `Background` sessions are the first to
/// lose their dense slot.  Strict priority is tempered by aging: a
/// session unscheduled for [`STARVATION_AGE`] iterations is treated as
/// top-class (and, being the least-recent inside it, scheduled first),
/// so no class can be starved indefinitely.  The sharded server's
/// batchers run this policy.  Outputs are still policy-independent
/// (park/unpark is bit-exact); only park counts and latency profiles
/// move.
#[derive(Debug, Default)]
pub struct PriorityPark;

impl ParkPolicy for PriorityPark {
    fn name(&self) -> &'static str {
        "priority-lru"
    }

    fn schedule(&mut self, metas: &[SessionMeta], n_run: usize, out: &mut Vec<usize>) {
        // Age is measured against the most recently scheduled session
        // (the policy sees no global clock; the freshest `last_step` is
        // at most one iteration behind it).
        let newest = metas.iter().map(|m| m.last_step).max().unwrap_or(0);
        let mut order: Vec<usize> = (0..metas.len()).collect();
        order.sort_by_key(|&i| {
            let m = &metas[i];
            let rank = if newest.saturating_sub(m.last_step) >= STARVATION_AGE {
                0
            } else {
                m.priority.rank()
            };
            (rank, m.last_step, m.session_id)
        });
        out.extend(order.into_iter().take(n_run));
    }
}

struct Active {
    sess: Session,
    last_step: u64,
}

/// One staged (waiting) request plus the scheduler iteration it entered
/// the queue at — the aging reference that keeps strict priority pops
/// from starving an admitted low-priority request (the seed's FIFO
/// guaranteed eventual activation; the aged pop restores that bound).
struct Staged {
    req: QueuedRequest,
    staged_step: u64,
}

/// Iteration-level continuous batcher over one engine.
pub struct ContinuousBatcher {
    max_batch: usize,
    queue_depth: usize,
    /// Priority-ordered staging queue (pop order is by
    /// `(aged priority rank, tag)`, decided at pop — storage order is
    /// irrelevant).
    queue: Vec<Staged>,
    active: Vec<Active>,
    outcomes: Vec<GenerationResponse>,
    /// `(tag, token)` stream of the latest iteration's decode output, in
    /// emission order; the serving loop drains it after every step and
    /// forwards each token to its request's `ResponseHandle`
    /// (DESIGN.md §11).  Cleared at the top of each step, so it never
    /// grows past one iteration's tokens.
    emitted: Vec<(u64, u16)>,
    policy: Box<dyn ParkPolicy>,
    /// Iteration counter feeding `SessionMeta::last_step`.
    step_counter: u64,
    /// Requests that left the staging queue (activated into a session or
    /// retired at pop) whose departure has not yet been reported through
    /// a [`StepReport`].  Nonzero only mid-step — or after a step
    /// errored out part-way, in which case the server's fault cleanup
    /// drains it ([`ContinuousBatcher::take_departed`]) so the global
    /// waiting gauge stays exact even for departures inside a failed
    /// step.
    departed: usize,
    /// Sessions parked to free a slot (admission or schedule-in).
    preempted: u64,
    /// Test/bench hook (DESIGN.md §12): run every remaining prefill
    /// chunk of a scheduled Prefilling session in one iteration,
    /// ignoring scheduled decode traffic — the starvation mode the
    /// fairness tests assert the default policy avoids.
    greedy_prefill: bool,
    // Reusable scheduling scratch.
    sched: Vec<usize>,
    metas: Vec<SessionMeta>,
    /// Active-list index behind each entry of `metas` (Prefilling
    /// sessions are excluded from the policy's view, so meta index !=
    /// active index under chunked prefill).
    meta_idx: Vec<usize>,
    /// Policy output scratch (indices into `metas`).
    picked: Vec<usize>,
}

impl ContinuousBatcher {
    pub fn new(max_batch: usize, queue_depth: usize) -> Self {
        Self::with_policy(max_batch, queue_depth,
                          Box::new(RoundRobinPark::default()))
    }

    /// Like [`ContinuousBatcher::new`] with an explicit park policy.
    pub fn with_policy(max_batch: usize, queue_depth: usize,
                       policy: Box<dyn ParkPolicy>) -> Self {
        ContinuousBatcher {
            max_batch,
            queue_depth,
            queue: Vec::new(),
            active: Vec::new(),
            outcomes: Vec::new(),
            emitted: Vec::new(),
            policy,
            step_counter: 0,
            departed: 0,
            preempted: 0,
            greedy_prefill: false,
            sched: Vec::new(),
            metas: Vec::new(),
            meta_idx: Vec::new(),
            picked: Vec::new(),
        }
    }

    /// Force a scheduled Prefilling session to take *all* its remaining
    /// chunks in one iteration (DESIGN.md §12).  Default off: a
    /// Prefilling session yields after one chunk whenever a decode-phase
    /// session of equal or higher urgency is scheduled.  The fairness
    /// tests flip this on to demonstrate the latency bound trips when
    /// prefill is allowed to starve decode.
    pub fn force_greedy_prefill(&mut self, on: bool) {
        self.greedy_prefill = on;
    }

    /// Admit a request; `Err` = backpressure (queue full).
    pub fn submit(&mut self, req: QueuedRequest) -> std::result::Result<(), QueuedRequest> {
        if self.queue.len() >= self.queue_depth {
            return Err(req);
        }
        self.queue.push(Staged { req, staged_step: self.step_counter });
        Ok(())
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    pub fn active(&self) -> usize {
        self.active.len()
    }

    pub fn idle(&self) -> bool {
        self.queue.is_empty() && self.active.is_empty()
    }

    /// Sessions parked to free a materialization slot so far.
    pub fn preempted(&self) -> u64 {
        self.preempted
    }

    /// Bytes currently resident across active sessions: compressed
    /// snapshots + parked tails + checked-out dense slots
    /// (DESIGN.md §10).  The dispatcher weights routing by this, and the
    /// scheduler publishes it into the engine's resident gauge.
    pub fn active_bytes(&self) -> usize {
        self.active.iter().map(|a| a.sess.resident_bytes()).sum()
    }

    /// The waiting request to pop next: lowest `(priority rank, tag)`,
    /// with the same [`STARVATION_AGE`] boost as the park policy — a
    /// request waiting that many scheduler iterations is treated as
    /// top-class (tag order then favors it over fresher arrivals), so
    /// sustained high-priority traffic delays a `Background` request but
    /// can never pin its queue_depth slot and byte-budget reservation
    /// forever (the seed's FIFO guaranteed eventual activation; this
    /// restores that bound under priority ordering).
    fn best_waiting(&self) -> Option<usize> {
        (0..self.queue.len()).min_by_key(|&i| {
            let e = &self.queue[i];
            let rank = if self.step_counter.saturating_sub(e.staged_step)
                >= STARVATION_AGE
            {
                0
            } else {
                e.req.request.priority.rank()
            };
            (rank, e.req.tag)
        })
    }

    /// Run one scheduler iteration: retire cancelled sessions (their
    /// slots free up first), refill the batch from the staging queue in
    /// priority order — shedding cancelled/expired requests at pop time
    /// without a slot — schedule up to `slots` sessions dense, advance
    /// each of them by one token, and retire the finished ones.
    // lint: cold-path — scheduling layer; the §9 zero-alloc contract
    // covers `Engine::decode_step`, not batch bookkeeping.  Also stops
    // the name-level resolution of `StreamingProbe::step` calls from
    // descending here (DESIGN.md §13).
    pub fn step(&mut self, engine: &mut Engine) -> Result<StepReport> {
        self.step_counter += 1;
        // The token stream covers one iteration: callers that want it
        // (the serving loop) drain between steps; everyone else —
        // run_to_completion, bench harnesses driving step() directly —
        // must not accumulate it unboundedly.  Clearing keeps capacity,
        // so the steady-state loop still allocates nothing here.
        self.emitted.clear();

        // Cancellation sweep: flag fired since the last iteration —
        // retire *before* admission so the dense slot is already back in
        // the pool when the refill below needs one.
        let mut swept = false;
        for a in &mut self.active {
            if !a.sess.is_done() && a.sess.cancel.is_cancelled() {
                a.sess.finish = FinishReason::Cancelled;
                a.sess.done = true;
                swept = true;
            }
        }
        if swept {
            self.retire_finished(engine);
        }

        // Waiting-queue lifecycle sweep: every staged request whose
        // cancel token fired or whose deadline passed retires *now* —
        // regardless of queue position or free decode slots — so its
        // outcome (and the server-side load/byte reservation keyed on
        // it) is released this iteration, never stuck behind
        // higher-priority traffic.
        let now = Instant::now();
        let mut i = 0;
        while i < self.queue.len() {
            let cancelled = self.queue[i].req.request.cancel.is_cancelled();
            let expired = self.queue[i].req.request.expired(now);
            if !(cancelled || expired) {
                i += 1;
                continue;
            }
            let q = self.queue.swap_remove(i).req;
            let finish = if cancelled {
                engine.metrics.cancelled += 1;
                FinishReason::Cancelled
            } else {
                engine.metrics.shed_by_priority[q.request.priority.rank()] += 1;
                FinishReason::DeadlineExpired
            };
            self.outcomes
                .push(GenerationResponse::without_session(q.tag, finish));
            self.departed += 1;
        }

        // Admission, in priority order: pop the lowest
        // `(Priority::rank, tag)` while decode slots remain, parking a
        // victim when the pool is exhausted.  With chunked prefill the
        // admitted session enters the Prefilling phase and its prompt is
        // processed by the chunk loop below, interleaved with decode;
        // with `prefill_chunk = 0` the whole prefill runs here, exactly
        // as before (DESIGN.md §12).  A cancel firing between the sweep
        // above and the pop is caught by the next iteration's
        // active-session sweep.
        let mut prefill_chunks = 0usize;
        let mut prefill_tokens = 0usize;
        while self.active.len() < self.max_batch {
            let Some(best) = self.best_waiting() else { break };
            if engine.free_slots() == 0 && !self.park_one(engine) {
                break;
            }
            let q = self.queue.swap_remove(best).req;
            let tag = q.tag;
            self.departed += 1;
            let mut sess = engine.begin_session(q.request)?;
            sess.tag = tag;
            if !sess.is_prefilling() {
                // Monolithic prefill just ran: one all-covering "chunk"
                // in the report's work accounting.
                prefill_chunks += 1;
                prefill_tokens += sess.prompt.len();
            }
            self.active.push(Active { sess, last_step: self.step_counter });
        }

        // Schedule-in: pick which sessions hold dense slots this
        // iteration.  When every active session fits (slots >=
        // active — always true at `slots == max_batch`), skip the
        // policy entirely: nothing is parked and the decode order is
        // exactly the unbounded batcher's.
        let n_run = engine.slot_capacity().min(self.active.len());
        self.sched.clear();
        if n_run == self.active.len() {
            self.sched.extend(0..self.active.len());
            // Everyone fits — but a session parked under earlier pressure
            // (batch has since drained) still needs its slot back.
            // No-op for dense sessions, so the `slots == max_batch` path
            // stays exactly the unbounded batcher.
            for &i in &self.sched {
                engine.unpark(&mut self.active[i].sess)?;
            }
        } else {
            // Prefilling sessions pin their dense slots (no compressed
            // snapshot to park to — DESIGN.md §12): they are always
            // scheduled, and the park policy decides over the remaining
            // sessions and slots only.
            self.metas.clear();
            self.meta_idx.clear();
            for (i, a) in self.active.iter().enumerate() {
                if a.sess.is_prefilling() {
                    self.sched.push(i);
                } else {
                    self.meta_idx.push(i);
                    self.metas.push(SessionMeta {
                        session_id: a.sess.id,
                        last_step: a.last_step,
                        resident: !a.sess.is_parked(),
                        priority: a.sess.priority,
                    });
                }
            }
            let n_decode_run = engine
                .slot_capacity()
                .saturating_sub(self.sched.len())
                .min(self.metas.len());
            self.picked.clear();
            self.policy.schedule(&self.metas, n_decode_run, &mut self.picked);
            for k in 0..self.picked.len() {
                self.sched.push(self.meta_idx[self.picked[k]]);
            }
            // Decode in active order regardless of policy order (outputs
            // are interleaving-independent; this keeps traces readable).
            self.sched.sort_unstable();
            // Park every resident session not scheduled in — exactly the
            // slots the scheduled parked sessions are about to take.
            // (Prefilling sessions are all in `sched`, so they are never
            // selected as victims here.)
            for i in 0..self.active.len() {
                if self.sched.binary_search(&i).is_err()
                    && !self.active[i].sess.is_parked()
                {
                    engine.park(&mut self.active[i].sess);
                    self.preempted += 1;
                }
            }
            for &i in &self.sched {
                engine.unpark(&mut self.active[i].sess)?;
            }
        }

        // Chunked prefill (DESIGN.md §12): every scheduled Prefilling
        // session advances at least one chunk per iteration (so prefill
        // can never be starved), and yields after that one chunk
        // whenever a decode-phase session of equal or higher urgency is
        // scheduled — Background prefill yields to Interactive decode.
        // With no such traffic (or under the greedy test hook) it bursts
        // every remaining chunk now; a session finishing its last chunk
        // falls through to the decode loop in this same iteration, which
        // keeps `prefill_chunk >= prompt_len` step-aligned with the
        // monolithic path.
        for k in 0..self.sched.len() {
            let i = self.sched[k];
            if !self.active[i].sess.is_prefilling() {
                continue;
            }
            let my_rank = self.active[i].sess.priority.rank();
            let yields = !self.greedy_prefill
                && self.sched.iter().any(|&j| {
                    let a = &self.active[j];
                    j != i
                        && !a.sess.is_prefilling()
                        && !a.sess.is_done()
                        && a.sess.priority.rank() <= my_rank
                });
            loop {
                let n = self.active[i].sess.prompt.len();
                let covered = {
                    let p = self.active[i].sess.prefill.as_ref()
                        .expect("prefilling checked above");
                    (n - p.done).min(p.chunk)
                };
                let finished = engine.prefill_chunk(&mut self.active[i].sess)?;
                prefill_chunks += 1;
                prefill_tokens += covered;
                if finished || yields {
                    break;
                }
            }
            self.active[i].last_step = self.step_counter;
        }

        // Iteration-level decode across the scheduled set; every emitted
        // token is streamed (tag-keyed) for incremental delivery.
        // Sessions still Prefilling after their chunk allowance skip
        // decode this iteration.
        let mut decoded = 0usize;
        for &i in &self.sched {
            let a = &mut self.active[i];
            if a.sess.is_prefilling() {
                continue;
            }
            if let Some(tok) = engine.decode_step(&mut a.sess)? {
                self.emitted.push((a.sess.tag, tok));
            }
            decoded += 1;
            a.last_step = self.step_counter;
        }

        // Retire finished sessions.
        self.retire_finished(engine);
        engine.metrics.note_resident(self.active_bytes());
        Ok(StepReport {
            activated: std::mem::take(&mut self.departed),
            decoded,
            prefill_chunks,
            prefill_tokens,
        })
    }

    /// Departures (queue exits) not yet reported through a
    /// [`StepReport`] — nonzero only after a `step` error interrupted
    /// the report.  The serving loop's fault cleanup drains this so a
    /// failed step's activations still leave the global waiting gauge.
    pub fn take_departed(&mut self) -> usize {
        std::mem::take(&mut self.departed)
    }

    /// Move every done session out of the active set, through
    /// `Engine::finish` (slot release + metrics), into the outcome list.
    fn retire_finished(&mut self, engine: &mut Engine) {
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].sess.is_done() {
                let a = self.active.swap_remove(i);
                self.outcomes.push(engine.finish(a.sess));
            } else {
                i += 1;
            }
        }
    }

    /// Park one resident session (the policy's last pick survives
    /// longest: we keep the `residents - 1` sessions it would schedule
    /// and park the leftover).  Returns false when nothing is parkable
    /// — including when every resident is mid-prefill (a Prefilling
    /// session pins its slot; DESIGN.md §12).
    fn park_one(&mut self, engine: &mut Engine) -> bool {
        let residents: Vec<usize> = (0..self.active.len())
            .filter(|&i| {
                !self.active[i].sess.is_parked()
                    && !self.active[i].sess.is_prefilling()
            })
            .collect();
        if residents.is_empty() {
            return false;
        }
        self.metas.clear();
        self.metas.extend(residents.iter().map(|&i| SessionMeta {
            session_id: self.active[i].sess.id,
            last_step: self.active[i].last_step,
            resident: true,
            priority: self.active[i].sess.priority,
        }));
        self.sched.clear();
        self.policy
            .schedule(&self.metas, self.metas.len() - 1, &mut self.sched);
        let victim = (0..self.metas.len())
            .find(|m| !self.sched.contains(m))
            .expect("n-1 of n scheduled leaves one victim");
        engine.park(&mut self.active[residents[victim]].sess);
        self.preempted += 1;
        true
    }

    /// Drive until every queued/active request completes; returns
    /// responses sorted by tag.
    pub fn run_to_completion(&mut self, engine: &mut Engine)
                             -> Result<Vec<GenerationResponse>> {
        while !self.idle() {
            self.step(engine)?;
        }
        let mut out = std::mem::take(&mut self.outcomes);
        out.sort_by_key(|o| o.tag);
        Ok(out)
    }

    /// Take completed responses accumulated so far.
    pub fn take_outcomes(&mut self) -> Vec<GenerationResponse> {
        std::mem::take(&mut self.outcomes)
    }

    /// Drain every *staged* (not yet activated) request, in storage
    /// order.  Shard-fatal path only (DESIGN.md §14): these requests
    /// never touched engine state, so the supervisor can redeliver them
    /// to a live shard and their content-derived seeds reproduce the
    /// fault-free output bit-for-bit.  Does not touch the departure
    /// counter — redelivered requests keep their global waiting slot.
    pub fn take_staged(&mut self) -> Vec<QueuedRequest> {
        self.queue.drain(..).map(|s| s.req).collect()
    }

    /// Drain every *active* session.  Shard-fatal path only
    /// (DESIGN.md §14): these sessions already streamed tokens, so they
    /// cannot be redelivered without violating at-most-once streaming —
    /// the caller answers each with `FinishReason::ShardFailed` and the
    /// tokens generated so far.
    pub fn take_active(&mut self) -> Vec<Session> {
        self.active.drain(..).map(|a| a.sess).collect()
    }

    /// Drain the `(tag, token)` stream emitted by the *latest*
    /// [`ContinuousBatcher::step`], in emission order (each step clears
    /// the previous iteration's stream, so undrained tokens do not
    /// accumulate in direct-drive mode).  A drain (not `mem::take`) so
    /// the buffer keeps its capacity: the serving loop calls this every
    /// scheduler iteration and must not re-allocate the stream Vec per
    /// step (DESIGN.md §9's allocation discipline).
    pub fn drain_emitted(&mut self) -> std::vec::Drain<'_, (u64, u16)> {
        self.emitted.drain(..)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(tag: u64) -> QueuedRequest {
        QueuedRequest { request: GenerationRequest::new(vec![1], 1), tag }
    }

    #[test]
    fn backpressure_rejects_when_full() {
        let mut b = ContinuousBatcher::new(2, 2);
        assert!(b.submit(req(0)).is_ok());
        assert!(b.submit(req(1)).is_ok());
        assert!(b.submit(req(2)).is_err());
        assert_eq!(b.pending(), 2);
    }

    #[test]
    fn idle_initially() {
        let b = ContinuousBatcher::new(4, 8);
        assert!(b.idle());
        assert_eq!(b.active(), 0);
        assert_eq!(b.preempted(), 0);
        assert_eq!(b.active_bytes(), 0);
    }

    #[test]
    fn pop_order_is_priority_then_tag() {
        let mut b = ContinuousBatcher::new(4, 8);
        let mk = |tag, p: Priority| QueuedRequest {
            request: GenerationRequest::new(vec![1], 1).priority(p),
            tag,
        };
        b.submit(mk(0, Priority::Background)).unwrap();
        b.submit(mk(1, Priority::Interactive)).unwrap();
        b.submit(mk(2, Priority::Batch)).unwrap();
        b.submit(mk(3, Priority::Interactive)).unwrap();
        let mut order = Vec::new();
        while let Some(i) = b.best_waiting() {
            order.push(b.queue.swap_remove(i).req.tag);
        }
        // Interactive (by tag), then Batch, then Background.
        assert_eq!(order, vec![1, 3, 2, 0]);
    }

    #[test]
    fn waiting_queue_ages_starved_requests_to_the_front() {
        // A Background request staged STARVATION_AGE iterations ago is
        // boosted to top class and outranks a fresh Interactive arrival
        // (tag order inside the class favors the older request) — the
        // pop-side half of the anti-starvation bound.
        let mut b = ContinuousBatcher::new(4, 8);
        let mk = |tag, p: Priority| QueuedRequest {
            request: GenerationRequest::new(vec![1], 1).priority(p),
            tag,
        };
        b.submit(mk(0, Priority::Background)).unwrap(); // staged at step 0
        b.step_counter = STARVATION_AGE;
        b.submit(mk(1, Priority::Interactive)).unwrap();
        assert_eq!(b.best_waiting(), Some(0), "starved request must pop first");
        // One iteration earlier it would still lose to Interactive.
        b.step_counter = STARVATION_AGE - 1;
        assert_eq!(b.best_waiting(), Some(1));
    }

    fn metas(ids: &[u64], steps: &[u64]) -> Vec<SessionMeta> {
        metas_p(ids, steps, &vec![Priority::Interactive; ids.len()])
    }

    fn metas_p(ids: &[u64], steps: &[u64], prios: &[Priority]) -> Vec<SessionMeta> {
        ids.iter()
            .zip(steps)
            .zip(prios)
            .map(|((&session_id, &last_step), &priority)| SessionMeta {
                session_id,
                last_step,
                resident: true,
                priority,
            })
            .collect()
    }

    #[test]
    fn round_robin_rotates_across_calls() {
        let mut p = RoundRobinPark::default();
        let m = metas(&[0, 1, 2], &[0, 0, 0]);
        let mut out = Vec::new();
        p.schedule(&m, 1, &mut out);
        assert_eq!(out, vec![0]);
        out.clear();
        p.schedule(&m, 1, &mut out);
        assert_eq!(out, vec![1]);
        out.clear();
        p.schedule(&m, 1, &mut out);
        assert_eq!(out, vec![2]);
        out.clear();
        p.schedule(&m, 1, &mut out); // wraps
        assert_eq!(out, vec![0]);
        out.clear();
        p.schedule(&m, 2, &mut out); // window > 1 advances past its end
        assert_eq!(out, vec![1, 2]);
    }

    #[test]
    fn round_robin_survives_retirement() {
        let mut p = RoundRobinPark::default();
        let mut out = Vec::new();
        p.schedule(&metas(&[0, 1, 2], &[0, 0, 0]), 1, &mut out);
        assert_eq!(out, vec![0]);
        // Session 1 retired; cursor (=1) falls through to id 2.
        out.clear();
        p.schedule(&metas(&[0, 2], &[0, 0]), 1, &mut out);
        assert_eq!(out, vec![1]); // index of id 2
    }

    #[test]
    fn lru_prefers_oldest_last_step() {
        let mut p = LruByLastStep;
        let m = metas(&[0, 1, 2], &[5, 2, 9]);
        let mut out = Vec::new();
        p.schedule(&m, 2, &mut out);
        assert_eq!(out, vec![1, 0]); // steps 2, then 5
    }

    #[test]
    fn lru_ties_break_by_session_id() {
        let mut p = LruByLastStep;
        let m = metas(&[7, 3, 5], &[4, 4, 4]);
        let mut out = Vec::new();
        p.schedule(&m, 1, &mut out);
        assert_eq!(out, vec![1]); // id 3 is the lowest
    }

    #[test]
    fn priority_park_schedules_background_out_first() {
        let mut p = PriorityPark;
        // Background decoded least recently — LRU alone would keep it,
        // but priority outranks recency across classes (ages here are
        // all below the starvation threshold).
        let m = metas_p(&[0, 1, 2], &[9, 7, 8],
                        &[Priority::Interactive, Priority::Background,
                          Priority::Batch]);
        let mut out = Vec::new();
        p.schedule(&m, 2, &mut out);
        assert_eq!(out, vec![0, 2], "Background must be the parked leftover");
        // Inside a class, LRU (then id) still orders.
        out.clear();
        let m = metas_p(&[0, 1, 2], &[5, 2, 2],
                        &[Priority::Batch, Priority::Batch, Priority::Batch]);
        p.schedule(&m, 2, &mut out);
        assert_eq!(out, vec![1, 2]);
    }

    #[test]
    fn priority_park_ages_starved_sessions_back_in() {
        let mut p = PriorityPark;
        // A Background session unscheduled for STARVATION_AGE iterations
        // is boosted to top class and (being least-recent there)
        // scheduled first — bounded starvation, not strict priority.
        let m = metas_p(&[0, 1], &[20, 20 - STARVATION_AGE],
                        &[Priority::Interactive, Priority::Background]);
        let mut out = Vec::new();
        p.schedule(&m, 1, &mut out);
        assert_eq!(out, vec![1], "starved Background must be boosted");
        // One iteration younger: still below the threshold, priority wins.
        out.clear();
        let m = metas_p(&[0, 1], &[20, 20 - STARVATION_AGE + 1],
                        &[Priority::Interactive, Priority::Background]);
        p.schedule(&m, 1, &mut out);
        assert_eq!(out, vec![0]);
    }

    #[test]
    fn priority_park_matches_lru_when_unprioritized() {
        // All-defaults requests must schedule exactly like LruByLastStep
        // (the serving pool's previous behaviour modulo policy).
        let m = metas(&[3, 1, 2], &[7, 7, 4]);
        let (mut a, mut b) = (PriorityPark, LruByLastStep);
        let (mut oa, mut ob) = (Vec::new(), Vec::new());
        a.schedule(&m, 2, &mut oa);
        b.schedule(&m, 2, &mut ob);
        assert_eq!(oa, ob);
    }
}
