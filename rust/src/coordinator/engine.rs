//! The engine: prefill (Alg. 2), decode + streaming recompression (Alg. 3)
//! over the PJRT artifacts, parameterized by a compression policy.
//!
//! Both compression points — the prefill snapshot and every streaming
//! recompression cycle — fan the independent `(layer, head)` planes out
//! across the engine's [`WorkerPool`] (`cfg.parallelism`, DESIGN.md §5)
//! and record per-stage timing in `EngineMetrics::compress_stages`.

use std::sync::Arc;
use std::time::Instant;

use crate::baselines::{
    CompressionPolicy, Fp16Policy, GearPolicy, H2oPolicy, KiviPolicy, MikvPolicy,
    PolicyInput, ZipCachePolicy,
};
use crate::config::{EngineConfig, PolicyKind, QuantConfig};
use crate::kvcache::prefix_store::DEFAULT_GRANULE;
use crate::kvcache::{CacheLayout, CompressScratch, CompressedKV, PrefixHit,
                     PrefixStore, SlotPool};
use crate::metrics::EngineMetrics;
use crate::runtime::{FaultInjector, FaultPlan, FaultSite, Runtime, Tensor, TensorView};
use crate::saliency::{select_probes, ProbeStrategy};
use crate::util::pool::WorkerPool;
use crate::Result;

use crate::runtime::ExecScratch;

use super::request::{FinishReason, GenerationRequest, GenerationResponse};
use super::session::{PolicyOverride, PrefillProgress, Residency, Session};

/// The serving engine for one model config + one compression policy.
pub struct Engine {
    pub cfg: EngineConfig,
    rt: Runtime,
    policy: Box<dyn CompressionPolicy>,
    /// Plane-level compression pool (DESIGN.md §5), sized by
    /// `cfg.parallelism`.
    pool: WorkerPool,
    /// Compression-cycle scratch reused across sessions and cycles
    /// (DESIGN.md §9).
    scratch: CompressScratch,
    /// Bounded pool of dense materialization slots (DESIGN.md §10):
    /// `memory.slots` of them (default `max_batch`), checked out by the
    /// sessions currently scheduled for decode.
    slots: SlotPool,
    /// Precomputed `decode_<model>` entry name — the decode hot path must
    /// not rebuild this string every step.
    decode_entry: String,
    /// Content-addressed shared-prefix segment store (DESIGN.md §16).
    /// `None` when `prefix.enable` is off or the backend lacks the
    /// chunked entries (the saliency catch-up entry rides the same
    /// capability).  Bare engines own theirs; under a server the shard
    /// loop installs the dispatcher-shared store so it survives shard
    /// respawns ([`Engine::set_prefix_store`]).
    prefix_store: Option<Arc<PrefixStore>>,
    pub metrics: EngineMetrics,
    next_session_id: u64,
}

impl Engine {
    pub fn new(cfg: EngineConfig) -> Result<Self> {
        cfg.validate()?;
        // Resolve the quant kernel once, before any engine touches the
        // hot path (DESIGN.md §15): a `simd` request on a scalar-only
        // CPU dies here, not mid-decode.
        crate::quant::kernel::apply_choice(cfg.quant.kernel)?;
        let rt = Runtime::load(&cfg.artifacts_dir, &cfg.model)?;
        let policy = make_policy(&cfg);
        let pool = WorkerPool::new(cfg.parallelism);
        let decode_entry = rt.entry("decode");
        let slot_cap = if cfg.memory.slots == 0 {
            cfg.scheduler.max_batch
        } else {
            cfg.memory.slots
        };
        let slots = SlotPool::new(slot_cap.max(1), rt.model_info().cache_layout());
        // Segment hash boundaries follow the prefill chunking so a warm
        // session resumes exactly at a cold chunk boundary; with
        // monolithic prefill the DEFAULT_GRANULE keeps segments
        // shareable at a fixed stride (DESIGN.md §16).
        let prefix_store = if cfg.prefix.enable && rt.supports_chunked_prefill() {
            let granule = if cfg.scheduler.prefill_chunk > 0 {
                cfg.scheduler.prefill_chunk
            } else {
                DEFAULT_GRANULE
            };
            Some(PrefixStore::new(&cfg.model, cfg.policy, granule,
                                  cfg.prefix.max_bytes))
        } else {
            None
        };
        Ok(Engine { cfg, rt, policy, pool, scratch: CompressScratch::default(),
                    slots, decode_entry, prefix_store,
                    metrics: EngineMetrics::default(),
                    next_session_id: 0 })
    }

    /// The compression worker pool (width follows `cfg.parallelism`).
    pub fn pool(&self) -> &WorkerPool {
        &self.pool
    }

    /// The dense materialization-slot pool (DESIGN.md §10).
    pub fn slot_pool(&self) -> &SlotPool {
        &self.slots
    }

    /// Total materialization slots (`memory.slots`, default `max_batch`).
    pub fn slot_capacity(&self) -> usize {
        self.slots.capacity()
    }

    /// Slots acquirable right now (schedulers park a session when 0).
    pub fn free_slots(&self) -> usize {
        self.slots.available()
    }

    /// Install a dispatcher-shared prefix store (DESIGN.md §16).  The
    /// server calls this from the shard loop so the store outlives any
    /// one engine incarnation: a respawned shard re-attaches to the
    /// same interned segments instead of starting cold.
    pub fn set_prefix_store(&mut self, store: Arc<PrefixStore>) {
        self.prefix_store = Some(store);
    }

    /// The shared-prefix segment store, when enabled (DESIGN.md §16).
    pub fn prefix_store(&self) -> Option<&Arc<PrefixStore>> {
        self.prefix_store.as_ref()
    }

    /// Swap the compression policy (bench harnesses sweep these).
    pub fn set_policy(&mut self, kind: PolicyKind) {
        self.cfg.policy = kind;
        self.policy = make_policy(&self.cfg);
    }

    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    pub fn layout(&self) -> CacheLayout {
        self.rt.model_info().cache_layout()
    }

    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }

    /// Arm fault injection (DESIGN.md §14) for the shard that owns this
    /// engine: parses `cfg.faults.plan` (already checked by
    /// `EngineConfig::validate`) and decorates the runtime with a
    /// [`FaultInjector`].  No-op on an empty plan, so bare engines and
    /// fault-free servers stay bit-identical.
    pub fn arm_faults(&mut self, shard: usize) -> Result<()> {
        if self.cfg.faults.plan.is_empty() {
            return Ok(());
        }
        let plan = FaultPlan::parse(&self.cfg.faults.plan)?;
        let seed = self.cfg.faults.seed;
        self.rt.arm_faults(FaultInjector::new(&plan, shard, seed));
        Ok(())
    }

    /// Convenience: run one prompt to completion with a defaults-built
    /// request (the legacy positional signature, kept as a thin wrapper
    /// — DESIGN.md §11).
    pub fn generate(&mut self, prompt: &[u16], max_new: usize)
                    -> Result<GenerationResponse> {
        self.generate_request(GenerationRequest::new(prompt.to_vec(), max_new))
    }

    /// Run one typed request to completion.
    pub fn generate_request(&mut self, req: GenerationRequest)
                            -> Result<GenerationResponse> {
        let mut s = self.start_session(req)?;
        while !s.is_done() {
            self.decode_step(&mut s)?;
        }
        Ok(self.finish(s))
    }

    pub fn finish(&mut self, s: Session) -> GenerationResponse {
        // Counting discipline (DESIGN.md §11): `requests_completed` counts
        // *natural* completions only, so it always equals the
        // `completed_by_priority` sum — a cancel lands in `cancelled`
        // whether it fired while the request was still waiting (pop-time
        // retirement, no session) or mid-decode (this path), instead of
        // shifting between counters with cancel timing.
        match s.finish {
            _ if s.finish.is_natural() => {
                self.metrics.requests_completed += 1;
                self.metrics.completed_by_priority[s.priority.rank()] += 1;
            }
            FinishReason::Cancelled => self.metrics.cancelled += 1,
            // Unreachable today: deadlines are checked only at pop time,
            // before a session exists (the batcher counts the shed
            // there).  Kept so a future mid-decode deadline check lands
            // in the same counter — for any one request the two paths
            // are mutually exclusive, so this can never double-count.
            FinishReason::DeadlineExpired => {
                self.metrics.shed_by_priority[s.priority.rank()] += 1;
            }
            FinishReason::ShardFailed => {
                // Normally the server's fatal path answers failed
                // sessions itself (the engine is gone with the shard —
                // DESIGN.md §14); counted here defensively so a future
                // in-engine path can never lose the failure.
                self.metrics.failed_sessions += 1;
            }
            _ => unreachable!("is_natural covers Eos and MaxTokens"),
        }
        // Return the dense slot to the pool (a parked session holds none).
        if let Residency::Dense(slot) = s.residency {
            self.slots.release(slot);
        }
        GenerationResponse {
            tag: s.tag,
            finish: s.finish,
            tokens: s.generated,
            prefill_ms: s.prefill_us as f64 / 1000.0,
            decode_ms: s.decode_us as f64 / 1000.0,
            compression_ratio: s.compression_ratio,
            cache_bytes: s.cache_bytes,
        }
    }

    /// Effective prefill chunk size (DESIGN.md §12): the
    /// `scheduler.prefill_chunk` knob when the backend provides the
    /// chunked entries, else 0 — monolithic prefill, today's behavior
    /// bit-for-bit.
    pub fn prefill_chunk_size(&self) -> usize {
        if self.rt.supports_chunked_prefill() {
            self.cfg.scheduler.prefill_chunk
        } else {
            0
        }
    }

    /// Alg. 2: prefill, saliency, compression; returns a live session
    /// holding a dense slot checked out of the pool (DESIGN.md §10).
    /// Fails when the pool is exhausted — schedulers park a session
    /// first ([`Engine::park`]).  Request validation goes through the
    /// shared [`GenerationRequest::validate`] contract (DESIGN.md §11),
    /// the same check `ServerHandle` applies at submit time.
    ///
    /// With chunked prefill enabled this runs every chunk back-to-back —
    /// the same work as [`Engine::begin_session`] +
    /// [`Engine::prefill_chunk`] to completion, for callers that do not
    /// interleave (bare-engine loops, benches).  An error mid-prefill
    /// drops the session; its dense slot returns to the pool via the
    /// [`DenseSlot`](crate::kvcache::DenseSlot) drop path.
    pub fn start_session(&mut self, req: GenerationRequest) -> Result<Session> {
        let mut s = self.begin_session(req)?;
        while s.is_prefilling() {
            self.prefill_chunk(&mut s)?;
        }
        Ok(s)
    }

    /// Resolve the shared-prefix hit for an incoming request
    /// (DESIGN.md §16).  A dispatcher-attached hit (admission-time
    /// affinity) wins; a bare engine consults its own store.  Backends
    /// without the chunked entries cannot run the saliency catch-up, so
    /// any hit is dropped there — cold-start semantics, bit-identical
    /// to prefix-disabled.
    // lint: cold-path — once per admission (DESIGN.md §13).
    fn resolve_prefix(&mut self, req: &mut GenerationRequest) -> Option<PrefixHit> {
        let attached = req.prefix.take();
        if !self.rt.supports_chunked_prefill() {
            return None;
        }
        let hit = match attached {
            Some(h) if h.covered > 0 && h.covered < req.prompt.len() => Some(h),
            _ => self.prefix_store.as_ref().and_then(|st| st.lookup(&req.prompt)),
        };
        if self.prefix_store.is_some() || hit.is_some() {
            match &hit {
                Some(h) => {
                    self.metrics.prefix_hits += 1;
                    self.metrics.prefill_tokens_skipped += h.covered as u64;
                }
                None => self.metrics.prefix_misses += 1,
            }
        }
        hit
    }

    /// Publish the session's exact fp32 prefix rows into the shared
    /// store (DESIGN.md §16).  Must run *before* `compress_session`
    /// dequantizes the slot in place — the store only ever sees
    /// bit-exact prefill output.  A warm session re-interns the same
    /// bytes (an LRU touch for existing links, fresh links past its
    /// covered span), so hit and cold admissions stay symmetric.
    // lint: cold-path — once per prefill (DESIGN.md §13).
    fn intern_prefix(&mut self, s: &Session, layout: CacheLayout) {
        let Some(store) = &self.prefix_store else { return };
        let Residency::Dense(slot) = &s.residency else { return };
        store.intern(&s.prompt, &slot.kbuf, &slot.vbuf, &layout);
        // Store-derived gauges refresh at the only point they can move.
        self.metrics.prefix_evictions = store.evictions();
        self.metrics.shared_segment_bytes = store.shared_bytes() as u64;
    }

    /// Admit a session without necessarily finishing its prefill
    /// (DESIGN.md §12).  With `prefill_chunk = 0` (or a backend without
    /// the chunked entries) this completes the monolithic prefill and
    /// returns a decode-ready session — exactly the historical
    /// `start_session` body.  Otherwise it acquires the dense slot,
    /// stages the chunked-prefill state, and returns a session in the
    /// *Prefilling* phase; the scheduler then drives
    /// [`Engine::prefill_chunk`] between decode iterations.
    pub fn begin_session(&mut self, mut req: GenerationRequest) -> Result<Session> {
        let chunk = self.prefill_chunk_size();
        // Resolve any shared-prefix hit first (DESIGN.md §16): a hit
        // reroutes even the `prefill_chunk = 0` config through the
        // chunked machinery (one suffix chunk), because the saliency
        // catch-up entry is what lets prefill skip the covered span.
        let hit = self.resolve_prefix(&mut req);
        if chunk == 0 && hit.is_none() {
            return self.start_session_monolithic(req);
        }
        let info = self.rt.model_info().clone();
        let layout = info.cache_layout();
        req.validate(info.max_seq)?;
        let (prompt, max_new) = (&req.prompt, req.max_new);

        let id = self.next_session_id;
        self.next_session_id += 1;
        // Same content-derived seed as the monolithic path (DESIGN.md §8).
        let seed = request_seed(req.seed.unwrap_or(self.cfg.seed), prompt, max_new);

        let n = prompt.len();
        let covered = hit.as_ref().map_or(0, |h| h.covered);
        debug_assert!(covered < n, "prefix hit may never cover the last token");
        // A warm hit under monolithic config prefills the whole
        // uncovered suffix as one chunk; the `start_session` drive loop
        // then completes it in a single `prefill_chunk` call.
        let eff_chunk = if chunk == 0 { n - covered } else { chunk };
        let smax = info.max_seq;
        let mut tokens = vec![0i32; smax];
        for (i, &t) in prompt.iter().enumerate() {
            tokens[i] = t as i32;
        }
        let full_scores = self.policy.requires_full_scores();
        let probes = if full_scores {
            Vec::new()
        } else {
            // Probe selection is over the *full* prompt before any chunk
            // runs — identical draws to the monolithic path, padded and
            // sorted the same way.  A warm hit changes nothing here: the
            // draws depend only on request content (DESIGN.md §8), which
            // is what makes fork-from-prefix bit-identical to cold start.
            let probes = select_probes(ProbeStrategy::RandomRecent, n,
                                       self.cfg.quant.probe_ratio, None, seed);
            let pc = info.probe_count;
            let mut pidx: Vec<i32> = probes.iter().map(|&i| i as i32).collect();
            while pidx.len() < pc {
                pidx.push((n - 1) as i32); // repeat last token (harmless dup)
            }
            pidx.truncate(pc);
            pidx.sort_unstable();
            pidx
        };

        // The slot is acquired up front: chunk rows scatter straight into
        // it (an abandoned session's slot returns to the pool on drop).
        let mut slot = self.slots.acquire().ok_or_else(|| {
            anyhow::anyhow!(
                "no free materialization slot ({} in use; park a session first)",
                self.slots.capacity()
            )
        })?;
        // Seed the slot from the shared segments: rows [0, covered) land
        // exactly as the cold prefill would have written them (segments
        // hold exact fp32 prefill rows — DESIGN.md §16), so every chunk
        // that follows reads a bit-identical prefix.
        if let Some(h) = &hit {
            for r in &h.segs {
                r.segment().materialize_into(&mut slot.kbuf, &mut slot.vbuf,
                                             &layout);
            }
        }
        let mut valid = vec![0f32; smax];
        for v in valid[..covered].iter_mut() {
            *v = 1.0;
        }
        let mut p = Box::new(PrefillProgress {
            done: covered,
            chunk: eff_chunk,
            tokens,
            valid,
            probes,
            full_scores,
            sal: vec![0f32; info.n_layers * smax],
            us: 0,
            exec: ExecScratch::default(),
        });
        // Saliency catch-up over the covered span (DESIGN.md §16): the
        // dedicated `prefill_sal_*` entry replays exactly the
        // accumulator additions the skipped chunks would have performed
        // — same f32 order — so the accumulator state entering the first
        // live chunk matches a cold run bitwise.
        if covered > 0 {
            let tc = Instant::now();
            let entry = self.rt.entry(if full_scores {
                "prefill_sal_full"
            } else {
                "prefill_sal_flash"
            });
            let start_in = [0i32];
            let end_in = [covered as i32];
            let win_dims = [smax];
            let sal_dims = [info.n_layers, smax];
            {
                let PrefillProgress { tokens, valid, probes, sal, exec,
                                      full_scores, .. } = &mut *p;
                let probe_dims = [probes.len()];
                let mut inputs = vec![
                    TensorView::i32(tokens, &win_dims),
                    TensorView::f32(valid, &win_dims),
                    TensorView::scalar_i32(&start_in),
                    TensorView::scalar_i32(&end_in),
                ];
                if !*full_scores {
                    inputs.push(TensorView::i32(probes, &probe_dims));
                }
                inputs.push(TensorView::f32(sal, &sal_dims));
                self.rt.execute_into(&entry, &inputs, exec)?;
            }
            p.sal.copy_from_slice(p.exec.out_f32(0));
            p.us += tc.elapsed().as_micros() as u64;
        }
        let mut s = Session::new(id, req, layout,
                                 self.cfg.quant.recompress_every, seed, slot);
        s.prefill = Some(p);
        // CoW fork point: the session holds pins on the shared segments
        // for its lifetime, while all of its own writes (suffix chunks,
        // decode rows, every recompression) go to session-private state.
        if let Some(h) = hit {
            s.covered = h.covered;
            s.shared = h.segs;
        }
        if let Some(q) = &s.quant {
            let mut quant = self.cfg.quant.clone();
            quant.bits_high = q.bits_high;
            quant.bits_low = q.bits_low;
            quant.saliency_ratio = q.saliency_ratio;
            s.policy_override =
                Some(PolicyOverride(build_policy(self.cfg.policy, &quant)));
        }
        self.metrics.admitted_by_priority[s.priority.rank()] += 1;
        Ok(s)
    }

    /// Run the next prefill chunk of a Prefilling session (DESIGN.md
    /// §12): KV rows for `[start, end)` scatter into the pinned dense
    /// slot, the saliency accumulator advances through the runtime's
    /// running-accumulator chunk entry (preserving the monolithic f32
    /// addition order), and the *final* chunk finalizes saliency, runs
    /// the one prefill compression pass, and moves the session to the
    /// decode phase — bit-identically to the monolithic epilogue.
    /// Returns `true` when the session left the Prefilling phase.
    // lint: cold-path — prefill phase, outside the §9 steady-decode
    // contract (DESIGN.md §13).
    pub fn prefill_chunk(&mut self, s: &mut Session) -> Result<bool> {
        let mut p = s.prefill.take().ok_or_else(|| {
            anyhow::anyhow!("prefill_chunk on session {} not in the \
                             Prefilling phase", s.id)
        })?;
        let (smax, n_layers) = {
            let info = self.rt.model_info();
            (info.max_seq, info.n_layers)
        };
        let layout = self.layout();
        let n = s.prompt.len();
        let t0 = Instant::now();

        let start = p.done;
        let end = (start + p.chunk).min(n);
        debug_assert!(start < n, "prefill_chunk past the prompt");
        // Switch this chunk's rows live *before* the call: an attention
        // row for query q < end reads valid columns <= q only, so the
        // prefix mask yields rows bit-identical to the monolithic pass.
        for v in p.valid[start..end].iter_mut() {
            *v = 1.0;
        }

        let entry = self.rt.entry(if p.full_scores {
            "prefill_chunk_full"
        } else {
            "prefill_chunk_flash"
        });
        let start_in = [start as i32];
        let end_in = [end as i32];
        let win_dims = [smax];
        let sal_dims = [n_layers, smax];
        let probe_dims = [p.probes.len()];
        {
            let PrefillProgress { tokens, valid, probes, sal, exec,
                                  full_scores, .. } = &mut *p;
            let mut inputs = vec![
                TensorView::i32(tokens, &win_dims),
                TensorView::f32(valid, &win_dims),
                TensorView::scalar_i32(&start_in),
                TensorView::scalar_i32(&end_in),
            ];
            if !*full_scores {
                inputs.push(TensorView::i32(probes, &probe_dims));
            }
            inputs.push(TensorView::f32(sal, &sal_dims));
            self.rt.execute_into(&entry, &inputs, exec)?;
        }

        // outputs: k/v chunk rows [L, H, end-start, dh] + updated
        // accumulator.  Scatter the rows into the pinned slot (per-plane
        // contiguous) and advance the accumulator.
        let clen = end - start;
        {
            let slot = s.slot_mut();
            let (dh, heads, layers) = (layout.d_head, layout.heads, layout.layers);
            let kc = p.exec.out_f32(0);
            let vc = p.exec.out_f32(1);
            for hi in 0..layers * heads {
                let src = hi * clen * dh;
                let dst = hi * smax * dh + start * dh;
                slot.kbuf[dst..dst + clen * dh]
                    .copy_from_slice(&kc[src..src + clen * dh]);
                slot.vbuf[dst..dst + clen * dh]
                    .copy_from_slice(&vc[src..src + clen * dh]);
            }
        }
        p.sal.copy_from_slice(p.exec.out_f32(2));
        p.done = end;

        let finished = end >= n;
        if !finished {
            let us = t0.elapsed().as_micros() as u64;
            p.us += us;
            self.metrics.prefill_chunk.record_us(us);
            self.metrics.prefill_chunks += 1;
            s.prefill = Some(p);
            return Ok(false);
        }

        // Final chunk: normalize the completed accumulator through the
        // finalize entry (the exact division loop the monolithic entries
        // run), then the single prefill compression pass over the exact
        // dense rows — per-chunk compression would quantize early chunks
        // against partial-prefix saliency and re-quantize already
        // dequantized rows, breaking the §9 parity argument
        // (DESIGN.md §12).
        let fin = self.rt.entry(if p.full_scores {
            "prefill_fin_full"
        } else {
            "prefill_fin_flash"
        });
        let n_in = [n as i32];
        {
            let PrefillProgress { probes, sal, exec, full_scores, .. } = &mut *p;
            let inputs = if *full_scores {
                vec![TensorView::f32(sal, &sal_dims),
                     TensorView::scalar_i32(&n_in)]
            } else {
                vec![TensorView::f32(sal, &sal_dims),
                     TensorView::i32(probes, &probe_dims)]
            };
            self.rt.execute_into(&fin, &inputs, exec)?;
        }
        let mut nrm = Vec::new();
        layer_mean_into(p.exec.out_f32(0), n_layers, smax, &mut nrm);
        s.norm_saliency = nrm;
        s.acc_saliency = if p.full_scores {
            let mut acc = Vec::new();
            layer_mean_into(&p.sal, n_layers, smax, &mut acc);
            acc
        } else {
            Vec::new()
        };

        // Identical epilogue to the monolithic path: compress rows
        // [0, n-1) (the prompt tail is withheld so the first generated
        // token reads quantized state), zero the dead tail, and re-feed
        // the final prompt token through the decode artifact.
        self.intern_prefix(s, layout);
        self.rt.fault_point(FaultSite::Compress)?;
        self.compress_session(s, n - 1);
        let (dh, heads) = (layout.d_head, layout.heads);
        let tail = (smax - (n - 1)) * dh;
        {
            let slot = s.slot_mut();
            for hi in 0..layout.layers * heads {
                let o = hi * smax * dh + (n - 1) * dh;
                slot.kbuf[o..o + tail].fill(0.0);
                slot.vbuf[o..o + tail].fill(0.0);
            }
        }
        s.pos = n - 1;
        s.next_token = s.prompt[n - 1];
        s.prompt_tail_pending = true;
        let us = t0.elapsed().as_micros() as u64;
        self.metrics.prefill_chunk.record_us(us);
        self.metrics.prefill_chunks += 1;
        // Session-level total = sum of *active* chunk spans, excluding
        // inter-chunk scheduling gaps — comparable to the monolithic
        // histogram entry (both are pure prefill work).
        s.prefill_us = p.us + us;
        self.metrics.prefill.record_us(s.prefill_us);
        Ok(true)
    }

    /// The historical monolithic prefill: one runtime call covers the
    /// whole prompt.  This is the `prefill_chunk = 0` path and the only
    /// path on backends without the chunked entries; it must stay
    /// bit-for-bit identical to the pre-chunking behavior.
    fn start_session_monolithic(&mut self, req: GenerationRequest)
                                -> Result<Session> {
        let info = self.rt.model_info().clone();
        let layout = info.cache_layout();
        req.validate(info.max_seq)?;
        let (prompt, max_new) = (&req.prompt, req.max_new);

        let id = self.next_session_id;
        self.next_session_id += 1;
        // Seed from the request *content*, never from admission order: two
        // servers admitting the same request in different orders (or
        // across different shard counts — DESIGN.md §8) must probe the
        // same positions and generate the same tokens.  A per-request
        // seed override swaps the *base*; the content mix stays.
        let seed = request_seed(req.seed.unwrap_or(self.cfg.seed), prompt, max_new);

        let t0 = Instant::now();
        let n = prompt.len();
        let smax = info.max_seq;
        let mut tokens = vec![0i32; smax];
        for (i, &t) in prompt.iter().enumerate() {
            tokens[i] = t as i32;
        }
        let mut valid = vec![0f32; smax];
        for v in valid.iter_mut().take(n) {
            *v = 1.0;
        }

        let (kc, vc, norm_sal, acc_sal) = if self.policy.requires_full_scores() {
            // Baseline path: standard attention, full scores materialized.
            let out = self.rt.execute(
                &self.rt.entry("prefill_full"),
                &[Tensor::i32(tokens, &[smax]), Tensor::f32(valid.clone(), &[smax])],
            )?;
            // outputs: logits, kcache, vcache, acc_saliency, norm_saliency
            // (the logits are unused — the first token is produced through
            // the compressed cache, see below)
            let mut it = out.into_iter();
            let _logits = it.next().unwrap();
            let kc = it.next().unwrap().into_f32();
            let vc = it.next().unwrap().into_f32();
            let acc = layer_mean(it.next().unwrap().into_f32(), info.n_layers, smax);
            let nrm = layer_mean(it.next().unwrap().into_f32(), info.n_layers, smax);
            (kc, vc, nrm, acc)
        } else {
            // ZipCache fast path: FlashAttention + probe saliency (Alg. 2).
            let probes = select_probes(ProbeStrategy::RandomRecent, n,
                                       self.cfg.quant.probe_ratio, None, seed);
            // pad/trim to the artifact's static probe count
            let pc = info.probe_count;
            let mut pidx: Vec<i32> = probes.iter().map(|&i| i as i32).collect();
            while pidx.len() < pc {
                pidx.push((n - 1) as i32); // repeat last token (harmless dup)
            }
            pidx.truncate(pc);
            pidx.sort_unstable();
            let out = self.rt.execute(
                &self.rt.entry("prefill_flash"),
                &[Tensor::i32(tokens, &[smax]), Tensor::f32(valid.clone(), &[smax]),
                  Tensor::i32(pidx, &[pc])],
            )?;
            // outputs: logits, kcache, vcache, norm_saliency
            let mut it = out.into_iter();
            let _logits = it.next().unwrap();
            let kc = it.next().unwrap().into_f32();
            let vc = it.next().unwrap().into_f32();
            let nrm = layer_mean(it.next().unwrap().into_f32(), info.n_layers, smax);
            (kc, vc, nrm, Vec::new())
        };

        // All fallible work is behind us: check a materialization slot
        // out of the pool and scatter the prefill cache into it.  (The
        // acquire sits after the execute so an execute error can never
        // strand a checked-out slot.)
        let mut slot = self.slots.acquire().ok_or_else(|| {
            anyhow::anyhow!(
                "no free materialization slot ({} in use; park a session first)",
                self.slots.capacity()
            )
        })?;
        slot.kbuf.copy_from_slice(&kc);
        slot.vbuf.copy_from_slice(&vc);
        let mut s = Session::new(id, req, layout,
                                 self.cfg.quant.recompress_every, seed, slot);
        s.norm_saliency = norm_sal;
        s.acc_saliency = acc_sal;
        // Compile the per-request quant override once (DESIGN.md §11):
        // same policy *kind* as the engine (so the prefill path and
        // saliency inputs match) with the request's knobs swapped in.
        // Every compression cycle borrows this instead of rebuilding it.
        if let Some(q) = &s.quant {
            let mut quant = self.cfg.quant.clone();
            quant.bits_high = q.bits_high;
            quant.bits_low = q.bits_low;
            quant.saliency_ratio = q.saliency_ratio;
            s.policy_override =
                Some(PolicyOverride(build_policy(self.cfg.policy, &quant)));
        }
        self.metrics.admitted_by_priority[s.priority.rank()] += 1;

        // Compress the prompt cache under the policy — withholding the final
        // prompt token, which is then re-fed through the decode artifact so
        // the first generated token genuinely reads the *quantized* cache
        // (the paper's evaluation protocol: answers come from the compressed
        // state, not from uncompressed prefill activations).
        self.intern_prefix(&s, layout);
        self.rt.fault_point(FaultSite::Compress)?;
        self.compress_session(&mut s, n - 1);
        // Rows >= n-1 still hold whatever the prefill artifact emitted
        // there: the withheld prompt-tail row, plus — on a real PJRT
        // backend — anything the lowered graph wrote at padded positions
        // (the sim zero-fills them, real artifacts need not).  The
        // compression above covered only rows [0, n-1), so zero the whole
        // dead tail once here; that establishes the session buffer
        // invariant the scratch materialization relies on — rows >=
        // n_tokens are neutral (DESIGN.md §9) — bit-exactly, not merely
        // up to `valid` masking.  Decode steps rewrite rows as `pos`
        // advances and every later cycle covers them, so one cold-path
        // clear per session suffices.
        let (dh, heads) = (layout.d_head, layout.heads);
        let tail = (smax - (n - 1)) * dh;
        {
            let slot = s.slot_mut();
            for hi in 0..layout.layers * heads {
                let o = hi * smax * dh + (n - 1) * dh;
                slot.kbuf[o..o + tail].fill(0.0);
                slot.vbuf[o..o + tail].fill(0.0);
            }
        }
        s.pos = n - 1;
        s.next_token = s.prompt[n - 1];
        s.prompt_tail_pending = true;
        s.prefill_us = t0.elapsed().as_micros() as u64;
        self.metrics.prefill.record_us(s.prefill_us);
        Ok(s)
    }

    /// One decode step (Alg. 3): attend to the (quantized) cache, append
    /// the new KV row uncompressed, maybe probe, maybe recompress.
    ///
    /// Zero-allocation hot path (DESIGN.md §9): the K/V cache and
    /// validity mask cross the runtime boundary as borrowed
    /// [`TensorView`]s (the old owned-`Tensor` path cloned the whole
    /// `[L,H,S,dh]` cache twice per step), outputs land in the session's
    /// reusable scratch slots, and in the non-recompression case the
    /// steady-state step performs no heap allocation at all (pinned by
    /// `benches/decode_steady.rs`).
    // lint: hot-path — zero-alloc steady decode root (DESIGN.md §13).
    pub fn decode_step(&mut self, s: &mut Session) -> Result<Option<u16>> {
        if s.is_done() {
            return Ok(None);
        }
        anyhow::ensure!(!s.is_parked(),
                        "decode_step on a parked session (unpark first)");
        anyhow::ensure!(!s.is_prefilling(),
                        "decode_step on a prefilling session (run \
                         prefill_chunk to completion first)");
        // Copy the scalar hyper-parameters out instead of cloning
        // ModelInfo (its `trained` field owns a heap string).
        let (layout, smax, n_layers) = {
            let info = self.rt.model_info();
            (info.cache_layout(), info.max_seq, info.n_layers)
        };
        let t0 = Instant::now();

        let tok = s.next_token;
        let emitting = !s.prompt_tail_pending;
        if emitting {
            s.generated.push(tok);
            self.metrics.tokens_generated += 1;

            // Budget/window/EOS-or-stop-token termination BEFORE running
            // the step for the next token (the emitted token is already
            // decided).
            let stopped = GenerationRequest::is_stop(&s.stop_tokens, tok);
            if stopped || s.generated.len() >= s.max_new
                || s.remaining_window(smax) == 0
            {
                s.finish = if stopped {
                    FinishReason::Eos
                } else {
                    FinishReason::MaxTokens
                };
                s.done = true;
                s.decode_us += t0.elapsed().as_micros() as u64;
                return Ok(Some(tok));
            }
        }

        let tok_in = [tok as i32];
        let pos_in = [s.pos as i32];
        let cache_dims = [layout.layers, layout.heads, smax, layout.d_head];
        let valid_dims = [smax];
        {
            // Field-level split borrow: the decode artifact reads the
            // checked-out slot's buffers while its outputs land in the
            // sibling scratch slots.
            let Session { residency, scratch, pos, .. } = &mut *s;
            let Residency::Dense(slot) = residency else {
                // The ensure! at entry rejected parked sessions before
                // any state mutation; nothing in between re-parks.
                unreachable!("dense checked at entry");
            };
            self.rt.execute_into(
                &self.decode_entry,
                &[
                    TensorView::scalar_i32(&tok_in),
                    TensorView::scalar_i32(&pos_in),
                    TensorView::f32(&slot.kbuf, &cache_dims),
                    TensorView::f32(&slot.vbuf, &cache_dims),
                    TensorView::f32(&slot.valid, &valid_dims),
                ],
                &mut scratch.exec,
            )?;

            // outputs: logits, k_new, v_new, a_row — in session-owned
            // slots.  Write the new row (uncompressed until the next
            // recompression).
            let (dh, heads, layers) = (layout.d_head, layout.heads, layout.layers);
            let k_new = scratch.exec.out_f32(1);
            let v_new = scratch.exec.out_f32(2);
            for l in 0..layers {
                for h in 0..heads {
                    let src = (l * heads + h) * dh;
                    let dst = (l * heads + h) * smax * dh + *pos * dh;
                    slot.kbuf[dst..dst + dh].copy_from_slice(&k_new[src..src + dh]);
                    slot.vbuf[dst..dst + dh].copy_from_slice(&v_new[src..src + dh]);
                }
            }
            slot.valid[*pos] = 1.0;
        }
        s.pos += 1;

        // Layer-mean of the attention row, into the session scratch.
        layer_mean_into(s.scratch.exec.out_f32(3), n_layers, smax,
                        &mut s.scratch.a_mean);

        // Streaming probes (Alg. 3): ZipCache probes selectively; the
        // accumulated-score baselines effectively track every row (they run
        // standard attention anyway).
        if self.policy.requires_full_scores() {
            if s.acc_saliency.len() < smax {
                s.acc_saliency.resize(smax, 0.0);
            }
            for (acc, &a) in s.acc_saliency.iter_mut().zip(&s.scratch.a_mean) {
                *acc += a;
            }
        } else if s.stream.should_probe() {
            s.stream.record(&s.scratch.a_mean[..smax], s.pos - 1);
        }

        // Recompression cycle.  Timed with its own Instant: the compress
        // histogram must cover only the recompression block (saliency
        // merge + Split->Quant->Concat), not the decode artifact execution
        // and row writes above — and the decode histogram must exclude the
        // recompression span, or both would double-count the same wall
        // time (the bug fixed in PR 2).
        let mut compress_us = 0u64;
        if s.stream.step() {
            let tc = Instant::now();
            let n_live = s.pos;
            if let Some(stream_sal) = s.stream.take_saliency(smax) {
                merge_streaming_saliency(&mut s.norm_saliency, &stream_sal);
            }
            self.rt.fault_point(FaultSite::Compress)?;
            self.compress_session(s, n_live);
            compress_us = tc.elapsed().as_micros() as u64;
            self.metrics.compress.record_us(compress_us);
        }

        s.next_token = argmax(s.scratch.exec.out_f32(0)) as u16;
        s.prompt_tail_pending = false;
        let step_us = t0.elapsed().as_micros() as u64;
        s.decode_us += step_us; // session wall time keeps the full step
        self.metrics.decode.record_us(step_us.saturating_sub(compress_us));
        Ok(if emitting { Some(tok) } else { None })
    }

    /// Compress rows `[0, n_live)` of the session cache under the policy
    /// and re-materialize the fp32 buffers the decode artifact reads.
    /// Gather/staging buffers come from the engine's [`CompressScratch`],
    /// reused across cycles and sessions (DESIGN.md §9).  The compressed
    /// store is *retained* on the session as its resident cache form
    /// (DESIGN.md §10) — parking drops the dense slot and keeps it.
    // lint: cold-path — the recompression branch is outside the §9
    // zero-alloc contract (the dynamic bench asserts non-recompression
    // steps only); scratch reuse here is best-effort (DESIGN.md §13).
    fn compress_session(&mut self, s: &mut Session, n_live: usize) {
        let layout = self.layout();
        let input = PolicyInput {
            n_tokens: n_live,
            acc_saliency: if s.acc_saliency.is_empty() { None } else { Some(&s.acc_saliency) },
            norm_saliency: if s.norm_saliency.is_empty() { None } else { Some(&s.norm_saliency) },
        };
        // Per-request quantization override (DESIGN.md §11): the
        // session carries its policy pre-built by start_session, so a
        // cycle borrows it — no per-cycle construction.
        let policy: &dyn CompressionPolicy = match &s.policy_override {
            Some(p) => &*p.0,
            None => &*self.policy,
        };
        let classes = policy.assign(&input);
        let Residency::Dense(slot) = &mut s.residency else {
            panic!("compress_session on a parked session");
        };
        // Fan the independent (layer, head) planes out across the pool;
        // bit-identical to the sequential path at any width (DESIGN.md §5).
        let (store, stages) = CompressedKV::compress_instrumented_scratch(
            &slot.kbuf, &slot.vbuf, layout, &classes, policy.quant_spec(),
            &self.pool, &mut self.scratch);
        self.metrics.record_compress_stages(&stages);
        // Zero-only-dead-rows materialization: rows beyond the live
        // prefix are untouched, which is sound because a session row is
        // only ever written at position `pos` and every later cycle
        // covers it (DESIGN.md §9).
        store.materialize_into_scratch(&mut slot.kbuf, &mut slot.vbuf,
                                       &mut slot.valid, &mut self.scratch);
        s.cache_bytes = store.resident_bytes();
        s.compression_ratio = store.compression_ratio();
        s.classes = classes;
        s.compressed = Some(store);
        self.metrics.record_cache(s.cache_bytes,
                                  layout.fp16_baseline_bytes(n_live));
    }

    /// Park `s` out of its materialization slot (DESIGN.md §10): the
    /// retained compressed snapshot becomes the resident form, the fp32
    /// rows appended since that snapshot (the streaming tail, at most
    /// `recompress_every` of them) are saved exactly, and the dense slot
    /// returns to the pool.  Bit-exact: [`Engine::unpark`] reconstructs
    /// the dense buffers as they were, so parking at any point never
    /// perturbs the tokens a session goes on to generate.  No-op when
    /// already parked.
    pub fn park(&mut self, s: &mut Session) {
        if s.is_parked() {
            return;
        }
        // A Prefilling session pins its slot: its compressed snapshot
        // does not exist yet and its dense rows are the only copy of the
        // chunks already run, so parking it would have to discard work.
        // Schedulers exclude Prefilling sessions from victim selection
        // (DESIGN.md §12).
        assert!(!s.is_prefilling(), "cannot park a prefilling session");
        // The snapshot always exists after start_session; a session that
        // somehow never compressed falls back to a fresh compression
        // through the existing scratch path.
        if s.compressed.is_none() {
            self.compress_session(s, s.pos);
        }
        let tail_from = s.compressed.as_ref().unwrap().n_tokens;
        let lay = s.layout;
        let rows = s.pos - tail_from;
        // Tail buffers recycle through the session scratch (warm after
        // the first park; no per-cycle allocation under a bounded pool,
        // where a park can happen every scheduler iteration).
        let (mut tail_k, mut tail_v) = std::mem::take(&mut s.scratch.tail_spare);
        tail_k.clear();
        tail_v.clear();
        if rows > 0 {
            let (smax, dh) = (lay.seq, lay.d_head);
            let slot = s.slot();
            tail_k.reserve(lay.layers * lay.heads * rows * dh);
            tail_v.reserve(lay.layers * lay.heads * rows * dh);
            for hi in 0..lay.layers * lay.heads {
                let o = hi * smax * dh + tail_from * dh;
                tail_k.extend_from_slice(&slot.kbuf[o..o + rows * dh]);
                tail_v.extend_from_slice(&slot.vbuf[o..o + rows * dh]);
            }
        }
        let Residency::Dense(slot) = std::mem::replace(
            &mut s.residency,
            Residency::Parked { tail_k, tail_v, tail_from },
        ) else {
            unreachable!("checked above");
        };
        self.slots.release(slot);
        // The decode scratch (exec slots + a_mean, O(vocab + planes))
        // stays on the session: re-warming it every park/unpark cycle
        // would put allocations back on the bounded-residency decode
        // path that PR 3 made allocation-free.
        self.metrics.park_cycles += 1;
    }

    /// Schedule `s` back in: check a slot out of the pool, materialize
    /// the retained compressed snapshot into it
    /// ([`CompressedKV::materialize_into_scratch`] — the slot comes back
    /// zeroed, so the neutral-rows precondition holds), and restore the
    /// saved fp32 tail bit-exactly.  Fails when the pool is exhausted
    /// (park another session first).  No-op when already dense.
    pub fn unpark(&mut self, s: &mut Session) -> Result<()> {
        if !s.is_parked() {
            return Ok(());
        }
        let mut slot = self.slots.acquire().ok_or_else(|| {
            anyhow::anyhow!(
                "no free materialization slot to unpark session {} \
                 ({} in use; park another session first)",
                s.id,
                self.slots.capacity()
            )
        })?;
        let store = s
            .compressed
            .as_ref()
            .expect("parked session without a compressed snapshot");
        store.materialize_into_scratch(&mut slot.kbuf, &mut slot.vbuf,
                                       &mut slot.valid, &mut self.scratch);
        let Residency::Parked { tail_k, tail_v, tail_from } = &s.residency else {
            unreachable!("checked above");
        };
        let lay = s.layout;
        let (smax, dh) = (lay.seq, lay.d_head);
        let rows = s.pos - tail_from;
        if rows > 0 {
            for hi in 0..lay.layers * lay.heads {
                let src = hi * rows * dh;
                let o = hi * smax * dh + tail_from * dh;
                slot.kbuf[o..o + rows * dh]
                    .copy_from_slice(&tail_k[src..src + rows * dh]);
                slot.vbuf[o..o + rows * dh]
                    .copy_from_slice(&tail_v[src..src + rows * dh]);
            }
            for t in *tail_from..s.pos {
                slot.valid[t] = 1.0;
            }
        }
        // Recycle the tail buffers' capacity for the next park.
        match std::mem::replace(&mut s.residency, Residency::Dense(slot)) {
            Residency::Parked { mut tail_k, mut tail_v, .. } => {
                tail_k.clear();
                tail_v.clear();
                s.scratch.tail_spare = (tail_k, tail_v);
            }
            Residency::Dense(_) => unreachable!("checked at entry"),
        }
        Ok(())
    }
}

/// Per-request seed: FNV-1a over the prompt tokens and budget, mixed with
/// the engine's base seed.  A pure function of the request content, so the
/// probe selection and streaming-probe draws it feeds are independent of
/// admission order, batcher interleaving, and shard placement
/// (DESIGN.md §8's determinism contract).
pub fn request_seed(base: u64, prompt: &[u16], max_new: usize) -> u64 {
    const FNV_OFFSET: u64 = 0xCBF2_9CE4_8422_2325;
    const FNV_PRIME: u64 = 0x0000_0100_0000_01B3;
    let mut h = FNV_OFFSET;
    for &t in prompt {
        h = (h ^ t as u64).wrapping_mul(FNV_PRIME);
    }
    h = (h ^ max_new as u64).wrapping_mul(FNV_PRIME);
    // SplitMix64 finalize so low-entropy prompts still disperse.
    crate::workload::rng::splitmix_mix(h ^ base)
}

/// The streaming-saliency merge rule (Alg. 3): positions the probe cycle
/// observed (estimate > 0) take the fresh streaming estimate; everything
/// else keeps its prior (prefill or earlier-cycle) value.
pub fn merge_streaming_saliency(norm: &mut Vec<f32>, stream_sal: &[f32]) {
    if norm.len() < stream_sal.len() {
        norm.resize(stream_sal.len(), 0.0);
    }
    for (n, &s) in norm.iter_mut().zip(stream_sal) {
        if s > 0.0 {
            *n = s;
        }
    }
}

/// Build the configured policy.
fn make_policy(cfg: &EngineConfig) -> Box<dyn CompressionPolicy> {
    build_policy(cfg.policy, &cfg.quant)
}

/// Build a policy of `kind` over an explicit quant-knob set (the
/// per-request override path swaps the knobs, never the kind).
fn build_policy(kind: PolicyKind, q: &QuantConfig) -> Box<dyn CompressionPolicy> {
    match kind {
        PolicyKind::Fp16 => Box::new(Fp16Policy),
        PolicyKind::H2o => Box::new(H2oPolicy::default()),
        PolicyKind::Gear => Box::new(GearPolicy { bits: q.bits_high }),
        PolicyKind::Kivi => Box::new(KiviPolicy::default()),
        PolicyKind::Mikv => Box::new(MikvPolicy {
            saliency_ratio: q.saliency_ratio, hi: q.bits_high, lo: q.bits_low }),
        PolicyKind::Zipcache => Box::new(ZipCachePolicy {
            saliency_ratio: q.saliency_ratio, hi: q.bits_high, lo: q.bits_low }),
    }
}

/// Mean over layers of a `[L, S]` row-major buffer, into `out` -> `[S]`.
/// The decode hot path reuses the session's `a_mean` buffer; no
/// steady-state allocation.
fn layer_mean_into(x: &[f32], layers: usize, s: usize, out: &mut Vec<f32>) {
    debug_assert_eq!(x.len(), layers * s);
    out.clear();
    out.resize(s, 0.0);
    for l in 0..layers {
        for i in 0..s {
            out[i] += x[l * s + i];
        }
    }
    let inv = 1.0 / layers as f32;
    for o in out.iter_mut() {
        *o *= inv;
    }
}

/// Allocating wrapper over [`layer_mean_into`] (prefill path).
fn layer_mean(x: Vec<f32>, layers: usize, s: usize) -> Vec<f32> {
    let mut out = Vec::with_capacity(s);
    layer_mean_into(&x, layers, s, &mut out);
    out
}

/// Index of the maximum logit — NaN-safe and deterministic.
///
/// NaN entries never win (the old `partial_cmp(..).unwrap_or(Equal)`
/// comparator let a NaN logit pick an arbitrary, ordering-dependent
/// winner), exact ties resolve to the lowest index, and an empty or
/// all-NaN slice yields 0.
fn argmax(xs: &[f32]) -> usize {
    let mut best: Option<(usize, f32)> = None;
    for (i, &x) in xs.iter().enumerate() {
        if x.is_nan() {
            continue;
        }
        match best {
            Some((_, bx)) if x <= bx => {}
            _ => best = Some((i, x)),
        }
    }
    best.map_or(0, |(i, _)| i)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_mean_small() {
        let x = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]; // [2, 3]
        assert_eq!(layer_mean(x, 2, 3), vec![2.5, 3.5, 4.5]);
    }

    #[test]
    fn argmax_basics() {
        assert_eq!(argmax(&[0.1, 0.9, 0.3]), 1);
        assert_eq!(argmax(&[]), 0);
    }

    #[test]
    fn argmax_skips_nan() {
        // A NaN logit must never win, wherever it sits.
        assert_eq!(argmax(&[f32::NAN, 0.2, 0.9]), 2);
        assert_eq!(argmax(&[0.9, f32::NAN, 0.2]), 0);
        assert_eq!(argmax(&[0.2, 0.9, f32::NAN]), 1);
        // All-NaN degenerates to 0, like empty.
        assert_eq!(argmax(&[f32::NAN, f32::NAN]), 0);
    }

    #[test]
    fn argmax_exact_ties_take_lowest_index() {
        assert_eq!(argmax(&[0.5, 0.9, 0.9, 0.1]), 1);
        assert_eq!(argmax(&[0.7, 0.7, 0.7]), 0);
        // Ties across a NaN gap still resolve to the first maximum.
        assert_eq!(argmax(&[0.3, f32::NAN, 0.3]), 0);
        // Negative-only inputs (max is the least-negative).
        assert_eq!(argmax(&[-2.0, -1.0, -1.0]), 1);
    }

    #[test]
    fn layer_mean_into_reuses_buffer() {
        let x = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]; // [2, 3]
        let mut out = Vec::new();
        layer_mean_into(&x, 2, 3, &mut out);
        assert_eq!(out, vec![2.5, 3.5, 4.5]);
        let ptr = out.as_ptr();
        layer_mean_into(&x, 2, 3, &mut out);
        assert_eq!(out, vec![2.5, 3.5, 4.5]);
        assert_eq!(out.as_ptr(), ptr); // no reallocation at steady state
    }

    #[test]
    fn request_seed_is_content_derived() {
        let p1 = vec![1u16, 2, 3];
        let p2 = vec![1u16, 2, 4];
        assert_eq!(request_seed(0, &p1, 4), request_seed(0, &p1, 4));
        assert_ne!(request_seed(0, &p1, 4), request_seed(0, &p2, 4));
        assert_ne!(request_seed(0, &p1, 4), request_seed(0, &p1, 5));
        assert_ne!(request_seed(0, &p1, 4), request_seed(7, &p1, 4));
    }

    #[test]
    fn merge_overwrites_only_observed_positions() {
        let mut norm = vec![0.5, 0.6, 0.7, 0.8];
        merge_streaming_saliency(&mut norm, &[0.0, 0.9, 0.0, 0.1]);
        assert_eq!(norm, vec![0.5, 0.9, 0.7, 0.1]);
    }

    #[test]
    fn merge_grows_short_prior() {
        // A session whose prefill saliency was shorter than the window
        // (flash path resizing) must extend before merging.
        let mut norm = vec![0.5];
        merge_streaming_saliency(&mut norm, &[0.0, 0.2, 0.0]);
        assert_eq!(norm, vec![0.5, 0.2, 0.0]);
    }
}
