//! Trace-driven load generation against a running server (DESIGN.md §8,
//! §11).
//!
//! Replays a [`RequestTrace`]'s arrival process (open-loop: submission
//! times follow the trace, not the server's progress) through a
//! [`ServerHandle`], measuring per-request submit-to-completion latency,
//! submit-time rejections (backpressure), and aggregate throughput.
//! Entries carry the typed request options (priority class, deadline,
//! pre-fired cancellation), and the report breaks completions down by
//! [`FinishReason`].  Used by the `serve` subcommand and
//! `benches/serving_throughput.rs`.

use std::time::{Duration, Instant};

use crate::coordinator::request::{FinishReason, GenerationResponse, Priority};
use crate::metrics::LatencyStats;
use crate::workload::rng::SplitMix64;
use crate::workload::tasks::{Sample, BOS, EOS, KEY0, NKEY, NL, NVAL, QUERY, SEP, VAL0};
use crate::workload::{RequestTrace, Task, TraceEntry};
use crate::Result;

use super::ServerHandle;

/// Memory-pressure scenario (DESIGN.md §10): `n` concurrent long-window,
/// short-decode requests — line-retrieval prompts sized to nearly fill
/// the model window, a 2-token decode budget, and every arrival at t=0.
/// Each admitted session pins close to the worst-case byte footprint for
/// almost its whole lifetime, so replaying this trace against a
/// budget-configured server exercises the admission boundary (and, with
/// `memory.slots < max_batch`, the park/unpark path) under real
/// concurrency rather than only in unit tests.
///
/// Window ceiling: line-retrieval indexes lines with two digits, so
/// prompts cap at 100 lines (605 tokens).  Every current model config
/// (micro/tiny/base, windows 64–512) sits below that; for a future
/// window beyond ~612 tokens the prompts stop tracking the window and
/// callers sizing budgets from `worst_case_resident_bytes(full window)`
/// would over-admit — size the budget from this trace's actual prompt
/// lengths instead in that regime.
pub fn memory_pressure_trace(max_seq: usize, n: usize, seed: u64) -> RequestTrace {
    let max_new = 2;
    // Line-retrieval prompts are `6 * lines + 5` tokens; size `lines` so
    // prompt + decode budget just fits the window.
    let lines = (max_seq.saturating_sub(max_new + 5) / 6).clamp(1, 100);
    RequestTrace::batch(Task::Lines(lines), max_seq - max_new, n, max_new, seed)
}

/// Mixed-priority scenario (DESIGN.md §11): `n` concurrent code-task
/// requests whose priority classes cycle
/// `Interactive -> Batch -> Background` in trace order, plus two special
/// entries exercising the non-natural finish paths deterministically:
/// the last entry is submitted pre-cancelled (retires with
/// `FinishReason::Cancelled` at pop, holding no slot) and the
/// second-to-last carries an already-expired deadline (deterministically
/// shed with `FinishReason::DeadlineExpired`).  Replaying it against any
/// server therefore produces at least one cancelled and one shed request
/// and per-priority traffic for all three classes (with `n >= 5`).
pub fn priority_mix_trace(max_seq: usize, n: usize, max_new: usize,
                          seed: u64) -> RequestTrace {
    let max_new = max_new.clamp(1, max_seq.saturating_sub(1).max(1));
    let mut trace =
        RequestTrace::batch(Task::Code, max_seq - max_new, n, max_new, seed);
    for (i, e) in trace.entries.iter_mut().enumerate() {
        e.priority = Priority::ALL[i % Priority::ALL.len()];
    }
    let n = trace.entries.len();
    if n >= 1 {
        trace.entries[n - 1].cancelled = true;
    }
    if n >= 2 {
        trace.entries[n - 2].deadline_ms = Some(0.0);
    }
    trace
}

/// Long-prompt-burst scenario (DESIGN.md §12): one `Background`
/// near-window long-prompt request — the sim-window analogue of an
/// 8k-token production prefill, scaled with the same line-retrieval
/// sizing (and 100-line ceiling) as [`memory_pressure_trace`] — plus
/// `n - 1` `Interactive` short-prompt requests, all arriving at t=0 with
/// the long request first in trace order.  Replayed against a server
/// with `scheduler.prefill_chunk > 0`, the background prefill must be
/// chunked and interleaved so interactive decode keeps streaming; with
/// monolithic prefill (or a greedy chunk schedule) the long pass blocks
/// the whole step and interactive token gaps balloon — the property the
/// fairness tests in `tests/serving_pool.rs` pin down.
pub fn long_prompt_burst_trace(max_seq: usize, n: usize, max_new: usize,
                               seed: u64) -> RequestTrace {
    let max_new = max_new.clamp(1, max_seq.saturating_sub(1).max(1));
    let long_lines = (max_seq.saturating_sub(max_new + 5) / 6).clamp(1, 100);
    let mut trace = RequestTrace::batch(Task::Lines(long_lines), max_seq - max_new,
                                        1, max_new, seed);
    trace.entries[0].priority = Priority::Background;
    let short = RequestTrace::batch(Task::Lines(3), max_seq - max_new,
                                    n.saturating_sub(1), max_new, seed ^ 0xB00);
    for mut e in short.entries {
        e.priority = Priority::Interactive;
        trace.entries.push(e);
    }
    trace
}

/// Chaos scenario (DESIGN.md §14, EXPERIMENTS.md §Chaos): `n` concurrent
/// code-task requests with enough decode budget that sessions are still
/// streaming when an armed fault plan fires mid-run.  Designed to pair
/// with `faults.plan` (CLI `--fault-plan`): all arrivals at t=0, so on a
/// multi-shard server the victim shard holds both live sessions (which
/// finish `ShardFailed` with their streamed prefix) and staged requests
/// (which the supervisor redelivers bit-identically).  Fault-free, it is
/// just a plain concurrent batch — replaying it twice, with and without
/// a plan, is how the chaos suite pins output parity.
pub fn chaos_trace(max_seq: usize, n: usize, seed: u64) -> RequestTrace {
    // A generous decode budget keeps sessions alive across many steps,
    // widening the window in which an injected fault lands mid-stream.
    let max_new = (max_seq / 2).clamp(1, 24);
    RequestTrace::batch(Task::Code, max_seq - max_new, n, max_new, seed)
}

/// Shared-prefix scenario (DESIGN.md §16, EXPERIMENTS.md §Prefix):
/// `1 + rolls` phases of `n` requests each.  Within a phase every
/// request shares one long "system prompt" — `BOS` plus a block of
/// key/value lines sized to most of the window — and appends a short
/// distinct tail (`QUERY key SEP`, querying a different pair per
/// request), so a prefix-enabled server interns the shared span on the
/// first request and skips its prefill on the rest.  Each roll rotates
/// the key/value block (fresh phase seed), modelling a system-prompt
/// update: the old segments go refcount-idle and, under a
/// `prefix.max_bytes` cap, churn out via LRU eviction.
///
/// Entries carry [`TraceEntry::expect_prefix_hit`]: the first request
/// of every phase expects a miss, the rest expect hits.  Arrivals are
/// spaced `25ms` apart so each prefill (sim-backend microseconds)
/// completes — and interns — before the next lookup; the expectations
/// describe this in-order replay.  Replayed with the store disabled the
/// trace is just a staggered batch (every expectation then counts as a
/// declared miss against `prefix_misses == 0`, which callers should
/// only assert when the store is on).
pub fn shared_prefix_trace(max_seq: usize, n: usize, rolls: usize,
                           seed: u64) -> RequestTrace {
    let max_new = 2;
    // Prompt layout: BOS + n_pairs*(KEY SEP VAL NL) + QUERY key SEP,
    // answer [VAL, EOS]; size the shared block to fill the window.
    let n_pairs = (max_seq.saturating_sub(1 + 3 + max_new) / 4).clamp(2, NKEY as usize);
    let mut entries = Vec::with_capacity((1 + rolls) * n);
    for phase in 0..=rolls {
        let mut rng = SplitMix64::new(seed ^ (phase as u64).wrapping_mul(0x9E37_79B9));
        let mut keys: Vec<u16> = (0..NKEY).collect();
        rng.shuffle(&mut keys);
        keys.truncate(n_pairs);
        let vals: Vec<u16> =
            (0..n_pairs).map(|_| rng.below(NVAL as u64) as u16).collect();
        let mut body: Vec<u16> = vec![BOS];
        for (&k, &v) in keys.iter().zip(&vals) {
            body.extend_from_slice(&[KEY0 + k, SEP, VAL0 + v, NL]);
        }
        for i in 0..n {
            let qi = i % n_pairs;
            let mut tokens = body.clone();
            tokens.extend_from_slice(&[QUERY, KEY0 + keys[qi], SEP]);
            let prompt_len = tokens.len();
            let answer = vec![VAL0 + vals[qi], EOS];
            tokens.extend_from_slice(&answer);
            let span = 1 + 4 * qi;
            entries.push(TraceEntry {
                arrival_ms: (phase * n + i) as f64 * 25.0,
                sample: Sample {
                    tokens,
                    prompt_len,
                    answer,
                    salient_span: (span, span + 4),
                },
                max_new_tokens: max_new,
                priority: Priority::default(),
                deadline_ms: None,
                cancelled: false,
                expect_prefix_hit: Some(i > 0),
            });
        }
    }
    RequestTrace { entries }
}

/// Outcome of one trace replay.
#[derive(Debug, Default)]
pub struct LoadReport {
    /// Requests offered to the server (the whole trace).
    pub submitted: usize,
    /// Requests that completed naturally (`Eos` / `MaxTokens`).
    pub completed: usize,
    /// Requests rejected at submit time (queue full / invalid).
    pub rejected: usize,
    /// Requests accepted but failed in flight (server error).
    pub failed: usize,
    /// Requests finishing with `FinishReason::Cancelled`.
    pub cancelled: usize,
    /// Requests shed with `FinishReason::DeadlineExpired`.
    pub shed: usize,
    /// Requests finishing with `FinishReason::ShardFailed`: their shard
    /// died mid-session, so they keep the tokens streamed before the
    /// failure (a prefix of the fault-free output) but never resume
    /// (DESIGN.md §14).  Requests a failed shard was still *waiting* on
    /// are redelivered instead and land in `completed`.
    pub shard_failed: usize,
    /// Entries declaring `expect_prefix_hit == Some(true)` — the trace's
    /// prediction of the server's `prefix_hits` metric under in-order
    /// replay (DESIGN.md §16).  The replay itself cannot observe
    /// per-request cache outcomes; callers compare these against the
    /// post-replay [`MetricsSnapshot`](crate::metrics::MetricsSnapshot).
    pub expected_prefix_hits: usize,
    /// Entries declaring `expect_prefix_hit == Some(false)` (cold
    /// prefixes: first sight of each phase's system prompt).
    pub expected_prefix_misses: usize,
    /// Wall-clock of the whole replay (first submit to last completion).
    pub wall: Duration,
    /// Submit-to-completion latency of naturally completed requests.
    pub latency: LatencyStats,
    /// `(trace index, response)` for every request the server resolved
    /// (any finish reason), in trace order — callers score accuracy by
    /// zipping the natural completions with the trace entries.
    pub outputs: Vec<(usize, GenerationResponse)>,
}

impl LoadReport {
    pub fn requests_per_second(&self) -> f64 {
        let s = self.wall.as_secs_f64();
        if s == 0.0 {
            return 0.0;
        }
        self.completed as f64 / s
    }

    pub fn tokens(&self) -> usize {
        self.outputs.iter().map(|(_, o)| o.tokens.len()).sum()
    }

    pub fn tokens_per_second(&self) -> f64 {
        let s = self.wall.as_secs_f64();
        if s == 0.0 {
            return 0.0;
        }
        self.tokens() as f64 / s
    }
}

/// Replay `trace` against `handle`: submit each entry at its arrival
/// offset (with its priority/deadline/cancellation options), wait for
/// every accepted request, and aggregate the report.
///
/// Completion waits run on one short-lived thread per accepted request —
/// requests complete out of order across shards, and latency must be
/// measured at completion, not at a later poll.
pub fn replay(handle: &ServerHandle, trace: &RequestTrace) -> Result<LoadReport> {
    let t0 = Instant::now();
    let mut report = LoadReport { submitted: trace.len(), ..LoadReport::default() };
    for e in &trace.entries {
        match e.expect_prefix_hit {
            Some(true) => report.expected_prefix_hits += 1,
            Some(false) => report.expected_prefix_misses += 1,
            None => {}
        }
    }
    let mut waiters = Vec::new();
    for (i, e) in trace.entries.iter().enumerate() {
        let target = Duration::from_micros((e.arrival_ms * 1000.0) as u64);
        let now = t0.elapsed();
        if target > now {
            std::thread::sleep(target - now);
        }
        let t_sub = Instant::now();
        match handle.submit_request(e.request()) {
            Ok(h) => waiters.push(std::thread::spawn(move || {
                let out = h.wait();
                (i, t_sub.elapsed(), out)
            })),
            Err(_) => report.rejected += 1,
        }
    }
    for w in waiters {
        let (i, dur, out) = w
            .join()
            .map_err(|_| anyhow::anyhow!("loadgen waiter panicked"))?;
        match out {
            Ok(response) => {
                match response.finish {
                    f if f.is_natural() => {
                        report.completed += 1;
                        report.latency.record(dur);
                    }
                    FinishReason::Cancelled => report.cancelled += 1,
                    FinishReason::DeadlineExpired => report.shed += 1,
                    FinishReason::ShardFailed => report.shard_failed += 1,
                    f => unreachable!("is_natural covers {f}"),
                }
                report.outputs.push((i, response));
            }
            Err(_) => report.failed += 1,
        }
    }
    report.outputs.sort_by_key(|(i, _)| *i);
    report.wall = t0.elapsed();
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shared_prefix_trace_shape_and_expectations() {
        let t = shared_prefix_trace(64, 4, 2, 7);
        assert_eq!(t.len(), 12, "3 phases x 4 requests");
        for (i, e) in t.entries.iter().enumerate() {
            // First request of each phase is the cold prefix.
            assert_eq!(e.expect_prefix_hit, Some(i % 4 != 0), "entry {i}");
            assert_eq!(e.arrival_ms, i as f64 * 25.0);
            assert!(e.sample.tokens.len() <= 64);
            // Genuine recall task: the answer value sits inside the
            // shared block at the queried pair (accuracy stays scorable).
            let (a, _) = e.sample.salient_span;
            assert_eq!(e.sample.tokens[a + 2], e.sample.answer[0]);
        }
        // Within a phase: one shared body, distinct 3-token tails.
        let shared = t.entries[0].sample.prompt_len - 3;
        let mut tails = Vec::new();
        for e in &t.entries[..4] {
            assert_eq!(e.sample.tokens[..shared],
                       t.entries[0].sample.tokens[..shared]);
            tails.push(e.sample.tokens[shared..e.sample.prompt_len].to_vec());
        }
        tails.sort();
        tails.dedup();
        assert_eq!(tails.len(), 4, "tails must be distinct");
        // A roll rotates the shared body.
        assert_ne!(t.entries[0].sample.tokens[..shared],
                   t.entries[4].sample.tokens[..shared]);
        // And the trace is deterministic.
        let u = shared_prefix_trace(64, 4, 2, 7);
        for (a, b) in t.entries.iter().zip(&u.entries) {
            assert_eq!(a.sample, b.sample);
        }
    }

    #[test]
    fn shared_prefix_trace_declares_one_miss_per_phase() {
        let t = shared_prefix_trace(64, 3, 1, 1);
        let mut hits = 0;
        let mut misses = 0;
        for e in &t.entries {
            match e.expect_prefix_hit {
                Some(true) => hits += 1,
                Some(false) => misses += 1,
                None => {}
            }
        }
        assert_eq!((hits, misses), (4, 2));
    }
}
