//! Trace-driven load generation against a running server (DESIGN.md §8,
//! §11).
//!
//! Replays a [`RequestTrace`]'s arrival process (open-loop: submission
//! times follow the trace, not the server's progress) through a
//! [`ServerHandle`], measuring per-request submit-to-completion latency,
//! submit-time rejections (backpressure), and aggregate throughput.
//! Entries carry the typed request options (priority class, deadline,
//! pre-fired cancellation), and the report breaks completions down by
//! [`FinishReason`].  Used by the `serve` subcommand and
//! `benches/serving_throughput.rs`.

use std::time::{Duration, Instant};

use crate::coordinator::request::{FinishReason, GenerationResponse, Priority};
use crate::metrics::LatencyStats;
use crate::workload::{RequestTrace, Task};
use crate::Result;

use super::ServerHandle;

/// Memory-pressure scenario (DESIGN.md §10): `n` concurrent long-window,
/// short-decode requests — line-retrieval prompts sized to nearly fill
/// the model window, a 2-token decode budget, and every arrival at t=0.
/// Each admitted session pins close to the worst-case byte footprint for
/// almost its whole lifetime, so replaying this trace against a
/// budget-configured server exercises the admission boundary (and, with
/// `memory.slots < max_batch`, the park/unpark path) under real
/// concurrency rather than only in unit tests.
///
/// Window ceiling: line-retrieval indexes lines with two digits, so
/// prompts cap at 100 lines (605 tokens).  Every current model config
/// (micro/tiny/base, windows 64–512) sits below that; for a future
/// window beyond ~612 tokens the prompts stop tracking the window and
/// callers sizing budgets from `worst_case_resident_bytes(full window)`
/// would over-admit — size the budget from this trace's actual prompt
/// lengths instead in that regime.
pub fn memory_pressure_trace(max_seq: usize, n: usize, seed: u64) -> RequestTrace {
    let max_new = 2;
    // Line-retrieval prompts are `6 * lines + 5` tokens; size `lines` so
    // prompt + decode budget just fits the window.
    let lines = (max_seq.saturating_sub(max_new + 5) / 6).clamp(1, 100);
    RequestTrace::batch(Task::Lines(lines), max_seq - max_new, n, max_new, seed)
}

/// Mixed-priority scenario (DESIGN.md §11): `n` concurrent code-task
/// requests whose priority classes cycle
/// `Interactive -> Batch -> Background` in trace order, plus two special
/// entries exercising the non-natural finish paths deterministically:
/// the last entry is submitted pre-cancelled (retires with
/// `FinishReason::Cancelled` at pop, holding no slot) and the
/// second-to-last carries an already-expired deadline (deterministically
/// shed with `FinishReason::DeadlineExpired`).  Replaying it against any
/// server therefore produces at least one cancelled and one shed request
/// and per-priority traffic for all three classes (with `n >= 5`).
pub fn priority_mix_trace(max_seq: usize, n: usize, max_new: usize,
                          seed: u64) -> RequestTrace {
    let max_new = max_new.clamp(1, max_seq.saturating_sub(1).max(1));
    let mut trace =
        RequestTrace::batch(Task::Code, max_seq - max_new, n, max_new, seed);
    for (i, e) in trace.entries.iter_mut().enumerate() {
        e.priority = Priority::ALL[i % Priority::ALL.len()];
    }
    let n = trace.entries.len();
    if n >= 1 {
        trace.entries[n - 1].cancelled = true;
    }
    if n >= 2 {
        trace.entries[n - 2].deadline_ms = Some(0.0);
    }
    trace
}

/// Long-prompt-burst scenario (DESIGN.md §12): one `Background`
/// near-window long-prompt request — the sim-window analogue of an
/// 8k-token production prefill, scaled with the same line-retrieval
/// sizing (and 100-line ceiling) as [`memory_pressure_trace`] — plus
/// `n - 1` `Interactive` short-prompt requests, all arriving at t=0 with
/// the long request first in trace order.  Replayed against a server
/// with `scheduler.prefill_chunk > 0`, the background prefill must be
/// chunked and interleaved so interactive decode keeps streaming; with
/// monolithic prefill (or a greedy chunk schedule) the long pass blocks
/// the whole step and interactive token gaps balloon — the property the
/// fairness tests in `tests/serving_pool.rs` pin down.
pub fn long_prompt_burst_trace(max_seq: usize, n: usize, max_new: usize,
                               seed: u64) -> RequestTrace {
    let max_new = max_new.clamp(1, max_seq.saturating_sub(1).max(1));
    let long_lines = (max_seq.saturating_sub(max_new + 5) / 6).clamp(1, 100);
    let mut trace = RequestTrace::batch(Task::Lines(long_lines), max_seq - max_new,
                                        1, max_new, seed);
    trace.entries[0].priority = Priority::Background;
    let short = RequestTrace::batch(Task::Lines(3), max_seq - max_new,
                                    n.saturating_sub(1), max_new, seed ^ 0xB00);
    for mut e in short.entries {
        e.priority = Priority::Interactive;
        trace.entries.push(e);
    }
    trace
}

/// Chaos scenario (DESIGN.md §14, EXPERIMENTS.md §Chaos): `n` concurrent
/// code-task requests with enough decode budget that sessions are still
/// streaming when an armed fault plan fires mid-run.  Designed to pair
/// with `faults.plan` (CLI `--fault-plan`): all arrivals at t=0, so on a
/// multi-shard server the victim shard holds both live sessions (which
/// finish `ShardFailed` with their streamed prefix) and staged requests
/// (which the supervisor redelivers bit-identically).  Fault-free, it is
/// just a plain concurrent batch — replaying it twice, with and without
/// a plan, is how the chaos suite pins output parity.
pub fn chaos_trace(max_seq: usize, n: usize, seed: u64) -> RequestTrace {
    // A generous decode budget keeps sessions alive across many steps,
    // widening the window in which an injected fault lands mid-stream.
    let max_new = (max_seq / 2).clamp(1, 24);
    RequestTrace::batch(Task::Code, max_seq - max_new, n, max_new, seed)
}

/// Outcome of one trace replay.
#[derive(Debug, Default)]
pub struct LoadReport {
    /// Requests offered to the server (the whole trace).
    pub submitted: usize,
    /// Requests that completed naturally (`Eos` / `MaxTokens`).
    pub completed: usize,
    /// Requests rejected at submit time (queue full / invalid).
    pub rejected: usize,
    /// Requests accepted but failed in flight (server error).
    pub failed: usize,
    /// Requests finishing with `FinishReason::Cancelled`.
    pub cancelled: usize,
    /// Requests shed with `FinishReason::DeadlineExpired`.
    pub shed: usize,
    /// Requests finishing with `FinishReason::ShardFailed`: their shard
    /// died mid-session, so they keep the tokens streamed before the
    /// failure (a prefix of the fault-free output) but never resume
    /// (DESIGN.md §14).  Requests a failed shard was still *waiting* on
    /// are redelivered instead and land in `completed`.
    pub shard_failed: usize,
    /// Wall-clock of the whole replay (first submit to last completion).
    pub wall: Duration,
    /// Submit-to-completion latency of naturally completed requests.
    pub latency: LatencyStats,
    /// `(trace index, response)` for every request the server resolved
    /// (any finish reason), in trace order — callers score accuracy by
    /// zipping the natural completions with the trace entries.
    pub outputs: Vec<(usize, GenerationResponse)>,
}

impl LoadReport {
    pub fn requests_per_second(&self) -> f64 {
        let s = self.wall.as_secs_f64();
        if s == 0.0 {
            return 0.0;
        }
        self.completed as f64 / s
    }

    pub fn tokens(&self) -> usize {
        self.outputs.iter().map(|(_, o)| o.tokens.len()).sum()
    }

    pub fn tokens_per_second(&self) -> f64 {
        let s = self.wall.as_secs_f64();
        if s == 0.0 {
            return 0.0;
        }
        self.tokens() as f64 / s
    }
}

/// Replay `trace` against `handle`: submit each entry at its arrival
/// offset (with its priority/deadline/cancellation options), wait for
/// every accepted request, and aggregate the report.
///
/// Completion waits run on one short-lived thread per accepted request —
/// requests complete out of order across shards, and latency must be
/// measured at completion, not at a later poll.
pub fn replay(handle: &ServerHandle, trace: &RequestTrace) -> Result<LoadReport> {
    let t0 = Instant::now();
    let mut report = LoadReport { submitted: trace.len(), ..LoadReport::default() };
    let mut waiters = Vec::new();
    for (i, e) in trace.entries.iter().enumerate() {
        let target = Duration::from_micros((e.arrival_ms * 1000.0) as u64);
        let now = t0.elapsed();
        if target > now {
            std::thread::sleep(target - now);
        }
        let t_sub = Instant::now();
        match handle.submit_request(e.request()) {
            Ok(h) => waiters.push(std::thread::spawn(move || {
                let out = h.wait();
                (i, t_sub.elapsed(), out)
            })),
            Err(_) => report.rejected += 1,
        }
    }
    for w in waiters {
        let (i, dur, out) = w
            .join()
            .map_err(|_| anyhow::anyhow!("loadgen waiter panicked"))?;
        match out {
            Ok(response) => {
                match response.finish {
                    f if f.is_natural() => {
                        report.completed += 1;
                        report.latency.record(dur);
                    }
                    FinishReason::Cancelled => report.cancelled += 1,
                    FinishReason::DeadlineExpired => report.shed += 1,
                    FinishReason::ShardFailed => report.shard_failed += 1,
                    f => unreachable!("is_natural covers {f}"),
                }
                report.outputs.push((i, response));
            }
            Err(_) => report.failed += 1,
        }
    }
    report.outputs.sort_by_key(|(i, _)| *i);
    report.wall = t0.elapsed();
    Ok(report)
}
