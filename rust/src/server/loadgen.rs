//! Trace-driven load generation against a running server (DESIGN.md §8).
//!
//! Replays a [`RequestTrace`]'s arrival process (open-loop: submission
//! times follow the trace, not the server's progress) through a
//! [`ServerHandle`], measuring per-request submit-to-completion latency,
//! submit-time rejections (backpressure), and aggregate throughput.
//! Used by the `serve` subcommand and `benches/serving_throughput.rs`.

use std::time::{Duration, Instant};

use crate::coordinator::GenerationOutput;
use crate::metrics::LatencyStats;
use crate::workload::{RequestTrace, Task};
use crate::Result;

use super::ServerHandle;

/// Memory-pressure scenario (DESIGN.md §10): `n` concurrent long-window,
/// short-decode requests — line-retrieval prompts sized to nearly fill
/// the model window, a 2-token decode budget, and every arrival at t=0.
/// Each admitted session pins close to the worst-case byte footprint for
/// almost its whole lifetime, so replaying this trace against a
/// budget-configured server exercises the admission boundary (and, with
/// `memory.slots < max_batch`, the park/unpark path) under real
/// concurrency rather than only in unit tests.
///
/// Window ceiling: line-retrieval indexes lines with two digits, so
/// prompts cap at 100 lines (605 tokens).  Every current model config
/// (micro/tiny/base, windows 64–512) sits below that; for a future
/// window beyond ~612 tokens the prompts stop tracking the window and
/// callers sizing budgets from `worst_case_resident_bytes(full window)`
/// would over-admit — size the budget from this trace's actual prompt
/// lengths instead in that regime.
pub fn memory_pressure_trace(max_seq: usize, n: usize, seed: u64) -> RequestTrace {
    let max_new = 2;
    // Line-retrieval prompts are `6 * lines + 5` tokens; size `lines` so
    // prompt + decode budget just fits the window.
    let lines = (max_seq.saturating_sub(max_new + 5) / 6).clamp(1, 100);
    RequestTrace::batch(Task::Lines(lines), max_seq - max_new, n, max_new, seed)
}

/// Outcome of one trace replay.
#[derive(Debug, Default)]
pub struct LoadReport {
    /// Requests offered to the server (the whole trace).
    pub submitted: usize,
    /// Requests that completed with an output.
    pub completed: usize,
    /// Requests rejected at submit time (queue full / invalid).
    pub rejected: usize,
    /// Requests accepted but failed in flight (server error).
    pub failed: usize,
    /// Wall-clock of the whole replay (first submit to last completion).
    pub wall: Duration,
    /// Submit-to-completion latency of completed requests.
    pub latency: LatencyStats,
    /// `(trace index, output)` for every completed request, in trace
    /// order — callers score accuracy by zipping with the trace entries.
    pub outputs: Vec<(usize, GenerationOutput)>,
}

impl LoadReport {
    pub fn requests_per_second(&self) -> f64 {
        let s = self.wall.as_secs_f64();
        if s == 0.0 {
            return 0.0;
        }
        self.completed as f64 / s
    }

    pub fn tokens(&self) -> usize {
        self.outputs.iter().map(|(_, o)| o.tokens.len()).sum()
    }

    pub fn tokens_per_second(&self) -> f64 {
        let s = self.wall.as_secs_f64();
        if s == 0.0 {
            return 0.0;
        }
        self.tokens() as f64 / s
    }
}

/// Replay `trace` against `handle`: submit each entry at its arrival
/// offset, wait for every accepted request, and aggregate the report.
///
/// Completion waits run on one short-lived thread per accepted request —
/// requests complete out of order across shards, and latency must be
/// measured at completion, not at a later poll.
pub fn replay(handle: &ServerHandle, trace: &RequestTrace) -> Result<LoadReport> {
    let t0 = Instant::now();
    let mut report = LoadReport { submitted: trace.len(), ..LoadReport::default() };
    let mut waiters = Vec::new();
    for (i, e) in trace.entries.iter().enumerate() {
        let target = Duration::from_micros((e.arrival_ms * 1000.0) as u64);
        let now = t0.elapsed();
        if target > now {
            std::thread::sleep(target - now);
        }
        let t_sub = Instant::now();
        match handle.submit(e.sample.prompt().to_vec(), e.max_new_tokens) {
            Ok(h) => waiters.push(std::thread::spawn(move || {
                let out = h.wait();
                (i, t_sub.elapsed(), out)
            })),
            Err(_) => report.rejected += 1,
        }
    }
    for w in waiters {
        let (i, dur, out) = w
            .join()
            .map_err(|_| anyhow::anyhow!("loadgen waiter panicked"))?;
        match out {
            Ok(output) => {
                report.completed += 1;
                report.latency.record(dur);
                report.outputs.push((i, output));
            }
            Err(_) => report.failed += 1,
        }
    }
    report.outputs.sort_by_key(|(i, _)| *i);
    report.wall = t0.elapsed();
    Ok(report)
}
