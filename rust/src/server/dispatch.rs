//! Request dispatch for the sharded serving pool (DESIGN.md §8, §10).
//!
//! The [`Dispatcher`] is the **single admission point** of the server:
//! one global waiting-count bounded by `queue_depth` decides accept or
//! reject at submit time, and an admitted request is routed to the
//! least-loaded shard immediately.  Nothing downstream applies a second
//! depth limit — the per-shard batcher stages waiting requests in its
//! priority-ordered queue but never rejects (its depth is unbounded
//! under the server), and the global count tracks them until they hold
//! a decode slot — so the configured depth is the *exact* rejection
//! boundary (the seed stacked two queues, making the effective depth 2x
//! the configured value and surfacing the inner rejection as a
//! delivered error instead of submit-time backpressure).
//!
//! On top of the depth boundary sits the per-shard **byte budget**
//! (DESIGN.md §10): each shard carries a CAS-reserved count of the
//! worst-case compressed-resident bytes of its in-flight requests, and a
//! request is admitted only onto a shard whose reservation stays within
//! `memory.budget_bytes`.  Like the depth, the boundary is exact under
//! concurrent submitters; unlike the depth, it is per shard, so a
//! request is rejected only when *no* live shard can hold it.
//!
//! Accounting protocol (all counters SeqCst; traffic is far below
//! contention-relevant rates):
//!
//! * `queued` (global) — requests admitted but not yet holding a decode
//!   slot.  Incremented by [`Dispatcher::try_admit`]; decremented by the
//!   owning shard via [`ShardCtx::note_activated`] as requests leave its
//!   batcher's priority-ordered staging queue — by activating into a
//!   session *or* retiring at pop (cancelled / deadline-shed), so the
//!   boundary counts exactly the requests still waiting for a slot even
//!   though shards stage eagerly (DESIGN.md §11).
//! * `load` (per shard) — requests in flight on that shard (waiting in
//!   its channel + actively decoding).  Incremented at admission;
//!   decremented via [`ShardCtx::note_done`] when the reply is sent.
//! * `reserved` (per shard) — worst-case resident bytes of in-flight
//!   requests.  CAS-reserved at admission against the budget; released
//!   by [`ShardCtx::note_done`] with the amount carried on the request.
//! * `resident` (per shard) — live resident bytes last published by the
//!   shard's batcher ([`ShardCtx::publish_resident`]).  `try_admit`
//!   routes to the shard with the minimum `(load, resident, index)` —
//!   resident bytes break load ties, so two shards with equal request
//!   counts route by who actually holds less memory.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::{Arc, Mutex};

use crate::coordinator::request::GenerationRequest;
use crate::kvcache::PrefixStore;
use crate::Result;

use super::ResponseEvent;

/// Everything `try_admit` needs for one submission — the typed request
/// plus the submit-side plumbing (one struct, DESIGN.md §11; the seed
/// API threaded four positional arguments through here).
pub(crate) struct AdmitRequest {
    pub request: GenerationRequest,
    /// Worst-case resident footprint, reserved against the per-shard
    /// byte budget when one is configured.
    pub wc_bytes: usize,
    /// Per-covered-token reservation discount on a prefix hit
    /// ([`crate::kvcache::prefix_reservation_shrink`]; 0 when the
    /// policy is ineligible or the prefix store is off — DESIGN.md §16).
    pub shrink_per_token: usize,
    /// Streamed token / final response channel back to the handle.
    pub reply: Sender<ResponseEvent>,
}

/// One admitted request, in flight to (or inside) a shard.
pub(crate) struct ShardRequest {
    pub request: GenerationRequest,
    /// Global submission-order tag (diagnostics; outputs never depend on
    /// it — seeds derive from request content, DESIGN.md §8).
    pub tag: u64,
    /// Worst-case resident bytes reserved on the owning shard's budget
    /// (0 when no budget is configured); released at `note_done`.
    pub reserved_bytes: usize,
    pub reply: Sender<ResponseEvent>,
}

/// The dispatcher's per-shard route: channel + accounting + liveness.
/// The sender sits behind a mutex because `mpsc::Sender` is not `Sync`
/// on older toolchains and the dispatcher is shared across submitter
/// threads; the critical section is one non-blocking `send`.  `alive`
/// flips to false the first time a send fails (shard thread exited on an
/// engine error) so routing skips the dead shard from then on.
struct ShardLink {
    tx: Mutex<Sender<ShardRequest>>,
    // lint: gauge — requests in flight on this shard; inc at route,
    // dec at `note_done`.
    load: Arc<AtomicUsize>,
    // lint: gauge — worst-case resident bytes; CAS-reserved at
    // admission (`try_reserve`), released at `note_done`.
    reserved: Arc<AtomicUsize>,
    resident: Arc<AtomicUsize>,
    /// Shared with the shard's own fatal path and the supervisor
    /// (DESIGN.md §14): false while the shard is dead or restarting,
    /// true again once the supervisor's replacement thread is ready.
    alive: Arc<AtomicBool>,
    /// Monotonic iteration counter ticked by the serving loop — *not* a
    /// gauge (it only grows).  The supervisor reads it to tell a busy
    /// shard from a wedged one (DESIGN.md §14).
    heartbeat: Arc<AtomicU64>,
}

/// Submit-side state shared by every [`super::ServerHandle`] clone.
pub(crate) struct Dispatcher {
    shards: Vec<ShardLink>,
    // lint: gauge — global admitted-not-yet-activated count
    // (`queue_depth` backpressure); CAS-inc at `try_admit`, dec at
    // `note_activated` / failed-send rollback.
    queued: Arc<AtomicUsize>,
    queue_depth: usize,
    /// Per-shard worst-case byte budget; 0 = unlimited.
    budget_bytes: usize,
    /// Per-shard shared-prefix stores (DESIGN.md §16): empty when prefix
    /// caching is off, else one per shard.  Owned here — not by the
    /// engines — so interned segments survive shard respawns; routing
    /// probes them for affinity and subtracts their `shared_bytes` from
    /// the shard's admission budget (the store is budgeted *inside*
    /// `memory.budget_bytes`, never on top of it).
    prefix_stores: Vec<Arc<PrefixStore>>,
    next_tag: AtomicU64,
}

/// Shard-side endpoints handed to each serving thread.
pub(crate) struct ShardCtx {
    pub rx: Receiver<ShardRequest>,
    queued: Arc<AtomicUsize>,
    load: Arc<AtomicUsize>,
    reserved: Arc<AtomicUsize>,
    resident: Arc<AtomicUsize>,
    alive: Arc<AtomicBool>,
    heartbeat: Arc<AtomicU64>,
}

impl ShardCtx {
    /// `n` requests just left the shard's staging queue (activated into a
    /// session, or retired at pop as cancelled/deadline-shed).
    pub fn note_activated(&self, n: usize) {
        self.queued.fetch_sub(n, Ordering::SeqCst);
    }

    /// The request's reply has been sent (or dropped): frees shard load
    /// and releases its worst-case byte reservation.
    pub fn note_done(&self, reserved_bytes: usize) {
        self.load.fetch_sub(1, Ordering::SeqCst);
        self.reserved.fetch_sub(reserved_bytes, Ordering::SeqCst);
    }

    /// Publish the shard's live resident bytes (routing weight).
    pub fn publish_resident(&self, bytes: usize) {
        self.resident.store(bytes, Ordering::SeqCst);
    }

    /// Tick the shard's liveness counter; called once per serving-loop
    /// iteration so the supervisor can tell progress from a wedge
    /// (DESIGN.md §14).
    pub fn tick_heartbeat(&self) {
        self.heartbeat.fetch_add(1, Ordering::SeqCst);
    }

    /// The shard's fatal path calls this first (DESIGN.md §14): routing
    /// stops considering the shard before its reply slots are drained,
    /// so no new request can race into the dying channel.
    pub fn mark_dead(&self) {
        self.alive.store(false, Ordering::SeqCst);
    }
}

/// Build a dispatcher and its `n_shards` shard endpoints.
/// `budget_bytes` is the per-shard worst-case byte budget (0 = off).
pub(crate) fn build(
    n_shards: usize,
    queue_depth: usize,
    budget_bytes: usize,
) -> (Dispatcher, Vec<ShardCtx>) {
    assert!(n_shards >= 1, "dispatcher needs at least one shard");
    let queued = Arc::new(AtomicUsize::new(0));
    let mut shards = Vec::with_capacity(n_shards);
    let mut ctxs = Vec::with_capacity(n_shards);
    for _ in 0..n_shards {
        let (tx, rx) = mpsc::channel();
        let load = Arc::new(AtomicUsize::new(0));
        let reserved = Arc::new(AtomicUsize::new(0));
        let resident = Arc::new(AtomicUsize::new(0));
        let alive = Arc::new(AtomicBool::new(true));
        let heartbeat = Arc::new(AtomicU64::new(0));
        shards.push(ShardLink {
            tx: Mutex::new(tx),
            load: load.clone(),
            reserved: reserved.clone(),
            resident: resident.clone(),
            alive: alive.clone(),
            heartbeat: heartbeat.clone(),
        });
        ctxs.push(ShardCtx {
            rx,
            queued: queued.clone(),
            load,
            reserved,
            resident,
            alive,
            heartbeat,
        });
    }
    let dispatcher = Dispatcher {
        shards,
        queued,
        queue_depth,
        budget_bytes,
        prefix_stores: Vec::new(),
        next_tag: AtomicU64::new(0),
    };
    (dispatcher, ctxs)
}

/// CAS-reserve `n` on `a` without exceeding `bound`; exact under
/// concurrent reservers (the same discipline as the queue-depth CAS).
fn try_reserve(a: &AtomicUsize, n: usize, bound: usize) -> bool {
    let mut cur = a.load(Ordering::SeqCst);
    loop {
        if cur + n > bound {
            return false;
        }
        match a.compare_exchange_weak(cur, cur + n, Ordering::SeqCst,
                                      Ordering::SeqCst) {
            Ok(_) => return true,
            Err(now) => cur = now,
        }
    }
}

impl Dispatcher {
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Install the per-shard prefix stores (DESIGN.md §16).  Called once
    /// by `Server::start` before the dispatcher is shared; empty leaves
    /// prefix caching off and routing byte-identical to its prior form.
    pub fn set_prefix_stores(&mut self, stores: Vec<Arc<PrefixStore>>) {
        assert!(stores.is_empty() || stores.len() == self.shards.len(),
                "one prefix store per shard");
        self.prefix_stores = stores;
    }

    /// Shard `i`'s prefix store, when prefix caching is on (the shard
    /// loop installs this same Arc into its engine at spawn/respawn).
    pub fn prefix_store(&self, shard: usize) -> Option<&Arc<PrefixStore>> {
        self.prefix_stores.get(shard)
    }

    /// Covered-token count shard `i` could serve for `prompt` right now
    /// (a refcount-free [`PrefixStore::probe`]; 0 when prefix is off).
    fn probe_covered(&self, shard: usize, prompt: &[u16]) -> usize {
        self.prefix_stores
            .get(shard)
            .map_or(0, |st| st.probe(prompt))
    }

    /// Shard `i`'s effective admission budget: the configured per-shard
    /// budget minus what its prefix store currently holds — shared
    /// segments are counted once per shard, inside the same budget the
    /// reservations draw from (DESIGN.md §16).
    fn budget_for(&self, shard: usize) -> usize {
        let shared = self
            .prefix_stores
            .get(shard)
            .map_or(0, |st| st.shared_bytes());
        self.budget_bytes.saturating_sub(shared)
    }

    /// Requests currently waiting for a decode slot (observability).
    pub fn queued(&self) -> usize {
        self.queued.load(Ordering::SeqCst)
    }

    /// Per-shard in-flight loads (observability).
    pub fn loads(&self) -> Vec<usize> {
        self.shards.iter().map(|s| s.load.load(Ordering::SeqCst)).collect()
    }

    /// Per-shard reserved worst-case bytes (observability).
    pub fn reserved_bytes(&self) -> Vec<usize> {
        self.shards
            .iter()
            .map(|s| s.reserved.load(Ordering::SeqCst))
            .collect()
    }

    /// Per-shard published live resident bytes (observability).
    pub fn resident_bytes(&self) -> Vec<usize> {
        self.shards
            .iter()
            .map(|s| s.resident.load(Ordering::SeqCst))
            .collect()
    }

    /// Per-shard liveness flags (supervisor + tests, DESIGN.md §14).
    pub fn alive_flags(&self) -> Vec<bool> {
        self.shards.iter().map(|s| s.alive.load(Ordering::SeqCst)).collect()
    }

    /// Per-shard monotonic iteration counters (stall detection, §14).
    pub fn heartbeats(&self) -> Vec<u64> {
        self.shards
            .iter()
            .map(|s| s.heartbeat.load(Ordering::SeqCst))
            .collect()
    }

    /// Flip a shard's routing liveness; the supervisor sets `true` only
    /// after the replacement thread has signalled ready (§14).
    pub fn set_alive(&self, shard: usize, alive: bool) {
        self.shards[shard].alive.store(alive, Ordering::SeqCst);
    }

    /// Release `n` global waiting slots without going through a shard.
    /// Fatal-path only (§14): used when a request staged on a dead shard
    /// cannot be redelivered anywhere, so its reply is answered directly
    /// and its admission slot must still drain.
    pub fn release_queued(&self, n: usize) {
        self.queued.fetch_sub(n, Ordering::SeqCst);
    }

    /// Cut a wedged shard off (DESIGN.md §14): mark it dead for routing
    /// and replace its sender with one whose receiver is already gone.
    /// Dropping the old sender disconnects the wedged thread's
    /// `rx.recv()`, which it treats as fatal — so a stall drains through
    /// the same fatal path as a panic.
    pub fn sever(&self, shard: usize) {
        let link = &self.shards[shard];
        link.alive.store(false, Ordering::SeqCst);
        let (dead_tx, _) = mpsc::channel();
        *link.tx.lock().expect("dispatch sender poisoned") = dead_tx;
    }

    /// Wire a fresh channel for a restarted shard and hand back its new
    /// [`ShardCtx`] (same accounting atomics — gauges survive restarts).
    /// Does *not* flip `alive`: the supervisor does that only once the
    /// replacement thread reports ready, so no request can race into a
    /// channel whose engine is still loading (§14).
    pub fn revive(&self, shard: usize) -> ShardCtx {
        let link = &self.shards[shard];
        let (tx, rx) = mpsc::channel();
        *link.tx.lock().expect("dispatch sender poisoned") = tx;
        ShardCtx {
            rx,
            queued: self.queued.clone(),
            load: link.load.clone(),
            reserved: link.reserved.clone(),
            resident: link.resident.clone(),
            alive: link.alive.clone(),
            heartbeat: link.heartbeat.clone(),
        }
    }

    /// Re-route a request that was waiting on a failed shard to a live
    /// one (DESIGN.md §14).  Keeps the original tag and the already-held
    /// global waiting slot (no queue-depth CAS, no re-validation — the
    /// request was admitted once and stays admitted); re-reserves its
    /// worst-case bytes on the target.  Content-derived seeds make the
    /// redelivered output bit-identical to the fault-free run.  Fails
    /// only when no live shard can hold the reservation; the caller then
    /// answers the reply directly and releases the waiting slot.
    pub fn redeliver(&self, shard_req: ShardRequest) -> Result<()> {
        let ShardRequest { request, tag, reserved_bytes, reply } = shard_req;
        let mut request = request;
        // Any attached prefix hit pinned the *failed* shard's store;
        // drop the pins and let the surviving shard's engine re-resolve
        // against its own store (DESIGN.md §16).  The already-shrunk
        // reservation stays sound: the discount is a policy-wide bound,
        // not a property of the hit (see `prefix_reservation_shrink`).
        request.prefix = None;
        let mut reply = reply;
        loop {
            let route_key = |i: usize| {
                let s = &self.shards[i];
                (s.load.load(Ordering::SeqCst),
                 s.resident.load(Ordering::SeqCst), i)
            };
            let mut live = (0..self.shards.len())
                .filter(|&i| self.shards[i].alive.load(Ordering::SeqCst))
                .peekable();
            if live.peek().is_none() {
                anyhow::bail!("redelivery failed: no live shards");
            }
            let chosen = if self.budget_bytes == 0 {
                live.min_by_key(|&i| route_key(i))
            } else {
                let mut order: Vec<usize> = live.collect();
                order.sort_by_key(|&i| route_key(i));
                order.into_iter().find(|&i| {
                    try_reserve(&self.shards[i].reserved, reserved_bytes,
                                self.budget_for(i))
                })
            };
            let Some(idx) = chosen else {
                anyhow::bail!(
                    "redelivery failed: no live shard can hold the \
                     {reserved_bytes} B reservation"
                );
            };
            let link = &self.shards[idx];
            link.load.fetch_add(1, Ordering::SeqCst);
            let sent = link
                .tx
                .lock()
                .expect("dispatch sender poisoned")
                .send(ShardRequest { request, tag, reserved_bytes, reply });
            match sent {
                Ok(()) => return Ok(()),
                Err(mpsc::SendError(req)) => {
                    link.load.fetch_sub(1, Ordering::SeqCst);
                    link.reserved.fetch_sub(reserved_bytes, Ordering::SeqCst);
                    link.alive.store(false, Ordering::SeqCst);
                    request = req.request;
                    reply = req.reply;
                }
            }
        }
    }

    /// Admit one request or reject with backpressure.  The
    /// [`AdmitRequest`] carries the typed request, its worst-case
    /// resident footprint (reserved against the per-shard byte budget
    /// when one is configured), and the reply channel.  On success the
    /// request is already routed to the least-loaded shard (resident
    /// bytes break load ties) that could hold the reservation; the
    /// returned tag is its global submission index.
    pub fn try_admit(&self, admit: AdmitRequest) -> Result<u64> {
        let AdmitRequest { request, wc_bytes, shrink_per_token, reply } = admit;
        // Reserve a waiting slot with a CAS loop so the boundary is exact
        // even under concurrent submitters.
        let mut cur = self.queued.load(Ordering::SeqCst);
        loop {
            if cur >= self.queue_depth {
                anyhow::bail!("queue full (backpressure)");
            }
            match self.queued.compare_exchange_weak(
                cur,
                cur + 1,
                Ordering::SeqCst,
                Ordering::SeqCst,
            ) {
                Ok(_) => break,
                Err(now) => cur = now,
            }
        }

        // Route to the best live shard that can also hold the request's
        // worst-case byte reservation: candidates in (covered-prefix
        // desc, load, resident, index) order — prefix affinity outranks
        // load so a warm shard wins even when slightly busier
        // (DESIGN.md §16); with prefix off, covered is uniformly 0 and
        // this is the historical (load, resident, index) order.  The
        // first reservable candidate wins.  A failed send marks that
        // shard dead, rolls its accounting back, and retries, so a
        // single crashed shard never blackholes admissions while healthy
        // shards have capacity (DESIGN.md §8).
        let mut request = request;
        let mut reply = reply;
        loop {
            let route_key = |i: usize| {
                let s = &self.shards[i];
                (std::cmp::Reverse(self.probe_covered(i, &request.prompt)),
                 s.load.load(Ordering::SeqCst),
                 s.resident.load(Ordering::SeqCst), i)
            };
            let mut live = (0..self.shards.len())
                .filter(|&i| self.shards[i].alive.load(Ordering::SeqCst))
                .peekable();
            if live.peek().is_none() {
                self.queued.fetch_sub(1, Ordering::SeqCst);
                anyhow::bail!("server stopped (no live shards)");
            }
            let chosen = if self.budget_bytes == 0 {
                // No budget: min scan, first index wins ties through the
                // key's index component; nothing is reserved.
                live.min_by_key(|&i| route_key(i)).map(|i| (i, 0))
            } else {
                // Budget: candidates in routing order; the first one
                // whose reservation fits wins, so a full best shard
                // spills to the next rather than rejecting.  On a warm
                // candidate the reservation shrinks by the covered span
                // (`prefix_reservation_shrink` is a policy-wide bound,
                // so the discount stays sound even if the hit is evicted
                // before the session starts — DESIGN.md §16).
                let mut order: Vec<usize> = live.collect();
                order.sort_by_key(|&i| route_key(i));
                order.into_iter().find_map(|i| {
                    let covered = self.probe_covered(i, &request.prompt);
                    let amt = wc_bytes
                        .saturating_sub(covered * shrink_per_token);
                    try_reserve(&self.shards[i].reserved, amt,
                                self.budget_for(i))
                        .then_some((i, amt))
                })
            };
            let Some((idx, reserved_bytes)) = chosen else {
                // Every live shard's budget is exhausted (or the request
                // can never fit): exact submit-time backpressure.
                self.queued.fetch_sub(1, Ordering::SeqCst);
                anyhow::bail!(
                    "memory budget exceeded (worst-case {wc_bytes} B does not \
                     fit any shard's {} B budget — backpressure)",
                    self.budget_bytes
                );
            };
            let link = &self.shards[idx];
            // Only the winning shard pays for a real lookup: the hit pins
            // its segments from admission until the session finishes, so
            // churn between now and activation cannot free rows the warm
            // prefill is counting on (deferred reclamation).
            if let Some(st) = self.prefix_stores.get(idx) {
                request.prefix = st.lookup(&request.prompt);
            }
            link.load.fetch_add(1, Ordering::SeqCst);
            let tag = self.next_tag.fetch_add(1, Ordering::SeqCst);
            let sent = link
                .tx
                .lock()
                .expect("dispatch sender poisoned")
                .send(ShardRequest { request, tag, reserved_bytes, reply });
            match sent {
                Ok(()) => return Ok(tag),
                Err(mpsc::SendError(req)) => {
                    // Shard thread gone: roll its accounting back, mark it
                    // dead, and re-route the request.  The attached hit
                    // belongs to the dead shard's store; dropping it here
                    // releases the pins, and the retry re-resolves
                    // against whichever shard wins next.
                    link.load.fetch_sub(1, Ordering::SeqCst);
                    link.reserved.fetch_sub(reserved_bytes, Ordering::SeqCst);
                    link.alive.store(false, Ordering::SeqCst);
                    request = req.request;
                    request.prefix = None;
                    reply = req.reply;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A minimal admission packet (`wc` = worst-case bytes reserved).
    fn packet(wc: usize) -> AdmitRequest {
        AdmitRequest {
            request: GenerationRequest::new(vec![1], 2),
            wc_bytes: wc,
            shrink_per_token: 0,
            reply: mpsc::channel().0,
        }
    }

    /// A packet with an explicit prompt and per-token shrink.
    fn prompt_packet(prompt: Vec<u16>, wc: usize, shrink: usize)
                     -> AdmitRequest {
        AdmitRequest {
            request: GenerationRequest::new(prompt, 2),
            wc_bytes: wc,
            shrink_per_token: shrink,
            reply: mpsc::channel().0,
        }
    }

    #[test]
    fn exact_rejection_boundary() {
        // depth D admits exactly D waiting requests; D+1 rejects; freeing
        // one waiting slot admits exactly one more.
        let depth = 3;
        let (d, ctxs) = build(2, depth, 0);
        for i in 0..depth {
            assert!(d.try_admit(packet(0)).is_ok(), "admit {i}");
        }
        assert_eq!(d.queued(), depth);
        let err = d.try_admit(packet(0)).unwrap_err();
        assert!(err.to_string().contains("queue full"), "{err}");
        // a shard pulls one request into its batcher -> one slot frees
        ctxs[0].note_activated(1);
        assert!(d.try_admit(packet(0)).is_ok());
        assert!(d.try_admit(packet(0)).is_err());
    }

    #[test]
    fn zero_depth_rejects_everything() {
        let (d, _ctxs) = build(1, 0, 0);
        assert!(d.try_admit(packet(0)).is_err());
    }

    #[test]
    fn least_loaded_routing_balances() {
        let (d, ctxs) = build(3, 64, 0);
        for _ in 0..6 {
            d.try_admit(packet(0)).unwrap();
        }
        assert_eq!(d.loads(), vec![2, 2, 2]);
        // completion on shard 1 draws the next request there
        ctxs[1].note_activated(1);
        ctxs[1].note_done(0);
        d.try_admit(packet(0)).unwrap();
        assert_eq!(d.loads(), vec![2, 2, 2]);
        // requests actually landed in the right channels
        assert_eq!(ctxs[0].rx.try_iter().count(), 2);
        assert_eq!(ctxs[1].rx.try_iter().count(), 3);
        assert_eq!(ctxs[2].rx.try_iter().count(), 2);
    }

    #[test]
    fn resident_bytes_break_load_ties() {
        // Equal loads everywhere; shard 1 publishes the smallest live
        // resident footprint, so the next request routes there instead of
        // falling through to the lowest index.
        let (d, ctxs) = build(3, 64, 0);
        ctxs[0].publish_resident(9_000);
        ctxs[1].publish_resident(1_000);
        ctxs[2].publish_resident(5_000);
        d.try_admit(packet(0)).unwrap();
        assert_eq!(d.loads(), vec![0, 1, 0]);
        assert_eq!(ctxs[1].rx.try_iter().count(), 1);
        // With shard 1 now ahead on load, the tie among 0 and 2 goes to
        // the lighter shard 2, not the lower index.
        d.try_admit(packet(0)).unwrap();
        assert_eq!(d.loads(), vec![0, 1, 1]);
        assert_eq!(ctxs[2].rx.try_iter().count(), 1);
        // Exact load+resident tie: lowest index wins.
        ctxs[0].publish_resident(5_000);
        ctxs[2].publish_resident(5_000);
        d.try_admit(packet(0)).unwrap();
        assert_eq!(ctxs[0].rx.try_iter().count(), 1);
    }

    #[test]
    fn budget_boundary_is_exact() {
        // Budget = 2 x wc: two requests reserve exactly the budget, the
        // third rejects at submit time, and releasing one reservation
        // admits exactly one more — the queue-depth discipline, in bytes.
        let wc = 1000;
        let (d, ctxs) = build(1, 64, 2 * wc);
        assert!(d.try_admit(packet(wc)).is_ok());
        assert!(d.try_admit(packet(wc)).is_ok());
        assert_eq!(d.reserved_bytes(), vec![2 * wc]);
        let err = d.try_admit(packet(wc)).unwrap_err();
        assert!(err.to_string().contains("memory budget"), "{err}");
        // queued was rolled back: the rejection is a budget rejection,
        // not a stuck waiting slot.
        assert_eq!(d.queued(), 2);
        ctxs[0].note_activated(1);
        ctxs[0].note_done(wc);
        assert_eq!(d.reserved_bytes(), vec![wc]);
        assert!(d.try_admit(packet(wc)).is_ok());
        assert!(d.try_admit(packet(wc)).is_err());
    }

    #[test]
    fn oversized_request_rejected_even_when_idle() {
        let (d, _ctxs) = build(2, 64, 1000);
        let err = d.try_admit(packet(1001)).unwrap_err();
        assert!(err.to_string().contains("memory budget"), "{err}");
        assert_eq!(d.queued(), 0);
        assert_eq!(d.reserved_bytes(), vec![0, 0]);
    }

    #[test]
    fn budget_spills_to_sibling_shard() {
        // Shard 0's budget is full; the request must land on shard 1
        // rather than reject — rejection only when *no* shard fits.
        let wc = 500;
        let (d, ctxs) = build(2, 64, 2 * wc);
        for _ in 0..4 {
            d.try_admit(packet(wc)).unwrap();
        }
        assert_eq!(d.reserved_bytes(), vec![2 * wc, 2 * wc]);
        assert!(d.try_admit(packet(wc)).is_err());
        assert_eq!(ctxs[0].rx.try_iter().count(), 2);
        assert_eq!(ctxs[1].rx.try_iter().count(), 2);
    }

    /// A 1-plane store with the test prompt's first 8 tokens interned
    /// (granule 4 -> two links, 128 payload bytes).
    fn warm_store(prompt: &[u16]) -> Arc<PrefixStore> {
        use crate::config::PolicyKind;
        use crate::kvcache::CacheLayout;
        let lay = CacheLayout { layers: 1, heads: 1, seq: 16, d_head: 2 };
        let st = PrefixStore::new("micro", PolicyKind::Zipcache, 4, 0);
        let buf = vec![0f32; lay.cache_len()];
        st.intern(prompt, &buf, &buf, &lay);
        st
    }

    fn cold_store() -> Arc<PrefixStore> {
        use crate::config::PolicyKind;
        PrefixStore::new("micro", PolicyKind::Zipcache, 4, 0)
    }

    #[test]
    fn prefix_affinity_outranks_load() {
        let prompt: Vec<u16> = (5..14).collect(); // 9 tokens, covered = 8
        let (mut d, ctxs) = build(2, 16, 0);
        d.set_prefix_stores(vec![cold_store(), warm_store(&prompt)]);
        // Shape loads to [0, 2]: the warm shard is strictly busier.
        for _ in 0..4 {
            d.try_admit(packet(0)).unwrap();
        }
        ctxs[0].note_done(0);
        ctxs[0].note_done(0);
        assert_eq!(d.loads(), vec![0, 2]);
        // The warm prompt still routes to shard 1 — covered outranks
        // load — and arrives with the hit pinned at admission.
        d.try_admit(prompt_packet(prompt.clone(), 0, 0)).unwrap();
        assert_eq!(d.loads(), vec![0, 3]);
        let got = ctxs[1].rx.try_iter().last().unwrap();
        let hit = got.request.prefix.expect("hit attached at admission");
        assert_eq!(hit.covered, 8);
        assert_eq!(hit.segs.len(), 2);
        // A cold prompt keeps the historical least-loaded routing.
        d.try_admit(packet(0)).unwrap();
        assert_eq!(d.loads(), vec![1, 3]);
    }

    #[test]
    fn warm_reservation_shrinks_by_covered_span() {
        let prompt: Vec<u16> = (5..14).collect();
        let (mut d, ctxs) = build(1, 16, 400);
        d.set_prefix_stores(vec![warm_store(&prompt)]);
        // Store payload (128 B) is budgeted *inside* the 400 B budget:
        // the admission bound is 272 B.  A warm request reserves
        // wc - covered*shrink = 1000 - 8*100 = 200 B.
        d.try_admit(prompt_packet(prompt.clone(), 1000, 100)).unwrap();
        assert_eq!(d.reserved_bytes(), vec![200]);
        let got = ctxs[0].rx.try_recv().unwrap();
        assert_eq!(got.reserved_bytes, 200);
        // A second warm request (200 B) no longer fits 272 - 200 = 72 B.
        let err = d.try_admit(prompt_packet(prompt, 1000, 100)).unwrap_err();
        assert!(err.to_string().contains("memory budget"), "{err}");
        assert_eq!(d.reserved_bytes(), vec![200], "failed admit leaked");
    }

    #[test]
    fn shared_store_bytes_count_against_the_budget() {
        let prompt: Vec<u16> = (5..14).collect();
        // 150 B budget, 128 B already interned: only 22 B remain, so a
        // cold 100 B request that would fit an empty shard rejects.
        let (mut d, _ctxs) = build(1, 16, 150);
        d.set_prefix_stores(vec![warm_store(&prompt)]);
        let err = d.try_admit(packet(100)).unwrap_err();
        assert!(err.to_string().contains("memory budget"), "{err}");
        let (d2, _ctxs2) = build(1, 16, 150);
        assert!(d2.try_admit(packet(100)).is_ok());
    }

    #[test]
    fn tags_are_submission_ordered() {
        let (d, _ctxs) = build(2, 8, 0);
        let t0 = d.try_admit(packet(0)).unwrap();
        let t1 = d.try_admit(packet(0)).unwrap();
        assert_eq!((t0, t1), (0, 1));
    }

    #[test]
    fn dead_shard_rolls_back_counters() {
        let (d, ctxs) = build(1, 4, 4096);
        drop(ctxs); // receiver gone
        let err = d.try_admit(packet(100)).unwrap_err();
        assert!(err.to_string().contains("no live shards"), "{err}");
        assert_eq!(d.queued(), 0);
        assert_eq!(d.loads(), vec![0]);
        assert_eq!(d.reserved_bytes(), vec![0], "reservation leaked");
    }

    #[test]
    fn heartbeat_is_monotonic_per_shard() {
        let (d, ctxs) = build(2, 8, 0);
        ctxs[1].tick_heartbeat();
        ctxs[1].tick_heartbeat();
        assert_eq!(d.heartbeats(), vec![0, 2]);
    }

    #[test]
    fn sever_disconnects_then_revive_rewires() {
        let (d, ctxs) = build(2, 8, 0);
        d.sever(0);
        assert_eq!(d.alive_flags(), vec![false, true]);
        // the severed shard's receiver is already disconnected
        assert!(ctxs[0].rx.recv().is_err());
        let new_ctx = d.revive(0);
        assert_eq!(d.alive_flags(), vec![false, true],
                   "revive must not flip alive — only the supervisor does");
        d.set_alive(0, true);
        d.try_admit(packet(0)).unwrap();
        // lowest-index tie-break lands on the revived shard's new channel
        assert_eq!(new_ctx.rx.try_iter().count(), 1);
    }

    #[test]
    fn redeliver_keeps_tag_and_waiting_slot() {
        let (d, ctxs) = build(2, 8, 0);
        let tag = d.try_admit(packet(0)).unwrap();
        let req = ctxs[0].rx.try_recv().unwrap();
        // shard 0 dies: fatal path releases its load, keeps `queued`
        ctxs[0].mark_dead();
        ctxs[0].note_done(req.reserved_bytes);
        d.redeliver(req).unwrap();
        assert_eq!(d.queued(), 1, "redelivery must keep the waiting slot");
        let re = ctxs[1].rx.try_recv().unwrap();
        assert_eq!(re.tag, tag, "redelivery must keep the original tag");
        assert_eq!(d.loads(), vec![0, 1]);
    }

    #[test]
    fn redeliver_fails_cleanly_with_no_live_shards() {
        let (d, ctxs) = build(1, 8, 0);
        d.try_admit(packet(0)).unwrap();
        let req = ctxs[0].rx.try_recv().unwrap();
        ctxs[0].mark_dead();
        ctxs[0].note_done(req.reserved_bytes);
        let err = d.redeliver(req).unwrap_err();
        assert!(err.to_string().contains("no live shards"), "{err}");
        d.release_queued(1);
        assert_eq!(d.queued(), 0);
    }

    #[test]
    fn routing_skips_dead_shard() {
        // One crashed shard must not blackhole admissions: sends that hit
        // its closed channel re-route to the live shard.
        let (d, mut ctxs) = build(2, 16, 0);
        let live = ctxs.remove(1);
        drop(ctxs); // shard 0's receiver gone (thread died)
        for _ in 0..4 {
            d.try_admit(packet(0)).unwrap();
        }
        assert_eq!(live.rx.try_iter().count(), 4, "requests lost");
        assert_eq!(d.loads()[0], 0, "dead shard holds phantom load");
        assert_eq!(d.loads()[1], 4);
        assert_eq!(d.queued(), 4);
    }
}
