//! Request dispatch for the sharded serving pool (DESIGN.md §8).
//!
//! The [`Dispatcher`] is the **single admission point** of the server:
//! one global waiting-count bounded by `queue_depth` decides accept or
//! reject at submit time, and an admitted request is routed to the
//! least-loaded shard immediately.  Nothing downstream applies a second
//! depth limit — the per-shard batcher only ever receives work it has a
//! free decode slot for — so the configured depth is the *exact*
//! rejection boundary (the seed stacked two queues, making the effective
//! depth 2x the configured value and surfacing the inner rejection as a
//! delivered error instead of submit-time backpressure).
//!
//! Accounting protocol (all counters SeqCst; traffic is far below
//! contention-relevant rates):
//!
//! * `queued` (global) — requests admitted but not yet holding a decode
//!   slot.  Incremented by [`Dispatcher::try_admit`]; decremented by the
//!   owning shard via [`ShardCtx::note_activated`] the moment it pulls
//!   the request into its batcher.
//! * `load` (per shard) — requests in flight on that shard (waiting in
//!   its channel + actively decoding).  Incremented at admission;
//!   decremented via [`ShardCtx::note_done`] when the reply is sent.
//!   `try_admit` routes to the shard with the minimum load (ties break
//!   to the lowest shard index).

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::{Arc, Mutex};

use crate::coordinator::GenerationOutput;
use crate::Result;

/// One admitted request, in flight to (or inside) a shard.
pub(crate) struct ShardRequest {
    pub prompt: Vec<u16>,
    pub max_new: usize,
    /// Global submission-order tag (diagnostics; outputs never depend on
    /// it — seeds derive from request content, DESIGN.md §8).
    pub tag: u64,
    pub reply: Sender<Result<GenerationOutput>>,
}

/// The dispatcher's per-shard route: channel + load counter + liveness.
/// The sender sits behind a mutex because `mpsc::Sender` is not `Sync`
/// on older toolchains and the dispatcher is shared across submitter
/// threads; the critical section is one non-blocking `send`.  `alive`
/// flips to false the first time a send fails (shard thread exited on an
/// engine error) so routing skips the dead shard from then on.
struct ShardLink {
    tx: Mutex<Sender<ShardRequest>>,
    load: Arc<AtomicUsize>,
    alive: AtomicBool,
}

/// Submit-side state shared by every [`super::ServerHandle`] clone.
pub(crate) struct Dispatcher {
    shards: Vec<ShardLink>,
    queued: Arc<AtomicUsize>,
    queue_depth: usize,
    next_tag: AtomicU64,
}

/// Shard-side endpoints handed to each serving thread.
pub(crate) struct ShardCtx {
    pub rx: Receiver<ShardRequest>,
    queued: Arc<AtomicUsize>,
    load: Arc<AtomicUsize>,
}

impl ShardCtx {
    /// The request just left the waiting queue for a decode slot.
    pub fn note_activated(&self) {
        self.queued.fetch_sub(1, Ordering::SeqCst);
    }

    /// The request's reply has been sent (or dropped): frees shard load.
    pub fn note_done(&self) {
        self.load.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Build a dispatcher and its `n_shards` shard endpoints.
pub(crate) fn build(n_shards: usize, queue_depth: usize) -> (Dispatcher, Vec<ShardCtx>) {
    assert!(n_shards >= 1, "dispatcher needs at least one shard");
    let queued = Arc::new(AtomicUsize::new(0));
    let mut shards = Vec::with_capacity(n_shards);
    let mut ctxs = Vec::with_capacity(n_shards);
    for _ in 0..n_shards {
        let (tx, rx) = mpsc::channel();
        let load = Arc::new(AtomicUsize::new(0));
        shards.push(ShardLink {
            tx: Mutex::new(tx),
            load: load.clone(),
            alive: AtomicBool::new(true),
        });
        ctxs.push(ShardCtx { rx, queued: queued.clone(), load });
    }
    let dispatcher = Dispatcher {
        shards,
        queued,
        queue_depth,
        next_tag: AtomicU64::new(0),
    };
    (dispatcher, ctxs)
}

impl Dispatcher {
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Requests currently waiting for a decode slot (observability).
    pub fn queued(&self) -> usize {
        self.queued.load(Ordering::SeqCst)
    }

    /// Per-shard in-flight loads (observability).
    pub fn loads(&self) -> Vec<usize> {
        self.shards.iter().map(|s| s.load.load(Ordering::SeqCst)).collect()
    }

    /// Admit one request or reject with backpressure.  On success the
    /// request is already routed to the least-loaded shard; the returned
    /// tag is its global submission index.
    pub fn try_admit(
        &self,
        prompt: Vec<u16>,
        max_new: usize,
        reply: Sender<Result<GenerationOutput>>,
    ) -> Result<u64> {
        // Reserve a waiting slot with a CAS loop so the boundary is exact
        // even under concurrent submitters.
        let mut cur = self.queued.load(Ordering::SeqCst);
        loop {
            if cur >= self.queue_depth {
                anyhow::bail!("queue full (backpressure)");
            }
            match self.queued.compare_exchange_weak(
                cur,
                cur + 1,
                Ordering::SeqCst,
                Ordering::SeqCst,
            ) {
                Ok(_) => break,
                Err(now) => cur = now,
            }
        }

        // Least-loaded live shard; first index wins ties.  A failed send
        // marks that shard dead and retries the next live one, so a
        // single crashed shard never blackholes admissions while healthy
        // shards have capacity (DESIGN.md §8).
        let mut prompt = prompt;
        let mut reply = reply;
        loop {
            let Some(link) = self
                .shards
                .iter()
                .filter(|s| s.alive.load(Ordering::SeqCst))
                .min_by_key(|s| s.load.load(Ordering::SeqCst))
            else {
                self.queued.fetch_sub(1, Ordering::SeqCst);
                anyhow::bail!("server stopped (no live shards)");
            };
            link.load.fetch_add(1, Ordering::SeqCst);
            let tag = self.next_tag.fetch_add(1, Ordering::SeqCst);
            let sent = link
                .tx
                .lock()
                .expect("dispatch sender poisoned")
                .send(ShardRequest { prompt, max_new, tag, reply });
            match sent {
                Ok(()) => return Ok(tag),
                Err(mpsc::SendError(req)) => {
                    // Shard thread gone: roll its load back, mark it dead,
                    // and re-route the request.
                    link.load.fetch_sub(1, Ordering::SeqCst);
                    link.alive.store(false, Ordering::SeqCst);
                    prompt = req.prompt;
                    reply = req.reply;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reply() -> Sender<Result<GenerationOutput>> {
        mpsc::channel().0
    }

    #[test]
    fn exact_rejection_boundary() {
        // depth D admits exactly D waiting requests; D+1 rejects; freeing
        // one waiting slot admits exactly one more.
        let depth = 3;
        let (d, ctxs) = build(2, depth);
        for i in 0..depth {
            assert!(d.try_admit(vec![1], 2, reply()).is_ok(), "admit {i}");
        }
        assert_eq!(d.queued(), depth);
        let err = d.try_admit(vec![1], 2, reply()).unwrap_err();
        assert!(err.to_string().contains("queue full"), "{err}");
        // a shard pulls one request into its batcher -> one slot frees
        ctxs[0].note_activated();
        assert!(d.try_admit(vec![1], 2, reply()).is_ok());
        assert!(d.try_admit(vec![1], 2, reply()).is_err());
    }

    #[test]
    fn zero_depth_rejects_everything() {
        let (d, _ctxs) = build(1, 0);
        assert!(d.try_admit(vec![1], 2, reply()).is_err());
    }

    #[test]
    fn least_loaded_routing_balances() {
        let (d, ctxs) = build(3, 64);
        for _ in 0..6 {
            d.try_admit(vec![1], 2, reply()).unwrap();
        }
        assert_eq!(d.loads(), vec![2, 2, 2]);
        // completion on shard 1 draws the next request there
        ctxs[1].note_activated();
        ctxs[1].note_done();
        d.try_admit(vec![1], 2, reply()).unwrap();
        assert_eq!(d.loads(), vec![2, 2, 2]);
        // requests actually landed in the right channels
        assert_eq!(ctxs[0].rx.try_iter().count(), 2);
        assert_eq!(ctxs[1].rx.try_iter().count(), 3);
        assert_eq!(ctxs[2].rx.try_iter().count(), 2);
    }

    #[test]
    fn tags_are_submission_ordered() {
        let (d, _ctxs) = build(2, 8);
        let t0 = d.try_admit(vec![1], 1, reply()).unwrap();
        let t1 = d.try_admit(vec![2], 1, reply()).unwrap();
        assert_eq!((t0, t1), (0, 1));
    }

    #[test]
    fn dead_shard_rolls_back_counters() {
        let (d, ctxs) = build(1, 4);
        drop(ctxs); // receiver gone
        let err = d.try_admit(vec![1], 2, reply()).unwrap_err();
        assert!(err.to_string().contains("no live shards"), "{err}");
        assert_eq!(d.queued(), 0);
        assert_eq!(d.loads(), vec![0]);
    }

    #[test]
    fn routing_skips_dead_shard() {
        // One crashed shard must not blackhole admissions: sends that hit
        // its closed channel re-route to the live shard.
        let (d, mut ctxs) = build(2, 16);
        let live = ctxs.remove(1);
        drop(ctxs); // shard 0's receiver gone (thread died)
        for _ in 0..4 {
            d.try_admit(vec![1], 2, reply()).unwrap();
        }
        assert_eq!(live.rx.try_iter().count(), 4, "requests lost");
        assert_eq!(d.loads()[0], 0, "dead shard holds phantom load");
        assert_eq!(d.loads()[1], 4);
        assert_eq!(d.queued(), 4);
    }
}
