//! Serving front-end: a sharded engine pool behind one admission point
//! (DESIGN.md §8).
//!
//! [`Server::start`] spawns `cfg.scheduler.shards` serving threads.  Each
//! shard owns a full engine stack — an [`Engine`] (and therefore its own
//! PJRT executables and plane-compression worker pool), plus a
//! [`ContinuousBatcher`] interleaving up to `max_batch` sessions.  Engines
//! are constructed *inside* their shard thread (PJRT executables are not
//! `Send`), and a startup barrier reports construction failures from
//! `Server::start` itself.
//!
//! Requests flow through the private dispatcher module: one global
//! `queue_depth` boundary decides accept/reject at submit time, then the
//! request is routed to the least-loaded shard.  A shard pulls a waiting
//! request only when it has a free decode slot, so no second queue ever
//! stacks on the configured depth.  Per-tag outputs are independent of
//! shard count and placement because sessions are independent and seeds
//! derive from request content (`coordinator::engine::request_seed`).
//!
//! Offline-build note: the environment ships no async runtime, so this is
//! a blocking-channel design (std::sync::mpsc) rather than tokio; the
//! public shape — submit returns a waitable handle, requests interleave
//! through per-shard continuous batchers — is the same (DESIGN.md §6).

mod dispatch;
pub mod loadgen;

use std::sync::mpsc::{self, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::config::EngineConfig;
use crate::coordinator::batcher::{ContinuousBatcher, QueuedRequest};
use crate::coordinator::{Engine, GenerationOutput};
use crate::kvcache::{worst_case_resident_bytes, CacheLayout};
use crate::metrics::{EngineMetrics, MetricsSnapshot};
use crate::Result;

use dispatch::{Dispatcher, ShardCtx, ShardRequest};

/// A waitable response slot for one submitted request.
pub struct ResponseHandle {
    rx: Receiver<Result<GenerationOutput>>,
    tag: u64,
}

impl ResponseHandle {
    /// Block until the generation completes.
    pub fn wait(self) -> Result<GenerationOutput> {
        self.rx
            .recv()
            .map_err(|_| anyhow::anyhow!("server dropped request"))?
    }

    /// Global submission-order tag of this request (diagnostics).
    pub fn tag(&self) -> u64 {
        self.tag
    }
}

/// Handle to a running server; cloneable, cheap to share across threads.
#[derive(Clone)]
pub struct ServerHandle {
    dispatcher: Arc<Dispatcher>,
    metrics: Arc<Vec<Mutex<EngineMetrics>>>,
    /// Cache shape, for submit-time validation and the worst-case
    /// byte-footprint bound the budget admission reserves (DESIGN.md §10).
    layout: CacheLayout,
    /// Streaming recompression period (sizes the worst-case fp32 tail).
    recompress_every: usize,
}

impl ServerHandle {
    /// Submit one generation request; returns a waitable handle.
    /// Errors immediately when the admission queue is full (backpressure),
    /// no shard can hold the request's worst-case byte footprint (memory
    /// budget), or the request is malformed (`max_new == 0`, empty
    /// prompt, window overflow).
    pub fn submit(&self, prompt: Vec<u16>, max_new: usize) -> Result<ResponseHandle> {
        // Validate the full session-start contract at admission so a bad
        // request is a submit-time error, never a poisoned shard: these
        // mirror the `ensure!`s in `Engine::start_session`, whose failure
        // inside a shard would tear the whole shard down (DESIGN.md §8).
        anyhow::ensure!(!prompt.is_empty(), "empty prompt");
        anyhow::ensure!(max_new >= 1, "max_new must be >= 1");
        anyhow::ensure!(
            prompt.len() + max_new <= self.layout.seq,
            "prompt {} + budget {max_new} exceeds window {}",
            prompt.len(),
            self.layout.seq
        );
        let wc = worst_case_resident_bytes(self.layout, prompt.len() + max_new,
                                           self.recompress_every);
        let (reply, rx) = mpsc::channel();
        let tag = self.dispatcher.try_admit(prompt, max_new, wc, reply)?;
        Ok(ResponseHandle { rx, tag })
    }

    /// Submit and wait (convenience).
    pub fn generate(&self, prompt: Vec<u16>, max_new: usize) -> Result<GenerationOutput> {
        self.submit(prompt, max_new)?.wait()
    }

    /// Number of engine shards serving this handle.
    pub fn shards(&self) -> usize {
        self.dispatcher.shard_count()
    }

    /// Requests currently waiting for a decode slot.
    pub fn queued(&self) -> usize {
        self.dispatcher.queued()
    }

    /// Per-shard in-flight request counts (waiting + active), in shard
    /// index order.
    pub fn shard_loads(&self) -> Vec<usize> {
        self.dispatcher.loads()
    }

    /// Per-shard live resident bytes as last published by each shard's
    /// batcher (DESIGN.md §10), in shard index order.
    pub fn shard_resident_bytes(&self) -> Vec<usize> {
        self.dispatcher.resident_bytes()
    }

    /// Per-shard worst-case bytes currently reserved against the memory
    /// budget (always 0 when `memory.budget_bytes = 0`).
    pub fn shard_reserved_bytes(&self) -> Vec<usize> {
        self.dispatcher.reserved_bytes()
    }

    /// A coherent metrics read: per-shard engine metrics (as last
    /// published by each shard) plus their aggregate.  Lock-cheap: one
    /// uncontended per-shard mutex clone each, no stop-the-world.
    pub fn metrics(&self) -> MetricsSnapshot {
        let per_shard: Vec<EngineMetrics> = self
            .metrics
            .iter()
            .map(|slot| slot.lock().expect("metrics slot poisoned").clone())
            .collect();
        MetricsSnapshot::aggregate(per_shard)
    }
}

/// A running server: shard threads + dispatch state.
pub struct Server {
    pub handle: ServerHandle,
    joins: Vec<JoinHandle<Result<()>>>,
}

impl Server {
    /// Start the shard pool.  `cfg.scheduler.shards == 0` means one shard
    /// per available core.  Fails fast if any shard's engine cannot be
    /// constructed (bad artifacts dir, unknown model, ...).
    pub fn start(cfg: EngineConfig) -> Result<Self> {
        cfg.validate()?;
        // Model shape for submit-time validation and worst-case byte
        // bounds (cheap: manifest read or sim registry, no compilation)
        // — also fails fast here when the artifacts dir is unreadable,
        // before any thread spawns.
        let layout = crate::runtime::load_model_info(&cfg.artifacts_dir, &cfg.model)?
            .cache_layout();
        let n_shards = if cfg.scheduler.shards == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            cfg.scheduler.shards
        };
        let (dispatcher, ctxs) = dispatch::build(n_shards, cfg.scheduler.queue_depth,
                                                 cfg.memory.budget_bytes);
        let metrics: Arc<Vec<Mutex<EngineMetrics>>> = Arc::new(
            (0..n_shards).map(|_| Mutex::new(EngineMetrics::default())).collect(),
        );
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();

        let mut joins = Vec::with_capacity(n_shards);
        for (i, ctx) in ctxs.into_iter().enumerate() {
            let cfg = cfg.clone();
            let ready = ready_tx.clone();
            let slot = metrics.clone();
            joins.push(
                std::thread::Builder::new()
                    .name(format!("zipcache-shard-{i}"))
                    .spawn(move || shard_loop(i, cfg, ctx, slot, ready))?,
            );
        }
        drop(ready_tx);

        // Startup barrier: every shard reports engine construction.
        let mut startup_err = None;
        for _ in 0..n_shards {
            match ready_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => startup_err = Some(e),
                Err(_) => {
                    startup_err =
                        Some(anyhow::anyhow!("shard thread died during startup"))
                }
            }
        }
        if let Some(e) = startup_err {
            // Tear down: dropping the dispatcher closes every shard
            // channel, so the healthy shards exit their loops.
            drop(dispatcher);
            for j in joins {
                let _ = j.join();
            }
            return Err(e);
        }

        Ok(Server {
            handle: ServerHandle {
                dispatcher: Arc::new(dispatcher),
                metrics,
                layout,
                recompress_every: cfg.quant.recompress_every,
            },
            joins,
        })
    }

    /// Graceful shutdown: close the admission side and join every shard
    /// (in-flight requests complete first).  Any outstanding
    /// [`ServerHandle`] clones must be dropped by their owners for the
    /// shards to observe disconnection.
    pub fn shutdown(self) -> Result<()> {
        drop(self.handle);
        let mut result = Ok(());
        for j in self.joins {
            match j.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => result = Err(e),
                Err(_) => result = Err(anyhow::anyhow!("shard thread panicked")),
            }
        }
        result
    }
}

/// One shard: engine + continuous batcher + reply routing.
///
/// Error altitude: requests that could fail `Engine::start_session` are
/// rejected at submit time (see `ServerHandle::submit`), so a `?` out of
/// `batcher.step` here means the *engine itself* failed (PJRT execute
/// error, artifact corruption) — that shard exits with the error and its
/// in-flight clients see "server dropped request", while other shards
/// keep serving.  The seed's single-engine-thread design lost the whole
/// server in that case; per-request error outcomes through the batcher
/// are a possible future refinement (DESIGN.md §8).
fn shard_loop(
    shard_idx: usize,
    cfg: EngineConfig,
    ctx: ShardCtx,
    slots: Arc<Vec<Mutex<EngineMetrics>>>,
    ready: Sender<Result<()>>,
) -> Result<()> {
    let max_batch = cfg.scheduler.max_batch;
    let mut engine = match Engine::new(cfg) {
        Ok(e) => {
            let _ = ready.send(Ok(()));
            e
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return Ok(()); // failure already reported through the barrier
        }
    };
    // The batcher's own queue is a staging slot only: requests are pulled
    // from the shard channel exclusively when a decode slot is free, so
    // its depth never rejects and never stacks on the dispatcher's
    // boundary (DESIGN.md §8).
    let mut batcher = ContinuousBatcher::new(max_batch, max_batch);
    let mut replies: Vec<ReplySlot> = Vec::new();

    loop {
        // Pull waiting requests while decode slots are free.
        while batcher.active() + batcher.pending() < max_batch {
            match ctx.rx.try_recv() {
                Ok(req) => admit(&mut batcher, &mut replies, req, &ctx),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    // Shutdown: finish in-flight work, publish, exit.
                    while !batcher.idle() {
                        batcher.step(&mut engine)?;
                        deliver(&mut batcher, &mut replies, &ctx, &engine,
                                &slots[shard_idx]);
                    }
                    ctx.publish_resident(0);
                    publish(&slots[shard_idx], &engine);
                    return Ok(());
                }
            }
        }
        if batcher.idle() {
            // Idle: publish metrics, then block for the next request.
            ctx.publish_resident(0);
            publish(&slots[shard_idx], &engine);
            match ctx.rx.recv() {
                Ok(req) => {
                    admit(&mut batcher, &mut replies, req, &ctx);
                    continue;
                }
                Err(_) => return Ok(()),
            }
        }
        batcher.step(&mut engine)?;
        // Routing weight (DESIGN.md §10): the dispatcher breaks load
        // ties by these live resident bytes, so publish every iteration.
        ctx.publish_resident(batcher.active_bytes());
        deliver(&mut batcher, &mut replies, &ctx, &engine, &slots[shard_idx]);
    }
}

/// One in-flight request's reply channel plus the worst-case byte
/// reservation to release when it completes.
struct ReplySlot {
    tag: u64,
    reserved_bytes: usize,
    reply: Sender<Result<GenerationOutput>>,
}

/// Move a pulled request into the batcher and register its reply slot.
fn admit(
    batcher: &mut ContinuousBatcher,
    replies: &mut Vec<ReplySlot>,
    req: ShardRequest,
    ctx: &ShardCtx,
) {
    ctx.note_activated();
    match batcher.submit(QueuedRequest {
        prompt: req.prompt,
        max_new: req.max_new,
        tag: req.tag,
    }) {
        Ok(()) => replies.push(ReplySlot {
            tag: req.tag,
            reserved_bytes: req.reserved_bytes,
            reply: req.reply,
        }),
        Err(_) => {
            // Unreachable by construction (pulls are slot-gated), but do
            // not let an accounting bug hang the client.
            let _ = req
                .reply
                .send(Err(anyhow::anyhow!("internal: shard batcher rejected")));
            ctx.note_done(req.reserved_bytes);
        }
    }
}

/// Send finished outcomes to their callers.  Metrics are published
/// *before* the replies go out, so any client whose `wait()` returned is
/// guaranteed to see its own request in the next snapshot.
fn deliver(
    batcher: &mut ContinuousBatcher,
    replies: &mut Vec<ReplySlot>,
    ctx: &ShardCtx,
    engine: &Engine,
    slot: &Mutex<EngineMetrics>,
) {
    let outcomes = batcher.take_outcomes();
    if outcomes.is_empty() {
        return;
    }
    publish(slot, engine);
    for outcome in outcomes {
        // Release accounting (load + byte reservation) *before* the
        // reply goes out, like the metrics publish above: a client whose
        // `wait()` has returned must observe its reservation gone.
        match replies.iter().position(|r| r.tag == outcome.tag) {
            Some(idx) => {
                let r = replies.swap_remove(idx);
                ctx.note_done(r.reserved_bytes);
                let _ = r.reply.send(Ok(outcome.output));
            }
            None => ctx.note_done(0),
        }
    }
}

/// Publish this shard's engine metrics into its shared snapshot slot.
///
/// This clones the full `EngineMetrics`, whose histograms keep every
/// sample — per-delivery cost therefore grows with run length.  Fine at
/// bench/test scale (exact percentiles are worth it); switching the
/// recorders to fixed-bucket histograms is the knob to turn if serving
/// runs ever get long enough for this clone to show up in a profile.
fn publish(slot: &Mutex<EngineMetrics>, engine: &Engine) {
    *slot.lock().expect("metrics slot poisoned") = engine.metrics.clone();
}
