//! Serving front-end: a sharded engine pool behind one admission point
//! (DESIGN.md §8), speaking the typed request/response API
//! (DESIGN.md §11).
//!
//! [`Server::start`] spawns `cfg.scheduler.shards` serving threads.  Each
//! shard owns a full engine stack — an [`Engine`] (and therefore its own
//! PJRT executables and plane-compression worker pool), plus a
//! [`ContinuousBatcher`] interleaving up to `max_batch` sessions.  Engines
//! are constructed *inside* their shard thread (PJRT executables are not
//! `Send`), and a startup barrier reports construction failures from
//! `Server::start` itself.
//!
//! Requests are [`GenerationRequest`]s (priority class, optional
//! deadline, per-request quant/seed overrides, stop tokens) and flow
//! through the private dispatcher module: one global `queue_depth`
//! boundary decides accept/reject at submit time, then the request is
//! routed to the least-loaded shard.  Inside a shard, waiting requests
//! stage in the batcher's *priority-ordered* queue; the global waiting
//! count is decremented only when a request actually leaves that queue
//! (decode slot granted, deadline shed, or cancelled at pop), so the
//! configured depth stays the exact rejection boundary (DESIGN.md §8).
//! Per-tag outputs are independent of shard count and placement because
//! sessions are independent and seeds derive from request content
//! (`coordinator::engine::request_seed`).
//!
//! Responses stream: a [`ResponseHandle`] yields tokens incrementally as
//! the batcher emits them ([`ResponseHandle::next_token`], or iterate the
//! handle), supports [`ResponseHandle::cancel`], and resolves to a
//! [`GenerationResponse`] carrying a
//! [`FinishReason`](crate::coordinator::FinishReason).
//!
//! **Failure model (DESIGN.md §14).**  A shard that panics, returns an
//! engine error, or wedges on an injected stall dies *cleanly*: its
//! fatal path releases every global waiting slot, per-shard load count,
//! and byte reservation it held, answers its live sessions with
//! [`FinishReason::ShardFailed`](crate::coordinator::FinishReason)
//! (carrying the tokens streamed so far — at-most-once streams, never
//! resumed), and hands its still-waiting requests to the supervisor.
//! The supervisor redelivers those to live shards — content-derived
//! seeds make the redelivered outputs bit-identical to the fault-free
//! run — and restarts the dead shard with a fresh engine on capped
//! exponential backoff.  Stalls are detected by a per-shard heartbeat
//! the supervisor polls; a frozen heartbeat with in-flight load gets the
//! shard severed, which drains it through the same fatal path.
//!
//! Offline-build note: the environment ships no async runtime, so this is
//! a blocking-channel design (std::sync::mpsc) rather than tokio; the
//! public shape — submit returns a streamable handle, requests interleave
//! through per-shard continuous batchers — is the same (DESIGN.md §6).

mod dispatch;
pub mod loadgen;

use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::{Arc, Mutex, MutexGuard, Weak};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::config::{EngineConfig, PolicyKind};
use crate::coordinator::batcher::{ContinuousBatcher, PriorityPark, QueuedRequest};
use crate::coordinator::request::{CancelToken, FinishReason, GenerationRequest,
                                  GenerationResponse};
use crate::coordinator::Engine;
use crate::kvcache::prefix_store::DEFAULT_GRANULE;
use crate::kvcache::{prefix_reservation_shrink, worst_case_resident_bytes,
                     CacheLayout, PrefixStore};
use crate::metrics::{EngineMetrics, MetricsSnapshot};
use crate::Result;

use dispatch::{AdmitRequest, Dispatcher, ShardCtx, ShardRequest};

/// One streamed event on a request's reply channel: an incremental token
/// or the final response.  Tokens always precede their `Done`, and their
/// concatenation equals `GenerationResponse::tokens` exactly — except
/// for [`FinishReason::ShardFailed`], where the streamed tokens are a
/// *prefix* of the final `tokens` (a token decoded in the iteration the
/// shard died may reach the final response without having streamed).
pub(crate) enum ResponseEvent {
    Token(u16),
    Done(Result<GenerationResponse>),
}

/// A streamable response slot for one submitted request (DESIGN.md §11).
///
/// Consume incrementally with [`ResponseHandle::next_token`] (or by
/// iterating: `for tok in &mut handle { .. }`), then finish with
/// [`ResponseHandle::wait`]; or call `wait()` directly to block until
/// completion.  [`ResponseHandle::cancel`] requests cancellation — the
/// shard retires the session at its next scheduler iteration, releasing
/// its dense slot and byte-budget reservation immediately, and the final
/// response arrives with
/// [`FinishReason::Cancelled`](crate::coordinator::FinishReason::Cancelled)
/// carrying the tokens
/// generated so far.
pub struct ResponseHandle {
    rx: Receiver<ResponseEvent>,
    tag: u64,
    cancel: CancelToken,
    /// Final result observed while streaming, stashed for `wait()`.
    done: Option<Result<GenerationResponse>>,
}

impl ResponseHandle {
    /// Block for the next streamed token; `None` once the generation has
    /// finished (then [`ResponseHandle::wait`] returns the final
    /// response without blocking).
    pub fn next_token(&mut self) -> Option<u16> {
        if self.done.is_some() {
            return None;
        }
        match self.rx.recv() {
            Ok(ResponseEvent::Token(t)) => Some(t),
            Ok(ResponseEvent::Done(r)) => {
                self.done = Some(r);
                None
            }
            Err(_) => {
                self.done = Some(Err(anyhow::anyhow!("server dropped request")));
                None
            }
        }
    }

    /// Block until the generation completes (draining any unread
    /// streamed tokens — they are a prefix of the final `tokens`).
    pub fn wait(mut self) -> Result<GenerationResponse> {
        while self.done.is_none() {
            self.next_token();
        }
        self.done.take().expect("loop exits only once done is set")
    }

    /// Request cancellation (idempotent).  Safe at any point in the
    /// request lifecycle: waiting requests retire at pop time, active
    /// sessions at the next scheduler iteration.
    pub fn cancel(&self) {
        self.cancel.cancel();
    }

    /// Global submission-order tag of this request (diagnostics).
    pub fn tag(&self) -> u64 {
        self.tag
    }
}

impl Iterator for ResponseHandle {
    type Item = u16;

    fn next(&mut self) -> Option<u16> {
        self.next_token()
    }
}

/// Handle to a running server; cloneable, cheap to share across threads.
#[derive(Clone)]
pub struct ServerHandle {
    dispatcher: Arc<Dispatcher>,
    metrics: Arc<Vec<Mutex<EngineMetrics>>>,
    /// Cache shape, for submit-time validation and the worst-case
    /// byte-footprint bound the budget admission reserves (DESIGN.md §10).
    layout: CacheLayout,
    /// Streaming recompression period (sizes the worst-case fp32 tail).
    recompress_every: usize,
    /// Per-covered-token reservation discount on a prefix hit
    /// (DESIGN.md §16): [`prefix_reservation_shrink`] when the prefix
    /// store is on *and* the policy's payload bound supports it
    /// (all-quantized policies — Gear/Mikv/Zipcache), else 0.
    shrink_per_token: usize,
}

impl ServerHandle {
    /// Submit one typed generation request; returns a streamable handle.
    /// Errors immediately when the admission queue is full (backpressure),
    /// no shard can hold the request's worst-case byte footprint (memory
    /// budget), or the request fails the shared
    /// [`GenerationRequest::validate`] contract — the same check
    /// `Engine::start_session` applies, so a bad request is a submit-time
    /// error, never a poisoned shard (DESIGN.md §8, §11).
    pub fn submit_request(&self, req: GenerationRequest) -> Result<ResponseHandle> {
        req.validate(self.layout.seq)?;
        // Worst-case resident footprint for the budget reservation.  The
        // bound is conservative for *any* admissible quant override: its
        // payload term charges fp16 (2 B/value), which dominates every
        // override width (max 8 bits), and its param term already assumes
        // the densest class mix — see `worst_case_resident_bytes`.
        let wc = worst_case_resident_bytes(self.layout,
                                           req.prompt.len() + req.max_new,
                                           self.recompress_every);
        let cancel = req.cancel.clone();
        let (reply, rx) = mpsc::channel();
        let tag = self.dispatcher.try_admit(AdmitRequest {
            request: req,
            wc_bytes: wc,
            shrink_per_token: self.shrink_per_token,
            reply,
        })?;
        Ok(ResponseHandle { rx, tag, cancel, done: None })
    }

    /// Legacy positional submit: a thin wrapper over builder defaults
    /// (DESIGN.md §11) — bit-identical to the pre-§11 path.
    pub fn submit(&self, prompt: Vec<u16>, max_new: usize) -> Result<ResponseHandle> {
        self.submit_request(GenerationRequest::new(prompt, max_new))
    }

    /// Submit and wait (convenience).
    pub fn generate(&self, prompt: Vec<u16>, max_new: usize)
                    -> Result<GenerationResponse> {
        self.submit(prompt, max_new)?.wait()
    }

    /// Number of engine shards serving this handle.
    pub fn shards(&self) -> usize {
        self.dispatcher.shard_count()
    }

    /// Requests currently waiting for a decode slot.
    pub fn queued(&self) -> usize {
        self.dispatcher.queued()
    }

    /// Per-shard in-flight request counts (waiting + active), in shard
    /// index order.
    pub fn shard_loads(&self) -> Vec<usize> {
        self.dispatcher.loads()
    }

    /// Per-shard live resident bytes as last published by each shard's
    /// batcher (DESIGN.md §10), in shard index order.
    pub fn shard_resident_bytes(&self) -> Vec<usize> {
        self.dispatcher.resident_bytes()
    }

    /// Per-shard worst-case bytes currently reserved against the memory
    /// budget (always 0 when `memory.budget_bytes = 0`).
    pub fn shard_reserved_bytes(&self) -> Vec<usize> {
        self.dispatcher.reserved_bytes()
    }

    /// Per-shard liveness (DESIGN.md §14): `false` while a shard is dead
    /// or restarting, `true` once it serves again.
    pub fn shard_alive(&self) -> Vec<bool> {
        self.dispatcher.alive_flags()
    }

    /// A coherent metrics read: per-shard engine metrics (as last
    /// published by each shard) plus their aggregate.  Lock-cheap: one
    /// uncontended per-shard mutex clone each, no stop-the-world.
    pub fn metrics(&self) -> MetricsSnapshot {
        let per_shard: Vec<EngineMetrics> = self
            .metrics
            .iter()
            .map(|slot| lock_metrics(slot).clone())
            .collect();
        MetricsSnapshot::aggregate(per_shard)
    }
}

/// Lock a metrics slot, recovering from poisoning (DESIGN.md §14): a
/// shard that panicked while publishing must not take the whole metrics
/// surface down with it.  The inner value is a plain counter struct that
/// is coherent at every assignment, so the poisoned guard is safe to
/// adopt.
fn lock_metrics(slot: &Mutex<EngineMetrics>) -> MutexGuard<'_, EngineMetrics> {
    slot.lock().unwrap_or_else(|e| e.into_inner())
}

/// A running server: shard threads + supervisor + dispatch state.
pub struct Server {
    pub handle: ServerHandle,
    /// The supervisor's join handle; the supervisor itself owns (and
    /// joins) every shard thread (DESIGN.md §14).
    joins: Vec<JoinHandle<Result<()>>>,
}

impl Server {
    /// Start the shard pool.  `cfg.scheduler.shards == 0` means one shard
    /// per available core.  Fails fast if any shard's engine cannot be
    /// constructed (bad artifacts dir, unknown model, ...).
    pub fn start(cfg: EngineConfig) -> Result<Self> {
        cfg.validate()?;
        // Model shape for submit-time validation and worst-case byte
        // bounds (cheap: manifest read or sim registry, no compilation)
        // — also fails fast here when the artifacts dir is unreadable,
        // before any thread spawns.
        let layout = crate::runtime::load_model_info(&cfg.artifacts_dir, &cfg.model)?
            .cache_layout();
        let n_shards = if cfg.scheduler.shards == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            cfg.scheduler.shards
        };
        let (mut dispatcher, ctxs) = dispatch::build(n_shards,
                                                     cfg.scheduler.queue_depth,
                                                     cfg.memory.budget_bytes);
        // Per-shard prefix stores live on the dispatcher, not in the
        // engines, so interned segments survive shard respawns
        // (DESIGN.md §16).  On a backend without the chunked entries the
        // engines never attach, so the stores stay empty and routing is
        // unchanged (probe 0, shared bytes 0).
        if cfg.prefix.enable {
            let granule = if cfg.scheduler.prefill_chunk > 0 {
                cfg.scheduler.prefill_chunk
            } else {
                DEFAULT_GRANULE
            };
            dispatcher.set_prefix_stores(
                (0..n_shards)
                    .map(|_| PrefixStore::new(&cfg.model, cfg.policy, granule,
                                              cfg.prefix.max_bytes))
                    .collect(),
            );
        }
        let dispatcher = Arc::new(dispatcher);
        let metrics: Arc<Vec<Mutex<EngineMetrics>>> = Arc::new(
            (0..n_shards).map(|_| Mutex::new(EngineMetrics::default())).collect(),
        );
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let (event_tx, event_rx) = mpsc::channel::<ShardFatal>();

        let mut joins = Vec::with_capacity(n_shards);
        for (i, ctx) in ctxs.into_iter().enumerate() {
            let cfg = cfg.clone();
            let ready = ready_tx.clone();
            let slot = metrics.clone();
            let events = event_tx.clone();
            let pstore = dispatcher.prefix_store(i).cloned();
            joins.push(
                std::thread::Builder::new()
                    .name(format!("zipcache-shard-{i}"))
                    .spawn(move || {
                        shard_loop(i, 0, cfg, ctx, slot,
                                   EngineMetrics::default(), pstore,
                                   ready, events)
                    })?,
            );
        }
        drop(ready_tx);

        // Startup barrier: every shard reports engine construction.
        let mut startup_err = None;
        for _ in 0..n_shards {
            match ready_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => startup_err = Some(e),
                Err(_) => {
                    startup_err =
                        Some(anyhow::anyhow!("shard thread died during startup"))
                }
            }
        }
        if let Some(e) = startup_err {
            // Tear down: dropping the dispatcher closes every shard
            // channel, so the healthy shards exit their loops.
            drop(dispatcher);
            for j in joins {
                let _ = j.join();
            }
            return Err(e);
        }

        // The supervisor (DESIGN.md §14) owns the shard join handles: it
        // joins the dead on fatal events, respawns them after backoff,
        // and joins everything at shutdown.  It holds the dispatcher
        // weakly, so dropping the last handle still closes every shard
        // channel — a failed upgrade *is* the shutdown signal.
        let supervisor = Supervisor {
            cfg: cfg.clone(),
            dispatcher: Arc::downgrade(&dispatcher),
            metrics: metrics.clone(),
            events: event_rx,
            event_tx,
            joins: joins.into_iter().map(Some).collect(),
            generations: vec![0; n_shards],
            attempts: vec![0; n_shards],
            hb_last: vec![0; n_shards],
            hb_frozen: vec![0; n_shards],
            pending: Vec::new(),
        };
        let sup = std::thread::Builder::new()
            .name("zipcache-supervisor".into())
            .spawn(move || supervisor.run())?;

        let shrink_eligible = matches!(
            cfg.policy,
            PolicyKind::Gear | PolicyKind::Mikv | PolicyKind::Zipcache
        );
        Ok(Server {
            handle: ServerHandle {
                dispatcher,
                metrics,
                layout,
                recompress_every: cfg.quant.recompress_every,
                shrink_per_token: if cfg.prefix.enable && shrink_eligible {
                    prefix_reservation_shrink(layout)
                } else {
                    0
                },
            },
            joins: vec![sup],
        })
    }

    /// Graceful shutdown: close the admission side, let the supervisor
    /// observe it (weak-upgrade failure) and join every shard (in-flight
    /// requests complete first), then join the supervisor.  Any
    /// outstanding [`ServerHandle`] clones must be dropped by their
    /// owners for the shards to observe disconnection.
    pub fn shutdown(self) -> Result<()> {
        drop(self.handle);
        let mut result = Ok(());
        for j in self.joins {
            match j.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => result = Err(e),
                Err(_) => result = Err(anyhow::anyhow!("server thread panicked")),
            }
        }
        result
    }
}

/// Death notice from a shard's fatal path to the supervisor
/// (DESIGN.md §14).
struct ShardFatal {
    shard: usize,
    /// Incarnation counter, so a stale notice from a previous thread of
    /// the same shard index can never double-restart it.
    generation: u64,
    error: String,
    /// Requests that were still *waiting* on the dead shard (staged or
    /// in its channel backlog): no tokens streamed, so the supervisor
    /// resubmits them and their content-derived seeds reproduce the
    /// fault-free outputs bit-for-bit.
    redeliver: Vec<ShardRequest>,
    /// Live sessions answered with `ShardFailed` by the fatal path.
    failed_sessions: u64,
}

/// Restart ticket: a dead shard waiting out its backoff.
struct PendingRestart {
    shard: usize,
    due: Instant,
}

/// The shard supervisor (DESIGN.md §14): consumes [`ShardFatal`] events,
/// redelivers the dead shard's waiting requests, restarts shards with
/// capped exponential backoff, and severs shards whose heartbeat froze
/// with load still in flight (injected stalls, runaway steps).
struct Supervisor {
    cfg: EngineConfig,
    dispatcher: Weak<Dispatcher>,
    metrics: Arc<Vec<Mutex<EngineMetrics>>>,
    events: Receiver<ShardFatal>,
    /// Template sender cloned into every respawned shard.
    event_tx: Sender<ShardFatal>,
    joins: Vec<Option<JoinHandle<Result<()>>>>,
    generations: Vec<u64>,
    /// Restart attempts per shard (drives the backoff exponent and the
    /// `max_restarts` cap).
    attempts: Vec<u64>,
    hb_last: Vec<u64>,
    /// Consecutive polls the shard's heartbeat stayed frozen with
    /// in-flight load; reaching `faults.stall_ticks` severs it.
    hb_frozen: Vec<u64>,
    pending: Vec<PendingRestart>,
}

impl Supervisor {
    fn run(mut self) -> Result<()> {
        let poll = Duration::from_millis(self.cfg.faults.poll_ms.max(1));
        loop {
            match self.events.recv_timeout(poll) {
                Ok(ev) => self.on_fatal(ev),
                Err(RecvTimeoutError::Timeout) => {}
                // Unreachable (we hold `event_tx`), but treat it as
                // shutdown rather than spinning.
                Err(RecvTimeoutError::Disconnected) => break,
            }
            let Some(d) = self.dispatcher.upgrade() else {
                break; // every handle dropped: shutdown
            };
            self.scan_stalls(&d);
            self.restart_due(&d);
        }
        // Shutdown: the shard channels are closed (the dispatcher is
        // gone), so every live loop drains and exits on its own.
        let mut result = Ok(());
        for j in self.joins.iter_mut().filter_map(Option::take) {
            match j.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => result = Err(e),
                Err(_) => {
                    result = Err(anyhow::anyhow!("shard thread panicked at shutdown"))
                }
            }
        }
        result
    }

    /// A shard died: join its thread, redeliver what it was holding, and
    /// schedule its restart.  The fatal path already released all of the
    /// shard's accounting and answered its live sessions; redelivered
    /// requests keep their global waiting slot, so the queue-depth
    /// boundary is unchanged throughout.
    fn on_fatal(&mut self, ev: ShardFatal) {
        let ShardFatal { shard, generation, error, redeliver, failed_sessions } = ev;
        if generation != self.generations[shard] {
            return; // stale notice from an already-replaced incarnation
        }
        if let Some(j) = self.joins[shard].take() {
            // The thread's Err already drained through its fatal path;
            // clients were answered there, nothing left to propagate.
            let _ = j.join();
        }
        let Some(d) = self.dispatcher.upgrade() else {
            // Shutting down: dropping the redelivery packets drops their
            // reply senders, so waiting clients unblock with an error.
            return;
        };
        let mut redelivered = 0u64;
        let mut failed = failed_sessions;
        for req in redeliver {
            let tag = req.tag;
            let reply = req.reply.clone();
            match d.redeliver(req) {
                Ok(()) => redelivered += 1,
                Err(_) => {
                    // No live shard can take it: answer the client
                    // directly and drain its waiting slot here.
                    failed += 1;
                    d.release_queued(1);
                    let _ = reply.send(ResponseEvent::Done(Ok(
                        GenerationResponse::without_session(
                            tag, FinishReason::ShardFailed),
                    )));
                }
            }
        }
        {
            let mut m = lock_metrics(&self.metrics[shard]);
            m.redelivered += redelivered;
            m.failed_sessions += failed;
        }
        eprintln!(
            "zipcache-supervisor: shard {shard} failed ({error}); \
             redelivered {redelivered}, failed sessions {failed}"
        );
        self.schedule_restart(shard);
    }

    fn schedule_restart(&mut self, shard: usize) {
        let f = &self.cfg.faults;
        let attempt = self.attempts[shard];
        if f.max_restarts > 0 && attempt >= f.max_restarts {
            eprintln!(
                "zipcache-supervisor: shard {shard} hit max_restarts={}; \
                 leaving it dead",
                f.max_restarts
            );
            return;
        }
        // Capped exponential backoff: base * 2^attempt, clamped.
        let backoff = f
            .backoff_base_ms
            .saturating_mul(1u64 << attempt.min(20))
            .min(f.backoff_cap_ms);
        self.pending.push(PendingRestart {
            shard,
            due: Instant::now() + Duration::from_millis(backoff),
        });
    }

    /// Sever shards whose heartbeat froze with load in flight
    /// (DESIGN.md §14).  Severing flips the shard's `alive` flag
    /// proactively — routing stops *before* the wedged thread notices —
    /// and swaps its channel sender for a disconnected one, so the
    /// thread's blocking `recv` fails and it drains through the normal
    /// fatal path (which raises the [`ShardFatal`] we then act on).
    fn scan_stalls(&mut self, d: &Arc<Dispatcher>) {
        let stall_ticks = self.cfg.faults.stall_ticks;
        let hbs = d.heartbeats();
        let loads = d.loads();
        let alive = d.alive_flags();
        for i in 0..hbs.len() {
            if !alive[i] {
                // Dead or restarting: not our patient.
                self.hb_frozen[i] = 0;
                self.hb_last[i] = hbs[i];
                continue;
            }
            if hbs[i] == self.hb_last[i] && loads[i] > 0 {
                self.hb_frozen[i] += 1;
            } else {
                self.hb_frozen[i] = 0;
            }
            self.hb_last[i] = hbs[i];
            if self.hb_frozen[i] >= stall_ticks {
                self.hb_frozen[i] = 0;
                eprintln!(
                    "zipcache-supervisor: shard {i} heartbeat frozen for \
                     {stall_ticks} polls with load {}; severing",
                    loads[i]
                );
                d.sever(i);
            }
        }
    }

    fn restart_due(&mut self, d: &Arc<Dispatcher>) {
        let now = Instant::now();
        let mut i = 0;
        while i < self.pending.len() {
            if self.pending[i].due > now {
                i += 1;
                continue;
            }
            let shard = self.pending.swap_remove(i).shard;
            self.respawn(d, shard);
        }
    }

    /// Spawn a fresh engine thread for a dead shard.  The new thread
    /// publishes `base` merged with its live engine metrics, so counters
    /// survive the restart; `alive` flips back only after the thread's
    /// ready barrier, so no request can race into a channel whose engine
    /// is still constructing.
    fn respawn(&mut self, d: &Arc<Dispatcher>, shard: usize) {
        self.attempts[shard] += 1;
        self.generations[shard] += 1;
        let generation = self.generations[shard];
        let ctx = d.revive(shard);
        let base = {
            let mut m = lock_metrics(&self.metrics[shard]);
            m.shard_restarts += 1;
            let mut b = m.clone();
            // Store-derived *snapshots* (not counters): the prefix store
            // outlives the dead engine, and the fresh engine republishes
            // them from that same store — keeping the old values in the
            // base would double-count them in every post-restart publish
            // (DESIGN.md §16).
            b.prefix_evictions = 0;
            b.shared_segment_bytes = 0;
            b
        };
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let cfg = self.cfg.clone();
        let slots = self.metrics.clone();
        let events = self.event_tx.clone();
        let pstore = d.prefix_store(shard).cloned();
        let spawned = std::thread::Builder::new()
            .name(format!("zipcache-shard-{shard}.{generation}"))
            .spawn(move || {
                shard_loop(shard, generation, cfg, ctx, slots, base, pstore,
                           ready_tx, events)
            });
        let handle = match spawned {
            Ok(h) => h,
            Err(_) => {
                self.schedule_restart(shard);
                return;
            }
        };
        match ready_rx.recv() {
            Ok(Ok(())) => {
                d.set_alive(shard, true);
                self.hb_frozen[shard] = 0;
                self.joins[shard] = Some(handle);
                eprintln!(
                    "zipcache-supervisor: shard {shard} restarted \
                     (generation {generation})"
                );
            }
            Ok(Err(e)) => {
                let _ = handle.join();
                eprintln!(
                    "zipcache-supervisor: shard {shard} restart failed \
                     ({e:#}); backing off"
                );
                self.schedule_restart(shard);
            }
            Err(_) => {
                let _ = handle.join();
                self.schedule_restart(shard);
            }
        }
    }
}

/// One shard: engine + continuous batcher + reply routing.
///
/// The batcher runs the priority-aware park policy (`PriorityPark`,
/// DESIGN.md §11) and stages every waiting request in its
/// priority-ordered queue; its depth is effectively unbounded here
/// because the dispatcher's global `queue_depth` is the single admission
/// boundary, decremented per
/// [`StepReport::activated`](crate::coordinator::StepReport) as requests
/// leave the staging queue.
///
/// Error altitude (DESIGN.md §14): requests that could fail
/// `Engine::start_session` are rejected at submit time (see
/// `ServerHandle::submit_request`), so a failure out of the serving loop
/// means the *engine itself* failed — a PJRT execute error, artifact
/// corruption, an injected fault, or a panic (caught here, never
/// unwinding past the shard).  Either way the shard dies cleanly through
/// [`fail_shard`] and the supervisor restarts it; the seed's
/// single-engine-thread design lost the whole server in that case, and
/// the pre-§14 pool leaked its waiting clients.
#[allow(clippy::too_many_arguments)]
fn shard_loop(
    shard_idx: usize,
    generation: u64,
    cfg: EngineConfig,
    ctx: ShardCtx,
    slots: Arc<Vec<Mutex<EngineMetrics>>>,
    base: EngineMetrics,
    prefix: Option<Arc<PrefixStore>>,
    ready: Sender<Result<()>>,
    events: Sender<ShardFatal>,
) -> Result<()> {
    let max_batch = cfg.scheduler.max_batch;
    let armed = Engine::new(cfg).and_then(|mut e| {
        // Fault decoration (DESIGN.md §14): a no-op unless `faults.plan`
        // is set; each shard gets its own seeded injector.  Only the
        // *first* incarnation arms — a fresh injector would reset the
        // plan's hit counters and re-fire every Nth trigger, turning a
        // "kill shard k once" plan into a crash loop.  A restarted shard
        // is therefore fault-free, and a plan's restart count is exactly
        // its kill count.
        if generation == 0 {
            e.arm_faults(shard_idx)?;
        }
        Ok(e)
    });
    let mut engine = match armed {
        Ok(e) => {
            let _ = ready.send(Ok(()));
            e
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return Ok(()); // failure already reported through the barrier
        }
    };
    // Swap in the dispatcher-owned prefix store (DESIGN.md §16) — but
    // only where the engine built its own (prefix on *and* chunked
    // entries available); elsewhere the shared store must stay detached
    // or the monolithic epilogue would intern segments no warm path can
    // ever read.  A respawned shard re-attaches to the surviving store,
    // so its store-derived metric snapshots refresh immediately.
    if engine.prefix_store().is_some() {
        if let Some(st) = prefix {
            engine.metrics.prefix_evictions = st.evictions();
            engine.metrics.shared_segment_bytes = st.shared_bytes() as u64;
            engine.set_prefix_store(st);
        }
    }
    let mut batcher = ContinuousBatcher::with_policy(max_batch, usize::MAX,
                                                     Box::new(PriorityPark));
    // Tag-keyed: eager staging can hold up to the whole global
    // queue_depth here (not just max_batch), and every streamed token
    // and completion looks its slot up — O(1), not a linear scan.
    let mut replies: HashMap<u64, ReplySlot> = HashMap::new();

    // Panic isolation (DESIGN.md §14): an unwind out of the serving loop
    // (injected or real) is converted into the same fatal path as an
    // engine error.  AssertUnwindSafe is justified because everything
    // the closure touches is either dropped with this incarnation
    // (engine) or only used through unwind-tolerant drains afterwards
    // (batcher's take_*, the reply map).
    let stepped = catch_unwind(AssertUnwindSafe(|| {
        serve_shard(shard_idx, &mut engine, &mut batcher, &mut replies, &ctx,
                    &slots, &base)
    }));
    let result = match stepped {
        Ok(r) => r,
        Err(payload) => Err(anyhow::anyhow!(
            "shard {shard_idx} panicked: {}", panic_message(payload.as_ref()))),
    };
    match result {
        Ok(()) => Ok(()),
        Err(e) => {
            fail_shard(shard_idx, generation, &e, &mut batcher, &mut replies,
                       &ctx, &engine, &slots[shard_idx], &base, &events);
            Err(e)
        }
    }
}

/// Best-effort text of a caught panic payload.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> &str {
    if let Some(s) = payload.downcast_ref::<&str>() {
        s
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s
    } else {
        "non-string panic payload"
    }
}

/// The shard's serving loop proper; an `Err` is an engine failure
/// (`shard_loop` drains the shard's accounting through `fail_shard`
/// afterwards).
fn serve_shard(
    shard_idx: usize,
    engine: &mut Engine,
    batcher: &mut ContinuousBatcher,
    replies: &mut HashMap<u64, ReplySlot>,
    ctx: &ShardCtx,
    slots: &[Mutex<EngineMetrics>],
    base: &EngineMetrics,
) -> Result<()> {
    loop {
        // Liveness heartbeat (DESIGN.md §14): one tick per iteration.
        // The supervisor severs a shard whose heartbeat freezes with
        // load in flight, so every stall funnel below — including the
        // injected wedge — is eventually fatal, never silent.
        ctx.tick_heartbeat();
        if engine.runtime().fault_stalled() {
            // Injected stall (§14): stop making progress — no steps, no
            // heartbeat — but keep absorbing channel traffic so the
            // fatal drain sees the complete picture.  The supervisor
            // notices the frozen heartbeat, severs our channel, and the
            // disconnect below is our fatal exit.
            loop {
                match ctx.rx.recv() {
                    Ok(req) => stage(batcher, replies, req, ctx),
                    Err(_) => anyhow::bail!(
                        "shard {shard_idx} stalled (injected) and was severed"
                    ),
                }
            }
        }
        // Stage every waiting request into the priority queue (pop order
        // is decided there; the global `queued` gauge still counts them
        // until they activate or shed).
        loop {
            match ctx.rx.try_recv() {
                Ok(req) => stage(batcher, replies, req, ctx),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    // Shutdown: finish in-flight work, publish, exit.
                    while !batcher.idle() {
                        let report = batcher.step(engine)?;
                        ctx.note_activated(report.activated);
                        stream_tokens(batcher, replies);
                        deliver(batcher, replies, ctx, engine,
                                &slots[shard_idx], base);
                    }
                    ctx.publish_resident(0);
                    publish(&slots[shard_idx], base, engine);
                    return Ok(());
                }
            }
        }
        if batcher.idle() {
            // Idle: publish metrics, then block for the next request.
            ctx.publish_resident(0);
            publish(&slots[shard_idx], base, engine);
            match ctx.rx.recv() {
                Ok(req) => {
                    stage(batcher, replies, req, ctx);
                    continue;
                }
                Err(_) => return Ok(()),
            }
        }
        let report = batcher.step(engine)?;
        ctx.note_activated(report.activated);
        // Streamed tokens go out before any completion below, so a
        // handle's token stream is always a prefix of its final tokens.
        stream_tokens(batcher, replies);
        // Routing weight (DESIGN.md §10): the dispatcher breaks load
        // ties by these live resident bytes, so publish every iteration.
        ctx.publish_resident(batcher.active_bytes());
        deliver(batcher, replies, ctx, engine, &slots[shard_idx], base);
    }
}

/// The shard-fatal path (DESIGN.md §14).  Runs after the serving loop
/// died (panic, engine error, or severed stall) and leaves the shard
/// *fully drained*: routing off, every gauge it held released, live
/// sessions answered with `ShardFailed` (at-most-once: the streamed
/// prefix is kept, never replayed), finished-but-undelivered outcomes
/// delivered normally, and every still-waiting request packed into a
/// [`ShardFatal`] for the supervisor to redeliver — those keep their
/// global waiting slot, so the queue-depth boundary never shrinks.
#[allow(clippy::too_many_arguments)]
fn fail_shard(
    shard_idx: usize,
    generation: u64,
    error: &anyhow::Error,
    batcher: &mut ContinuousBatcher,
    replies: &mut HashMap<u64, ReplySlot>,
    ctx: &ShardCtx,
    engine: &Engine,
    slot: &Mutex<EngineMetrics>,
    base: &EngineMetrics,
    events: &Sender<ShardFatal>,
) {
    // Routing off first: after this store no new request can race into
    // the dying channel through `try_admit` (stragglers already inside
    // it drain into the redelivery list below).
    ctx.mark_dead();

    // Work that finished before the failure is real — deliver it.
    for outcome in batcher.take_outcomes() {
        match replies.remove(&outcome.tag) {
            Some(r) => {
                ctx.note_done(r.reserved_bytes);
                let _ = r.reply.send(ResponseEvent::Done(Ok(outcome)));
            }
            None => ctx.note_done(0),
        }
    }

    // Activations inside the step that died (its report was lost to the
    // failure) still left the staging queue: drain their waiting slots.
    ctx.note_activated(batcher.take_departed());

    // Live sessions: their streams are at-most-once, so they finish
    // `ShardFailed` carrying the tokens generated so far — a prefix of
    // the fault-free stream (content-derived seeds) that is never
    // resumed or replayed.
    let mut failed = 0u64;
    for sess in batcher.take_active() {
        let Some(r) = replies.remove(&sess.tag) else {
            ctx.note_done(0);
            continue;
        };
        ctx.note_done(r.reserved_bytes);
        failed += 1;
        let _ = r.reply.send(ResponseEvent::Done(Ok(GenerationResponse {
            tag: sess.tag,
            finish: FinishReason::ShardFailed,
            tokens: sess.generated,
            prefill_ms: sess.prefill_us as f64 / 1000.0,
            decode_ms: sess.decode_us as f64 / 1000.0,
            compression_ratio: sess.compression_ratio,
            cache_bytes: sess.cache_bytes,
        })));
    }

    // Still-waiting requests (staged + channel backlog): redeliverable.
    // Their per-shard accounting is released here; their *global*
    // waiting slot is kept — the supervisor's redelivery re-routes them
    // without re-admission.
    let mut redeliver = Vec::new();
    for q in batcher.take_staged() {
        let Some(r) = replies.remove(&q.tag) else { continue };
        ctx.note_done(r.reserved_bytes);
        if r.streamed {
            // Unreachable by construction (staged requests never
            // stream), but at-most-once is a contract, not an
            // assumption: never redeliver a stream a client may have
            // observed.
            failed += 1;
            ctx.note_activated(1);
            let _ = r.reply.send(ResponseEvent::Done(Ok(
                GenerationResponse::without_session(
                    q.tag, FinishReason::ShardFailed),
            )));
            continue;
        }
        redeliver.push(ShardRequest {
            request: q.request,
            tag: q.tag,
            reserved_bytes: r.reserved_bytes,
            reply: r.reply,
        });
    }
    while let Ok(req) = ctx.rx.try_recv() {
        ctx.note_done(req.reserved_bytes);
        redeliver.push(req);
    }

    // Anything left was consumed mid-activation by the dying step: the
    // request is gone, so it cannot be redelivered — fail it cleanly
    // (its waiting slot already drained via `take_departed` above).
    for (tag, r) in replies.drain() {
        ctx.note_done(r.reserved_bytes);
        failed += 1;
        let _ = r.reply.send(ResponseEvent::Done(Ok(
            GenerationResponse::without_session(tag, FinishReason::ShardFailed),
        )));
    }

    ctx.publish_resident(0);
    publish(slot, base, engine);
    let _ = events.send(ShardFatal {
        shard: shard_idx,
        generation,
        error: format!("{error:#}"),
        redeliver,
        failed_sessions: failed,
    });
}

/// One in-flight request's reply channel plus the worst-case byte
/// reservation to release when it completes (keyed by tag in the shard's
/// reply map).
struct ReplySlot {
    reserved_bytes: usize,
    /// True once any token streamed to the client: the at-most-once
    /// guard — a request that streamed is never redelivered
    /// (DESIGN.md §14).
    streamed: bool,
    reply: Sender<ResponseEvent>,
}

/// Move a pulled request into the batcher's staging queue and register
/// its reply slot.  Never rejects: the staging depth is unbounded and
/// the dispatcher's global boundary has already admitted the request.
fn stage(
    batcher: &mut ContinuousBatcher,
    replies: &mut HashMap<u64, ReplySlot>,
    req: ShardRequest,
    ctx: &ShardCtx,
) {
    match batcher.submit(QueuedRequest { request: req.request, tag: req.tag }) {
        Ok(()) => {
            replies.insert(req.tag, ReplySlot {
                reserved_bytes: req.reserved_bytes,
                streamed: false,
                reply: req.reply,
            });
        }
        Err(_) => {
            // Unreachable by construction (staging depth is unbounded),
            // but do not let an accounting bug hang the client.
            let _ = req.reply.send(ResponseEvent::Done(Err(anyhow::anyhow!(
                "internal: shard batcher rejected"
            ))));
            ctx.note_activated(1);
            ctx.note_done(req.reserved_bytes);
        }
    }
}

/// Forward the batcher's freshly emitted `(tag, token)` stream to the
/// matching reply channels (best-effort: a dropped handle just stops
/// listening).
fn stream_tokens(batcher: &mut ContinuousBatcher,
                 replies: &mut HashMap<u64, ReplySlot>) {
    for (tag, tok) in batcher.drain_emitted() {
        if let Some(r) = replies.get_mut(&tag) {
            r.streamed = true;
            let _ = r.reply.send(ResponseEvent::Token(tok));
        }
    }
}

/// Send finished outcomes to their callers.  Metrics are published
/// *before* the replies go out, so any client whose `wait()` returned is
/// guaranteed to see its own request in the next snapshot.
fn deliver(
    batcher: &mut ContinuousBatcher,
    replies: &mut HashMap<u64, ReplySlot>,
    ctx: &ShardCtx,
    engine: &Engine,
    slot: &Mutex<EngineMetrics>,
    base: &EngineMetrics,
) {
    let outcomes = batcher.take_outcomes();
    if outcomes.is_empty() {
        return;
    }
    publish(slot, base, engine);
    for outcome in outcomes {
        // Release accounting (load + byte reservation) *before* the
        // reply goes out, like the metrics publish above: a client whose
        // `wait()` has returned must observe its reservation gone —
        // including cancelled and deadline-shed requests, whose release
        // therefore happens the same iteration the cancel/shed is
        // observed, not at natural completion (DESIGN.md §11).
        match replies.remove(&outcome.tag) {
            Some(r) => {
                ctx.note_done(r.reserved_bytes);
                let _ = r.reply.send(ResponseEvent::Done(Ok(outcome)));
            }
            None => ctx.note_done(0),
        }
    }
}

/// Publish this shard's engine metrics into its shared snapshot slot:
/// `base` (the history inherited from this shard's previous incarnations
/// plus the supervisor's failure counters, DESIGN.md §14 — zero for a
/// first-generation shard) merged with the live engine counters, so a
/// restart never zeroes the shard's column in the snapshot.
///
/// This clones the full `EngineMetrics`, whose histograms keep every
/// sample — per-delivery cost therefore grows with run length.  Fine at
/// bench/test scale (exact percentiles are worth it); switching the
/// recorders to fixed-bucket histograms is the knob to turn if serving
/// runs ever get long enough for this clone to show up in a profile.
fn publish(slot: &Mutex<EngineMetrics>, base: &EngineMetrics, engine: &Engine) {
    let mut merged = base.clone();
    merged.merge(&engine.metrics);
    *lock_metrics(slot) = merged;
}
