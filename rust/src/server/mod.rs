//! Serving front-end: a sharded engine pool behind one admission point
//! (DESIGN.md §8), speaking the typed request/response API
//! (DESIGN.md §11).
//!
//! [`Server::start`] spawns `cfg.scheduler.shards` serving threads.  Each
//! shard owns a full engine stack — an [`Engine`] (and therefore its own
//! PJRT executables and plane-compression worker pool), plus a
//! [`ContinuousBatcher`] interleaving up to `max_batch` sessions.  Engines
//! are constructed *inside* their shard thread (PJRT executables are not
//! `Send`), and a startup barrier reports construction failures from
//! `Server::start` itself.
//!
//! Requests are [`GenerationRequest`]s (priority class, optional
//! deadline, per-request quant/seed overrides, stop tokens) and flow
//! through the private dispatcher module: one global `queue_depth`
//! boundary decides accept/reject at submit time, then the request is
//! routed to the least-loaded shard.  Inside a shard, waiting requests
//! stage in the batcher's *priority-ordered* queue; the global waiting
//! count is decremented only when a request actually leaves that queue
//! (decode slot granted, deadline shed, or cancelled at pop), so the
//! configured depth stays the exact rejection boundary (DESIGN.md §8).
//! Per-tag outputs are independent of shard count and placement because
//! sessions are independent and seeds derive from request content
//! (`coordinator::engine::request_seed`).
//!
//! Responses stream: a [`ResponseHandle`] yields tokens incrementally as
//! the batcher emits them ([`ResponseHandle::next_token`], or iterate the
//! handle), supports [`ResponseHandle::cancel`], and resolves to a
//! [`GenerationResponse`] carrying a
//! [`FinishReason`](crate::coordinator::FinishReason).
//!
//! Offline-build note: the environment ships no async runtime, so this is
//! a blocking-channel design (std::sync::mpsc) rather than tokio; the
//! public shape — submit returns a streamable handle, requests interleave
//! through per-shard continuous batchers — is the same (DESIGN.md §6).

mod dispatch;
pub mod loadgen;

use std::collections::HashMap;
use std::sync::mpsc::{self, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::config::EngineConfig;
use crate::coordinator::batcher::{ContinuousBatcher, PriorityPark, QueuedRequest};
use crate::coordinator::request::{CancelToken, GenerationRequest,
                                  GenerationResponse};
use crate::coordinator::Engine;
use crate::kvcache::{worst_case_resident_bytes, CacheLayout};
use crate::metrics::{EngineMetrics, MetricsSnapshot};
use crate::Result;

use dispatch::{AdmitRequest, Dispatcher, ShardCtx, ShardRequest};

/// One streamed event on a request's reply channel: an incremental token
/// or the final response.  Tokens always precede their `Done`, and their
/// concatenation equals `GenerationResponse::tokens` exactly.
pub(crate) enum ResponseEvent {
    Token(u16),
    Done(Result<GenerationResponse>),
}

/// A streamable response slot for one submitted request (DESIGN.md §11).
///
/// Consume incrementally with [`ResponseHandle::next_token`] (or by
/// iterating: `for tok in &mut handle { .. }`), then finish with
/// [`ResponseHandle::wait`]; or call `wait()` directly to block until
/// completion.  [`ResponseHandle::cancel`] requests cancellation — the
/// shard retires the session at its next scheduler iteration, releasing
/// its dense slot and byte-budget reservation immediately, and the final
/// response arrives with
/// [`FinishReason::Cancelled`](crate::coordinator::FinishReason::Cancelled)
/// carrying the tokens
/// generated so far.
pub struct ResponseHandle {
    rx: Receiver<ResponseEvent>,
    tag: u64,
    cancel: CancelToken,
    /// Final result observed while streaming, stashed for `wait()`.
    done: Option<Result<GenerationResponse>>,
}

impl ResponseHandle {
    /// Block for the next streamed token; `None` once the generation has
    /// finished (then [`ResponseHandle::wait`] returns the final
    /// response without blocking).
    pub fn next_token(&mut self) -> Option<u16> {
        if self.done.is_some() {
            return None;
        }
        match self.rx.recv() {
            Ok(ResponseEvent::Token(t)) => Some(t),
            Ok(ResponseEvent::Done(r)) => {
                self.done = Some(r);
                None
            }
            Err(_) => {
                self.done = Some(Err(anyhow::anyhow!("server dropped request")));
                None
            }
        }
    }

    /// Block until the generation completes (draining any unread
    /// streamed tokens — they are a prefix of the final `tokens`).
    pub fn wait(mut self) -> Result<GenerationResponse> {
        while self.done.is_none() {
            self.next_token();
        }
        self.done.take().expect("loop exits only once done is set")
    }

    /// Request cancellation (idempotent).  Safe at any point in the
    /// request lifecycle: waiting requests retire at pop time, active
    /// sessions at the next scheduler iteration.
    pub fn cancel(&self) {
        self.cancel.cancel();
    }

    /// Global submission-order tag of this request (diagnostics).
    pub fn tag(&self) -> u64 {
        self.tag
    }
}

impl Iterator for ResponseHandle {
    type Item = u16;

    fn next(&mut self) -> Option<u16> {
        self.next_token()
    }
}

/// Handle to a running server; cloneable, cheap to share across threads.
#[derive(Clone)]
pub struct ServerHandle {
    dispatcher: Arc<Dispatcher>,
    metrics: Arc<Vec<Mutex<EngineMetrics>>>,
    /// Cache shape, for submit-time validation and the worst-case
    /// byte-footprint bound the budget admission reserves (DESIGN.md §10).
    layout: CacheLayout,
    /// Streaming recompression period (sizes the worst-case fp32 tail).
    recompress_every: usize,
}

impl ServerHandle {
    /// Submit one typed generation request; returns a streamable handle.
    /// Errors immediately when the admission queue is full (backpressure),
    /// no shard can hold the request's worst-case byte footprint (memory
    /// budget), or the request fails the shared
    /// [`GenerationRequest::validate`] contract — the same check
    /// `Engine::start_session` applies, so a bad request is a submit-time
    /// error, never a poisoned shard (DESIGN.md §8, §11).
    pub fn submit_request(&self, req: GenerationRequest) -> Result<ResponseHandle> {
        req.validate(self.layout.seq)?;
        // Worst-case resident footprint for the budget reservation.  The
        // bound is conservative for *any* admissible quant override: its
        // payload term charges fp16 (2 B/value), which dominates every
        // override width (max 8 bits), and its param term already assumes
        // the densest class mix — see `worst_case_resident_bytes`.
        let wc = worst_case_resident_bytes(self.layout,
                                           req.prompt.len() + req.max_new,
                                           self.recompress_every);
        let cancel = req.cancel.clone();
        let (reply, rx) = mpsc::channel();
        let tag = self
            .dispatcher
            .try_admit(AdmitRequest { request: req, wc_bytes: wc, reply })?;
        Ok(ResponseHandle { rx, tag, cancel, done: None })
    }

    /// Legacy positional submit: a thin wrapper over builder defaults
    /// (DESIGN.md §11) — bit-identical to the pre-§11 path.
    pub fn submit(&self, prompt: Vec<u16>, max_new: usize) -> Result<ResponseHandle> {
        self.submit_request(GenerationRequest::new(prompt, max_new))
    }

    /// Submit and wait (convenience).
    pub fn generate(&self, prompt: Vec<u16>, max_new: usize)
                    -> Result<GenerationResponse> {
        self.submit(prompt, max_new)?.wait()
    }

    /// Number of engine shards serving this handle.
    pub fn shards(&self) -> usize {
        self.dispatcher.shard_count()
    }

    /// Requests currently waiting for a decode slot.
    pub fn queued(&self) -> usize {
        self.dispatcher.queued()
    }

    /// Per-shard in-flight request counts (waiting + active), in shard
    /// index order.
    pub fn shard_loads(&self) -> Vec<usize> {
        self.dispatcher.loads()
    }

    /// Per-shard live resident bytes as last published by each shard's
    /// batcher (DESIGN.md §10), in shard index order.
    pub fn shard_resident_bytes(&self) -> Vec<usize> {
        self.dispatcher.resident_bytes()
    }

    /// Per-shard worst-case bytes currently reserved against the memory
    /// budget (always 0 when `memory.budget_bytes = 0`).
    pub fn shard_reserved_bytes(&self) -> Vec<usize> {
        self.dispatcher.reserved_bytes()
    }

    /// A coherent metrics read: per-shard engine metrics (as last
    /// published by each shard) plus their aggregate.  Lock-cheap: one
    /// uncontended per-shard mutex clone each, no stop-the-world.
    pub fn metrics(&self) -> MetricsSnapshot {
        let per_shard: Vec<EngineMetrics> = self
            .metrics
            .iter()
            .map(|slot| slot.lock().expect("metrics slot poisoned").clone())
            .collect();
        MetricsSnapshot::aggregate(per_shard)
    }
}

/// A running server: shard threads + dispatch state.
pub struct Server {
    pub handle: ServerHandle,
    joins: Vec<JoinHandle<Result<()>>>,
}

impl Server {
    /// Start the shard pool.  `cfg.scheduler.shards == 0` means one shard
    /// per available core.  Fails fast if any shard's engine cannot be
    /// constructed (bad artifacts dir, unknown model, ...).
    pub fn start(cfg: EngineConfig) -> Result<Self> {
        cfg.validate()?;
        // Model shape for submit-time validation and worst-case byte
        // bounds (cheap: manifest read or sim registry, no compilation)
        // — also fails fast here when the artifacts dir is unreadable,
        // before any thread spawns.
        let layout = crate::runtime::load_model_info(&cfg.artifacts_dir, &cfg.model)?
            .cache_layout();
        let n_shards = if cfg.scheduler.shards == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            cfg.scheduler.shards
        };
        let (dispatcher, ctxs) = dispatch::build(n_shards, cfg.scheduler.queue_depth,
                                                 cfg.memory.budget_bytes);
        let metrics: Arc<Vec<Mutex<EngineMetrics>>> = Arc::new(
            (0..n_shards).map(|_| Mutex::new(EngineMetrics::default())).collect(),
        );
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();

        let mut joins = Vec::with_capacity(n_shards);
        for (i, ctx) in ctxs.into_iter().enumerate() {
            let cfg = cfg.clone();
            let ready = ready_tx.clone();
            let slot = metrics.clone();
            joins.push(
                std::thread::Builder::new()
                    .name(format!("zipcache-shard-{i}"))
                    .spawn(move || shard_loop(i, cfg, ctx, slot, ready))?,
            );
        }
        drop(ready_tx);

        // Startup barrier: every shard reports engine construction.
        let mut startup_err = None;
        for _ in 0..n_shards {
            match ready_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => startup_err = Some(e),
                Err(_) => {
                    startup_err =
                        Some(anyhow::anyhow!("shard thread died during startup"))
                }
            }
        }
        if let Some(e) = startup_err {
            // Tear down: dropping the dispatcher closes every shard
            // channel, so the healthy shards exit their loops.
            drop(dispatcher);
            for j in joins {
                let _ = j.join();
            }
            return Err(e);
        }

        Ok(Server {
            handle: ServerHandle {
                dispatcher: Arc::new(dispatcher),
                metrics,
                layout,
                recompress_every: cfg.quant.recompress_every,
            },
            joins,
        })
    }

    /// Graceful shutdown: close the admission side and join every shard
    /// (in-flight requests complete first).  Any outstanding
    /// [`ServerHandle`] clones must be dropped by their owners for the
    /// shards to observe disconnection.
    pub fn shutdown(self) -> Result<()> {
        drop(self.handle);
        let mut result = Ok(());
        for j in self.joins {
            match j.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => result = Err(e),
                Err(_) => result = Err(anyhow::anyhow!("shard thread panicked")),
            }
        }
        result
    }
}

/// One shard: engine + continuous batcher + reply routing.
///
/// The batcher runs the priority-aware park policy (`PriorityPark`,
/// DESIGN.md §11) and stages every waiting request in its
/// priority-ordered queue; its depth is effectively unbounded here
/// because the dispatcher's global `queue_depth` is the single admission
/// boundary, decremented per
/// [`StepReport::activated`](crate::coordinator::StepReport) as requests
/// leave the staging queue.
///
/// Error altitude: requests that could fail `Engine::start_session` are
/// rejected at submit time (see `ServerHandle::submit_request`), so a `?`
/// out of `batcher.step` here means the *engine itself* failed (PJRT
/// execute error, artifact corruption) — that shard exits with the error
/// and its in-flight clients see "server dropped request", while other
/// shards keep serving.  The seed's single-engine-thread design lost the
/// whole server in that case; per-request error outcomes through the
/// batcher are a possible future refinement (DESIGN.md §8).
fn shard_loop(
    shard_idx: usize,
    cfg: EngineConfig,
    ctx: ShardCtx,
    slots: Arc<Vec<Mutex<EngineMetrics>>>,
    ready: Sender<Result<()>>,
) -> Result<()> {
    let max_batch = cfg.scheduler.max_batch;
    let mut engine = match Engine::new(cfg) {
        Ok(e) => {
            let _ = ready.send(Ok(()));
            e
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return Ok(()); // failure already reported through the barrier
        }
    };
    let mut batcher = ContinuousBatcher::with_policy(max_batch, usize::MAX,
                                                     Box::new(PriorityPark));
    // Tag-keyed: eager staging can hold up to the whole global
    // queue_depth here (not just max_batch), and every streamed token
    // and completion looks its slot up — O(1), not a linear scan.
    let mut replies: HashMap<u64, ReplySlot> = HashMap::new();

    let result = serve_shard(shard_idx, &mut engine, &mut batcher, &mut replies,
                             &ctx, &slots);
    if result.is_err() {
        // Fault isolation (DESIGN.md §8): this shard dies, the others
        // keep serving — which requires releasing the *global* waiting
        // slots of every request this shard still holds, or a dead
        // shard permanently shrinks the `queue_depth` boundary for the
        // healthy ones (the staging queue is unbounded here, so up to
        // the whole depth could be pinned).  Clients see "server
        // dropped request" when the reply senders drop.
        fail_pending(&mut batcher, &mut replies, &ctx);
    }
    result
}

/// The shard's serving loop proper; an `Err` is an engine failure
/// (`shard_loop` releases the shard's global accounting afterwards).
fn serve_shard(
    shard_idx: usize,
    engine: &mut Engine,
    batcher: &mut ContinuousBatcher,
    replies: &mut HashMap<u64, ReplySlot>,
    ctx: &ShardCtx,
    slots: &[Mutex<EngineMetrics>],
) -> Result<()> {
    loop {
        // Stage every waiting request into the priority queue (pop order
        // is decided there; the global `queued` gauge still counts them
        // until they activate or shed).
        loop {
            match ctx.rx.try_recv() {
                Ok(req) => stage(batcher, replies, req, ctx),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    // Shutdown: finish in-flight work, publish, exit.
                    while !batcher.idle() {
                        let report = batcher.step(engine)?;
                        ctx.note_activated(report.activated);
                        stream_tokens(batcher, replies);
                        deliver(batcher, replies, ctx, engine,
                                &slots[shard_idx]);
                    }
                    ctx.publish_resident(0);
                    publish(&slots[shard_idx], engine);
                    return Ok(());
                }
            }
        }
        if batcher.idle() {
            // Idle: publish metrics, then block for the next request.
            ctx.publish_resident(0);
            publish(&slots[shard_idx], engine);
            match ctx.rx.recv() {
                Ok(req) => {
                    stage(batcher, replies, req, ctx);
                    continue;
                }
                Err(_) => return Ok(()),
            }
        }
        let report = batcher.step(engine)?;
        ctx.note_activated(report.activated);
        // Streamed tokens go out before any completion below, so a
        // handle's token stream is always a prefix of its final tokens.
        stream_tokens(batcher, replies);
        // Routing weight (DESIGN.md §10): the dispatcher breaks load
        // ties by these live resident bytes, so publish every iteration.
        ctx.publish_resident(batcher.active_bytes());
        deliver(batcher, replies, ctx, engine, &slots[shard_idx]);
    }
}

/// Release the global/per-shard accounting of everything a failed shard
/// still holds: staged requests leave the global waiting gauge
/// (`note_activated`), every reply slot's load + byte reservation is
/// released, and the channel backlog (requests routed here before the
/// dispatcher learns of the death via its first failed send) is drained
/// the same way.  A request arriving in the instant between this drain
/// and the receiver dropping still leaks its waiting slot — the same
/// small race the pre-§11 design documented; everything a shard
/// *observably* held is now rolled back.
fn fail_pending(
    batcher: &mut ContinuousBatcher,
    replies: &mut HashMap<u64, ReplySlot>,
    ctx: &ShardCtx,
) {
    // Still-pending requests, plus departures inside the very step that
    // errored (its StepReport was lost to the `?`): both classes leave
    // the waiting gauge exactly once.
    ctx.note_activated(batcher.take_departed() + batcher.pending());
    for (_, r) in replies.drain() {
        ctx.note_done(r.reserved_bytes);
    }
    while let Ok(req) = ctx.rx.try_recv() {
        ctx.note_activated(1);
        ctx.note_done(req.reserved_bytes);
    }
}

/// One in-flight request's reply channel plus the worst-case byte
/// reservation to release when it completes (keyed by tag in the shard's
/// reply map).
struct ReplySlot {
    reserved_bytes: usize,
    reply: Sender<ResponseEvent>,
}

/// Move a pulled request into the batcher's staging queue and register
/// its reply slot.  Never rejects: the staging depth is unbounded and
/// the dispatcher's global boundary has already admitted the request.
fn stage(
    batcher: &mut ContinuousBatcher,
    replies: &mut HashMap<u64, ReplySlot>,
    req: ShardRequest,
    ctx: &ShardCtx,
) {
    match batcher.submit(QueuedRequest { request: req.request, tag: req.tag }) {
        Ok(()) => {
            replies.insert(req.tag, ReplySlot {
                reserved_bytes: req.reserved_bytes,
                reply: req.reply,
            });
        }
        Err(_) => {
            // Unreachable by construction (staging depth is unbounded),
            // but do not let an accounting bug hang the client.
            let _ = req.reply.send(ResponseEvent::Done(Err(anyhow::anyhow!(
                "internal: shard batcher rejected"
            ))));
            ctx.note_activated(1);
            ctx.note_done(req.reserved_bytes);
        }
    }
}

/// Forward the batcher's freshly emitted `(tag, token)` stream to the
/// matching reply channels (best-effort: a dropped handle just stops
/// listening).
fn stream_tokens(batcher: &mut ContinuousBatcher,
                 replies: &HashMap<u64, ReplySlot>) {
    for (tag, tok) in batcher.drain_emitted() {
        if let Some(r) = replies.get(&tag) {
            let _ = r.reply.send(ResponseEvent::Token(tok));
        }
    }
}

/// Send finished outcomes to their callers.  Metrics are published
/// *before* the replies go out, so any client whose `wait()` returned is
/// guaranteed to see its own request in the next snapshot.
fn deliver(
    batcher: &mut ContinuousBatcher,
    replies: &mut HashMap<u64, ReplySlot>,
    ctx: &ShardCtx,
    engine: &Engine,
    slot: &Mutex<EngineMetrics>,
) {
    let outcomes = batcher.take_outcomes();
    if outcomes.is_empty() {
        return;
    }
    publish(slot, engine);
    for outcome in outcomes {
        // Release accounting (load + byte reservation) *before* the
        // reply goes out, like the metrics publish above: a client whose
        // `wait()` has returned must observe its reservation gone —
        // including cancelled and deadline-shed requests, whose release
        // therefore happens the same iteration the cancel/shed is
        // observed, not at natural completion (DESIGN.md §11).
        match replies.remove(&outcome.tag) {
            Some(r) => {
                ctx.note_done(r.reserved_bytes);
                let _ = r.reply.send(ResponseEvent::Done(Ok(outcome)));
            }
            None => ctx.note_done(0),
        }
    }
}

/// Publish this shard's engine metrics into its shared snapshot slot.
///
/// This clones the full `EngineMetrics`, whose histograms keep every
/// sample — per-delivery cost therefore grows with run length.  Fine at
/// bench/test scale (exact percentiles are worth it); switching the
/// recorders to fixed-bucket histograms is the knob to turn if serving
/// runs ever get long enough for this clone to show up in a profile.
fn publish(slot: &Mutex<EngineMetrics>, engine: &Engine) {
    *slot.lock().expect("metrics slot poisoned") = engine.metrics.clone();
}
