//! Serving front-end: a threaded service that owns the engine on a
//! dedicated worker thread (PJRT executables are not `Send`) and exposes a
//! request/response channel API with backpressure.
//!
//! Offline-build note: the environment ships no async runtime, so this is a
//! blocking-channel design (std::sync::mpsc) rather than tokio; the public
//! shape — submit returns a waitable handle, requests interleave through
//! the continuous batcher — is the same (DESIGN.md §6).
//!
//! The engine thread owns the compression worker pool: requests that hit a
//! prefill or recompression point fan their plane work out across
//! `cfg.parallelism` threads (DESIGN.md §5) while the serving loop itself
//! stays single-threaded, so batcher scheduling order — and therefore
//! per-tag output — is unchanged at any pool width.

use std::sync::mpsc::{self, Receiver, Sender, SyncSender, TrySendError};
use std::thread::JoinHandle;

use crate::config::EngineConfig;
use crate::coordinator::batcher::{ContinuousBatcher, QueuedRequest};
use crate::coordinator::{Engine, GenerationOutput};
use crate::Result;

/// One request to the serving loop.
struct ServerRequest {
    prompt: Vec<u16>,
    max_new: usize,
    reply: Sender<Result<GenerationOutput>>,
}

/// A waitable response slot for one submitted request.
pub struct ResponseHandle {
    rx: Receiver<Result<GenerationOutput>>,
}

impl ResponseHandle {
    /// Block until the generation completes.
    pub fn wait(self) -> Result<GenerationOutput> {
        self.rx
            .recv()
            .map_err(|_| anyhow::anyhow!("server dropped request"))?
    }
}

/// Handle to a running server; cloneable, cheap to share across threads.
#[derive(Clone)]
pub struct ServerHandle {
    tx: SyncSender<ServerRequest>,
}

impl ServerHandle {
    /// Submit one generation request; returns a waitable handle.
    /// Errors immediately when the queue is full (backpressure).
    pub fn submit(&self, prompt: Vec<u16>, max_new: usize) -> Result<ResponseHandle> {
        let (reply, rx) = mpsc::channel();
        match self.tx.try_send(ServerRequest { prompt, max_new, reply }) {
            Ok(()) => Ok(ResponseHandle { rx }),
            Err(TrySendError::Full(_)) => anyhow::bail!("queue full (backpressure)"),
            Err(TrySendError::Disconnected(_)) => anyhow::bail!("server stopped"),
        }
    }

    /// Submit and wait (convenience).
    pub fn generate(&self, prompt: Vec<u16>, max_new: usize) -> Result<GenerationOutput> {
        self.submit(prompt, max_new)?.wait()
    }
}

/// A running server: engine thread + request channel.
pub struct Server {
    pub handle: ServerHandle,
    join: JoinHandle<Result<()>>,
}

impl Server {
    /// Start the engine thread with iteration-level continuous batching.
    pub fn start(cfg: EngineConfig) -> Result<Self> {
        let (tx, rx) = mpsc::sync_channel::<ServerRequest>(cfg.scheduler.queue_depth);
        let max_batch = cfg.scheduler.max_batch;
        let queue_depth = cfg.scheduler.queue_depth;

        let join = std::thread::Builder::new()
            .name("zipcache-engine".into())
            .spawn(move || -> Result<()> {
                let mut engine = Engine::new(cfg)?;
                let mut batcher = ContinuousBatcher::new(max_batch, queue_depth);
                let mut replies: Vec<(u64, Sender<Result<GenerationOutput>>)> = Vec::new();
                let mut next_tag = 0u64;
                loop {
                    // Drain waiting requests without blocking while busy.
                    loop {
                        match rx.try_recv() {
                            Ok(req) => {
                                let tag = next_tag;
                                next_tag += 1;
                                if batcher
                                    .submit(QueuedRequest {
                                        prompt: req.prompt,
                                        max_new: req.max_new,
                                        tag,
                                    })
                                    .is_err()
                                {
                                    let _ = req
                                        .reply
                                        .send(Err(anyhow::anyhow!("queue full")));
                                } else {
                                    replies.push((tag, req.reply));
                                }
                            }
                            Err(mpsc::TryRecvError::Empty) => break,
                            Err(mpsc::TryRecvError::Disconnected) => {
                                // Finish in-flight work, then exit.
                                while !batcher.idle() {
                                    batcher.step(&mut engine)?;
                                    deliver(&mut batcher, &mut replies);
                                }
                                return Ok(());
                            }
                        }
                    }
                    if batcher.idle() {
                        // Idle: block for the next request (or shutdown).
                        match rx.recv() {
                            Ok(req) => {
                                let tag = next_tag;
                                next_tag += 1;
                                if batcher
                                    .submit(QueuedRequest {
                                        prompt: req.prompt,
                                        max_new: req.max_new,
                                        tag,
                                    })
                                    .is_err()
                                {
                                    let _ = req
                                        .reply
                                        .send(Err(anyhow::anyhow!("queue full")));
                                } else {
                                    replies.push((tag, req.reply));
                                }
                            }
                            Err(_) => return Ok(()),
                        }
                        continue;
                    }
                    batcher.step(&mut engine)?;
                    deliver(&mut batcher, &mut replies);
                }
            })?;

        Ok(Server { handle: ServerHandle { tx }, join })
    }

    /// Graceful shutdown: close the channel and join the engine thread
    /// (in-flight requests complete first).
    pub fn shutdown(self) -> Result<()> {
        drop(self.handle);
        match self.join.join() {
            Ok(r) => r,
            Err(_) => anyhow::bail!("engine thread panicked"),
        }
    }
}

fn deliver(
    batcher: &mut ContinuousBatcher,
    replies: &mut Vec<(u64, Sender<Result<GenerationOutput>>)>,
) {
    for outcome in batcher.take_outcomes() {
        if let Some(idx) = replies.iter().position(|(t, _)| *t == outcome.tag) {
            let (_, reply) = replies.swap_remove(idx);
            let _ = reply.send(Ok(outcome.output));
        }
    }
}
