//! Compression policies: ZipCache and every baseline the paper compares
//! against (Tables 3/A/B, Fig. 5), implemented behind one trait so the
//! coordinator and the benches treat them uniformly.
//!
//! | policy  | paper ref | precision plan                          | saliency metric |
//! |---------|-----------|------------------------------------------|-----------------|
//! | FP16    | baseline  | all tokens fp16                          | —               |
//! | H2O     | [46]      | keep heavy+recent fp16, evict rest       | accumulated     |
//! | GEAR    | [21]      | whole cache 4-bit                        | —               |
//! | KIVI    | [32]      | recent window fp16, rest 2-bit groupwise | — (recency)     |
//! | MiKV    | [43]      | salient 4-bit / rest 2-bit               | accumulated     |
//! | ZipCache| this paper| salient 4-bit / rest 2-bit               | normalized (probe) |
//!
//! GEAR's low-rank error-compensation term is not modelled (we reproduce
//! its uniform-quantization core); see DESIGN.md §2 substitutions.

pub mod policies;

pub use policies::{
    standard_policies, CompressionPolicy, Fp16Policy, GearPolicy, H2oPolicy,
    KiviPolicy, MikvPolicy, PolicyInput, ZipCachePolicy,
};
