//! The policy trait and the six policies of the paper's comparison set.

use crate::kvcache::ratio::{self, RatioShape};
use crate::kvcache::{PrecisionClass, QuantSpec};
use crate::quant::Granularity;
use crate::saliency::metric::select_salient;

/// Everything a policy may consult when assigning per-token precision.
#[derive(Debug, Clone, Copy)]
pub struct PolicyInput<'a> {
    /// Number of live prompt tokens (prefix of the window).
    pub n_tokens: usize,
    /// Accumulated attention scores (Eq. 7), aggregated over layers/heads.
    /// Present only when the coordinator ran the full-score prefill.
    pub acc_saliency: Option<&'a [f32]>,
    /// Normalized attention scores (Eq. 8), probe-approximated on the fast
    /// path or exact on the full path.
    pub norm_saliency: Option<&'a [f32]>,
}

/// A KV cache compression policy (ZipCache or a baseline).
pub trait CompressionPolicy: Send + Sync {
    fn name(&self) -> &'static str;

    /// Does this policy need the full attention-score prefill artifact?
    /// (H2O/MiKV's accumulated metric requires materialized scores — the
    /// very inefficiency the paper's Fig. 4/6 measures.)
    fn requires_full_scores(&self) -> bool;

    /// Quantization granularities for the planes this policy quantizes.
    fn quant_spec(&self) -> QuantSpec {
        QuantSpec::default()
    }

    /// Assign one precision class per live token.
    fn assign(&self, input: &PolicyInput) -> Vec<PrecisionClass>;

    /// Analytic compression ratio under the paper's accounting.
    fn analytic_ratio(&self, shape: RatioShape) -> f64;
}

// ---------------------------------------------------------------------------

/// FP16: the uncompressed reference point.
#[derive(Debug, Clone, Copy, Default)]
pub struct Fp16Policy;

impl CompressionPolicy for Fp16Policy {
    fn name(&self) -> &'static str {
        "FP16"
    }
    fn requires_full_scores(&self) -> bool {
        false
    }
    fn assign(&self, input: &PolicyInput) -> Vec<PrecisionClass> {
        vec![PrecisionClass::Fp16; input.n_tokens]
    }
    fn analytic_ratio(&self, _shape: RatioShape) -> f64 {
        1.0
    }
}

/// H2O [46]: keep `heavy_ratio` heavy hitters (by accumulated scores) and
/// `recent_ratio` recent tokens at fp16; evict everything else.
#[derive(Debug, Clone, Copy)]
pub struct H2oPolicy {
    pub heavy_ratio: f64,
    pub recent_ratio: f64,
}

impl Default for H2oPolicy {
    fn default() -> Self {
        // paper setup: 40% kept total (20%+20% in the original H2O paper;
        // Table 3 uses "16/0, 40%")
        H2oPolicy { heavy_ratio: 0.2, recent_ratio: 0.2 }
    }
}

impl CompressionPolicy for H2oPolicy {
    fn name(&self) -> &'static str {
        "H2O"
    }
    fn requires_full_scores(&self) -> bool {
        true
    }
    fn assign(&self, input: &PolicyInput) -> Vec<PrecisionClass> {
        let n = input.n_tokens;
        let acc = input.acc_saliency.expect("H2O needs accumulated scores");
        let n_recent = ((n as f64) * self.recent_ratio).round() as usize;
        let recent_from = n.saturating_sub(n_recent);
        // heavy hitters among the non-recent prefix
        let heavy = select_salient(&acc[..recent_from.max(1).min(acc.len())],
                                   recent_from, self.heavy_ratio * n as f64
                                       / recent_from.max(1) as f64);
        (0..n)
            .map(|t| {
                if t >= recent_from || heavy.get(t).copied().unwrap_or(false) {
                    PrecisionClass::Fp16
                } else {
                    PrecisionClass::Evicted
                }
            })
            .collect()
    }
    fn analytic_ratio(&self, _shape: RatioShape) -> f64 {
        ratio::eviction(self.heavy_ratio + self.recent_ratio)
    }
}

/// GEAR [21]: the whole cache uniformly quantized to 4-bit.
#[derive(Debug, Clone, Copy)]
pub struct GearPolicy {
    pub bits: u8,
}

impl Default for GearPolicy {
    fn default() -> Self {
        GearPolicy { bits: 4 }
    }
}

impl CompressionPolicy for GearPolicy {
    fn name(&self) -> &'static str {
        "GEAR"
    }
    fn requires_full_scores(&self) -> bool {
        // GEAR itself is saliency-free, but its reference implementation
        // runs standard attention (paper Table A shows its high prefill
        // latency); model that faithfully.
        true
    }
    fn quant_spec(&self) -> QuantSpec {
        // GEAR uses per-token/groupwise quantization of outliers; model the
        // storage as groupwise (its accounting in the paper is 3.00x).
        QuantSpec { key_gran: Granularity::Group(32), value_gran: Granularity::Group(32) }
    }
    fn assign(&self, input: &PolicyInput) -> Vec<PrecisionClass> {
        vec![PrecisionClass::Bits(self.bits); input.n_tokens]
    }
    fn analytic_ratio(&self, _shape: RatioShape) -> f64 {
        // The paper credits GEAR with 3.00x at 4-bit (quantization +
        // residual bookkeeping); use the printed value.
        3.0
    }
}

/// KIVI [32]: the most recent `window` tokens at fp16, the rest 2-bit with
/// fine-grained groupwise quantization (keys per-channel groups).
#[derive(Debug, Clone, Copy)]
pub struct KiviPolicy {
    pub window: usize,
    pub bits: u8,
    pub group: usize,
}

impl Default for KiviPolicy {
    fn default() -> Self {
        KiviPolicy { window: 32, bits: 2, group: 32 }
    }
}

impl CompressionPolicy for KiviPolicy {
    fn name(&self) -> &'static str {
        "KIVI"
    }
    fn requires_full_scores(&self) -> bool {
        false
    }
    fn quant_spec(&self) -> QuantSpec {
        QuantSpec { key_gran: Granularity::Group(self.group),
                    value_gran: Granularity::Group(self.group) }
    }
    fn assign(&self, input: &PolicyInput) -> Vec<PrecisionClass> {
        let n = input.n_tokens;
        let from = n.saturating_sub(self.window);
        (0..n)
            .map(|t| if t >= from { PrecisionClass::Fp16 } else { PrecisionClass::Bits(self.bits) })
            .collect()
    }
    fn analytic_ratio(&self, shape: RatioShape) -> f64 {
        // fp16 window + groupwise low bits for the rest
        let w = (self.window as f64 / shape.l as f64).min(1.0);
        let bits_eff = w * 16.0 + (1.0 - w) * self.bits as f64;
        let bhld = (shape.b * shape.hd * shape.l) as f64;
        let data = 2.0 * bhld * bits_eff;
        let params = (1.0 - w) * (4.0 * bhld / self.group as f64) * 16.0;
        (2.0 * bhld * 16.0) / (data + params)
    }
}

/// MiKV [43]: mixed precision driven by **accumulated** attention scores —
/// the metric the paper shows misidentifies salient tokens (Fig. 3).
#[derive(Debug, Clone, Copy)]
pub struct MikvPolicy {
    pub saliency_ratio: f64,
    pub hi: u8,
    pub lo: u8,
}

impl Default for MikvPolicy {
    fn default() -> Self {
        MikvPolicy { saliency_ratio: 0.6, hi: 4, lo: 2 }
    }
}

impl CompressionPolicy for MikvPolicy {
    fn name(&self) -> &'static str {
        "MiKV"
    }
    fn requires_full_scores(&self) -> bool {
        true
    }
    fn assign(&self, input: &PolicyInput) -> Vec<PrecisionClass> {
        let acc = input.acc_saliency.expect("MiKV needs accumulated scores");
        let mask = select_salient(acc, input.n_tokens, self.saliency_ratio);
        mask.into_iter()
            .map(|m| PrecisionClass::Bits(if m { self.hi } else { self.lo }))
            .collect()
    }
    fn analytic_ratio(&self, shape: RatioShape) -> f64 {
        ratio::mixed_precision(shape, self.hi as u32, self.lo as u32,
                               self.saliency_ratio)
    }
}

/// ZipCache (this paper): mixed precision driven by **normalized** scores
/// (probe-approximated on the fast path).
#[derive(Debug, Clone, Copy)]
pub struct ZipCachePolicy {
    pub saliency_ratio: f64,
    pub hi: u8,
    pub lo: u8,
}

impl Default for ZipCachePolicy {
    fn default() -> Self {
        ZipCachePolicy { saliency_ratio: 0.6, hi: 4, lo: 2 }
    }
}

impl CompressionPolicy for ZipCachePolicy {
    fn name(&self) -> &'static str {
        "ZipCache"
    }
    fn requires_full_scores(&self) -> bool {
        false
    }
    fn assign(&self, input: &PolicyInput) -> Vec<PrecisionClass> {
        let sal = input
            .norm_saliency
            .expect("ZipCache needs normalized (probe) saliency");
        let mask = select_salient(sal, input.n_tokens, self.saliency_ratio);
        mask.into_iter()
            .map(|m| PrecisionClass::Bits(if m { self.hi } else { self.lo }))
            .collect()
    }
    fn analytic_ratio(&self, shape: RatioShape) -> f64 {
        ratio::mixed_precision(shape, self.hi as u32, self.lo as u32,
                               self.saliency_ratio)
    }
}

/// The paper's standard comparison set with Table-3 hyper-parameters.
pub fn standard_policies(saliency_ratio: f64) -> Vec<Box<dyn CompressionPolicy>> {
    vec![
        Box::new(Fp16Policy),
        Box::new(H2oPolicy::default()),
        Box::new(GearPolicy::default()),
        Box::new(KiviPolicy::default()),
        Box::new(MikvPolicy { saliency_ratio, ..Default::default() }),
        Box::new(ZipCachePolicy { saliency_ratio, ..Default::default() }),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn input_with(n: usize) -> (Vec<f32>, Vec<f32>) {
        // accumulated biased toward token 0; normalized flags token n-2
        let acc: Vec<f32> = (0..n).map(|i| (n - i) as f32).collect();
        let mut norm = vec![0.1f32; n];
        norm[n - 2] = 1.0;
        (acc, norm)
    }

    #[test]
    fn fp16_all_full_precision() {
        let p = Fp16Policy;
        let classes = p.assign(&PolicyInput { n_tokens: 8, acc_saliency: None,
                                              norm_saliency: None });
        assert!(classes.iter().all(|c| *c == PrecisionClass::Fp16));
    }

    #[test]
    fn h2o_keeps_recent_and_heavy_evicts_rest() {
        let n = 100;
        let (acc, _) = input_with(n);
        let p = H2oPolicy::default();
        let classes = p.assign(&PolicyInput { n_tokens: n, acc_saliency: Some(&acc),
                                              norm_saliency: None });
        let kept = classes.iter().filter(|c| !c.is_evicted()).count();
        assert!((35..=45).contains(&kept), "{kept}");
        // most recent tokens kept
        assert!(!classes[n - 1].is_evicted());
        // heavy (token 0 under this acc) kept
        assert!(!classes[0].is_evicted());
    }

    #[test]
    fn kivi_window_fp16_rest_low_bits() {
        let p = KiviPolicy::default();
        let classes = p.assign(&PolicyInput { n_tokens: 100, acc_saliency: None,
                                              norm_saliency: None });
        assert_eq!(classes[99], PrecisionClass::Fp16);
        assert_eq!(classes[68], PrecisionClass::Fp16); // window = [68, 100)
        assert_eq!(classes[67], PrecisionClass::Bits(2));
        assert_eq!(classes[10], PrecisionClass::Bits(2));
        assert_eq!(classes.iter().filter(|c| **c == PrecisionClass::Fp16).count(), 32);
    }

    #[test]
    fn mikv_vs_zipcache_diverge_on_biased_scores() {
        // This is the paper's core claim in miniature: with accumulated
        // scores biased to early tokens, MiKV protects token 0 while
        // ZipCache (normalized) protects the genuinely hot late token.
        let n = 100;
        let (acc, norm) = input_with(n);
        let inp = PolicyInput { n_tokens: n, acc_saliency: Some(&acc),
                                norm_saliency: Some(&norm) };
        let mikv = MikvPolicy { saliency_ratio: 0.1, ..Default::default() }.assign(&inp);
        let zip = ZipCachePolicy { saliency_ratio: 0.1, ..Default::default() }.assign(&inp);
        assert_eq!(mikv[0], PrecisionClass::Bits(4));
        assert_eq!(mikv[n - 2], PrecisionClass::Bits(2)); // missed!
        assert_eq!(zip[n - 2], PrecisionClass::Bits(4)); // found
    }

    #[test]
    fn analytic_ratios_match_table3() {
        let shape = RatioShape { b: 1, hd: 4096, l: 840 };
        assert!((H2oPolicy::default().analytic_ratio(shape) - 2.5).abs() < 1e-9);
        assert!((GearPolicy::default().analytic_ratio(shape) - 3.0).abs() < 1e-9);
        let z = ZipCachePolicy { saliency_ratio: 0.6, ..Default::default() };
        assert!((z.analytic_ratio(shape) - 4.98).abs() < 0.08);
    }

    #[test]
    fn standard_set_has_six_policies() {
        let ps = standard_policies(0.6);
        assert_eq!(ps.len(), 6);
        assert_eq!(ps.last().unwrap().name(), "ZipCache");
    }
}
