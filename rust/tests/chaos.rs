//! Chaos suite (DESIGN.md §14): fault-injected shard failures against
//! the 2-shard serving pool, pinning the failure-model contract end to
//! end.
//!
//! * **Parity pin** — with a plan that kills one shard mid-flight, every
//!   accepted request either completes bit-identical to its fault-free
//!   run (survivor or redelivered) or finishes `ShardFailed` carrying a
//!   prefix of its fault-free token stream.  Never duplicates, never
//!   divergent tokens.
//! * **Recovery** — the supervisor restarts the dead shard (restart
//!   counter exactly matches the plan's kill count: restarted shards are
//!   fault-free), flips it alive, and it serves new requests
//!   bit-identically.
//! * **Accounting** — after recovery the queue/load/reserved/resident
//!   gauges all drain to zero: a dead shard leaks nothing.
//!
//! Determinism: the sim runtime + seeded fault plans make outputs exact;
//! the suite never sleeps — waits are yield-spins on supervisor-observable
//! state (metrics counters, alive flags, gauge values) with a wall-clock
//! deadline used only to fail fast on a hang.

use std::time::{Duration, Instant};

use zipcache::config::EngineConfig;
use zipcache::coordinator::{Engine, FinishReason, GenerationResponse};
use zipcache::server::{Server, ServerHandle};
use zipcache::workload::{Task, TaskGen};

fn chaos_config(shards: usize, plan: &str) -> EngineConfig {
    let mut cfg = EngineConfig::load_default("sim", "micro").unwrap();
    cfg.scheduler.shards = shards;
    cfg.parallelism = 1;
    cfg.faults.plan = plan.to_string();
    // Tight supervision so recovery is near-immediate: stall detection in
    // 3 consecutive 1 ms polls, restart with zero backoff.  Production
    // defaults (1 s stall window, 10 ms base backoff) are for real loads.
    cfg.faults.poll_ms = 1;
    cfg.faults.stall_ticks = 3;
    cfg.faults.backoff_base_ms = 0;
    cfg.faults.backoff_cap_ms = 0;
    cfg
}

fn prompts(n: usize) -> Vec<Vec<u16>> {
    let gen = TaskGen::new(Task::Code, 60);
    (0..n).map(|i| gen.sample(i as u64).prompt().to_vec()).collect()
}

/// Fault-free reference outputs for `ps` under the *same* engine config
/// (quantization knobs change tokens, so the baseline must share them) —
/// a bare 1-shard engine with the plan stripped.
fn fault_free(cfg: &EngineConfig, ps: &[Vec<u16>], max_new: usize) -> Vec<Vec<u16>> {
    let mut cfg = cfg.clone();
    cfg.faults.plan = String::new();
    cfg.scheduler.shards = 1;
    let mut engine = Engine::new(cfg).unwrap();
    ps.iter().map(|p| engine.generate(p, max_new).unwrap().tokens).collect()
}

/// Yield-spin until `cond` holds; no sleeps, wall deadline only to turn a
/// supervision hang into a test failure instead of a CI timeout.
fn wait_until(what: &str, mut cond: impl FnMut() -> bool) {
    let deadline = Instant::now() + Duration::from_secs(30);
    while !cond() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::yield_now();
    }
}

/// The §14 parity pin for one resolved request: bit-identical natural
/// completion, or `ShardFailed` with a prefix of the fault-free stream.
fn check_parity(out: &GenerationResponse, fault_free: &[u16]) {
    if out.finish.is_natural() {
        assert_eq!(out.tokens, fault_free,
                   "survivor/redelivered output diverged from fault-free run");
    } else {
        assert_eq!(out.finish, FinishReason::ShardFailed,
                   "unexpected finish reason under fault injection");
        assert!(out.tokens.len() <= fault_free.len()
                    && out.tokens[..] == fault_free[..out.tokens.len()],
                "ShardFailed tokens {:?} are not a prefix of the fault-free stream {:?}",
                out.tokens, fault_free);
    }
}

fn gauges_drained(h: &ServerHandle) -> bool {
    h.queued() == 0
        && h.shard_loads().iter().all(|&l| l == 0)
        && h.shard_reserved_bytes().iter().all(|&b| b == 0)
        && h.shard_resident_bytes().iter().all(|&b| b == 0)
}

/// Submit every prompt, wait for all of them, and return the responses in
/// prompt order.
fn run_batch(h: &ServerHandle, ps: &[Vec<u16>], max_new: usize)
             -> Vec<GenerationResponse> {
    let handles: Vec<_> = ps.iter()
        .map(|p| h.submit(p.clone(), max_new).unwrap())
        .collect();
    handles.into_iter().map(|h| h.wait().unwrap()).collect()
}

#[test]
fn panic_mid_decode_isolates_restarts_and_preserves_parity() {
    let ps = prompts(6);
    let max_new = 8;
    let cfg = chaos_config(2, "shard0:decode:2:panic");
    let base = fault_free(&cfg, &ps, max_new);
    // Min-load routing gives shard 0 half the batch, and every session
    // contributes at least one decode-site hit (the prompt-tail re-feed),
    // so the 2nd hit — and the panic — is guaranteed to fire.
    assert!(base.iter().all(|t| !t.is_empty()), "baselines must decode");

    let server = Server::start(cfg).unwrap();
    let outs = run_batch(&server.handle, &ps, max_new);

    let mut failed = 0u64;
    for (o, b) in outs.iter().zip(&base) {
        check_parity(o, b);
        if o.finish == FinishReason::ShardFailed {
            failed += 1;
        }
    }
    assert!(failed >= 1, "the armed panic never hit a live session");

    wait_until("shard restart after panic", || {
        server.handle.metrics().total.shard_restarts >= 1
            && server.handle.shard_alive().iter().all(|&a| a)
    });
    let snap = server.handle.metrics();
    assert_eq!(snap.total.shard_restarts, 1,
               "one kill clause fires once; restarted shards are fault-free");
    assert_eq!(snap.total.failed_sessions, failed,
               "every failed_session increment must surface as a ShardFailed response");

    wait_until("gauges drained after recovery", || gauges_drained(&server.handle));

    // The restarted shard serves again: with all loads at zero the next
    // submit routes to shard 0 by the lowest-index tie-break, and every
    // replayed prompt must come back bit-identical.
    for (p, b) in ps.iter().zip(&base) {
        let o = server.handle.submit(p.clone(), max_new).unwrap().wait().unwrap();
        assert!(o.finish.is_natural(), "post-recovery request failed: {:?}", o.finish);
        assert_eq!(&o.tokens, b, "post-recovery output diverged");
    }
    server.shutdown().unwrap();
}

#[test]
fn error_mid_prefill_chunk_redelivers_waiting_requests() {
    let ps = prompts(6);
    let max_new = 4;
    let mut cfg = chaos_config(2, "shard0:prefill_chunk:1:error");
    cfg.scheduler.prefill_chunk = 2;
    // One activation at a time: min-load routing splits the batch 3/3, so
    // the victim shard dies holding one active (zero-token) session and
    // at least one *waiting* request — the redelivery path under test.
    cfg.scheduler.max_batch = 1;
    let base = fault_free(&cfg, &ps, max_new);

    let server = Server::start(cfg).unwrap();
    let outs = run_batch(&server.handle, &ps, max_new);

    let mut failed = 0u64;
    for (o, b) in outs.iter().zip(&base) {
        check_parity(o, b);
        if o.finish == FinishReason::ShardFailed {
            failed += 1;
            assert!(o.tokens.is_empty(),
                    "a prefill-time victim never streamed, yet carries tokens {:?}",
                    o.tokens);
        }
    }
    assert!(failed >= 1, "the armed prefill-chunk error never hit an activation");

    wait_until("shard restart after prefill error", || {
        server.handle.metrics().total.shard_restarts >= 1
            && server.handle.shard_alive().iter().all(|&a| a)
    });
    let snap = server.handle.metrics();
    assert_eq!(snap.total.shard_restarts, 1);
    assert!(snap.total.redelivered >= 1,
            "waiting requests on the dead shard must be redelivered, not failed");
    assert_eq!(snap.total.failed_sessions, failed);

    wait_until("gauges drained after recovery", || gauges_drained(&server.handle));
    let o = server.handle.submit(ps[0].clone(), max_new).unwrap().wait().unwrap();
    assert!(o.finish.is_natural());
    assert_eq!(o.tokens, base[0]);
    server.shutdown().unwrap();
}

#[test]
fn error_mid_recompression_fails_session_with_stream_prefix() {
    let max_new = 8;
    let ps = prompts(8);
    // Compress hit 1 is the monolithic prefill compression; hit 2 is the
    // first streaming recompression, which only happens after the session
    // has decoded `recompress_every` tokens — a genuinely mid-stream kill.
    let mut cfg = chaos_config(2, "shard0:compress:2:error");
    cfg.scheduler.prefill_chunk = 0;
    cfg.quant.recompress_every = 4;
    let base = fault_free(&cfg, &ps, max_new);
    // The victim must decode its full budget fault-free so the second
    // compression is guaranteed to fire mid-stream.
    let idx = base.iter().position(|t| t.len() == max_new)
        .expect("no sim prompt decodes the full budget");
    let other = (idx + 1) % ps.len();

    let server = Server::start(cfg).unwrap();
    // Victim routes to shard 0 (lowest-index tie-break on a fresh pool),
    // the second request to shard 1 — it must survive untouched.
    let vh = server.handle.submit(ps[idx].clone(), max_new).unwrap();
    let oh = server.handle.submit(ps[other].clone(), max_new).unwrap();

    let v = vh.wait().unwrap();
    assert_eq!(v.finish, FinishReason::ShardFailed,
               "recompression error must fail the session, got {:?}", v.finish);
    assert!(!v.tokens.is_empty(),
            "a recompression-time victim has streamed tokens before the kill");
    assert!(v.tokens.len() < base[idx].len()
                && v.tokens[..] == base[idx][..v.tokens.len()],
            "ShardFailed tokens must be a strict prefix of the fault-free stream");

    let o = oh.wait().unwrap();
    assert!(o.finish.is_natural());
    assert_eq!(o.tokens, base[other], "survivor on the healthy shard diverged");

    wait_until("shard restart after compress error", || {
        server.handle.metrics().total.shard_restarts >= 1
            && server.handle.shard_alive().iter().all(|&a| a)
    });
    let snap = server.handle.metrics();
    assert_eq!(snap.total.shard_restarts, 1);
    assert_eq!(snap.total.failed_sessions, 1);
    assert_eq!(snap.total.redelivered, 0, "nothing was waiting on the victim shard");

    wait_until("gauges drained after recovery", || gauges_drained(&server.handle));
    // The same request, replayed on the restarted shard, now completes
    // bit-identically — the content-derived-seed exactness argument.
    let o = server.handle.submit(ps[idx].clone(), max_new).unwrap().wait().unwrap();
    assert!(o.finish.is_natural());
    assert_eq!(o.tokens, base[idx]);
    server.shutdown().unwrap();
}

#[test]
fn stalled_shard_is_severed_requests_redelivered_bit_identical() {
    let max_new = 8;
    let ps = prompts(8);
    let mut cfg = chaos_config(2, "shard0:decode:3:stall");
    // Widen the sever window (50 polls x 1 ms): the test submits requests
    // *at* the wedged shard below, and they must be routed before the
    // supervisor flips it dead — µs of submits against a 50 ms window.
    cfg.faults.stall_ticks = 50;
    let base = fault_free(&cfg, &ps, max_new);
    let idx = base.iter().position(|t| t.len() == max_new)
        .expect("no sim prompt decodes the full budget");

    let server = Server::start(cfg).unwrap();
    // Victim routes to shard 0 (fresh pool, lowest-index tie-break).
    // Decode-site hit accounting: hit 1 is the prompt-tail re-feed (emits
    // nothing), and hit k happens inside the call that first emits token
    // k-1 — so the 3rd hit sets the sticky stall flag in the same step
    // that emits token 2, and observing two streamed tokens is the
    // synchronization oracle: from then on shard 0 can never step again.
    let mut vh = server.handle.submit(ps[idx].clone(), max_new).unwrap();
    let streamed: Vec<u16> = (0..2)
        .map(|_| vh.next_token().expect("victim stream ended before the stall"))
        .collect();
    assert_eq!(streamed.as_slice(), &base[idx][..2]);

    // Three more submissions while shard 0 is frozen at load 1: min-load
    // routing sends exactly one of them to the wedged shard (ties resolve
    // either way, but loads can never diverge past one), and that request
    // must be redelivered and still complete bit-identically.
    let others: Vec<usize> = (0..ps.len()).filter(|&i| i != idx).take(3).collect();
    let hs: Vec<_> = others.iter()
        .map(|&i| server.handle.submit(ps[i].clone(), max_new).unwrap())
        .collect();
    for (&i, h) in others.iter().zip(hs) {
        let o = h.wait().unwrap();
        assert!(o.finish.is_natural(),
                "redelivered/survivor request failed: {:?}", o.finish);
        assert_eq!(o.tokens, base[i], "redelivery changed the output");
    }

    // The stalled session is severed with exactly its streamed prefix —
    // the at-most-once contract: no token is ever re-streamed.
    let v = vh.wait().unwrap();
    assert_eq!(v.finish, FinishReason::ShardFailed);
    assert_eq!(v.tokens, base[idx][..2].to_vec(),
               "severed session must keep exactly the tokens it streamed");

    wait_until("stalled shard severed and restarted", || {
        server.handle.metrics().total.shard_restarts >= 1
            && server.handle.shard_alive().iter().all(|&a| a)
    });
    let snap = server.handle.metrics();
    assert_eq!(snap.total.shard_restarts, 1);
    assert_eq!(snap.total.redelivered, 1,
               "exactly one request was staged behind the wedge");
    assert_eq!(snap.total.failed_sessions, 1);

    wait_until("gauges drained after recovery", || gauges_drained(&server.handle));
    let o = server.handle.submit(ps[idx].clone(), max_new).unwrap().wait().unwrap();
    assert!(o.finish.is_natural());
    assert_eq!(o.tokens, base[idx]);
    server.shutdown().unwrap();
}

#[test]
fn every_shard_killed_once_supervisor_restarts_each() {
    let ps = prompts(6);
    let max_new = 6;
    let cfg = chaos_config(2, "shard0:decode:1:error;shard1:decode:2:error");
    let base = fault_free(&cfg, &ps, max_new);

    let server = Server::start(cfg).unwrap();
    let outs = run_batch(&server.handle, &ps, max_new);
    // Both shards die mid-batch; redelivery may itself hit a dying or
    // not-yet-restarted shard, but the parity pin must hold for every
    // single request regardless of how the failures interleave.
    for (o, b) in outs.iter().zip(&base) {
        check_parity(o, b);
    }

    wait_until("both shards restarted", || {
        server.handle.metrics().total.shard_restarts >= 2
            && server.handle.shard_alive().iter().all(|&a| a)
    });
    assert_eq!(server.handle.metrics().total.shard_restarts, 2,
               "each kill clause fires exactly once");

    wait_until("gauges drained after recovery", || gauges_drained(&server.handle));
    for (p, b) in ps.iter().zip(&base) {
        let o = server.handle.submit(p.clone(), max_new).unwrap().wait().unwrap();
        assert!(o.finish.is_natural(), "post-recovery request failed: {:?}", o.finish);
        assert_eq!(&o.tokens, b);
    }
    server.shutdown().unwrap();
}
