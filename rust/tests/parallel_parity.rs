//! Parallel/sequential parity contract (DESIGN.md §5): compressing the
//! same cache through the plane-level worker pool must produce planes that
//! are **byte-identical** to the sequential path — same packed codes, same
//! quantization parameters, same accounting — at every pool width.  Plus:
//! the continuous batcher must preserve per-tag outputs when the engine
//! compresses through a wide pool (artifact-gated, skipped when the AOT
//! artifacts are not built).

use zipcache::config::{EngineConfig, PolicyKind};
use zipcache::coordinator::batcher::{ContinuousBatcher, QueuedRequest};
use zipcache::coordinator::{Engine, GenerationRequest};
use zipcache::kvcache::{CacheLayout, CompressedKV, PrecisionClass, QuantSpec};
use zipcache::quant::Granularity;
use zipcache::util::pool::WorkerPool;
use zipcache::workload::rng::SplitMix64;
use zipcache::workload::{Task, TaskGen};

fn synth_cache(lay: CacheLayout, seed: u64) -> (Vec<f32>, Vec<f32>) {
    let mut rng = SplitMix64::new(seed);
    let n = lay.cache_len();
    let gen = |rng: &mut SplitMix64| -> Vec<f32> {
        (0..n)
            .map(|_| (rng.unit_f64() as f32 - 0.5) * 8.0)
            .collect()
    };
    let k = gen(&mut rng);
    let v = gen(&mut rng);
    (k, v)
}

fn mixed_classes(n: usize, seed: u64) -> Vec<PrecisionClass> {
    let mut rng = SplitMix64::new(seed);
    (0..n)
        .map(|_| match rng.below(6) {
            0 => PrecisionClass::Fp16,
            1 => PrecisionClass::Evicted,
            2 => PrecisionClass::Bits(4),
            3 => PrecisionClass::Bits(8),
            _ => PrecisionClass::Bits(2),
        })
        .collect()
}

#[test]
fn parallel_planes_byte_identical_across_widths() {
    let lay = CacheLayout { layers: 4, heads: 6, seq: 64, d_head: 16 };
    let (k, v) = synth_cache(lay, 99);
    let classes = mixed_classes(48, 7);
    let seq = CompressedKV::compress(&k, &v, lay, &classes, QuantSpec::default());
    for threads in [2usize, 3, 4, 7, 16, 0] {
        let pool = WorkerPool::new(threads);
        let par = CompressedKV::compress_with_pool(
            &k, &v, lay, &classes, QuantSpec::default(), &pool);
        assert_eq!(par.content_digest(), seq.content_digest(),
                   "threads={} digest diverged", pool.threads());
        assert_eq!(par.storage_bytes(2), seq.storage_bytes(2));
        assert_eq!(par.compression_ratio(), seq.compression_ratio());
        // And the materialized fp32 caches agree exactly.
        let n = lay.cache_len();
        let (mut ks, mut vs, mut ms) = (vec![0f32; n], vec![0f32; n],
                                        vec![0f32; lay.seq]);
        let (mut kp, mut vp, mut mp) = (vec![0f32; n], vec![0f32; n],
                                        vec![0f32; lay.seq]);
        seq.materialize_into(&mut ks, &mut vs, &mut ms);
        par.materialize_into(&mut kp, &mut vp, &mut mp);
        assert_eq!(ks, kp);
        assert_eq!(vs, vp);
        assert_eq!(ms, mp);
    }
}

#[test]
fn parity_holds_for_every_quant_spec() {
    let lay = CacheLayout { layers: 2, heads: 3, seq: 40, d_head: 8 };
    let (k, v) = synth_cache(lay, 3);
    let classes = mixed_classes(40, 21);
    let specs = [
        QuantSpec::default(),
        QuantSpec { key_gran: Granularity::Token,
                    value_gran: Granularity::Token },
        QuantSpec { key_gran: Granularity::Group(4),
                    value_gran: Granularity::Group(8) },
        QuantSpec { key_gran: Granularity::ChannelSeparableToken,
                    value_gran: Granularity::Channel },
    ];
    let pool = WorkerPool::new(4);
    for spec in specs {
        let seq = CompressedKV::compress(&k, &v, lay, &classes, spec);
        let par = CompressedKV::compress_with_pool(&k, &v, lay, &classes, spec,
                                                   &pool);
        assert_eq!(par.content_digest(), seq.content_digest(), "{spec:?}");
    }
}

#[test]
fn instrumented_stats_are_consistent() {
    let lay = CacheLayout { layers: 4, heads: 4, seq: 64, d_head: 16 };
    let (k, v) = synth_cache(lay, 11);
    let classes = vec![PrecisionClass::Bits(2); 64];
    let (store, st) = CompressedKV::compress_instrumented(
        &k, &v, lay, &classes, QuantSpec::default(), &WorkerPool::new(4));
    assert_eq!(st.planes, 16);
    assert_eq!(st.threads, 4);
    assert!(st.wall_us >= st.quant_wall_us);
    assert!(store.compression_ratio() > 1.0);
}

// ---- artifact-gated engine/batcher tests ----------------------------------

fn config(parallelism: usize) -> Option<EngineConfig> {
    let dir = std::env::var("ZIPCACHE_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if !std::path::Path::new(&dir).join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built");
        return None;
    }
    let mut cfg = EngineConfig::load_default(dir, "micro").ok()?;
    cfg.policy = PolicyKind::Zipcache;
    cfg.parallelism = parallelism;
    Some(cfg)
}

/// Interleaved scheduling over a wide pool must preserve per-tag outputs:
/// the exact tokens each tagged request produces are independent of the
/// compression pool width.
#[test]
fn batcher_outputs_stable_under_pool() {
    let Some(cfg1) = config(1) else { return };
    let Some(cfg4) = config(4) else { return };
    let run = |cfg: EngineConfig| -> Vec<(u64, Vec<u16>, f64)> {
        let mut engine = Engine::new(cfg).unwrap();
        let info = engine.runtime().model_info().clone();
        let gen = TaskGen::new(Task::Code, info.max_seq - 4);
        let mut b = ContinuousBatcher::new(2, 8);
        for tag in 0..5u64 {
            b.submit(QueuedRequest {
                request: GenerationRequest::new(gen.sample(tag).prompt().to_vec(),
                                                3),
                tag,
            })
            .unwrap();
        }
        b.run_to_completion(&mut engine)
            .unwrap()
            .into_iter()
            .map(|o| (o.tag, o.tokens, o.compression_ratio))
            .collect()
    };
    let seq = run(cfg1);
    let par = run(cfg4);
    assert_eq!(seq.len(), 5);
    assert_eq!(seq, par, "pool width changed batcher outputs");
}
