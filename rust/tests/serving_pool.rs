//! Sharded serving pool contracts (DESIGN.md §8), all runnable with no
//! artifacts: the sim runtime backend (`artifacts_dir = "sim"`) stands in
//! for the PJRT executables with a deterministic host-side model.
//!
//! * **Determinism** — per-tag outputs are bit-identical at any shard
//!   count (sessions are independent; seeds derive from request content,
//!   not admission order), and identical to a bare engine run.
//! * **Admission** — the dispatcher is the single admission point:
//!   `queue_depth` is the exact waiting-request boundary, rejections are
//!   submit-time errors, and malformed requests never reach a shard.
//! * **Decode accounting** — `max_new` boundaries enforced; the compress
//!   histogram no longer double-counts decode wall time.

use zipcache::config::EngineConfig;
use zipcache::coordinator::batcher::{ContinuousBatcher, QueuedRequest};
use zipcache::coordinator::Engine;
use zipcache::server::Server;
use zipcache::workload::{Task, TaskGen};

fn sim_config(shards: usize) -> EngineConfig {
    let mut cfg = EngineConfig::load_default("sim", "micro").unwrap();
    cfg.scheduler.shards = shards;
    cfg.parallelism = 1; // pool-width parity is pinned in parallel_parity.rs
    cfg
}

fn prompts(n: usize) -> Vec<Vec<u16>> {
    let gen = TaskGen::new(Task::Code, 60);
    (0..n).map(|i| gen.sample(i as u64).prompt().to_vec()).collect()
}

#[test]
fn per_tag_outputs_identical_across_shard_counts() {
    let ps = prompts(6);
    let run = |shards: usize| -> Vec<(Vec<u16>, usize, f64)> {
        let mut cfg = sim_config(shards);
        cfg.quant.recompress_every = 4; // several streaming cycles per request
        let server = Server::start(cfg).unwrap();
        let handles: Vec<_> = ps
            .iter()
            .map(|p| server.handle.submit(p.clone(), 8).unwrap())
            .collect();
        let outs: Vec<_> = handles
            .into_iter()
            .map(|h| {
                let o = h.wait().unwrap();
                (o.tokens, o.cache_bytes, o.compression_ratio)
            })
            .collect();
        server.shutdown().unwrap();
        outs
    };
    let one = run(1);
    assert!(one.iter().all(|(t, _, _)| !t.is_empty()));
    assert_eq!(one, run(2), "2 shards changed per-request outputs");
    assert_eq!(one, run(4), "4 shards changed per-request outputs");
}

#[test]
fn server_outputs_match_bare_engine() {
    // Scheduling through the pool must be invisible: the same request
    // through a bare engine yields the same tokens.
    let ps = prompts(3);
    let mut engine = Engine::new(sim_config(1)).unwrap();
    let direct: Vec<Vec<u16>> = ps
        .iter()
        .map(|p| engine.generate(p, 5).unwrap().tokens)
        .collect();
    let server = Server::start(sim_config(2)).unwrap();
    // submit in reverse order: admission order must not matter either
    let served: Vec<Vec<u16>> = {
        let handles: Vec<_> = ps
            .iter()
            .rev()
            .map(|p| server.handle.submit(p.clone(), 5).unwrap())
            .collect();
        let mut outs: Vec<_> =
            handles.into_iter().map(|h| h.wait().unwrap().tokens).collect();
        outs.reverse();
        outs
    };
    server.shutdown().unwrap();
    assert_eq!(direct, served);
}

#[test]
fn smoke_two_shards_complete_all_requests() {
    let server = Server::start(sim_config(2)).unwrap();
    assert_eq!(server.handle.shards(), 2);
    let mut handles = Vec::new();
    for p in prompts(6) {
        handles.push(server.handle.submit(p, 3).unwrap());
    }
    for h in handles {
        let out = h.wait().unwrap();
        assert!(!out.tokens.is_empty() && out.tokens.len() <= 3);
    }
    let snap = server.handle.metrics();
    assert_eq!(snap.shards(), 2);
    assert_eq!(snap.total.requests_completed, 6);
    assert_eq!(
        snap.per_shard.iter().map(|m| m.requests_completed).sum::<u64>(),
        6,
        "per-shard breakdown must sum to the total"
    );
    assert!(snap.total.prefill.count() >= 6);
    server.shutdown().unwrap();
}

#[test]
fn max_new_boundaries() {
    let mut engine = Engine::new(sim_config(1)).unwrap();
    let p = prompts(1).remove(0);
    // max_new = 0 is rejected at session start (the old off-by-one would
    // have emitted one token anyway)...
    assert!(engine.start_session(p.clone(), 0).is_err());
    // ...and the server rejects it at submit time, before it can poison a
    // shard.
    let server = Server::start(sim_config(1)).unwrap();
    assert!(server.handle.submit(p.clone(), 0).is_err());
    assert!(server.handle.submit(Vec::new(), 3).is_err());
    // Window overflow is also a submit-time error (micro window = 64),
    // and the rejection must not consume an admission slot or poison the
    // shard: a well-formed request right after still completes.
    assert!(server.handle.submit(p.clone(), 64).is_err());
    assert_eq!(server.handle.queued() + server.handle.shard_loads()[0], 0);
    // max_new = 1 emits exactly one token.
    let out = engine.generate(&p, 1).unwrap();
    assert_eq!(out.tokens.len(), 1);
    let out = server.handle.generate(p, 1).unwrap();
    assert_eq!(out.tokens.len(), 1);
    server.shutdown().unwrap();
}

#[test]
fn overload_rejects_at_submit_time() {
    let mut cfg = sim_config(1);
    cfg.scheduler.max_batch = 1;
    cfg.scheduler.queue_depth = 1;
    let server = Server::start(cfg).unwrap();
    let ps = prompts(8);
    let mut accepted = Vec::new();
    let mut rejected = 0;
    for p in ps {
        match server.handle.submit(p, 16) {
            Ok(h) => accepted.push(h),
            Err(e) => {
                assert!(e.to_string().contains("queue full"), "{e}");
                rejected += 1;
            }
        }
    }
    // One decode slot + one waiting slot: back-to-back submission of 8
    // requests must hit backpressure (a shard can activate at most one
    // request before the loop finishes submitting).
    assert!(rejected >= 1, "no submit-time backpressure observed");
    let completed = accepted.len();
    for h in accepted {
        h.wait().unwrap();
    }
    assert_eq!(completed + rejected, 8);
    server.shutdown().unwrap();
}

#[test]
fn start_fails_fast_on_unloadable_artifacts() {
    let mut cfg = sim_config(2);
    cfg.artifacts_dir = "definitely_missing_artifacts_dir".into();
    assert!(Server::start(cfg).is_err());
}

#[test]
fn batcher_interleaves_over_sim_engine() {
    // The artifact-gated engine_e2e batcher test, runnable everywhere.
    let mut engine = Engine::new(sim_config(1)).unwrap();
    let mut b = ContinuousBatcher::new(2, 8);
    for (tag, p) in prompts(5).into_iter().enumerate() {
        b.submit(QueuedRequest { prompt: p, max_new: 3, tag: tag as u64 }).unwrap();
    }
    let outcomes = b.run_to_completion(&mut engine).unwrap();
    assert_eq!(outcomes.len(), 5);
    assert!(outcomes.iter().all(|o| !o.output.tokens.is_empty()));
    assert_eq!(engine.metrics.requests_completed, 5);
}

#[test]
fn decode_histogram_excludes_recompression_span() {
    // Pin the accounting fix: per-step decode samples exclude the
    // recompression block, so sum(decode) + sum(compress) cannot exceed
    // the session's total decode wall time.  (The old code recorded the
    // full step span into *both* histograms — sums then overshoot as soon
    // as a cycle fires.)
    let mut cfg = sim_config(1);
    cfg.quant.recompress_every = 2;
    let mut engine = Engine::new(cfg).unwrap();
    let mut session_decode_ms = 0.0;
    for p in prompts(4) {
        session_decode_ms += engine.generate(&p, 12).unwrap().decode_ms;
    }
    let m = &engine.metrics;
    assert!(m.compress.count() >= 1, "expected recompression cycles");
    let decode_total = m.decode.mean_ms() * m.decode.count() as f64;
    let compress_total = m.compress.mean_ms() * m.compress.count() as f64;
    assert!(
        decode_total + compress_total <= session_decode_ms + 0.2,
        "histograms double-count: decode {decode_total:.3}ms + compress \
         {compress_total:.3}ms > sessions {session_decode_ms:.3}ms"
    );
}

#[test]
fn streaming_recompression_triggers_on_sim() {
    let mut cfg = sim_config(1);
    cfg.quant.recompress_every = 4;
    let mut engine = Engine::new(cfg).unwrap();
    for p in prompts(3) {
        let mut sess = engine.start_session(p, 16).unwrap();
        while !sess.is_done() {
            engine.decode_step(&mut sess).unwrap();
        }
    }
    assert!(engine.metrics.compress.count() >= 1, "recompression never fired");
}
